"""Cross-language interchange: numpy must read the .npy files the rust
`corrsh gen` CLI writes (util::npy), and the values must be a valid dataset.

Skipped when the release binary hasn't been built yet."""

import os
import subprocess

import numpy as np
import pytest

BIN = os.path.join(os.path.dirname(__file__), "..", "..", "target", "release", "corrsh")


@pytest.mark.skipif(not os.path.exists(BIN), reason="cargo build --release first")
@pytest.mark.parametrize("kind,n,dim", [("mnist", 12, 64), ("gaussian", 8, 16)])
def test_rust_npy_readable_by_numpy(tmp_path, kind, n, dim):
    out = tmp_path / f"{kind}.npy"
    subprocess.run(
        [BIN, "gen", "--kind", kind, "--n", str(n), "--dim", str(dim),
         "--seed", "3", "--out", str(out)],
        check=True,
        capture_output=True,
    )
    arr = np.load(out)
    assert arr.shape == (n, dim)
    assert arr.dtype == np.float32
    assert np.isfinite(arr).all()
    if kind == "mnist":
        assert arr.min() >= 0.0 and arr.max() <= 1.0
        assert arr.sum() > 0  # ring images are not blank


@pytest.mark.skipif(not os.path.exists(BIN), reason="cargo build --release first")
def test_rust_gen_deterministic(tmp_path):
    outs = []
    for name in ["a.npy", "b.npy"]:
        p = tmp_path / name
        subprocess.run(
            [BIN, "gen", "--kind", "gaussian", "--n", "6", "--dim", "8",
             "--seed", "11", "--out", str(p)],
            check=True,
            capture_output=True,
        )
        outs.append(np.load(p))
    np.testing.assert_array_equal(outs[0], outs[1])
