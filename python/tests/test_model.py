"""L2 model graph: masked chunk sums vs oracle, padding semantics, AOT contract."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref

METRICS = ("l1", "l2", "cosine")


def _rand(rng, shape, scale=1.0):
    return (rng.normal(size=shape) * scale).astype(np.float32)


@pytest.mark.parametrize("metric", METRICS)
def test_chunk_sums_vs_oracle(metric):
    rng = np.random.default_rng(7)
    x, y = _rand(rng, (64, 256)), _rand(rng, (16, 256))
    mask = rng.integers(0, 2, size=16).astype(np.float32)
    got = np.asarray(model.chunk_sums(jnp.array(x), jnp.array(y), jnp.array(mask), metric))
    want = np.asarray(ref.chunk_sums(jnp.array(x), jnp.array(y), jnp.array(mask), metric))
    denom = max(np.abs(want).max(), 1.0)
    np.testing.assert_allclose(got / denom, want / denom, rtol=1e-5, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(
    a=st.integers(1, 70),
    r=st.integers(1, 40),
    d=st.integers(2, 300),
    metric=st.sampled_from(METRICS),
    seed=st.integers(0, 2**31 - 1),
)
def test_chunk_sums_sweep(a, r, d, metric, seed):
    rng = np.random.default_rng(seed)
    x, y = _rand(rng, (a, d)), _rand(rng, (r, d))
    mask = rng.integers(0, 2, size=r).astype(np.float32)
    got = np.asarray(model.chunk_sums(jnp.array(x), jnp.array(y), jnp.array(mask), metric))
    want = np.asarray(ref.chunk_sums(jnp.array(x), jnp.array(y), jnp.array(mask), metric))
    assert got.shape == (a,)
    denom = max(np.abs(want).max(), 1e-6)
    np.testing.assert_allclose(got / denom, want / denom, rtol=5e-4, atol=5e-4)


@pytest.mark.parametrize("metric", METRICS)
def test_ref_padding_is_exact(metric):
    """Zero-padded, mask=0 reference rows must not change the sums at all.

    This is the exact contract the rust bucket planner relies on: a job with
    r_real refs padded up to the R bucket gives identical sums.
    """
    rng = np.random.default_rng(11)
    x = _rand(rng, (32, 128))
    y_real = _rand(rng, (10, 128))
    base = np.asarray(model.chunk_sums(
        jnp.array(x), jnp.array(y_real), jnp.ones(10, jnp.float32), metric))

    y_pad = np.zeros((16, 128), np.float32)
    y_pad[:10] = y_real
    mask = np.zeros(16, np.float32)
    mask[:10] = 1.0
    padded = np.asarray(model.chunk_sums(
        jnp.array(x), jnp.array(y_pad), jnp.array(mask), metric))
    np.testing.assert_allclose(padded, base, rtol=1e-6, atol=1e-5)


@pytest.mark.parametrize("metric", METRICS)
def test_arm_padding_rows_discardable(metric):
    """Padded arm rows change nothing for the real arms (rust discards them)."""
    rng = np.random.default_rng(13)
    x_real = _rand(rng, (12, 64))
    y = _rand(rng, (8, 64))
    mask = np.ones(8, np.float32)
    base = np.asarray(model.chunk_sums(jnp.array(x_real), jnp.array(y), jnp.array(mask), metric))

    x_pad = np.zeros((16, 64), np.float32)
    x_pad[:12] = x_real
    padded = np.asarray(model.chunk_sums(jnp.array(x_pad), jnp.array(y), jnp.array(mask), metric))
    np.testing.assert_allclose(padded[:12], base, rtol=1e-6, atol=1e-5)


def test_mask_all_zero_gives_zero():
    rng = np.random.default_rng(17)
    x, y = _rand(rng, (8, 32)), _rand(rng, (4, 32))
    out = np.asarray(model.chunk_sums(
        jnp.array(x), jnp.array(y), jnp.zeros(4, jnp.float32), "l1"))
    np.testing.assert_allclose(out, 0.0, atol=1e-7)


def test_entry_returns_tuple():
    entry = model.chunk_sums_entry("l2")
    rng = np.random.default_rng(19)
    out = entry(jnp.array(_rand(rng, (4, 16))), jnp.array(_rand(rng, (4, 16))),
                jnp.ones(4, jnp.float32))
    assert isinstance(out, tuple) and len(out) == 1 and out[0].shape == (4,)
