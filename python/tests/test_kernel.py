"""Kernel-vs-oracle correctness: the CORE signal for the L1 Pallas layer.

Hypothesis sweeps shapes (including non-tile-divisible and degenerate ones),
value scales and tile overrides; every case asserts the Pallas kernels agree
with the pure-jnp oracle in ref.py."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import distances as K
from compile.kernels import ref

METRICS = list(K.METRICS)
RNG = np.random.default_rng(1234)


def _rand(shape, scale=1.0, rng=RNG):
    return (rng.normal(size=shape) * scale).astype(np.float32)


def _assert_close(metric, got, want, scale=1.0):
    # l1 sums ~d terms; tolerance scales with magnitude of the result.
    atol = 1e-4 * max(1.0, scale) * (1.0 if metric != "l1" else 10.0)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=atol)


# ---------------------------------------------------------------------------
# Fixed-shape exactness on tile-aligned shapes
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("metric", METRICS)
@pytest.mark.parametrize("shape", [(64, 16, 512), (64, 64, 256), (128, 64, 1024)])
def test_tile_aligned(metric, shape):
    a, r, d = shape
    x, y = _rand((a, d)), _rand((r, d))
    got = np.asarray(K.pairwise_distances(jnp.array(x), jnp.array(y), metric))
    want = np.asarray(ref.pairwise(jnp.array(x), jnp.array(y), metric))
    _assert_close(metric, got, want)


# ---------------------------------------------------------------------------
# Hypothesis sweep: arbitrary shapes exercise the pad/slice wrapper
# ---------------------------------------------------------------------------

@settings(max_examples=40, deadline=None)
@given(
    a=st.integers(1, 90),
    r=st.integers(1, 90),
    d=st.integers(1, 600),
    metric=st.sampled_from(METRICS),
    scale=st.sampled_from([1e-3, 1.0, 1e3]),
    seed=st.integers(0, 2**31 - 1),
)
def test_shape_sweep(a, r, d, metric, scale, seed):
    rng = np.random.default_rng(seed)
    x = _rand((a, d), scale, rng)
    y = _rand((r, d), scale, rng)
    got = np.asarray(K.pairwise_distances(jnp.array(x), jnp.array(y), metric))
    want = np.asarray(ref.pairwise(jnp.array(x), jnp.array(y), metric))
    assert got.shape == (a, r)
    # normalize out the scale so tolerances are scale-free
    denom = max(np.abs(want).max(), 1e-6)
    np.testing.assert_allclose(got / denom, want / denom, rtol=3e-4, atol=3e-4)


@settings(max_examples=15, deadline=None)
@given(
    metric=st.sampled_from(METRICS),
    ta=st.sampled_from([8, 32, 64]),
    tr=st.sampled_from([8, 16, 64]),
    tk=st.sampled_from([32, 128, 512]),
)
def test_tile_override_invariance(metric, ta, tr, tk):
    """Result must not depend on the tiling schedule."""
    x, y = _rand((70, 300)), _rand((50, 300))
    base = np.asarray(K.pairwise_distances(jnp.array(x), jnp.array(y), metric))
    tiled = np.asarray(
        K.pairwise_distances(jnp.array(x), jnp.array(y), metric, ta=ta, tr=tr, tk=tk))
    np.testing.assert_allclose(tiled, base, rtol=2e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# Metric properties
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("metric", METRICS)
def test_self_distance_zero(metric):
    x = _rand((20, 64))
    d = np.asarray(K.pairwise_distances(jnp.array(x), jnp.array(x), metric))
    # l2 uses the matmul factorization ||x||^2+||y||^2-2x.y, whose diagonal is
    # cancellation-limited: |raw err| ~ eps*||x||^2, sqrt amplifies to ~eps^.5*||x||.
    atol = 0.05 if metric == "l2" else 2e-3
    np.testing.assert_allclose(np.diag(d), 0.0, atol=atol)


@pytest.mark.parametrize("metric", ["l1", "l2"])
def test_symmetry(metric):
    x, y = _rand((17, 100)), _rand((23, 100))
    dxy = np.asarray(K.pairwise_distances(jnp.array(x), jnp.array(y), metric))
    dyx = np.asarray(K.pairwise_distances(jnp.array(y), jnp.array(x), metric))
    np.testing.assert_allclose(dxy, dyx.T, rtol=1e-5, atol=1e-4)


def test_cosine_range_and_scale_invariance():
    x, y = np.abs(_rand((10, 50))), np.abs(_rand((12, 50)))
    d1 = np.asarray(K.pairwise_distances(jnp.array(x), jnp.array(y), "cosine"))
    d2 = np.asarray(K.pairwise_distances(jnp.array(x * 7.5), jnp.array(y * 0.3), "cosine"))
    np.testing.assert_allclose(d1, d2, rtol=1e-4, atol=1e-5)
    assert (d1 > -1e-5).all() and (d1 < 2 + 1e-5).all()


def test_cosine_zero_row():
    x = _rand((4, 32))
    x[2] = 0.0
    y = _rand((5, 32))
    d = np.asarray(K.pairwise_distances(jnp.array(x), jnp.array(y), "cosine"))
    np.testing.assert_allclose(d[2], 1.0, atol=1e-6)  # zero row -> distance 1


def test_l1_exact_hand_values():
    x = jnp.array([[0.0, 0.0], [1.0, 2.0]])
    y = jnp.array([[1.0, 1.0], [-1.0, 0.5]])
    d = np.asarray(K.pairwise_distances(x, y, "l1"))
    np.testing.assert_allclose(d, [[2.0, 1.5], [1.0, 3.5]], atol=1e-6)


def test_l2_exact_hand_values():
    x = jnp.array([[0.0, 0.0]])
    y = jnp.array([[3.0, 4.0]])
    d = np.asarray(K.pairwise_distances(x, y, "l2"))
    np.testing.assert_allclose(d, [[5.0]], atol=1e-6)


def test_unknown_metric_raises():
    x = jnp.zeros((2, 3))
    with pytest.raises(ValueError):
        K.pairwise_raw(x, x, "chebyshev")


def test_shape_mismatch_raises():
    with pytest.raises(ValueError):
        K.pairwise_raw(jnp.zeros((2, 3)), jnp.zeros((2, 4)), "l1")
