"""AOT path: HLO text artifacts well-formed, manifest contract, caching."""

import json
import os

import pytest

from compile import aot


def test_artifact_name_roundtrip():
    assert aot.artifact_name("l1", 64, 16, 256) == "chunk_sums_l1_a64_r16_d256"


def test_parse_buckets():
    assert aot.parse_buckets("a64r16,a256r64") == ((64, 16), (256, 64))


def test_lower_one_emits_hlo_text():
    text = aot.lower_one("l2", 8, 4, 32)
    assert text.startswith("HloModule")
    assert "ENTRY" in text
    # the entry layout must match the manifest contract
    assert "f32[8,32]" in text and "f32[4,32]" in text and "f32[4]" in text
    assert "f32[8]" in text  # output


@pytest.mark.parametrize("metric", ["l1", "l2", "cosine"])
def test_lower_each_metric(metric):
    text = aot.lower_one(metric, 8, 4, 16)
    assert text.startswith("HloModule")


def test_build_manifest_and_cache(tmp_path):
    out = str(tmp_path)
    m1 = aot.build(out, ("l1",), ((8, 4),), (16,))
    assert len(m1["artifacts"]) == 1
    entry = m1["artifacts"][0]
    path = os.path.join(out, entry["file"])
    assert os.path.exists(path)
    mtime = os.path.getmtime(path)

    # Second build must hit the cache (no rewrite).
    m2 = aot.build(out, ("l1",), ((8, 4),), (16,))
    assert os.path.getmtime(path) == mtime
    assert m2["artifacts"][0]["sha256_16"] == entry["sha256_16"]

    with open(os.path.join(out, "manifest.json")) as f:
        manifest = json.load(f)
    assert manifest["entry"] == "chunk_sums"
    assert [i["name"] for i in manifest["inputs"]] == ["x_arms", "y_refs", "mask"]
    assert manifest["output"]["tuple"] is True


def test_build_force_rebuilds(tmp_path):
    out = str(tmp_path)
    aot.build(out, ("l2",), ((8, 4),), (16,))
    path = os.path.join(out, "chunk_sums_l2_a8_r4_d16.hlo.txt")
    with open(path, "w") as f:
        f.write("corrupted")
    m = aot.build(out, ("l2",), ((8, 4),), (16,), force=True)
    with open(path) as f:
        assert f.read().startswith("HloModule")
    assert m["artifacts"][0]["sha256_16"] != "corrupted"
