"""AOT compile path: lower the L2 chunk graph to HLO *text* artifacts.

Run once at build time (``make artifacts``); Python never appears on the
request path.  For every (metric, arm-bucket A, ref-bucket R, dim d) in the
manifest this jits ``model.chunk_sums_entry(metric)`` with static shapes,
lowers to stablehlo, converts to an XlaComputation and dumps **HLO text**:

    artifacts/chunk_sums_<metric>_a<A>_r<R>_d<d>.hlo.txt

Why text and not ``lowered.compile()`` / serialized HloModuleProto: jax >=
0.5 emits protos with 64-bit instruction ids which the rust ``xla`` crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the HLO text parser
reassigns ids, so text round-trips cleanly (see /opt/xla-example/README.md).

The manifest is the single source of truth shared with the rust runtime: it
is also written to ``artifacts/manifest.json`` with the bucket list, input
order and dtype contract, which ``rust/src/runtime`` reads at startup.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# ---------------------------------------------------------------------------
# Default bucket manifest.
#
# Arm buckets x ref buckets define the job shapes the rust batch planner can
# pick from; dims cover the synthetic datasets (test=256, mnist-like=784,
# rnaseq-like=2048).  Keep the cross product lean: every entry costs a
# trace+lower at build time and a compile at rust startup (lazily, on first
# use).  The planner only needs a ladder, not a lattice: big buckets for the
# early rounds, one small bucket for the tail.
# ---------------------------------------------------------------------------
DEFAULT_METRICS = ("l1", "l2", "cosine")
DEFAULT_AR_BUCKETS = ((64, 16), (64, 64), (256, 64), (256, 256), (1024, 256))
DEFAULT_DIMS = (256, 784, 2048)


def artifact_name(metric: str, a: int, r: int, d: int) -> str:
    return f"chunk_sums_{metric}_a{a}_r{r}_d{d}"


def to_hlo_text(lowered) -> str:
    """stablehlo MLIR -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_one(metric: str, a: int, r: int, d: int) -> str:
    entry = model.chunk_sums_entry(metric)
    args = (
        jax.ShapeDtypeStruct((a, d), jnp.float32),   # x_arms
        jax.ShapeDtypeStruct((r, d), jnp.float32),   # y_refs
        jax.ShapeDtypeStruct((r,), jnp.float32),     # mask
    )
    return to_hlo_text(jax.jit(entry).lower(*args))


def build(out_dir: str, metrics, ar_buckets, dims, force: bool = False) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    entries = []
    t0 = time.time()
    n_built = n_cached = 0
    for metric in metrics:
        for (a, r) in ar_buckets:
            for d in dims:
                name = artifact_name(metric, a, r, d)
                path = os.path.join(out_dir, name + ".hlo.txt")
                if force or not os.path.exists(path):
                    text = lower_one(metric, a, r, d)
                    with open(path, "w") as f:
                        f.write(text)
                    n_built += 1
                else:
                    n_cached += 1
                with open(path, "rb") as f:
                    digest = hashlib.sha256(f.read()).hexdigest()[:16]
                entries.append({
                    "name": name,
                    "file": name + ".hlo.txt",
                    "metric": metric,
                    "arms": a,
                    "refs": r,
                    "dim": d,
                    "sha256_16": digest,
                })
    manifest = {
        "version": 1,
        "entry": "chunk_sums",
        # Input order/dtypes the rust runtime must honour.
        "inputs": [
            {"name": "x_arms", "shape": ["arms", "dim"], "dtype": "f32"},
            {"name": "y_refs", "shape": ["refs", "dim"], "dtype": "f32"},
            {"name": "mask", "shape": ["refs"], "dtype": "f32"},
        ],
        "output": {"shape": ["arms"], "dtype": "f32", "tuple": True},
        "artifacts": entries,
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    dt = time.time() - t0
    print(f"aot: {n_built} built, {n_cached} cached, "
          f"{len(entries)} artifacts in {out_dir} ({dt:.1f}s)", file=sys.stderr)
    return manifest


def parse_buckets(spec: str):
    """Parse 'a64r16,a256r64' into ((64,16),(256,64))."""
    out = []
    for part in spec.split(","):
        a_part, r_part = part.strip().lstrip("a").split("r")
        out.append((int(a_part), int(r_part)))
    return tuple(out)


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out", default="../artifacts",
                   help="output dir (or path ending in .hlo.txt for single)")
    p.add_argument("--metrics", default=",".join(DEFAULT_METRICS))
    p.add_argument("--buckets", default=None,
                   help="e.g. 'a64r16,a256r64' (default: built-in ladder)")
    p.add_argument("--dims", default=",".join(str(d) for d in DEFAULT_DIMS))
    p.add_argument("--force", action="store_true", help="rebuild even if cached")
    args = p.parse_args()

    out_dir = args.out
    # Makefile passes .../model.hlo.txt as a stamp target; treat its parent
    # as the artifact dir and also write the stamp.
    stamp = None
    if out_dir.endswith(".hlo.txt"):
        stamp = out_dir
        out_dir = os.path.dirname(out_dir) or "."

    metrics = tuple(m.strip() for m in args.metrics.split(",") if m.strip())
    buckets = parse_buckets(args.buckets) if args.buckets else DEFAULT_AR_BUCKETS
    dims = tuple(int(d) for d in args.dims.split(","))
    manifest = build(out_dir, metrics, buckets, dims, force=args.force)

    if stamp:
        # Stamp file doubles as a tiny smoke artifact: the first entry's text.
        first = manifest["artifacts"][0]
        with open(os.path.join(out_dir, first["file"])) as f:
            text = f.read()
        with open(stamp, "w") as f:
            f.write(text)


if __name__ == "__main__":
    main()
