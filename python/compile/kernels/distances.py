"""L1: Pallas tiled pairwise-distance kernels.

The pull hot-spot of Correlated Sequential Halving is the batched distance
evaluation ``D[a, r] = d(X[a, :], Y[r, :])`` between an arm tile and the
round's shared reference tile.  These kernels tile the computation over
(arm-tile TA, ref-tile TR, feature-tile TK) with an f32 accumulator that
lives across the feature grid axis — the Pallas/TPU shape of the schedule a
GPU paper would express with threadblocks (see DESIGN.md §6).

TPU mapping notes (the kernels run here under ``interpret=True`` on CPU —
Mosaic custom-calls cannot execute on the CPU PJRT plugin — but are written
for the TPU memory hierarchy):

* ``l2`` and ``cosine`` route the inner reduction through ``jnp.dot`` so a
  real TPU lowering hits the 128x128 MXU systolic array
  (``||x-y||^2 = ||x||^2 + ||y||^2 - 2 x.y``).
* ``l1`` has no matmul factorization; it loops over the ref tile rows with a
  vectorized VPU body, keeping the (TA, TK) operand resident in VMEM.
* BlockSpecs stage HBM->VMEM; the (TA, TR) accumulator is the kernel output
  block, zero-initialised on the first feature step.  Default tiles
  (TA, TR, TK) = (64, 64, 512): VMEM footprint = (64+64)*512*4 inputs +
  64*64*4 acc ~= 278 KiB, far under 16 MiB, leaving headroom for double
  buffering by the Mosaic pipeliner.

Raw kernel outputs (accumulated over feature tiles):

* l1     -> sum_k |x_k - y_k|                  (the distance itself)
* l2     -> sum_k (x_k - y_k)^2                (squared; sqrt applied in L2)
* cosine -> sum_k x_k * y_k                    (dot; 1 - dot on unit rows in L2)

``pairwise_raw`` wraps the kernels with pad-to-tile-multiple handling so the
hypothesis test sweep can hit arbitrary shapes; ``make artifacts`` only ever
lowers bucket shapes that divide the tiles exactly.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

METRICS = ("l1", "l2", "cosine")

# Default tile sizes (see module docstring for the VMEM accounting).
DEFAULT_TA = 64
DEFAULT_TR = 64
DEFAULT_TK = 512


def _tiles(n_arms: int, n_refs: int, dim: int,
           ta: int | None, tr: int | None, tk: int | None) -> Tuple[int, int, int]:
    """Clamp default tiles to the problem size (small test shapes)."""
    ta = min(ta or DEFAULT_TA, n_arms)
    tr = min(tr or DEFAULT_TR, n_refs)
    tk = min(tk or DEFAULT_TK, dim)
    return ta, tr, tk


def _l1_kernel(x_ref, y_ref, o_ref):
    """o[a, r] += sum_k |x[a, k] - y[r, k]|, accumulated over the k grid axis.

    The ref tile is walked row-by-row with a fori_loop so the intermediate is
    (TA, TK) — never the (TA, TR, TK) broadcast cube, which would blow VMEM.
    """
    k_step = pl.program_id(2)

    @pl.when(k_step == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]  # (TA, TK)
    y = y_ref[...]  # (TR, TK)

    def body(r, acc):
        # (TA,) column of partial distances for reference row r.
        col = jnp.sum(jnp.abs(x - y[r, :][None, :]), axis=1)
        return acc.at[:, r].add(col)

    o_ref[...] = jax.lax.fori_loop(0, y.shape[0], body, o_ref[...])


def _l2sq_kernel(x_ref, y_ref, o_ref):
    """o[a, r] += sum_k (x - y)^2 via the matmul factorization (MXU path)."""
    k_step = pl.program_id(2)

    @pl.when(k_step == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]
    y = y_ref[...]
    xsq = jnp.sum(x * x, axis=1)[:, None]          # (TA, 1)
    ysq = jnp.sum(y * y, axis=1)[None, :]          # (1, TR)
    xy = jnp.dot(x, y.T, preferred_element_type=jnp.float32)  # (TA, TR) on MXU
    o_ref[...] += xsq + ysq - 2.0 * xy


def _dot_kernel(x_ref, y_ref, o_ref):
    """o[a, r] += x[a, :] . y[r, :]  (cosine similarity on pre-normalized rows)."""
    k_step = pl.program_id(2)

    @pl.when(k_step == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(x_ref[...], y_ref[...].T,
                          preferred_element_type=jnp.float32)


_KERNELS = {"l1": _l1_kernel, "l2": _l2sq_kernel, "cosine": _dot_kernel}


@functools.partial(jax.jit, static_argnames=("metric", "ta", "tr", "tk"))
def pairwise_raw(x: jax.Array, y: jax.Array, metric: str,
                 ta: int | None = None, tr: int | None = None,
                 tk: int | None = None) -> jax.Array:
    """Raw accumulated pairwise quantity (see module docstring) of shape (A, R).

    Pads A/R/K up to tile multiples (zero padding), runs the Pallas kernel,
    slices back.  Zero-padded features contribute 0 under all three raw
    reductions, so padding is exact.
    """
    if metric not in METRICS:
        raise ValueError(f"unknown metric {metric!r}; expected one of {METRICS}")
    if x.ndim != 2 or y.ndim != 2 or x.shape[1] != y.shape[1]:
        raise ValueError(f"shape mismatch: x {x.shape}, y {y.shape}")

    n_arms, dim = x.shape
    n_refs = y.shape[0]
    t_a, t_r, t_k = _tiles(n_arms, n_refs, dim, ta, tr, tk)

    pad_a = (-n_arms) % t_a
    pad_r = (-n_refs) % t_r
    pad_k = (-dim) % t_k
    xp = jnp.pad(x.astype(jnp.float32), ((0, pad_a), (0, pad_k)))
    yp = jnp.pad(y.astype(jnp.float32), ((0, pad_r), (0, pad_k)))
    pa, pk = xp.shape
    pr = yp.shape[0]

    grid = (pa // t_a, pr // t_r, pk // t_k)
    out = pl.pallas_call(
        _KERNELS[metric],
        grid=grid,
        in_specs=[
            pl.BlockSpec((t_a, t_k), lambda a, r, k: (a, k)),
            pl.BlockSpec((t_r, t_k), lambda a, r, k: (r, k)),
        ],
        out_specs=pl.BlockSpec((t_a, t_r), lambda a, r, k: (a, r)),
        out_shape=jax.ShapeDtypeStruct((pa, pr), jnp.float32),
        interpret=True,  # CPU PJRT cannot execute Mosaic custom-calls
    )(xp, yp)
    return out[:n_arms, :n_refs]


def normalize_rows(x: jax.Array, eps: float = 1e-12) -> jax.Array:
    """Unit-normalize rows for cosine distance; zero rows stay zero."""
    norms = jnp.sqrt(jnp.sum(x * x, axis=1, keepdims=True))
    return x / jnp.maximum(norms, eps)


def pairwise_distances(x: jax.Array, y: jax.Array, metric: str,
                       ta: int | None = None, tr: int | None = None,
                       tk: int | None = None) -> jax.Array:
    """Finished pairwise distances (A, R) for any supported metric.

    l1: raw. l2: sqrt(max(raw, 0)) — raw can be -eps from cancellation.
    cosine: 1 - <x_hat, y_hat> (zero rows get distance 1 to everything).
    """
    if metric == "cosine":
        raw = pairwise_raw(normalize_rows(x), normalize_rows(y), metric,
                           ta=ta, tr=tr, tk=tk)
        return 1.0 - raw
    raw = pairwise_raw(x, y, metric, ta=ta, tr=tr, tk=tk)
    if metric == "l2":
        return jnp.sqrt(jnp.maximum(raw, 0.0))
    return raw
