"""Pure-jnp correctness oracle for the Pallas distance kernels.

No Pallas, no tiling, no padding: the straightforward O(A*R*d) definition of
each metric.  Every kernel and the L2 model graph are asserted against these
in python/tests/ (hypothesis sweeps shapes and values)."""

from __future__ import annotations

import jax.numpy as jnp


def l1(x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """Pairwise l1 distances: out[a, r] = sum_k |x[a,k] - y[r,k]|."""
    return jnp.sum(jnp.abs(x[:, None, :] - y[None, :, :]), axis=-1)


def l2(x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """Pairwise euclidean distances."""
    d = x[:, None, :] - y[None, :, :]
    return jnp.sqrt(jnp.sum(d * d, axis=-1))


def cosine(x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """Pairwise cosine distances: 1 - <x,y>/(|x||y|); zero rows -> distance 1."""
    eps = 1e-12
    xn = x / jnp.maximum(jnp.linalg.norm(x, axis=1, keepdims=True), eps)
    yn = y / jnp.maximum(jnp.linalg.norm(y, axis=1, keepdims=True), eps)
    return 1.0 - xn @ yn.T


METRIC_FNS = {"l1": l1, "l2": l2, "cosine": cosine}


def pairwise(x: jnp.ndarray, y: jnp.ndarray, metric: str) -> jnp.ndarray:
    return METRIC_FNS[metric](x, y)


def chunk_sums(x_arms: jnp.ndarray, y_refs: jnp.ndarray, mask: jnp.ndarray,
               metric: str) -> jnp.ndarray:
    """Oracle for the L2 model entrypoint: masked per-arm distance sums."""
    d = pairwise(x_arms, y_refs, metric)
    return d @ mask.astype(d.dtype)
