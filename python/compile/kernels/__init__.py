# L1: Pallas kernels for the batched-distance pull hot-spot + jnp oracle.
from . import ref  # noqa: F401
from .distances import (  # noqa: F401
    DEFAULT_TA,
    DEFAULT_TK,
    DEFAULT_TR,
    METRICS,
    normalize_rows,
    pairwise_distances,
    pairwise_raw,
)
