"""L2: the JAX compute graph the rust coordinator executes per batch-plan job.

One entrypoint, ``chunk_sums``: given an arm tile ``x_arms (A, d)``, the
round's shared reference tile ``y_refs (R, d)`` and a ``mask (R,)`` marking
which reference rows are real (vs bucket padding), return the per-arm partial
centrality sums

    sums[a] = sum_r mask[r] * d(x_arms[a], y_refs[r])            shape (A,)

The pairwise distances come from the L1 Pallas kernels
(``kernels.distances``), so the whole thing lowers into a single HLO module:
Pallas tiles (interpret=True -> plain HLO) + the masked reduction, which XLA
fuses.  The rust coordinator accumulates these partial sums into arm state
across jobs; padded *arm* rows are simply discarded on readback (padding
semantics are exact — see pairwise_raw docstring).

Cosine note: rows are normalized inside the graph so the rust side feeds raw
feature rows for every metric.  Padded zero rows normalize to zero -> cosine
distance 1 -> harmless, masked or discarded.

AOT contract (aot.py): for each (metric, A, R, d) bucket this function is
jitted and lowered with static shapes; artifact name
``chunk_sums_<metric>_a<A>_r<R>_d<d>.hlo.txt``.  Inputs in order:
(x_arms f32[A,d], y_refs f32[R,d], mask f32[R]).  Output: 1-tuple of
f32[A] (lowered with return_tuple=True; rust unwraps with to_tuple1).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernels import distances as K


@functools.partial(jax.jit, static_argnames=("metric", "ta", "tr", "tk"))
def chunk_sums(x_arms: jax.Array, y_refs: jax.Array, mask: jax.Array,
               metric: str, ta: int | None = None, tr: int | None = None,
               tk: int | None = None) -> jax.Array:
    """Masked per-arm partial centrality sums for one batch-plan job."""
    mask = mask.astype(jnp.float32)
    if metric == "cosine":
        raw = K.pairwise_raw(K.normalize_rows(x_arms), K.normalize_rows(y_refs),
                             "cosine", ta=ta, tr=tr, tk=tk)
        dists = 1.0 - raw
    elif metric == "l2":
        raw = K.pairwise_raw(x_arms, y_refs, "l2", ta=ta, tr=tr, tk=tk)
        dists = jnp.sqrt(jnp.maximum(raw, 0.0))
    elif metric == "l1":
        dists = K.pairwise_raw(x_arms, y_refs, "l1", ta=ta, tr=tr, tk=tk)
    else:
        raise ValueError(f"unknown metric {metric!r}")
    # Masked reduction over refs; XLA fuses this with the kernel epilogue.
    return dists @ mask


def chunk_sums_entry(metric: str):
    """Positional-only wrapper with the metric baked in, for AOT lowering."""

    def entry(x_arms, y_refs, mask):
        return (chunk_sums(x_arms, y_refs, mask, metric),)

    entry.__name__ = f"chunk_sums_{metric}"
    return entry
