//! Determinism under concurrency: for a fixed seed, every algorithm must
//! produce bitwise-identical decisions regardless of engine thread count —
//! including on datasets with duplicate points, which exercise the
//! total_cmp + arm-index tie-break path (duplicate rows have bitwise-equal
//! sums under a shared reference set, so any ordering leak from sort
//! internals or chunking would surface here).

use std::sync::Arc;

use corrsh::bandits::{CorrSh, MedoidAlgorithm, SeqHalving};
use corrsh::config::KMedoidsConfig;
use corrsh::data::synth::{gaussian, SynthConfig};
use corrsh::data::{Data, DenseData};
use corrsh::distance::Metric;
use corrsh::engine::NativeEngine;
use corrsh::kmedoids::{BanditKMedoids, ClusteringAlgorithm};
use corrsh::util::rng::Rng;

/// A mixture dataset where every point appears twice (row i and row
/// n/2 + i are bitwise identical) — maximal tie pressure.
fn duplicated_mixture(half: usize, clusters: usize, seed: u64) -> Arc<Data> {
    let base = gaussian::generate_mixture(&SynthConfig {
        n: half,
        dim: 8,
        seed,
        clusters,
        ..Default::default()
    })
    .to_dense();
    let mut raw = base.data.clone();
    raw.extend_from_slice(&base.data);
    Arc::new(Data::Dense(DenseData::new(half * 2, base.dim, raw)))
}

#[test]
fn medoid_identical_across_worker_counts_with_duplicates() {
    let data = duplicated_mixture(150, 3, 9);
    let one = NativeEngine::with_threads(data.clone(), Metric::L2, 1);
    let eight = NativeEngine::with_threads(data, Metric::L2, 8);
    for seed in 0..8 {
        let a = CorrSh::with_pulls_per_arm(16.0).run(&one, &mut Rng::seeded(seed));
        let b = CorrSh::with_pulls_per_arm(16.0).run(&eight, &mut Rng::seeded(seed));
        assert_eq!(a.best, b.best, "seed {seed}: medoid diverged across worker counts");
        assert_eq!(a.pulls, b.pulls, "seed {seed}: pull ledgers diverged");
        assert_eq!(a.rounds, b.rounds, "seed {seed}: round traces diverged");
        let s = SeqHalving::with_pulls_per_arm(16.0).run(&one, &mut Rng::seeded(seed));
        let t = SeqHalving::with_pulls_per_arm(16.0).run(&eight, &mut Rng::seeded(seed));
        assert_eq!(s.best, t.best, "seed {seed}: seq-halving diverged");
    }
}

#[test]
fn kmedoids_identical_across_worker_counts_with_duplicates() {
    let data = duplicated_mixture(200, 4, 3);
    let one = NativeEngine::with_threads(data.clone(), Metric::L2, 1);
    let eight = NativeEngine::with_threads(data, Metric::L2, 8);
    let cfg = KMedoidsConfig { k: 4, ..Default::default() };
    for seed in 0..3 {
        let a = BanditKMedoids::new(cfg.clone()).run(&one, &mut Rng::seeded(seed));
        let b = BanditKMedoids::new(cfg.clone()).run(&eight, &mut Rng::seeded(seed));
        assert_eq!(a.medoids, b.medoids, "seed {seed}: medoid sets diverged");
        assert_eq!(a.assignments, b.assignments, "seed {seed}: assignments diverged");
        assert_eq!(a.pulls(), b.pulls(), "seed {seed}: pull counts diverged");
        assert_eq!(
            a.loss_trajectory,
            b.loss_trajectory,
            "seed {seed}: loss trajectories diverged"
        );
    }
}

#[test]
fn block_sums_bitwise_identical_across_worker_counts() {
    // The property the two tests above rest on, checked directly: chunk
    // boundaries change with the thread count, but each arm's f64 sum is
    // accumulated in reference order, so outputs are bitwise identical.
    let data = duplicated_mixture(300, 5, 17);
    let n = data.n();
    let one = NativeEngine::with_threads(data.clone(), Metric::L2, 1);
    let eight = NativeEngine::with_threads(data, Metric::L2, 8);
    let arms: Vec<usize> = (0..n).collect();
    let mut rng = Rng::seeded(0);
    let refs = rng.sample_without_replacement(n, 64);
    let mut a = vec![0f64; n];
    let mut b = vec![0f64; n];
    one.pull_block(&arms, &refs, &mut a);
    eight.pull_block(&arms, &refs, &mut b);
    assert_eq!(a, b);
    // Duplicate rows really do produce bitwise-equal sums (the tie the
    // selection layer must break by index).
    for i in 0..n / 2 {
        assert_eq!(a[i], a[n / 2 + i], "rows {i} and {} are duplicates", n / 2 + i);
    }
}
