//! The paper's defining invariant, verified mechanically: **within every
//! round of Correlated Sequential Halving, all surviving arms are scored
//! against the SAME reference set J_r** (Algorithm 1 line 3) — drawn
//! without replacement — while the uncorrelated ablation must NOT share
//! references across arms. An instrumented engine records every
//! (arms, refs) batch it serves.

use std::sync::Mutex;

use corrsh::bandits::{CorrSh, MedoidAlgorithm, RandBaseline, SeqHalving};
use corrsh::distance::Metric;
use corrsh::engine::PullEngine;
use corrsh::util::rng::Rng;

/// Deterministic fake dataset: d(i, j) = |i − j| mod 97 (cheap, asymmetric
/// θ profile, no ties at the top for the sizes used here).
struct RecordingEngine {
    n: usize,
    batches: Mutex<Vec<(Vec<usize>, Vec<usize>)>>,
}

impl RecordingEngine {
    fn new(n: usize) -> Self {
        RecordingEngine { n, batches: Mutex::new(Vec::new()) }
    }

    fn batches(&self) -> Vec<(Vec<usize>, Vec<usize>)> {
        self.batches.lock().unwrap().clone()
    }
}

impl PullEngine for RecordingEngine {
    fn n(&self) -> usize {
        self.n
    }
    fn dim(&self) -> usize {
        1
    }
    fn metric(&self) -> Metric {
        Metric::L1
    }
    fn pull(&self, a: usize, r: usize) -> f32 {
        self.batches.lock().unwrap().push((vec![a], vec![r]));
        ((a as i64 - r as i64).unsigned_abs() % 97) as f32 + a as f32 * 1e-3
    }
    fn pull_block(&self, arms: &[usize], refs: &[usize], out: &mut [f64]) {
        self.batches.lock().unwrap().push((arms.to_vec(), refs.to_vec()));
        for (k, &a) in arms.iter().enumerate() {
            out[k] = refs
                .iter()
                .map(|&r| ((a as i64 - r as i64).unsigned_abs() % 97) as f64 + a as f64 * 1e-3)
                .sum();
        }
    }
}

#[test]
fn corrsh_shares_one_reference_set_per_round() {
    for n in [17, 64, 300, 1000] {
        let engine = RecordingEngine::new(n);
        let res = CorrSh::with_pulls_per_arm(8.0).run(&engine, &mut Rng::seeded(n as u64));
        let batches = engine.batches();
        // one batch per round, arms = full survivor set
        assert_eq!(batches.len(), res.rounds.len(), "n={n}: one pull_block per round");
        let mut prev_survivors = n;
        for (round, (arms, refs)) in res.rounds.iter().zip(&batches) {
            assert_eq!(arms.len(), round.survivors, "n={n} r={}", round.r);
            assert_eq!(refs.len(), round.t, "n={n} r={}", round.r);
            assert!(arms.len() <= prev_survivors);
            prev_survivors = arms.len();
            // THE correlation invariant: the round used a single shared J_r
            // (a single batch serves every arm) drawn without replacement:
            let mut sorted = refs.clone();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted.len(), refs.len(), "n={n}: J_r has duplicates");
            assert!(sorted.iter().all(|&r| r < n));
        }
        // survivor sets nest: arms of round r+1 ⊆ arms of round r
        for w in batches.windows(2) {
            let prev: std::collections::HashSet<_> = w[0].0.iter().collect();
            assert!(
                w[1].0.iter().all(|a| prev.contains(a)),
                "n={n}: survivors are not a subset of the previous round"
            );
        }
    }
}

#[test]
fn uncorrelated_sh_draws_independent_references() {
    let n = 256;
    let engine = RecordingEngine::new(n);
    let _ = SeqHalving::with_pulls_per_arm(8.0).run(&engine, &mut Rng::seeded(3));
    let batches = engine.batches();
    // every batch is single-arm (per-arm reference draws)
    assert!(batches.iter().all(|(arms, _)| arms.len() == 1));
    // round 0: n arms, each with its own reference multiset; they must not
    // all be identical (that would be correlation)
    let round0: Vec<&Vec<usize>> = batches.iter().take(n).map(|(_, r)| r).collect();
    let all_same = round0.windows(2).all(|w| w[0] == w[1]);
    assert!(!all_same, "uncorrelated SH reused one reference set — ablation is broken");
}

#[test]
fn rand_is_correlated_but_not_adaptive() {
    let n = 128;
    let engine = RecordingEngine::new(n);
    let _ = RandBaseline::new(20).run(&engine, &mut Rng::seeded(1));
    let batches = engine.batches();
    // single batch: every arm vs one shared reference set, no adaptivity
    assert_eq!(batches.len(), 1);
    assert_eq!(batches[0].0.len(), n);
    assert_eq!(batches[0].1.len(), 20);
}

#[test]
fn corrsh_budget_monotone_in_rounds() {
    // more budget ⇒ same or more refs per round, never fewer rounds of
    // useful work (exact-exit may shorten the schedule)
    let n = 500;
    let engine = RecordingEngine::new(n);
    let small = CorrSh::with_pulls_per_arm(4.0).run(&engine, &mut Rng::seeded(9));
    let big = CorrSh::with_pulls_per_arm(64.0).run(&engine, &mut Rng::seeded(9));
    for (a, b) in small.rounds.iter().zip(&big.rounds) {
        assert!(b.t >= a.t, "round {}: bigger budget drew fewer refs", a.r);
    }
    assert!(big.pulls > small.pulls);
}
