//! Property contracts for this PR's two tiers (DESIGN.md §17):
//!
//! * **Reuse-cache neutrality** — k-medoids with the cross-round pull-reuse
//!   cache on vs off at equal seeds returns bitwise-identical medoids,
//!   assignments, loss, and loss trajectory, while consuming *strictly
//!   fewer* engine-boundary pulls (measured by [`CountingEngine`], not the
//!   algorithm's own ledger) — and both ledgers still match their engine
//!   counters exactly.
//! * **trimed exactness** — the triangle-inequality elimination tier
//!   reports the same medoid as the exact O(n²) sweep across metrics ×
//!   dense/sparse data × resident/sharded backends × shard widths, never
//!   spending more than the `n² + anchors·n` worst case.

use std::path::PathBuf;
use std::sync::Arc;

use corrsh::bandits::{Exact, MedoidAlgorithm, Trimed};
use corrsh::config::KMedoidsConfig;
use corrsh::data::store::{self, ShardedData, StoreOptions};
use corrsh::data::synth::{Kind, SynthConfig};
use corrsh::data::{loader, Data};
use corrsh::distance::Metric;
use corrsh::engine::{CountingEngine, NativeEngine, PreparedEngine};
use corrsh::kmedoids::{BanditKMedoids, ClusteringAlgorithm, KMedoidsResult};
use corrsh::util::rng::Rng;
use corrsh::util::testing;

fn tmp(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("corrsh-reuse-trimed-tests").join(name);
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Write `data` to disk and re-open it through the sharded store (the
/// `corrsh shard` conversion path), `rows_per_shard` wide.
fn shard(data: &Data, dir: &PathBuf, rows_per_shard: usize) -> ShardedData {
    let input = if data.is_sparse() {
        let Data::Sparse(s) = data else { unreachable!() };
        let mut text = format!("csr {} {}\n", s.n, s.dim);
        for i in 0..s.n {
            let r = s.row(i);
            for (&c, &v) in r.indices.iter().zip(r.values) {
                text.push_str(&format!("{i} {c} {v}\n"));
            }
        }
        let p = dir.join("input.csr");
        std::fs::write(&p, text).unwrap();
        p
    } else {
        let p = dir.join("input.npy");
        loader::save_dense_npy(&p, &data.to_dense()).unwrap();
        p
    };
    let manifest = store::shard_file(&input, dir.join("shards"), rows_per_shard).unwrap();
    ShardedData::open_with(&manifest, &StoreOptions::default()).unwrap()
}

/// Everything about a k-medoids run the reuse cache must not change.
fn fingerprint(r: &KMedoidsResult) -> (Vec<usize>, Vec<usize>, u64, Vec<u64>) {
    (
        r.medoids.clone(),
        r.assignments.clone(),
        r.loss.to_bits(),
        r.loss_trajectory.iter().map(|l| l.to_bits()).collect(),
    )
}

#[test]
fn reuse_cache_is_result_neutral_and_strictly_cheaper() {
    let cases = testing::cases_from_env(12);
    testing::check(
        "reuse-neutrality",
        cases,
        |rng| {
            let n = 120 + rng.below(280);
            let k = 2 + rng.below(4);
            let sparse = rng.chance(0.3);
            let seed = rng.below(1 << 20) as u64;
            (n, k, sparse, seed)
        },
        |&(n, k, sparse, seed), _| {
            let cfg = SynthConfig {
                n,
                dim: 12,
                seed,
                clusters: k,
                density: 0.1,
                ..Default::default()
            };
            let (data, metric) = if sparse {
                (Kind::RnaSeq.generate(&cfg), Metric::L1)
            } else {
                (Kind::Mixture.generate(&cfg), Metric::L2)
            };
            let engine = CountingEngine::new(NativeEngine::new(data, metric));

            let mut run = |reuse: bool| {
                let kcfg = KMedoidsConfig { k, reuse_cache: reuse, ..Default::default() };
                engine.reset();
                let res = BanditKMedoids::new(kcfg).run(&engine, &mut Rng::seeded(seed ^ 0x5EED));
                if res.pulls() != engine.pulls() {
                    return Err(format!(
                        "reuse={reuse}: ledger {} != engine counter {}",
                        res.pulls(),
                        engine.pulls()
                    ));
                }
                Ok((fingerprint(&res), engine.pulls()))
            };
            let (fp_on, pulls_on) = run(true)?;
            let (fp_off, pulls_off) = run(false)?;
            if fp_on != fp_off {
                return Err("cache-on run diverged from cache-off run".into());
            }
            if pulls_on >= pulls_off {
                return Err(format!(
                    "reuse saved nothing: {pulls_on} on vs {pulls_off} off"
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn trimed_matches_exact_across_metrics_data_and_shard_widths() {
    let cases = testing::cases_from_env(24);
    for metric in Metric::ALL {
        testing::check_shrink(
            &format!("trimed-exactness-{metric}"),
            cases,
            |rng| {
                let n = 2 + rng.below(140);
                let dim = 1 + rng.below(32);
                let rows_per_shard = 1 + rng.below(n + 4);
                let sparse = rng.chance(0.5);
                let anchors = 1 + rng.below(8);
                let seed = rng.below(1 << 20) as u64;
                (n, dim, rows_per_shard, sparse, anchors, seed)
            },
            |&(n, dim, rows_per_shard, sparse, anchors, seed)| {
                let mut out = Vec::new();
                for nn in testing::shrink_usize(n, 2) {
                    out.push((nn, dim, rows_per_shard.min(nn + 1), sparse, anchors, seed));
                }
                for dd in testing::shrink_usize(dim, 1) {
                    out.push((n, dd, rows_per_shard, sparse, anchors, seed));
                }
                for aa in testing::shrink_usize(anchors, 1) {
                    out.push((n, dim, rows_per_shard, sparse, aa, seed));
                }
                out
            },
            |&(n, dim, rows_per_shard, sparse, anchors, seed), _| {
                let cfg = SynthConfig { n, dim, seed, density: 0.2, ..Default::default() };
                let data = if sparse {
                    Kind::RnaSeq.generate(&cfg)
                } else {
                    Kind::Gaussian.generate(&cfg)
                };
                let dir = tmp(&format!("trimed-{metric}-{n}-{dim}-{rows_per_shard}-{sparse}"));
                let sharded = Arc::new(Data::Sharded(shard(&data, &dir, rows_per_shard)));

                let resident = CountingEngine::new(NativeEngine::new(data, metric));
                let sh_prep = PreparedEngine::prepare(sharded, metric);
                let sh_engine = NativeEngine::from_prepared(Arc::new(sh_prep), 2);

                let truth = Exact::new().run(&resident, &mut Rng::seeded(0)).best;
                resident.reset();
                let algo = Trimed::new(anchors);
                let res = algo.run(&resident, &mut Rng::seeded(0));
                if res.best != truth {
                    return Err(format!("resident: trimed {} != exact {truth}", res.best));
                }
                if res.pulls != resident.pulls() {
                    return Err(format!(
                        "ledger {} != engine counter {}",
                        res.pulls,
                        resident.pulls()
                    ));
                }
                let worst = (n as u64) * (n as u64) + (anchors as u64) * (n as u64);
                if res.pulls > worst {
                    return Err(format!("{} pulls over the n²+a·n cap {worst}", res.pulls));
                }
                let sh_res = algo.run(&sh_engine, &mut Rng::seeded(0));
                if sh_res.best != truth {
                    return Err(format!("sharded: trimed {} != exact {truth}", sh_res.best));
                }
                if sh_res.pulls != res.pulls {
                    return Err(format!(
                        "backend-dependent pull count: resident {} vs sharded {}",
                        res.pulls, sh_res.pulls
                    ));
                }
                Ok(())
            },
        );
    }
}
