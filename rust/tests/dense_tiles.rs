//! Engine-level contract of the dense tiled kernel layer (DESIGN.md §11):
//! `pull_block` / `pull_matrix` on dense data route through the packed-tile
//! kernels and must (a) match the seed per-pair scalar reference within
//! 1e-5 relative on every metric, dim tail, and tile remainder, (b) stay
//! bitwise deterministic across worker counts, and (c) survive
//! near-duplicate rows without NaN or negative distances.

use std::sync::Arc;

use corrsh::data::synth::{gaussian, mnist, SynthConfig};
use corrsh::data::{Data, DenseData};
use corrsh::distance::{dense, Metric};
use corrsh::engine::kernel::DenseTileCtx;
use corrsh::engine::simd::{self, Variant};
use corrsh::engine::{NativeEngine, PullEngine};
use corrsh::util::rng::Rng;
use corrsh::util::testing;

#[test]
fn tiled_engine_matches_scalar_reference_property() {
    // check_shrink: a failure minimizes (dim, arms, refs) before panicking,
    // so kernel regressions report at the smallest reproducing geometry.
    testing::check_shrink(
        "engine-dense-tile-parity",
        // Each case prepares three engines over fresh data — keep the count
        // CI-friendly; the kernel-level property test sweeps more shapes.
        (testing::default_cases() / 4).max(8),
        |rng| {
            let dim = [1, 3, 4, 7, 8, 33, 65, 129][rng.below(8)];
            let n_arms = 4 + rng.below(29); // ≥ ARM_TILE so the tiles engage
            let n_refs = 1 + rng.below(37);
            (dim, n_arms, n_refs)
        },
        |&(dim, n_arms, n_refs)| {
            let mut out = Vec::new();
            for d in testing::shrink_usize(dim, 1) {
                out.push((d, n_arms, n_refs));
            }
            for a in testing::shrink_usize(n_arms, 4) {
                out.push((dim, a, n_refs));
            }
            for r in testing::shrink_usize(n_refs, 1) {
                out.push((dim, n_arms, r));
            }
            out
        },
        |&(dim, n_arms, n_refs), rng| {
            let n = 60;
            let data = Arc::new(gaussian::generate(&SynthConfig {
                n,
                dim,
                seed: rng.below(1 << 30) as u64,
                ..Default::default()
            }));
            let arms: Vec<usize> = (0..n_arms).map(|_| rng.below(n)).collect();
            let refs: Vec<usize> = (0..n_refs).map(|_| rng.below(n)).collect();
            for metric in Metric::ALL {
                let e = NativeEngine::with_threads(data.clone(), metric, 4);
                let mut tiled = vec![0f64; n_arms];
                let mut scalar = vec![0f64; n_arms];
                e.pull_block(&arms, &refs, &mut tiled);
                e.pull_block_scalar(&arms, &refs, &mut scalar);
                for (k, (&t, &s)) in tiled.iter().zip(&scalar).enumerate() {
                    if (t - s).abs() > 1e-5 * s.abs().max(1.0) {
                        return Err(format!(
                            "{metric} d={dim} arm {k}: tiled {t} vs scalar {s}"
                        ));
                    }
                }
                let mut tm = vec![0f32; n_arms * n_refs];
                let mut sm = vec![0f32; n_arms * n_refs];
                e.pull_matrix(&arms, &refs, &mut tm);
                e.pull_matrix_scalar(&arms, &refs, &mut sm);
                for (p, (&t, &s)) in tm.iter().zip(&sm).enumerate() {
                    if (t - s).abs() > 1e-5 * s.abs().max(1.0) {
                        return Err(format!(
                            "{metric} d={dim} cell {p}: tiled {t} vs scalar {s}"
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn acceptance_geometry_mnist_784() {
    // The ISSUE's acceptance shape (MNIST-like d=784, L2) at a CI-sized n:
    // tile parity on the exact geometry the ≥3× throughput target is
    // measured on (`benches/engine.rs` dense-tiles group).
    let data = Arc::new(mnist::generate(&SynthConfig {
        n: 200,
        dim: 784,
        seed: 4,
        ..Default::default()
    }));
    let mut rng = Rng::seeded(9);
    let arms: Vec<usize> = (0..199).collect(); // 199 % 4 != 0
    let refs = rng.sample_without_replacement(200, 61); // 61 % 8 != 0
    for metric in Metric::ALL {
        let e = NativeEngine::with_threads(data.clone(), metric, 8);
        let mut tiled = vec![0f64; arms.len()];
        let mut scalar = vec![0f64; arms.len()];
        e.pull_block(&arms, &refs, &mut tiled);
        e.pull_block_scalar(&arms, &refs, &mut scalar);
        for (k, (&t, &s)) in tiled.iter().zip(&scalar).enumerate() {
            assert!(
                (t - s).abs() < 1e-5 * s.abs().max(1.0),
                "{metric} arm {k}: tiled {t} vs scalar {s}"
            );
        }
    }
}

#[test]
fn tiled_block_bitwise_deterministic_across_workers() {
    // Ported from an ad-hoc nested loop to the shared property harness:
    // each case draws a worker count, arm/ref geometry off the tile grid,
    // and a metric, and must reproduce the single-threaded result bitwise.
    let data = Arc::new(mnist::generate(&SynthConfig {
        n: 300,
        dim: 144,
        seed: 6,
        ..Default::default()
    }));
    let data = &data;
    testing::check(
        "engine-dense-tile-worker-determinism",
        (testing::default_cases() / 4).max(12),
        |rng| {
            let threads = 2 + rng.below(7);
            let n_arms = 5 + rng.below(293); // off the ARM_TILE grid on purpose
            let n_refs = 1 + rng.below(60);
            let metric_idx = rng.below(3);
            (threads, n_arms, n_refs, metric_idx)
        },
        |&(threads, n_arms, n_refs, metric_idx), rng| {
            let metric = Metric::ALL[metric_idx];
            let arms: Vec<usize> = (0..n_arms).collect();
            let refs = rng.sample_without_replacement(300, n_refs);
            let one = NativeEngine::with_threads(data.clone(), metric, 1);
            let mut base_sums = vec![0f64; arms.len()];
            let mut base_mat = vec![0f32; arms.len() * refs.len()];
            one.pull_block(&arms, &refs, &mut base_sums);
            one.pull_matrix(&arms, &refs, &mut base_mat);
            let e = NativeEngine::with_threads(data.clone(), metric, threads);
            let mut sums = vec![0f64; arms.len()];
            e.pull_block(&arms, &refs, &mut sums);
            if sums != base_sums {
                return Err(format!("{metric}: block diverged at {threads} workers"));
            }
            let mut mat = vec![0f32; arms.len() * refs.len()];
            e.pull_matrix(&arms, &refs, &mut mat);
            if mat != base_mat {
                return Err(format!("{metric}: matrix diverged at {threads} workers"));
            }
            Ok(())
        },
    );
}

#[test]
fn simd_kernels_bitwise_equal_scalar_reference() {
    // DESIGN.md §14 contract at the engine-facing layer: for every metric,
    // dim (fold boundaries included), and off-grid arm/ref geometry, the
    // runtime-detected vector kernel must reproduce the scalar reference
    // *bitwise* — both the f64 block sums and the f32 matrix cells. On
    // hardware without AVX2/NEON, detect() is Scalar and this pins the
    // dispatch plumbing instead of vector lanes — never a false pass.
    let detected = simd::detect();
    // Fold boundaries (63/64/65, 127/128/129, ...) plus small dims and one
    // past the last full segment; drawn per case.
    let dims: [usize; 20] = [
        1, 2, 3, 5, 8, 31, 63, 64, 65, 96, 127, 128, 129, 191, 192, 193, 255, 256, 257, 300,
    ];
    testing::check(
        "engine-simd-bitwise-parity",
        (testing::default_cases() / 4).max(12),
        |rng| {
            let dim = dims[rng.below(dims.len())];
            let n_arms = 1 + rng.below(30); // straddles the ARM_TILE grid
            let n_refs = 1 + rng.below(37); // straddles the 8-lane grid
            let threads = 1 + rng.below(4);
            let seed = rng.below(1 << 30) as u64;
            (dim, n_arms, n_refs, threads, seed)
        },
        |&(dim, n_arms, n_refs, threads, seed), rng| {
            let n = 50;
            let data = gaussian::generate(&SynthConfig { n, dim, seed, ..Default::default() });
            let data = match &data {
                Data::Dense(d) => d,
                _ => unreachable!("gaussian is dense"),
            };
            let norms: Vec<f32> = (0..n).map(|i| dense::norm(data.row(i))).collect();
            let sq: Vec<f64> = (0..n).map(|i| dense::sqnorm_f64(data.row(i))).collect();
            let arms: Vec<usize> = (0..n_arms).map(|_| rng.below(n)).collect();
            let refs: Vec<usize> = (0..n_refs).map(|_| rng.below(n)).collect();
            for metric in Metric::ALL {
                let base = DenseTileCtx::new(data, metric, Some(&norms[..]), Some(&sq[..]));
                let scalar = base.with_variant(Variant::Scalar);
                let simd_ctx = DenseTileCtx::new(data, metric, Some(&norms[..]), Some(&sq[..]))
                    .with_variant(detected);
                let mut s_sums = vec![0f64; n_arms];
                let mut v_sums = vec![0f64; n_arms];
                scalar.block_sums(&arms, &refs, threads, &mut s_sums);
                simd_ctx.block_sums(&arms, &refs, threads, &mut v_sums);
                if s_sums != v_sums {
                    return Err(format!(
                        "{metric} d={dim}: {detected} block sums diverged from scalar"
                    ));
                }
                let mut s_mat = vec![0f32; n_arms * n_refs];
                let mut v_mat = vec![0f32; n_arms * n_refs];
                scalar.matrix(&arms, &refs, threads, &mut s_mat);
                simd_ctx.matrix(&arms, &refs, threads, &mut v_mat);
                if s_mat != v_mat {
                    return Err(format!(
                        "{metric} d={dim}: {detected} matrix diverged from scalar"
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn near_duplicate_rows_never_nan_or_negative() {
    // Rows crafted so the L2 norm expansion cancels catastrophically:
    // identical rows, rows offset by ~1e-7 relative, and a large-magnitude
    // cluster. The clamp + direct-kernel fallback must keep every distance
    // finite and non-negative through the full engine path.
    let dim = 784;
    let mut rng = Rng::seeded(3);
    let base: Vec<f32> = (0..dim).map(|_| (rng.gaussian() * 1e5).abs() as f32).collect();
    let mut raw = Vec::new();
    for i in 0..24 {
        // rows 0..8 identical, 8..16 nudged by one part in ~1e7, 16..24 far
        let scale = if i < 16 { 1.0f32 } else { 1.5 + (i as f32) * 0.01 };
        let nudge = if (8..16).contains(&i) { 1e-2f32 * (i as f32 - 7.0) } else { 0.0 };
        raw.extend(base.iter().map(|&v| v * scale + nudge));
    }
    let data = Arc::new(Data::Dense(DenseData::new(24, dim, raw)));
    let arms: Vec<usize> = (0..24).collect();
    for metric in [Metric::L2, Metric::L1, Metric::Cosine] {
        let e = NativeEngine::with_threads(data.clone(), metric, 4);
        let mut mat = vec![0f32; 24 * 24];
        e.pull_matrix(&arms, &arms, &mut mat);
        let mut sums = vec![0f64; 24];
        e.pull_block(&arms, &arms, &mut sums);
        let floor = if metric == Metric::Cosine { -1e-5 } else { 0.0 };
        for (p, &d) in mat.iter().enumerate() {
            assert!(!d.is_nan(), "{metric} cell {p} is NaN");
            assert!(d >= floor, "{metric} cell {p} went negative: {d}");
        }
        for (k, &s) in sums.iter().enumerate() {
            assert!(!s.is_nan() && s >= floor as f64 * 24.0, "{metric} sum {k}: {s}");
        }
        assert_eq!(e.nan_pulls(), 0, "{metric}: clamp/fallback leaked NaN");
        if metric == Metric::L2 {
            // identical rows are exactly zero apart (fallback, not clamp)
            for i in 0..8 {
                for j in 0..8 {
                    assert_eq!(mat[i * 24 + j], 0.0, "identical rows ({i},{j})");
                }
            }
        }
    }
}
