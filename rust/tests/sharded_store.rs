//! Integration contract of the sharded dataset layer (DESIGN.md §12):
//!
//! * **Storage round-trip property** — random (n, d, rows_per_shard,
//!   metric, dense/sparse) datasets written through the `corrsh shard`
//!   conversion path, reloaded as `ShardedData`, and held bitwise equal to
//!   the resident path for `row()`, `norms()`, the `PreparedEngine`
//!   reductions, and full `pull_matrix` output — through the default
//!   reader *and* an eviction-forcing pinned reader (and the mmap reader
//!   when the `mmap` feature is compiled in).
//! * **End-to-end determinism** — corrSH medoid + k-medoids on a planted
//!   mixture return identical winners and pull counts for resident vs
//!   sharded backends across worker counts and shard sizes that do/don't
//!   divide n.
//! * **Server soak** — concurrent clients over a manifest-registered
//!   dataset while another client churns register/unregister; responses
//!   stay byte-identical (modulo wall-clock) to a resident reference and
//!   the `shard_cache` gauges stay monotone.
//! * **npy format fixtures** — v1/v2/v3 headers with non-64-byte padding
//!   (checked in under `rust/tests/fixtures/`) parse to the same payload.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::path::PathBuf;
use std::sync::Arc;

use corrsh::bandits::{CorrSh, MedoidAlgorithm};
use corrsh::config::KMedoidsConfig;
use corrsh::data::store::{self, cache_stats, ShardedData, StoreOptions};
use corrsh::data::synth::{Kind, SynthConfig};
use corrsh::data::{loader, Data};
use corrsh::distance::Metric;
use corrsh::engine::{CountingEngine, NativeEngine, PreparedEngine, PullEngine};
use corrsh::kmedoids::{BanditKMedoids, ClusteringAlgorithm};
use corrsh::server::{self, State};
use corrsh::util::json;
use corrsh::util::rng::Rng;
use corrsh::util::testing;

fn tmp(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("corrsh-sharded-store-tests").join(name);
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Save `data` resident (.npy or .csr text), then run it through the CLI
/// conversion path (`store::shard_file`, what `corrsh shard` calls).
fn shard_via_cli(data: &Data, dir: &PathBuf, rows_per_shard: usize) -> PathBuf {
    let input = if data.is_sparse() {
        let Data::Sparse(s) = data else { unreachable!() };
        let mut text = format!("csr {} {}\n", s.n, s.dim);
        for i in 0..s.n {
            let r = s.row(i);
            for (&c, &v) in r.indices.iter().zip(r.values) {
                // exact round-trip: f32 -> shortest decimal -> f32 is lossless
                text.push_str(&format!("{i} {c} {v}\n"));
            }
        }
        let p = dir.join("input.csr");
        std::fs::write(&p, text).unwrap();
        p
    } else {
        let p = dir.join("input.npy");
        loader::save_dense_npy(&p, &data.to_dense()).unwrap();
        p
    };
    store::shard_file(&input, dir.join("shards"), rows_per_shard).unwrap()
}

/// Reader configurations the round-trip is checked under. The pinned
/// configs run everywhere; the default config additionally exercises mmap
/// when the feature is compiled in.
fn reader_configs(dim: usize) -> Vec<(&'static str, StoreOptions)> {
    vec![
        ("default", StoreOptions::default()),
        (
            "pinned-evicting",
            StoreOptions {
                cache_bytes: (2 * dim * 4).max(64),
                block_bytes: (dim * 4).max(32),
                force_pinned: true,
            },
        ),
    ]
}

#[test]
fn storage_roundtrip_property_per_metric() {
    // Acceptance floor: >= 64 seeded cases per metric by default
    // (CORRSH_PROPTEST_CASES still scales it down for quick local runs).
    let cases = testing::cases_from_env(64);
    for metric in Metric::ALL {
        testing::check_shrink(
            &format!("sharded-roundtrip-{metric}"),
            cases,
            |rng| {
                let n = 2 + rng.below(90);
                let dim = 1 + rng.below(48);
                // shard sizes below, at, and above n
                let rows_per_shard = 1 + rng.below(n + 4);
                let sparse = rng.chance(0.5);
                (n, dim, rows_per_shard, sparse)
            },
            |&(n, dim, rows_per_shard, sparse)| {
                let mut out = Vec::new();
                for nn in testing::shrink_usize(n, 2) {
                    out.push((nn, dim, rows_per_shard.min(nn + 1), sparse));
                }
                for dd in testing::shrink_usize(dim, 1) {
                    out.push((n, dd, rows_per_shard, sparse));
                }
                for rr in testing::shrink_usize(rows_per_shard, 1) {
                    out.push((n, dim, rr, sparse));
                }
                out
            },
            |&(n, dim, rows_per_shard, sparse), rng| {
                let cfg = SynthConfig {
                    n,
                    dim,
                    seed: rng.below(1 << 30) as u64,
                    density: 0.2,
                    ..Default::default()
                };
                let data = if sparse {
                    Kind::RnaSeq.generate(&cfg)
                } else {
                    Kind::Gaussian.generate(&cfg)
                };
                let dir = tmp(&format!("prop-{metric}-{n}-{dim}-{rows_per_shard}-{sparse}"));
                let manifest = shard_via_cli(&data, &dir, rows_per_shard);
                let resident = Arc::new(data);
                let res_prep = PreparedEngine::prepare(resident.clone(), metric);
                let res_engine = NativeEngine::from_prepared(Arc::new(res_prep), 4);
                let arms: Vec<usize> = (0..n).collect();
                let mut res_mat = vec![0f32; n * n];
                res_engine.pull_matrix(&arms, &arms, &mut res_mat);
                let res_norms = resident.norms();

                for (reader, opts) in reader_configs(dim) {
                    let sd = ShardedData::open_with(&manifest, &opts)
                        .map_err(|e| format!("open ({reader}): {e}"))?;
                    let sharded = Arc::new(Data::Sharded(sd));
                    // row() / densify_row_into bitwise
                    let mut a = vec![0f32; dim];
                    let mut b = vec![0f32; dim];
                    for i in 0..n {
                        resident.densify_row_into(i, &mut a);
                        sharded.densify_row_into(i, &mut b);
                        if a.iter().map(|v| v.to_bits()).ne(b.iter().map(|v| v.to_bits())) {
                            return Err(format!("{reader}: row {i} bytes diverged"));
                        }
                    }
                    // norms bitwise
                    let sh_norms = sharded.norms();
                    if res_norms.iter().map(|v| v.to_bits()).ne(
                        sh_norms.iter().map(|v| v.to_bits()),
                    ) {
                        return Err(format!("{reader}: norms diverged"));
                    }
                    // PreparedEngine reductions bitwise
                    let sh_prep = PreparedEngine::prepare(sharded.clone(), metric);
                    let rp = res_engine.prepared();
                    if rp.norms() != sh_prep.norms() {
                        return Err(format!("{reader}: prepared norms diverged"));
                    }
                    if rp.sq_norms() != sh_prep.sq_norms() {
                        return Err(format!("{reader}: prepared sq_norms diverged"));
                    }
                    if rp.row_reductions() != sh_prep.row_reductions() {
                        return Err(format!("{reader}: prepared row reductions diverged"));
                    }
                    // full pull_matrix bitwise
                    let sh_engine = NativeEngine::from_prepared(Arc::new(sh_prep), 4);
                    let mut sh_mat = vec![0f32; n * n];
                    sh_engine.pull_matrix(&arms, &arms, &mut sh_mat);
                    for (p, (x, y)) in res_mat.iter().zip(&sh_mat).enumerate() {
                        if x.to_bits() != y.to_bits() {
                            return Err(format!(
                                "{reader}: pull_matrix cell {p}: {x} vs {y}"
                            ));
                        }
                    }
                }
                let _ = std::fs::remove_dir_all(&dir);
                Ok(())
            },
        );
    }
}

#[cfg(feature = "mmap")]
#[test]
fn mmap_reader_is_active_and_bitwise_equal() {
    // With the feature compiled in (on a supported target), the default
    // reader actually maps — and serves the same bytes as the pinned one.
    let cfg = SynthConfig { n: 64, dim: 17, seed: 5, ..Default::default() };
    let data = Kind::Gaussian.generate(&cfg);
    let dir = tmp("mmap-active");
    let manifest = store::write_sharded(&data, dir.join("shards"), 16).unwrap();
    let mapped = ShardedData::open(&manifest).unwrap();
    assert!(
        !store::mmap_compiled() || mapped.mmapped(),
        "mmap compiled but the writer-aligned shards did not map"
    );
    let pinned = ShardedData::open_with(
        &manifest,
        &StoreOptions { force_pinned: true, ..Default::default() },
    )
    .unwrap();
    assert!(!pinned.mmapped());
    let mut a = vec![0f32; 17];
    let mut b = vec![0f32; 17];
    for i in 0..64 {
        mapped.densify_row_into(i, &mut a);
        pinned.densify_row_into(i, &mut b);
        assert_eq!(a, b, "row {i}");
    }
}

#[test]
fn e2e_determinism_resident_vs_sharded() {
    // Planted mixture; shard sizes that do (100) and don't (77) divide n;
    // workers 1 and 8. Winners AND pull counts must match exactly.
    let n = 600;
    let k = 4;
    let cfg = SynthConfig { n, dim: 12, seed: 21, clusters: k, ..Default::default() };
    let data = Kind::Mixture.generate(&cfg);
    let dir = tmp("e2e-determinism");
    let resident = Arc::new(data);

    // resident reference (1 worker)
    let reference = {
        let engine = CountingEngine::new(NativeEngine::with_threads(
            resident.clone(),
            Metric::L2,
            1,
        ));
        let medoid = CorrSh::with_pulls_per_arm(24.0).run(&engine, &mut Rng::seeded(3));
        let medoid_pulls = engine.pulls();
        engine.reset();
        let km = BanditKMedoids::new(KMedoidsConfig { k, ..Default::default() })
            .run(&engine, &mut Rng::seeded(3));
        (medoid.best, medoid.pulls, medoid_pulls, km.medoids.clone(), km.pulls(), engine.pulls())
    };

    for rows_per_shard in [100usize, 77] {
        let manifest = store::write_sharded(
            &resident,
            dir.join(format!("shards-{rows_per_shard}")),
            rows_per_shard,
        )
        .unwrap();
        for workers in [1usize, 8] {
            for (backend, data) in [
                ("resident", resident.clone()),
                (
                    "sharded",
                    Arc::new(Data::Sharded(
                        ShardedData::open_with(
                            &manifest,
                            &StoreOptions {
                                cache_bytes: 1 << 15,
                                block_bytes: 1 << 11,
                                force_pinned: true,
                            },
                        )
                        .unwrap(),
                    )),
                ),
            ] {
                let tag = format!("{backend}/rps={rows_per_shard}/workers={workers}");
                let engine =
                    CountingEngine::new(NativeEngine::with_threads(data, Metric::L2, workers));
                let medoid = CorrSh::with_pulls_per_arm(24.0).run(&engine, &mut Rng::seeded(3));
                assert_eq!(medoid.best, reference.0, "{tag}: medoid winner");
                assert_eq!(medoid.pulls, reference.1, "{tag}: medoid pull count");
                assert_eq!(engine.pulls(), reference.2, "{tag}: engine-counted pulls");
                engine.reset();
                let km = BanditKMedoids::new(KMedoidsConfig { k, ..Default::default() })
                    .run(&engine, &mut Rng::seeded(3));
                assert_eq!(km.medoids, reference.3, "{tag}: kmedoids winners");
                assert_eq!(km.pulls(), reference.4, "{tag}: kmedoids pull count");
                assert_eq!(engine.pulls(), reference.5, "{tag}: kmedoids engine pulls");
            }
        }
    }
}

/// One line-delimited request/response exchange over a shared connection.
fn roundtrip(sock: &mut TcpStream, reader: &mut BufReader<TcpStream>, msg: &str) -> json::Value {
    sock.write_all(msg.as_bytes()).unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    json::parse(line.trim()).unwrap()
}

/// Strip fields that legitimately differ between runs (wall-clock) and
/// compare everything else byte-for-byte via the canonical serializer.
fn canonical_without_wall(line: &str) -> String {
    let v = json::parse(line.trim()).unwrap();
    let json::Value::Object(mut obj) = v else { panic!("not an object: {line}") };
    obj.remove("wall_ms");
    json::to_string(&json::Value::Object(obj))
}

#[test]
fn server_soak_manifest_registered_dataset() {
    // Shared dataset on disk, registered from a manifest; 4 clients hammer
    // medoid queries while a fifth churns register/unregister of a second
    // dataset. Executor must not stall, answers must match the resident
    // reference, shard_cache gauges must be monotone.
    let n = 400;
    let cfg = SynthConfig { n, dim: 10, seed: 9, ..Default::default() };
    let data = Kind::Gaussian.generate(&cfg);
    let dir = tmp("soak");
    let npy = dir.join("soak.npy");
    loader::save_dense_npy(&npy, &data.to_dense()).unwrap();
    let manifest = store::write_sharded(&data, dir.join("shards"), 96).unwrap();

    // resident reference answers (one per client seed)
    let reference = State::new();
    let r = reference.handle(
        &json::parse(&format!(
            r#"{{"op":"register","name":"soak","path":{:?},"metric":"l2"}}"#,
            npy.to_str().unwrap()
        ))
        .unwrap(),
    );
    assert_eq!(r.get("ok").as_bool(), Some(true), "{r}");
    let expected: Vec<String> = (0..4u64)
        .map(|seed| {
            let r = reference.handle(
                &json::parse(&format!(
                    r#"{{"op":"medoid","dataset":"soak","pulls_per_arm":24,"seed":{seed}}}"#
                ))
                .unwrap(),
            );
            assert_eq!(r.get("ok").as_bool(), Some(true), "{r}");
            canonical_without_wall(&json::to_string(&r))
        })
        .collect();

    // live server over the manifest registration
    let state = State::new();
    let r = state.handle(
        &json::parse(&format!(
            r#"{{"op":"register","name":"soak","path":{:?},"metric":"l2"}}"#,
            manifest.to_str().unwrap()
        ))
        .unwrap(),
    );
    assert_eq!(r.get("ok").as_bool(), Some(true), "{r}");
    assert_eq!(r.get("sharded").as_bool(), Some(true));
    let addr = server::serve_background(state).unwrap();

    let gauges = std::sync::Mutex::new(Vec::<(u64, u64)>::new());
    std::thread::scope(|s| {
        // 4 query clients
        for (seed, want) in expected.iter().enumerate() {
            s.spawn(move || {
                let mut sock = TcpStream::connect(addr).unwrap();
                let mut reader = BufReader::new(sock.try_clone().unwrap());
                let mut line = String::new();
                for round in 0..6 {
                    sock.write_all(
                        format!(
                            "{{\"op\":\"medoid\",\"dataset\":\"soak\",\
                             \"pulls_per_arm\":24,\"seed\":{seed}}}\n"
                        )
                        .as_bytes(),
                    )
                    .unwrap();
                    line.clear();
                    reader.read_line(&mut line).unwrap();
                    assert_eq!(
                        canonical_without_wall(&line),
                        *want,
                        "client {seed} round {round}: sharded response diverged from \
                         the resident reference"
                    );
                }
            });
        }
        // churn client: register/unregister a second manifest dataset, and
        // sample the shard_cache gauges for monotonicity as it goes
        let manifest2 = store::write_sharded(&data, dir.join("shards2"), 64).unwrap();
        let gauges = &gauges;
        s.spawn(move || {
            let mut sock = TcpStream::connect(addr).unwrap();
            let mut reader = BufReader::new(sock.try_clone().unwrap());
            for round in 0..5 {
                let r = roundtrip(
                    &mut sock,
                    &mut reader,
                    &format!(
                        "{{\"op\":\"register\",\"name\":\"churn\",\"path\":{:?},\
                         \"metric\":\"l2\"}}\n",
                        manifest2.to_str().unwrap()
                    ),
                );
                assert_eq!(r.get("ok").as_bool(), Some(true), "churn register {round}: {r}");
                let r = roundtrip(
                    &mut sock,
                    &mut reader,
                    &format!(
                        "{{\"op\":\"medoid\",\"dataset\":\"churn\",\
                         \"pulls_per_arm\":8,\"seed\":{round}}}\n"
                    ),
                );
                assert_eq!(r.get("ok").as_bool(), Some(true), "churn medoid {round}: {r}");
                let m = roundtrip(&mut sock, &mut reader, "{\"op\":\"metrics\"}\n");
                let sc = m.get("shard_cache");
                gauges.lock().unwrap().push((
                    sc.get("hits").as_u64().unwrap(),
                    sc.get("misses").as_u64().unwrap(),
                ));
                let unreg = "{\"op\":\"unregister\",\"name\":\"churn\"}\n";
                let r = roundtrip(&mut sock, &mut reader, unreg);
                assert_eq!(r.get("ok").as_bool(), Some(true), "churn unregister {round}: {r}");
            }
        });
    });

    // gauges sampled during the churn are monotone non-decreasing
    let samples = gauges.into_inner().unwrap();
    assert_eq!(samples.len(), 5);
    for w in samples.windows(2) {
        assert!(w[1].0 >= w[0].0, "shard_cache hits went backwards: {samples:?}");
        assert!(w[1].1 >= w[0].1, "shard_cache misses went backwards: {samples:?}");
    }
}

#[test]
fn npy_version_fixtures_parse_identically() {
    // Checked-in regression fixtures: the same 2x3 arange payload written
    // as v1.0 with 16-byte padding (old numpy), v2.0, and v3.0. The reader
    // must produce identical matrices for all three.
    let fixtures = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("rust/tests/fixtures");
    let want: Vec<f32> = (0..6).map(|i| i as f32).collect();
    for name in ["v1_pad16.npy", "v2.npy", "v3.npy"] {
        let m = corrsh::util::npy::read(fixtures.join(name))
            .unwrap_or_else(|e| panic!("{name}: {e}"));
        assert_eq!((m.rows, m.cols), (2, 3), "{name}");
        assert_eq!(m.data, want, "{name}");
    }
    // and an f8 v2 fixture downcasts exactly as the v1 reader did
    let m = corrsh::util::npy::read(fixtures.join("v2_f8.npy")).unwrap();
    assert_eq!(m.data, vec![0.5, -1.5]);
    // sharding straight from a fixture file works end to end
    let dir = tmp("fixture-shard");
    let manifest = store::shard_file(fixtures.join("v2.npy"), dir.join("shards"), 1).unwrap();
    let sd = ShardedData::open(&manifest).unwrap();
    assert_eq!((sd.n(), sd.dim()), (2, 3));
    let mut row = vec![0f32; 3];
    sd.densify_row_into(1, &mut row);
    assert_eq!(row, vec![3.0, 4.0, 5.0]);
}

#[test]
fn sharded_cache_stays_bounded_under_load() {
    // A full-universe corrSH run over a pinned shard set with a small
    // budget: pinned bytes (global gauge) must stay near the budget, not
    // the dataset size.
    let n = 500;
    let dim = 32;
    let cfg = SynthConfig { n, dim, seed: 13, ..Default::default() };
    let data = Kind::Gaussian.generate(&cfg);
    let dir = tmp("bounded");
    let manifest = store::write_sharded(&data, dir.join("shards"), 64).unwrap();
    let budget = 16 * 1024;
    let sd = ShardedData::open_with(
        &manifest,
        &StoreOptions { cache_bytes: budget, block_bytes: 2048, force_pinned: true },
    )
    .unwrap();
    let engine = NativeEngine::with_threads(Arc::new(Data::Sharded(sd.clone())), Metric::L2, 4);
    let res = CorrSh::with_pulls_per_arm(16.0).run(&engine, &mut Rng::seeded(1));
    assert!(res.best < n);
    assert!(
        sd.pinned_bytes() <= budget + 2048,
        "cache grew past its budget: {} > {budget}",
        sd.pinned_bytes()
    );
    assert!(cache_stats().misses() > 0);
}
