//! Integration: the PJRT path (AOT Pallas/JAX artifacts via the xla crate)
//! must agree with the native rust engine — same sums, same algorithm
//! decisions, same pull accounting. This is the composition proof for
//! L1 (Pallas) + L2 (JAX graph) + runtime + coordinator.
//!
//! Skips (with a note) when `artifacts/` is absent; `make artifacts` first.
//!
//! Built only with the `pjrt` cargo feature (see `required-features` in
//! Cargo.toml); the default offline build compiles it out entirely.

#![cfg(feature = "pjrt")]

use std::sync::Arc;

use corrsh::bandits::{CorrSh, MedoidAlgorithm};
use corrsh::data::synth::{mnist, rnaseq, SynthConfig};
use corrsh::data::Data;
use corrsh::distance::Metric;
use corrsh::engine::{CountingEngine, NativeEngine, PjrtEngine, PullEngine};
use corrsh::runtime::Runtime;
use corrsh::util::rng::Rng;

fn runtime() -> Option<Arc<Runtime>> {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("SKIP: artifacts/ missing — run `make artifacts`");
        return None;
    }
    Some(Arc::new(Runtime::open("artifacts").unwrap()))
}

#[test]
fn block_sums_agree_across_engines_all_metrics() {
    let Some(rt) = runtime() else { return };
    let data = Arc::new(mnist::generate(&SynthConfig {
        n: 500,
        dim: 784,
        seed: 31,
        ..Default::default()
    }));
    let mut rng = Rng::seeded(7);
    for metric in [Metric::L1, Metric::L2, Metric::Cosine] {
        let pjrt = PjrtEngine::new(data.clone(), metric, rt.clone()).unwrap();
        let native = NativeEngine::with_threads(data.clone(), metric, 1);
        for trial in 0..3 {
            let n_arms = rng.range(1, 400);
            let n_refs = rng.range(1, 200);
            let arms = rng.sample_without_replacement(500, n_arms);
            let refs = rng.sample_without_replacement(500, n_refs);
            let mut got = vec![0f64; arms.len()];
            let mut want = vec![0f64; arms.len()];
            pjrt.pull_block(&arms, &refs, &mut got);
            native.pull_block(&arms, &refs, &mut want);
            for k in 0..arms.len() {
                let tol = want[k].abs().max(1.0) * 3e-4;
                assert!(
                    (got[k] - want[k]).abs() < tol,
                    "{metric} trial {trial} arm {}: pjrt={} native={}",
                    arms[k],
                    got[k],
                    want[k]
                );
            }
        }
    }
}

#[test]
fn corrsh_decisions_identical_on_both_engines() {
    let Some(rt) = runtime() else { return };
    // f32 sums differ at ~1e-7 relative between XLA and native accumulation
    // order; on a planted-medoid instance the *decisions* (survivor sets,
    // final answer, pull ledger) must nevertheless be identical.
    let data = Arc::new(mnist::generate(&SynthConfig {
        n: 600,
        dim: 784,
        seed: 32,
        ..Default::default()
    }));
    let pjrt = CountingEngine::new(PjrtEngine::new(data.clone(), Metric::L2, rt).unwrap());
    let native = CountingEngine::new(NativeEngine::with_threads(data.clone(), Metric::L2, 1));
    for seed in 0..5 {
        let algo = CorrSh::with_pulls_per_arm(32.0);
        let a = algo.run(&pjrt, &mut Rng::seeded(seed));
        let b = algo.run(&native, &mut Rng::seeded(seed));
        assert_eq!(a.best, b.best, "seed {seed}: pjrt chose {} native {}", a.best, b.best);
        assert_eq!(a.pulls, b.pulls, "seed {seed}: pull ledgers diverged");
        assert_eq!(a.rounds, b.rounds, "seed {seed}: round traces diverged");
    }
    assert_eq!(pjrt.pulls(), native.pulls(), "engine counters diverged");
}

#[test]
fn sparse_dataset_through_pjrt_gather() {
    let Some(rt) = runtime() else { return };
    // CSR data is densified per tile by the gather; agreement must hold for
    // sparse inputs too (rnaseq synthetic at an artifact dim).
    let data = Arc::new(rnaseq::generate(&SynthConfig {
        n: 300,
        dim: 2048,
        seed: 33,
        ..Default::default()
    }));
    assert!(matches!(data.as_ref(), Data::Sparse(_)));
    let pjrt = PjrtEngine::new(data.clone(), Metric::L1, rt).unwrap();
    let native = NativeEngine::with_threads(data.clone(), Metric::L1, 1);
    let arms: Vec<usize> = (0..300).collect();
    let refs: Vec<usize> = (0..77).collect();
    let mut got = vec![0f64; 300];
    let mut want = vec![0f64; 300];
    pjrt.pull_block(&arms, &refs, &mut got);
    native.pull_block(&arms, &refs, &mut want);
    for k in 0..300 {
        assert!(
            (got[k] - want[k]).abs() < want[k].abs().max(1.0) * 3e-4,
            "arm {k}: pjrt={} native={}",
            got[k],
            want[k]
        );
    }
}

#[test]
fn runtime_reports_buckets_and_compiles_lazily() {
    let Some(rt) = runtime() else { return };
    assert_eq!(rt.cached_count(), 0, "nothing compiled before first use");
    let dims = rt.manifest().dims(Metric::L2);
    assert!(dims.contains(&784), "expected dim 784 artifact, have {dims:?}");
    let buckets = rt.manifest().buckets(Metric::L2, 784);
    assert!(buckets.len() >= 3, "bucket ladder too short: {buckets:?}");
    let _ = rt.executable(Metric::L2, buckets[0].0, buckets[0].1, 784).unwrap();
    assert_eq!(rt.cached_count(), 1);
}
