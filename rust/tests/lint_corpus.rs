//! Trap-case corpus for the `corrsh lint` analyzer (DESIGN.md §16).
//!
//! Each fixture is a (pretend path, source) pair fed straight into
//! `analysis::check_source` — the same entry point `corrsh lint` uses per
//! file — split into traps that MUST fire and look-alikes that MUST NOT
//! (the false positives the old grep/awk CI gates could not avoid).
//! The final test lints the shipped tree itself: the repo must be clean
//! under its own analyzer.

use std::path::Path;

use corrsh::analysis::{check_source, lint_root, Finding, LINT_VERSION, RULES};

fn fired(path: &str, src: &str) -> Vec<&'static str> {
    let mut rules: Vec<&'static str> =
        check_source(path, src).into_iter().map(|f: Finding| f.rule).collect();
    rules.dedup();
    rules
}

// ---------------------------------------------------------------- traps --

#[test]
fn r1_partial_cmp_in_code_fires() {
    let src = "fn f(a: f64, b: f64) { a.partial_cmp(&b); }";
    assert_eq!(fired("rust/src/bandits/corr_sh.rs", src), vec!["R1"]);
    // R1 has no test exemption: a NaN-unsound comparator in a test
    // launders the same bug class.
    let in_test = "#[cfg(test)]\nmod t { fn f(a: f64, b: f64) { a.partial_cmp(&b); } }";
    assert_eq!(fired("rust/src/bandits/corr_sh.rs", in_test), vec!["R1"]);
}

#[test]
fn r2_unsafe_off_allowlist_fires() {
    let src = "fn f() { unsafe { g() } }";
    assert_eq!(fired("rust/src/bandits/corr_sh.rs", src), vec!["R2"]);
}

#[test]
fn r2_unsafe_missing_safety_comment_fires() {
    // On the allowlist, but no // SAFETY: run within the 4-line window.
    let src = "fn f() { unsafe { g() } }";
    assert_eq!(fired("rust/src/engine/simd.rs", src), vec!["R2"]);
    // A SAFETY anchor 5 lines above is out of the window.
    let far = "// SAFETY: too far away\n\n\n\n\nfn f() { unsafe { g() } }";
    assert_eq!(fired("rust/src/engine/simd.rs", far), vec!["R2"]);
}

#[test]
fn r3_asm_and_syscall_helpers_off_allowlist_fire() {
    let asm = "fn f() { unsafe { std::arch::asm!(\"nop\") } }";
    let rules = fired("rust/src/engine/kernel.rs", asm);
    assert!(rules.contains(&"R3"), "asm! must fire R3, got {rules:?}");
    let helper = "fn g() { let r = syscall6(9, 0, 0, 0, 0, 0, 0); }";
    assert_eq!(fired("rust/src/util/pool.rs", helper), vec!["R3"]);
}

#[test]
fn r4_raw_thread_spawn_fires() {
    let src = "fn f() { std::thread::spawn(|| ()); }";
    assert_eq!(fired("rust/src/server/exec.rs", src), vec!["R4"]);
    assert_eq!(fired("examples/rnaseq_clustering.rs", src), vec!["R4"]);
}

#[test]
fn r5_unwrap_expect_panic_in_server_code_fire() {
    let src = r#"
        fn a(x: Option<u32>) -> u32 { x.unwrap() }
        fn b(x: Option<u32>) -> u32 { x.expect("msg") }
        fn c() { panic!("boom"); }
    "#;
    assert_eq!(fired("rust/src/server/ops.rs", src), vec!["R5"]);
    assert_eq!(fired("rust/src/engine/distributed.rs", src), vec!["R5"]);
    assert_eq!(check_source("rust/src/server/ops.rs", src).len(), 3);
}

#[test]
fn r6_unwaivered_float_eq_fires() {
    let src = "fn f(x: f64) -> bool { x == 0.0 || x != -1.5 }";
    let findings = check_source("rust/src/stats/mod.rs", src);
    assert_eq!(findings.len(), 2, "both comparisons fire: {findings:?}");
    assert!(findings.iter().all(|f| f.rule == "R6"));
}

#[test]
fn r7_process_exit_outside_main_fires() {
    let src = "fn f() { std::process::exit(2); }";
    assert_eq!(fired("rust/src/server/net.rs", src), vec!["R7"]);
}

#[test]
fn r8_unchecked_pull_arithmetic_fires() {
    // The exact shape of the ledger bug this rule exists for: a u64 pull
    // counter accumulated with wrapping `+=` deep in an accounting loop.
    let compound = "fn f(mut pulls: u64, t: usize) { pulls += t as u64; }";
    assert_eq!(fired("rust/src/bandits/meddit.rs", compound), vec!["R8"]);
    // Addend-side naming fires too (`spent += pulls`), as does a
    // path-qualified operand on either side.
    let addend = "fn f(mut spent: u64, pulls: u64) { spent += pulls; }";
    assert_eq!(fired("rust/src/coordinator/ledger.rs", addend), vec!["R8"]);
    let qualified = "fn f(w: &mut W, r: &Row) { w.pulls += r.pulls; }";
    assert_eq!(fired("rust/src/engine/distributed.rs", qualified), vec!["R8"]);
    let plain = "fn f(o: &Out, extra: u64) -> u64 { o.reported_pulls + extra }";
    assert_eq!(fired("rust/src/kmedoids/mod.rs", plain), vec!["R8"]);
}

// ---------------------------------------------- look-alikes (no finding) --

#[test]
fn saturating_and_waived_pull_arithmetic_do_not_fire_r8() {
    // The sanctioned form.
    let ok = "fn f(mut pulls: u64, t: u64) { pulls = pulls.saturating_add(t); }";
    assert!(fired("rust/src/bandits/meddit.rs", ok).is_empty());
    // Non-pull counters are out of scope even in the same expression.
    let other = "fn f(mut hits: u64, misses: u64) { hits += misses + 1; }";
    assert!(fired("rust/src/kmedoids/cache.rs", other).is_empty());
    // `pulls` as string/comment data, the grep-gate failure mode.
    let data = "// pulls += t would wrap\nfn f() -> &'static str { \"pulls + 1\" }";
    assert!(fired("rust/src/bandits/meddit.rs", data).is_empty());
    // Waived lines (same line or line above) and test scope are exempt.
    let waived = "// lint: pull-add-ok(bounded by n <= 2^16)\nfn f(mut pulls: u64) { pulls += 1; }";
    assert!(fired("rust/src/bandits/meddit.rs", waived).is_empty());
    let in_test = "#[test]\nfn t() { let mut pulls = 0u64; pulls += 9; }";
    assert!(fired("rust/src/bandits/meddit.rs", in_test).is_empty());
}

#[test]
fn partial_cmp_in_string_literal_does_not_fire() {
    // The exact failure mode of `grep -rn partial_cmp`: the banned token
    // inside string data, not code.
    let src = r#"fn f() -> &'static str { "use total_cmp, never partial_cmp" }"#;
    assert!(fired("rust/src/bandits/corr_sh.rs", src).is_empty());
}

#[test]
fn partial_cmp_in_comments_does_not_fire() {
    let doc = "/// Unlike `partial_cmp`, total_cmp orders NaN last.\nfn f() {}";
    assert!(fired("rust/src/bandits/corr_sh.rs", doc).is_empty());
    let line = "// a partial_cmp comparator would corrupt the halving order\nfn f() {}";
    assert!(fired("rust/src/bandits/corr_sh.rs", line).is_empty());
    let block = "/* partial_cmp /* nested partial_cmp */ */ fn f() {}";
    assert!(fired("rust/src/bandits/corr_sh.rs", block).is_empty());
}

#[test]
fn unsafe_in_raw_string_does_not_fire() {
    // grep's other blind spot: `unsafe` as string payload, here in a raw
    // string whose quotes would confuse a regex-based scanner.
    let src = r##"fn f() -> &'static str { r#"this "unsafe" is data"# }"##;
    assert!(fired("rust/src/bandits/corr_sh.rs", src).is_empty());
}

#[test]
fn safety_comment_run_satisfies_r2_on_allowlist() {
    // A multi-line justification run anchors at its last line, so an
    // attribute between the run and the unsafe keyword still passes.
    let src = "
        // SAFETY: lanes are in-bounds by construction (len checked above),
        // and the pointer came from a live slice.
        #[allow(clippy::needless_range_loop)]
        unsafe { g() }
    ";
    assert!(fired("rust/src/engine/simd.rs", src).is_empty());
}

#[test]
fn cfg_test_module_is_exempt_from_r5() {
    let src = r#"
        pub fn serve() {}
        #[cfg(test)]
        mod tests {
            #[test]
            fn t() {
                Some(1).unwrap();
                None::<u32>.expect("fine here");
                panic!("also fine");
            }
        }
    "#;
    assert!(fired("rust/src/server/ops.rs", src).is_empty());
}

#[test]
fn test_attr_fn_is_exempt_from_r5_but_production_code_is_not() {
    let src = r#"
        fn prod(x: Option<u32>) -> u32 { x.unwrap() }
        #[test]
        fn t() { Some(1).unwrap(); }
    "#;
    let findings = check_source("rust/src/server/ops.rs", src);
    assert_eq!(findings.len(), 1, "only the production unwrap: {findings:?}");
    assert_eq!(findings[0].rule, "R5");
    assert_eq!(findings[0].line, 2);
}

#[test]
fn float_eq_waivers_and_tuple_indices_do_not_fire() {
    let same = "fn f(x: f64) -> bool { x == 0.0 } // lint: float-eq-ok(exactness test)";
    assert!(fired("rust/src/util/json.rs", same).is_empty());
    let above = "// lint: float-eq-ok(integrality)\nfn f(x: f64) -> bool { x.fract() == 0.0 }";
    assert!(fired("rust/src/util/json.rs", above).is_empty());
    // `t.0.1 == q.0` is tuple indexing, not float literals.
    let tuple = "fn f(t: ((u8, u8), u8), q: (u8,)) -> bool { t.0.1 == q.0 }";
    assert!(fired("rust/src/util/json.rs", tuple).is_empty());
}

#[test]
fn spawn_through_util_threads_does_not_fire_r4() {
    let src = "fn f() { crate::util::threads::spawn(\"corrsh-x\", || ()); }";
    assert!(fired("rust/src/server/net.rs", src).is_empty());
    let builder = "fn f() { std::thread::Builder::new().spawn(|| ()); }";
    assert!(fired("rust/src/util/threads.rs", builder).is_empty());
}

// ------------------------------------------------------------ self-check --

#[test]
fn shipped_tree_is_lint_clean() {
    // CARGO_MANIFEST_DIR is the repo root (Cargo.toml lives there), which
    // is exactly what `corrsh lint --root` defaults to.
    let report = lint_root(Path::new(env!("CARGO_MANIFEST_DIR"))).expect("lint walk");
    assert!(report.files_scanned > 50, "walk found {} files", report.files_scanned);
    assert!(
        report.ok(),
        "shipped tree must be lint-clean, got:\n{}",
        report.render_text()
    );
    let v = report.to_json();
    assert_eq!(v.get("version").as_u64(), Some(LINT_VERSION));
    assert_eq!(v.get("rules").as_usize(), Some(RULES.len()));
}
