//! Protocol v2 integration tests over real TCP connections: the v1/v2
//! compat matrix, request pipelining with out-of-order id-matched
//! responses, deterministic admission-control sheds, the request size cap,
//! streaming partial results, and idle-connection timeouts.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

use corrsh::config::ServerConfig;
use corrsh::server::{event_loop_supported, serve_background_with, State};
use corrsh::util::json::{self, Value};

fn req(s: &str) -> Value {
    json::parse(s).unwrap()
}

/// One in-order request/response exchange on an established connection.
fn rpc(sock: &mut TcpStream, reader: &mut BufReader<TcpStream>, line: &str) -> Value {
    sock.write_all(line.as_bytes()).unwrap();
    sock.write_all(b"\n").unwrap();
    let mut resp = String::new();
    reader.read_line(&mut resp).unwrap();
    json::parse(resp.trim()).unwrap_or_else(|e| panic!("bad response {resp:?}: {e}"))
}

fn connect(addr: std::net::SocketAddr) -> (TcpStream, BufReader<TcpStream>) {
    let sock = TcpStream::connect(addr).unwrap();
    let reader = BufReader::new(sock.try_clone().unwrap());
    (sock, reader)
}

/// Recursively drop the fields that legitimately differ between two runs
/// (timings), between protocol versions (the v1 deprecation note), or
/// between processes (global shard-cache traffic, transport counters).
fn strip(v: &Value) -> Value {
    match v {
        Value::Object(o) => Value::Object(
            o.iter()
                .filter(|(k, _)| {
                    !matches!(k.as_str(), "wall_ms" | "note" | "shard_cache" | "net")
                })
                .map(|(k, v)| (k.clone(), strip(v)))
                .collect(),
        ),
        Value::Array(a) => Value::Array(a.iter().map(strip).collect()),
        other => other.clone(),
    }
}

/// Flatten a v2 response to the v1 shape: ok responses unwrap `result`
/// (which carries its own `"ok":true`), errors become the legacy flat
/// `{"ok":false,"error":"<message>"}`.
fn flatten_v2(resp: &Value) -> Value {
    if resp.get("ok").as_bool() == Some(true) {
        resp.get("result").clone()
    } else {
        Value::from_pairs(vec![
            ("ok", false.into()),
            ("error", resp.get("error").get("message").clone()),
        ])
    }
}

/// The compat matrix: every op (happy path and error path) run twice — as
/// bare v1 against one server and as a v2 envelope against an identically
/// configured second server — must produce canonically equal responses
/// after flattening, modulo `wall_ms`/`note`/process-global counters.
#[test]
fn v1_v2_compat_matrix_over_every_op() {
    // (op, request fields) — executed in order on both servers, so request
    // counters and cache hit/miss sequences line up exactly.
    let matrix: &[(&str, &str)] = &[
        ("ping", ""),
        ("register", r#""name":"toy","kind":"gaussian","n":300,"dim":8,"seed":4"#),
        ("list", ""),
        ("medoid", r#""dataset":"toy","pulls_per_arm":48,"seed":3"#),
        ("medoid", r#""dataset":"toy","algo":"exact","seed":0"#),
        ("medoid_batch", r#""dataset":"toy","pulls_per_arm":16,"seeds":[1,2]"#),
        ("stats", r#""dataset":"toy""#),
        ("kmedoids", r#""dataset":"toy","k":3,"seed":1"#),
        ("metrics", ""),
        ("frobnicate", ""),
        ("medoid", r#""dataset":"missing""#),
        ("register", r#""name":"bad","kind":"gaussian","n":0,"dim":4"#),
        ("unregister", r#""name":"toy""#),
        ("shutdown", ""),
    ];
    let cfg = ServerConfig { workers: 2, queue_cap: 32, ..Default::default() };
    let v1_addr = serve_background_with(State::new(), &cfg).unwrap();
    let v2_addr = serve_background_with(State::new(), &cfg).unwrap();
    let (mut s1, mut r1) = connect(v1_addr);
    let (mut s2, mut r2) = connect(v2_addr);

    for (i, (op, fields)) in matrix.iter().enumerate() {
        let sep = if fields.is_empty() { "" } else { "," };
        let v1_line = format!(r#"{{"op":"{op}"{sep}{fields}}}"#);
        let v2_line = format!(r#"{{"v":2,"id":{i},"op":"{op}","params":{{{fields}}}}}"#);
        let v1_resp = rpc(&mut s1, &mut r1, &v1_line);
        let v2_resp = rpc(&mut s2, &mut r2, &v2_line);
        assert_eq!(v2_resp.get("id").as_usize(), Some(i), "id echo for {v2_line}");
        let flat = json::to_string(&strip(&flatten_v2(&v2_resp)));
        let legacy = json::to_string(&strip(&v1_resp));
        assert_eq!(flat, legacy, "op {op:?} (step {i}) diverged between v1 and v2");
        if *op == "ping" {
            // The deprecation note is a v1-shim artifact: present on the
            // bare request, absent from the v2 envelope.
            assert!(v1_resp.get("note").as_str().unwrap().contains("deprecated"));
            assert!(matches!(v2_resp.get("result").get("note"), Value::Null));
        }
        if matches!(*op, "frobnicate") {
            assert_eq!(v2_resp.get("error").get("code").as_str(), Some("bad_request"));
        }
        if *op == "medoid" && fields.contains("missing") {
            assert_eq!(v2_resp.get("error").get("code").as_str(), Some("unknown_dataset"));
        }
    }
}

/// Pipelining: many requests written in one burst on one socket; responses
/// may come back in any order but must be id-matched and each must equal
/// the blocking single-threaded baseline for its seed.
#[test]
fn pipelined_requests_return_id_matched_responses() {
    let reference = State::new();
    reference.handle(&req(
        r#"{"op":"register","name":"toy","kind":"gaussian","n":400,"dim":8,"seed":4}"#,
    ));
    let mut expect = Vec::new();
    for seed in 0u64..8 {
        let r = reference.handle(&req(&format!(
            r#"{{"op":"medoid","dataset":"toy","pulls_per_arm":48,"seed":{seed}}}"#
        )));
        expect.push((r.get("medoid").as_usize().unwrap(), r.get("pulls").as_u64().unwrap()));
    }

    let state = State::new();
    state.handle(&req(
        r#"{"op":"register","name":"toy","kind":"gaussian","n":400,"dim":8,"seed":4}"#,
    ));
    let cfg = ServerConfig { workers: 4, queue_cap: 32, ..Default::default() };
    let addr = serve_background_with(state, &cfg).unwrap();
    let (mut sock, mut reader) = connect(addr);

    let mut burst = String::new();
    for seed in 0u64..8 {
        let id = 10 + seed;
        burst.push_str(&format!(
            "{{\"v\":2,\"id\":{id},\"op\":\"medoid\",\
             \"params\":{{\"dataset\":\"toy\",\"pulls_per_arm\":48,\"seed\":{seed}}}}}\n"
        ));
    }
    sock.write_all(burst.as_bytes()).unwrap();

    let mut seen = vec![false; 8];
    for _ in 0..8 {
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let resp = json::parse(line.trim()).unwrap();
        assert_eq!(resp.get("ok").as_bool(), Some(true), "{resp}");
        let id = resp.get("id").as_u64().unwrap();
        let seed = (id - 10) as usize;
        assert!(!seen[seed], "duplicate response for id {id}");
        seen[seed] = true;
        let (medoid, pulls) = expect[seed];
        assert_eq!(resp.get("result").get("medoid").as_usize(), Some(medoid), "seed {seed}");
        assert_eq!(resp.get("result").get("pulls").as_u64(), Some(pulls), "seed {seed}");
    }
    assert!(seen.iter().all(|&s| s), "missing responses: {seen:?}");
}

/// Admission control, per-connection quota: one burst of 8 requests on a
/// connection capped at 2 in flight, against a single slow worker — the
/// first 2 are admitted and answered, the other 6 are shed `overloaded`
/// in the same batch (deterministically: no completion can interleave).
#[test]
fn per_connection_quota_sheds_deterministically() {
    if !event_loop_supported() {
        return; // admission control lives in the event loop
    }
    let reference = State::new();
    reference.handle(&req(
        r#"{"op":"register","name":"big","kind":"gaussian","n":3000,"dim":8,"seed":1}"#,
    ));
    let expected =
        reference.handle(&req(r#"{"op":"medoid","dataset":"big","algo":"exact"}"#));
    let medoid = expected.get("medoid").as_usize().unwrap();

    let state = State::new();
    state.handle(&req(
        r#"{"op":"register","name":"big","kind":"gaussian","n":3000,"dim":8,"seed":1}"#,
    ));
    let cfg = ServerConfig {
        workers: 1,
        queue_cap: 64,
        max_inflight_per_conn: 2,
        idle_timeout_ms: 0,
        ..Default::default()
    };
    let addr = serve_background_with(state, &cfg).unwrap();
    let (mut sock, mut reader) = connect(addr);

    let mut burst = String::new();
    for id in 1..=8 {
        burst.push_str(&format!(
            "{{\"v\":2,\"id\":{id},\"op\":\"medoid\",\
             \"params\":{{\"dataset\":\"big\",\"algo\":\"exact\"}}}}\n"
        ));
    }
    sock.write_all(burst.as_bytes()).unwrap();

    let mut ok_ids = Vec::new();
    let mut shed_ids = Vec::new();
    for _ in 0..8 {
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let resp = json::parse(line.trim()).unwrap();
        let id = resp.get("id").as_u64().unwrap();
        if resp.get("ok").as_bool() == Some(true) {
            assert_eq!(resp.get("result").get("medoid").as_usize(), Some(medoid));
            ok_ids.push(id);
        } else {
            assert_eq!(resp.get("error").get("code").as_str(), Some("overloaded"), "{resp}");
            assert!(resp.get("error").get("message").as_str().unwrap().contains("quota"));
            shed_ids.push(id);
        }
    }
    ok_ids.sort_unstable();
    shed_ids.sort_unstable();
    assert_eq!(ok_ids, vec![1, 2], "exactly the first two requests are admitted");
    assert_eq!(shed_ids, vec![3, 4, 5, 6, 7, 8]);

    let m = rpc(&mut sock, &mut reader, r#"{"v":2,"id":99,"op":"metrics"}"#);
    assert_eq!(m.get("result").get("net").get("shed").as_u64(), Some(6));
    // The metrics request itself is the only thing in flight at snapshot time.
    assert_eq!(m.get("result").get("net").get("in_flight").as_u64(), Some(1));
}

/// Admission control, per-dataset quota: the quota is keyed by dataset, so
/// a burst saturating dataset A still admits a request for dataset B.
#[test]
fn per_dataset_quota_is_keyed_by_dataset() {
    if !event_loop_supported() {
        return;
    }
    let state = State::new();
    state.handle(&req(
        r#"{"op":"register","name":"a","kind":"gaussian","n":3000,"dim":8,"seed":1}"#,
    ));
    state.handle(&req(
        r#"{"op":"register","name":"b","kind":"gaussian","n":3000,"dim":8,"seed":2}"#,
    ));
    let cfg = ServerConfig {
        workers: 1,
        queue_cap: 64,
        max_inflight_per_dataset: 1,
        idle_timeout_ms: 0,
        ..Default::default()
    };
    let addr = serve_background_with(state, &cfg).unwrap();
    let (mut sock, mut reader) = connect(addr);

    let mut burst = String::new();
    for id in 1..=4 {
        burst.push_str(&format!(
            "{{\"v\":2,\"id\":{id},\"op\":\"medoid\",\
             \"params\":{{\"dataset\":\"a\",\"algo\":\"exact\"}}}}\n"
        ));
    }
    burst.push_str(
        "{\"v\":2,\"id\":5,\"op\":\"medoid\",\
         \"params\":{\"dataset\":\"b\",\"algo\":\"exact\"}}\n",
    );
    sock.write_all(burst.as_bytes()).unwrap();

    let mut ok_ids = Vec::new();
    let mut shed_ids = Vec::new();
    for _ in 0..5 {
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let resp = json::parse(line.trim()).unwrap();
        let id = resp.get("id").as_u64().unwrap();
        if resp.get("ok").as_bool() == Some(true) {
            ok_ids.push(id);
        } else {
            assert_eq!(resp.get("error").get("code").as_str(), Some("overloaded"), "{resp}");
            shed_ids.push(id);
        }
    }
    ok_ids.sort_unstable();
    shed_ids.sort_unstable();
    assert_eq!(ok_ids, vec![1, 5], "one per dataset admitted");
    assert_eq!(shed_ids, vec![2, 3, 4]);
}

/// The framing-layer size cap: an oversized line is answered with
/// `bad_request` and the connection keeps working; the counter advances.
#[test]
fn oversized_frames_get_bad_request_and_the_connection_survives() {
    let state = State::new();
    let cfg = ServerConfig { max_request_bytes: 256, ..Default::default() };
    let addr = serve_background_with(state, &cfg).unwrap();

    // v2-speaking connection: the error is a v2 envelope with a null id.
    let (mut sock, mut reader) = connect(addr);
    let p = rpc(&mut sock, &mut reader, r#"{"v":2,"id":1,"op":"ping"}"#);
    assert_eq!(p.get("ok").as_bool(), Some(true));
    let huge = format!(r#"{{"v":2,"id":2,"op":"ping","params":{{"pad":"{}"}}}}"#, "x".repeat(500));
    let e = rpc(&mut sock, &mut reader, &huge);
    assert_eq!(e.get("ok").as_bool(), Some(false));
    assert_eq!(e.get("error").get("code").as_str(), Some("bad_request"), "{e}");
    assert!(e.get("error").get("message").as_str().unwrap().contains("max_request_bytes"));
    assert!(matches!(e.get("id"), Value::Null), "oversized frames have no parseable id");
    let p = rpc(&mut sock, &mut reader, r#"{"v":2,"id":3,"op":"ping"}"#);
    assert_eq!(p.get("ok").as_bool(), Some(true), "connection must survive the cap");
    assert_eq!(p.get("id").as_u64(), Some(3));
    let m = rpc(&mut sock, &mut reader, r#"{"v":2,"id":4,"op":"metrics"}"#);
    assert_eq!(m.get("result").get("net").get("oversized").as_u64(), Some(1));

    // v1 connection: flat legacy error string, in order.
    let (mut sock, mut reader) = connect(addr);
    let e = rpc(&mut sock, &mut reader, &format!(r#"{{"op":"ping","pad":"{}"}}"#, "x".repeat(500)));
    assert_eq!(e.get("ok").as_bool(), Some(false));
    assert!(e.get("error").as_str().unwrap().contains("max_request_bytes"), "{e}");
    let p = rpc(&mut sock, &mut reader, r#"{"op":"ping"}"#);
    assert_eq!(p.get("pong").as_bool(), Some(true));
}

/// Streaming partial results: a long k-medoids run with `"stream":true`
/// emits `"partial":true` frames carrying the per-phase loss trajectory
/// before the final frame, and the final medoids equal the blocking
/// baseline; a streaming medoid query replays its halving rounds.
#[test]
fn streaming_partials_carry_the_loss_trajectory() {
    if !event_loop_supported() {
        return; // the blocking fallback answers with final frames only
    }
    let reference = State::new();
    reference.handle(&req(
        r#"{"op":"register","name":"mix","kind":"mixture","n":600,"dim":8,"seed":7,"clusters":3}"#,
    ));
    let baseline = reference.handle(&req(r#"{"op":"kmedoids","dataset":"mix","k":3,"seed":1}"#));
    assert_eq!(baseline.get("ok").as_bool(), Some(true), "{baseline}");

    let state = State::new();
    state.handle(&req(
        r#"{"op":"register","name":"mix","kind":"mixture","n":600,"dim":8,"seed":7,"clusters":3}"#,
    ));
    let addr = serve_background_with(state, &ServerConfig::default()).unwrap();
    let (mut sock, mut reader) = connect(addr);
    sock.write_all(
        b"{\"v\":2,\"id\":5,\"op\":\"kmedoids\",\
          \"params\":{\"dataset\":\"mix\",\"k\":3,\"seed\":1,\"stream\":true}}\n",
    )
    .unwrap();

    let mut partials = Vec::new();
    let fin = loop {
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let resp = json::parse(line.trim()).unwrap();
        assert_eq!(resp.get("id").as_u64(), Some(5));
        assert_eq!(resp.get("ok").as_bool(), Some(true), "{resp}");
        if resp.get("partial").as_bool() == Some(true) {
            partials.push(resp);
        } else {
            break resp;
        }
    };
    assert!(partials.len() >= 3, "BUILD alone contributes k=3 trajectory points");
    for (i, p) in partials.iter().enumerate() {
        assert_eq!(p.get("seq").as_usize(), Some(i), "contiguous seq numbers");
        let phase = p.get("result").get("phase").as_str().unwrap();
        assert!(matches!(phase, "build" | "swap" | "polish"), "unknown phase {phase}");
        assert!(p.get("result").get("loss").as_f64().is_some());
    }
    let last_loss = partials.last().unwrap().get("result").get("loss").as_f64().unwrap();
    let final_loss = fin.get("result").get("loss").as_f64().unwrap();
    assert!((last_loss - final_loss).abs() <= 1e-6 * final_loss.abs().max(1.0));
    assert_eq!(
        fin.get("result").get("medoids"),
        baseline.get("medoids"),
        "streamed run diverged from the blocking baseline"
    );

    // Streaming medoid: per-round survivor counts from the halving trace.
    sock.write_all(
        b"{\"v\":2,\"id\":6,\"op\":\"medoid\",\
          \"params\":{\"dataset\":\"mix\",\"pulls_per_arm\":48,\"seed\":2,\"stream\":true}}\n",
    )
    .unwrap();
    let mut rounds = Vec::new();
    loop {
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let resp = json::parse(line.trim()).unwrap();
        assert_eq!(resp.get("id").as_u64(), Some(6));
        if resp.get("partial").as_bool() == Some(true) {
            rounds.push(resp.get("result").get("survivors").as_usize().unwrap());
        } else {
            assert!(resp.get("result").get("medoid").as_usize().is_some());
            break;
        }
    }
    assert!(!rounds.is_empty(), "halving rounds were not streamed");
    for w in rounds.windows(2) {
        assert!(w[1] <= w[0], "survivors must shrink round over round: {rounds:?}");
    }
}

/// Idle connections are closed once `idle_timeout_ms` passes with nothing
/// in flight and nothing buffered.
#[test]
fn idle_connections_are_closed_by_the_timeout() {
    if !event_loop_supported() {
        return; // the blocking fallback has no idle sweep
    }
    let state = State::new();
    let cfg = ServerConfig { idle_timeout_ms: 300, ..Default::default() };
    let addr = serve_background_with(state, &cfg).unwrap();
    let (mut sock, mut reader) = connect(addr);
    let p = rpc(&mut sock, &mut reader, r#"{"v":2,"id":1,"op":"ping"}"#);
    assert_eq!(p.get("ok").as_bool(), Some(true));
    sock.set_read_timeout(Some(std::time::Duration::from_secs(5))).unwrap();
    let mut line = String::new();
    match reader.read_line(&mut line) {
        Ok(0) => {} // clean EOF: the server closed the idle connection
        Ok(n) => panic!("unexpected {n}-byte frame on an idle connection: {line:?}"),
        Err(e) => panic!("idle connection was not closed within 5s: {e}"),
    }
}
