//! Distributed coordinator/worker tests (DESIGN.md §15).
//!
//! Workers are real servers — each an in-process epoll event loop behind an
//! ephemeral TCP port — so every pull here crosses the actual protocol-v2
//! wire path (`worker.prepare` digest handshake, `worker.pull` fan-out,
//! `bits_value` encoding, canonical segment reduction). The properties:
//!
//! * `pull_block` sums and `pull_matrix` rows are **bitwise identical** at
//!   every worker count {1, 2, 4}, for dense and sparse datasets across
//!   shard widths (including a prime one that misaligns with the grid).
//! * CorrSh picks the same medoid with the same pull count whether it runs
//!   against one process or a fleet.
//! * Killing a worker mid-session re-dispatches its segments to survivors
//!   without changing the winner or double-charging the budget ledger.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::path::{Path, PathBuf};
use std::sync::Arc;

use corrsh::bandits::{CorrSh, MedoidAlgorithm};
use corrsh::config::ServerConfig;
use corrsh::data::loader;
use corrsh::data::store::write_sharded;
use corrsh::data::synth::{Kind, SynthConfig};
use corrsh::distance::Metric;
use corrsh::engine::{DistConfig, DistRuntime, DistributedEngine, NativeEngine, PullEngine};
use corrsh::server::{serve_background_with, State};
use corrsh::util::json::{self, Value};
use corrsh::util::rng::Rng;
use corrsh::util::testing;

fn tmp(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("corrsh-distributed-tests").join(name);
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Spawn `n` worker servers on ephemeral loopback ports; returns endpoints.
fn spawn_workers(n: usize) -> Vec<String> {
    let cfg = ServerConfig {
        workers: 2,
        queue_cap: 64,
        max_request_bytes: 1 << 26,
        ..Default::default()
    };
    (0..n).map(|_| serve_background_with(State::new(), &cfg).unwrap().to_string()).collect()
}

/// Generate a dataset, persist it as an on-disk shard set, and return the
/// register params every worker will replay (name fixed to `"d"`).
fn dataset(
    kind: Kind,
    metric: Metric,
    n: usize,
    dim: usize,
    seed: u64,
    dir: &Path,
    rows_per_shard: usize,
) -> Value {
    let cfg = SynthConfig { n, dim, seed, ..Default::default() };
    let data = kind.generate(&cfg);
    let manifest = write_sharded(&data, dir.join("shards"), rows_per_shard).unwrap();
    json::parse(&format!(
        r#"{{"name":"d","path":{:?},"metric":{:?}}}"#,
        manifest.to_str().unwrap(),
        metric.name()
    ))
    .unwrap()
}

/// Single-process reference over the *same manifest* the workers serve.
fn native_for(register: &Value, metric: Metric) -> NativeEngine {
    let path = register.get("path").as_str().unwrap();
    NativeEngine::new(loader::load(path).unwrap(), metric)
}

/// Random non-empty sorted index subset.
fn subset(rng: &mut Rng, n: usize, max_len: usize) -> Vec<usize> {
    let len = 1 + rng.below(max_len);
    let mut v: Vec<usize> = (0..len).map(|_| rng.below(n)).collect();
    v.sort_unstable();
    v.dedup();
    v
}

/// Take a worker down for real: connection loss alone is healed by revive,
/// so the re-dispatch tests use the server's own shutdown op.
fn shutdown(endpoint: &str) {
    let mut sock = TcpStream::connect(endpoint).unwrap();
    sock.write_all(b"{\"op\":\"shutdown\"}\n").unwrap();
    let mut line = String::new();
    BufReader::new(sock).read_line(&mut line).unwrap();
    assert!(line.contains("shutting_down"), "unexpected shutdown reply: {line}");
}

#[test]
fn reduction_is_bitwise_identical_across_worker_counts() {
    // Dense and sparse, shard widths that do and do not divide the grid.
    let cases = [
        (Kind::Gaussian, Metric::L2, 100usize, "dense-100"),
        (Kind::Gaussian, Metric::L2, 77, "dense-77"),
        (Kind::RnaSeq, Metric::L1, 61, "sparse-61"),
    ];
    for (kind, metric, rows, tag) in cases {
        let n = 240;
        let dir = tmp(&format!("parity-{tag}"));
        let reg = dataset(kind, metric, n, 24, 9, &dir, rows);
        let native = native_for(&reg, metric);
        let endpoints = spawn_workers(4);
        let engines: Vec<DistributedEngine> = [1usize, 2, 4]
            .iter()
            .map(|&w| {
                let cfg = DistConfig { segments: 8, shard_rows: rows, ..Default::default() };
                DistributedEngine::connect(&endpoints[..w], "d", &reg, cfg).unwrap()
            })
            .collect();
        for eng in &engines {
            assert_eq!((eng.n(), eng.dim(), eng.metric()), (n, native.dim(), metric), "{tag}");
            assert_eq!(eng.segments(), engines[0].segments(), "{tag}: grid depends on fleet size");
        }
        testing::check(
            &format!("dist-parity-{tag}"),
            testing::cases_from_env(6).min(12),
            |rng| (subset(rng, n, 40), subset(rng, n, 90)),
            |case, _| {
                let (arms, refs) = case;
                let mut base: Option<Vec<u64>> = None;
                for (i, eng) in engines.iter().enumerate() {
                    let mut out = vec![0f64; arms.len()];
                    eng.pull_block(arms, refs, &mut out);
                    let bits: Vec<u64> = out.iter().map(|x| x.to_bits()).collect();
                    match &base {
                        None => base = Some(bits),
                        Some(b) if *b != bits => {
                            return Err(format!("pull_block diverged at engine {i}"));
                        }
                        Some(_) => {}
                    }
                }
                // Matrix rows carry raw f32 distances (no reduction), so
                // they must match the single-process kernels bit for bit.
                let mut want = vec![0f32; arms.len() * refs.len()];
                native.pull_matrix(arms, refs, &mut want);
                for (i, eng) in engines.iter().enumerate() {
                    let mut got = vec![0f32; arms.len() * refs.len()];
                    eng.pull_matrix(arms, refs, &mut got);
                    for (p, (g, w)) in got.iter().zip(&want).enumerate() {
                        if g.to_bits() != w.to_bits() {
                            return Err(format!(
                                "pull_matrix cell {p} diverged at engine {i}: {g} vs {w}"
                            ));
                        }
                    }
                }
                Ok(())
            },
        );
        let _ = std::fs::remove_dir_all(&dir);
    }
}

#[test]
fn corrsh_matches_single_process_at_any_worker_count() {
    let n = 300;
    let dir = tmp("winner");
    let reg = dataset(Kind::Gaussian, Metric::L2, n, 16, 3, &dir, 64);
    let native = native_for(&reg, Metric::L2);
    let algo = CorrSh::with_total_pulls(n as u64 * 96);
    let reference = algo.run(&native, &mut Rng::seeded(11));

    let endpoints = spawn_workers(4);
    let mut first: Option<(usize, u64, Vec<(usize, f64)>)> = None;
    for w in [1usize, 2, 4] {
        let cfg = DistConfig { segments: 8, shard_rows: 64, ..Default::default() };
        let eng = DistributedEngine::connect(&endpoints[..w], "d", &reg, cfg).unwrap();
        let res = algo.run(&eng, &mut Rng::seeded(11));
        assert_eq!(res.best, reference.best, "{w} workers picked a different medoid");
        assert_eq!(res.pulls, reference.pulls, "{w} workers consumed a different budget");
        // Accounting invariant: workers report exactly the scheduled grid,
        // so the ledger's remote total equals the algorithm's own count.
        assert_eq!(eng.reported_pulls(), Some(res.pulls), "{w} workers: report drift");
        assert_eq!(eng.redispatches(), 0, "{w} workers: no failures expected");
        match &first {
            None => first = Some((res.best, res.pulls, res.estimates)),
            Some((_, _, est)) => {
                // Estimates fold in canonical segment order, so they are
                // bitwise reproducible across fleet sizes (not just close).
                assert_eq!(res.estimates, *est, "{w} workers: estimates diverged");
            }
        }
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn killed_worker_redispatches_without_changing_the_answer() {
    let n = 260;
    let dir = tmp("kill");
    let reg = dataset(Kind::Gaussian, Metric::L2, n, 12, 5, &dir, 50);
    let algo = CorrSh::with_total_pulls(n as u64 * 80);
    let cfg = DistConfig { segments: 9, shard_rows: 50, ..Default::default() };
    let all_refs: Vec<usize> = (0..n).collect();
    let probe_arms = [0usize, 1, 2, 3];

    // Healthy 3-worker baseline (same probe pulls as the victim run below,
    // so the remote-report totals stay comparable).
    let healthy_eps = spawn_workers(3);
    let healthy = DistributedEngine::connect(&healthy_eps, "d", &reg, cfg.clone()).unwrap();
    let mut want_probe = vec![0f64; probe_arms.len()];
    healthy.pull_block(&probe_arms, &all_refs, &mut want_probe);
    let want = algo.run(&healthy, &mut Rng::seeded(5));
    assert_eq!(healthy.redispatches(), 0);

    // Victim run: same dataset on a fresh fleet, then take worker 2 down
    // after the session is established.
    let eps = spawn_workers(3);
    let eng = DistributedEngine::connect(&eps, "d", &reg, cfg).unwrap();
    shutdown(&eps[2]);
    // A full-range block touches every segment, so the dead worker's share
    // must be re-dispatched — and the re-assembled sums must still match
    // the healthy fleet bit for bit.
    let mut got_probe = vec![0f64; probe_arms.len()];
    eng.pull_block(&probe_arms, &all_refs, &mut got_probe);
    assert!(eng.redispatches() >= 1, "victim's segments were never re-dispatched");
    let want_bits: Vec<u64> = want_probe.iter().map(|x| x.to_bits()).collect();
    let got_bits: Vec<u64> = got_probe.iter().map(|x| x.to_bits()).collect();
    assert_eq!(got_bits, want_bits, "re-dispatched sums diverged");

    let got = algo.run(&eng, &mut Rng::seeded(5));
    assert_eq!(got.best, want.best, "losing a worker changed the medoid");
    assert_eq!(got.pulls, want.pulls, "losing a worker changed the budget accounting");
    // Pulls count only on absorbed responses: abandoned requests to the
    // dead worker never reach the ledger, so the totals stay exact.
    assert_eq!(eng.reported_pulls(), healthy.reported_pulls(), "re-dispatch double-charged");

    let rows = eng.worker_rows();
    assert!(!rows[2].alive, "victim still marked alive after failing");
    assert!(rows[0].alive && rows[1].alive, "survivors were dropped");
    assert_eq!(eng.health_check(), vec![true, true, false]);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn coordinator_state_fans_out_and_reports_fleet_metrics() {
    let endpoints = spawn_workers(2);
    let coord = State::new();
    coord.set_distributed(Arc::new(DistRuntime::new(
        endpoints,
        DistConfig { segments: 8, ..Default::default() },
    )));
    let local = State::new();

    // Generator-backed registration: workers replay the same params and
    // must land on the same digest.
    let reg = r#"{"op":"register","name":"toy","kind":"gaussian","n":220,"dim":8,"seed":4}"#;
    let r = coord.handle(&json::parse(reg).unwrap());
    assert_eq!(r.get("ok").as_bool(), Some(true), "coordinator register failed: {r}");
    assert_eq!(r.get("distributed").as_bool(), Some(true));
    assert_eq!(r.get("workers").as_usize(), Some(2));
    assert_eq!(local.handle(&json::parse(reg).unwrap()).get("ok").as_bool(), Some(true));

    let q = r#"{"op":"medoid","dataset":"toy","algo":"corrsh","pulls_per_arm":48,"seed":1}"#;
    let a = coord.handle(&json::parse(q).unwrap());
    let b = local.handle(&json::parse(q).unwrap());
    assert_eq!(a.get("ok").as_bool(), Some(true), "coordinator medoid failed: {a}");
    assert_eq!(a.get("medoid").as_usize(), b.get("medoid").as_usize(), "answers diverged");
    assert_eq!(a.get("pulls").as_f64(), b.get("pulls").as_f64(), "pull accounting diverged");
    assert_eq!(a.get("distributed").as_bool(), Some(true));

    let m = coord.handle(&json::parse(r#"{"op":"metrics"}"#).unwrap());
    assert_eq!(m.get("coordinator").as_bool(), Some(true), "metrics lost the coordinator row: {m}");
    assert_eq!(m.get("redispatches").as_u64(), Some(0));
    assert_eq!(m.get("workers").idx(0).get("alive").as_bool(), Some(true));
    assert!(m.get("workers").idx(1).get("endpoint").as_str().is_some(), "missing worker row: {m}");

    let u = coord.handle(&json::parse(r#"{"op":"unregister","name":"toy"}"#).unwrap());
    assert_eq!(u.get("ok").as_bool(), Some(true), "unregister failed: {u}");
}
