//! Integration: end-to-end behaviour across the public API — planted-medoid
//! recovery on every dataset geometry, the experiment harness, the service
//! protocol over TCP, and the CLI binary itself.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::process::Command;
use std::sync::Arc;

use corrsh::bandits::{CorrSh, MedoidAlgorithm};
use corrsh::config::{AlgoConfig, RunConfig};
use corrsh::data::synth::{Kind, SynthConfig};
use corrsh::engine::NativeEngine;
use corrsh::experiments::{figures, runner};
use corrsh::util::json;
use corrsh::util::rng::Rng;

/// corrSH at a healthy budget recovers the exact medoid on every dataset
/// kind with its paper metric.
#[test]
fn corrsh_recovers_exact_medoid_on_every_geometry() {
    for kind in [Kind::RnaSeq, Kind::Netflix, Kind::Mnist, Kind::Gaussian] {
        let cfg = SynthConfig { n: 400, dim: 256, seed: 11, density: 0.02, ..Default::default() };
        let data = Arc::new(kind.generate(&cfg));
        let metric = kind.default_metric();
        let truth = runner::ground_truth(&data, metric, 100_000);
        let engine = NativeEngine::with_threads(data.clone(), metric, 2);
        let mut hits = 0;
        let trials = 10;
        for t in 0..trials {
            let res = CorrSh::with_pulls_per_arm(96.0).run(&engine, &mut Rng::seeded(t));
            hits += (res.best == truth) as usize;
        }
        assert!(
            hits >= trials as usize - 1,
            "{}: corrSH hit {hits}/{trials} (truth {truth})",
            kind.name()
        );
    }
}

/// The correlation ablation: at equal budget, corrSH must be at least as
/// accurate as uncorrelated SH on clustered data (averaged over budgets).
#[test]
fn correlation_never_hurts_on_clustered_data() {
    let cfg = RunConfig {
        dataset_kind: Kind::RnaSeq,
        synth: SynthConfig { n: 300, dim: 256, seed: 13, ..Default::default() },
        metric: corrsh::distance::Metric::L1,
        ..Default::default()
    };
    let pts = figures::ablation_corr_vs_uncorr(&cfg, &[4.0, 16.0], 12, 0).unwrap();
    let err_sum = |name: &str| -> f64 {
        pts.iter().filter(|p| p.algo == name).map(|p| p.error_rate).sum()
    };
    let corr = err_sum("corrsh");
    let uncorr = err_sum("seq-halving");
    assert!(
        corr <= uncorr + 0.10,
        "correlated SH ({corr:.3}) worse than uncorrelated ({uncorr:.3})"
    );
}

/// Table-1 row at toy scale: the paper's ordering (corrSH ≪ Med-dit ≪ RAND ≤
/// exact in pulls) must hold.
#[test]
fn table1_row_preserves_paper_ordering() {
    let cfg = RunConfig {
        dataset_kind: Kind::RnaSeq,
        synth: SynthConfig { n: 250, dim: 256, seed: 17, ..Default::default() },
        metric: corrsh::distance::Metric::L1,
        ..Default::default()
    };
    let row = corrsh::experiments::table1::run_row("rnaseq-test", &cfg, 4, 0).unwrap();
    let pulls = |name: &str| {
        row.cells
            .iter()
            .find(|c| c.algo.starts_with(name))
            .map(|c| c.pulls_per_arm)
            .unwrap()
    };
    assert!(pulls("corrSH") < pulls("Meddit"), "corrSH not cheaper than Med-dit");
    assert!(pulls("Meddit") <= pulls("Rand") + 1.0, "Med-dit not cheaper than RAND(1000)");
    assert!(pulls("Rand") <= pulls("Exact") + 1e-9);
}

/// Service protocol over real TCP.
#[test]
fn server_tcp_medoid_query() {
    let state = corrsh::server::State::new();
    let addr = corrsh::server::serve_background(state).unwrap();
    let mut sock = TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(sock.try_clone().unwrap());
    let mut rpc = |req: &str| -> json::Value {
        sock.write_all(req.as_bytes()).unwrap();
        sock.write_all(b"\n").unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        json::parse(line.trim()).unwrap()
    };
    let r = rpc(r#"{"op":"register","name":"g","kind":"gaussian","n":250,"dim":8,"seed":2}"#);
    assert_eq!(r.get("ok").as_bool(), Some(true));
    let r = rpc(r#"{"op":"medoid","dataset":"g","algo":"corrsh","pulls_per_arm":64,"seed":5}"#);
    assert_eq!(r.get("medoid").as_usize(), Some(0), "planted medoid over TCP");
}

/// The CLI binary works end to end (medoid + stats + gen).
#[test]
fn cli_binary_smoke() {
    let bin = env!("CARGO_BIN_EXE_corrsh");
    let out = Command::new(bin)
        .args(["medoid", "--preset", "toy", "--n", "300", "--dim", "8", "--algo", "corrsh",
               "--budget", "64", "--trials", "2"])
        .output()
        .expect("run corrsh medoid");
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("medoid=0"), "toy planted medoid not found: {stdout}");

    let out = Command::new(bin)
        .args(["stats", "--preset", "toy", "--n", "200", "--dim", "8"])
        .output()
        .unwrap();
    assert!(out.status.success());
    assert!(String::from_utf8_lossy(&out.stdout).contains("H2"));

    let dir = std::env::temp_dir().join("corrsh-cli-test");
    std::fs::create_dir_all(&dir).unwrap();
    let npy = dir.join("x.npy");
    let out = Command::new(bin)
        .args(["gen", "--kind", "mnist", "--n", "10", "--dim", "64", "--out"])
        .arg(&npy)
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let loaded = corrsh::data::loader::load(&npy).unwrap();
    assert_eq!((loaded.n(), loaded.dim()), (10, 64));

    // unknown flags must fail fast
    let out = Command::new(bin).args(["medoid", "--tpyo", "1"]).output().unwrap();
    assert!(!out.status.success());
}

/// The k-medoids CLI subcommand end to end (the PR's CLI-side acceptance
/// check): k = 5 planted clusters on n = 2000 via a config file with a
/// `kmedoids` block, ≥ 4/5 planted centers recovered at ≤ 5% of the exact
/// k·n² BUILD sweep.
#[test]
fn cli_kmedoids_recovers_planted_clusters() {
    let bin = env!("CARGO_BIN_EXE_corrsh");
    let dir = std::env::temp_dir().join("corrsh-cli-kmed");
    std::fs::create_dir_all(&dir).unwrap();
    let cfg_path = dir.join("kmed.json");
    std::fs::write(
        &cfg_path,
        r#"{"dataset": {"kind": "mixture", "n": 2000, "dim": 16, "seed": 42, "clusters": 5},
            "kmedoids": {"k": 5}}"#,
    )
    .unwrap();
    let out = Command::new(bin)
        .args(["kmedoids", "--config"])
        .arg(&cfg_path)
        .args(["--seed", "1"])
        .output()
        .expect("run corrsh kmedoids");
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8_lossy(&out.stdout);
    let list = stdout
        .split_once("medoids=[")
        .and_then(|(_, rest)| rest.split_once(']'))
        .map(|(inner, _)| inner)
        .unwrap_or_else(|| panic!("no medoids list in output: {stdout}"));
    let medoids: Vec<usize> =
        list.split(',').map(|s| s.trim().parse().unwrap()).collect();
    assert_eq!(medoids.len(), 5, "{stdout}");
    let hits = medoids.iter().filter(|&&m| m < 5).count();
    assert!(hits >= 4, "planted-center agreement {hits}/5: {stdout}");
    let pulls: u64 = stdout
        .split_once("pulls=")
        .and_then(|(_, rest)| rest.split_whitespace().next())
        .and_then(|s| s.parse().ok())
        .unwrap_or_else(|| panic!("no pull count in output: {stdout}"));
    assert!(pulls * 20 <= 5 * 2000 * 2000, "{pulls} pulls > 5% of the exact sweep");

    // flag overrides ride on top of the config file
    let out = Command::new(bin)
        .args(["kmedoids", "--config"])
        .arg(&cfg_path)
        .args(["--k", "3", "--swap-rounds", "0", "--seed", "0"])
        .output()
        .unwrap();
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("swaps=0/0"), "swap rounds not disabled: {stdout}");

    // degenerate k fails fast
    let out = Command::new(bin)
        .args(["kmedoids", "--kind", "gaussian", "--n", "50", "--dim", "4", "--k", "100"])
        .output()
        .unwrap();
    assert!(!out.status.success(), "k > n should fail");
}

/// Config file round-trip through the CLI.
#[test]
fn cli_config_file() {
    let bin = env!("CARGO_BIN_EXE_corrsh");
    let dir = std::env::temp_dir().join("corrsh-cli-cfg");
    std::fs::create_dir_all(&dir).unwrap();
    let cfg_path = dir.join("run.json");
    std::fs::write(
        &cfg_path,
        r#"{"dataset": {"kind": "gaussian", "n": 200, "dim": 8, "seed": 3},
            "algo": {"name": "corrsh", "pulls_per_arm": 64}}"#,
    )
    .unwrap();
    let out = Command::new(bin)
        .args(["medoid", "--config"])
        .arg(&cfg_path)
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    assert!(String::from_utf8_lossy(&out.stdout).contains("medoid=0"));
}

/// AlgoConfig::build produces runnable algorithms for all variants.
#[test]
fn every_algo_config_runs() {
    let data = Arc::new(Kind::Gaussian.generate(&SynthConfig {
        n: 120,
        dim: 8,
        seed: 23,
        ..Default::default()
    }));
    let engine = NativeEngine::with_threads(data, corrsh::distance::Metric::L2, 1);
    for algo in [
        AlgoConfig::CorrSh { pulls_per_arm: 32.0 },
        AlgoConfig::SeqHalving { pulls_per_arm: 32.0 },
        AlgoConfig::Meddit { delta: 0.0, cap: 5_000 },
        AlgoConfig::Rand { refs_per_arm: 60 },
        AlgoConfig::TopRank { phase1_refs: 40 },
        AlgoConfig::Exact,
    ] {
        let res = algo.build(120).run(&engine, &mut Rng::seeded(0));
        assert!(res.best < 120, "{} returned junk", algo.name());
        assert!(res.pulls > 0);
    }
}
