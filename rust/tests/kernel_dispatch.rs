//! `CORRSH_KERNEL` dispatch contract, in its own test binary: the env
//! override is read exactly once into the process-wide `OnceLock` in
//! `engine::simd`, so forcing it requires a process where *nothing* has
//! touched `simd::active()` yet — which the in-crate unit tests (one
//! shared binary, arbitrary test order) cannot guarantee.

use std::sync::Arc;

use corrsh::data::synth::{mnist, netflix, SynthConfig};
use corrsh::data::Data;
use corrsh::distance::Metric;
use corrsh::engine::kernel::DenseTileCtx;
use corrsh::engine::simd::{self, Variant};
use corrsh::engine::{NativeEngine, PullEngine};

#[test]
fn forced_scalar_env_agrees_with_detected_kernels() {
    // One #[test] on purpose: the harness runs separate tests on separate
    // threads, and the override must be in place before the first
    // `active()` call anywhere in the process.
    std::env::set_var("CORRSH_KERNEL", "scalar");
    assert_eq!(simd::active(), Variant::Scalar);
    let info = simd::kernel_info();
    assert!(
        info.contains("kernel_variant=scalar") && info.contains("source=env"),
        "kernel_info must reflect the env override: {info}"
    );

    // Dense: full engine outputs under the env-forced scalar dispatch vs a
    // tile session pinned to the *detected* vector variant — bitwise equal
    // on both APIs for every metric (DESIGN.md §14).
    let detected = simd::detect();
    let n = 64;
    let cfg = SynthConfig { n, dim: 97, seed: 11, ..Default::default() };
    let data = Arc::new(mnist::generate(&cfg));
    let arms: Vec<usize> = (0..n - 3).collect(); // off the ARM_TILE grid
    let refs: Vec<usize> = (0..27).map(|r| (r * 7 + 1) % n).collect(); // off the 8-lane grid
    for metric in Metric::ALL {
        let e = NativeEngine::with_threads(data.clone(), metric, 3);
        let d = match &*data {
            Data::Dense(d) => d,
            _ => unreachable!("mnist is dense"),
        };
        let ctx = DenseTileCtx::new(d, metric, e.prepared().norms(), e.prepared().sq_norms())
            .with_variant(detected);
        let mut env_sums = vec![0f64; arms.len()];
        let mut simd_sums = vec![0f64; arms.len()];
        e.pull_block(&arms, &refs, &mut env_sums);
        ctx.block_sums(&arms, &refs, 3, &mut simd_sums);
        assert_eq!(env_sums, simd_sums, "{metric}: forced-scalar block != {detected}");
        let mut env_mat = vec![0f32; arms.len() * refs.len()];
        let mut simd_mat = vec![0f32; arms.len() * refs.len()];
        e.pull_matrix(&arms, &refs, &mut env_mat);
        ctx.matrix(&arms, &refs, 3, &mut simd_mat);
        assert_eq!(env_mat, simd_mat, "{metric}: forced-scalar matrix != {detected}");
    }

    // Sparse: the forced-scalar run walks must still serve the engine
    // block path — finite sums that match the per-pull merge-walk oracle
    // (different algorithm, so tolerance not bitwise; the scalar/vector
    // bitwise identity itself is pinned by the `engine::simd` unit tests).
    let sdata = Arc::new(netflix::generate(&SynthConfig {
        n: 60,
        dim: 300,
        seed: 7,
        density: 0.2,
        ..Default::default()
    }));
    let sarms: Vec<usize> = (0..60).collect();
    let srefs: Vec<usize> = (0..31).collect();
    for metric in Metric::ALL {
        let e = NativeEngine::with_threads(sdata.clone(), metric, 2);
        let mut sums = vec![0f64; sarms.len()];
        e.pull_block(&sarms, &srefs, &mut sums);
        for (k, &a) in sarms.iter().enumerate() {
            assert!(sums[k].is_finite(), "{metric} arm {k}: non-finite sum {}", sums[k]);
            let oracle: f64 = srefs.iter().map(|&r| e.pull(a, r) as f64).sum();
            assert!(
                (sums[k] - oracle).abs() <= 1e-4 * oracle.abs().max(1.0),
                "{metric} arm {k}: forced-scalar block {} vs per-pull {oracle}",
                sums[k]
            );
        }
    }
}
