//! E11 — server-throughput bench (the PR-2 headline): protocol-level
//! medoid queries per second through `State::handle`, cold-engine vs
//! cached-engine, plus the executor path end to end. Emits
//! `BENCH_server.json` (schema_version 1) as a CI perf artifact next to
//! `BENCH_engine.json`.
//!
//! "Cold" re-registers the dataset before every query, which invalidates
//! the session cache and forces the O(n·d) preparation pass — the cost
//! every query paid before PR 2. "Cached" is the server's steady state.
//!
//! The `soak/*` rows (PR 6) exercise the epoll event loop end to end:
//! thousands of idle connections held open while hundreds of active
//! clients pipeline v2 queries over real sockets, then a deliberate
//! overload burst to measure the admission-control shed rate. The server
//! side runs on one event-loop thread plus the worker pool; every
//! response is id-matched against the blocking `State::handle` baseline.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::time::Instant;

use corrsh::config::ServerConfig;
use corrsh::server::{
    event_loop_supported, raise_nofile_limit, serve_background_with, Executor, State,
};
use corrsh::util::bench::Bencher;
use corrsh::util::json;

fn req(s: &str) -> json::Value {
    json::parse(s).unwrap()
}

fn env_or(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|s| s.parse().ok()).unwrap_or(default)
}

fn main() {
    let n: usize = std::env::var("CORRSH_BENCH_SERVER_N")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(2000);
    let register = format!(
        r#"{{"op":"register","name":"bench","kind":"rnaseq","n":{n},"dim":256,"seed":1}}"#
    );
    let medoid = r#"{"op":"medoid","dataset":"bench","algo":"corrsh","pulls_per_arm":16,"seed":7}"#;

    let mut b = Bencher::new();
    b.group(&format!("server medoid queries (rnaseq n={n}, corrsh@16ppa)"));

    // Cold: drop the cached session between queries so every query pays
    // the O(n·d) preparation pass — but NOT dataset regeneration, which
    // the cache does not amortize and would overstate the speedup.
    {
        let state = State::new();
        let r = state.handle(&req(&register));
        assert_eq!(r.get("ok").as_bool(), Some(true), "{r}");
        let q = req(medoid);
        b.bench_items("cold-engine", 1, || {
            state.engine_cache().invalidate("bench");
            let r = state.handle(&q);
            assert_eq!(r.get("ok").as_bool(), Some(true), "{r}");
            r.get("medoid").as_usize()
        });
        let m = state.handle(&req(r#"{"op":"metrics"}"#));
        b.record_metric(
            "cold/engine_preparations",
            m.get("engine_cache").get("misses").as_u64().unwrap_or(0) as f64,
            "preparations",
        );
    }

    // Cached: register once, query many times against the shared session.
    {
        let state = State::new();
        let r = state.handle(&req(&register));
        assert_eq!(r.get("ok").as_bool(), Some(true), "{r}");
        let q = req(medoid);
        b.bench_items("cached-engine", 1, || {
            let r = state.handle(&q);
            assert_eq!(r.get("ok").as_bool(), Some(true), "{r}");
            r.get("medoid").as_usize()
        });
        let m = state.handle(&req(r#"{"op":"metrics"}"#));
        b.record_metric(
            "cached/engine_preparations",
            m.get("engine_cache").get("misses").as_u64().unwrap_or(0) as f64,
            "preparations",
        );
    }

    // Executor path: the same cached query through the bounded queue (what
    // a TCP client exercises, minus the socket).
    {
        let state = State::new();
        let r = state.handle(&req(&register));
        assert_eq!(r.get("ok").as_bool(), Some(true), "{r}");
        let exec = Executor::new(state, 0, 256);
        let q = req(medoid);
        b.bench_items("cached-engine-via-executor", 1, || {
            let r = exec.submit(q.clone());
            assert_eq!(r.get("ok").as_bool(), Some(true), "{r}");
            r.get("medoid").as_usize()
        });
        // Batch amortization: 16 seeds per request.
        let batch = req(
            r#"{"op":"medoid_batch","dataset":"bench","pulls_per_arm":16,
                "seed":0,"count":16}"#,
        );
        b.bench_items("medoid_batch-16-seeds", 16, || {
            let r = exec.submit(batch.clone());
            assert_eq!(r.get("ok").as_bool(), Some(true), "{r}");
            r.get("jobs").as_usize()
        });
        exec.shutdown();
    }

    soak(&mut b);

    b.write_jsonl();
    b.write_bench_json("server");
}

const SOAK_REGISTER: &str =
    r#"{"op":"register","name":"soak","kind":"gaussian","n":500,"dim":16,"seed":1}"#;
const SAT_REGISTER: &str =
    r#"{"op":"register","name":"sat","kind":"gaussian","n":4000,"dim":32,"seed":2}"#;
const REQS_PER_CLIENT: usize = 20;
const SEEDS: usize = 32;
const SAT_REQS: usize = 256;

/// Soak the event loop: `CORRSH_BENCH_SOAK_IDLE` idle connections (default
/// 2000, degraded gracefully if the fd limit is lower) plus
/// `CORRSH_BENCH_SOAK_ACTIVE` pipelined clients (default 200), then an
/// overload burst against a single quota-capped connection.
fn soak(b: &mut Bencher) {
    b.group("soak");
    if !event_loop_supported() {
        // Keep the row schema stable for CI even where the epoll loop is
        // compiled out (the blocking fallback would need a thread per
        // connection, which defeats the point of a soak).
        b.record_metric("idle_conns", 0.0, "connections");
        b.record_metric("active_clients", 0.0, "clients");
        b.record_metric("sustained_rps", 0.0, "req/s");
        b.record_metric("p99_ms", 0.0, "ms");
        b.record_metric("shed_rate", 0.0, "fraction");
        return;
    }
    let fd_limit = raise_nofile_limit();
    let active = env_or("CORRSH_BENCH_SOAK_ACTIVE", 200);
    let idle_target = env_or("CORRSH_BENCH_SOAK_IDLE", 2000);
    // Every connection costs two fds here (the client end and the
    // in-process server end); keep headroom for the process itself.
    let budget = (fd_limit.saturating_sub(256) / 2) as usize;
    let idle = idle_target.min(budget.saturating_sub(2 * active));

    // Blocking-server baseline: the deterministic winner per seed.
    let reference = State::new();
    let r = reference.handle(&req(SOAK_REGISTER));
    assert_eq!(r.get("ok").as_bool(), Some(true), "{r}");
    let mut baseline = Vec::with_capacity(SEEDS);
    for seed in 0..SEEDS {
        let r = reference.handle(&req(&format!(
            r#"{{"op":"medoid","dataset":"soak","pulls_per_arm":16,"seed":{seed}}}"#
        )));
        assert_eq!(r.get("ok").as_bool(), Some(true), "{r}");
        baseline.push(r.get("medoid").as_usize().unwrap());
    }

    let state = State::new();
    assert_eq!(state.handle(&req(SOAK_REGISTER)).get("ok").as_bool(), Some(true));
    assert_eq!(state.handle(&req(SAT_REGISTER)).get("ok").as_bool(), Some(true));
    let cfg = ServerConfig {
        workers: 4,
        queue_cap: 8192,
        max_connections: idle + active + 64,
        max_inflight_per_conn: 64,
        idle_timeout_ms: 0,
        ..Default::default()
    };
    let addr = serve_background_with(state, &cfg).unwrap();

    let mut idle_conns = Vec::with_capacity(idle);
    for _ in 0..idle {
        match TcpStream::connect(addr) {
            Ok(s) => idle_conns.push(s),
            Err(_) => break, // fd pressure: record the degraded count below
        }
    }

    // Active phase: each client writes its whole pipelined burst in one
    // syscall, then collects id-matched responses. Latency is measured
    // per response from the burst write, i.e. it includes queueing behind
    // the client's own pipeline — the number a pipelining client observes.
    let start = Instant::now();
    let mut handles = Vec::with_capacity(active);
    for c in 0..active {
        let baseline = baseline.clone();
        handles.push(corrsh::util::threads::spawn(&format!("corrsh-bench-{c}"), move || {
            let mut sock = TcpStream::connect(addr).unwrap();
            let mut reader = BufReader::new(sock.try_clone().unwrap());
            let mut burst = String::new();
            for j in 0..REQS_PER_CLIENT {
                let id = (c * REQS_PER_CLIENT + j) as u64 + 1;
                let seed = (c * REQS_PER_CLIENT + j) % SEEDS;
                burst.push_str(&format!(
                    "{{\"v\":2,\"id\":{id},\"op\":\"medoid\",\
                     \"params\":{{\"dataset\":\"soak\",\"pulls_per_arm\":16,\"seed\":{seed}}}}}\n"
                ));
            }
            let t0 = Instant::now();
            sock.write_all(burst.as_bytes()).unwrap();
            let mut lat_us = Vec::with_capacity(REQS_PER_CLIENT);
            let mut seen = [false; REQS_PER_CLIENT];
            for _ in 0..REQS_PER_CLIENT {
                let mut line = String::new();
                reader.read_line(&mut line).unwrap();
                let resp = json::parse(line.trim()).unwrap();
                assert_eq!(resp.get("ok").as_bool(), Some(true), "{resp}");
                let id = resp.get("id").as_u64().unwrap() as usize;
                let j = (id - 1) - c * REQS_PER_CLIENT;
                assert!(j < REQS_PER_CLIENT && !seen[j], "bad or duplicate id {id}");
                seen[j] = true;
                let want = baseline[(id - 1) % SEEDS];
                assert_eq!(
                    resp.get("result").get("medoid").as_usize(),
                    Some(want),
                    "medoid diverged from the blocking baseline (id {id})"
                );
                lat_us.push(t0.elapsed().as_micros() as u64);
            }
            lat_us
        }));
    }
    let mut lat_us: Vec<u64> = Vec::with_capacity(active * REQS_PER_CLIENT);
    for h in handles {
        // A panic here means a dropped/duplicated in-flight request.
        lat_us.extend(h.join().expect("soak client failed"));
    }
    let wall = start.elapsed().as_secs_f64();
    let total = active * REQS_PER_CLIENT;
    lat_us.sort_unstable();
    let p99_ms = lat_us[(total * 99 / 100).min(total - 1)] as f64 / 1000.0;

    // The idle pool must have survived the whole active phase.
    for i in [0, idle_conns.len().saturating_sub(1)] {
        let Some(s) = idle_conns.get_mut(i) else { continue };
        s.write_all(b"{\"v\":2,\"id\":7,\"op\":\"ping\"}\n").unwrap();
        let mut line = String::new();
        BufReader::new(s.try_clone().unwrap()).read_line(&mut line).unwrap();
        assert!(line.contains("\"pong\":true"), "idle connection died during soak: {line}");
    }

    // Overload burst: one connection, quota 64, 256 requests in one write.
    // Admission control must answer the overflow with structured
    // `overloaded` errors instead of stalling or dropping frames.
    let mut sat = TcpStream::connect(addr).unwrap();
    let mut sat_reader = BufReader::new(sat.try_clone().unwrap());
    let mut burst = String::new();
    for i in 0..SAT_REQS {
        burst.push_str(&format!(
            "{{\"v\":2,\"id\":{},\"op\":\"medoid\",\
             \"params\":{{\"dataset\":\"sat\",\"pulls_per_arm\":24,\"seed\":3}}}}\n",
            i + 1
        ));
    }
    sat.write_all(burst.as_bytes()).unwrap();
    let mut shed = 0usize;
    for _ in 0..SAT_REQS {
        let mut line = String::new();
        sat_reader.read_line(&mut line).unwrap();
        let resp = json::parse(line.trim()).unwrap();
        if resp.get("ok").as_bool() != Some(true) {
            assert_eq!(resp.get("error").get("code").as_str(), Some("overloaded"), "{resp}");
            shed += 1;
        }
    }
    assert!(shed > 0, "saturation burst produced no overload sheds");

    b.record_metric("idle_conns", idle_conns.len() as f64, "connections");
    b.record_metric("active_clients", active as f64, "clients");
    b.record_metric("sustained_rps", total as f64 / wall, "req/s");
    b.record_metric("p99_ms", p99_ms, "ms");
    b.record_metric("shed_rate", shed as f64 / SAT_REQS as f64, "fraction");
}
