//! E11 — server-throughput bench (the PR-2 headline): protocol-level
//! medoid queries per second through `State::handle`, cold-engine vs
//! cached-engine, plus the executor path end to end. Emits
//! `BENCH_server.json` (schema_version 1) as a CI perf artifact next to
//! `BENCH_engine.json`.
//!
//! "Cold" re-registers the dataset before every query, which invalidates
//! the session cache and forces the O(n·d) preparation pass — the cost
//! every query paid before PR 2. "Cached" is the server's steady state.

use corrsh::server::{Executor, State};
use corrsh::util::bench::Bencher;
use corrsh::util::json;

fn req(s: &str) -> json::Value {
    json::parse(s).unwrap()
}

fn main() {
    let n: usize = std::env::var("CORRSH_BENCH_SERVER_N")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(2000);
    let register = format!(
        r#"{{"op":"register","name":"bench","kind":"rnaseq","n":{n},"dim":256,"seed":1}}"#
    );
    let medoid = r#"{"op":"medoid","dataset":"bench","algo":"corrsh","pulls_per_arm":16,"seed":7}"#;

    let mut b = Bencher::new();
    b.group(&format!("server medoid queries (rnaseq n={n}, corrsh@16ppa)"));

    // Cold: drop the cached session between queries so every query pays
    // the O(n·d) preparation pass — but NOT dataset regeneration, which
    // the cache does not amortize and would overstate the speedup.
    {
        let state = State::new();
        let r = state.handle(&req(&register));
        assert_eq!(r.get("ok").as_bool(), Some(true), "{r}");
        let q = req(medoid);
        b.bench_items("cold-engine", 1, || {
            state.engine_cache().invalidate("bench");
            let r = state.handle(&q);
            assert_eq!(r.get("ok").as_bool(), Some(true), "{r}");
            r.get("medoid").as_usize()
        });
        let m = state.handle(&req(r#"{"op":"metrics"}"#));
        b.record_metric(
            "cold/engine_preparations",
            m.get("engine_cache").get("misses").as_u64().unwrap_or(0) as f64,
            "preparations",
        );
    }

    // Cached: register once, query many times against the shared session.
    {
        let state = State::new();
        let r = state.handle(&req(&register));
        assert_eq!(r.get("ok").as_bool(), Some(true), "{r}");
        let q = req(medoid);
        b.bench_items("cached-engine", 1, || {
            let r = state.handle(&q);
            assert_eq!(r.get("ok").as_bool(), Some(true), "{r}");
            r.get("medoid").as_usize()
        });
        let m = state.handle(&req(r#"{"op":"metrics"}"#));
        b.record_metric(
            "cached/engine_preparations",
            m.get("engine_cache").get("misses").as_u64().unwrap_or(0) as f64,
            "preparations",
        );
    }

    // Executor path: the same cached query through the bounded queue (what
    // a TCP client exercises, minus the socket).
    {
        let state = State::new();
        let r = state.handle(&req(&register));
        assert_eq!(r.get("ok").as_bool(), Some(true), "{r}");
        let exec = Executor::new(state, 0, 256);
        let q = req(medoid);
        b.bench_items("cached-engine-via-executor", 1, || {
            let r = exec.submit(q.clone());
            assert_eq!(r.get("ok").as_bool(), Some(true), "{r}");
            r.get("medoid").as_usize()
        });
        // Batch amortization: 16 seeds per request.
        let batch = req(
            r#"{"op":"medoid_batch","dataset":"bench","pulls_per_arm":16,
                "seed":0,"count":16}"#,
        );
        b.bench_items("medoid_batch-16-seeds", 16, || {
            let r = exec.submit(batch.clone());
            assert_eq!(r.get("ok").as_bool(), Some(true), "{r}");
            r.get("jobs").as_usize()
        });
        exec.shutdown();
    }

    b.write_jsonl();
    b.write_bench_json("server");
}
