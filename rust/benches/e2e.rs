//! E10 — end-to-end corrSH wall-clock bench (the §Perf headline): one full
//! Correlated Sequential Halving run per iteration on each dataset
//! geometry, native engine, default thread count — the number EXPERIMENTS.md
//! §Perf tracks before/after optimization.
//!
//! Two storage-layer additions (EXPERIMENTS.md §Perf #7):
//!
//! * `sharded vs resident` — the same corrSH run over the same bytes,
//!   resident vs served from a shard manifest (pinned reader), recorded as
//!   the `sharded_vs_resident` relative-throughput row. Winners are
//!   asserted identical (the backends are bitwise-parity tested).
//! * `e2e million` (env `CORRSH_E2E_MILLION=1`) — an n = 10⁶, d = 128
//!   corrSH medoid run *from a shard manifest*, streamed through the
//!   shard writer so the matrix never materializes; records wall seconds,
//!   pulls/arm and the process peak-RSS, and fails loudly if resident
//!   memory exceeded 2 GiB (the ISSUE's acceptance envelope).
//!
//! Distributed rows (EXPERIMENTS.md §Perf #9): `dist/workers_{1,2,4}` run
//! the same corrSH workload through a coordinator fanning `worker.pull`
//! to real loopback worker servers; `dist/speedup` is single-process mean
//! over the 4-worker mean, and `dist/redispatch_ms` times the first
//! full-range block after one of three workers is killed mid-session.
//! Loopback workers share the host's cores, so speedup ≈ 1 here — the
//! rows exist to track protocol/coordination overhead and failure-path
//! latency, not to claim multi-host scaling.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;

use corrsh::bandits::{CorrSh, MedoidAlgorithm};
use corrsh::config::{RunConfig, ServerConfig};
use corrsh::data::store::{ShardedData, StoreOptions};
use corrsh::data::synth::{Kind, SynthConfig};
use corrsh::data::Data;
use corrsh::engine::{DistConfig, DistributedEngine};
use corrsh::experiments::runner;
use corrsh::server::{serve_background_with, State};
use corrsh::util::bench::Bencher;
use corrsh::util::json::{self, Value};
use corrsh::util::rng::Rng;

/// Peak resident set size of this process in bytes (linux VmHWM; 0 where
/// /proc is unavailable — the memory gate only runs on linux CI).
fn peak_rss_bytes() -> u64 {
    let Ok(status) = std::fs::read_to_string("/proc/self/status") else { return 0 };
    for line in status.lines() {
        if let Some(rest) = line.strip_prefix("VmHWM:") {
            let kb: u64 = rest
                .trim()
                .trim_end_matches("kB")
                .trim()
                .parse()
                .unwrap_or(0);
            return kb * 1024;
        }
    }
    0
}

/// Spawn `n` in-process worker servers on ephemeral loopback ports.
fn spawn_workers(n: usize) -> Vec<String> {
    let cfg = ServerConfig {
        workers: 2,
        queue_cap: 64,
        max_request_bytes: 1 << 26,
        ..Default::default()
    };
    (0..n).map(|_| serve_background_with(State::new(), &cfg).unwrap().to_string()).collect()
}

/// Register params the coordinator replays on every worker.
fn register_params(manifest: &std::path::Path) -> Value {
    json::parse(&format!(
        r#"{{"name":"d","path":{:?},"metric":"l2"}}"#,
        manifest.to_str().unwrap()
    ))
    .unwrap()
}

/// Kill a worker for real (its own shutdown op, not just connection loss).
fn kill_worker(endpoint: &str) {
    let mut sock = TcpStream::connect(endpoint).unwrap();
    sock.write_all(b"{\"op\":\"shutdown\"}\n").unwrap();
    let mut line = String::new();
    BufReader::new(sock).read_line(&mut line).unwrap();
}

fn main() {
    let scale: usize = std::env::var("CORRSH_BENCH_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(20);
    let mut b = Bencher::new();
    b.group(&format!("e2e corrSH (scale 1/{scale}, native engine)"));

    for preset in ["rnaseq20k", "netflix20k", "mnist"] {
        let cfg = RunConfig::preset(preset).unwrap().scaled_down(scale);
        let data = runner::build_data(&cfg);
        let n = data.n();
        let engine = corrsh::engine::NativeEngine::with_threads(
            data.clone(),
            cfg.metric,
            corrsh::util::threads::default_threads(),
        );
        let mut seed = 0u64;
        let mut pulls = 0u64;
        b.bench_items(&format!("{preset}/n={n}/corrsh@24ppa"), n as u64, || {
            let mut rng = Rng::seeded(seed);
            seed += 1;
            let res = CorrSh::with_pulls_per_arm(24.0).run(&engine, &mut rng);
            pulls = res.pulls;
            res.best
        });
        b.record_metric(&format!("{preset}/pulls_per_arm"), pulls as f64 / n as f64, "pulls/arm");
    }

    // ---- sharded vs resident: same bytes, two storage backends --------
    b.group("e2e sharded vs resident");
    {
        let cfg = RunConfig::preset("mnist").unwrap().scaled_down(scale);
        let data = runner::build_data(&cfg);
        let n = data.n();
        let dir = std::env::temp_dir().join("corrsh-e2e-bench").join("mnist-shards");
        let _ = std::fs::remove_dir_all(&dir);
        let manifest =
            corrsh::data::store::write_sharded(&data, &dir, (n / 8).max(1)).unwrap();
        // pinned reader (the portable worst case; mmap builds only get
        // faster than this)
        let sd = ShardedData::open_with(
            &manifest,
            &StoreOptions { force_pinned: true, ..Default::default() },
        )
        .unwrap();
        let threads = corrsh::util::threads::default_threads();
        let resident =
            corrsh::engine::NativeEngine::with_threads(data.clone(), cfg.metric, threads);
        let sharded = corrsh::engine::NativeEngine::with_threads(
            Arc::new(Data::Sharded(sd)),
            cfg.metric,
            threads,
        );
        let mut res_best = 0usize;
        b.bench_items(&format!("resident/n={n}/corrsh@24ppa"), n as u64, || {
            let mut rng = Rng::seeded(7);
            res_best = CorrSh::with_pulls_per_arm(24.0).run(&resident, &mut rng).best;
            res_best
        });
        let resident_s = b.last_mean_s().unwrap();
        let mut sh_best = 0usize;
        b.bench_items(&format!("sharded/n={n}/corrsh@24ppa"), n as u64, || {
            let mut rng = Rng::seeded(7);
            sh_best = CorrSh::with_pulls_per_arm(24.0).run(&sharded, &mut rng).best;
            sh_best
        });
        let sharded_s = b.last_mean_s().unwrap();
        assert_eq!(res_best, sh_best, "backends disagreed on the medoid");
        // >1 would mean sharding is free; the row tracks how close we get
        b.record_metric("sharded_vs_resident", resident_s / sharded_s, "x rel throughput");
    }

    // ---- distributed scale-out (EXPERIMENTS.md §Perf #9) ----------------
    b.group("e2e distributed (coordinator + loopback workers)");
    {
        let n: usize = std::env::var("CORRSH_E2E_DIST_N")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(8_000);
        let dim = 64;
        let rows = (n / 16).max(1);
        let dir = std::env::temp_dir().join("corrsh-e2e-bench").join("dist-shards");
        let _ = std::fs::remove_dir_all(&dir);
        let cfg = SynthConfig { n, dim, seed: 0, ..Default::default() };
        let data = Kind::Gaussian.generate(&cfg);
        let manifest = corrsh::data::store::write_sharded(&data, &dir, rows).unwrap();
        let reg = register_params(&manifest);
        let endpoints = spawn_workers(4);

        let local = corrsh::engine::NativeEngine::with_threads(
            Arc::new(data),
            corrsh::distance::Metric::L2,
            corrsh::util::threads::default_threads(),
        );
        let mut local_best = 0usize;
        b.bench_items(&format!("dist/single_process/n={n}"), n as u64, || {
            local_best = CorrSh::with_pulls_per_arm(24.0).run(&local, &mut Rng::seeded(3)).best;
            local_best
        });
        let single_s = b.last_mean_s().unwrap();

        let mut four_s = single_s;
        for w in [1usize, 2, 4] {
            let dcfg = DistConfig { segments: 8, shard_rows: rows, ..Default::default() };
            let eng = DistributedEngine::connect(&endpoints[..w], "d", &reg, dcfg).unwrap();
            let mut best = 0usize;
            b.bench_items(&format!("dist/workers_{w}/n={n}"), n as u64, || {
                best = CorrSh::with_pulls_per_arm(24.0).run(&eng, &mut Rng::seeded(3)).best;
                best
            });
            assert_eq!(best, local_best, "fleet of {w} workers disagreed on the medoid");
            if w == 4 {
                four_s = b.last_mean_s().unwrap();
            }
        }
        b.record_metric("dist/speedup", single_s / four_s, "x vs single process");

        // Failure path: kill one of three workers, then time the first
        // full-range block — re-detect + re-dispatch + survivor recompute.
        let eps = spawn_workers(3);
        let dcfg = DistConfig { segments: 9, shard_rows: rows, ..Default::default() };
        let eng = DistributedEngine::connect(&eps, "d", &reg, dcfg).unwrap();
        let arms = [0usize, 1, 2, 3];
        let refs: Vec<usize> = (0..n).collect();
        let mut out = vec![0f64; arms.len()];
        eng.pull_block(&arms, &refs, &mut out); // warm: every conn live
        kill_worker(&eps[2]);
        let t = std::time::Instant::now();
        eng.pull_block(&arms, &refs, &mut out);
        let redispatch_ms = t.elapsed().as_secs_f64() * 1e3;
        assert!(eng.redispatches() >= 1, "killed worker was never re-dispatched");
        b.record_metric("dist/redispatch_ms", redispatch_ms, "ms");
        let _ = std::fs::remove_dir_all(&dir);
    }

    // ---- the million-point acceptance run (opt-in: slow + 0.5 GB disk) --
    if std::env::var("CORRSH_E2E_MILLION").map(|v| v == "1").unwrap_or(false) {
        b.group("e2e million (sharded, d=128)");
        let n: usize = std::env::var("CORRSH_E2E_MILLION_N")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(1_000_000);
        let dim = 128;
        let dir = std::env::temp_dir().join("corrsh-e2e-bench").join("million-shards");
        let _ = std::fs::remove_dir_all(&dir);
        let cfg = SynthConfig { n, dim, seed: 0, ..Default::default() };
        let t0 = std::time::Instant::now();
        // Streams shard-by-shard: the n×d matrix never materializes.
        let manifest = Kind::Gaussian.write_sharded(&cfg, &dir, 16_384).unwrap();
        let gen_s = t0.elapsed().as_secs_f64();
        let sd = ShardedData::open(&manifest).unwrap();
        let engine = corrsh::engine::NativeEngine::with_threads(
            Arc::new(Data::Sharded(sd)),
            corrsh::distance::Metric::L2,
            corrsh::util::threads::default_threads(),
        );
        let t1 = std::time::Instant::now();
        let res = CorrSh::with_pulls_per_arm(24.0).run(&engine, &mut Rng::seeded(0));
        let run_s = t1.elapsed().as_secs_f64();
        let rss = peak_rss_bytes();
        let gib = rss as f64 / (1u64 << 30) as f64;
        b.record_metric("e2e_million/n", n as f64, "points");
        b.record_metric("e2e_million/gen_write_s", gen_s, "s");
        b.record_metric("e2e_million/corrsh_wall_s", run_s, "s");
        b.record_metric(
            "e2e_million/pulls_per_arm",
            res.pulls as f64 / n as f64,
            "pulls/arm",
        );
        b.record_metric("e2e_million/peak_rss_gib", gib, "GiB");
        println!("e2e million: medoid={} pulls={} rss={gib:.3} GiB", res.best, res.pulls);
        // Distributed variant over the same manifest: four loopback
        // workers stream the shards themselves (register-by-path), one
        // full corrSH run, wall-clock only. Separately gated — each worker
        // re-prepares the million-row session, which is minutes of extra
        // wall on a shared runner.
        if std::env::var("CORRSH_E2E_MILLION_DIST").map(|v| v == "1").unwrap_or(false) {
            let reg = register_params(&manifest);
            let endpoints = spawn_workers(4);
            let dcfg = DistConfig { segments: 16, shard_rows: 16_384, ..Default::default() };
            let eng = DistributedEngine::connect(&endpoints, "d", &reg, dcfg).unwrap();
            let t2 = std::time::Instant::now();
            let dres = CorrSh::with_pulls_per_arm(24.0).run(&eng, &mut Rng::seeded(0));
            let dist_s = t2.elapsed().as_secs_f64();
            assert_eq!(dres.best, res.best, "distributed million run disagreed on the medoid");
            b.record_metric("e2e_million/dist_workers_4_wall_s", dist_s, "s");
            b.record_metric("e2e_million/dist_speedup", run_s / dist_s, "x vs single process");
        }
        let _ = std::fs::remove_dir_all(&dir);
        if rss > 0 {
            assert!(
                gib < 2.0,
                "million-point sharded run exceeded the 2 GiB acceptance envelope: {gib:.3} GiB"
            );
        }
    }

    b.write_jsonl();
    b.write_bench_json("e2e");
}
