//! E10 — end-to-end corrSH wall-clock bench (the §Perf headline): one full
//! Correlated Sequential Halving run per iteration on each dataset
//! geometry, native engine, default thread count — the number EXPERIMENTS.md
//! §Perf tracks before/after optimization.
//!
//! Two storage-layer additions (EXPERIMENTS.md §Perf #7):
//!
//! * `sharded vs resident` — the same corrSH run over the same bytes,
//!   resident vs served from a shard manifest (pinned reader), recorded as
//!   the `sharded_vs_resident` relative-throughput row. Winners are
//!   asserted identical (the backends are bitwise-parity tested).
//! * `e2e million` (env `CORRSH_E2E_MILLION=1`) — an n = 10⁶, d = 128
//!   corrSH medoid run *from a shard manifest*, streamed through the
//!   shard writer so the matrix never materializes; records wall seconds,
//!   pulls/arm and the process peak-RSS, and fails loudly if resident
//!   memory exceeded 2 GiB (the ISSUE's acceptance envelope).

use std::sync::Arc;

use corrsh::bandits::{CorrSh, MedoidAlgorithm};
use corrsh::config::RunConfig;
use corrsh::data::store::{ShardedData, StoreOptions};
use corrsh::data::synth::{Kind, SynthConfig};
use corrsh::data::Data;
use corrsh::experiments::runner;
use corrsh::util::bench::Bencher;
use corrsh::util::rng::Rng;

/// Peak resident set size of this process in bytes (linux VmHWM; 0 where
/// /proc is unavailable — the memory gate only runs on linux CI).
fn peak_rss_bytes() -> u64 {
    let Ok(status) = std::fs::read_to_string("/proc/self/status") else { return 0 };
    for line in status.lines() {
        if let Some(rest) = line.strip_prefix("VmHWM:") {
            let kb: u64 = rest
                .trim()
                .trim_end_matches("kB")
                .trim()
                .parse()
                .unwrap_or(0);
            return kb * 1024;
        }
    }
    0
}

fn main() {
    let scale: usize = std::env::var("CORRSH_BENCH_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(20);
    let mut b = Bencher::new();
    b.group(&format!("e2e corrSH (scale 1/{scale}, native engine)"));

    for preset in ["rnaseq20k", "netflix20k", "mnist"] {
        let cfg = RunConfig::preset(preset).unwrap().scaled_down(scale);
        let data = runner::build_data(&cfg);
        let n = data.n();
        let engine = corrsh::engine::NativeEngine::with_threads(
            data.clone(),
            cfg.metric,
            corrsh::util::threads::default_threads(),
        );
        let mut seed = 0u64;
        let mut pulls = 0u64;
        b.bench_items(&format!("{preset}/n={n}/corrsh@24ppa"), n as u64, || {
            let mut rng = Rng::seeded(seed);
            seed += 1;
            let res = CorrSh::with_pulls_per_arm(24.0).run(&engine, &mut rng);
            pulls = res.pulls;
            res.best
        });
        b.record_metric(&format!("{preset}/pulls_per_arm"), pulls as f64 / n as f64, "pulls/arm");
    }

    // ---- sharded vs resident: same bytes, two storage backends --------
    b.group("e2e sharded vs resident");
    {
        let cfg = RunConfig::preset("mnist").unwrap().scaled_down(scale);
        let data = runner::build_data(&cfg);
        let n = data.n();
        let dir = std::env::temp_dir().join("corrsh-e2e-bench").join("mnist-shards");
        let _ = std::fs::remove_dir_all(&dir);
        let manifest =
            corrsh::data::store::write_sharded(&data, &dir, (n / 8).max(1)).unwrap();
        // pinned reader (the portable worst case; mmap builds only get
        // faster than this)
        let sd = ShardedData::open_with(
            &manifest,
            &StoreOptions { force_pinned: true, ..Default::default() },
        )
        .unwrap();
        let threads = corrsh::util::threads::default_threads();
        let resident =
            corrsh::engine::NativeEngine::with_threads(data.clone(), cfg.metric, threads);
        let sharded = corrsh::engine::NativeEngine::with_threads(
            Arc::new(Data::Sharded(sd)),
            cfg.metric,
            threads,
        );
        let mut res_best = 0usize;
        b.bench_items(&format!("resident/n={n}/corrsh@24ppa"), n as u64, || {
            let mut rng = Rng::seeded(7);
            res_best = CorrSh::with_pulls_per_arm(24.0).run(&resident, &mut rng).best;
            res_best
        });
        let resident_s = b.last_mean_s().unwrap();
        let mut sh_best = 0usize;
        b.bench_items(&format!("sharded/n={n}/corrsh@24ppa"), n as u64, || {
            let mut rng = Rng::seeded(7);
            sh_best = CorrSh::with_pulls_per_arm(24.0).run(&sharded, &mut rng).best;
            sh_best
        });
        let sharded_s = b.last_mean_s().unwrap();
        assert_eq!(res_best, sh_best, "backends disagreed on the medoid");
        // >1 would mean sharding is free; the row tracks how close we get
        b.record_metric("sharded_vs_resident", resident_s / sharded_s, "x rel throughput");
    }

    // ---- the million-point acceptance run (opt-in: slow + 0.5 GB disk) --
    if std::env::var("CORRSH_E2E_MILLION").map(|v| v == "1").unwrap_or(false) {
        b.group("e2e million (sharded, d=128)");
        let n: usize = std::env::var("CORRSH_E2E_MILLION_N")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(1_000_000);
        let dim = 128;
        let dir = std::env::temp_dir().join("corrsh-e2e-bench").join("million-shards");
        let _ = std::fs::remove_dir_all(&dir);
        let cfg = SynthConfig { n, dim, seed: 0, ..Default::default() };
        let t0 = std::time::Instant::now();
        // Streams shard-by-shard: the n×d matrix never materializes.
        let manifest = Kind::Gaussian.write_sharded(&cfg, &dir, 16_384).unwrap();
        let gen_s = t0.elapsed().as_secs_f64();
        let sd = ShardedData::open(&manifest).unwrap();
        let engine = corrsh::engine::NativeEngine::with_threads(
            Arc::new(Data::Sharded(sd)),
            corrsh::distance::Metric::L2,
            corrsh::util::threads::default_threads(),
        );
        let t1 = std::time::Instant::now();
        let res = CorrSh::with_pulls_per_arm(24.0).run(&engine, &mut Rng::seeded(0));
        let run_s = t1.elapsed().as_secs_f64();
        let rss = peak_rss_bytes();
        let gib = rss as f64 / (1u64 << 30) as f64;
        b.record_metric("e2e_million/n", n as f64, "points");
        b.record_metric("e2e_million/gen_write_s", gen_s, "s");
        b.record_metric("e2e_million/corrsh_wall_s", run_s, "s");
        b.record_metric(
            "e2e_million/pulls_per_arm",
            res.pulls as f64 / n as f64,
            "pulls/arm",
        );
        b.record_metric("e2e_million/peak_rss_gib", gib, "GiB");
        println!("e2e million: medoid={} pulls={} rss={gib:.3} GiB", res.best, res.pulls);
        let _ = std::fs::remove_dir_all(&dir);
        if rss > 0 {
            assert!(
                gib < 2.0,
                "million-point sharded run exceeded the 2 GiB acceptance envelope: {gib:.3} GiB"
            );
        }
    }

    b.write_jsonl();
    b.write_bench_json("e2e");
}
