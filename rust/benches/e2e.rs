//! E10 — end-to-end corrSH wall-clock bench (the §Perf headline): one full
//! Correlated Sequential Halving run per iteration on each dataset
//! geometry, native engine, default thread count — the number EXPERIMENTS.md
//! §Perf tracks before/after optimization.

use corrsh::bandits::{CorrSh, MedoidAlgorithm};
use corrsh::config::RunConfig;
use corrsh::experiments::runner;
use corrsh::util::bench::Bencher;
use corrsh::util::rng::Rng;

fn main() {
    let scale: usize = std::env::var("CORRSH_BENCH_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(20);
    let mut b = Bencher::new();
    b.group(&format!("e2e corrSH (scale 1/{scale}, native engine)"));

    for preset in ["rnaseq20k", "netflix20k", "mnist"] {
        let cfg = RunConfig::preset(preset).unwrap().scaled_down(scale);
        let data = runner::build_data(&cfg);
        let n = data.n();
        let engine = corrsh::engine::NativeEngine::with_threads(
            data.clone(),
            cfg.metric,
            corrsh::util::threads::default_threads(),
        );
        let mut seed = 0u64;
        let mut pulls = 0u64;
        b.bench_items(&format!("{preset}/n={n}/corrsh@24ppa"), n as u64, || {
            let mut rng = Rng::seeded(seed);
            seed += 1;
            let res = CorrSh::with_pulls_per_arm(24.0).run(&engine, &mut rng);
            pulls = res.pulls;
            res.best
        });
        b.record_metric(&format!("{preset}/pulls_per_arm"), pulls as f64 / n as f64, "pulls/arm");
    }
    b.write_jsonl();
}
