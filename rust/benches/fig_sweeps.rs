//! E2/E6 — Figs 1 & 5 regeneration bench: error-probability-vs-budget
//! sweeps for corrSH / Med-dit / RAND on each figure's dataset. The bench
//! reports the error rate at each budget (the figure's y-axis series) plus
//! the wall time of one full sweep.

use corrsh::config::RunConfig;
use corrsh::experiments::figures;
use corrsh::util::bench::Bencher;

fn main() {
    let scale: usize = std::env::var("CORRSH_BENCH_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(50);
    let trials: usize = std::env::var("CORRSH_BENCH_TRIALS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(10);
    let budgets = [2.0, 8.0, 32.0];
    let mut b = Bencher::new();
    b.group(&format!("fig1+fig5 sweeps (scale 1/{scale}, {trials} trials)"));

    for (figure, preset) in [
        ("fig1", "rnaseq20k"),
        ("fig1", "netflix100k"),
        ("fig5", "netflix20k"),
        ("fig5", "rnaseq100k"),
        ("fig5", "mnist"),
    ] {
        let cfg = RunConfig::preset(preset).unwrap().scaled_down(scale);
        let mut pts = Vec::new();
        b.bench(&format!("{figure}/{preset}/sweep"), || {
            pts = figures::error_vs_budget(&cfg, &budgets, trials, 0).unwrap();
            pts.len()
        });
        for p in &pts {
            b.record_metric(
                &format!("{figure}/{preset}/{}@{:.0}ppa", p.algo, p.pulls_per_arm),
                p.error_rate,
                "err",
            );
        }
    }
    b.write_jsonl();
}
