//! E9 — pull-engine micro-benchmarks: native vs PJRT batched throughput,
//! bucket-size sweep, dense vs sparse distance kernels. This is the roofline
//! evidence for EXPERIMENTS.md §Perf.

use std::sync::Arc;

use corrsh::data::synth::{mnist, netflix, rnaseq, SynthConfig};
use corrsh::distance::Metric;
use corrsh::engine::{NativeEngine, PullEngine};
use corrsh::util::bench::Bencher;
use corrsh::util::rng::Rng;

fn main() {
    let mut b = Bencher::new();
    let mut rng = Rng::seeded(0);

    // ---- dense scalar kernels -------------------------------------------------
    b.group("distance kernels (d=784 dense)");
    let data = Arc::new(mnist::generate(&SynthConfig {
        n: 2_048,
        dim: 784,
        seed: 1,
        ..Default::default()
    }));
    for metric in [Metric::L1, Metric::L2, Metric::Cosine] {
        let e = NativeEngine::with_threads(data.clone(), metric, 1);
        let mut i = 0usize;
        b.bench_items(&format!("single_pull/{metric}"), 1, || {
            i = (i + 1) % 2_000;
            e.pull(i, (i * 7 + 13) % 2_000)
        });
    }

    // ---- sparse kernels ---------------------------------------------------------
    b.group("distance kernels (sparse CSR)");
    let sp = Arc::new(netflix::generate(&SynthConfig {
        n: 4_096,
        dim: 8_192,
        seed: 2,
        density: 0.002,
        ..Default::default()
    }));
    let e = NativeEngine::with_threads(sp.clone(), Metric::Cosine, 1);
    let mut i = 0usize;
    b.bench_items("single_pull/cosine_csr", 1, || {
        i = (i + 1) % 4_000;
        e.pull(i, (i * 11 + 5) % 4_000)
    });

    // ---- dense tiles: seed per-pair path vs tiled kernel layer ---------------
    // The acceptance geometry for the tile layer (DESIGN.md §11): MNIST-like
    // n=1000, d=784, L2. `pull_block_scalar`/`pull_matrix_scalar` are the
    // seed hot path kept as the reference; `pull_block`/`pull_matrix` route
    // through the packed-tile kernels. The derived `speedup/*` rows land in
    // BENCH_engine.json so CI tracks old-vs-new on every run.
    b.group("dense tiles (n=1000 arms x 256 refs, d=784)");
    let tile_data = Arc::new(mnist::generate(&SynthConfig {
        n: 1_000,
        dim: 784,
        seed: 7,
        ..Default::default()
    }));
    let tile_arms: Vec<usize> = (0..1_000).collect();
    let tile_refs: Vec<usize> = rng.sample_without_replacement(1_000, 256);
    let mut tile_out = vec![0f64; tile_arms.len()];
    let mut tile_mat = vec![0f32; tile_arms.len() * tile_refs.len()];
    let pairs = (tile_arms.len() * tile_refs.len()) as u64;
    for metric in [Metric::L1, Metric::L2, Metric::Cosine] {
        let e = NativeEngine::with_threads(
            tile_data.clone(),
            metric,
            corrsh::util::threads::default_threads(),
        );
        b.bench_items(&format!("block_per_pair/{metric}"), pairs, || {
            e.pull_block_scalar(&tile_arms, &tile_refs, &mut tile_out);
            tile_out[0]
        });
        let old = b.last_mean_s().unwrap();
        b.bench_items(&format!("block_tiled/{metric}"), pairs, || {
            e.pull_block(&tile_arms, &tile_refs, &mut tile_out);
            tile_out[0]
        });
        let new = b.last_mean_s().unwrap();
        b.record_metric(&format!("speedup/block_{metric}"), old / new.max(1e-12), "x");
        if metric == Metric::L2 {
            b.bench_items(&format!("matrix_per_pair/{metric}"), pairs, || {
                e.pull_matrix_scalar(&tile_arms, &tile_refs, &mut tile_mat);
                tile_mat[0]
            });
            let old_m = b.last_mean_s().unwrap();
            b.bench_items(&format!("matrix_tiled/{metric}"), pairs, || {
                e.pull_matrix(&tile_arms, &tile_refs, &mut tile_mat);
                tile_mat[0]
            });
            let new_m = b.last_mean_s().unwrap();
            b.record_metric(&format!("speedup/matrix_{metric}"), old_m / new_m.max(1e-12), "x");
        }
    }

    // ---- native batched block throughput (the corrSH round shape) -------------
    b.group("pull_block (native, 1024 arms x 256 refs, d=784)");
    let arms: Vec<usize> = (0..1024).collect();
    let refs: Vec<usize> = rng.sample_without_replacement(2_048, 256);
    let mut out = vec![0f64; arms.len()];
    for threads in [1, corrsh::util::threads::default_threads()] {
        let e = NativeEngine::with_threads(data.clone(), Metric::L2, threads);
        b.bench_items(&format!("l2/threads={threads}"), (arms.len() * refs.len()) as u64, || {
            e.pull_block(&arms, &refs, &mut out);
            out[0]
        });
    }

    // ---- rnaseq sparse block (the real Table-1 row shape) ----------------------
    b.group("pull_block (native CSR l1, 1024x256, d=2048)");
    let rs = Arc::new(rnaseq::generate(&SynthConfig {
        n: 2_048,
        dim: 2_048,
        seed: 3,
        ..Default::default()
    }));
    for threads in [1, corrsh::util::threads::default_threads()] {
        let e = NativeEngine::with_threads(rs.clone(), Metric::L1, threads);
        b.bench_items(&format!("l1_csr/threads={threads}"), (arms.len() * refs.len()) as u64, || {
            e.pull_block(&arms, &refs, &mut out);
            out[0]
        });
    }

    // ---- PJRT path --------------------------------------------------------------
    #[cfg(feature = "pjrt")]
    {
        use corrsh::engine::PjrtEngine;
        use corrsh::runtime::Runtime;
        match Runtime::open("artifacts") {
            Err(e) => println!("(pjrt benches skipped: {e:#})"),
            Ok(rt) => {
                let rt = Arc::new(rt);
                b.group("pull_block (pjrt AOT artifacts, d=784)");
                for metric in [Metric::L1, Metric::L2, Metric::Cosine] {
                    let e = PjrtEngine::new(data.clone(), metric, rt.clone()).unwrap();
                    e.warmup().unwrap();
                    b.bench_items(
                        &format!("{metric}/1024x256"),
                        (arms.len() * refs.len()) as u64,
                        || {
                            e.pull_block(&arms, &refs, &mut out);
                            out[0]
                        },
                    );
                }
                // bucket-size sweep: how much does padding waste at small rounds?
                b.group("pjrt bucket sweep (l2, d=784)");
                let e = PjrtEngine::new(data.clone(), Metric::L2, rt.clone()).unwrap();
                for (na, nr) in [(64, 16), (256, 64), (1024, 256), (100, 37)] {
                    let a: Vec<usize> = (0..na).collect();
                    let r: Vec<usize> = (0..nr).collect();
                    let mut o = vec![0f64; na];
                    b.bench_items(&format!("{na}x{nr}"), (na * nr) as u64, || {
                        e.pull_block(&a, &r, &mut o);
                        o[0]
                    });
                }
            }
        }
    }
    #[cfg(not(feature = "pjrt"))]
    println!("(pjrt benches skipped: built without the `pjrt` feature)");

    b.write_jsonl();
    // Machine-readable perf baseline for trajectory tracking across PRs.
    b.write_bench_json("engine");
}
