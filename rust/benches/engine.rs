//! E9 — pull-engine micro-benchmarks: native vs PJRT batched throughput,
//! bucket-size sweep, dense vs sparse distance kernels. This is the roofline
//! evidence for EXPERIMENTS.md §Perf.

use std::sync::Arc;

use corrsh::data::synth::{mnist, netflix, rnaseq, SynthConfig};
use corrsh::data::Data;
use corrsh::distance::{dense, Metric};
use corrsh::engine::kernel::DenseTileCtx;
use corrsh::engine::simd::{self, Variant};
use corrsh::engine::{NativeEngine, PullEngine};
use corrsh::util::bench::Bencher;
use corrsh::util::rng::Rng;

fn main() {
    let mut b = Bencher::new();
    let mut rng = Rng::seeded(0);

    // ---- dense scalar kernels -------------------------------------------------
    b.group("distance kernels (d=784 dense)");
    let data = Arc::new(mnist::generate(&SynthConfig {
        n: 2_048,
        dim: 784,
        seed: 1,
        ..Default::default()
    }));
    for metric in [Metric::L1, Metric::L2, Metric::Cosine] {
        let e = NativeEngine::with_threads(data.clone(), metric, 1);
        let mut i = 0usize;
        b.bench_items(&format!("single_pull/{metric}"), 1, || {
            i = (i + 1) % 2_000;
            e.pull(i, (i * 7 + 13) % 2_000)
        });
    }

    // ---- sparse kernels ---------------------------------------------------------
    b.group("distance kernels (sparse CSR)");
    let sp = Arc::new(netflix::generate(&SynthConfig {
        n: 4_096,
        dim: 8_192,
        seed: 2,
        density: 0.002,
        ..Default::default()
    }));
    let e = NativeEngine::with_threads(sp.clone(), Metric::Cosine, 1);
    let mut i = 0usize;
    b.bench_items("single_pull/cosine_csr", 1, || {
        i = (i + 1) % 4_000;
        e.pull(i, (i * 11 + 5) % 4_000)
    });

    // ---- dense tiles: seed per-pair path vs tiled kernel layer ---------------
    // The acceptance geometry for the tile layer (DESIGN.md §11): MNIST-like
    // n=1000, d=784, L2. `pull_block_scalar`/`pull_matrix_scalar` are the
    // seed hot path kept as the reference; `pull_block`/`pull_matrix` route
    // through the packed-tile kernels. The derived `speedup/*` rows land in
    // BENCH_engine.json so CI tracks old-vs-new on every run.
    b.group("dense tiles (n=1000 arms x 256 refs, d=784)");
    let tile_data = Arc::new(mnist::generate(&SynthConfig {
        n: 1_000,
        dim: 784,
        seed: 7,
        ..Default::default()
    }));
    let tile_arms: Vec<usize> = (0..1_000).collect();
    let tile_refs: Vec<usize> = rng.sample_without_replacement(1_000, 256);
    let mut tile_out = vec![0f64; tile_arms.len()];
    let mut tile_mat = vec![0f32; tile_arms.len() * tile_refs.len()];
    let pairs = (tile_arms.len() * tile_refs.len()) as u64;
    for metric in [Metric::L1, Metric::L2, Metric::Cosine] {
        let e = NativeEngine::with_threads(
            tile_data.clone(),
            metric,
            corrsh::util::threads::default_threads(),
        );
        b.bench_items(&format!("block_per_pair/{metric}"), pairs, || {
            e.pull_block_scalar(&tile_arms, &tile_refs, &mut tile_out);
            tile_out[0]
        });
        let old = b.last_mean_s().unwrap();
        b.bench_items(&format!("block_tiled/{metric}"), pairs, || {
            e.pull_block(&tile_arms, &tile_refs, &mut tile_out);
            tile_out[0]
        });
        let new = b.last_mean_s().unwrap();
        b.record_metric(&format!("speedup/block_{metric}"), old / new.max(1e-12), "x");
        if metric == Metric::L2 {
            b.bench_items(&format!("matrix_per_pair/{metric}"), pairs, || {
                e.pull_matrix_scalar(&tile_arms, &tile_refs, &mut tile_mat);
                tile_mat[0]
            });
            let old_m = b.last_mean_s().unwrap();
            b.bench_items(&format!("matrix_tiled/{metric}"), pairs, || {
                e.pull_matrix(&tile_arms, &tile_refs, &mut tile_mat);
                tile_mat[0]
            });
            let new_m = b.last_mean_s().unwrap();
            b.record_metric(&format!("speedup/matrix_{metric}"), old_m / new_m.max(1e-12), "x");
        }
    }

    // ---- simd micro-kernels: scalar reference vs dispatched vector path ------
    // Same geometry as the dense-tiles group, but pinned at the tile-session
    // layer (`DenseTileCtx::with_variant`) so both sides run the identical
    // packing/threading path and the delta is the micro-kernel alone. The
    // group name is exactly "simd" so the row names CI greps
    // (`simd/speedup_block_*`) come out of the group-prefix join.
    b.group("simd");
    let active = simd::active();
    b.record_metric("variant_code", active.code() as f64, active.name());
    {
        let d = match &*tile_data {
            Data::Dense(d) => d,
            _ => unreachable!("mnist is dense"),
        };
        let norms: Vec<f32> = (0..d.n).map(|i| dense::norm(d.row(i))).collect();
        let sq: Vec<f64> = (0..d.n).map(|i| dense::sqnorm_f64(d.row(i))).collect();
        let threads = corrsh::util::threads::default_threads();
        for metric in [Metric::L1, Metric::L2, Metric::Cosine] {
            let scalar_ctx = DenseTileCtx::new(d, metric, Some(&norms[..]), Some(&sq[..]))
                .with_variant(Variant::Scalar);
            let simd_ctx = DenseTileCtx::new(d, metric, Some(&norms[..]), Some(&sq[..]))
                .with_variant(active);
            b.bench_items(&format!("block_scalar_{metric}"), pairs, || {
                scalar_ctx.block_sums(&tile_arms, &tile_refs, threads, &mut tile_out);
                tile_out[0]
            });
            let old = b.last_mean_s().unwrap();
            b.bench_items(&format!("block_simd_{metric}"), pairs, || {
                simd_ctx.block_sums(&tile_arms, &tile_refs, threads, &mut tile_out);
                tile_out[0]
            });
            let new = b.last_mean_s().unwrap();
            b.record_metric(&format!("speedup_block_{metric}"), old / new.max(1e-12), "x");
        }
    }

    // ---- pgo pipeline rows (bench/run_pgo.sh) --------------------------------
    // `pgo/active` is always present (1.0 only under the -Cprofile-use
    // rebuild, which exports CORRSH_PGO=1); the speedup rows compare this
    // run's simd/block_simd_* means against the baseline BENCH_engine.json
    // the pipeline saved before instrumenting.
    b.group("pgo");
    let pgo_active = std::env::var("CORRSH_PGO").map(|v| v == "1").unwrap_or(false);
    b.record_metric("active", if pgo_active { 1.0 } else { 0.0 }, "flag");
    if let Ok(path) = std::env::var("CORRSH_PGO_BASELINE") {
        let doc = std::fs::read_to_string(&path)
            .map_err(|e| e.to_string())
            .and_then(|t| corrsh::util::json::parse(&t).map_err(|e| format!("{e:#}")));
        match doc {
            Ok(doc) => {
                let results = doc.get("results").as_array().unwrap_or(&[]);
                for metric in [Metric::L1, Metric::L2, Metric::Cosine] {
                    let row = format!("block_simd_{metric}");
                    let base = results
                        .iter()
                        .find(|r| {
                            r.get("name").as_str().map(|n| n.ends_with(&row)).unwrap_or(false)
                        })
                        .and_then(|r| r.get("mean_s").as_f64());
                    match (base, b.mean_s_of(&row)) {
                        (Some(base), Some(cur)) => {
                            b.record_metric(
                                &format!("speedup_block_{metric}"),
                                base / cur.max(1e-12),
                                "x",
                            );
                        }
                        _ => eprintln!("warn: pgo baseline row {row} missing in {path}"),
                    }
                }
            }
            Err(e) => eprintln!("warn: CORRSH_PGO_BASELINE {path} unreadable: {e}"),
        }
    }

    // ---- native batched block throughput (the corrSH round shape) -------------
    b.group("pull_block (native, 1024 arms x 256 refs, d=784)");
    let arms: Vec<usize> = (0..1024).collect();
    let refs: Vec<usize> = rng.sample_without_replacement(2_048, 256);
    let mut out = vec![0f64; arms.len()];
    for threads in [1, corrsh::util::threads::default_threads()] {
        let e = NativeEngine::with_threads(data.clone(), Metric::L2, threads);
        b.bench_items(&format!("l2/threads={threads}"), (arms.len() * refs.len()) as u64, || {
            e.pull_block(&arms, &refs, &mut out);
            out[0]
        });
    }

    // ---- rnaseq sparse block (the real Table-1 row shape) ----------------------
    b.group("pull_block (native CSR l1, 1024x256, d=2048)");
    let rs = Arc::new(rnaseq::generate(&SynthConfig {
        n: 2_048,
        dim: 2_048,
        seed: 3,
        ..Default::default()
    }));
    for threads in [1, corrsh::util::threads::default_threads()] {
        let e = NativeEngine::with_threads(rs.clone(), Metric::L1, threads);
        b.bench_items(&format!("l1_csr/threads={threads}"), (arms.len() * refs.len()) as u64, || {
            e.pull_block(&arms, &refs, &mut out);
            out[0]
        });
    }

    // ---- PJRT path --------------------------------------------------------------
    #[cfg(feature = "pjrt")]
    {
        use corrsh::engine::PjrtEngine;
        use corrsh::runtime::Runtime;
        match Runtime::open("artifacts") {
            Err(e) => println!("(pjrt benches skipped: {e:#})"),
            Ok(rt) => {
                let rt = Arc::new(rt);
                b.group("pull_block (pjrt AOT artifacts, d=784)");
                for metric in [Metric::L1, Metric::L2, Metric::Cosine] {
                    let e = PjrtEngine::new(data.clone(), metric, rt.clone()).unwrap();
                    e.warmup().unwrap();
                    b.bench_items(
                        &format!("{metric}/1024x256"),
                        (arms.len() * refs.len()) as u64,
                        || {
                            e.pull_block(&arms, &refs, &mut out);
                            out[0]
                        },
                    );
                }
                // bucket-size sweep: how much does padding waste at small rounds?
                b.group("pjrt bucket sweep (l2, d=784)");
                let e = PjrtEngine::new(data.clone(), Metric::L2, rt.clone()).unwrap();
                for (na, nr) in [(64, 16), (256, 64), (1024, 256), (100, 37)] {
                    let a: Vec<usize> = (0..na).collect();
                    let r: Vec<usize> = (0..nr).collect();
                    let mut o = vec![0f64; na];
                    b.bench_items(&format!("{na}x{nr}"), (na * nr) as u64, || {
                        e.pull_block(&a, &r, &mut o);
                        o[0]
                    });
                }
            }
        }
    }
    #[cfg(not(feature = "pjrt"))]
    println!("(pjrt benches skipped: built without the `pjrt` feature)");

    // ---- medoid algorithm head-to-head ------------------------------------------
    // Wall-clock view of the three tiers over one engine (the pull-count
    // view lives in BENCH_kmedoids.json): corrSH's sublinear schedule,
    // trimed's triangle-inequality elimination, the exact n² sweep.
    {
        use corrsh::bandits::{CorrSh, Exact, MedoidAlgorithm, Trimed};
        use corrsh::data::synth::gaussian;

        b.group("medoid head-to-head (mixture n=2048, d=32)");
        let mix = Arc::new(gaussian::generate_mixture(&SynthConfig {
            n: 2_048,
            dim: 32,
            seed: 5,
            clusters: 4,
            ..Default::default()
        }));
        let e = NativeEngine::with_threads(mix, Metric::L2, 4);
        let algos: [(&str, Box<dyn MedoidAlgorithm>); 3] = [
            ("corrsh", Box::new(CorrSh::with_pulls_per_arm(24.0))),
            ("trimed", Box::new(Trimed::new(8))),
            ("exact", Box::new(Exact::new())),
        ];
        for (name, algo) in algos {
            let mut pulls = 0u64;
            b.bench_items(&format!("medoid/{name}"), 2_048, || {
                let res = algo.run(&e, &mut Rng::seeded(9));
                pulls = res.pulls;
                res.best
            });
            b.record_metric(&format!("medoid/{name}_pulls"), pulls as f64, "pulls");
        }
    }

    b.write_jsonl();
    // Machine-readable perf baseline for trajectory tracking across PRs.
    b.write_bench_json("engine");
}
