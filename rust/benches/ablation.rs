//! E8 — ablation bench: correlated vs uncorrelated Sequential Halving at
//! identical budgets (the isolated value of the paper's correlation trick),
//! plus Fig 2/3/4 statistics (the analysis artifacts).

use corrsh::config::RunConfig;
use corrsh::experiments::figures;
use corrsh::util::bench::Bencher;

fn main() {
    let scale: usize = std::env::var("CORRSH_BENCH_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(50);
    let trials: usize = std::env::var("CORRSH_BENCH_TRIALS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(10);
    let mut b = Bencher::new();
    b.group(&format!("ablation + analysis (scale 1/{scale})"));

    // corrSH vs uncorrelated SH
    let cfg = RunConfig::preset("rnaseq20k").unwrap().scaled_down(scale);
    let budgets = [2.0, 8.0, 32.0];
    let mut pts = Vec::new();
    b.bench("corr_vs_uncorr/sweep", || {
        pts = figures::ablation_corr_vs_uncorr(&cfg, &budgets, trials, 0).unwrap();
        pts.len()
    });
    for p in &pts {
        b.record_metric(
            &format!("corr_vs_uncorr/{}@{:.0}ppa", p.algo, p.pulls_per_arm),
            p.error_rate,
            "err",
        );
    }

    // fig2 toy
    let demo = figures::fig2_toy_demo(20_000, 0);
    b.record_metric("fig2/p_flip_independent", demo.p_flip_independent, "prob");
    b.record_metric("fig2/p_flip_correlated", demo.p_flip_correlated, "prob");

    // fig3 histograms
    let rows = figures::fig3_difference_histograms(&cfg, 10_000, 0).unwrap();
    for r in &rows {
        b.record_metric(&format!("fig3/{}/rho", r.arm_kind), r.rho, "rho");
        b.record_metric(
            &format!("fig3/{}/p_neg_ind", r.arm_kind),
            r.p_neg_independent,
            "prob",
        );
        b.record_metric(
            &format!("fig3/{}/p_neg_corr", r.arm_kind),
            r.p_neg_correlated,
            "prob",
        );
    }

    // fig4 hardness
    for preset in ["rnaseq20k", "mnist"] {
        let cfg = RunConfig::preset(preset).unwrap().scaled_down(scale);
        let out = figures::fig4_delta_vs_rho(&cfg, 0).unwrap();
        b.record_metric(&format!("fig4/{preset}/gain_H2_over_H2tilde"), out.gain_ratio, "x");
    }

    // fig6 distance-to-medoid histograms (count only; csv is the artifact)
    for preset in ["rnaseq20k", "mnist"] {
        let cfg = RunConfig::preset(preset).unwrap().scaled_down(scale);
        let h = figures::fig6_distance_to_medoid(&cfg, 0).unwrap();
        b.record_metric(&format!("fig6/{preset}/points"), h.count as f64, "pts");
    }
    b.write_jsonl();
}
