//! E12 — k-medoids workload bench: BUILD-only vs full BUILD/SWAP/polish on
//! a planted Gaussian mixture, plus the pull-budget fraction vs the exact
//! k·n² BUILD sweep. Emits `BENCH_kmedoids.json` (schema_version 1) as a CI
//! perf artifact next to `BENCH_engine.json` / `BENCH_server.json`.

use std::sync::Arc;

use corrsh::config::KMedoidsConfig;
use corrsh::data::synth::{gaussian, SynthConfig};
use corrsh::distance::Metric;
use corrsh::engine::NativeEngine;
use corrsh::kmedoids::{BanditKMedoids, ClusteringAlgorithm};
use corrsh::util::bench::Bencher;
use corrsh::util::rng::Rng;

fn main() {
    let n: usize = std::env::var("CORRSH_BENCH_KMEDOIDS_N")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1_000);
    let k = 5usize;
    let data = Arc::new(gaussian::generate_mixture(&SynthConfig {
        n,
        dim: 16,
        seed: 1,
        clusters: k,
        ..Default::default()
    }));
    let engine = NativeEngine::with_threads(
        data,
        Metric::L2,
        corrsh::util::threads::default_threads(),
    );

    let mut b = Bencher::new();
    b.group(&format!("kmedoids (mixture n={n}, k={k}, d=16)"));

    let build_only = KMedoidsConfig {
        k,
        max_swap_rounds: 0,
        polish_pulls_per_arm: 0.0,
        ..Default::default()
    };
    let mut seed = 0u64;
    b.bench_items("build-only", n as u64, || {
        seed += 1;
        let res = BanditKMedoids::new(build_only.clone()).run(&engine, &mut Rng::seeded(seed));
        res.medoids.len()
    });

    let full = KMedoidsConfig { k, ..Default::default() };
    b.bench_items("build+swap+polish", n as u64, || {
        seed += 1;
        let res = BanditKMedoids::new(full.clone()).run(&engine, &mut Rng::seeded(seed));
        res.medoids.len()
    });

    // Pull economics of one representative full run: fraction of the exact
    // k·n² BUILD sweep, and planted-center recovery.
    let res = BanditKMedoids::new(full).run(&engine, &mut Rng::seeded(7));
    let exact_cost = (k * n * n) as f64;
    b.record_metric("pulls/total", res.pulls() as f64, "pulls");
    b.record_metric(
        "pulls/fraction_of_exact_build",
        res.pulls() as f64 / exact_cost,
        "fraction",
    );
    b.record_metric(
        "quality/planted_centers_recovered",
        res.medoids.iter().filter(|&&m| m < k).count() as f64,
        "centers",
    );
    b.record_metric("quality/mean_loss", res.loss, "distance");

    b.write_jsonl();
    b.write_bench_json("kmedoids");
}
