//! E12 — k-medoids workload bench: BUILD-only vs full BUILD/SWAP/polish on
//! a planted Gaussian mixture, plus the pull-budget fraction vs the exact
//! k·n² BUILD sweep, the cross-round reuse-cache pull comparison (on vs off
//! at identical seeds and, by construction, identical results), and the
//! corrsh/trimed/exact single-medoid head-to-head. Emits
//! `BENCH_kmedoids.json` (schema_version 1) as a CI perf artifact next to
//! `BENCH_engine.json` / `BENCH_server.json`.

use std::sync::Arc;

use corrsh::bandits::{CorrSh, Exact, MedoidAlgorithm, Trimed};
use corrsh::config::KMedoidsConfig;
use corrsh::data::synth::{gaussian, SynthConfig};
use corrsh::distance::Metric;
use corrsh::engine::{CountingEngine, NativeEngine};
use corrsh::kmedoids::{BanditKMedoids, ClusteringAlgorithm};
use corrsh::util::bench::Bencher;
use corrsh::util::rng::Rng;

fn main() {
    let n: usize = std::env::var("CORRSH_BENCH_KMEDOIDS_N")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1_000);
    let k = 5usize;
    let data = Arc::new(gaussian::generate_mixture(&SynthConfig {
        n,
        dim: 16,
        seed: 1,
        clusters: k,
        ..Default::default()
    }));
    let engine = NativeEngine::with_threads(
        data,
        Metric::L2,
        corrsh::util::threads::default_threads(),
    );

    let mut b = Bencher::new();
    b.group(&format!("kmedoids (mixture n={n}, k={k}, d=16)"));

    let build_only = KMedoidsConfig {
        k,
        max_swap_rounds: 0,
        polish_pulls_per_arm: 0.0,
        ..Default::default()
    };
    let mut seed = 0u64;
    b.bench_items("build-only", n as u64, || {
        seed += 1;
        let res = BanditKMedoids::new(build_only.clone()).run(&engine, &mut Rng::seeded(seed));
        res.medoids.len()
    });

    let full = KMedoidsConfig { k, ..Default::default() };
    b.bench_items("build+swap+polish", n as u64, || {
        seed += 1;
        let res = BanditKMedoids::new(full.clone()).run(&engine, &mut Rng::seeded(seed));
        res.medoids.len()
    });

    // Pull economics of one representative full run: fraction of the exact
    // k·n² BUILD sweep, and planted-center recovery.
    let res = BanditKMedoids::new(full).run(&engine, &mut Rng::seeded(7));
    let exact_cost = (k * n * n) as f64;
    b.record_metric("pulls/total", res.pulls() as f64, "pulls");
    b.record_metric(
        "pulls/fraction_of_exact_build",
        res.pulls() as f64 / exact_cost,
        "fraction",
    );
    b.record_metric(
        "quality/planted_centers_recovered",
        res.medoids.iter().filter(|&&m| m < k).count() as f64,
        "centers",
    );
    b.record_metric("quality/mean_loss", res.loss, "distance");

    // Cross-round pull reuse (DESIGN.md §17): the same clustering runs with
    // the reuse cache on and off at equal seeds. The cache is result-neutral
    // (bitwise-identical medoids and loss — asserted here, pinned by the
    // property suite), so the only thing that moves is the engine-boundary
    // pull count; `reuse/speedup_pulls` is the off/on ratio CI greps for.
    {
        let counting = CountingEngine::new(NativeEngine::with_threads(
            Arc::new(gaussian::generate_mixture(&SynthConfig {
                n,
                dim: 16,
                seed: 1,
                clusters: k,
                ..Default::default()
            })),
            Metric::L2,
            corrsh::util::threads::default_threads(),
        ));
        // More SWAP rounds than the default so consecutive rounds re-score
        // overlapping candidate sets — the regime the cache exists for.
        let mut run = |reuse: bool| {
            let cfg = KMedoidsConfig {
                k,
                max_swap_rounds: 8,
                reuse_cache: reuse,
                ..Default::default()
            };
            counting.reset();
            let res = BanditKMedoids::new(cfg).run(&counting, &mut Rng::seeded(11));
            (res, counting.pulls())
        };
        let (res_on, pulls_on) = run(true);
        let (res_off, pulls_off) = run(false);
        assert_eq!(res_on.medoids, res_off.medoids, "reuse cache changed the medoids");
        assert_eq!(
            res_on.loss.to_bits(),
            res_off.loss.to_bits(),
            "reuse cache changed the loss"
        );
        b.record_metric("reuse/pulls_on", pulls_on as f64, "pulls");
        b.record_metric("reuse/pulls_off", pulls_off as f64, "pulls");
        b.record_metric(
            "reuse/speedup_pulls",
            pulls_off as f64 / pulls_on.max(1) as f64,
            "ratio",
        );
        b.record_metric(
            "reuse/swap_pulls_saved_frac",
            1.0 - res_on.swap_pulls as f64 / res_off.swap_pulls.max(1) as f64,
            "fraction",
        );
    }

    // Single-medoid head-to-head on the same mixture: corrSH (sublinear
    // bandit), trimed (exact via triangle-inequality elimination), and the
    // exact n² sweep. `trimed/matches_exact` must be 1 and its pull count
    // sub-n² on clustered data; corrSH stays the cheapest.
    {
        let counting = CountingEngine::new(NativeEngine::with_threads(
            Arc::new(gaussian::generate_mixture(&SynthConfig {
                n,
                dim: 16,
                seed: 2,
                clusters: k,
                ..Default::default()
            })),
            Metric::L2,
            corrsh::util::threads::default_threads(),
        ));
        let n2 = (n * n) as f64;
        let mut best = [0usize; 3];
        let algos: [(&str, Box<dyn MedoidAlgorithm>); 3] = [
            ("corrsh", Box::new(CorrSh::with_pulls_per_arm(24.0))),
            ("trimed", Box::new(Trimed::new(8))),
            ("exact", Box::new(Exact::new())),
        ];
        for (i, (name, algo)) in algos.into_iter().enumerate() {
            counting.reset();
            let t0 = std::time::Instant::now();
            let res = algo.run(&counting, &mut Rng::seeded(3));
            let wall = t0.elapsed().as_secs_f64();
            best[i] = res.best;
            b.record_metric(&format!("{name}/pulls"), res.pulls as f64, "pulls");
            let frac = res.pulls as f64 / n2;
            b.record_metric(&format!("{name}/pulls_fraction_of_n2"), frac, "fraction");
            b.record_metric(&format!("{name}/wall_s"), wall, "s");
        }
        b.record_metric(
            "trimed/matches_exact",
            (best[1] == best[2]) as u64 as f64,
            "bool",
        );
        b.record_metric(
            "corrsh/matches_exact",
            (best[0] == best[2]) as u64 as f64,
            "bool",
        );
    }

    b.write_jsonl();
    b.write_bench_json("kmedoids");
}
