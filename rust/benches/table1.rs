//! E1 — Table 1 regeneration bench: times every algorithm on every (scaled)
//! dataset row and records pulls/arm + error, the two quantities the paper
//! tabulates. `CORRSH_BENCH_SCALE` (default 50) divides each preset's n.

use corrsh::config::RunConfig;
use corrsh::experiments::{runner, table1};
use corrsh::util::bench::Bencher;

fn main() {
    let scale: usize = std::env::var("CORRSH_BENCH_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(50);
    let mut b = Bencher::new();
    b.group(&format!("table1 (scale 1/{scale})"));

    for preset in ["rnaseq20k", "netflix20k", "mnist"] {
        let cfg = RunConfig::preset(preset).unwrap().scaled_down(scale);
        let data = runner::build_data(&cfg);
        let n = data.n();
        let truth = runner::ground_truth(&data, cfg.metric, 20_000);

        for (label, algo) in [
            ("corrsh", corrsh::config::AlgoConfig::CorrSh { pulls_per_arm: 24.0 }),
            ("meddit", corrsh::config::AlgoConfig::Meddit { delta: 0.0, cap: 0 }),
            ("rand1000", corrsh::config::AlgoConfig::Rand { refs_per_arm: 1000 }),
            ("exact", corrsh::config::AlgoConfig::Exact),
        ] {
            let engine = corrsh::engine::NativeEngine::with_threads(
                data.clone(),
                cfg.metric,
                corrsh::util::threads::default_threads(),
            );
            let mut seed = 0u64;
            let mut last_pulls = 0u64;
            let mut errs = 0usize;
            let mut runs = 0usize;
            b.bench(&format!("{preset}/{label}"), || {
                let mut rng = corrsh::util::rng::Rng::seeded(seed);
                seed += 1;
                let res = algo.build(n).run(&engine, &mut rng);
                last_pulls = res.pulls;
                errs += (res.best != truth) as usize;
                runs += 1;
                res.best
            });
            b.record_metric(
                &format!("{preset}/{label}/pulls_per_arm"),
                last_pulls as f64 / n as f64,
                "pulls/arm",
            );
            b.record_metric(
                &format!("{preset}/{label}/error_rate"),
                errs as f64 / runs.max(1) as f64,
                "frac",
            );
        }
    }
    b.write_jsonl();
}
