//! Cross-round pull-reuse cache — the BanditPAM++ idea (arXiv 2310.18844)
//! applied at the `correlated_halving_argmin` seam.
//!
//! BUILD steps and SWAP rounds repeatedly score overlapping candidate sets
//! against fresh reference draws, and every winner additionally pays a full
//! n-pull verification row that the next round used to re-pull from
//! scratch. This cache sits between the k-medoids scorers and
//! [`PullEngine::pull_matrix`], keyed by `(arm-row, reference)`: each
//! round's deduplicated candidate rows and each winner's verification row
//! are retained for the rest of the run, so later rounds only pull
//! references they have never seen.
//!
//! Correctness rests on the crate's bitwise-determinism invariant
//! (DESIGN.md §14): a per-pair distance is independent of the batch shape
//! it was computed in, so serving a cached value is bitwise identical to
//! re-pulling it and the halving winners — and therefore the clustering
//! result — are unchanged by the cache. The property test in
//! `rust/tests/reuse_trimed.rs` pins this: equal seeds ⇒ identical
//! medoids/loss/trajectory with strictly fewer engine-boundary pulls.
//!
//! Pull accounting stays honest through the `_reported` hook of
//! [`crate::bandits::corr_sh::correlated_halving_argmin_reported`]: both
//! fill methods return the number of *fresh* engine pulls they executed,
//! which the scorers report per block, so `KMedoidsResult` phase counters
//! keep matching an external [`crate::engine::CountingEngine`] exactly.
//!
//! Memory is bounded: rows are cached slot-major (n values + n presence
//! flags per slot) up to a ~256 MiB budget; once the slot table is full,
//! additional rows bypass the cache and hit the engine directly, so a huge
//! dataset degrades to the uncached behavior instead of OOMing.

use std::collections::HashMap;

use crate::engine::PullEngine;

/// Soft cap on cached-row storage (values + presence flags).
const CACHE_BYTES: usize = 256 << 20;

/// Per-run reuse cache over full distance rows. `enabled = false` turns
/// every fill into a direct engine call through the same entry points, so
/// cache-on and cache-off runs differ only in which pulls reach the engine.
pub struct PullCache {
    n: usize,
    enabled: bool,
    max_slots: usize,
    /// dataset row → slot (insertion-ordered, deterministic).
    slots: HashMap<usize, usize>,
    /// Slot-major cached values: `vals[slot * n + j] = d(row, x_j)`.
    vals: Vec<f32>,
    /// Slot-major presence flags for `vals`.
    have: Vec<bool>,
    /// The full reference universe `0..n` (kept so `fill_row` never
    /// re-allocates it).
    all: Vec<usize>,
    hits: u64,
    fresh: u64,
    scratch: Vec<f32>,
    missing: Vec<usize>,
}

impl PullCache {
    pub fn new(n: usize, enabled: bool) -> Self {
        let max_slots = if n == 0 { 0 } else { (CACHE_BYTES / (5 * n)).clamp(1, n) };
        PullCache {
            n,
            enabled,
            max_slots,
            slots: HashMap::new(),
            vals: Vec::new(),
            have: Vec::new(),
            all: (0..n).collect(),
            hits: 0,
            fresh: 0,
            scratch: Vec::new(),
            missing: Vec::new(),
        }
    }

    /// (arm, ref) pairs served from the cache so far.
    pub fn hits(&self) -> u64 {
        self.hits
    }

    /// Fresh engine pulls executed through the cache so far.
    pub fn fresh(&self) -> u64 {
        self.fresh
    }

    pub fn enabled(&self) -> bool {
        self.enabled
    }

    /// Slot for `x`, allocating one if the table has room. `None` ⇒ the row
    /// bypasses the cache (table full).
    fn slot_for(&mut self, x: usize) -> Option<usize> {
        if let Some(&s) = self.slots.get(&x) {
            return Some(s);
        }
        if self.slots.len() >= self.max_slots {
            return None;
        }
        let s = self.slots.len();
        self.slots.insert(x, s);
        self.vals.resize((s + 1) * self.n, 0.0);
        self.have.resize((s + 1) * self.n, false);
        Some(s)
    }

    /// Fill `out[k * refs.len() + j] = d(xs[k], refs[j])`, pulling only the
    /// pairs the cache has never seen. Returns the fresh engine pulls
    /// executed (what the block should report to the budget ledger).
    ///
    /// Rows with no cached entry at all are batched into a single
    /// `pull_matrix` call (the round-0 shape); partially-cached rows pull
    /// just their missing references.
    pub fn fill_matrix(
        &mut self,
        engine: &dyn PullEngine,
        xs: &[usize],
        refs: &[usize],
        out: &mut [f32],
    ) -> u64 {
        let m = refs.len();
        assert_eq!(xs.len() * m, out.len());
        if !self.enabled {
            engine.pull_matrix(xs, refs, out);
            let f = (xs.len() * m) as u64;
            self.fresh = self.fresh.saturating_add(f);
            return f;
        }
        let mut fresh = 0u64;

        // Pass 1: allocate slots; batch rows that are entirely new to the
        // cache (slot allocated this call ⇒ nothing cached yet) into one
        // engine call, and collect full-table overflow rows for a direct
        // bypass pull.
        let mut new_rows: Vec<usize> = Vec::new(); // positions in xs
        let mut bypass: Vec<usize> = Vec::new(); // positions in xs
        for (k, &x) in xs.iter().enumerate() {
            if self.slots.contains_key(&x) {
                continue;
            }
            match self.slot_for(x) {
                Some(_) => new_rows.push(k),
                None => bypass.push(k),
            }
        }
        if !new_rows.is_empty() {
            let rows: Vec<usize> = new_rows.iter().map(|&k| xs[k]).collect();
            self.scratch.clear();
            self.scratch.resize(rows.len() * m, 0.0);
            engine.pull_matrix(&rows, refs, &mut self.scratch);
            fresh += (rows.len() * m) as u64;
            for (r, &x) in rows.iter().enumerate() {
                let s = self.slots[&x];
                for (j, &rf) in refs.iter().enumerate() {
                    self.vals[s * self.n + rf] = self.scratch[r * m + j];
                    self.have[s * self.n + rf] = true;
                }
            }
        }
        if !bypass.is_empty() {
            let rows: Vec<usize> = bypass.iter().map(|&k| xs[k]).collect();
            self.scratch.clear();
            self.scratch.resize(rows.len() * m, 0.0);
            engine.pull_matrix(&rows, refs, &mut self.scratch);
            fresh += (rows.len() * m) as u64;
            for (r, &k) in bypass.iter().enumerate() {
                out[k * m..(k + 1) * m].copy_from_slice(&self.scratch[r * m..(r + 1) * m]);
            }
        }

        // Pass 2: serve every slotted row from the cache, pulling only the
        // references it is missing.
        for (k, &x) in xs.iter().enumerate() {
            let s = match self.slots.get(&x) {
                Some(&s) => s,
                None => continue, // bypass row, already written
            };
            self.missing.clear();
            self.missing.extend(refs.iter().copied().filter(|&rf| !self.have[s * self.n + rf]));
            if !self.missing.is_empty() {
                self.scratch.clear();
                self.scratch.resize(self.missing.len(), 0.0);
                engine.pull_matrix(&[x], &self.missing, &mut self.scratch);
                fresh += self.missing.len() as u64;
                for (j, &rf) in self.missing.iter().enumerate() {
                    self.vals[s * self.n + rf] = self.scratch[j];
                    self.have[s * self.n + rf] = true;
                }
            }
            for (j, &rf) in refs.iter().enumerate() {
                out[k * m + j] = self.vals[s * self.n + rf];
            }
        }

        let total = (xs.len() * m) as u64;
        self.fresh = self.fresh.saturating_add(fresh);
        self.hits = self.hits.saturating_add(total - fresh.min(total));
        fresh
    }

    /// Fill `out` with the full distance row of `x` (`out[j] = d(x, x_j)`,
    /// `out.len() == n`), pulling only missing references. Returns fresh
    /// engine pulls. This is the winner-verification path: the halving
    /// winner was always scored on at least one reference, so with the
    /// cache enabled this saves ≥ 1 pull per verification — and the full
    /// row is retained, so a re-verified or re-scored winner later in the
    /// run is free.
    pub fn fill_row(&mut self, engine: &dyn PullEngine, x: usize, out: &mut [f32]) -> u64 {
        assert_eq!(out.len(), self.n);
        if !self.enabled {
            engine.pull_matrix(&[x], &self.all, out);
            self.fresh = self.fresh.saturating_add(self.n as u64);
            return self.n as u64;
        }
        let s = match self.slot_for(x) {
            Some(s) => s,
            None => {
                engine.pull_matrix(&[x], &self.all, out);
                self.fresh = self.fresh.saturating_add(self.n as u64);
                return self.n as u64;
            }
        };
        self.missing.clear();
        self.missing.extend((0..self.n).filter(|&j| !self.have[s * self.n + j]));
        let fresh = self.missing.len() as u64;
        if !self.missing.is_empty() {
            self.scratch.clear();
            self.scratch.resize(self.missing.len(), 0.0);
            engine.pull_matrix(&[x], &self.missing, &mut self.scratch);
            for (j, &rf) in self.missing.iter().enumerate() {
                self.vals[s * self.n + rf] = self.scratch[j];
                self.have[s * self.n + rf] = true;
            }
        }
        out.copy_from_slice(&self.vals[s * self.n..(s + 1) * self.n]);
        self.fresh = self.fresh.saturating_add(fresh);
        self.hits = self.hits.saturating_add(self.n as u64 - fresh);
        fresh
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{gaussian, SynthConfig};
    use crate::distance::Metric;
    use crate::engine::{CountingEngine, NativeEngine};

    fn engine(n: usize) -> CountingEngine<NativeEngine> {
        let data = gaussian::generate(&SynthConfig { n, dim: 8, seed: 9, ..Default::default() });
        CountingEngine::new(NativeEngine::new(data, Metric::L2))
    }

    #[test]
    fn cached_values_are_bitwise_identical_to_direct_pulls() {
        let n = 64;
        let e = engine(n);
        let mut cache = PullCache::new(n, true);
        let xs = [3usize, 11, 3, 40];
        let refs = [0usize, 5, 9, 13, 21];
        let mut got = vec![0f32; xs.len() * refs.len()];
        let fresh = cache.fill_matrix(&e, &xs, &refs, &mut got);
        // Duplicate row 3 is pulled once; the second copy is a pure hit.
        assert_eq!(fresh, 3 * refs.len() as u64);
        let mut want = vec![0f32; xs.len() * refs.len()];
        e.pull_matrix(&xs, &refs, &mut want);
        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&got), bits(&want));
        // Re-filling the same block is now entirely free.
        let fresh2 = cache.fill_matrix(&e, &xs, &refs, &mut got);
        assert_eq!(fresh2, 0);
        assert_eq!(bits(&got), bits(&want));
    }

    #[test]
    fn fill_row_only_pulls_missing_refs_and_counts_match_engine() {
        let n = 50;
        let e = engine(n);
        let mut cache = PullCache::new(n, true);
        let refs = [1usize, 2, 3];
        let mut block = vec![0f32; refs.len()];
        e.reset();
        let f1 = cache.fill_matrix(&e, &[7], &refs, &mut block);
        assert_eq!(f1, 3);
        let mut row = vec![0f32; n];
        let f2 = cache.fill_row(&e, 7, &mut row);
        assert_eq!(f2, (n - 3) as u64, "only never-seen refs are pulled");
        assert_eq!(e.pulls(), f1 + f2, "fresh counts track the engine counter exactly");
        let mut want = vec![0f32; n];
        let all: Vec<usize> = (0..n).collect();
        e.pull_matrix(&[7], &all, &mut want);
        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&row), bits(&want));
        // The whole row is cached now: verification is free forever.
        assert_eq!(cache.fill_row(&e, 7, &mut row), 0);
        // And a matrix block over row 7 is served without the engine.
        let before = e.pulls();
        let f3 = cache.fill_matrix(&e, &[7], &[0, 49], &mut block[..2]);
        assert_eq!((f3, e.pulls()), (0, before));
    }

    #[test]
    fn disabled_cache_is_a_transparent_passthrough() {
        let n = 32;
        let e = engine(n);
        let mut cache = PullCache::new(n, false);
        let refs = [4usize, 8];
        let mut out = vec![0f32; 2];
        e.reset();
        assert_eq!(cache.fill_matrix(&e, &[5], &refs, &mut out), 2);
        assert_eq!(cache.fill_matrix(&e, &[5], &refs, &mut out), 2, "nothing is retained");
        assert_eq!(e.pulls(), 4);
        let mut row = vec![0f32; n];
        assert_eq!(cache.fill_row(&e, 5, &mut row), n as u64);
        assert_eq!(cache.hits(), 0);
    }

    #[test]
    fn overflow_rows_bypass_without_corrupting_cached_rows() {
        let n = 40;
        let e = engine(n);
        let mut cache = PullCache::new(n, true);
        cache.max_slots = 2; // force overflow
        let refs: Vec<usize> = (0..n).collect();
        let mut out = vec![0f32; 4 * n];
        let fresh = cache.fill_matrix(&e, &[0, 1, 2, 3], &refs, &mut out);
        assert_eq!(fresh, 4 * n as u64, "first sight of every row is fresh");
        // Rows 0/1 got slots; 2/3 bypassed. A second call re-pulls only the
        // bypass rows.
        let fresh2 = cache.fill_matrix(&e, &[0, 1, 2, 3], &refs, &mut out);
        assert_eq!(fresh2, 2 * n as u64);
        let mut want = vec![0f32; 4 * n];
        e.pull_matrix(&[0, 1, 2, 3], &refs, &mut want);
        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&out), bits(&want));
    }
}
