//! SWAP phase: PAM improvement as a best-arm problem.
//!
//! A round treats every (medoid slot `c`, non-medoid `x`) pair as an arm
//! (`arm = xi·k + c`); the score against reference `j` is the post-swap
//! loss contribution
//!
//! ```text
//! score((c, x), j) = min(removed_c(j), d(x, j))
//! removed_c(j)     = d2(j) if nearest(j) = c else d1(j)
//! ```
//!
//! with `d1/d2/nearest` derived from the exact cached medoid rows — so the
//! only pulls a round needs are `d(x, J_r)` for the *distinct* candidates
//! still alive, shared across the k slots that reference them (the same
//! correlated-reference amortization the engine's densified sparse path
//! exploits), minus whatever the run's [`PullCache`] already holds from
//! earlier rounds and phases. The halving winner is then verified exactly:
//! its full row (≤ n fresh pulls through the cache) gives the true
//! post-swap loss, and the swap is applied only on strict improvement —
//! otherwise the phase has converged and stops.

use crate::bandits::corr_sh::{correlated_halving_argmin_reported, Budget};
use crate::engine::PullEngine;
use crate::kmedoids::cache::PullCache;
use crate::kmedoids::{ClusterState, Trajectory};
use crate::util::rng::Rng;

/// SWAP phase outcome: engine-boundary pulls, rounds run, swaps applied.
#[derive(Clone, Copy, Debug, Default)]
pub struct SwapOutcome {
    pub pulls: u64,
    pub rounds: usize,
    pub accepted: usize,
}

pub(crate) fn run(
    engine: &dyn PullEngine,
    state: &mut ClusterState,
    pulls_per_arm: f64,
    max_rounds: usize,
    cache: &mut PullCache,
    rng: &mut Rng,
    trajectory: &mut Trajectory<'_>,
) -> SwapOutcome {
    let n = engine.n();
    let k = state.medoids.len();
    let mut row = vec![0f32; n];
    let mut out = SwapOutcome::default();
    // Scorer scratch, alloc-reused across rounds: `xs` doubles as the
    // sorted distinct-candidate index (binary search replaces the old
    // per-block HashMap — no SipHash, no per-round rehash).
    let mut xs: Vec<usize> = Vec::new();
    let mut d: Vec<f32> = Vec::new();

    for _round in 0..max_rounds {
        state.refresh();
        let cur_loss = state.loss();
        let mut is_medoid = vec![false; n];
        for &m in &state.medoids {
            is_medoid[m] = true;
        }
        let cands: Vec<usize> = (0..n).filter(|&j| !is_medoid[j]).collect();
        if cands.is_empty() {
            break;
        }
        let n_arms = cands.len() * k;
        let budget = Budget::PerArm(pulls_per_arm).total(n_arms);

        // Engine-boundary pull accounting: rounds deduplicate the candidate
        // rows shared by the k slots and the reuse cache strips pairs seen
        // in earlier rounds/phases, so the reported fresh pulls ≤ the
        // schedule's |S_r|·t_r charge.
        let outcome = {
            let state = &*state; // shared borrow for the scorer
            let xs = &mut xs;
            let d = &mut d;
            let cache = &mut *cache;
            correlated_halving_argmin_reported(n_arms, n, budget, rng, &mut |arms, refs, sums| {
                // Distinct candidate rows of this block as a sorted index.
                xs.clear();
                xs.extend(arms.iter().map(|&arm| cands[arm / k]));
                xs.sort_unstable();
                xs.dedup();
                let m = refs.len();
                d.clear();
                d.resize(xs.len() * m, 0.0);
                let fresh = cache.fill_matrix(engine, xs, refs, d);
                for (ai, &arm) in arms.iter().enumerate() {
                    let x = cands[arm / k];
                    let c = arm % k;
                    let slot = xs.binary_search(&x).expect("candidate row is in the index");
                    let drow = &d[slot * m..(slot + 1) * m];
                    let mut acc = 0f64;
                    for (ri, &j) in refs.iter().enumerate() {
                        let removed = if state.nearest[j] == c {
                            state.d2[j]
                        } else {
                            state.d1[j]
                        };
                        acc += (removed as f64).min(drow[ri] as f64);
                    }
                    sums[ai] = acc;
                }
                fresh
            })
        };
        out.pulls = out.pulls.saturating_add(outcome.reported_pulls);
        out.rounds += 1;

        // Exact verification of the winning pair before applying it — the
        // shared `post_swap_loss`/`apply_row` criterion (also used by the
        // polish pass). The winner was scored on ≥ 1 reference during the
        // halving, so the cached fill always saves pulls with reuse on.
        let (c, x) = (outcome.best % k, cands[outcome.best / k]);
        let fresh = cache.fill_row(engine, x, &mut row);
        out.pulls = out.pulls.saturating_add(fresh);
        if state.post_swap_loss(c, &row) < cur_loss {
            state.apply_row(c, x, &row);
            trajectory.push(state.loss());
            out.accepted += 1;
        } else {
            break; // best candidate swap does not improve ⇒ converged
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{gaussian, SynthConfig};
    use crate::distance::Metric;
    use crate::engine::{CountingEngine, NativeEngine};
    use crate::kmedoids::build;

    #[test]
    fn swap_only_ever_improves_the_exact_loss() {
        let data = gaussian::generate_mixture(&SynthConfig {
            n: 500,
            dim: 8,
            seed: 4,
            clusters: 3,
            ..Default::default()
        });
        let engine = CountingEngine::new(NativeEngine::new(data, Metric::L2));
        let mut rng = Rng::seeded(2);
        let mut trajectory = Trajectory::new();
        let mut cache = PullCache::new(engine.n(), true);
        // Deliberately under-budget BUILD so SWAP has work to do.
        let (mut state, _) = build::run(&engine, 3, 2.0, &mut cache, &mut rng, &mut trajectory);
        state.refresh();
        let before = state.loss();
        let out = run(&engine, &mut state, 4.0, 6, &mut cache, &mut rng, &mut trajectory);
        state.refresh();
        assert!(state.loss() <= before + 1e-9, "SWAP regressed the loss");
        assert!(out.rounds >= 1);
        if out.accepted > 0 {
            assert!(state.loss() < before);
        }
    }

    #[test]
    fn swap_repairs_an_uncovered_cluster() {
        // Seed the state with cluster 0 uncovered (two medoids inside
        // cluster 1, one in cluster 2): the loss gap is at the inter-center
        // scale, so SWAP must move a medoid into cluster 0 and the loss
        // must drop sharply.
        let k = 3;
        let n = 300;
        let data = gaussian::generate_mixture(&SynthConfig {
            n,
            dim: 8,
            seed: 6,
            clusters: k,
            ..Default::default()
        });
        let engine = CountingEngine::new(NativeEngine::new(data, Metric::L2));
        let mut state = crate::kmedoids::ClusterState::new(n);
        // generator layout: point j belongs to cluster j % k, points 0..k
        // are the planted centers — so {k + 1, 1, 2} covers clusters
        // {1, 1, 2} and leaves cluster 0 unserved.
        let seeds = [k + 1, 1, 2];
        let all: Vec<usize> = (0..n).collect();
        let mut row = vec![0f32; n];
        for &m in &seeds {
            engine.pull_matrix(&[m], &all, &mut row);
            state.rows.extend_from_slice(&row);
            state.medoids.push(m);
        }
        state.refresh();
        let before = state.loss();
        let mut rng = Rng::seeded(0);
        let mut trajectory = Trajectory::new();
        let mut cache = PullCache::new(n, true);
        let out = run(&engine, &mut state, 6.0, 6, &mut cache, &mut rng, &mut trajectory);
        assert!(out.accepted >= 1, "SWAP accepted nothing on an improvable seed");
        state.refresh();
        assert!(
            state.loss() < before * 0.8,
            "loss barely improved: {before} -> {}",
            state.loss()
        );
        let mut covered = vec![false; k];
        for &m in &state.medoids {
            covered[m % k] = true;
        }
        assert!(covered.iter().all(|&c| c), "a cluster is still uncovered: {:?}", state.medoids);
    }
}
