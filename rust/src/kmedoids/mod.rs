//! k-medoids clustering (BUILD / SWAP / polish) on top of the corrSH pull
//! substrate — the paper's motivating workload ("clustering the data to
//! discover sub-classes of cells, where medoid finding is used as a
//! subroutine") promoted from example code to a first-class subsystem, in
//! the style of BanditPAM (Tiwari et al., NeurIPS 2020): every phase is a
//! best-arm problem answered by the *same* correlated halving oracle that
//! powers single-medoid identification.
//!
//! * **BUILD** ([`build`]) — greedy seeding: step `i` treats every
//!   non-medoid as an arm whose score against reference `j` is the marginal
//!   loss `min(best_i(j), d(x, j))`, and runs
//!   [`correlated_halving_argmin`] over the candidates (shared reference
//!   draws ⇒ the cross-cluster variance cancels exactly as in Theorem 2.1).
//! * **SWAP** ([`swap`]) — PAM improvement: arms are (medoid, non-medoid)
//!   pairs scored by the post-swap loss `min(removed(j), d(x, j))`; the
//!   winning pair is verified against the *exact* current loss before being
//!   applied, so SWAP never accepts a regression.
//! * **Polish** — per-cluster corrSH restricted to the cluster's members
//!   (the same subroutine the paper's intro describes), again accepted only
//!   on exact improvement.
//!
//! All distance work flows through [`PullEngine::pull_matrix`] /
//! [`PullEngine::pull_block`], i.e. the persistent worker pool and (via the
//! server) the cached `PreparedEngine` sessions. Pull counts are reported
//! per phase and measured at the engine boundary (SWAP deduplicates the
//! shared candidate rows inside a round, so it pulls *fewer* distances than
//! the schedule charges).

pub mod build;
pub mod cache;
pub mod swap;

use std::time::{Duration, Instant};

use crate::bandits::corr_sh::correlated_halving_argmin;
use crate::config::KMedoidsConfig;
use crate::engine::PullEngine;
use crate::kmedoids::cache::PullCache;
use crate::util::rng::Rng;

/// Outcome of one k-medoids run.
#[derive(Clone, Debug)]
pub struct KMedoidsResult {
    /// Selected medoids (BUILD order; positions are dataset row indices).
    pub medoids: Vec<usize>,
    /// Per-point index into `medoids` (nearest medoid under the metric).
    pub assignments: Vec<usize>,
    /// Final mean distance to the assigned medoid.
    pub loss: f64,
    /// Mean loss after each BUILD step, each accepted SWAP and each
    /// accepted polish — non-increasing by construction.
    pub loss_trajectory: Vec<f64>,
    /// Distance computations per phase, measured at the engine boundary.
    pub build_pulls: u64,
    pub swap_pulls: u64,
    pub polish_pulls: u64,
    /// SWAP rounds executed / swaps accepted before convergence.
    pub swap_rounds: usize,
    pub swaps_accepted: usize,
    pub wall: Duration,
}

impl KMedoidsResult {
    /// Total distance computations across all phases (saturating, like
    /// every other pull accumulator in the tree — a near-`u64::MAX` phase
    /// counter from a saturated ledger must not wrap the total).
    pub fn pulls(&self) -> u64 {
        self.build_pulls.saturating_add(self.swap_pulls).saturating_add(self.polish_pulls)
    }

    /// Cluster sizes, index-aligned with `medoids`.
    pub fn cluster_sizes(&self) -> Vec<usize> {
        let mut sizes = vec![0usize; self.medoids.len()];
        for &a in &self.assignments {
            sizes[a] += 1;
        }
        sizes
    }
}

/// A k-medoids clustering algorithm — the [`crate::bandits::MedoidAlgorithm`]
/// counterpart for the clustering workload (same engine/rng contract, richer
/// result).
pub trait ClusteringAlgorithm {
    fn name(&self) -> &'static str;

    /// Cluster `engine`'s dataset using `rng` for all randomness.
    fn run(&self, engine: &dyn PullEngine, rng: &mut Rng) -> KMedoidsResult;
}

/// The loss trajectory under construction, with an optional live observer
/// (the server's streaming-partials hook). `push` has the same call syntax
/// as the `Vec<f64>` it replaced; the observer additionally sees
/// `(phase, step-within-phase, loss)` for every point, as it happens, and
/// never affects the recorded trajectory or the run's determinism.
pub(crate) struct Trajectory<'a> {
    points: Vec<f64>,
    phase: &'static str,
    step: usize,
    observer: Option<&'a mut dyn FnMut(&'static str, usize, f64)>,
}

impl Default for Trajectory<'_> {
    fn default() -> Self {
        Trajectory { points: Vec::new(), phase: "", step: 0, observer: None }
    }
}

impl<'a> Trajectory<'a> {
    pub(crate) fn new() -> Self {
        Self::default()
    }

    pub(crate) fn with_observer(observer: &'a mut dyn FnMut(&'static str, usize, f64)) -> Self {
        Trajectory { observer: Some(observer), ..Self::default() }
    }

    pub(crate) fn set_phase(&mut self, phase: &'static str) {
        self.phase = phase;
        self.step = 0;
    }

    pub(crate) fn push(&mut self, loss: f64) {
        if let Some(obs) = self.observer.as_mut() {
            obs(self.phase, self.step, loss);
        }
        self.step += 1;
        self.points.push(loss);
    }

    #[cfg(test)]
    pub(crate) fn points(&self) -> &[f64] {
        &self.points
    }

    pub(crate) fn into_points(self) -> Vec<f64> {
        self.points
    }
}

/// Cached per-medoid distance rows plus the derived assignment structure.
/// `rows` is row-major k×n with `rows[c·n + j] = d(medoids[c], x_j)` — the
/// only O(k·n) state the phases share; every update (swap, polish) replaces
/// one row for n pulls and re-derives the rest for free.
pub(crate) struct ClusterState {
    pub medoids: Vec<usize>,
    pub rows: Vec<f32>,
    /// Index into `medoids` of each point's nearest medoid.
    pub nearest: Vec<usize>,
    /// Distance to the nearest medoid.
    pub d1: Vec<f32>,
    /// Distance to the second-nearest medoid (∞ when k = 1) — the removal
    /// cost the SWAP scorer needs.
    pub d2: Vec<f32>,
}

impl ClusterState {
    pub(crate) fn new(n: usize) -> Self {
        ClusterState {
            medoids: Vec::new(),
            rows: Vec::new(),
            nearest: vec![0; n],
            d1: vec![f32::INFINITY; n],
            d2: vec![f32::INFINITY; n],
        }
    }

    /// Re-derive nearest / d1 / d2 from the cached rows (O(k·n) compute,
    /// zero pulls). NaN distances never win a comparison, so a poisoned
    /// point keeps its previous-best finite assignment where one exists.
    pub(crate) fn refresh(&mut self) {
        let k = self.medoids.len();
        let n = self.d1.len();
        for j in 0..n {
            let (mut c1, mut b1, mut b2) = (0usize, f32::INFINITY, f32::INFINITY);
            for c in 0..k {
                let d = self.rows[c * n + j];
                if d < b1 {
                    b2 = b1;
                    b1 = d;
                    c1 = c;
                } else if d < b2 {
                    b2 = d;
                }
            }
            self.nearest[j] = c1;
            self.d1[j] = b1;
            self.d2[j] = b2;
        }
    }

    /// Mean distance to the assigned medoid.
    pub(crate) fn loss(&self) -> f64 {
        let n = self.d1.len().max(1);
        self.d1.iter().map(|&d| d as f64).sum::<f64>() / n as f64
    }

    /// Exact mean loss if medoid slot `c` were replaced by a point whose
    /// full distance row is `row` — zero pulls, derived from the cached
    /// d1/d2/nearest structure. The single acceptance criterion shared by
    /// SWAP and polish.
    pub(crate) fn post_swap_loss(&self, c: usize, row: &[f32]) -> f64 {
        let n = self.d1.len();
        let mut acc = 0f64;
        for j in 0..n {
            let removed = if self.nearest[j] == c { self.d2[j] } else { self.d1[j] };
            acc += (removed as f64).min(row[j] as f64);
        }
        acc / n.max(1) as f64
    }

    /// Install `medoid` (with its full distance `row`) into slot `c` and
    /// re-derive the assignment structure.
    pub(crate) fn apply_row(&mut self, c: usize, medoid: usize, row: &[f32]) {
        let n = self.d1.len();
        self.medoids[c] = medoid;
        self.rows[c * n..(c + 1) * n].copy_from_slice(row);
        self.refresh();
    }
}

/// BanditPAM-style k-medoids: bandit BUILD seeding + bandit SWAP
/// improvement + per-cluster corrSH polish, all through the shared
/// correlated halving oracle.
#[derive(Clone, Debug)]
pub struct BanditKMedoids {
    pub cfg: KMedoidsConfig,
}

impl BanditKMedoids {
    pub fn new(cfg: KMedoidsConfig) -> Self {
        BanditKMedoids { cfg }
    }

    /// [`ClusteringAlgorithm::run`] with a live view of the loss
    /// trajectory: `observer` is called with `(phase, step, loss)` for
    /// every trajectory point as the run produces it — phases are
    /// `"build"`, `"swap"`, `"polish"` — which the server streams to
    /// clients as `"partial":true` frames. The observer is passive: the
    /// result is identical to `run` for the same engine and seed.
    pub fn run_with_observer(
        &self,
        engine: &dyn PullEngine,
        rng: &mut Rng,
        observer: &mut dyn FnMut(&'static str, usize, f64),
    ) -> KMedoidsResult {
        self.run_inner(engine, rng, Trajectory::with_observer(observer))
    }

    fn run_inner(
        &self,
        engine: &dyn PullEngine,
        rng: &mut Rng,
        mut trajectory: Trajectory<'_>,
    ) -> KMedoidsResult {
        let start = Instant::now();
        let n = engine.n();
        if n == 0 {
            return KMedoidsResult {
                medoids: vec![],
                assignments: vec![],
                loss: 0.0,
                loss_trajectory: vec![],
                build_pulls: 0,
                swap_pulls: 0,
                polish_pulls: 0,
                swap_rounds: 0,
                swaps_accepted: 0,
                wall: start.elapsed(),
            };
        }
        let k = self.cfg.k.clamp(1, n);
        // One reuse cache for the whole run: BUILD's candidate rows and
        // winner verification rows carry into SWAP and polish.
        let mut cache = PullCache::new(n, self.cfg.reuse_cache);

        trajectory.set_phase("build");
        let (mut state, build_pulls) =
            build::run(engine, k, self.cfg.build_pulls_per_arm, &mut cache, rng, &mut trajectory);

        trajectory.set_phase("swap");
        let swap_out = if self.cfg.max_swap_rounds > 0 && k < n {
            swap::run(
                engine,
                &mut state,
                self.cfg.swap_pulls_per_arm,
                self.cfg.max_swap_rounds,
                &mut cache,
                rng,
                &mut trajectory,
            )
        } else {
            swap::SwapOutcome::default()
        };

        trajectory.set_phase("polish");
        let polish_pulls = if self.cfg.polish_pulls_per_arm > 0.0 {
            polish(
                engine,
                &mut state,
                self.cfg.polish_pulls_per_arm,
                &mut cache,
                rng,
                &mut trajectory,
            )
        } else {
            0
        };

        state.refresh();
        KMedoidsResult {
            assignments: state.nearest.clone(),
            loss: state.loss(),
            medoids: state.medoids,
            loss_trajectory: trajectory.into_points(),
            build_pulls,
            swap_pulls: swap_out.pulls,
            polish_pulls,
            swap_rounds: swap_out.rounds,
            swaps_accepted: swap_out.accepted,
            wall: start.elapsed(),
        }
    }
}

impl ClusteringAlgorithm for BanditKMedoids {
    fn name(&self) -> &'static str {
        "bandit-kmedoids"
    }

    fn run(&self, engine: &dyn PullEngine, rng: &mut Rng) -> KMedoidsResult {
        self.run_inner(engine, rng, Trajectory::new())
    }
}

/// Polish: re-run the paper's single-medoid subroutine inside each cluster
/// (corrSH over the members, the `examples/rnaseq_clustering.rs` pattern),
/// accepting a candidate only when the *exact* global loss improves.
/// Returns the pulls spent.
fn polish(
    engine: &dyn PullEngine,
    state: &mut ClusterState,
    pulls_per_arm: f64,
    cache: &mut PullCache,
    rng: &mut Rng,
    trajectory: &mut Trajectory<'_>,
) -> u64 {
    let n = engine.n();
    let k = state.medoids.len();
    state.refresh();
    let mut pulls = 0u64;
    let mut row = vec![0f32; n];
    for c in 0..k {
        let members: Vec<usize> = (0..n).filter(|&j| state.nearest[j] == c).collect();
        if members.len() < 2 {
            continue;
        }
        let m = members.len();
        let budget = crate::bandits::corr_sh::Budget::PerArm(pulls_per_arm).total(m);
        // Scoring stays on `pull_block`'s f64 sum path (the cache holds
        // per-pair f32 values, not block sums); only the verification row
        // below goes through — and lands in — the reuse cache.
        let outcome = correlated_halving_argmin(m, m, budget, rng, &mut |arms, refs, out| {
            let a: Vec<usize> = arms.iter().map(|&i| members[i]).collect();
            let r: Vec<usize> = refs.iter().map(|&j| members[j]).collect();
            engine.pull_block(&a, &r, out);
        });
        pulls = pulls.saturating_add(outcome.pulls);
        let cand = members[outcome.best];
        if cand == state.medoids[c] {
            continue;
        }
        // Exact acceptance: replace row c by the candidate's and keep the
        // change only if the global loss strictly improves.
        let fresh = cache.fill_row(engine, cand, &mut row);
        pulls = pulls.saturating_add(fresh);
        if state.post_swap_loss(c, &row) < state.loss() {
            state.apply_row(c, cand, &row);
            trajectory.push(state.loss());
        }
    }
    pulls
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{gaussian, SynthConfig};
    use crate::data::{Data, DenseData};
    use crate::distance::Metric;
    use crate::engine::{CountingEngine, NativeEngine};

    fn mixture_engine(n: usize, k: usize, seed: u64) -> CountingEngine<NativeEngine> {
        let data = gaussian::generate_mixture(&SynthConfig {
            n,
            dim: 16,
            seed,
            clusters: k,
            ..Default::default()
        });
        CountingEngine::new(NativeEngine::new(data, Metric::L2))
    }

    /// The PR's acceptance bar: k = 5 planted clusters on n = 2000 points,
    /// ≥ 90% exact-medoid agreement at ≤ 5% of the exact-algorithm pull
    /// count (exact BUILD alone sweeps k·n² distances).
    #[test]
    fn recovers_planted_mixture_medoids_cheaply() {
        let n = 2000;
        let k = 5;
        let engine = mixture_engine(n, k, 42);
        let exact_cost = (k as u64) * (n as u64) * (n as u64);
        let trials = 5u64;
        let mut agree = 0usize;
        for seed in 0..trials {
            let before = engine.pulls();
            let mut rng = Rng::seeded(seed);
            let res = BanditKMedoids::new(KMedoidsConfig { k, ..Default::default() })
                .run(&engine, &mut rng);
            let consumed = engine.pulls() - before;
            assert_eq!(res.pulls(), consumed, "phase pull accounting vs engine counter");
            assert!(
                res.pulls() * 20 <= exact_cost,
                "seed {seed}: {} pulls > 5% of exact {exact_cost}",
                res.pulls()
            );
            // Planted medoids are points 0..k (exact centers of the
            // generator's clusters).
            let hits = res.medoids.iter().filter(|&&m| m < k).count();
            assert!(hits >= k - 1, "seed {seed}: medoids {:?} missed >1 center", res.medoids);
            agree += hits;
            // medoids are distinct and assignments index into them
            let mut sorted = res.medoids.clone();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted.len(), k, "duplicate medoids: {:?}", res.medoids);
            assert_eq!(res.assignments.len(), n);
            assert!(res.assignments.iter().all(|&a| a < k));
        }
        let rate = agree as f64 / (trials as usize * k) as f64;
        assert!(rate >= 0.9, "exact-medoid agreement {rate:.2} < 0.9");
    }

    #[test]
    fn loss_trajectory_is_monotone_nonincreasing() {
        let engine = mixture_engine(600, 4, 7);
        let res = BanditKMedoids::new(KMedoidsConfig { k: 4, ..Default::default() })
            .run(&engine, &mut Rng::seeded(1));
        assert!(!res.loss_trajectory.is_empty());
        for w in res.loss_trajectory.windows(2) {
            assert!(
                w[1] <= w[0] + 1e-9,
                "loss increased along the trajectory: {:?}",
                res.loss_trajectory
            );
        }
        let last = *res.loss_trajectory.last().unwrap();
        assert!((last - res.loss).abs() < 1e-9);
        assert_eq!(res.cluster_sizes().iter().sum::<usize>(), 600);
    }

    #[test]
    fn assignments_are_nearest_medoid() {
        let engine = mixture_engine(300, 3, 9);
        let res = BanditKMedoids::new(KMedoidsConfig { k: 3, ..Default::default() })
            .run(&engine, &mut Rng::seeded(0));
        for j in 0..300 {
            let assigned = engine.pull(res.medoids[res.assignments[j]], j);
            for &m in &res.medoids {
                assert!(
                    assigned <= engine.pull(m, j) + 1e-5,
                    "point {j} not assigned to its nearest medoid"
                );
            }
        }
    }

    #[test]
    fn k_equals_one_matches_single_medoid() {
        // Single cluster with the planted medoid at point 0: k = 1 must
        // reduce to the paper's problem.
        let data = gaussian::generate(&SynthConfig {
            n: 400,
            dim: 16,
            seed: 3,
            outlier_frac: 0.05,
            ..Default::default()
        });
        let engine = CountingEngine::new(NativeEngine::new(data, Metric::L2));
        let res = BanditKMedoids::new(KMedoidsConfig {
            k: 1,
            build_pulls_per_arm: 48.0,
            ..Default::default()
        })
        .run(&engine, &mut Rng::seeded(2));
        assert_eq!(res.medoids, vec![0]);
        assert!(res.assignments.iter().all(|&a| a == 0));
    }

    #[test]
    fn k_clamps_to_n_and_degenerate_inputs_are_safe() {
        let raw: Vec<f32> = (0..6 * 2).map(|i| i as f32).collect();
        let data = Data::Dense(DenseData::new(6, 2, raw));
        let engine = NativeEngine::new(data, Metric::L2);
        let res = BanditKMedoids::new(KMedoidsConfig { k: 100, ..Default::default() })
            .run(&engine, &mut Rng::seeded(0));
        assert_eq!(res.medoids.len(), 6, "k clamps to n");
        assert!(res.loss < 1e-9, "every point is its own medoid");
        let mut sorted = res.medoids.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..6).collect::<Vec<_>>());
    }

    #[test]
    fn observer_sees_every_trajectory_point_without_changing_the_run() {
        let engine = mixture_engine(600, 4, 7);
        let algo = BanditKMedoids::new(KMedoidsConfig { k: 4, ..Default::default() });
        let plain = algo.run(&engine, &mut Rng::seeded(1));
        let mut seen: Vec<(&'static str, usize, f64)> = Vec::new();
        let mut observer = |phase: &'static str, step: usize, loss: f64| {
            seen.push((phase, step, loss));
        };
        let observed = algo.run_with_observer(&engine, &mut Rng::seeded(1), &mut observer);
        // Passive observer: identical result.
        assert_eq!(observed.medoids, plain.medoids);
        assert_eq!(observed.pulls(), plain.pulls());
        assert_eq!(observed.loss_trajectory, plain.loss_trajectory);
        // Every trajectory point was streamed, in order.
        let losses: Vec<f64> = seen.iter().map(|&(_, _, l)| l).collect();
        assert_eq!(losses, plain.loss_trajectory);
        // BUILD contributes exactly k points as steps 0..k, and phase
        // labels stay within the known set with per-phase step counters.
        assert_eq!(seen[..4].iter().map(|&(p, s, _)| (p, s)).collect::<Vec<_>>(), vec![
            ("build", 0),
            ("build", 1),
            ("build", 2),
            ("build", 3)
        ]);
        for &(phase, _, _) in &seen {
            assert!(matches!(phase, "build" | "swap" | "polish"), "unknown phase {phase}");
        }
        let mut last: std::collections::HashMap<&str, usize> = Default::default();
        for &(phase, step, _) in &seen[..] {
            let next = last.entry(phase).or_insert(0);
            assert_eq!(step, *next, "non-contiguous steps in phase {phase}");
            *next += 1;
        }
    }

    #[test]
    fn deterministic_for_fixed_seed() {
        let engine = mixture_engine(500, 4, 13);
        let a = BanditKMedoids::new(KMedoidsConfig { k: 4, ..Default::default() })
            .run(&engine, &mut Rng::seeded(5));
        let b = BanditKMedoids::new(KMedoidsConfig { k: 4, ..Default::default() })
            .run(&engine, &mut Rng::seeded(5));
        assert_eq!(a.medoids, b.medoids);
        assert_eq!(a.assignments, b.assignments);
        assert_eq!(a.pulls(), b.pulls());
        assert_eq!(a.loss_trajectory, b.loss_trajectory);
    }
}
