//! BUILD phase: greedy bandit seeding à la BanditPAM.
//!
//! Step `i` chooses the point that most reduces the current loss. Every
//! non-medoid is an arm; its score against reference `j` is the marginal
//! loss `min(best_i(j), d(x, j))` where `best_i(j)` is `j`'s distance to
//! the closest already-chosen medoid (∞ at step 0, so step 0 *is* the
//! paper's medoid problem). The arm is pulled through the shared
//! [`correlated_halving_argmin`] oracle: one reference draw per round
//! shared by all candidates, which cancels the dominant
//! which-cluster-is-the-reference-in variance exactly as in Theorem 2.1.
//!
//! After each step the winner's full distance row (n pulls) updates
//! `best_i` exactly and is cached in [`ClusterState::rows`] for the SWAP
//! phase — so BUILD costs `k · (halving budget + n)` pulls total. All
//! engine traffic routes through the run's [`PullCache`]: with reuse
//! enabled, candidate rows scored in earlier steps and previous winners'
//! verification rows are served from the cache, and the reported pull
//! counters reflect only the fresh engine work.

use crate::bandits::corr_sh::{correlated_halving_argmin_reported, Budget};
use crate::engine::PullEngine;
use crate::kmedoids::cache::PullCache;
use crate::kmedoids::{ClusterState, Trajectory};
use crate::util::rng::Rng;

/// Run BUILD: returns the seeded state (medoids + cached rows, refreshed)
/// and the pulls spent. Appends the post-step mean loss to `trajectory`.
pub(crate) fn run(
    engine: &dyn PullEngine,
    k: usize,
    pulls_per_arm: f64,
    cache: &mut PullCache,
    rng: &mut Rng,
    trajectory: &mut Trajectory<'_>,
) -> (ClusterState, u64) {
    let n = engine.n();
    let mut state = ClusterState::new(n);
    let mut best = vec![f64::INFINITY; n];
    let mut is_medoid = vec![false; n];
    let mut row = vec![0f32; n];
    let mut pulls = 0u64;
    // Scorer scratch, alloc-reused across steps and rounds.
    let mut mapped: Vec<usize> = Vec::new();
    let mut d: Vec<f32> = Vec::new();

    for _step in 0..k.min(n) {
        let candidates: Vec<usize> = (0..n).filter(|&i| !is_medoid[i]).collect();
        let budget = Budget::PerArm(pulls_per_arm).total(candidates.len());
        let outcome = correlated_halving_argmin_reported(
            candidates.len(),
            n,
            budget,
            rng,
            &mut |arms, refs, out| {
                // Arms index into `candidates`; score = Σ_j marginal loss.
                mapped.clear();
                mapped.extend(arms.iter().map(|&a| candidates[a]));
                let m = refs.len();
                d.clear();
                d.resize(mapped.len() * m, 0.0);
                let fresh = cache.fill_matrix(engine, &mapped, refs, &mut d);
                for (ai, o) in out.iter_mut().enumerate() {
                    let mut acc = 0f64;
                    for (ri, &j) in refs.iter().enumerate() {
                        // NaN distances fall back to the incumbent best
                        // (f64::min ignores NaN): a poisoned candidate can
                        // never *look* like an improvement.
                        acc += best[j].min(d[ai * m + ri] as f64);
                    }
                    *o = acc;
                }
                fresh
            },
        );
        pulls = pulls.saturating_add(outcome.reported_pulls);
        let winner = candidates[outcome.best];

        // Exact update: the winner's full row refreshes best_i and is the
        // SWAP phase's cached row for this medoid. The halving scored the
        // winner on at least one reference, so the cached fill saves ≥ 1
        // pull per step with reuse on.
        let fresh = cache.fill_row(engine, winner, &mut row);
        pulls = pulls.saturating_add(fresh);
        for (b, &d) in best.iter_mut().zip(row.iter()) {
            let d = d as f64;
            if d < *b {
                *b = d;
            }
        }
        state.rows.extend_from_slice(&row);
        state.medoids.push(winner);
        is_medoid[winner] = true;
        let covered: f64 = best.iter().map(|&b| if b.is_finite() { b } else { 0.0 }).sum();
        trajectory.push(covered / n as f64);
    }

    state.refresh();
    (state, pulls)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{gaussian, SynthConfig};
    use crate::distance::Metric;
    use crate::engine::{CountingEngine, NativeEngine};

    #[test]
    fn build_covers_every_planted_cluster() {
        // 4 well-separated clusters: greedy seeding must pick exactly one
        // point in each (marginal losses across clusters differ by the
        // inter-center scale, which shared references resolve at tiny t).
        let k = 4;
        let data = gaussian::generate_mixture(&SynthConfig {
            n: 800,
            dim: 16,
            seed: 5,
            clusters: k,
            ..Default::default()
        });
        let engine = CountingEngine::new(NativeEngine::new(data, Metric::L2));
        for seed in 0..3 {
            let mut trajectory = Trajectory::new();
            let mut cache = PullCache::new(engine.n(), true);
            let (state, pulls) =
                run(&engine, k, 12.0, &mut cache, &mut Rng::seeded(seed), &mut trajectory);
            assert_eq!(state.medoids.len(), k);
            // generator layout: point j belongs to cluster j % k
            let mut covered: Vec<bool> = vec![false; k];
            for &m in &state.medoids {
                covered[m % k] = true;
            }
            assert!(
                covered.iter().all(|&c| c),
                "seed {seed}: medoids {:?} leave a cluster uncovered",
                state.medoids
            );
            let points = trajectory.points();
            assert!(pulls > 0 && points.len() == k);
            for w in points.windows(2) {
                assert!(w[1] <= w[0] + 1e-9, "BUILD loss increased: {points:?}");
            }
        }
    }

    #[test]
    fn step_zero_is_the_medoid_problem() {
        // Single planted cluster: BUILD with k = 1 and a healthy budget
        // finds the planted medoid (point 0), same as CorrSh.
        let data = gaussian::generate(&SynthConfig {
            n: 400,
            dim: 16,
            seed: 8,
            outlier_frac: 0.05,
            ..Default::default()
        });
        let engine = CountingEngine::new(NativeEngine::new(data, Metric::L2));
        let mut hits = 0;
        for seed in 0..5 {
            let mut traj = Trajectory::new();
            let mut cache = PullCache::new(engine.n(), true);
            let (state, _) =
                run(&engine, 1, 48.0, &mut cache, &mut Rng::seeded(seed), &mut traj);
            hits += (state.medoids == vec![0]) as usize;
        }
        assert!(hits >= 4, "BUILD step 0 found the planted medoid {hits}/5");
    }
}
