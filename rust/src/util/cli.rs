//! Launcher flag parsing: `corrsh <command> [--flag value] [--switch]`.
//!
//! Hand-rolled (clap is outside the offline closure). Flags accept
//! `--key value` and `--key=value`; unknown flags are an error so typos
//! fail fast.

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub command: Option<String>,
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
    switches: Vec<String>,
    /// flags consumed by accessors — for unknown-flag detection
    seen: std::cell::RefCell<std::collections::BTreeSet<String>>,
}

#[derive(Debug)]
pub enum CliError {
    MissingValue(String),
    Unknown(String),
    Invalid { flag: String, value: String, why: String },
    MissingRequired(String),
}

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CliError::MissingValue(flag) => write!(f, "missing value for flag --{flag}"),
            CliError::Unknown(flags) => write!(f, "unknown flag(s): {flags}"),
            CliError::Invalid { flag, value, why } => {
                write!(f, "invalid value for --{flag}: {value:?} ({why})")
            }
            CliError::MissingRequired(flag) => write!(f, "missing required flag --{flag}"),
        }
    }
}

impl std::error::Error for CliError {}

impl Args {
    pub fn parse(argv: impl IntoIterator<Item = String>) -> Result<Args, CliError> {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(flag) = tok.strip_prefix("--") {
                if let Some((k, v)) = flag.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else {
                    // `--key value` unless next token is another flag -> switch
                    match it.peek() {
                        Some(next) if !next.starts_with("--") => {
                            let v = it.next().unwrap();
                            out.flags.insert(flag.to_string(), v);
                        }
                        _ => out.switches.push(flag.to_string()),
                    }
                }
            } else if out.command.is_none() {
                out.command = Some(tok);
            } else {
                out.positional.push(tok);
            }
        }
        Ok(out)
    }

    pub fn from_env() -> Result<Args, CliError> {
        Self::parse(std::env::args().skip(1))
    }

    pub fn str_opt(&self, key: &str) -> Option<&str> {
        self.seen.borrow_mut().insert(key.to_string());
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.str_opt(key).unwrap_or(default).to_string()
    }

    pub fn str_required(&self, key: &str) -> Result<String, CliError> {
        self.str_opt(key)
            .map(|s| s.to_string())
            .ok_or_else(|| CliError::MissingRequired(key.to_string()))
    }

    pub fn parse_opt<T: std::str::FromStr>(&self, key: &str) -> Result<Option<T>, CliError>
    where
        T::Err: std::fmt::Display,
    {
        match self.str_opt(key) {
            None => Ok(None),
            Some(s) => s.parse::<T>().map(Some).map_err(|e| CliError::Invalid {
                flag: key.to_string(),
                value: s.to_string(),
                why: e.to_string(),
            }),
        }
    }

    pub fn parse_or<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T, CliError>
    where
        T::Err: std::fmt::Display,
    {
        Ok(self.parse_opt(key)?.unwrap_or(default))
    }

    pub fn switch(&self, key: &str) -> bool {
        self.seen.borrow_mut().insert(key.to_string());
        self.switches.iter().any(|s| s == key)
    }

    /// Call after all accessors: errors if the user passed flags nothing read.
    pub fn finish(&self) -> Result<(), CliError> {
        let seen = self.seen.borrow();
        let unknown: Vec<String> = self
            .flags
            .keys()
            .chain(self.switches.iter())
            .filter(|k| !seen.contains(*k))
            .map(|k| format!("--{k}"))
            .collect();
        if unknown.is_empty() {
            Ok(())
        } else {
            Err(CliError::Unknown(unknown.join(", ")))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn command_and_flags() {
        let a = parse("medoid --dataset rnaseq --n 2000 --verbose");
        assert_eq!(a.command.as_deref(), Some("medoid"));
        assert_eq!(a.str_opt("dataset"), Some("rnaseq"));
        assert_eq!(a.parse_or("n", 0usize).unwrap(), 2000);
        assert!(a.switch("verbose"));
        assert!(a.finish().is_ok());
    }

    #[test]
    fn equals_form() {
        let a = parse("repro --exp=table1 --trials=50");
        assert_eq!(a.str_opt("exp"), Some("table1"));
        assert_eq!(a.parse_or("trials", 0u32).unwrap(), 50);
    }

    #[test]
    fn trailing_switch() {
        let a = parse("stats --fast");
        assert!(a.switch("fast"));
    }

    #[test]
    fn unknown_flag_detected() {
        let a = parse("medoid --typo 3");
        let _ = a.str_opt("dataset");
        assert!(matches!(a.finish(), Err(CliError::Unknown(_))));
    }

    #[test]
    fn invalid_value() {
        let a = parse("x --n abc");
        assert!(matches!(
            a.parse_opt::<usize>("n"),
            Err(CliError::Invalid { .. })
        ));
    }

    #[test]
    fn positional_args() {
        let a = parse("load file1.npy file2.npy");
        assert_eq!(a.positional, vec!["file1.npy", "file2.npy"]);
    }

    #[test]
    fn negative_number_as_value() {
        // `--key value` where value starts with '-' but not '--'
        let a = parse("x --offset -3");
        assert_eq!(a.parse_or("offset", 0i32).unwrap(), -3);
    }
}
