//! Micro-benchmark harness (criterion is outside the offline closure).
//!
//! `cargo bench` runs the `[[bench]]` binaries with `harness = false`; each
//! uses this module: warmup, timed iterations until a wall-clock budget,
//! mean / median / p10 / p90, optional throughput, and machine-readable JSON
//! lines appended to `target/bench_results.jsonl` so EXPERIMENTS.md entries
//! are regenerable.

use std::time::{Duration, Instant};

use crate::util::json::Value;

#[derive(Clone, Debug)]
pub struct Stats {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub median: Duration,
    pub p10: Duration,
    pub p90: Duration,
    /// Optional items/sec (set via [`Bencher::throughput`]).
    pub throughput: Option<f64>,
}

pub struct Bencher {
    /// Max wall-clock budget for one benchmark (default 3s, env
    /// `CORRSH_BENCH_SECS` overrides).
    budget: Duration,
    warmup: Duration,
    min_iters: usize,
    results: Vec<Stats>,
    group: String,
}

impl Default for Bencher {
    fn default() -> Self {
        Self::new()
    }
}

fn fmt_dur(d: Duration) -> String {
    let s = d.as_secs_f64();
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} µs", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

impl Bencher {
    pub fn new() -> Self {
        let secs = std::env::var("CORRSH_BENCH_SECS")
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .unwrap_or(3.0);
        Bencher {
            budget: Duration::from_secs_f64(secs),
            warmup: Duration::from_secs_f64((secs / 10.0).min(0.5)),
            min_iters: 5,
            results: Vec::new(),
            group: String::new(),
        }
    }

    pub fn group(&mut self, name: &str) -> &mut Self {
        self.group = name.to_string();
        println!("\n== {name} ==");
        self
    }

    /// Benchmark `f`, which performs one logical iteration and returns a
    /// value (kept opaque to stop the optimizer from deleting the work).
    pub fn bench<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> &mut Self {
        self.bench_with_throughput(name, None, |_| f())
    }

    /// Benchmark with a known per-iteration item count (reports items/sec).
    pub fn bench_items<T>(
        &mut self,
        name: &str,
        items: u64,
        mut f: impl FnMut() -> T,
    ) -> &mut Self {
        self.bench_with_throughput(name, Some(items), |_| f())
    }

    fn bench_with_throughput<T>(
        &mut self,
        name: &str,
        items: Option<u64>,
        mut f: impl FnMut(usize) -> T,
    ) -> &mut Self {
        // Warmup
        let w0 = Instant::now();
        let mut iters_hint = 0usize;
        while w0.elapsed() < self.warmup || iters_hint < 1 {
            std::hint::black_box(f(iters_hint));
            iters_hint += 1;
        }
        // Timed
        let mut samples: Vec<Duration> = Vec::new();
        let t0 = Instant::now();
        let mut i = 0usize;
        while (t0.elapsed() < self.budget && samples.len() < 10_000)
            || samples.len() < self.min_iters
        {
            let s = Instant::now();
            std::hint::black_box(f(i));
            samples.push(s.elapsed());
            i += 1;
        }
        samples.sort_unstable();
        let n = samples.len();
        let total: Duration = samples.iter().sum();
        let mean = total / n as u32;
        let median = samples[n / 2];
        let p10 = samples[n / 10];
        let p90 = samples[(n * 9) / 10];
        let throughput = items.map(|it| it as f64 / mean.as_secs_f64());
        let full = if self.group.is_empty() {
            name.to_string()
        } else {
            format!("{}/{}", self.group, name)
        };
        let stats = Stats { name: full.clone(), iters: n, mean, median, p10, p90, throughput };
        match throughput {
            Some(tp) => println!(
                "{full:<52} time: [{} {} {}]  thrpt: {:.3e} items/s ({} iters)",
                fmt_dur(p10),
                fmt_dur(median),
                fmt_dur(p90),
                tp,
                n
            ),
            None => println!(
                "{full:<52} time: [{} {} {}] ({} iters)",
                fmt_dur(p10),
                fmt_dur(median),
                fmt_dur(p90),
                n
            ),
        }
        self.results.push(stats);
        self
    }

    /// Mean seconds of the most recently finished benchmark — lets a bench
    /// binary derive ratios (e.g. old-path vs new-path speedup) and record
    /// them via [`Bencher::record_metric`] without re-measuring.
    pub fn last_mean_s(&self) -> Option<f64> {
        self.results.last().map(|s| s.mean.as_secs_f64())
    }

    /// Mean seconds of the most recent result whose full name ends with
    /// `suffix` — lets the PGO stage of `benches/engine.rs` look up the
    /// timings it just produced by row name instead of call order.
    pub fn mean_s_of(&self, suffix: &str) -> Option<f64> {
        self.results
            .iter()
            .rev()
            .find(|s| s.name.ends_with(suffix))
            .map(|s| s.mean.as_secs_f64())
    }

    /// Record a pre-measured scalar (e.g. pulls/arm from an experiment run)
    /// so it lands in the JSONL alongside timings.
    pub fn record_metric(&mut self, name: &str, value: f64, unit: &str) -> &mut Self {
        let full = if self.group.is_empty() {
            name.to_string()
        } else {
            format!("{}/{}", self.group, name)
        };
        println!("{full:<52} {value:.4} {unit}");
        self.results.push(Stats {
            name: format!("{full} [{unit}]"),
            iters: 1,
            mean: Duration::from_secs_f64(value.max(0.0)),
            median: Duration::ZERO,
            p10: Duration::ZERO,
            p90: Duration::ZERO,
            throughput: Some(value),
        });
        self
    }

    fn stats_value(s: &Stats) -> Value {
        Value::from_pairs(vec![
            ("name", s.name.as_str().into()),
            ("iters", s.iters.into()),
            ("mean_s", s.mean.as_secs_f64().into()),
            ("median_s", s.median.as_secs_f64().into()),
            ("p10_s", s.p10.as_secs_f64().into()),
            ("p90_s", s.p90.as_secs_f64().into()),
            (
                "throughput",
                s.throughput.map(Value::from).unwrap_or(Value::Null),
            ),
        ])
    }

    /// Append all results to `target/bench_results.jsonl`.
    pub fn write_jsonl(&self) {
        let path = std::path::Path::new("target").join("bench_results.jsonl");
        let _ = std::fs::create_dir_all("target");
        let mut lines = String::new();
        for s in &self.results {
            lines.push_str(&crate::util::json::to_string(&Self::stats_value(s)));
            lines.push('\n');
        }
        use std::io::Write;
        if let Ok(mut f) = std::fs::OpenOptions::new().create(true).append(true).open(path) {
            let _ = f.write_all(lines.as_bytes());
        }
    }

    /// Write a machine-readable summary of this run to `BENCH_<tag>.json` in
    /// the working directory (the package root under `cargo bench`).
    ///
    /// One file per bench binary, overwritten on each run: the perf-baseline
    /// artifact CI uploads so perf-focused PRs have a trajectory to compare
    /// against. Schema: `{"bench", "schema_version", "results": [Stats...]}`
    /// with durations in seconds (see [`Stats`]).
    pub fn write_bench_json(&self, tag: &str) {
        let doc = Value::from_pairs(vec![
            ("bench", tag.into()),
            ("schema_version", 1usize.into()),
            (
                "results",
                Value::Array(self.results.iter().map(Self::stats_value).collect()),
            ),
        ]);
        let path = format!("BENCH_{tag}.json");
        match std::fs::write(&path, crate::util::json::to_string(&doc) + "\n") {
            Ok(()) => println!("[bench json] {path}"),
            Err(e) => eprintln!("warn: could not write {path}: {e}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_reports() {
        std::env::set_var("CORRSH_BENCH_SECS", "0.05");
        let mut b = Bencher::new();
        b.group("unit").bench("noop", || 1 + 1);
        b.bench_items("sum", 1000, || (0..1000u64).sum::<u64>());
        assert_eq!(b.results.len(), 2);
        assert!(b.results[0].iters >= 5);
        assert!(b.results[1].throughput.unwrap() > 0.0);
        assert_eq!(b.last_mean_s(), Some(b.results[1].mean.as_secs_f64()));
        assert_eq!(b.mean_s_of("unit/noop"), Some(b.results[0].mean.as_secs_f64()));
        assert_eq!(b.mean_s_of("no-such-row"), None);
        std::env::remove_var("CORRSH_BENCH_SECS");
    }

    #[test]
    fn bench_json_schema() {
        let s = Stats {
            name: "group/case".into(),
            iters: 3,
            mean: Duration::from_millis(2),
            median: Duration::from_millis(2),
            p10: Duration::from_millis(1),
            p90: Duration::from_millis(3),
            throughput: Some(10.0),
        };
        let v = Bencher::stats_value(&s);
        assert_eq!(v.get("name").as_str(), Some("group/case"));
        assert_eq!(v.get("iters").as_usize(), Some(3));
        assert!((v.get("mean_s").as_f64().unwrap() - 0.002).abs() < 1e-12);
        assert_eq!(v.get("throughput").as_f64(), Some(10.0));
        // serialized form round-trips through the in-tree parser
        let text = crate::util::json::to_string(&v);
        assert_eq!(crate::util::json::parse(&text).unwrap(), v);
    }

    #[test]
    fn fmt_dur_scales() {
        assert!(fmt_dur(Duration::from_nanos(12)).contains("ns"));
        assert!(fmt_dur(Duration::from_micros(12)).contains("µs"));
        assert!(fmt_dur(Duration::from_millis(12)).contains("ms"));
        assert!(fmt_dur(Duration::from_secs(2)).contains(" s"));
    }
}
