//! Property-test harness (proptest is outside the offline closure).
//!
//! Three pieces shared by every property test in the crate (DESIGN.md §8):
//!
//! * **Seeded case generation** — [`check`]/[`check_shrink`] run a property
//!   over many cases, each drawn from a per-case seeded RNG, so a failure
//!   names the exact seed and [`replay`] reproduces it.
//! * **Shrink-on-fail** — [`check_shrink`] takes a caller-supplied shrinker
//!   (candidate smaller inputs) and greedily minimizes the failing case
//!   before panicking, re-running the property with the *same* per-case
//!   RNG so data generated inside the property stays deterministic.
//! * **`cases_from_env`** — one knob (`CORRSH_PROPTEST_CASES`) scales every
//!   property's case count between CI (fast) and local soak runs.

use crate::util::rng::Rng;

/// Per-property case count: env `CORRSH_PROPTEST_CASES`, else `default`.
pub fn cases_from_env(default: usize) -> usize {
    std::env::var("CORRSH_PROPTEST_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(default)
}

/// [`cases_from_env`] with the crate-wide default of 128.
pub fn default_cases() -> usize {
    cases_from_env(128)
}

/// Run `prop` on `cases` random inputs drawn by `gen`.
///
/// `gen` receives a per-case seeded RNG; `prop` returns `Err(reason)` to
/// fail. Panics with case debug + seed on the first failure. (No shrinking
/// — use [`check_shrink`] when a smaller counterexample helps.)
pub fn check<T: std::fmt::Debug + Clone>(
    name: &str,
    cases: usize,
    gen: impl Fn(&mut Rng) -> T,
    prop: impl Fn(&T, &mut Rng) -> Result<(), String>,
) {
    check_shrink(name, cases, gen, |_| Vec::new(), prop);
}

/// Maximum property re-runs spent minimizing one failure.
const SHRINK_BUDGET: usize = 256;

/// [`check`] plus shrink-on-fail: on the first failing case, `shrink`
/// proposes smaller candidate inputs; the first candidate that still fails
/// becomes the new case, repeating (greedy descent, bounded by
/// [`SHRINK_BUDGET`] re-runs) until no candidate fails. The panic reports
/// both the original and the minimized case.
///
/// Every re-run uses the failing case's per-case RNG seed, so properties
/// that generate data internally shrink deterministically.
pub fn check_shrink<T: std::fmt::Debug + Clone>(
    name: &str,
    cases: usize,
    gen: impl Fn(&mut Rng) -> T,
    shrink: impl Fn(&T) -> Vec<T>,
    prop: impl Fn(&T, &mut Rng) -> Result<(), String>,
) {
    let base_seed: u64 = std::env::var("CORRSH_PROPTEST_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FFEE);
    for case in 0..cases {
        let seed = base_seed ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut grng = Rng::seeded(seed);
        let input = gen(&mut grng);
        let run = |x: &T| prop(x, &mut Rng::seeded(seed ^ 0xABCD));
        let Err(why) = run(&input) else { continue };
        // Greedy shrink: accept the first failing candidate each round.
        let mut best = input.clone();
        let mut best_why = why.clone();
        let mut spent = 0usize;
        'outer: while spent < SHRINK_BUDGET {
            for cand in shrink(&best) {
                spent += 1;
                if let Err(w) = run(&cand) {
                    best = cand;
                    best_why = w;
                    continue 'outer;
                }
                if spent >= SHRINK_BUDGET {
                    break;
                }
            }
            break;
        }
        panic!(
            "property `{name}` failed on case {case} (seed {seed:#x}):\n  \
             input: {input:?}\n  reason: {why}\n  \
             shrunk: {best:?}\n  shrunk reason: {best_why}\n  \
             replay: CORRSH_PROPTEST_SEED={base_seed} (case {case})"
        );
    }
}

/// Shrink candidates for a sized knob: step toward `lo` by halving the
/// distance, then by one. The building block most tuple shrinkers want.
pub fn shrink_usize(x: usize, lo: usize) -> Vec<usize> {
    let mut out = Vec::new();
    if x > lo {
        out.push(lo);
        let mid = lo + (x - lo) / 2;
        if mid != lo && mid != x {
            out.push(mid);
        }
        out.push(x - 1);
    }
    out.dedup();
    out
}

/// Re-run a single failing case by seed (debug helper).
pub fn replay<T: std::fmt::Debug>(
    seed: u64,
    gen: impl Fn(&mut Rng) -> T,
    prop: impl Fn(&T, &mut Rng) -> Result<(), String>,
) -> Result<(), String> {
    let mut grng = Rng::seeded(seed);
    let input = gen(&mut grng);
    let mut prng = Rng::seeded(seed ^ 0xABCD);
    prop(&input, &mut prng)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("add-commutes", 64, |r| (r.below(1000), r.below(1000)), |&(a, b), _| {
            if a + b == b + a {
                Ok(())
            } else {
                Err("math broke".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property `always-fails` failed")]
    fn failing_property_panics_with_case() {
        check("always-fails", 8, |r| r.below(10), |_, _| Err("nope".into()));
    }

    #[test]
    #[should_panic(expected = "shrunk: 10")]
    fn shrinker_minimizes_failures() {
        // property fails for x >= 10; generated x is large; the greedy
        // shrinker must walk it down to exactly the boundary.
        check_shrink(
            "shrinks-to-boundary",
            64,
            |r| 500 + r.below(1000),
            |&x| shrink_usize(x, 0),
            |&x, _| if x >= 10 { Err(format!("{x} too big")) } else { Ok(()) },
        );
    }

    #[test]
    fn shrink_candidates_descend() {
        assert_eq!(shrink_usize(5, 5), Vec::<usize>::new());
        assert_eq!(shrink_usize(6, 5), vec![5]);
        let c = shrink_usize(100, 1);
        assert!(c.contains(&1) && c.contains(&50) && c.contains(&99));
        for &x in &c {
            assert!(x < 100);
        }
    }

    #[test]
    fn cases_from_env_defaults() {
        // (env may be set by CI; only check the fallback contract)
        if std::env::var("CORRSH_PROPTEST_CASES").is_err() {
            assert_eq!(cases_from_env(7), 7);
            assert_eq!(default_cases(), 128);
        } else {
            assert_eq!(cases_from_env(7), default_cases());
        }
    }

    #[test]
    fn replay_reproduces() {
        // find a failing seed then confirm replay fails identically
        let gen = |r: &mut Rng| r.below(100);
        let prop = |x: &usize, _: &mut Rng| if *x % 2 == 0 { Err("even".into()) } else { Ok(()) };
        let mut failing = None;
        for case in 0..64u64 {
            let seed = 0xC0FFEE ^ case.wrapping_mul(0x9E3779B97F4A7C15);
            if replay(seed, gen, prop).is_err() {
                failing = Some(seed);
                break;
            }
        }
        let seed = failing.expect("some even draw in 64 cases");
        assert!(replay(seed, gen, prop).is_err());
        assert!(replay(seed, gen, prop).is_err(), "replay must be deterministic");
    }
}
