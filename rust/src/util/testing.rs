//! Property-testing loop (proptest is outside the offline closure).
//!
//! [`check`] runs a property over many randomly generated cases; on failure
//! it panics with the case's `Debug` and the per-case seed so the exact case
//! is reproducible with [`replay`]. Used across the crate for the
//! coordinator/batcher/state invariants DESIGN.md §8 calls out.

use crate::util::rng::Rng;

/// Number of cases per property: env `CORRSH_PROPTEST_CASES` or 128.
pub fn default_cases() -> usize {
    std::env::var("CORRSH_PROPTEST_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(128)
}

/// Run `prop` on `cases` random inputs drawn by `gen`.
///
/// `gen` receives a per-case seeded RNG; `prop` returns `Err(reason)` to
/// fail. Panics with case debug + seed on the first failure.
pub fn check<T: std::fmt::Debug>(
    name: &str,
    cases: usize,
    gen: impl Fn(&mut Rng) -> T,
    prop: impl Fn(&T, &mut Rng) -> Result<(), String>,
) {
    let base_seed: u64 = std::env::var("CORRSH_PROPTEST_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FFEE);
    for case in 0..cases {
        let seed = base_seed ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut grng = Rng::seeded(seed);
        let input = gen(&mut grng);
        let mut prng = Rng::seeded(seed ^ 0xABCD);
        if let Err(why) = prop(&input, &mut prng) {
            panic!(
                "property `{name}` failed on case {case} (seed {seed:#x}):\n  \
                 input: {input:?}\n  reason: {why}\n  \
                 replay: CORRSH_PROPTEST_SEED={base_seed} (case {case})"
            );
        }
    }
}

/// Re-run a single failing case by seed (debug helper).
pub fn replay<T: std::fmt::Debug>(
    seed: u64,
    gen: impl Fn(&mut Rng) -> T,
    prop: impl Fn(&T, &mut Rng) -> Result<(), String>,
) -> Result<(), String> {
    let mut grng = Rng::seeded(seed);
    let input = gen(&mut grng);
    let mut prng = Rng::seeded(seed ^ 0xABCD);
    prop(&input, &mut prng)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("add-commutes", 64, |r| (r.below(1000), r.below(1000)), |&(a, b), _| {
            if a + b == b + a {
                Ok(())
            } else {
                Err("math broke".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property `always-fails` failed")]
    fn failing_property_panics_with_case() {
        check("always-fails", 8, |r| r.below(10), |_, _| Err("nope".into()));
    }

    #[test]
    fn replay_reproduces() {
        // find a failing seed then confirm replay fails identically
        let gen = |r: &mut Rng| r.below(100);
        let prop = |x: &usize, _: &mut Rng| if *x % 2 == 0 { Err("even".into()) } else { Ok(()) };
        let mut failing = None;
        for case in 0..64u64 {
            let seed = 0xC0FFEE ^ case.wrapping_mul(0x9E3779B97F4A7C15);
            if replay(seed, gen, prop).is_err() {
                failing = Some(seed);
                break;
            }
        }
        let seed = failing.expect("some even draw in 64 cases");
        assert!(replay(seed, gen, prop).is_err());
        assert!(replay(seed, gen, prop).is_err(), "replay must be deterministic");
    }
}
