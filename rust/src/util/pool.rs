//! Persistent work-stealing worker pool.
//!
//! The native engine's `pull_block` runs once per halving round — ⌈log₂ n⌉
//! times per medoid query — and under sustained traffic the old
//! `std::thread::scope` implementation paid a full OS thread spawn + join
//! per call. This pool keeps workers alive for the process lifetime and
//! turns each parallel call into one queue push: the same chunk list /
//! atomic-cursor work-stealing design as before, minus the per-call thread
//! churn. `util::threads` keeps its public API as thin shims over
//! [`global()`].
//!
//! Design invariants:
//!
//! * **The submitter always participates.** `run` drives the job with the
//!   calling thread too, so a job completes even when every worker is busy
//!   (or the pool has zero workers), and nested submission — an engine
//!   `pull_block` inside a server executor job inside a `parallel_map` —
//!   can never deadlock: the innermost submitter just executes its own
//!   chunks serially in the worst case.
//! * **Chunks run exactly once.** The atomic cursor dispenses each chunk
//!   index to exactly one thread, so results are identical regardless of
//!   worker count or interleaving (the determinism the
//!   `parallel_matches_serial` tests pin down).
//! * **Panics propagate.** A panicking chunk is caught on the worker,
//!   recorded, and re-thrown on the submitting thread after the job drains;
//!   the worker itself survives for the next job.

use std::collections::VecDeque;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

/// One injected parallel call: `task(i)` executes chunk `i`.
///
/// `task` points at a closure on the submitting thread's stack with its
/// lifetime erased; `run` does not return until every chunk has finished
/// executing, which is what makes the erasure sound (workers never
/// dereference `task` except inside a claimed chunk, and all claimed chunks
/// complete before `run` returns).
struct Job {
    task: *const (dyn Fn(usize) + Sync),
    n_chunks: usize,
    /// Workers (beyond the submitter) allowed to join; joins happen under
    /// the queue lock, so the cap is exact.
    max_helpers: usize,
    helpers: AtomicUsize,
    cursor: AtomicUsize,
    completed: AtomicUsize,
    /// First panic payload observed while running a chunk.
    panic: Mutex<Option<Box<dyn std::any::Any + Send>>>,
    done: Mutex<bool>,
    done_cv: Condvar,
}

// SAFETY: `task` is only dereferenced while the submitting thread is blocked
// in `WorkerPool::run` (see the struct docs); the pointee is `Sync`.
unsafe impl Send for Job {}
unsafe impl Sync for Job {}

impl Job {
    /// Claim and execute chunks until the cursor is exhausted.
    fn work(&self) {
        loop {
            let i = self.cursor.fetch_add(1, Ordering::Relaxed);
            if i >= self.n_chunks {
                break;
            }
            // SAFETY: see the struct-level invariant.
            let task = unsafe { &*self.task };
            if let Err(payload) = catch_unwind(AssertUnwindSafe(|| task(i))) {
                let mut slot = self.panic.lock().unwrap();
                if slot.is_none() {
                    *slot = Some(payload);
                }
            }
            if self.completed.fetch_add(1, Ordering::AcqRel) + 1 == self.n_chunks {
                let mut done = self.done.lock().unwrap();
                *done = true;
                self.done_cv.notify_all();
            }
        }
    }

    fn exhausted(&self) -> bool {
        self.cursor.load(Ordering::Relaxed) >= self.n_chunks
    }
}

struct QueueState {
    jobs: VecDeque<Arc<Job>>,
    shutdown: bool,
}

struct Shared {
    queue: Mutex<QueueState>,
    available: Condvar,
}

/// A fixed set of long-lived worker threads executing injected [`Job`]s.
pub struct WorkerPool {
    shared: Arc<Shared>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl WorkerPool {
    /// Spawn `workers` persistent threads. Zero is valid: every `run` then
    /// executes entirely on the submitting thread.
    pub fn new(workers: usize) -> Self {
        let shared = Arc::new(Shared {
            queue: Mutex::new(QueueState { jobs: VecDeque::new(), shutdown: false }),
            available: Condvar::new(),
        });
        let handles = (0..workers)
            .map(|i| {
                let shared = shared.clone();
                std::thread::Builder::new()
                    .name(format!("corrsh-pool-{i}"))
                    .spawn(move || worker_loop(shared))
                    .expect("spawn pool worker")
            })
            .collect();
        WorkerPool { shared, workers: handles }
    }

    pub fn workers(&self) -> usize {
        self.workers.len()
    }

    /// Execute `task(i)` for every `i in 0..n_chunks`, blocking until all
    /// chunks have run. At most `max_threads` threads (submitter included)
    /// touch the job. Panics from `task` are re-raised here.
    pub fn run(&self, n_chunks: usize, max_threads: usize, task: &(dyn Fn(usize) + Sync)) {
        if n_chunks == 0 {
            return;
        }
        // SAFETY: lifetime erasure only — `run` blocks until every chunk
        // has completed, so the reference cannot dangle while dereferenced.
        // (A plain `as` cast cannot lengthen the trait-object lifetime
        // bound, hence the transmute.)
        #[allow(clippy::useless_transmute, clippy::transmutes_expressible_as_ptr_casts)]
        let task: *const (dyn Fn(usize) + Sync) = unsafe {
            std::mem::transmute::<&(dyn Fn(usize) + Sync), *const (dyn Fn(usize) + Sync)>(task)
        };
        let job = Arc::new(Job {
            task,
            n_chunks,
            max_helpers: max_threads.saturating_sub(1).min(self.workers.len()),
            helpers: AtomicUsize::new(0),
            cursor: AtomicUsize::new(0),
            completed: AtomicUsize::new(0),
            panic: Mutex::new(None),
            done: Mutex::new(false),
            done_cv: Condvar::new(),
        });
        let advertised = job.max_helpers > 0 && n_chunks > 1;
        if advertised {
            self.shared.queue.lock().unwrap().jobs.push_back(job.clone());
            self.shared.available.notify_all();
        }
        job.work();
        // Wait for helpers still inside chunks they claimed.
        {
            let mut done = job.done.lock().unwrap();
            while !*done {
                done = job.done_cv.wait(done).unwrap();
            }
        }
        if advertised {
            // Drop the (now exhausted) job from the queue if no worker did.
            let mut q = self.shared.queue.lock().unwrap();
            q.jobs.retain(|j| !Arc::ptr_eq(j, &job));
        }
        if let Some(payload) = job.panic.lock().unwrap().take() {
            resume_unwind(payload);
        }
    }

    /// Stop accepting work and join all workers. Idempotent; also runs on
    /// drop. In-flight `run` calls still complete (their submitters drive
    /// them to the end regardless of worker availability).
    pub fn shutdown(&mut self) {
        self.shared.queue.lock().unwrap().shutdown = true;
        self.shared.available.notify_all();
        for handle in self.workers.drain(..) {
            let _ = handle.join();
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn worker_loop(shared: Arc<Shared>) {
    let mut q = shared.queue.lock().unwrap();
    loop {
        if q.shutdown {
            return;
        }
        // Discard stale fronts: exhausted jobs, or jobs at their helper cap.
        while let Some(front) = q.jobs.front() {
            let full = front.helpers.load(Ordering::Relaxed) >= front.max_helpers;
            if front.exhausted() || full {
                q.jobs.pop_front();
            } else {
                break;
            }
        }
        match q.jobs.front().cloned() {
            Some(job) => {
                job.helpers.fetch_add(1, Ordering::Relaxed);
                drop(q);
                job.work();
                q = shared.queue.lock().unwrap();
            }
            None => q = shared.available.wait(q).unwrap(),
        }
    }
}

static GLOBAL: OnceLock<WorkerPool> = OnceLock::new();

/// The process-global pool: `default_threads() - 1` workers (the submitting
/// thread is the final participant), created on first use. `CORRSH_THREADS`
/// therefore still bounds total parallelism exactly as before.
pub fn global() -> &'static WorkerPool {
    GLOBAL.get_or_init(|| {
        WorkerPool::new(crate::util::threads::default_threads().saturating_sub(1))
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn runs_every_chunk_exactly_once() {
        let pool = WorkerPool::new(3);
        let hits: Vec<AtomicU64> = (0..1000).map(|_| AtomicU64::new(0)).collect();
        pool.run(hits.len(), 8, &|i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        for (i, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::Relaxed), 1, "chunk {i}");
        }
    }

    #[test]
    fn zero_worker_pool_degrades_to_serial() {
        let pool = WorkerPool::new(0);
        let sum = AtomicU64::new(0);
        pool.run(100, 4, &|i| {
            sum.fetch_add(i as u64, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 4950);
    }

    #[test]
    fn pool_is_reusable_across_many_jobs() {
        let pool = WorkerPool::new(2);
        for round in 0..50u64 {
            let acc = AtomicU64::new(0);
            pool.run(17, 4, &|i| {
                acc.fetch_add(round + i as u64, Ordering::Relaxed);
            });
            assert_eq!(acc.load(Ordering::Relaxed), 17 * round + 136);
        }
    }

    #[test]
    fn nested_submission_does_not_deadlock() {
        let pool = WorkerPool::new(2);
        let total = AtomicU64::new(0);
        pool.run(4, 4, &|_| {
            // Submit a child job from inside a job (the engine-inside-
            // executor shape). The submitter drives it even when all
            // workers are busy with the outer job.
            pool.run(8, 4, &|j| {
                total.fetch_add(j as u64 + 1, Ordering::Relaxed);
            });
        });
        assert_eq!(total.load(Ordering::Relaxed), 4 * 36);
    }

    #[test]
    fn panics_propagate_and_pool_survives() {
        let pool = WorkerPool::new(2);
        let result = std::panic::catch_unwind(AssertUnwindSafe(|| {
            pool.run(8, 4, &|i| {
                if i == 5 {
                    panic!("chunk 5 exploded");
                }
            });
        }));
        assert!(result.is_err(), "panic must reach the submitter");
        // ...and the pool still works afterwards.
        let ok = AtomicU64::new(0);
        pool.run(8, 4, &|_| {
            ok.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(ok.load(Ordering::Relaxed), 8);
    }

    #[test]
    fn shutdown_joins_workers() {
        let mut pool = WorkerPool::new(3);
        let acc = AtomicU64::new(0);
        pool.run(32, 4, &|_| {
            acc.fetch_add(1, Ordering::Relaxed);
        });
        pool.shutdown();
        assert_eq!(pool.workers(), 0);
        // Post-shutdown runs still complete (on the submitter).
        pool.run(8, 4, &|_| {
            acc.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(acc.load(Ordering::Relaxed), 40);
        pool.shutdown(); // idempotent
    }

    #[test]
    fn global_pool_exists_and_runs() {
        let acc = AtomicU64::new(0);
        global().run(64, 8, &|i| {
            acc.fetch_add(i as u64, Ordering::Relaxed);
        });
        assert_eq!(acc.load(Ordering::Relaxed), 2016);
    }
}
