//! NumPy `.npy` (format version 1.0) reader/writer for f32/f64 C-order
//! matrices — the dataset interchange format between the python layer
//! (generators, notebooks) and the rust runtime.

use std::io::{Read, Write};
use std::path::Path;

use crate::bail;
use crate::util::error::{Context, Result};

const MAGIC: &[u8; 6] = b"\x93NUMPY";

/// A dense row-major f32 matrix loaded from / written to `.npy`.
#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Matrix {
    pub fn new(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(rows * cols, data.len());
        Matrix { rows, cols, data }
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }
}

fn parse_header(h: &str) -> Result<(String, bool, Vec<usize>)> {
    // Python dict literal: {'descr': '<f4', 'fortran_order': False, 'shape': (3, 4), }
    let get = |key: &str| -> Result<&str> {
        let pat = format!("'{key}':");
        let at = h.find(&pat).with_context(|| format!("missing {key} in npy header"))?;
        Ok(h[at + pat.len()..].trim_start())
    };
    let descr_rest = get("descr")?;
    let descr = descr_rest
        .strip_prefix('\'')
        .and_then(|s| s.split('\'').next())
        .context("bad descr")?
        .to_string();
    let fortran = get("fortran_order")?.starts_with("True");
    let shape_rest = get("shape")?;
    let inner = shape_rest
        .strip_prefix('(')
        .and_then(|s| s.split(')').next())
        .context("bad shape")?;
    let dims: Vec<usize> = inner
        .split(',')
        .map(|t| t.trim())
        .filter(|t| !t.is_empty())
        .map(|t| t.parse::<usize>().context("bad shape dim"))
        .collect::<Result<_>>()?;
    Ok((descr, fortran, dims))
}

/// Read a 1-D or 2-D f32/f64 little-endian `.npy` file as a [`Matrix`]
/// (1-D becomes a single row).
pub fn read(path: impl AsRef<Path>) -> Result<Matrix> {
    let mut f = std::fs::File::open(&path)
        .with_context(|| format!("open {:?}", path.as_ref()))?;
    let mut magic = [0u8; 8];
    f.read_exact(&mut magic).context("npy magic")?;
    if &magic[..6] != MAGIC {
        bail!("not an npy file: bad magic");
    }
    let major = magic[6];
    if major != 1 {
        bail!("unsupported npy version {major}.x (only 1.0)");
    }
    let mut lenb = [0u8; 2];
    f.read_exact(&mut lenb)?;
    let hlen = u16::from_le_bytes(lenb) as usize;
    let mut hdr = vec![0u8; hlen];
    f.read_exact(&mut hdr)?;
    let hdr = String::from_utf8(hdr).context("npy header utf8")?;
    let (descr, fortran, dims) = parse_header(&hdr)?;
    if fortran {
        bail!("fortran-order npy unsupported (write C-order from numpy)");
    }
    let (rows, cols) = match dims.len() {
        1 => (1, dims[0]),
        2 => (dims[0], dims[1]),
        d => bail!("npy ndim {d} unsupported (want 1 or 2)"),
    };
    let count = rows * cols;
    let mut raw = Vec::new();
    f.read_to_end(&mut raw)?;
    let data: Vec<f32> = match descr.as_str() {
        "<f4" | "|f4" => {
            if raw.len() < count * 4 {
                bail!("npy truncated: want {} bytes, have {}", count * 4, raw.len());
            }
            raw.chunks_exact(4)
                .take(count)
                .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                .collect()
        }
        "<f8" => {
            if raw.len() < count * 8 {
                bail!("npy truncated");
            }
            raw.chunks_exact(8)
                .take(count)
                .map(|c| f64::from_le_bytes(c.try_into().unwrap()) as f32)
                .collect()
        }
        other => bail!("npy dtype {other} unsupported (want <f4 or <f8)"),
    };
    Ok(Matrix::new(rows, cols, data))
}

/// Write a [`Matrix`] as `<f4` C-order `.npy` v1.0.
pub fn write(path: impl AsRef<Path>, m: &Matrix) -> Result<()> {
    let mut header = format!(
        "{{'descr': '<f4', 'fortran_order': False, 'shape': ({}, {}), }}",
        m.rows, m.cols
    );
    // pad header so that magic(6)+ver(2)+len(2)+header is a multiple of 64
    let unpadded = 10 + header.len() + 1;
    let pad = (64 - unpadded % 64) % 64;
    header.extend(std::iter::repeat(' ').take(pad));
    header.push('\n');

    let mut f = std::fs::File::create(&path)
        .with_context(|| format!("create {:?}", path.as_ref()))?;
    f.write_all(MAGIC)?;
    f.write_all(&[1u8, 0u8])?;
    f.write_all(&(header.len() as u16).to_le_bytes())?;
    f.write_all(header.as_bytes())?;
    let mut buf = Vec::with_capacity(m.data.len() * 4);
    for &x in &m.data {
        buf.extend_from_slice(&x.to_le_bytes());
    }
    f.write_all(&buf)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("corrsh-npy-tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn roundtrip_2d() {
        let m = Matrix::new(3, 4, (0..12).map(|i| i as f32 * 0.5).collect());
        let p = tmp("rt2d.npy");
        write(&p, &m).unwrap();
        let back = read(&p).unwrap();
        assert_eq!(back, m);
    }

    #[test]
    fn header_is_64_aligned() {
        let m = Matrix::new(2, 2, vec![1.0; 4]);
        let p = tmp("aligned.npy");
        write(&p, &m).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        let hlen = u16::from_le_bytes([bytes[8], bytes[9]]) as usize;
        assert_eq!((10 + hlen) % 64, 0);
    }

    #[test]
    fn rejects_bad_magic() {
        let p = tmp("bad.npy");
        std::fs::write(&p, b"not numpy at all").unwrap();
        assert!(read(&p).is_err());
    }

    #[test]
    fn python_numpy_compat() {
        // Byte-level golden file: numpy 1.x/2.x writes exactly this layout
        // for np.arange(6, dtype='<f4').reshape(2,3) — verified against
        // python in CI (`python/tests/test_npy_compat.py`).
        let m = Matrix::new(2, 3, (0..6).map(|i| i as f32).collect());
        let p = tmp("compat.npy");
        write(&p, &m).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        assert_eq!(&bytes[..6], MAGIC);
        assert_eq!(&bytes[6..8], &[1, 0]);
        let hdr = String::from_utf8_lossy(&bytes[10..]).into_owned();
        assert!(hdr.contains("'descr': '<f4'"));
        assert!(hdr.contains("'shape': (2, 3)"));
    }

    #[test]
    fn reads_f64() {
        // hand-build a <f8 file
        let p = tmp("f64.npy");
        let mut header =
            "{'descr': '<f8', 'fortran_order': False, 'shape': (1, 2), }".to_string();
        let unpadded = 10 + header.len() + 1;
        header.extend(std::iter::repeat(' ').take((64 - unpadded % 64) % 64));
        header.push('\n');
        let mut bytes = Vec::new();
        bytes.extend_from_slice(MAGIC);
        bytes.extend_from_slice(&[1, 0]);
        bytes.extend_from_slice(&(header.len() as u16).to_le_bytes());
        bytes.extend_from_slice(header.as_bytes());
        bytes.extend_from_slice(&1.5f64.to_le_bytes());
        bytes.extend_from_slice(&(-2.0f64).to_le_bytes());
        std::fs::write(&p, bytes).unwrap();
        let m = read(&p).unwrap();
        assert_eq!(m.data, vec![1.5, -2.0]);
    }

    #[test]
    fn reads_1d_as_row() {
        let p = tmp("oned.npy");
        let mut header = "{'descr': '<f4', 'fortran_order': False, 'shape': (3,), }".to_string();
        let unpadded = 10 + header.len() + 1;
        header.extend(std::iter::repeat(' ').take((64 - unpadded % 64) % 64));
        header.push('\n');
        let mut bytes = Vec::new();
        bytes.extend_from_slice(MAGIC);
        bytes.extend_from_slice(&[1, 0]);
        bytes.extend_from_slice(&(header.len() as u16).to_le_bytes());
        bytes.extend_from_slice(header.as_bytes());
        for x in [1f32, 2.0, 3.0] {
            bytes.extend_from_slice(&x.to_le_bytes());
        }
        std::fs::write(&p, bytes).unwrap();
        let m = read(&p).unwrap();
        assert_eq!((m.rows, m.cols), (1, 3));
    }
}
