//! NumPy `.npy` reader/writer for f32/f64 C-order matrices — the dataset
//! interchange format between the python layer (generators, notebooks) and
//! the rust runtime.
//!
//! The reader accepts format versions 1.0–3.x: v1 carries a 2-byte header
//! length, v2/v3 a 4-byte one (v3 only changes the allowed field-name
//! encoding, which this parser never relied on). Header padding is *not*
//! assumed to land on any particular alignment — numpy ≥1.9 pads to 64
//! bytes, older writers to 16, and hand-rolled files to anything — so the
//! payload offset is always derived from the encoded header length. The
//! writer emits v1.0 with 64-byte alignment (what every modern numpy
//! produces and the mmap reader wants).

use std::fs::File;
use std::io::{Read, Write};
use std::path::Path;

use crate::bail;
use crate::util::error::{Context, Result};

const MAGIC: &[u8; 6] = b"\x93NUMPY";

/// A dense row-major f32 matrix loaded from / written to `.npy`.
#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Matrix {
    pub fn new(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(rows * cols, data.len());
        Matrix { rows, cols, data }
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }
}

fn parse_header(h: &str) -> Result<(String, bool, Vec<usize>)> {
    // Python dict literal: {'descr': '<f4', 'fortran_order': False, 'shape': (3, 4), }
    let get = |key: &str| -> Result<&str> {
        let pat = format!("'{key}':");
        let at = h.find(&pat).with_context(|| format!("missing {key} in npy header"))?;
        Ok(h[at + pat.len()..].trim_start())
    };
    let descr_rest = get("descr")?;
    let descr = descr_rest
        .strip_prefix('\'')
        .and_then(|s| s.split('\'').next())
        .context("bad descr")?
        .to_string();
    let fortran = get("fortran_order")?.starts_with("True");
    let shape_rest = get("shape")?;
    let inner = shape_rest
        .strip_prefix('(')
        .and_then(|s| s.split(')').next())
        .context("bad shape")?;
    let dims: Vec<usize> = inner
        .split(',')
        .map(|t| t.trim())
        .filter(|t| !t.is_empty())
        .map(|t| t.parse::<usize>().context("bad shape dim"))
        .collect::<Result<_>>()?;
    Ok((descr, fortran, dims))
}

/// Element type of an `.npy` payload this reader understands.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    F4,
    F8,
}

impl Dtype {
    pub fn size(&self) -> usize {
        match self {
            Dtype::F4 => 4,
            Dtype::F8 => 8,
        }
    }
}

/// Parsed `.npy` preamble: shape, dtype, and the byte offset where the
/// payload starts. Parsing the header alone is what lets the sharded store
/// register terabyte-scale shard sets without touching their payloads.
#[derive(Clone, Debug)]
pub struct Header {
    pub rows: usize,
    pub cols: usize,
    pub dtype: Dtype,
    /// Absolute byte offset of the first payload element.
    pub data_offset: u64,
}

/// Parse the magic + version + header dict from an open file positioned at
/// the start. Accepts versions 1.0 through 3.x (2-byte header length for
/// v1, 4-byte for v2/v3) and any header padding.
pub fn read_header_from(f: &mut File) -> Result<Header> {
    let mut magic = [0u8; 8];
    f.read_exact(&mut magic).context("npy magic")?;
    if &magic[..6] != MAGIC {
        bail!("not an npy file: bad magic");
    }
    let major = magic[6];
    let (hlen, pre) = match major {
        1 => {
            let mut lenb = [0u8; 2];
            f.read_exact(&mut lenb)?;
            (u16::from_le_bytes(lenb) as usize, 10usize)
        }
        2 | 3 => {
            let mut lenb = [0u8; 4];
            f.read_exact(&mut lenb)?;
            (u32::from_le_bytes(lenb) as usize, 12usize)
        }
        other => bail!("unsupported npy version {other}.x (want 1.x-3.x)"),
    };
    let mut hdr = vec![0u8; hlen];
    f.read_exact(&mut hdr).context("npy header")?;
    let hdr = String::from_utf8(hdr).context("npy header utf8")?;
    let (descr, fortran, dims) = parse_header(&hdr)?;
    if fortran {
        bail!("fortran-order npy unsupported (write C-order from numpy)");
    }
    let (rows, cols) = match dims.len() {
        1 => (1, dims[0]),
        2 => (dims[0], dims[1]),
        d => bail!("npy ndim {d} unsupported (want 1 or 2)"),
    };
    let dtype = match descr.as_str() {
        "<f4" | "|f4" => Dtype::F4,
        "<f8" => Dtype::F8,
        other => bail!("npy dtype {other} unsupported (want <f4 or <f8)"),
    };
    Ok(Header { rows, cols, dtype, data_offset: (pre + hlen) as u64 })
}

/// Parse only the preamble of an `.npy` file (shape/dtype/payload offset).
pub fn read_header(path: impl AsRef<Path>) -> Result<Header> {
    let mut f = File::open(&path).with_context(|| format!("open {:?}", path.as_ref()))?;
    read_header_from(&mut f)
}

/// Read a 1-D or 2-D f32/f64 little-endian `.npy` file (any supported
/// format version) as a [`Matrix`] (1-D becomes a single row).
pub fn read(path: impl AsRef<Path>) -> Result<Matrix> {
    let mut f = File::open(&path).with_context(|| format!("open {:?}", path.as_ref()))?;
    let h = read_header_from(&mut f)?;
    let count = h.rows * h.cols;
    let mut raw = Vec::new();
    f.read_to_end(&mut raw)?;
    if raw.len() < count * h.dtype.size() {
        bail!("npy truncated: want {} bytes, have {}", count * h.dtype.size(), raw.len());
    }
    let data: Vec<f32> = match h.dtype {
        Dtype::F4 => raw
            .chunks_exact(4)
            .take(count)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect(),
        Dtype::F8 => raw
            .chunks_exact(8)
            .take(count)
            .map(|c| f64::from_le_bytes(c.try_into().unwrap()) as f32)
            .collect(),
    };
    Ok(Matrix::new(h.rows, h.cols, data))
}

/// Write a [`Matrix`] as `<f4` C-order `.npy` v1.0.
pub fn write(path: impl AsRef<Path>, m: &Matrix) -> Result<()> {
    let mut header = format!(
        "{{'descr': '<f4', 'fortran_order': False, 'shape': ({}, {}), }}",
        m.rows, m.cols
    );
    // pad header so that magic(6)+ver(2)+len(2)+header is a multiple of 64
    let unpadded = 10 + header.len() + 1;
    let pad = (64 - unpadded % 64) % 64;
    header.extend(std::iter::repeat(' ').take(pad));
    header.push('\n');

    let mut f = std::fs::File::create(&path)
        .with_context(|| format!("create {:?}", path.as_ref()))?;
    f.write_all(MAGIC)?;
    f.write_all(&[1u8, 0u8])?;
    f.write_all(&(header.len() as u16).to_le_bytes())?;
    f.write_all(header.as_bytes())?;
    let mut buf = Vec::with_capacity(m.data.len() * 4);
    for &x in &m.data {
        buf.extend_from_slice(&x.to_le_bytes());
    }
    f.write_all(&buf)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("corrsh-npy-tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn roundtrip_2d() {
        let m = Matrix::new(3, 4, (0..12).map(|i| i as f32 * 0.5).collect());
        let p = tmp("rt2d.npy");
        write(&p, &m).unwrap();
        let back = read(&p).unwrap();
        assert_eq!(back, m);
    }

    #[test]
    fn header_is_64_aligned() {
        let m = Matrix::new(2, 2, vec![1.0; 4]);
        let p = tmp("aligned.npy");
        write(&p, &m).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        let hlen = u16::from_le_bytes([bytes[8], bytes[9]]) as usize;
        assert_eq!((10 + hlen) % 64, 0);
    }

    #[test]
    fn rejects_bad_magic() {
        let p = tmp("bad.npy");
        std::fs::write(&p, b"not numpy at all").unwrap();
        assert!(read(&p).is_err());
    }

    #[test]
    fn python_numpy_compat() {
        // Byte-level golden file: numpy 1.x/2.x writes exactly this layout
        // for np.arange(6, dtype='<f4').reshape(2,3) — verified against
        // python in CI (`python/tests/test_npy_compat.py`).
        let m = Matrix::new(2, 3, (0..6).map(|i| i as f32).collect());
        let p = tmp("compat.npy");
        write(&p, &m).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        assert_eq!(&bytes[..6], MAGIC);
        assert_eq!(&bytes[6..8], &[1, 0]);
        let hdr = String::from_utf8_lossy(&bytes[10..]).into_owned();
        assert!(hdr.contains("'descr': '<f4'"));
        assert!(hdr.contains("'shape': (2, 3)"));
    }

    #[test]
    fn reads_f64() {
        // hand-build a <f8 file
        let p = tmp("f64.npy");
        let mut header =
            "{'descr': '<f8', 'fortran_order': False, 'shape': (1, 2), }".to_string();
        let unpadded = 10 + header.len() + 1;
        header.extend(std::iter::repeat(' ').take((64 - unpadded % 64) % 64));
        header.push('\n');
        let mut bytes = Vec::new();
        bytes.extend_from_slice(MAGIC);
        bytes.extend_from_slice(&[1, 0]);
        bytes.extend_from_slice(&(header.len() as u16).to_le_bytes());
        bytes.extend_from_slice(header.as_bytes());
        bytes.extend_from_slice(&1.5f64.to_le_bytes());
        bytes.extend_from_slice(&(-2.0f64).to_le_bytes());
        std::fs::write(&p, bytes).unwrap();
        let m = read(&p).unwrap();
        assert_eq!(m.data, vec![1.5, -2.0]);
    }

    /// Build an npy byte stream with an explicit version and padding (the
    /// shapes the fixture files under `rust/tests/fixtures/` pin at the
    /// integration level).
    fn build_npy(major: u8, pad_to: usize, descr: &str, shape: &str, payload: &[u8]) -> Vec<u8> {
        let mut header =
            format!("{{'descr': '{descr}', 'fortran_order': False, 'shape': {shape}, }}");
        let pre = if major == 1 { 10 } else { 12 };
        let unpadded = pre + header.len() + 1;
        header.extend(std::iter::repeat(' ').take((pad_to - unpadded % pad_to) % pad_to));
        header.push('\n');
        let mut bytes = Vec::new();
        bytes.extend_from_slice(MAGIC);
        bytes.extend_from_slice(&[major, 0]);
        if major == 1 {
            bytes.extend_from_slice(&(header.len() as u16).to_le_bytes());
        } else {
            bytes.extend_from_slice(&(header.len() as u32).to_le_bytes());
        }
        bytes.extend_from_slice(header.as_bytes());
        bytes.extend_from_slice(payload);
        bytes
    }

    #[test]
    fn reads_v2_and_v3_headers() {
        let payload: Vec<u8> =
            [1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0].iter().flat_map(|v| v.to_le_bytes()).collect();
        for major in [2u8, 3] {
            let p = tmp(&format!("v{major}.npy"));
            std::fs::write(&p, build_npy(major, 64, "<f4", "(2, 3)", &payload)).unwrap();
            let m = read(&p).unwrap();
            assert_eq!((m.rows, m.cols), (2, 3), "v{major}");
            assert_eq!(m.data, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0], "v{major}");
            let h = read_header(&p).unwrap();
            assert_eq!(h.dtype, Dtype::F4);
            assert_eq!(h.data_offset % 64, 0, "v{major}: writer aligned to 64");
        }
        // version 4 does not exist — must be rejected, not misparsed
        let p = tmp("v4.npy");
        std::fs::write(&p, build_npy(4, 64, "<f4", "(2, 3)", &payload)).unwrap();
        assert!(read(&p).is_err());
    }

    #[test]
    fn tolerates_odd_header_padding() {
        // Old numpy (<1.9) pads v1 headers to 16 bytes, not 64; nothing in
        // the spec forbids even unpadded headers. The payload offset must
        // come from the encoded length, never an alignment assumption.
        let payload: Vec<u8> = [7.5f32, -1.25].iter().flat_map(|v| v.to_le_bytes()).collect();
        for (pad, name) in [(16usize, "pad16.npy"), (1, "pad1.npy"), (64, "pad64.npy")] {
            let p = tmp(name);
            std::fs::write(&p, build_npy(1, pad, "<f4", "(1, 2)", &payload)).unwrap();
            let m = read(&p).unwrap();
            assert_eq!(m.data, vec![7.5, -1.25], "pad {pad}");
        }
        let h = read_header(&tmp("pad1.npy")).unwrap();
        assert_ne!(h.data_offset % 64, 0, "unaligned fixture actually unaligned");
    }

    #[test]
    fn header_only_parse_matches_full_read() {
        let m = Matrix::new(5, 3, (0..15).map(|i| i as f32).collect());
        let p = tmp("hdr.npy");
        write(&p, &m).unwrap();
        let h = read_header(&p).unwrap();
        assert_eq!((h.rows, h.cols), (5, 3));
        assert_eq!(h.dtype, Dtype::F4);
        let bytes = std::fs::read(&p).unwrap();
        assert_eq!(bytes.len() as u64, h.data_offset + 15 * 4);
    }

    #[test]
    fn reads_1d_as_row() {
        let p = tmp("oned.npy");
        let mut header = "{'descr': '<f4', 'fortran_order': False, 'shape': (3,), }".to_string();
        let unpadded = 10 + header.len() + 1;
        header.extend(std::iter::repeat(' ').take((64 - unpadded % 64) % 64));
        header.push('\n');
        let mut bytes = Vec::new();
        bytes.extend_from_slice(MAGIC);
        bytes.extend_from_slice(&[1, 0]);
        bytes.extend_from_slice(&(header.len() as u16).to_le_bytes());
        bytes.extend_from_slice(header.as_bytes());
        for x in [1f32, 2.0, 3.0] {
            bytes.extend_from_slice(&x.to_le_bytes());
        }
        std::fs::write(&p, bytes).unwrap();
        let m = read(&p).unwrap();
        assert_eq!((m.rows, m.cols), (1, 3));
    }
}
