//! Data-parallel helpers for the native pull engine and the experiment
//! harness (no rayon in the offline closure).
//!
//! Since PR 2 these are thin shims over the persistent [`crate::util::pool`]
//! worker pool: same chunk / atomic-cursor work-stealing semantics and the
//! same signatures, but the hot `pull_block` path no longer spawns OS
//! threads via `std::thread::scope` on every call — workers are long-lived
//! and a parallel call is one queue push.

use std::sync::{Condvar, Mutex, MutexGuard};

use crate::util::pool;

/// Spawn a named OS thread. Every `thread::spawn` in the crate routes
/// through here or the worker pool (lint rule R4, DESIGN.md §16), so the
/// process's thread inventory is auditable in one place and every thread
/// carries a `corrsh-*` name in stack traces and `/proc`.
///
/// Panics only if the OS refuses to create a thread (resource exhaustion) —
/// the same contract as `std::thread::spawn`.
pub fn spawn<T, F>(name: &str, f: F) -> std::thread::JoinHandle<T>
where
    T: Send + 'static,
    F: FnOnce() -> T + Send + 'static,
{
    std::thread::Builder::new()
        .name(name.to_string())
        .spawn(f)
        .unwrap_or_else(|e| panic!("spawn thread {name:?}: {e}"))
}

/// Lock a mutex, recovering the guard if the lock is poisoned.
///
/// Server and distributed-engine code must never `.unwrap()` a lock (lint
/// rule R5): query jobs run under `catch_unwind` in the executor, so a
/// panicked job poisons shared metrics/registry mutexes while leaving the
/// protected data structurally sound — recovering and serving beats
/// wedging the event loop over a counter.
pub fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// [`Condvar::wait`] with the same poison-recovery policy as [`lock`].
pub fn wait<'a, T>(cv: &Condvar, g: MutexGuard<'a, T>) -> MutexGuard<'a, T> {
    cv.wait(g).unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// Number of worker threads to use: `CORRSH_THREADS` env override, else the
/// available parallelism, else 4.
pub fn default_threads() -> usize {
    if let Ok(s) = std::env::var("CORRSH_THREADS") {
        if let Ok(n) = s.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

/// Serial-vs-parallel cutoff for the engine block paths, in element-ops
/// (pairs × dim). The old cutoff counted (arm, ref) pairs alone, so a
/// 4095-pair block at d = 784 (~3.2 M FLOPs) ran single-threaded while a
/// 4096-pair block at d = 4 paid pool dispatch for ~16 K FLOPs. 2¹⁸
/// element-ops ≈ the seed's 4096-pair cutoff at d = 64.
pub const PAR_FLOP_CUTOFF: usize = 1 << 18;

/// How many workers a block of `pairs` (arm, ref) distances over `dim`
/// features should use: 1 below [`PAR_FLOP_CUTOFF`] element-ops (pool
/// dispatch would dominate), else the engine's configured `threads`.
pub fn plan_threads(threads: usize, pairs: usize, dim: usize) -> usize {
    if pairs.saturating_mul(dim.max(1)) < PAR_FLOP_CUTOFF {
        1
    } else {
        threads.max(1)
    }
}

/// A take-once cell handing each chunk to exactly one claimant.
type Slot<'a, T> = Mutex<Option<(usize, &'a mut [T])>>;

/// Run `f(chunk_start, chunk)` over mutable chunks of `out`, where chunk `c`
/// covers `out[c*chunk_size .. ]`. Work is pre-split (regular chunks), which
/// is the right shape for the dense distance sweeps. Each chunk is executed
/// exactly once, so results do not depend on thread count or scheduling.
pub fn parallel_chunks_mut<T: Send, F>(out: &mut [T], chunk_size: usize, threads: usize, f: F)
where
    F: Fn(usize, &mut [T]) + Sync,
{
    let chunk_size = chunk_size.max(1);
    if threads <= 1 || out.len() <= chunk_size {
        for (c, chunk) in out.chunks_mut(chunk_size).enumerate() {
            f(c * chunk_size, chunk);
        }
        return;
    }
    let slots: Vec<Slot<'_, T>> = out
        .chunks_mut(chunk_size)
        .enumerate()
        .map(|(c, chunk)| Mutex::new(Some((c * chunk_size, chunk))))
        .collect();
    pool::global().run(slots.len(), threads, &|i| {
        if let Some((start, chunk)) = lock(&slots[i]).take() {
            f(start, chunk);
        }
    });
}

/// Map `f` over `0..n` in parallel, collecting results in index order.
pub fn parallel_map<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    parallel_chunks_mut(&mut out, 1.max(n / (threads * 4).max(1)), threads, |start, chunk| {
        for (off, slot) in chunk.iter_mut().enumerate() {
            *slot = Some(f(start + off));
        }
    });
    out.into_iter().map(|x| x.expect("parallel_map slot unfilled")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunks_cover_everything_once() {
        let mut data = vec![0u32; 10_007];
        parallel_chunks_mut(&mut data, 64, 8, |start, chunk| {
            for (i, x) in chunk.iter_mut().enumerate() {
                *x += (start + i) as u32 + 1;
            }
        });
        for (i, &x) in data.iter().enumerate() {
            assert_eq!(x, i as u32 + 1, "slot {i} touched {x} times");
        }
    }

    #[test]
    fn single_thread_fallback() {
        let mut data = vec![0u8; 100];
        parallel_chunks_mut(&mut data, 7, 1, |_, chunk| {
            for x in chunk {
                *x += 1;
            }
        });
        assert!(data.iter().all(|&x| x == 1));
    }

    #[test]
    fn map_preserves_order() {
        let out = parallel_map(1000, 8, |i| i * i);
        for (i, &x) in out.iter().enumerate() {
            assert_eq!(x, i * i);
        }
    }

    #[test]
    fn flop_cutoff_counts_dim_not_just_pairs() {
        // The regression this exists for: a 4095-pair block at d=784 is
        // ~3.2M FLOPs and must engage the pool even though it is under the
        // old 4096-pair cutoff.
        assert_eq!(plan_threads(8, 4095, 784), 8, "high-dim small-pair block stayed serial");
        // …while genuinely tiny work stays serial at any dim:
        assert_eq!(plan_threads(8, 100, 8), 1);
        assert_eq!(plan_threads(8, 4095, 4), 1, "low-dim small-pair block engaged the pool");
        // boundary: exactly the cutoff goes parallel, one element-op less
        // does not
        assert_eq!(plan_threads(8, PAR_FLOP_CUTOFF, 1), 8);
        assert_eq!(plan_threads(8, PAR_FLOP_CUTOFF - 1, 1), 1);
        // degenerate inputs never return 0 workers or overflow
        assert_eq!(plan_threads(0, usize::MAX, usize::MAX), 1);
        assert_eq!(plan_threads(8, usize::MAX, 0), 8);
    }

    #[test]
    fn empty_input_ok() {
        let mut data: Vec<u8> = vec![];
        parallel_chunks_mut(&mut data, 4, 4, |_, _| panic!("no chunks expected"));
        assert!(parallel_map(0, 4, |i| i).is_empty());
    }

    #[test]
    fn repeated_calls_reuse_the_pool() {
        // The regression this PR exists for: many small parallel calls in a
        // row (one per halving round per query) must keep working against
        // the persistent pool without spawning per call.
        for round in 0..100usize {
            let out = parallel_map(64, 4, |i| i + round);
            assert_eq!(out[63], 63 + round);
        }
    }

    #[test]
    fn named_spawn_runs_and_joins() {
        let h = spawn("corrsh-test", || 41 + 1);
        assert_eq!(h.join().ok(), Some(42));
    }

    #[test]
    fn lock_recovers_from_poison() {
        let m = std::sync::Arc::new(Mutex::new(7u32));
        let m2 = std::sync::Arc::clone(&m);
        let _ = spawn("corrsh-poison", move || {
            let _g = m2.lock();
            panic!("poison the mutex");
        })
        .join();
        assert!(m.is_poisoned());
        assert_eq!(*lock(&m), 7, "guard recovered with data intact");
        *lock(&m) += 1;
        assert_eq!(*lock(&m), 8);
    }

    #[test]
    fn nested_parallelism_completes() {
        // engine pull_block inside an executor job inside parallel_map
        let outer = parallel_map(4, 4, |i| {
            let inner = parallel_map(32, 4, |j| j * i);
            inner.iter().sum::<usize>()
        });
        for (i, &s) in outer.iter().enumerate() {
            assert_eq!(s, 496 * i);
        }
    }
}
