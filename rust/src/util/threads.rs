//! Scoped data-parallelism for the native pull engine and the experiment
//! harness (no rayon in the offline closure; `std::thread::scope` is all we
//! need — the workloads are large, regular chunks).

use std::sync::atomic::{AtomicUsize, Ordering};

/// Number of worker threads to use: `CORRSH_THREADS` env override, else the
/// available parallelism, else 4.
pub fn default_threads() -> usize {
    if let Ok(s) = std::env::var("CORRSH_THREADS") {
        if let Ok(n) = s.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

/// Run `f(chunk_start, chunk)` over mutable chunks of `out`, where chunk `c`
/// covers `out[c*chunk_size .. ]`. Work is pre-split (regular chunks), which
/// is the right shape for the dense distance sweeps.
pub fn parallel_chunks_mut<T: Send, F>(out: &mut [T], chunk_size: usize, threads: usize, f: F)
where
    F: Fn(usize, &mut [T]) + Sync,
{
    if threads <= 1 || out.len() <= chunk_size {
        for (c, chunk) in out.chunks_mut(chunk_size).enumerate() {
            f(c * chunk_size, chunk);
        }
        return;
    }
    let next = AtomicUsize::new(0);
    let chunks: Vec<(usize, &mut [T])> = {
        let mut v = Vec::new();
        let mut start = 0;
        let mut rest = out;
        while !rest.is_empty() {
            let take = chunk_size.min(rest.len());
            let (head, tail) = rest.split_at_mut(take);
            v.push((start, head));
            start += take;
            rest = tail;
        }
        v
    };
    // Work-stealing over the chunk list via an atomic cursor.
    let slots: Vec<_> = chunks.into_iter().map(parking_cell::new).collect();
    std::thread::scope(|s| {
        for _ in 0..threads.min(slots.len()) {
            s.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= slots.len() {
                    break;
                }
                if let Some((start, chunk)) = parking_cell::take(&slots[i]) {
                    f(start, chunk);
                }
            });
        }
    });
}

/// Tiny cell wrapper so chunks can be handed to exactly one worker.
mod parking_cell {
    use std::sync::Mutex;

    pub type Cell<T> = Mutex<Option<T>>;

    pub fn new<T>(v: T) -> Cell<T> {
        Mutex::new(Some(v))
    }

    pub fn take<T>(c: &Cell<T>) -> Option<T> {
        c.lock().unwrap().take()
    }
}

/// Map `f` over `0..n` in parallel, collecting results in index order.
pub fn parallel_map<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    parallel_chunks_mut(&mut out, 1.max(n / (threads * 4).max(1)), threads, |start, chunk| {
        for (off, slot) in chunk.iter_mut().enumerate() {
            *slot = Some(f(start + off));
        }
    });
    out.into_iter().map(|x| x.expect("parallel_map slot unfilled")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunks_cover_everything_once() {
        let mut data = vec![0u32; 10_007];
        parallel_chunks_mut(&mut data, 64, 8, |start, chunk| {
            for (i, x) in chunk.iter_mut().enumerate() {
                *x += (start + i) as u32 + 1;
            }
        });
        for (i, &x) in data.iter().enumerate() {
            assert_eq!(x, i as u32 + 1, "slot {i} touched {x} times");
        }
    }

    #[test]
    fn single_thread_fallback() {
        let mut data = vec![0u8; 100];
        parallel_chunks_mut(&mut data, 7, 1, |_, chunk| {
            for x in chunk {
                *x += 1;
            }
        });
        assert!(data.iter().all(|&x| x == 1));
    }

    #[test]
    fn map_preserves_order() {
        let out = parallel_map(1000, 8, |i| i * i);
        for (i, &x) in out.iter().enumerate() {
            assert_eq!(x, i * i);
        }
    }

    #[test]
    fn empty_input_ok() {
        let mut data: Vec<u8> = vec![];
        parallel_chunks_mut(&mut data, 4, 4, |_, _| panic!("no chunks expected"));
        assert!(parallel_map(0, 4, |i| i).is_empty());
    }
}
