//! In-tree error handling: a context-chaining error type with the familiar
//! `Result` / `Context` / `bail!` / `ensure!` surface.
//!
//! PR 2 dropped the crate's last external dependencies (`anyhow`,
//! `thiserror`) so the dependency closure is empty: `Cargo.lock` is exact by
//! construction, offline builds never resolve against a registry, and the
//! binary carries no code this repo doesn't own. The API mirrors the anyhow
//! subset the codebase already used, so call sites read identically:
//!
//! ```
//! use corrsh::util::error::{Context, Result};
//!
//! fn lookup(map: &std::collections::BTreeMap<String, u32>, k: &str) -> Result<u32> {
//!     if k.is_empty() {
//!         corrsh::bail!("empty key");
//!     }
//!     map.get(k).copied().with_context(|| format!("key {k:?} missing"))
//! }
//! ```

use std::fmt;

/// Chain-of-context error: outermost context first, root cause last.
///
/// Deliberately does **not** implement [`std::error::Error`] — exactly like
/// `anyhow::Error`, that is what makes the blanket `From<E: Error>` impl
/// coherent, so `?` converts any std-error type into this one.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Build from a single message (the `bail!` entry point).
    pub fn msg(m: impl fmt::Display) -> Self {
        Error { chain: vec![m.to_string()] }
    }

    /// Wrap with an outer context layer (consuming, like `anyhow`).
    pub fn context(mut self, c: impl fmt::Display) -> Self {
        self.chain.insert(0, c.to_string());
        self
    }

    /// Context layers, outermost first; the last entry is the root cause.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }

    pub fn root_cause(&self) -> &str {
        self.chain.last().expect("error chain is never empty")
    }
}

/// `{e}` prints the outermost message; `{e:#}` the whole chain joined with
/// `": "` — the anyhow conventions the launcher and server already rely on.
impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(&self.chain[0])
        }
    }
}

/// Debug (what `unwrap()`/`main` print) shows the full chain.
impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.chain.join(": "))
    }
}

/// Any std error converts via `?`, flattening its `source()` chain.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// Crate-wide result type; `E` defaults to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to `Result`s and `Option`s (the `anyhow::Context` subset
/// the crate uses).
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: std::error::Error + Send + Sync + 'static> Context<T> for Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| Error::from(e).context(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T> for Result<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| e.context(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Early-return with a formatted [`Error`] (in-tree `anyhow::bail!`).
/// Accepts either a format literal plus arguments or any one `Display`
/// expression.
#[macro_export]
macro_rules! bail {
    ($msg:literal $(, $arg:expr)* $(,)?) => {
        return Err($crate::util::error::Error::msg(format!($msg $(, $arg)*)))
    };
    ($msg:expr) => {
        return Err($crate::util::error::Error::msg($msg))
    };
}

/// Check a condition, `bail!`ing with the message when it fails (in-tree
/// `anyhow::ensure!`).
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        std::fs::read_to_string("/definitely/not/a/file").context("read config")?;
        Ok(())
    }

    #[test]
    fn context_chains_outermost_first() {
        let e = io_fail().unwrap_err().context("boot");
        let layers: Vec<&str> = e.chain().collect();
        assert_eq!(layers[0], "boot");
        assert_eq!(layers[1], "read config");
        assert!(layers.len() >= 3, "io root cause should be appended");
    }

    #[test]
    fn display_plain_vs_alternate() {
        let e = Error::msg("inner").context("outer");
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: inner");
        assert_eq!(format!("{e:?}"), "outer: inner");
        assert_eq!(e.root_cause(), "inner");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("missing field").unwrap_err();
        assert_eq!(format!("{e}"), "missing field");
        let some = Some(7u32).with_context(|| "unused").unwrap();
        assert_eq!(some, 7);
    }

    #[test]
    fn bail_and_ensure() {
        fn f(x: u32) -> Result<u32> {
            crate::ensure!(x < 10, "x too big: {x}");
            if x == 3 {
                crate::bail!("unlucky {x}");
            }
            Ok(x)
        }
        assert_eq!(f(5).unwrap(), 5);
        assert_eq!(format!("{}", f(3).unwrap_err()), "unlucky 3");
        assert_eq!(format!("{}", f(12).unwrap_err()), "x too big: 12");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn parse(s: &str) -> Result<u64> {
            Ok(s.parse::<u64>()?)
        }
        assert_eq!(parse("41").unwrap(), 41);
        assert!(format!("{:#}", parse("nope").unwrap_err()).contains("invalid digit"));
    }
}
