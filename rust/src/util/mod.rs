//! In-tree substrates (this build is offline: the only external crates are
//! `anyhow` and `thiserror`; even the feature-gated PJRT path compiles
//! against an in-tree stub backend rather than pulling `xla` bindings).
//!
//! * [`rng`] — deterministic xoshiro256++ RNG with the sampling primitives
//!   the bandit algorithms need (without-replacement draws, shuffles,
//!   gaussians, power laws).
//! * [`json`] — minimal JSON parser/writer for the AOT `manifest.json`,
//!   config files, experiment outputs and the server protocol.
//! * [`cli`] — flag parser for the launcher.
//! * [`threads`] — scoped parallel-for used by the native pull engine.
//! * [`bench`] — micro-benchmark harness (criterion-style reporting).
//! * [`testing`] — property-test loop (randomized cases, seed reported on
//!   failure) used across the crate's unit tests.
//! * [`npy`] — NumPy `.npy` v1 reader/writer for dataset interchange with
//!   the python layer.

pub mod bench;
pub mod cli;
pub mod json;
pub mod npy;
pub mod rng;
pub mod testing;
pub mod threads;
