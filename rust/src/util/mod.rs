//! In-tree substrates (this build is offline and the dependency closure is
//! **empty** — error handling, JSON, RNG, CLI and thread-pool all live
//! here; even the feature-gated PJRT path compiles against an in-tree stub
//! backend rather than pulling `xla` bindings).
//!
//! * [`error`] — context-chaining error type + `Result`/`Context` and the
//!   crate-root `bail!`/`ensure!` macros (the former `anyhow` surface).
//! * [`rng`] — deterministic xoshiro256++ RNG with the sampling primitives
//!   the bandit algorithms need (without-replacement draws, shuffles,
//!   gaussians, power laws).
//! * [`json`] — minimal JSON parser/writer for the AOT `manifest.json`,
//!   config files, experiment outputs and the server protocol.
//! * [`cli`] — flag parser for the launcher.
//! * [`pool`] — persistent work-stealing worker pool (process-global).
//! * [`threads`] — parallel-for shims over the pool, used by the native
//!   pull engine and the trial runner.
//! * [`bench`] — micro-benchmark harness (criterion-style reporting).
//! * [`testing`] — property-test harness (seeded case generation,
//!   shrink-on-fail, `cases_from_env`) used across the crate's unit and
//!   integration tests.
//! * [`npy`] — NumPy `.npy` v1–v3 reader / v1 writer for dataset
//!   interchange with the python layer and the sharded store's dense
//!   shard files.

pub mod bench;
pub mod cli;
pub mod error;
pub mod json;
pub mod npy;
pub mod pool;
pub mod rng;
pub mod testing;
pub mod threads;
