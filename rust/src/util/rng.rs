//! Deterministic RNG: xoshiro256++ seeded via splitmix64.
//!
//! Every randomized component in the crate (generators, bandit algorithms,
//! experiment trials) takes one of these explicitly — trials are reproduced
//! by seed, mirroring the paper's §3.1 "the only variable across trials was
//! the random seed, varied across 0–999".

/// xoshiro256++ — fast, high-quality, 256-bit state.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second output of the last Box-Muller draw.
    gauss_spare: Option<f64>,
}

#[inline]
fn rotl(x: u64, k: u32) -> u64 {
    x.rotate_left(k)
}

/// splitmix64 — used to expand a u64 seed into the xoshiro state.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Deterministic RNG from a 64-bit seed.
    pub fn seeded(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, gauss_spare: None }
    }

    /// Independent child stream (for per-trial / per-worker RNGs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        let mut sm = self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15);
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, gauss_spare: None }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = rotl(s[0].wrapping_add(s[3]), 23).wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = rotl(s[3], 45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 high bits -> [0,1)
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire's method).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n = n as u64;
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo)
    }

    /// Standard normal via Box-Muller (with spare caching).
    pub fn gaussian(&mut self) -> f64 {
        if let Some(z) = self.gauss_spare.take() {
            return z;
        }
        // u in (0,1] to avoid ln(0)
        let u = 1.0 - self.f64();
        let v = self.f64();
        let r = (-2.0 * u.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * v;
        self.gauss_spare = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Exponential with rate 1.
    #[inline]
    pub fn exponential(&mut self) -> f64 {
        -(1.0 - self.f64()).ln()
    }

    /// Pareto-ish power law: returns x >= 1 with P(X > x) = x^-alpha.
    #[inline]
    pub fn power_law(&mut self, alpha: f64) -> f64 {
        (1.0 - self.f64()).powf(-1.0 / alpha)
    }

    /// Bernoulli.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// In-place Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// `k` distinct indices drawn uniformly **without replacement** from
    /// `[0, n)` — the correlated reference draw of Algorithm 1 line 3.
    ///
    /// Floyd's algorithm: O(k) expected time, O(k) space, order then
    /// shuffled so the result is an exchangeable uniform sample.
    pub fn sample_without_replacement(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "sample_without_replacement: k={k} > n={n}");
        if k == n {
            let mut all: Vec<usize> = (0..n).collect();
            self.shuffle(&mut all);
            return all;
        }
        let mut chosen = std::collections::HashSet::with_capacity(k * 2);
        let mut out = Vec::with_capacity(k);
        for j in (n - k)..n {
            let t = self.below(j + 1);
            if chosen.insert(t) {
                out.push(t);
            } else {
                chosen.insert(j);
                out.push(j);
            }
        }
        self.shuffle(&mut out);
        out
    }

    /// `k` indices drawn uniformly **with replacement** from `[0, n)` —
    /// the independent-sampling baselines (Med-dit, uncorrelated SH).
    pub fn sample_with_replacement(&mut self, n: usize, k: usize) -> Vec<usize> {
        (0..k).map(|_| self.below(n)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_by_seed() {
        let mut a = Rng::seeded(42);
        let mut b = Rng::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::seeded(43);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::seeded(1);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_unbiased_coverage() {
        let mut r = Rng::seeded(2);
        let mut counts = [0usize; 7];
        for _ in 0..70_000 {
            counts[r.below(7)] += 1;
        }
        for &c in &counts {
            // each bin ~10k; 5 sigma ~ 480
            assert!((9_400..10_600).contains(&c), "biased bin: {counts:?}");
        }
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::seeded(3);
        let n = 200_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let z = r.gaussian();
            s += z;
            s2 += z * z;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
    }

    #[test]
    fn swr_distinct_and_uniform() {
        let mut r = Rng::seeded(4);
        for _ in 0..200 {
            let k = r.range(1, 50);
            let n = k + r.below(100);
            let s = r.sample_without_replacement(n, k);
            assert_eq!(s.len(), k);
            let set: std::collections::HashSet<_> = s.iter().collect();
            assert_eq!(set.len(), k, "duplicates in {s:?}");
            assert!(s.iter().all(|&i| i < n));
        }
        // marginal uniformity: each index appears with prob k/n
        let (n, k, trials) = (20, 5, 40_000);
        let mut counts = vec![0usize; n];
        for _ in 0..trials {
            for i in r.sample_without_replacement(n, k) {
                counts[i] += 1;
            }
        }
        let expect = trials * k / n; // 10_000
        for &c in &counts {
            assert!((c as i64 - expect as i64).abs() < 600, "{counts:?}");
        }
    }

    #[test]
    fn swr_full_population_is_permutation() {
        let mut r = Rng::seeded(5);
        let mut s = r.sample_without_replacement(10, 10);
        s.sort_unstable();
        assert_eq!(s, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::seeded(6);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn power_law_tail() {
        let mut r = Rng::seeded(7);
        let n = 100_000;
        let alpha = 2.0;
        let frac_gt2 = (0..n).filter(|_| r.power_law(alpha) > 2.0).count() as f64 / n as f64;
        // P(X>2) = 2^-2 = 0.25
        assert!((frac_gt2 - 0.25).abs() < 0.01, "{frac_gt2}");
    }

    #[test]
    fn fork_streams_diverge() {
        let mut root = Rng::seeded(8);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }
}
