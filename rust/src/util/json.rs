//! Minimal JSON: recursive-descent parser + writer.
//!
//! Consumers: the AOT `artifacts/manifest.json` contract with the python
//! layer, config files, experiment result emission, and the medoid service
//! wire protocol. Full JSON (RFC 8259) minus `\u` surrogate pairs beyond the
//! BMP; numbers are f64 (adequate: the manifest carries small ints).

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Array(Vec<Value>),
    Object(BTreeMap<String, Value>),
}

impl Value {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(x) => Some(*x),
            _ => None,
        }
    }
    #[allow(clippy::float_cmp)]
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|x| {
            // lint: float-eq-ok(fract()==0.0 is the exact integrality test)
            if x >= 0.0 && x.fract() == 0.0 {
                Some(x as usize)
            } else {
                None
            }
        })
    }
    /// Lossless u64 view. JSON numbers are stored as f64, which is exact
    /// for integers up to 2⁵³; anything larger (e.g. full-width RNG seeds)
    /// should be sent as a decimal string (`"seed":"18446744073709551615"`),
    /// which this accessor also accepts. Returns `None` for negative,
    /// fractional, or non-exactly-representable numbers instead of silently
    /// truncating the way `as_f64() as u64` did.
    #[allow(clippy::float_cmp)]
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            // Integral f64s below 2⁶⁴ convert exactly (they carry ≤ 53
            // significant bits by construction).
            // lint: float-eq-ok(fract()==0.0 is the exact integrality test)
            Value::Num(x) if *x >= 0.0 && x.fract() == 0.0 && *x < u64::MAX as f64 => {
                Some(*x as u64)
            }
            Value::Str(s) => s.parse::<u64>().ok(),
            _ => None,
        }
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_object(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Object(o) => Some(o),
            _ => None,
        }
    }
    /// `obj["a"]["b"]`-style access; returns Null for missing paths.
    pub fn get(&self, key: &str) -> &Value {
        static NULL: Value = Value::Null;
        match self {
            Value::Object(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }
    pub fn idx(&self, i: usize) -> &Value {
        static NULL: Value = Value::Null;
        match self {
            Value::Array(a) => a.get(i).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    pub fn from_pairs(pairs: Vec<(&str, Value)>) -> Value {
        Value::Object(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
}

impl From<f64> for Value {
    fn from(x: f64) -> Self {
        Value::Num(x)
    }
}
impl From<usize> for Value {
    fn from(x: usize) -> Self {
        Value::Num(x as f64)
    }
}
impl From<u64> for Value {
    fn from(x: u64) -> Self {
        Value::Num(x as f64)
    }
}
impl From<bool> for Value {
    fn from(x: bool) -> Self {
        Value::Bool(x)
    }
}
impl From<&str> for Value {
    fn from(x: &str) -> Self {
        Value::Str(x.to_string())
    }
}
impl From<String> for Value {
    fn from(x: String) -> Self {
        Value::Str(x)
    }
}
impl<T: Into<Value>> From<Vec<T>> for Value {
    fn from(xs: Vec<T>) -> Self {
        Value::Array(xs.into_iter().map(Into::into).collect())
    }
}

#[derive(Debug)]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for ParseError {}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err<T>(&self, msg: impl Into<String>) -> Result<T, ParseError> {
        Err(ParseError { pos: self.pos, msg: msg.into() })
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.pos += 1;
        }
        c
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), ParseError> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            self.pos -= usize::from(self.pos > 0);
            self.err(format!("expected {:?}", c as char))
        }
    }

    fn literal(&mut self, lit: &str, v: Value) -> Result<Value, ParseError> {
        if self.b[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            self.err(format!("expected `{lit}`"))
        }
    }

    fn value(&mut self) -> Result<Value, ParseError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.literal("true", Value::Bool(true)),
            Some(b'f') => self.literal("false", Value::Bool(false)),
            Some(b'n') => self.literal("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => self.err(format!("unexpected byte {:?}", c as char)),
            None => self.err("unexpected end of input"),
        }
    }

    fn object(&mut self) -> Result<Value, ParseError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Object(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Value::Object(map)),
                _ => return self.err("expected `,` or `}`"),
            }
        }
    }

    fn array(&mut self) -> Result<Value, ParseError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Array(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Value::Array(out)),
                _ => return self.err("expected `,` or `]`"),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return self.err("unterminated string"),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| ParseError {
                                pos: self.pos,
                                msg: "truncated \\u".into(),
                            })?;
                            code = code * 16
                                + (c as char).to_digit(16).ok_or_else(|| ParseError {
                                    pos: self.pos,
                                    msg: "bad hex in \\u".into(),
                                })?;
                        }
                        s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    _ => return self.err("bad escape"),
                },
                Some(c) if c < 0x80 => s.push(c as char),
                Some(c) => {
                    // multi-byte utf-8: copy the full sequence
                    let len = match c {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    let start = self.pos - 1;
                    let end = (start + len).min(self.b.len());
                    self.pos = end;
                    match std::str::from_utf8(&self.b[start..end]) {
                        Ok(chunk) => s.push_str(chunk),
                        Err(_) => s.push('\u{fffd}'),
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        match text.parse::<f64>() {
            Ok(x) => Ok(Value::Num(x)),
            Err(_) => self.err(format!("bad number `{text}`")),
        }
    }
}

/// Parse a JSON document (must consume all non-whitespace input).
pub fn parse(text: &str) -> Result<Value, ParseError> {
    let mut p = Parser { b: text.as_bytes(), pos: 0 };
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.b.len() {
        return p.err("trailing garbage");
    }
    Ok(v)
}

fn escape(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

#[allow(clippy::float_cmp)]
fn write_value(v: &Value, out: &mut String) {
    match v {
        Value::Null => out.push_str("null"),
        Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Value::Num(x) => {
            // lint: float-eq-ok(integral f64s print as integers, exactly)
            if x.fract() == 0.0 && x.abs() < 9e15 {
                out.push_str(&(*x as i64).to_string());
            } else {
                out.push_str(&x.to_string());
            }
        }
        Value::Str(s) => escape(s, out),
        Value::Array(a) => {
            out.push('[');
            for (i, v) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_value(v, out);
            }
            out.push(']');
        }
        Value::Object(o) => {
            out.push('{');
            for (i, (k, v)) in o.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                escape(k, out);
                out.push(':');
                write_value(v, out);
            }
            out.push('}');
        }
    }
}

/// Serialize compactly.
pub fn to_string(v: &Value) -> String {
    let mut s = String::new();
    write_value(v, &mut s);
    s
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&to_string(self))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse("false").unwrap(), Value::Bool(false));
        assert_eq!(parse("3.25").unwrap(), Value::Num(3.25));
        assert_eq!(parse("-17").unwrap(), Value::Num(-17.0));
        assert_eq!(parse("1e3").unwrap(), Value::Num(1000.0));
        assert_eq!(parse("\"hi\\n\"").unwrap(), Value::Str("hi\n".into()));
    }

    #[test]
    fn parses_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(v.get("a").idx(2).get("b").as_str(), Some("x"));
        assert_eq!(v.get("c"), &Value::Null);
        assert_eq!(v.get("missing"), &Value::Null);
    }

    #[test]
    fn parses_real_manifest() {
        // mirror of the python aot.py output structure
        let text = r#"{"version": 1, "entry": "chunk_sums",
          "inputs": [{"name": "x_arms", "shape": ["arms", "dim"], "dtype": "f32"}],
          "artifacts": [{"name": "chunk_sums_l1_a64_r16_d256", "metric": "l1",
                          "arms": 64, "refs": 16, "dim": 256}]}"#;
        let v = parse(text).unwrap();
        assert_eq!(v.get("version").as_usize(), Some(1));
        let a = &v.get("artifacts").as_array().unwrap()[0];
        assert_eq!(a.get("arms").as_usize(), Some(64));
        assert_eq!(a.get("metric").as_str(), Some("l1"));
    }

    #[test]
    fn as_u64_is_honest() {
        assert_eq!(parse("7").unwrap().as_u64(), Some(7));
        assert_eq!(parse("0").unwrap().as_u64(), Some(0));
        // exact at the f64 integer limit
        assert_eq!(parse("9007199254740992").unwrap().as_u64(), Some(1u64 << 53));
        // negative / fractional / non-numeric are refused, not truncated
        assert_eq!(parse("-1").unwrap().as_u64(), None);
        assert_eq!(parse("1.5").unwrap().as_u64(), None);
        assert_eq!(parse("true").unwrap().as_u64(), None);
        // full-width seeds round-trip via the string form
        let v = parse(r#""18446744073709551615""#).unwrap();
        assert_eq!(v.as_u64(), Some(u64::MAX));
        assert_eq!(parse(r#""not a number""#).unwrap().as_u64(), None);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse("{\"a\" 1}").is_err());
        assert!(parse("\"unterminated").is_err());
    }

    #[test]
    fn roundtrip() {
        let cases = [
            r#"{"a":[1,2.5,true,null,"s"],"b":{"c":-3}}"#,
            r#"[[],{},"",0]"#,
            r#"{"unicode":"héllo ☃"}"#,
        ];
        for c in cases {
            let v = parse(c).unwrap();
            let s = to_string(&v);
            assert_eq!(parse(&s).unwrap(), v, "roundtrip failed for {c}");
        }
    }

    #[test]
    fn escape_control_chars() {
        let v = Value::Str("a\"b\\c\nd\u{1}".into());
        let s = to_string(&v);
        assert_eq!(parse(&s).unwrap(), v);
    }

    #[test]
    fn property_roundtrip_random() {
        use crate::util::rng::Rng;
        let mut rng = Rng::seeded(99);
        fn random_value(rng: &mut Rng, depth: usize) -> Value {
            match if depth == 0 { rng.below(4) } else { rng.below(6) } {
                0 => Value::Null,
                1 => Value::Bool(rng.chance(0.5)),
                2 => Value::Num((rng.f64() * 2e6).round() / 64.0 - 1e4),
                3 => Value::Str(
                    (0..rng.below(12)).map(|_| char::from(32 + rng.below(94) as u8)).collect(),
                ),
                4 => {
                    Value::Array((0..rng.below(5)).map(|_| random_value(rng, depth - 1)).collect())
                }
                _ => Value::Object(
                    (0..rng.below(5))
                        .map(|i| (format!("k{i}"), random_value(rng, depth - 1)))
                        .collect(),
                ),
            }
        }
        for _ in 0..300 {
            let v = random_value(&mut rng, 3);
            let s = to_string(&v);
            assert_eq!(parse(&s).unwrap(), v, "roundtrip failed for {s}");
        }
    }
}
