//! # corrsh — Ultra Fast Medoid Identification via Correlated Sequential Halving
//!
//! A production-shaped reproduction of Baharav & Tse, *Ultra Fast Medoid
//! Identification via Correlated Sequential Halving* (NeurIPS 2019), built as
//! a three-layer Rust + JAX + Pallas system:
//!
//! * **L1/L2 (build time, Python)** — Pallas tiled distance kernels and the
//!   masked chunk-centrality JAX graph, AOT-lowered to HLO-text artifacts
//!   (`make artifacts`, see `python/compile/`).
//! * **L3 (this crate)** — the coordinator: the Correlated Sequential
//!   Halving round scheduler (the paper's contribution), every baseline it
//!   is evaluated against (Med-dit/UCB, RAND, TOPRANK, exact, uncorrelated
//!   sequential halving), the data substrates, the PJRT runtime that
//!   executes the artifacts, the statistics engine behind the paper's
//!   figures, and the experiment harness that regenerates every table and
//!   figure. Python never runs on the request path.
//!
//! ## Quick start
//!
//! ```no_run
//! use corrsh::data::synth::{rnaseq, SynthConfig};
//! use corrsh::distance::Metric;
//! use corrsh::engine::{CountingEngine, NativeEngine};
//! use corrsh::bandits::{corr_sh::CorrSh, MedoidAlgorithm};
//! use corrsh::util::rng::Rng;
//!
//! let data = rnaseq::generate(&SynthConfig { n: 2_000, dim: 256, seed: 7, ..Default::default() });
//! let engine = CountingEngine::new(NativeEngine::new(data, Metric::L1));
//! let mut rng = Rng::seeded(0);
//! let res = CorrSh::with_pulls_per_arm(24.0).run(&engine, &mut rng);
//! println!("medoid = {} after {} pulls", res.best, res.pulls);
//! ```
//!
//! See `examples/` for end-to-end drivers and `DESIGN.md` for the complete
//! system inventory and per-experiment index.
//!
//! ## Features
//!
//! * `default` — pure-Rust, fully offline: the native engine, every
//!   algorithm, the experiment harness and the server.
//! * `pjrt` — additionally compiles the XLA/PJRT runtime path
//!   ([`runtime`], `engine::pjrt`). Executing artifacts requires linking
//!   real PJRT bindings in place of the in-tree stub backend
//!   (`runtime::xla`); see `README.md` for the build matrix.

pub mod analysis;
pub mod bandits;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod distance;
pub mod engine;
pub mod experiments;
pub mod kmedoids;
pub mod metrics;
#[cfg(feature = "pjrt")]
pub mod runtime;
pub mod server;
pub mod stats;
pub mod util;

/// Crate-wide result type (in-tree error chain — the crate has no external
/// dependencies; see [`util::error`]).
pub type Result<T> = util::error::Result<T>;

pub use util::error::Error;
