//! Experiment harness: regenerates every table and figure in the paper's
//! evaluation (DESIGN.md §3 maps experiment ids E1–E10 to these functions).
//!
//! * [`runner`] — dataset/engine construction from [`RunConfig`], parallel
//!   seeded trials, ground-truth resolution (exact sweep, or most-frequent
//!   corrSH answer for the 100k configs, as in paper §3.1).
//! * [`table1`] — Table 1: wall-clock + pulls/arm for every algorithm on
//!   every dataset row.
//! * [`figures`] — Figs 1 & 5 (error-prob vs budget sweeps), Fig 2 (toy
//!   correlation demo), Fig 3 (difference histograms), Fig 4 (1/Δ vs 1/ρ +
//!   H₂/H̃₂), Fig 6 (distance-to-medoid histograms), plus the corrSH-vs-SH
//!   ablation (E8).
//!
//! Every emitter returns its rows *and* writes CSV into `results/` so the
//! artifacts are diffable; EXPERIMENTS.md records one reference run.

pub mod figures;
pub mod runner;
pub mod table1;

pub use runner::{ground_truth, run_trials, Summary, TrialOutcome};

use std::path::{Path, PathBuf};

/// Results directory (created on demand).
pub fn results_dir() -> PathBuf {
    let p = Path::new("results").to_path_buf();
    let _ = std::fs::create_dir_all(&p);
    p
}

/// Write a CSV artifact and echo its path.
pub fn write_csv(name: &str, content: &str) -> PathBuf {
    let path = results_dir().join(name);
    if let Err(e) = std::fs::write(&path, content) {
        eprintln!("warn: could not write {path:?}: {e}");
    }
    path
}
