//! Table 1 — "Algorithm performance": wall-clock seconds and pulls/arm for
//! corrSH, Med-dit, RAND and exact computation on each dataset row, with
//! final percent error noted when nonzero (the paper's exact layout).
//!
//! Paper rows: RNA-Seq 20k/100k (ℓ₁), Netflix 20k/100k (cosine), MNIST
//! zeros (ℓ₂). The harness accepts a scale divisor so CI can run the full
//! matrix in minutes; the reference full-scale run is recorded in
//! EXPERIMENTS.md (shape comparison, not absolute numbers — different
//! testbed + synthetic data, DESIGN.md §7).

use std::sync::Arc;

use crate::Result;

use crate::bandits::MedoidAlgorithm;
use crate::config::{AlgoConfig, RunConfig};
use crate::experiments::{runner, write_csv};

/// One table cell group: an algorithm's summary on one dataset.
#[derive(Clone, Debug)]
pub struct Cell {
    pub algo: String,
    pub time_s: f64,
    pub pulls_per_arm: f64,
    pub error_pct: f64,
}

#[derive(Clone, Debug)]
pub struct Row {
    pub dataset: String,
    pub n: usize,
    pub dim: usize,
    pub metric: String,
    pub cells: Vec<Cell>,
}

/// The per-dataset algorithm lineup of Table 1.
/// corrSH budgets are the operating points for *our synthetic geometry*
/// (DESIGN.md §7): the paper reports 2.1–2.4 pulls/arm on the real RNA-Seq
/// data, whose Δ/ρ structure is more benign than our generator's
/// dropout-heavy rows — the sweep figures (fig1/fig5) show the full
/// error-vs-budget tradeoff either way.
fn lineup(dataset: &str, trials_are_cheap: bool) -> Vec<(&'static str, AlgoConfig)> {
    let corr_budget = match dataset {
        d if d.starts_with("rnaseq") => 40.0, // paper: 2.1-2.4 on real data
        d if d.starts_with("netflix") => 32.0, // paper: 15-18.5
        _ => 64.0,                            // mnist: 47.9
    };
    let mut v = vec![
        ("corrSH", AlgoConfig::CorrSh { pulls_per_arm: corr_budget }),
        // cap Med-dit at 500 pulls/arm — the top of the paper's observed
        // operating range (420 on RNA-Seq 100k); uncapped near-ties can
        // grind toward n², which is the UCB-overhead effect the paper
        // itself complains about (resolved per-row in run_row, n-dependent)
        ("Meddit", AlgoConfig::Meddit { delta: 0.0, cap: 0 }),
        ("Rand", AlgoConfig::Rand { refs_per_arm: 1000 }),
    ];
    if trials_are_cheap {
        v.push(("Exact Comp.", AlgoConfig::Exact));
    }
    v
}

/// Run the full table. `scale` divides every preset's n (1 = paper scale).
pub fn run(scale: usize, trials: usize, seed: u64) -> Result<Vec<Row>> {
    let presets = ["rnaseq20k", "rnaseq100k", "netflix20k", "netflix100k", "mnist"];
    let mut rows = Vec::new();
    for preset in presets {
        let cfg = RunConfig::preset(preset)?.scaled_down(scale.max(1));
        rows.push(run_row(preset, &cfg, trials, seed)?);
    }
    emit(&rows);
    Ok(rows)
}

/// Run one dataset row.
pub fn run_row(name: &str, cfg: &RunConfig, trials: usize, seed: u64) -> Result<Row> {
    let data = runner::build_data(cfg);
    let n = data.n();
    // exact ground truth is affordable up to ~20k points on this substrate
    let truth = runner::ground_truth(&data, cfg.metric, 20_000);

    let exact_ok = n <= 20_000;
    let mut cells = Vec::new();
    for (label, mut algo) in lineup(name, exact_ok) {
        if let AlgoConfig::Meddit { cap, .. } = &mut algo {
            *cap = 500 * n as u64;
        }
        let algo = Arc::new(algo);
        let algo2 = algo.clone();
        let mk = move || -> Box<dyn MedoidAlgorithm> { algo2.build(n) };
        // exact is deterministic: one trial is enough
        let t = if matches!(*algo, AlgoConfig::Exact) { 1 } else { trials };
        let outcomes = runner::run_trials(&mk, &data, cfg.metric, t, seed);
        let s = runner::summarize(&outcomes, truth, n);
        cells.push(Cell {
            algo: label.to_string(),
            time_s: s.mean_wall.as_secs_f64(),
            pulls_per_arm: s.mean_pulls_per_arm,
            error_pct: s.error_rate * 100.0,
        });
    }
    Ok(Row {
        dataset: name.to_string(),
        n,
        dim: data.dim(),
        metric: cfg.metric.name().to_string(),
        cells,
    })
}

/// Pretty-print in the paper's layout + CSV artifact.
pub fn emit(rows: &[Row]) {
    let mut csv = String::from("dataset,n,dim,metric,algo,time_s,pulls_per_arm,error_pct\n");
    println!("\nTable 1: Algorithm performance (time = mean seconds/trial; % error if nonzero)");
    println!("{:-<100}", "");
    println!(
        "{:<16} {:>9} {:>7}  {:<8} | {:>22} {:>22} {:>22} {:>16}",
        "dataset", "n", "d", "metric", "corrSH", "Meddit", "Rand", "Exact"
    );
    for r in rows {
        let fmt_cell = |c: Option<&Cell>| match c {
            None => format!("{:>22}", "-"),
            Some(c) => {
                let err = if c.error_pct > 0.0 {
                    format!(" ({:.1}%)", c.error_pct)
                } else {
                    String::new()
                };
                format!("{:>9.2}s {:>6.1}p{err:<6}", c.time_s, c.pulls_per_arm)
            }
        };
        let get = |name: &str| r.cells.iter().find(|c| c.algo.starts_with(name));
        println!(
            "{:<16} {:>9} {:>7}  {:<8} | {} {} {} {}",
            r.dataset,
            r.n,
            r.dim,
            r.metric,
            fmt_cell(get("corrSH")),
            fmt_cell(get("Meddit")),
            fmt_cell(get("Rand")),
            fmt_cell(get("Exact")),
        );
        for c in &r.cells {
            csv.push_str(&format!(
                "{},{},{},{},{},{:.6},{:.4},{:.3}\n",
                r.dataset, r.n, r.dim, r.metric, c.algo, c.time_s, c.pulls_per_arm, c.error_pct
            ));
        }
    }
    let path = write_csv("table1.csv", &csv);
    println!("\n[csv] {}", path.display());
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_row_runs_and_orders_algorithms() {
        // heavily scaled-down rnaseq row: corrSH must use far fewer pulls
        // than RAND and exact
        let cfg = RunConfig::preset("rnaseq20k").unwrap().scaled_down(100);
        let row = run_row("rnaseq20k", &cfg, 3, 0).unwrap();
        let get = |name: &str| {
            row.cells
                .iter()
                .find(|c| c.algo.starts_with(name))
                .unwrap_or_else(|| panic!("{name} cell missing"))
        };
        let corr = get("corrSH");
        let rand = get("Rand");
        let exact = get("Exact");
        assert!(corr.pulls_per_arm < rand.pulls_per_arm);
        assert!(rand.pulls_per_arm <= exact.pulls_per_arm + 1e-9);
        assert_eq!(exact.error_pct, 0.0);
    }
}
