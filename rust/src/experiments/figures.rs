//! Figure emitters: Figs 1, 2, 3, 4, 5, 6 and the corrSH-vs-SH ablation.
//!
//! Each function reproduces the *series* behind the paper figure (the paper
//! plots them with matplotlib; we emit CSV + a terminal summary so the run
//! is scriptable and diffable). Shapes to reproduce:
//!
//! * Fig 1/5: error probability vs pulls/arm — corrSH's curve drops orders
//!   of magnitude earlier than Med-dit's, which drops earlier than RAND's.
//! * Fig 2: a periphery reference point misleads independent estimation,
//!   correlated estimation is immune (toy 2-D numbers).
//! * Fig 3: correlated difference histogram is much tighter than the
//!   independent one (σ_corr < σ_ind; P(diff < 0) collapses).
//! * Fig 4: 1/ρ grows with 1/Δ (harder arms benefit more); H₂/H̃₂ ≫ 1.
//! * Fig 6: d(medoid, x_i) distribution is far from 0 in high dimension.

use std::sync::Arc;

use crate::Result;

use crate::bandits::{CorrSh, MedoidAlgorithm, Meddit, RandBaseline, SeqHalving};
use crate::config::RunConfig;
use crate::distance::Metric;
use crate::engine::{NativeEngine, PullEngine};
use crate::experiments::{runner, write_csv};
use crate::stats::{self, Histogram};
use crate::util::rng::Rng;

/// One point of an error-vs-budget sweep.
#[derive(Clone, Debug)]
pub struct SweepPoint {
    pub algo: String,
    pub pulls_per_arm: f64,
    pub error_rate: f64,
    pub trials: usize,
}

/// Figs 1 & 5: sweep pulls/arm budgets for corrSH / Med-dit / RAND on one
/// dataset; the paper's y-axis is P(wrong medoid) over seeds 0..trials.
pub fn error_vs_budget(
    cfg: &RunConfig,
    budgets: &[f64],
    trials: usize,
    seed: u64,
) -> Result<Vec<SweepPoint>> {
    let data = runner::build_data(cfg);
    let n = data.n();
    let truth = runner::ground_truth(&data, cfg.metric, 20_000);
    let mut points = Vec::new();

    for &x in budgets {
        // corrSH: behaviour depends on the input budget (paper: solid dots)
        let mk = move || -> Box<dyn MedoidAlgorithm> { Box::new(CorrSh::with_pulls_per_arm(x)) };
        let outs = runner::run_trials(&mk, &data, cfg.metric, trials, seed);
        let s = runner::summarize(&outs, truth, n);
        points.push(SweepPoint {
            algo: "corrsh".into(),
            pulls_per_arm: s.mean_pulls_per_arm,
            error_rate: s.error_rate,
            trials,
        });

        // RAND at m = x refs/arm
        let m = (x.ceil() as usize).clamp(1, n);
        let mk = move || -> Box<dyn MedoidAlgorithm> { Box::new(RandBaseline::new(m)) };
        let outs = runner::run_trials(&mk, &data, cfg.metric, trials, seed);
        let s = runner::summarize(&outs, truth, n);
        points.push(SweepPoint {
            algo: "rand".into(),
            pulls_per_arm: s.mean_pulls_per_arm,
            error_rate: s.error_rate,
            trials,
        });

        // Med-dit capped at budget x·n (anytime curve, as in the paper)
        let cap = (x * n as f64) as u64;
        let mk = move || -> Box<dyn MedoidAlgorithm> {
            Box::new(Meddit::new(1.0 / n as f64).with_budget_cap(cap))
        };
        let outs = runner::run_trials(&mk, &data, cfg.metric, trials, seed);
        let s = runner::summarize(&outs, truth, n);
        points.push(SweepPoint {
            algo: "meddit".into(),
            pulls_per_arm: s.mean_pulls_per_arm,
            error_rate: s.error_rate,
            trials,
        });
    }
    Ok(points)
}

/// Emit a sweep as CSV + terminal table. `figname` e.g. "fig1_rnaseq20k".
pub fn emit_sweep(figname: &str, points: &[SweepPoint]) {
    let mut csv = String::from("algo,pulls_per_arm,error_rate,trials\n");
    println!("\n{figname}: error probability vs pulls/arm");
    println!("{:<10} {:>14} {:>12} {:>8}", "algo", "pulls/arm", "err rate", "trials");
    for p in points {
        println!(
            "{:<10} {:>14.2} {:>12.4} {:>8}",
            p.algo, p.pulls_per_arm, p.error_rate, p.trials
        );
        csv.push_str(&format!(
            "{},{:.4},{:.6},{}\n",
            p.algo, p.pulls_per_arm, p.error_rate, p.trials
        ));
    }
    let path = write_csv(&format!("{figname}.csv"), &csv);
    println!("[csv] {}", path.display());
}

/// E8 ablation: corrSH vs uncorrelated SH at identical budgets.
pub fn ablation_corr_vs_uncorr(
    cfg: &RunConfig,
    budgets: &[f64],
    trials: usize,
    seed: u64,
) -> Result<Vec<SweepPoint>> {
    let data = runner::build_data(cfg);
    let n = data.n();
    let truth = runner::ground_truth(&data, cfg.metric, 20_000);
    let mut points = Vec::new();
    for &x in budgets {
        for (name, correlated) in [("corrsh", true), ("seq-halving", false)] {
            let mk = move || -> Box<dyn MedoidAlgorithm> {
                if correlated {
                    Box::new(CorrSh::with_pulls_per_arm(x))
                } else {
                    Box::new(SeqHalving::with_pulls_per_arm(x))
                }
            };
            let s = runner::summarize(
                &runner::run_trials(&mk, &data, cfg.metric, trials, seed),
                truth,
                n,
            );
            points.push(SweepPoint {
                algo: name.into(),
                pulls_per_arm: s.mean_pulls_per_arm,
                error_rate: s.error_rate,
                trials,
            });
        }
    }
    Ok(points)
}

/// Fig 2 (toy): a 2-D gaussian cloud; compare the chance that a periphery
/// vs core reference point flips the comparison θ̂_1 < θ̂_2 under
/// independent vs correlated single-sample estimation.
pub struct Fig2Demo {
    pub p_flip_independent: f64,
    pub p_flip_correlated: f64,
}

pub fn fig2_toy_demo(samples: usize, seed: u64) -> Fig2Demo {
    use crate::data::synth::{gaussian, SynthConfig};
    let data = Arc::new(gaussian::generate(&SynthConfig {
        n: 500,
        dim: 2,
        seed,
        outlier_frac: 0.1,
        ..Default::default()
    }));
    let engine = NativeEngine::with_threads(data.clone(), Metric::L2, 1);
    // arm 1 = medoid (planted at origin), arm i = a mid-pack point
    let thetas = crate::bandits::exact::exact_thetas(&engine);
    let medoid = crate::bandits::argmin(thetas.iter().cloned());
    let mut order: Vec<usize> = (0..thetas.len()).collect();
    order.sort_by(|&a, &b| thetas[a].total_cmp(&thetas[b]).then_with(|| a.cmp(&b)));
    let mid = order[order.len() / 2];

    let mut rng = Rng::seeded(seed ^ 0xF16);
    let n = engine.n();
    let (mut flip_ind, mut flip_corr) = (0usize, 0usize);
    for _ in 0..samples {
        let j = rng.below(n);
        if engine.pull(medoid, j) > engine.pull(mid, j) {
            flip_corr += 1;
        }
        let (j1, j2) = (rng.below(n), rng.below(n));
        if engine.pull(medoid, j1) > engine.pull(mid, j2) {
            flip_ind += 1;
        }
    }
    Fig2Demo {
        p_flip_independent: flip_ind as f64 / samples as f64,
        p_flip_correlated: flip_corr as f64 / samples as f64,
    }
}

/// Fig 3: correlated vs independent difference histograms for a hard arm
/// (small Δ) and a mid-pack arm on the given dataset.
pub struct Fig3Output {
    pub arm_kind: String,
    pub sigma: f64,
    pub rho: f64,
    pub std_independent: f64,
    pub p_neg_independent: f64,
    pub p_neg_correlated: f64,
}

pub fn fig3_difference_histograms(
    cfg: &RunConfig,
    samples: usize,
    seed: u64,
) -> Result<Vec<Fig3Output>> {
    let data = runner::build_data(cfg);
    let engine = NativeEngine::with_threads(
        data.clone(),
        cfg.metric,
        crate::util::threads::default_threads(),
    );
    let mut rng = Rng::seeded(seed);
    let st = stats::instance_stats(&engine, 512.min(data.n()), &mut rng);

    // hard arm: smallest positive Δ; mid arm: median Δ
    let mut order: Vec<usize> = (0..data.n()).filter(|&i| i != st.medoid).collect();
    order.sort_by(|&a, &b| st.deltas[a].total_cmp(&st.deltas[b]).then_with(|| a.cmp(&b)));
    let hard = order[0];
    let mid = order[order.len() / 2];

    let mut out = Vec::new();
    for (kind, arm) in [("hard(small Δ)", hard), ("mid", mid)] {
        let ds = stats::difference_samples(&engine, st.medoid, arm, samples, &mut rng);
        let hc = Histogram::auto(&ds.correlated, 60);
        let hi = Histogram::auto(&ds.independent, 60);
        write_csv(&format!("fig3_{}_correlated.csv", kind_slug(kind)), &hc.to_csv());
        write_csv(&format!("fig3_{}_independent.csv", kind_slug(kind)), &hi.to_csv());
        println!("fig3 {kind}: corr {} | ind {}", hc.sparkline(), hi.sparkline());
        out.push(Fig3Output {
            arm_kind: kind.to_string(),
            sigma: st.sigma,
            rho: ds.std_correlated / st.sigma,
            std_independent: ds.std_independent,
            p_neg_independent: stats::DifferenceSamples::p_negative(&ds.independent),
            p_neg_correlated: stats::DifferenceSamples::p_negative(&ds.correlated),
        });
    }
    Ok(out)
}

fn kind_slug(kind: &str) -> String {
    kind.chars().filter(|c| c.is_ascii_alphanumeric()).collect()
}

/// Fig 4: per-arm (1/Δ_i, 1/ρ_i) scatter + the H₂/H̃₂ headline ratio.
pub struct Fig4Output {
    pub h2: f64,
    pub h2_tilde: f64,
    pub gain_ratio: f64,
    pub rows: usize,
}

pub fn fig4_delta_vs_rho(cfg: &RunConfig, seed: u64) -> Result<Fig4Output> {
    let data = runner::build_data(cfg);
    let engine = NativeEngine::with_threads(
        data.clone(),
        cfg.metric,
        crate::util::threads::default_threads(),
    );
    let mut rng = Rng::seeded(seed);
    let st = stats::instance_stats(&engine, 512.min(data.n()), &mut rng);
    let mut csv = String::from("arm,delta,rho,inv_delta,inv_rho\n");
    for i in 0..data.n() {
        if i == st.medoid || st.deltas[i] <= 0.0 || st.rhos[i] <= 0.0 {
            continue;
        }
        csv.push_str(&format!(
            "{},{:.6e},{:.6e},{:.6e},{:.6e}\n",
            i,
            st.deltas[i],
            st.rhos[i],
            1.0 / st.deltas[i],
            1.0 / st.rhos[i]
        ));
    }
    let name = format!("fig4_{}.csv", cfg.dataset_kind.name());
    write_csv(&name, &csv);
    Ok(Fig4Output {
        h2: st.h2,
        h2_tilde: st.h2_tilde,
        gain_ratio: st.gain_ratio(),
        rows: data.n() - 1,
    })
}

/// Fig 6: histogram of distances from the medoid to every other point.
pub fn fig6_distance_to_medoid(cfg: &RunConfig, seed: u64) -> Result<Histogram> {
    let data = runner::build_data(cfg);
    let engine = NativeEngine::with_threads(
        data.clone(),
        cfg.metric,
        crate::util::threads::default_threads(),
    );
    let truth = runner::ground_truth(&data, cfg.metric, 50_000);
    let _ = seed;
    let n = data.n();
    let all: Vec<usize> = (0..n).filter(|&i| i != truth).collect();
    let mut d = vec![0f32; all.len()];
    engine.pull_matrix(&[truth], &all, &mut d);
    let vals: Vec<f64> = d.iter().map(|&x| x as f64).collect();
    let h = Histogram::auto(&vals, 60);
    write_csv(&format!("fig6_{}.csv", cfg.dataset_kind.name()), &h.to_csv());
    println!("fig6 {}: {}", cfg.dataset_kind.name(), h.sparkline());
    Ok(h)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::RunConfig;
    use crate::data::synth::{Kind, SynthConfig};

    fn tiny_cfg() -> RunConfig {
        RunConfig {
            dataset_kind: Kind::RnaSeq,
            synth: SynthConfig { n: 150, dim: 128, seed: 3, ..Default::default() },
            metric: Metric::L1,
            ..Default::default()
        }
    }

    #[test]
    fn sweep_error_decreases_with_budget() {
        let cfg = tiny_cfg();
        let pts = error_vs_budget(&cfg, &[2.0, 64.0], 6, 0).unwrap();
        let err = |algo: &str, budget_rank: usize| {
            pts.iter()
                .filter(|p| p.algo == algo)
                .nth(budget_rank)
                .map(|p| p.error_rate)
                .unwrap()
        };
        assert!(err("corrsh", 1) <= err("corrsh", 0) + 1e-9);
        assert!(err("rand", 1) <= err("rand", 0) + 1e-9);
    }

    #[test]
    fn fig2_correlation_helps() {
        let d = fig2_toy_demo(4000, 11);
        assert!(
            d.p_flip_correlated <= d.p_flip_independent + 0.02,
            "corr {} vs ind {}",
            d.p_flip_correlated,
            d.p_flip_independent
        );
    }

    #[test]
    fn fig3_correlated_tighter() {
        let out = fig3_difference_histograms(&tiny_cfg(), 1500, 5).unwrap();
        for row in &out {
            let std_corr = row.rho * row.sigma;
            assert!(
                std_corr <= row.std_independent * 1.1,
                "{}: corr std {} vs ind {}",
                row.arm_kind,
                std_corr,
                row.std_independent
            );
            assert!(row.p_neg_correlated <= row.p_neg_independent + 0.05);
        }
    }

    #[test]
    fn fig4_gain_ratio_positive() {
        let out = fig4_delta_vs_rho(&tiny_cfg(), 1).unwrap();
        assert!(out.h2 > 0.0 && out.h2_tilde > 0.0);
        assert!(out.gain_ratio > 0.5, "gain {}", out.gain_ratio);
    }

    #[test]
    fn fig6_distances_positive() {
        let h = fig6_distance_to_medoid(&tiny_cfg(), 0).unwrap();
        assert!(h.count > 0);
        // high-dimensional data: no point sits on the medoid, so the
        // histogram's support starts strictly above zero (paper Fig 6)
        assert!(h.lo > 0.0, "distance histogram touches zero: lo={}", h.lo);
        assert_eq!(h.underflow + h.overflow, 0);
    }
}
