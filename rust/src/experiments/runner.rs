//! Trial runner: seeded, parallel, ledger-checked.
//!
//! Mirrors the paper's protocol (§3.1): every point is the average of many
//! trials where "the only variable across trials was the random seed,
//! varied 0–999 for reproducibility".

use std::sync::Arc;
use std::time::Duration;

use crate::Result;

use crate::bandits::{CorrSh, MedoidAlgorithm};
use crate::config::{EngineKind, RunConfig};
use crate::data::Data;
use crate::distance::Metric;
use crate::engine::{NativeEngine, PreparedEngine, PullEngine};
use crate::util::rng::Rng;
use crate::util::threads;

/// One trial's outcome.
#[derive(Clone, Debug)]
pub struct TrialOutcome {
    pub seed: u64,
    pub best: usize,
    pub pulls: u64,
    pub wall: Duration,
}

/// Aggregate over trials.
#[derive(Clone, Debug)]
pub struct Summary {
    pub trials: usize,
    pub error_rate: f64,
    pub mean_pulls_per_arm: f64,
    pub mean_wall: Duration,
    pub total_wall: Duration,
}

pub fn summarize(outcomes: &[TrialOutcome], truth: usize, n: usize) -> Summary {
    let trials = outcomes.len().max(1);
    let errors = outcomes.iter().filter(|o| o.best != truth).count();
    let pulls: f64 = outcomes.iter().map(|o| o.pulls as f64).sum::<f64>() / trials as f64;
    let total: Duration = outcomes.iter().map(|o| o.wall).sum();
    Summary {
        trials: outcomes.len(),
        error_rate: errors as f64 / trials as f64,
        mean_pulls_per_arm: pulls / n as f64,
        mean_wall: total / trials as u32,
        total_wall: total,
    }
}

/// Build the dataset once (generators are deterministic in the config seed).
pub fn build_data(cfg: &RunConfig) -> Arc<Data> {
    Arc::new(cfg.dataset_kind.generate(&cfg.synth))
}

/// Run `trials` seeded trials of `make_algo()` on `data`, parallel across
/// trials (each trial gets a single-threaded engine so pull accounting and
/// wall-clock are per-trial honest).
pub fn run_trials(
    make_algo: &(dyn Fn() -> Box<dyn MedoidAlgorithm> + Sync),
    data: &Arc<Data>,
    metric: Metric,
    trials: usize,
    base_seed: u64,
) -> Vec<TrialOutcome> {
    let workers = threads::default_threads();
    // One shared preparation (norms / row-reductions) for the whole trial
    // batch; per-trial engines used to redo the O(n·d) pass each.
    let prepared = Arc::new(PreparedEngine::prepare(data.clone(), metric));
    threads::parallel_map(trials, workers, |t| {
        let engine = NativeEngine::from_prepared(prepared.clone(), 1);
        let mut rng = Rng::seeded(base_seed + t as u64);
        let algo = make_algo();
        let res = algo.run(&engine, &mut rng);
        TrialOutcome {
            seed: base_seed + t as u64,
            best: res.best,
            pulls: res.pulls,
            wall: res.wall,
        }
    })
}

/// Run trials on a specific (possibly PJRT) engine, serially.
pub fn run_trials_on_engine(
    make_algo: &dyn Fn() -> Box<dyn MedoidAlgorithm>,
    engine: &dyn PullEngine,
    trials: usize,
    base_seed: u64,
) -> Vec<TrialOutcome> {
    (0..trials)
        .map(|t| {
            let mut rng = Rng::seeded(base_seed + t as u64);
            let res = make_algo().run(engine, &mut rng);
            TrialOutcome {
                seed: base_seed + t as u64,
                best: res.best,
                pulls: res.pulls,
                wall: res.wall,
            }
        })
        .collect()
}

/// Ground truth: exact sweep when affordable, else the paper's §3.1
/// procedure — the most frequently returned point of high-budget corrSH.
pub fn ground_truth(data: &Arc<Data>, metric: Metric, exact_limit: usize) -> usize {
    let n = data.n();
    if n <= exact_limit {
        let engine = NativeEngine::with_threads(data.clone(), metric, threads::default_threads());
        return crate::bandits::argmin(
            crate::bandits::exact::exact_thetas(&engine).into_iter(),
        );
    }
    // most-frequent corrSH answer across 15 generous-budget trials
    let outcomes = run_trials(
        &|| Box::new(CorrSh::with_pulls_per_arm(64.0)) as Box<dyn MedoidAlgorithm>,
        data,
        metric,
        15,
        7_000_000,
    );
    let mut counts = std::collections::HashMap::new();
    for o in &outcomes {
        *counts.entry(o.best).or_insert(0usize) += 1;
    }
    counts.into_iter().max_by_key(|&(_, c)| c).map(|(i, _)| i).unwrap_or(0)
}

/// Build an engine per the config (PJRT requires artifacts for the dim and
/// a build with the `pjrt` feature).
pub fn build_engine(cfg: &RunConfig, data: &Arc<Data>) -> Result<Box<dyn PullEngine>> {
    Ok(match cfg.engine {
        EngineKind::Native => Box::new(NativeEngine::with_threads(
            data.clone(),
            cfg.metric,
            threads::default_threads(),
        )),
        EngineKind::Pjrt => build_pjrt_engine(cfg, data)?,
    })
}

#[cfg(feature = "pjrt")]
fn build_pjrt_engine(cfg: &RunConfig, data: &Arc<Data>) -> Result<Box<dyn PullEngine>> {
    let rt = Arc::new(crate::runtime::Runtime::open(&cfg.artifacts_dir)?);
    let e = crate::engine::PjrtEngine::new(data.clone(), cfg.metric, rt)?;
    e.warmup()?;
    Ok(Box::new(e))
}

#[cfg(not(feature = "pjrt"))]
fn build_pjrt_engine(_cfg: &RunConfig, _data: &Arc<Data>) -> Result<Box<dyn PullEngine>> {
    crate::bail!(
        "engine `pjrt` requires a build with the `pjrt` cargo feature \
         (cargo run --features pjrt ...); this binary was built with the \
         default pure-Rust engine set"
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::AlgoConfig;
    use crate::data::synth::{Kind, SynthConfig};

    fn toy_cfg() -> RunConfig {
        RunConfig {
            dataset_kind: Kind::Gaussian,
            synth: SynthConfig {
                n: 200,
                dim: 12,
                seed: 5,
                outlier_frac: 0.05,
                ..Default::default()
            },
            metric: Metric::L2,
            algo: AlgoConfig::CorrSh { pulls_per_arm: 32.0 },
            ..Default::default()
        }
    }

    #[test]
    fn trials_deterministic_by_seed() {
        let cfg = toy_cfg();
        let data = build_data(&cfg);
        let mk = || cfg.algo.build(200);
        let a = run_trials(&mk, &data, cfg.metric, 4, 100);
        let b = run_trials(&mk, &data, cfg.metric, 4, 100);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.best, y.best);
            assert_eq!(x.pulls, y.pulls);
        }
    }

    #[test]
    fn ground_truth_is_planted_medoid() {
        let cfg = toy_cfg();
        let data = build_data(&cfg);
        assert_eq!(ground_truth(&data, cfg.metric, 20_000), 0);
        // the sampling path must agree on an easy instance
        assert_eq!(ground_truth(&data, cfg.metric, 10), 0);
    }

    #[test]
    fn summary_math() {
        let outs = vec![
            TrialOutcome { seed: 0, best: 0, pulls: 100, wall: Duration::from_millis(10) },
            TrialOutcome { seed: 1, best: 3, pulls: 300, wall: Duration::from_millis(30) },
        ];
        let s = summarize(&outs, 0, 100);
        assert_eq!(s.error_rate, 0.5);
        assert!((s.mean_pulls_per_arm - 2.0).abs() < 1e-12);
        assert_eq!(s.mean_wall, Duration::from_millis(20));
    }
}
