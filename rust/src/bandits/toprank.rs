//! TOPRANK [10] — two-phase baseline from the closeness-centrality
//! literature.
//!
//! Phase 1 (RAND-style): score all arms against m shared references; build
//! Hoeffding intervals from the empirical range. Phase 2: exactly evaluate
//! every arm whose lower bound is below the best arm's upper bound (the
//! candidate set that could still be the medoid), return the exact argmin
//! among them.

use std::time::Instant;

use crate::bandits::{MedoidAlgorithm, MedoidResult};
use crate::engine::PullEngine;
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct TopRank {
    /// Phase-1 references per arm.
    pub phase1_refs: usize,
    /// Confidence parameter for the Hoeffding interval (δ).
    pub delta: f64,
}

impl TopRank {
    pub fn new(phase1_refs: usize) -> Self {
        TopRank { phase1_refs, delta: 0.01 }
    }
}

impl MedoidAlgorithm for TopRank {
    fn name(&self) -> &'static str {
        "toprank"
    }

    fn run(&self, engine: &dyn PullEngine, rng: &mut Rng) -> MedoidResult {
        let start = Instant::now();
        let n = engine.n();
        if n <= 1 {
            return MedoidResult {
                best: 0,
                pulls: 0,
                wall: start.elapsed(),
                rounds: vec![],
                estimates: vec![(0, 0.0)],
            };
        }
        let m = self.phase1_refs.clamp(1, n);
        let mut pulls: u64 = 0;

        // ---- phase 1: shared-reference scoring -----------------------------
        let refs = rng.sample_without_replacement(n, m);
        let arms: Vec<usize> = (0..n).collect();
        let mut sums = vec![0f64; n];
        engine.pull_block(&arms, &refs, &mut sums);
        pulls = pulls.saturating_add((n * m) as u64);
        let means: Vec<f64> = sums.iter().map(|&s| s / m as f64).collect();

        // Hoeffding radius from the empirical distance range (distances are
        // bounded by the data's diameter; we estimate it from phase 1).
        let range = {
            // range of single distances ≈ max mean + spread; conservative:
            let max_mean = means.iter().cloned().fold(0.0, f64::max);
            (2.0 * max_mean).max(1e-9)
        };
        let radius = range * ((2.0 / self.delta).ln() / (2.0 * m as f64)).sqrt();

        // ---- phase 2: exact evaluation of the candidate set ------------------
        let best_phase1 = crate::bandits::argmin(means.iter().cloned());
        let threshold = means[best_phase1] + radius;
        let mut candidates: Vec<usize> =
            (0..n).filter(|&i| means[i] - radius <= threshold).collect();
        // guardrail: cap candidates at n/4 by tightening to the k smallest
        let cap = (n / 4).max(2);
        if candidates.len() > cap {
            // NaN-safe total order (both NaN signs last), point index as
            // deterministic tie-break.
            candidates.sort_unstable_by(|&a, &b| {
                crate::bandits::nan_last(means[a])
                    .total_cmp(&crate::bandits::nan_last(means[b]))
                    .then_with(|| a.cmp(&b))
            });
            candidates.truncate(cap);
        }

        let all: Vec<usize> = (0..n).collect();
        let mut best = (best_phase1, f64::INFINITY);
        let mut estimates: Vec<(usize, f64)> = Vec::with_capacity(candidates.len());
        let mut out = vec![0f64; candidates.len()];
        engine.pull_block(&candidates, &all, &mut out);
        pulls = pulls.saturating_add((candidates.len() * n) as u64);
        for (k, &c) in candidates.iter().enumerate() {
            let theta = out[k] / n as f64;
            estimates.push((c, theta));
            if !theta.is_nan() && theta < best.1 {
                best = (c, theta);
            }
        }

        MedoidResult {
            best: best.0,
            pulls,
            wall: start.elapsed(),
            rounds: vec![],
            estimates,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{gaussian, SynthConfig};
    use crate::distance::Metric;
    use crate::engine::{CountingEngine, NativeEngine};

    fn engine(n: usize) -> CountingEngine<NativeEngine> {
        let data = gaussian::generate(&SynthConfig {
            n,
            dim: 16,
            seed: 51,
            outlier_frac: 0.05,
            ..Default::default()
        });
        CountingEngine::new(NativeEngine::new(data, Metric::L2))
    }

    #[test]
    fn finds_planted_medoid_reliably() {
        let e = engine(300);
        for t in 0..5 {
            let res = TopRank::new(64).run(&e, &mut Rng::seeded(t));
            assert_eq!(res.best, 0, "trial {t}");
        }
    }

    #[test]
    fn cheaper_than_exact() {
        let e = engine(400);
        let res = TopRank::new(64).run(&e, &mut Rng::seeded(0));
        assert!(res.pulls < 400 * 400, "toprank cost {} >= exact", res.pulls);
        assert_eq!(res.pulls, e.pulls());
    }

    #[test]
    fn candidate_set_capped() {
        // tiny phase-1 budget → huge radius → cap must kick in
        let e = engine(200);
        let res = TopRank::new(2).run(&e, &mut Rng::seeded(0));
        // phase2 pulls = candidates * n <= (n/4)*n
        let phase2 = res.pulls - (200 * 2) as u64;
        assert!(phase2 <= (200 / 4) * 200, "phase2 {phase2}");
    }
}
