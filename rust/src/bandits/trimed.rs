//! trimed — triangle-inequality elimination for *exact* medoid
//! identification (Newling & Fleuret, arXiv 1605.06950), adapted to the
//! pull-engine substrate as corrSH's verification/fallback tier.
//!
//! The idea: pull a handful of **anchor** rows `d(a, ·)` and lower-bound
//! every candidate's centrality with the triangle inequality,
//!
//! ```text
//! Σ_j d(i, j)  ≥  Σ_j max_a |d(a, i) − d(a, j)|
//! ```
//!
//! then compute exact sums only for candidates (in ascending-bound order)
//! whose bound still undercuts the best exact sum seen so far. On clustered
//! data the bound eliminates almost everything and the pull count is far
//! below the exact sweep's n²; in the worst case (fully concentrated
//! distances, where no elimination is possible) it degrades to
//! `n² + anchors·n` — never silently wrong, at most modestly wasteful.
//!
//! **Cosine is not a metric**, so the raw triangle inequality does not
//! hold for it. The chord transform `δ = √(2·d_cos)` *is* one (it is the
//! Euclidean distance between the normalized vectors), giving
//! `d_cos(i, j) = δ(i, j)²/2 ≥ (δ(a, i) − δ(a, j))²/2`; anchor rows are
//! transformed once and the per-pair bound squares the chord gap.
//!
//! Exactness contract: candidate sums are computed through the same
//! `pull_block` f64-sum path [`Exact`] uses (per-arm sums are independent
//! of arm batching), elimination is strict (`bound > best` — ties always
//! compute), the running best orders lexicographically by
//! `(total_cmp, index)`, and NaN is handled conservatively: a NaN bound
//! never eliminates (NaN loses every `>` comparison) and a NaN sum is
//! skipped exactly like [`crate::bandits::argmin`] skips it. The property
//! test in `rust/tests/reuse_trimed.rs` pins medoid identity with `Exact`
//! across metrics × dense/sparse × shard widths.
//!
//! [`Exact`]: crate::bandits::Exact

use std::time::Instant;

use crate::bandits::{MedoidAlgorithm, MedoidResult};
use crate::distance::Metric;
use crate::engine::PullEngine;
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct Trimed {
    /// Anchor count: more anchors tighten the elimination bound at
    /// `anchors·n` extra pulls. Evenly spaced over the dataset
    /// (deterministic — trimed uses no randomness).
    pub anchors: usize,
}

impl Default for Trimed {
    fn default() -> Self {
        Trimed::new(4)
    }
}

impl Trimed {
    pub fn new(anchors: usize) -> Self {
        Trimed { anchors: anchors.max(1) }
    }
}

impl MedoidAlgorithm for Trimed {
    fn name(&self) -> &'static str {
        "trimed"
    }

    fn run(&self, engine: &dyn PullEngine, _rng: &mut Rng) -> MedoidResult {
        let start = Instant::now();
        let n = engine.n();
        if n <= 1 {
            return MedoidResult {
                best: 0,
                pulls: 0,
                wall: start.elapsed(),
                rounds: vec![],
                estimates: vec![(0, 0.0)],
            };
        }
        let a = self.anchors.clamp(1, n);
        // i·n/a is strictly increasing for a ≤ n, so anchors are distinct.
        let anchors: Vec<usize> = (0..a).map(|i| i * n / a).collect();
        let all: Vec<usize> = (0..n).collect();
        let mut pulls = 0u64;
        let cosine = engine.metric() == Metric::Cosine;

        // Anchor rows (chord-transformed for cosine so the triangle
        // inequality applies).
        let mut rows: Vec<Vec<f32>> = Vec::with_capacity(a);
        for &anc in &anchors {
            let mut row = vec![0f32; n];
            engine.pull_matrix(&[anc], &all, &mut row);
            pulls = pulls.saturating_add(n as u64);
            if cosine {
                for v in row.iter_mut() {
                    *v = (2.0 * v.max(0.0)).sqrt();
                }
            }
            rows.push(row);
        }

        // Lower bounds: lb(i) = Σ_j max_a bound_a(i, j). O(a·n²) flops,
        // zero pulls. An anchor's own bound is exact (the a = i term is
        // d(i, j) itself), so anchors sort first among equals and seed the
        // scan with real sums early. NaN bounds contribute 0 (`>` is false
        // for NaN), so poisoned rows are never over-eliminated.
        let mut lb = vec![0f64; n];
        for (i, l) in lb.iter_mut().enumerate() {
            let mut acc = 0f64;
            for j in 0..n {
                let mut b = 0f32;
                for row in &rows {
                    let diff = (row[i] - row[j]).abs();
                    let bound = if cosine { diff * diff * 0.5 } else { diff };
                    if bound > b {
                        b = bound;
                    }
                }
                acc += b as f64;
            }
            *l = acc;
        }

        // Scan in ascending-bound order, computing exact sums through the
        // same blocked f64 path Exact uses, until every remaining bound
        // strictly exceeds the best exact sum.
        let mut order: Vec<usize> = (0..n).collect();
        order.sort_unstable_by(|&x, &y| lb[x].total_cmp(&lb[y]).then(x.cmp(&y)));

        let mut sum_out = [0f64; 1];
        let mut best: Option<(f64, usize)> = None;
        let mut estimates: Vec<(usize, f64)> = Vec::new();
        for &i in &order {
            if let Some((bs, _)) = best {
                if lb[i] > bs {
                    break; // sorted: everything after is eliminated too
                }
            }
            engine.pull_block(&[i], &all, &mut sum_out);
            pulls = pulls.saturating_add(n as u64);
            let s = sum_out[0];
            estimates.push((i, s / n as f64));
            if s.is_nan() {
                continue; // argmin semantics: NaN can never be the medoid
            }
            best = Some(match best {
                None => (s, i),
                Some((bs, bi)) => {
                    if s.total_cmp(&bs).is_lt() || (s.total_cmp(&bs).is_eq() && i < bi) {
                        (s, i)
                    } else {
                        (bs, bi)
                    }
                }
            });
        }

        MedoidResult {
            best: best.map(|(_, i)| i).unwrap_or(0),
            pulls,
            wall: start.elapsed(),
            rounds: vec![],
            estimates,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bandits::Exact;
    use crate::data::synth::{gaussian, netflix, rnaseq, SynthConfig};
    use crate::engine::{CountingEngine, NativeEngine};

    #[test]
    fn matches_exact_and_counts_pulls_honestly() {
        let data = gaussian::generate(&SynthConfig {
            n: 300,
            dim: 16,
            seed: 12,
            outlier_frac: 0.05,
            ..Default::default()
        });
        let engine = CountingEngine::new(NativeEngine::new(data, Metric::L2));
        let truth = Exact::new().run(&engine, &mut Rng::seeded(0)).best;
        engine.reset();
        let res = Trimed::new(4).run(&engine, &mut Rng::seeded(0));
        assert_eq!(res.best, truth, "trimed disagreed with the exact sweep");
        assert_eq!(res.pulls, engine.pulls(), "ledger vs engine counter");
    }

    #[test]
    fn clustered_data_eliminates_most_candidates() {
        // Well-separated mixture: the anchor bounds put whole far clusters
        // above the best sum, so the exact-sum scan touches only a small
        // fraction of the points and stays well under the n² sweep.
        let n = 600;
        let data = gaussian::generate_mixture(&SynthConfig {
            n,
            dim: 16,
            seed: 3,
            clusters: 4,
            ..Default::default()
        });
        let engine = CountingEngine::new(NativeEngine::new(data, Metric::L2));
        let truth = Exact::new().run(&engine, &mut Rng::seeded(0)).best;
        engine.reset();
        // 8 evenly spaced anchors land in every cluster of the interleaved
        // generator layout, so inter-cluster distances bound tightly.
        let res = Trimed::new(8).run(&engine, &mut Rng::seeded(0));
        assert_eq!(res.best, truth);
        let n2 = (n as u64) * (n as u64);
        assert!(
            res.pulls * 2 < n2,
            "elimination too weak: {} pulls vs n² = {n2}",
            res.pulls
        );
    }

    #[test]
    fn chord_bound_is_exact_on_sparse_cosine() {
        let data = netflix::generate(&SynthConfig {
            n: 250,
            dim: 512,
            seed: 8,
            density: 0.02,
            ..Default::default()
        });
        let engine = CountingEngine::new(NativeEngine::new(data, Metric::Cosine));
        let truth = Exact::new().run(&engine, &mut Rng::seeded(0)).best;
        engine.reset();
        let res = Trimed::new(6).run(&engine, &mut Rng::seeded(0));
        assert_eq!(res.best, truth, "cosine chord bound broke exactness");
    }

    #[test]
    fn sparse_l1_matches_exact() {
        let data =
            rnaseq::generate(&SynthConfig { n: 280, dim: 256, seed: 6, ..Default::default() });
        let engine = NativeEngine::new(data, Metric::L1);
        let truth = Exact::new().run(&engine, &mut Rng::seeded(0)).best;
        let res = Trimed::new(4).run(&engine, &mut Rng::seeded(0));
        assert_eq!(res.best, truth);
    }

    #[test]
    fn degenerate_sizes_are_safe() {
        for n in [1usize, 2, 3, 5] {
            let data = gaussian::generate(&SynthConfig {
                n,
                dim: 4,
                seed: 1,
                ..Default::default()
            });
            let engine = NativeEngine::new(data, Metric::L2);
            let truth = Exact::new().run(&engine, &mut Rng::seeded(0)).best;
            // More anchors than points must clamp, not panic.
            let res = Trimed::new(16).run(&engine, &mut Rng::seeded(0));
            assert_eq!(res.best, truth, "n = {n}");
        }
    }
}
