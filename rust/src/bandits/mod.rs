//! Medoid-identification algorithms: the paper's Correlated Sequential
//! Halving plus every baseline it is evaluated against.
//!
//! | Module | Algorithm | Paper role |
//! |---|---|---|
//! | [`corr_sh`] | Correlated Sequential Halving (Algorithm 1) | the contribution |
//! | [`seq_halving`] | uncorrelated Sequential Halving | ablation isolating the ρ gain |
//! | [`meddit`] | Med-dit (UCB, δ=1/n) [1] | main adaptive baseline |
//! | [`rand_baseline`] | RAND [2] | non-adaptive baseline |
//! | [`toprank`] | TOPRANK [10] | related-work baseline |
//! | [`exact`] | exact O(n²) sweep | ground truth + Table 1 column |
//! | [`trimed`] | trimed triangle elimination [1605.06950] | exact tier, sub-n² |
//!
//! All algorithms see the data only through [`PullEngine`]: one pull = one
//! distance computation = the unit of the paper's x-axes.

pub mod corr_sh;
pub mod exact;
pub mod meddit;
pub mod rand_baseline;
pub mod seq_halving;
pub mod toprank;
pub mod trimed;

pub use corr_sh::CorrSh;
pub use exact::Exact;
pub use meddit::Meddit;
pub use rand_baseline::RandBaseline;
pub use seq_halving::SeqHalving;
pub use toprank::TopRank;
pub use trimed::Trimed;

use std::time::Duration;

use crate::engine::PullEngine;
use crate::util::rng::Rng;

/// Per-round trace (corrSH / SH) for debugging and the experiment logs.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct RoundLog {
    pub r: usize,
    pub survivors: usize,
    pub t: usize,
    pub pulls: u64,
}

/// Outcome of one algorithm run.
#[derive(Clone, Debug)]
pub struct MedoidResult {
    /// Index of the reported medoid.
    pub best: usize,
    /// Distance computations consumed (the algorithm's own ledger; the
    /// harness cross-checks it against the engine's pull counter).
    pub pulls: u64,
    pub wall: Duration,
    pub rounds: Vec<RoundLog>,
    /// Estimated centralities for the arms still tracked at exit (exact
    /// algorithms fill all n; bandit algorithms fill what they measured).
    pub estimates: Vec<(usize, f64)>,
}

/// A medoid identification algorithm.
pub trait MedoidAlgorithm {
    fn name(&self) -> &'static str;

    /// Run on `engine`'s dataset using `rng` for all randomness.
    fn run(&self, engine: &dyn PullEngine, rng: &mut Rng) -> MedoidResult;
}

/// Argmin over f64 (first index on ties), shared by every algorithm.
///
/// NaN-safe: candidates compare under `f64::total_cmp` and NaN values are
/// skipped outright, so a poisoned estimate (NaN distance upstream) can
/// never be reported as the medoid — regardless of NaN sign bits, which
/// `total_cmp` alone would order *below* every number for -NaN.
pub(crate) fn argmin(values: impl IntoIterator<Item = f64>) -> usize {
    let mut best = 0usize;
    let mut best_v = f64::INFINITY;
    for (i, v) in values.into_iter().enumerate() {
        if !v.is_nan() && v.total_cmp(&best_v).is_lt() {
            best_v = v;
            best = i;
        }
    }
    best
}

/// Sort key mapping NaN of *either sign* to +∞, used by every selection
/// sort. `total_cmp` alone orders -NaN *below* every number, which would
/// let a sign-flipped NaN score win a smallest-first selection; routing
/// keys through this helper guarantees poisoned scores sort last.
#[inline]
pub(crate) fn nan_last(x: f64) -> f64 {
    if x.is_nan() {
        f64::INFINITY
    } else {
        x
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmin_first_on_tie() {
        assert_eq!(argmin([3.0, 1.0, 1.0, 2.0]), 1);
        assert_eq!(argmin([f64::INFINITY]), 0);
        assert_eq!(argmin([]), 0);
    }

    #[test]
    fn argmin_skips_nan() {
        assert_eq!(argmin([f64::NAN, 2.0, 1.0]), 2);
        assert_eq!(argmin([2.0, -f64::NAN, 1.0]), 2, "-NaN must not win");
        assert_eq!(argmin([f64::NAN, f64::NAN]), 0, "all-NaN falls back to 0");
        assert_eq!(argmin([1.0, f64::NEG_INFINITY]), 1, "-inf is a real value");
    }

    #[test]
    fn nan_last_orders_both_nan_signs_after_everything() {
        let mut xs = [1.0, -f64::NAN, f64::NEG_INFINITY, f64::NAN, 0.0];
        xs.sort_unstable_by(|a, b| nan_last(*a).total_cmp(&nan_last(*b)));
        assert_eq!(xs[0], f64::NEG_INFINITY);
        assert_eq!(xs[1], 0.0);
        assert_eq!(xs[2], 1.0);
        assert!(xs[3].is_nan() && xs[4].is_nan(), "NaNs must sort last: {xs:?}");
    }

    /// Shared smoke check: every algorithm finds the planted medoid of an
    /// easy gaussian instance.
    #[test]
    fn all_algorithms_find_planted_medoid() {
        use crate::data::synth::{gaussian, SynthConfig};
        use crate::distance::Metric;
        use crate::engine::{CountingEngine, NativeEngine};

        let data = gaussian::generate(&SynthConfig {
            n: 256,
            dim: 24,
            seed: 77,
            outlier_frac: 0.04,
            ..Default::default()
        });
        let engine = CountingEngine::new(NativeEngine::new(data, Metric::L2));
        let thetas = crate::bandits::exact::exact_thetas(&engine);
        let mut sorted = thetas.clone();
        sorted.sort_by(f64::total_cmp);
        let q10 = sorted[256 / 10];
        engine.reset();

        // (algorithm, exact-hit required?) — uncorrelated SH keeps the full
        // reference variance by design, so it only owes a top-decile arm.
        let algos: Vec<(Box<dyn MedoidAlgorithm>, bool)> = vec![
            (Box::new(CorrSh::with_pulls_per_arm(48.0)), true),
            (Box::new(SeqHalving::with_pulls_per_arm(64.0)), false),
            (Box::new(Meddit::new(1.0 / 256.0)), true),
            (Box::new(RandBaseline::new(200)), true),
            (Box::new(TopRank::new(64)), true),
            (Box::new(Exact::new()), true),
            (Box::new(Trimed::new(4)), true),
        ];
        for (algo, must_hit) in algos {
            let mut rng = Rng::seeded(1);
            let before = engine.pulls();
            let res = algo.run(&engine, &mut rng);
            let consumed = engine.pulls() - before;
            if must_hit {
                assert_eq!(res.best, 0, "{} missed the planted medoid", algo.name());
            } else {
                assert!(
                    thetas[res.best] <= q10,
                    "{} returned a non-central arm (θ={:.4})",
                    algo.name(),
                    thetas[res.best]
                );
            }
            assert_eq!(
                res.pulls,
                consumed,
                "{}'s ledger disagrees with the engine counter",
                algo.name()
            );
        }
    }
}
