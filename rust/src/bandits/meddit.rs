//! Med-dit (Medoid-Bandit) [1] — the UCB baseline the paper improves on.
//!
//! Fixed-confidence best-arm identification for the *minimum* mean: each arm
//! i keeps a running mean θ̂_i over references drawn i.i.d. **with
//! replacement** (independent across arms — the direct bandit reduction).
//! Confidence radius after T_i pulls:
//!
//! ```text
//! β_i = σ̂ · sqrt( 2 log(1/δ) / T_i ),   δ = 1/n in the paper's runs
//! ```
//!
//! Loop: pull the arm with the smallest LCB (θ̂ − β); an arm pulled n times
//! is promoted to its exact centrality (β = 0), mirroring Med-dit's
//! "evaluate exactly once a point has been sampled enough". Stop when one
//! arm's UCB is below every other arm's LCB (or the safety budget runs out).
//!
//! σ̂ is estimated online from the first `init_pulls` per arm, as in the
//! reference implementation. The `batch` knob pulls the best-B arms per
//! step: the paper notes UCB's per-step overhead dominates wall-clock —
//! batching is the standard mitigation and is what our Table 1 runs use.

use std::time::Instant;

use crate::bandits::{MedoidAlgorithm, MedoidResult};
use crate::engine::PullEngine;
use crate::metrics::Welford;
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct Meddit {
    /// Target error probability (paper: 1/n).
    pub delta: f64,
    /// Initial pulls per arm (paper: 1 for plots, 16 for wall-clock).
    pub init_pulls: usize,
    /// Arms pulled per scheduling step.
    pub batch: usize,
    /// Pulls added to each selected arm per step.
    pub pulls_per_step: usize,
    /// Safety cap on total pulls (0 = n² i.e. exact-computation cost).
    pub max_pulls: u64,
}

impl Meddit {
    pub fn new(delta: f64) -> Self {
        // init_pulls = 2 so the pooled within-arm variance (σ̂ of a single
        // pull) is estimable; the paper uses 1 for plotting and "16 or some
        // larger constant" in practice. batch x pulls_per_step trades the
        // per-step O(n log n) scheduling sort against pull granularity —
        // the UCB-overhead effect the paper's §3 discusses.
        Meddit { delta, init_pulls: 2, batch: 16, pulls_per_step: 16, max_pulls: 0 }
    }

    pub fn with_budget_cap(mut self, cap: u64) -> Self {
        self.max_pulls = cap;
        self
    }
}

struct Arm {
    idx: usize,
    count: usize,
    mean: f64,
    /// exact centrality once count reaches n
    exact: bool,
}

impl MedoidAlgorithm for Meddit {
    fn name(&self) -> &'static str {
        "meddit"
    }

    fn run(&self, engine: &dyn PullEngine, rng: &mut Rng) -> MedoidResult {
        let start = Instant::now();
        let n = engine.n();
        if n <= 1 {
            return MedoidResult {
                best: 0,
                pulls: 0,
                wall: start.elapsed(),
                rounds: vec![],
                estimates: vec![(0, 0.0)],
            };
        }
        let cap = if self.max_pulls == 0 { (n as u64) * (n as u64) } else { self.max_pulls };
        let log_term = (1.0 / self.delta).ln().max(1.0);
        let mut pulls: u64 = 0;

        // --- init: `init_pulls` i.i.d. references per arm -------------------
        // Individual distances (pull_matrix, not sums) so σ̂ can be the
        // *pooled within-arm* std of a single pull — the quantity the
        // Hoeffding radius needs. Estimating it from the spread of arm means
        // would conflate the Δ_i spread and stall the stopping rule.
        let mut arms: Vec<Arm> = (0..n)
            .map(|idx| Arm { idx, count: 0, mean: 0.0, exact: false })
            .collect();
        let mut pooled = Welford::default();
        {
            let t = self.init_pulls.max(1).min(n);
            let mut row = vec![0f32; t];
            for arm in arms.iter_mut() {
                let refs = rng.sample_with_replacement(n, t);
                engine.pull_matrix(&[arm.idx], &refs, &mut row);
                pulls = pulls.saturating_add(t as u64);
                arm.count = t;
                arm.mean = row.iter().map(|&x| x as f64).sum::<f64>() / t as f64;
                if t >= 2 {
                    for &x in &row {
                        pooled.push(x as f64 - arm.mean);
                    }
                }
            }
        }
        let sigma = pooled.std().max(1e-9);

        let radius = |count: usize, sigma: f64| -> f64 {
            if count >= usize::MAX {
                return 0.0;
            }
            sigma * (2.0 * log_term / count as f64).sqrt()
        };

        // --- UCB loop --------------------------------------------------------
        while pulls < cap {
            // candidate arm order by LCB
            let mut order: Vec<usize> = (0..n).collect();
            let lcb_of = |arm: &Arm| {
                arm.mean - if arm.exact { 0.0 } else { radius(arm.count, sigma) }
            };
            // NaN-safe total order (both NaN signs last) + arm index as
            // deterministic tie-break.
            order.sort_unstable_by(|&a, &b| {
                let la = crate::bandits::nan_last(lcb_of(&arms[a]));
                let lb = crate::bandits::nan_last(lcb_of(&arms[b]));
                la.total_cmp(&lb).then_with(|| a.cmp(&b))
            });

            // stopping rule: best arm's UCB <= everyone else's LCB
            let best = order[0];
            let best_ucb = arms[best].mean
                + if arms[best].exact { 0.0 } else { radius(arms[best].count, sigma) };
            let mut separated = true;
            for &o in &order[1..] {
                let lcb =
                    arms[o].mean - if arms[o].exact { 0.0 } else { radius(arms[o].count, sigma) };
                if lcb < best_ucb {
                    separated = false;
                    break;
                }
            }
            if separated {
                break;
            }

            // pull the most promising `batch` non-exact arms
            let mut pulled_any = false;
            for &o in order.iter().take(self.batch.max(1)) {
                if arms[o].exact {
                    continue;
                }
                pulled_any = true;
                let t = self.pulls_per_step.max(1);
                if arms[o].count + t >= n {
                    // promote to exact: full sweep (costs n pulls, as in [1])
                    let all: Vec<usize> = (0..n).collect();
                    let mut out = [0f64];
                    engine.pull_block(&[arms[o].idx], &all, &mut out);
                    pulls = pulls.saturating_add(n as u64);
                    arms[o].mean = out[0] / n as f64;
                    arms[o].count = n;
                    arms[o].exact = true;
                } else {
                    let refs = rng.sample_with_replacement(n, t);
                    let mut out = [0f64];
                    engine.pull_block(&[arms[o].idx], &refs, &mut out);
                    pulls = pulls.saturating_add(t as u64);
                    let total = arms[o].mean * arms[o].count as f64 + out[0];
                    arms[o].count += t;
                    arms[o].mean = total / arms[o].count as f64;
                }
                if pulls >= cap {
                    break;
                }
            }
            if !pulled_any {
                break; // everything exact
            }
        }

        // (mean, idx) total order ⇒ the unique minimum is the *first* index
        // among tied means, and NaN means (either sign) sort last instead
        // of winning.
        let best = arms
            .iter()
            .min_by(|a, b| {
                crate::bandits::nan_last(a.mean)
                    .total_cmp(&crate::bandits::nan_last(b.mean))
                    .then_with(|| a.idx.cmp(&b.idx))
            })
            .map(|a| a.idx)
            .unwrap_or(0);
        MedoidResult {
            best,
            pulls,
            wall: start.elapsed(),
            rounds: vec![],
            estimates: arms.iter().map(|a| (a.idx, a.mean)).collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{gaussian, SynthConfig};
    use crate::distance::Metric;
    use crate::engine::{CountingEngine, NativeEngine};

    fn engine(n: usize) -> CountingEngine<NativeEngine> {
        let data = gaussian::generate(&SynthConfig {
            n,
            dim: 16,
            seed: 21,
            outlier_frac: 0.05,
            ..Default::default()
        });
        CountingEngine::new(NativeEngine::new(data, Metric::L2))
    }

    #[test]
    fn finds_planted_medoid() {
        // δ = 1/n has a small finite-n error floor (paper Remark 3 reports
        // 6% for Med-dit on Netflix-100k) — require ≥ 8/10 here.
        let e = engine(200);
        let mut hits = 0;
        for t in 0..10 {
            let res = Meddit::new(1.0 / 200.0).run(&e, &mut Rng::seeded(t));
            hits += (res.best == 0) as usize;
        }
        assert!(hits >= 8, "meddit hit rate {hits}/10");
    }

    #[test]
    fn never_exceeds_exact_cost_by_much() {
        let e = engine(128);
        let res = Meddit::new(1.0 / 128.0).run(&e, &mut Rng::seeded(5));
        // cap = n^2; one batch step may overshoot by batch*n pulls at most
        assert!(res.pulls <= 128 * 128 + 16 * 128, "pulls {}", res.pulls);
        assert_eq!(res.pulls, e.pulls());
    }

    #[test]
    fn adaptive_beats_exact_on_easy_instance() {
        // The gaussian core has many near-ties, so UCB spends heavily on the
        // top arms (that is exactly the gap corrSH exploits); it must still
        // come in clearly under the n² exact cost.
        let e = engine(400);
        let res = Meddit::new(1.0 / 400.0).run(&e, &mut Rng::seeded(3));
        assert_eq!(res.best, 0);
        assert!(
            res.pulls < 400 * 400 * 3 / 4,
            "meddit used {} pulls, barely better than exact",
            res.pulls
        );
    }

    #[test]
    fn budget_cap_respected() {
        let e = engine(100);
        let res = Meddit::new(0.01).with_budget_cap(1_000).run(&e, &mut Rng::seeded(1));
        // may overshoot by at most one batch step
        assert!(res.pulls <= 1_000 + 16 * 8 + 100, "pulls {}", res.pulls);
    }
}
