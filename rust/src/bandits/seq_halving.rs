//! Uncorrelated Sequential Halving [7] — the ablation baseline.
//!
//! Identical schedule to Correlated Sequential Halving, but each arm draws
//! its own i.i.d. reference multiset (with replacement, as a direct bandit
//! reduction would). The *only* delta vs `corr_sh` is the reference draw, so
//! the measured gap between the two is exactly the paper's correlation
//! effect (ablation E8 in DESIGN.md).

use std::time::Instant;

use crate::bandits::corr_sh::Budget;
use crate::bandits::{MedoidAlgorithm, MedoidResult, RoundLog};
use crate::coordinator::{rounds, BudgetLedger};
use crate::engine::PullEngine;
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct SeqHalving {
    pub budget: Budget,
}

impl SeqHalving {
    pub fn new(budget: Budget) -> Self {
        SeqHalving { budget }
    }

    pub fn with_total_pulls(t: u64) -> Self {
        SeqHalving::new(Budget::Total(t))
    }

    pub fn with_pulls_per_arm(x: f64) -> Self {
        SeqHalving::new(Budget::PerArm(x))
    }
}

impl MedoidAlgorithm for SeqHalving {
    fn name(&self) -> &'static str {
        "seq-halving"
    }

    fn run(&self, engine: &dyn PullEngine, rng: &mut Rng) -> MedoidResult {
        let start = Instant::now();
        let n = engine.n();
        if n <= 1 {
            return MedoidResult {
                best: 0,
                pulls: 0,
                wall: start.elapsed(),
                rounds: vec![],
                estimates: vec![(0, 0.0)],
            };
        }
        let total = self.budget.total(n);
        let mut ledger = BudgetLedger::new(total, n);
        let mut survivors: Vec<usize> = (0..n).collect();
        let mut round_logs = Vec::new();
        let mut estimates: Vec<(usize, f64)> = Vec::new();

        for r in 0..rounds::ceil_log2(n) {
            let t = rounds::t_r(total, survivors.len(), n);
            let pulls = (survivors.len() * t) as u64;
            ledger.charge_round(r, pulls).expect("schedule overspent (bug)");

            // Independent reference draw PER ARM (with replacement) — the
            // direct bandit reduction the paper improves on.
            let mut sums = vec![0f64; survivors.len()];
            for (k, &arm) in survivors.iter().enumerate() {
                let refs = rng.sample_with_replacement(n, t);
                let mut out = [0f64];
                engine.pull_block(&[arm], &refs, &mut out);
                sums[k] = out[0];
            }

            round_logs.push(RoundLog { r, survivors: survivors.len(), t, pulls });
            estimates = survivors
                .iter()
                .zip(&sums)
                .map(|(&i, &s)| (i, s / t as f64))
                .collect();

            // NOTE: t = n is *not* an exact exit here — references are drawn
            // with replacement, so even n samples per arm stay noisy. The
            // schedule still halves to a single survivor.
            let keep = survivors.len().div_ceil(2);
            let mut order: Vec<usize> = (0..survivors.len()).collect();
            // Same NaN-safe total order (both NaN signs last) + index
            // tie-break as corrSH, so the ablation differs from it ONLY in
            // the reference draw.
            order.sort_unstable_by(|&a, &b| {
                crate::bandits::nan_last(sums[a])
                    .total_cmp(&crate::bandits::nan_last(sums[b]))
                    .then_with(|| survivors[a].cmp(&survivors[b]))
            });
            survivors = order[..keep].iter().map(|&k| survivors[k]).collect();
            if survivors.len() <= 1 {
                break;
            }
        }

        MedoidResult {
            best: survivors[0],
            pulls: ledger.spent(),
            wall: start.elapsed(),
            rounds: round_logs,
            estimates,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{gaussian, SynthConfig};
    use crate::distance::Metric;
    use crate::engine::{CountingEngine, NativeEngine};

    #[test]
    fn same_schedule_as_corrsh() {
        let data =
            gaussian::generate(&SynthConfig { n: 200, dim: 8, seed: 1, ..Default::default() });
        let engine = CountingEngine::new(NativeEngine::new(data, Metric::L2));
        let a = SeqHalving::with_pulls_per_arm(16.0).run(&engine, &mut Rng::seeded(0));
        let b = crate::bandits::CorrSh::with_pulls_per_arm(16.0).run(&engine, &mut Rng::seeded(0));
        let shape =
            |r: &[RoundLog]| r.iter().map(|x| (x.survivors, x.t)).collect::<Vec<_>>();
        assert_eq!(shape(&a.rounds), shape(&b.rounds));
    }

    #[test]
    fn returns_near_central_arm() {
        // Without correlation the estimator differences keep the full
        // reference-point variance (that is the paper's whole point), so we
        // do not demand the exact medoid — only an arm in the most-central
        // 10% by true θ, reliably.
        let data = gaussian::generate(&SynthConfig {
            n: 256,
            dim: 16,
            seed: 2,
            outlier_frac: 0.05,
            ..Default::default()
        });
        let engine = CountingEngine::new(NativeEngine::new(data, Metric::L2));
        let thetas = crate::bandits::exact::exact_thetas(&engine);
        let mut sorted = thetas.clone();
        sorted.sort_by(f64::total_cmp);
        let q10 = sorted[256 / 10];
        let mut hits = 0;
        for t in 0..10 {
            let res = SeqHalving::with_pulls_per_arm(128.0).run(&engine, &mut Rng::seeded(t));
            hits += (thetas[res.best] <= q10) as usize;
        }
        assert!(hits >= 9, "uncorrelated SH top-decile rate {hits}/10");
    }
}
