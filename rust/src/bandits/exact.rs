//! Exact O(n²) medoid computation — ground truth and the "Exact Comp."
//! column of Table 1.
//!
//! Sweeps the full distance matrix in arm-blocks through the engine's
//! batched hot path (so even the exact baseline benefits from the
//! vectorized/PJRT substrate — wall-clock comparisons stay apples-to-apples)
//! and returns exact centralities for every arm.

use std::time::Instant;

use crate::bandits::{argmin, MedoidAlgorithm, MedoidResult};
use crate::engine::PullEngine;
use crate::util::rng::Rng;

#[derive(Clone, Debug, Default)]
pub struct Exact {
    /// Arm-block size for the sweep (memory/parallelism knob).
    pub block: usize,
}

impl Exact {
    pub fn new() -> Self {
        Exact { block: 512 }
    }
}

impl MedoidAlgorithm for Exact {
    fn name(&self) -> &'static str {
        "exact"
    }

    fn run(&self, engine: &dyn PullEngine, _rng: &mut Rng) -> MedoidResult {
        let start = Instant::now();
        let n = engine.n();
        if n <= 1 {
            return MedoidResult {
                best: 0,
                pulls: 0,
                wall: start.elapsed(),
                rounds: vec![],
                estimates: vec![(0, 0.0)],
            };
        }
        let refs: Vec<usize> = (0..n).collect();
        let mut sums = vec![0f64; n];
        let block = self.block.max(1);
        let mut estimates = Vec::with_capacity(n);
        for chunk_start in (0..n).step_by(block) {
            let arms: Vec<usize> = (chunk_start..(chunk_start + block).min(n)).collect();
            let out = &mut sums[chunk_start..chunk_start + arms.len()];
            engine.pull_block(&arms, &refs, out);
        }
        for (i, &s) in sums.iter().enumerate() {
            estimates.push((i, s / n as f64));
        }
        let best = argmin(estimates.iter().map(|&(_, v)| v));
        MedoidResult {
            best,
            pulls: (n as u64) * (n as u64),
            wall: start.elapsed(),
            rounds: vec![],
            estimates,
        }
    }
}

/// Convenience: exact centralities θ_i for the stats engine.
pub fn exact_thetas(engine: &dyn PullEngine) -> Vec<f64> {
    let mut rng = Rng::seeded(0); // unused by Exact
    let res = Exact::new().run(engine, &mut rng);
    let mut thetas = vec![0f64; engine.n()];
    for (i, v) in res.estimates {
        thetas[i] = v;
    }
    thetas
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{gaussian, SynthConfig};
    use crate::distance::Metric;
    use crate::engine::{CountingEngine, NativeEngine};

    #[test]
    fn matches_naive_double_loop() {
        let data =
            gaussian::generate(&SynthConfig { n: 60, dim: 8, seed: 41, ..Default::default() });
        let engine = CountingEngine::new(NativeEngine::new(data, Metric::L2));
        let res = Exact::new().run(&engine, &mut Rng::seeded(0));
        // naive recomputation
        let mut best = (0usize, f64::INFINITY);
        for i in 0..60 {
            let mut s = 0f64;
            for j in 0..60 {
                s += engine.pull(i, j) as f64;
            }
            let theta = s / 60.0;
            let est = res.estimates[i].1;
            assert!((est - theta).abs() < 1e-4, "θ_{i}: {est} vs {theta}");
            if theta < best.1 {
                best = (i, theta);
            }
        }
        assert_eq!(res.best, best.0);
        assert_eq!(res.pulls, 3600);
    }

    #[test]
    fn block_size_does_not_change_answer() {
        let data =
            gaussian::generate(&SynthConfig { n: 97, dim: 8, seed: 42, ..Default::default() });
        let engine = CountingEngine::new(NativeEngine::new(data, Metric::L2));
        let a = Exact { block: 7 }.run(&engine, &mut Rng::seeded(0));
        let b = Exact { block: 1024 }.run(&engine, &mut Rng::seeded(0));
        assert_eq!(a.best, b.best);
    }
}
