//! RAND [2] — the non-adaptive baseline.
//!
//! Draw one uniform reference subset of size m (without replacement) and
//! score **every** arm against all of it; return the empirical argmin. The
//! paper runs it at m = 1000 pulls/arm (Table 1 & figures). Note RAND is
//! incidentally "correlated" in the paper's sense (same references for all
//! arms) — what it lacks is *adaptivity*; corrSH beats it by concentrating
//! budget on the surviving arms.

use std::time::Instant;

use crate::bandits::{argmin, MedoidAlgorithm, MedoidResult};
use crate::engine::PullEngine;
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct RandBaseline {
    /// References per arm (m). Clamped to n.
    pub refs_per_arm: usize,
}

impl RandBaseline {
    pub fn new(refs_per_arm: usize) -> Self {
        RandBaseline { refs_per_arm }
    }
}

impl MedoidAlgorithm for RandBaseline {
    fn name(&self) -> &'static str {
        "rand"
    }

    fn run(&self, engine: &dyn PullEngine, rng: &mut Rng) -> MedoidResult {
        let start = Instant::now();
        let n = engine.n();
        if n <= 1 {
            return MedoidResult {
                best: 0,
                pulls: 0,
                wall: start.elapsed(),
                rounds: vec![],
                estimates: vec![(0, 0.0)],
            };
        }
        let m = self.refs_per_arm.clamp(1, n);
        let refs = rng.sample_without_replacement(n, m);
        let arms: Vec<usize> = (0..n).collect();
        let mut sums = vec![0f64; n];
        engine.pull_block(&arms, &refs, &mut sums);
        let estimates: Vec<(usize, f64)> =
            arms.iter().map(|&i| (i, sums[i] / m as f64)).collect();
        let best = argmin(estimates.iter().map(|&(_, v)| v));
        MedoidResult {
            best,
            pulls: (n * m) as u64,
            wall: start.elapsed(),
            rounds: vec![],
            estimates,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{gaussian, SynthConfig};
    use crate::distance::Metric;
    use crate::engine::{CountingEngine, NativeEngine};

    fn engine(n: usize) -> CountingEngine<NativeEngine> {
        let data = gaussian::generate(&SynthConfig {
            n,
            dim: 16,
            seed: 31,
            outlier_frac: 0.05,
            ..Default::default()
        });
        CountingEngine::new(NativeEngine::new(data, Metric::L2))
    }

    #[test]
    fn full_budget_equals_exact() {
        let e = engine(100);
        // m = n: every arm scored against everyone → exact medoid
        let res = RandBaseline::new(100).run(&e, &mut Rng::seeded(0));
        assert_eq!(res.best, 0);
        assert_eq!(res.pulls, 100 * 100);
    }

    #[test]
    fn pull_count_is_n_times_m() {
        let e = engine(150);
        let res = RandBaseline::new(40).run(&e, &mut Rng::seeded(1));
        assert_eq!(res.pulls, 150 * 40);
        assert_eq!(res.pulls, e.pulls());
    }

    #[test]
    fn m_clamped_to_n() {
        let e = engine(50);
        let res = RandBaseline::new(5_000).run(&e, &mut Rng::seeded(2));
        assert_eq!(res.pulls, 50 * 50);
    }

    #[test]
    fn reasonable_accuracy_at_modest_m() {
        let e = engine(300);
        let mut hits = 0;
        for t in 0..10 {
            hits += (RandBaseline::new(60).run(&e, &mut Rng::seeded(t)).best == 0) as usize;
        }
        assert!(hits >= 8, "RAND hit rate {hits}/10 at m=60");
    }
}
