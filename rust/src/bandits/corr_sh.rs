//! **Correlated Sequential Halving** — Algorithm 1 of the paper, verbatim
//! semantics.
//!
//! The single algorithmic change vs classical Sequential Halving [7] is line
//! 3: each round draws ONE reference set `J_r` (uniform, without
//! replacement) shared by every surviving arm, so the estimator differences
//! `θ̂_1 − θ̂_i` are built from *correlated* samples and concentrate at rate
//! `ρ_i σ` instead of `σ` (Theorem 2.1). The round loop:
//!
//! ```text
//! S_0 = [n]
//! for r = 0 .. ⌈log₂ n⌉ − 1:
//!     t_r = clamp(⌊T / (|S_r| ⌈log₂ n⌉)⌋, 1, n)
//!     J_r ~ Unif([n] choose t_r)                  # shared — the correlation
//!     θ̂_i = (1/t_r) Σ_{j∈J_r} d(x_i, x_j)   ∀ i ∈ S_r
//!     if t_r = n: return argmin θ̂              # exact ⇒ zero uncertainty
//!     S_{r+1} = the ⌈|S_r|/2⌉ arms with smallest θ̂
//! return the arm in S_{⌈log₂ n⌉}
//! ```
//!
//! The pull workload of each round goes through `PullEngine::pull_block`
//! (one correlated batch), which the PJRT engine tiles into AOT bucket jobs
//! via the coordinator's batch planner.

use std::time::Instant;

use crate::bandits::{MedoidAlgorithm, MedoidResult, RoundLog};
use crate::coordinator::{rounds, BudgetLedger};
use crate::engine::PullEngine;
use crate::util::rng::Rng;

/// Budget specification: the paper sweeps pulls/arm on its x-axes.
#[derive(Clone, Copy, Debug)]
pub enum Budget {
    /// Total distance computations T.
    Total(u64),
    /// x pulls per arm: T = x·n.
    PerArm(f64),
}

impl Budget {
    pub fn total(&self, n: usize) -> u64 {
        match *self {
            Budget::Total(t) => t,
            Budget::PerArm(x) => (x * n as f64).ceil() as u64,
        }
    }
}

#[derive(Clone, Debug)]
pub struct CorrSh {
    pub budget: Budget,
}

impl CorrSh {
    pub fn new(budget: Budget) -> Self {
        CorrSh { budget }
    }

    pub fn with_total_pulls(t: u64) -> Self {
        CorrSh::new(Budget::Total(t))
    }

    pub fn with_pulls_per_arm(x: f64) -> Self {
        CorrSh::new(Budget::PerArm(x))
    }
}

impl MedoidAlgorithm for CorrSh {
    fn name(&self) -> &'static str {
        "corrsh"
    }

    fn run(&self, engine: &dyn PullEngine, rng: &mut Rng) -> MedoidResult {
        let start = Instant::now();
        let n = engine.n();
        if n <= 1 {
            return MedoidResult {
                best: 0,
                pulls: 0,
                wall: start.elapsed(),
                rounds: vec![],
                estimates: vec![(0, 0.0)],
            };
        }
        let total = self.budget.total(n);
        let mut ledger = BudgetLedger::new(total, n);
        let mut survivors: Vec<usize> = (0..n).collect();
        let mut round_logs = Vec::new();
        let mut sums = vec![0f32; n];
        let mut last_estimates: Vec<(usize, f64)> = Vec::new();

        for r in 0..rounds::ceil_log2(n) {
            let t = rounds::t_r(total, survivors.len(), n);
            let pulls = (survivors.len() * t) as u64;
            ledger
                .charge_round(r, pulls)
                .expect("halving schedule exceeded its own budget (bug)");

            // Line 3: ONE shared reference set for the whole round.
            let refs = rng.sample_without_replacement(n, t);

            let out = &mut sums[..survivors.len()];
            engine.pull_block(&survivors, &refs, out);

            round_logs.push(RoundLog { r, survivors: survivors.len(), t, pulls });
            last_estimates = survivors
                .iter()
                .zip(out.iter())
                .map(|(&i, &s)| (i, s as f64 / t as f64))
                .collect();

            if t == n {
                // Exact centralities: output the argmin immediately.
                let k = crate::bandits::argmin(last_estimates.iter().map(|&(_, v)| v));
                return MedoidResult {
                    best: last_estimates[k].0,
                    pulls: ledger.spent(),
                    wall: start.elapsed(),
                    rounds: round_logs,
                    estimates: last_estimates,
                };
            }

            // Keep the ⌈|S_r|/2⌉ arms with smallest θ̂.
            let keep = survivors.len().div_ceil(2);
            let mut order: Vec<usize> = (0..survivors.len()).collect();
            order.sort_unstable_by(|&a, &b| {
                out[a].partial_cmp(&out[b]).unwrap_or(std::cmp::Ordering::Equal)
            });
            survivors = order[..keep].iter().map(|&k| survivors[k]).collect();
            if survivors.len() <= 1 {
                break;
            }
        }

        MedoidResult {
            best: survivors[0],
            pulls: ledger.spent(),
            wall: start.elapsed(),
            rounds: round_logs,
            estimates: last_estimates,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{gaussian, rnaseq, SynthConfig};
    use crate::distance::Metric;
    use crate::engine::{CountingEngine, NativeEngine};
    use crate::util::testing;

    fn planted_engine(n: usize, seed: u64) -> CountingEngine<NativeEngine> {
        let data = gaussian::generate(&SynthConfig {
            n,
            dim: 16,
            seed,
            outlier_frac: 0.05,
            ..Default::default()
        });
        CountingEngine::new(NativeEngine::new(data, Metric::L2))
    }

    #[test]
    fn finds_planted_medoid_with_modest_budget() {
        let engine = planted_engine(512, 3);
        let mut hits = 0;
        for trial in 0..20 {
            let mut rng = Rng::seeded(trial);
            let res = CorrSh::with_pulls_per_arm(32.0).run(&engine, &mut rng);
            hits += (res.best == 0) as usize;
        }
        assert!(hits >= 19, "corrSH hit rate {hits}/20 too low");
    }

    #[test]
    fn respects_budget_property() {
        testing::check(
            "corrsh-budget",
            16, // engine construction is expensive; fewer cases
            |rng| {
                let n = rng.range(8, 400);
                let per_arm = rng.range(1, 50) as f64;
                (n, per_arm, rng.next_u64())
            },
            |&(n, per_arm, seed), prng| {
                let engine = planted_engine(n, seed);
                let res = CorrSh::with_pulls_per_arm(per_arm).run(&engine, prng);
                // budget + the t_r>=1 clamp slack (see BudgetLedger::new)
                let cap = (per_arm * n as f64).ceil() as u64 + 2 * n as u64 + 64;
                if res.pulls > cap {
                    return Err(format!("pulls {} > cap {cap}", res.pulls));
                }
                if res.pulls != engine.pulls() {
                    return Err("ledger vs engine counter mismatch".into());
                }
                engine.reset();
                Ok(())
            },
        );
    }

    #[test]
    fn round_structure_halves() {
        let engine = planted_engine(300, 4);
        let mut rng = Rng::seeded(9);
        let res = CorrSh::with_pulls_per_arm(8.0).run(&engine, &mut rng);
        for w in res.rounds.windows(2) {
            assert_eq!(w[1].survivors, w[0].survivors.div_ceil(2));
        }
        assert_eq!(res.rounds[0].survivors, 300);
    }

    #[test]
    fn huge_budget_exact_exit_is_perfect() {
        // t_0 = n ⇒ the answer equals the exact medoid every time
        let engine = planted_engine(128, 5);
        let mut rng = Rng::seeded(0);
        let res = CorrSh::with_pulls_per_arm(10_000.0).run(&engine, &mut rng);
        assert_eq!(res.rounds.len(), 1);
        assert_eq!(res.rounds[0].t, 128);
        assert_eq!(res.best, 0);
    }

    #[test]
    fn works_on_sparse_l1() {
        let data =
            rnaseq::generate(&SynthConfig { n: 300, dim: 256, seed: 6, ..Default::default() });
        let engine = CountingEngine::new(NativeEngine::new(data, Metric::L1));
        // ground truth by exact sweep
        let truth = crate::bandits::Exact::new().run(&engine, &mut Rng::seeded(0)).best;
        let mut hits = 0;
        for trial in 0..10 {
            let mut rng = Rng::seeded(100 + trial);
            if CorrSh::with_pulls_per_arm(64.0).run(&engine, &mut rng).best == truth {
                hits += 1;
            }
        }
        assert!(hits >= 9, "sparse l1 hit rate {hits}/10");
    }

    #[test]
    fn n_leq_1_trivial() {
        let engine = planted_engine(1, 7);
        let res = CorrSh::with_pulls_per_arm(5.0).run(&engine, &mut Rng::seeded(0));
        assert_eq!(res.best, 0);
        assert_eq!(res.pulls, 0);
    }
}
