//! **Correlated Sequential Halving** — Algorithm 1 of the paper, verbatim
//! semantics.
//!
//! The single algorithmic change vs classical Sequential Halving [7] is line
//! 3: each round draws ONE reference set `J_r` (uniform, without
//! replacement) shared by every surviving arm, so the estimator differences
//! `θ̂_1 − θ̂_i` are built from *correlated* samples and concentrate at rate
//! `ρ_i σ` instead of `σ` (Theorem 2.1). The round loop:
//!
//! ```text
//! S_0 = [n]
//! for r = 0 .. ⌈log₂ n⌉ − 1:
//!     t_r = clamp(⌊T / (|S_r| ⌈log₂ n⌉)⌋, 1, n)
//!     J_r ~ Unif([n] choose t_r)                  # shared — the correlation
//!     θ̂_i = (1/t_r) Σ_{j∈J_r} d(x_i, x_j)   ∀ i ∈ S_r
//!     if t_r = n: return argmin θ̂              # exact ⇒ zero uncertainty
//!     S_{r+1} = the ⌈|S_r|/2⌉ arms with smallest θ̂
//! return the arm in S_{⌈log₂ n⌉}
//! ```
//!
//! The pull workload of each round goes through `PullEngine::pull_block`
//! (one correlated batch), which the PJRT engine tiles into AOT bucket jobs
//! via the coordinator's batch planner.
//!
//! The round loop itself is exposed as [`correlated_halving_argmin`], a
//! generalized inner oracle over an arbitrary arm space scored against a
//! reference universe: `CorrSh::run` is the `arms == refs == dataset`
//! special case, and the k-medoids BUILD/SWAP phases
//! ([`crate::kmedoids`]) reuse the same oracle with marginal-loss and
//! swap-loss scores.
//!
//! Numerical policy (see DESIGN.md §9): round sums accumulate in `f64` end
//! to end (`t · d(x_i, x_j)` overflows f32's 24-bit mantissa long before
//! the paper's dataset scales), and all survivor selection orders with
//! `f64::total_cmp` plus an arm-index tie-break, so a NaN distance (e.g.
//! cosine on a zero-norm row) sorts *last* deterministically instead of
//! corrupting the halving order.

use std::time::Instant;

use crate::bandits::{MedoidAlgorithm, MedoidResult, RoundLog};
use crate::coordinator::{rounds, BudgetLedger};
use crate::engine::PullEngine;
use crate::util::rng::Rng;

/// Budget specification: the paper sweeps pulls/arm on its x-axes.
#[derive(Clone, Copy, Debug)]
pub enum Budget {
    /// Total distance computations T.
    Total(u64),
    /// x pulls per arm: T = x·n.
    PerArm(f64),
}

impl Budget {
    /// Total pull budget for an `n`-arm instance.
    ///
    /// Both variants are hardened against degenerate knobs. `PerArm`: `x ≤ 0`
    /// and NaN clamp to the floor of one pull per arm (`n`), and `x·n` beyond
    /// `u64::MAX` (including `x = ∞`) saturates. `Total`: a request like
    /// `{"total": 0}` clamps up to the same floor — round 0 always pays
    /// `n` pulls anyway (the `t_r ≥ 1` clamp), so a sub-`n` total only ever
    /// "worked" on `BudgetLedger` slack. The result is always in
    /// `[n, u64::MAX]` instead of wrapping or silently returning 0.
    pub fn total(&self, n: usize) -> u64 {
        match *self {
            Budget::Total(t) => t.max(n.max(1) as u64),
            Budget::PerArm(x) => {
                let floor = n.max(1) as u64;
                if x.is_nan() || x <= 0.0 {
                    return floor;
                }
                let t = (x * n as f64).ceil();
                if t >= u64::MAX as f64 {
                    u64::MAX
                } else {
                    (t as u64).max(floor)
                }
            }
        }
    }
}

/// Outcome of one generalized correlated-halving run (arm indices are in
/// `[0, n_arms)`; the caller owns any mapping to dataset rows or swap
/// pairs).
#[derive(Clone, Debug)]
pub struct HalvingOutcome {
    /// Winning arm index.
    pub best: usize,
    /// Pulls charged by the schedule ledger (`Σ_r |S_r|·t_r`).
    pub pulls: u64,
    /// Pulls the executing engine *reported* doing, aggregated by the
    /// ledger from each block's report (saturating). Equal to `pulls` for
    /// local engines; in the distributed path it is what workers actually
    /// charged — still equal in steady state, since re-dispatched segments
    /// are only counted once (DESIGN.md §15).
    pub reported_pulls: u64,
    pub rounds: Vec<RoundLog>,
    /// Estimates for the arms still tracked at exit.
    pub estimates: Vec<(usize, f64)>,
    /// True when a round reached `t_r = n_refs` (exact scores ⇒ immediate
    /// argmin exit).
    pub exact_exit: bool,
}

/// Generalized Algorithm 1 inner loop: correlated sequential halving over
/// `n_arms` arms scored against a reference universe of `n_refs` points.
///
/// `score_block(arms, refs, out)` must fill `out[k]` with the **sum** of
/// arm `arms[k]`'s scores over `refs` (f64, accumulated however the caller
/// likes — the engines accumulate in f64). It is called once per round with
/// one shared reference draw, which is exactly the correlation property of
/// the paper. The medoid problem is the special case
/// `n_arms == n_refs == n` with `score = d(x_i, x_j)` ([`CorrSh::run`]);
/// k-medoids BUILD/SWAP pass marginal-loss / swap-loss scores.
///
/// Selection is NaN-safe and fully deterministic: survivors are ordered by
/// `f64::total_cmp` on the round sums with the arm index as tie-break, so
/// duplicate points (bitwise-equal sums under a shared reference set) and
/// NaN scores (sorted last) can never make the halving order depend on
/// sort internals or thread count.
pub fn correlated_halving_argmin(
    n_arms: usize,
    n_refs: usize,
    total_budget: u64,
    rng: &mut Rng,
    score_block: &mut dyn FnMut(&[usize], &[usize], &mut [f64]),
) -> HalvingOutcome {
    correlated_halving_argmin_reported(n_arms, n_refs, total_budget, rng, &mut |arms, refs, out| {
        score_block(arms, refs, out);
        (arms.len() * refs.len()) as u64
    })
}

/// [`correlated_halving_argmin`] with pull *reporting*: `score_block`
/// additionally returns how many pulls its engine actually executed for the
/// block, and the ledger aggregates those reports (saturating) alongside
/// the scheduled charges. This is the distributed hook — worker report
/// frames flow through here so budget accounting reflects remote reality —
/// while local callers use the plain wrapper, which reports the scheduled
/// `|arms|·|refs|` per block.
pub fn correlated_halving_argmin_reported(
    n_arms: usize,
    n_refs: usize,
    total_budget: u64,
    rng: &mut Rng,
    score_block: &mut dyn FnMut(&[usize], &[usize], &mut [f64]) -> u64,
) -> HalvingOutcome {
    assert!(n_refs >= 1, "correlated_halving_argmin: empty reference universe");
    assert!(n_arms >= 1, "correlated_halving_argmin: empty arm space");
    if n_arms == 1 {
        return HalvingOutcome {
            best: 0,
            pulls: 0,
            reported_pulls: 0,
            rounds: vec![],
            estimates: vec![(0, 0.0)],
            exact_exit: false,
        };
    }
    let mut ledger = BudgetLedger::new(total_budget, n_arms);
    let mut survivors: Vec<usize> = (0..n_arms).collect();
    let mut round_logs = Vec::new();
    let mut sums = vec![0f64; n_arms];
    let mut last_estimates: Vec<(usize, f64)> = Vec::new();
    let log_rounds = rounds::ceil_log2(n_arms);

    for r in 0..log_rounds {
        let t = rounds::t_r_capped(total_budget, survivors.len(), log_rounds, n_refs);
        let pulls = (survivors.len() as u64) * (t as u64);
        ledger
            .charge_round(r, pulls)
            .expect("halving schedule exceeded its own budget (bug)");

        // Line 3: ONE shared reference set for the whole round.
        let refs = rng.sample_without_replacement(n_refs, t);

        let out = &mut sums[..survivors.len()];
        let reported = score_block(&survivors, &refs, out);
        ledger.report_remote(reported);

        round_logs.push(RoundLog { r, survivors: survivors.len(), t, pulls });
        last_estimates = survivors
            .iter()
            .zip(out.iter())
            .map(|(&i, &s)| (i, s / t as f64))
            .collect();

        if t == n_refs {
            // Exact scores: output the argmin immediately.
            let k = crate::bandits::argmin(last_estimates.iter().map(|&(_, v)| v));
            return HalvingOutcome {
                best: last_estimates[k].0,
                pulls: ledger.spent(),
                reported_pulls: ledger.remote_reported(),
                rounds: round_logs,
                estimates: last_estimates,
                exact_exit: true,
            };
        }

        // Keep the ⌈|S_r|/2⌉ arms with smallest sums — total order, NaN of
        // either sign last (`nan_last`: -NaN would otherwise sort *first*
        // under total_cmp), arm index as the deterministic tie-break.
        let keep = survivors.len().div_ceil(2);
        let mut order: Vec<usize> = (0..survivors.len()).collect();
        order.sort_unstable_by(|&a, &b| {
            crate::bandits::nan_last(out[a])
                .total_cmp(&crate::bandits::nan_last(out[b]))
                .then_with(|| survivors[a].cmp(&survivors[b]))
        });
        survivors = order[..keep].iter().map(|&k| survivors[k]).collect();
        if survivors.len() <= 1 {
            break;
        }
    }

    HalvingOutcome {
        best: survivors[0],
        pulls: ledger.spent(),
        reported_pulls: ledger.remote_reported(),
        rounds: round_logs,
        estimates: last_estimates,
        exact_exit: false,
    }
}

#[derive(Clone, Debug)]
pub struct CorrSh {
    pub budget: Budget,
}

impl CorrSh {
    pub fn new(budget: Budget) -> Self {
        CorrSh { budget }
    }

    pub fn with_total_pulls(t: u64) -> Self {
        CorrSh::new(Budget::Total(t))
    }

    pub fn with_pulls_per_arm(x: f64) -> Self {
        CorrSh::new(Budget::PerArm(x))
    }
}

impl MedoidAlgorithm for CorrSh {
    fn name(&self) -> &'static str {
        "corrsh"
    }

    fn run(&self, engine: &dyn PullEngine, rng: &mut Rng) -> MedoidResult {
        let start = Instant::now();
        let n = engine.n();
        if n <= 1 {
            return MedoidResult {
                best: 0,
                pulls: 0,
                wall: start.elapsed(),
                rounds: vec![],
                estimates: vec![(0, 0.0)],
            };
        }
        let total = self.budget.total(n);
        let outcome =
            correlated_halving_argmin_reported(n, n, total, rng, &mut |arms, refs, out| {
                // Engines fed by remote report frames (the distributed
                // coordinator) expose a monotone reported-pull counter; the
                // delta across the block is what workers actually charged.
                // Local engines report the scheduled block size.
                let before = engine.reported_pulls();
                engine.pull_block(arms, refs, out);
                match (before, engine.reported_pulls()) {
                    (Some(b), Some(a)) => a.saturating_sub(b),
                    _ => (arms.len() * refs.len()) as u64,
                }
            });
        MedoidResult {
            best: outcome.best,
            pulls: outcome.pulls,
            wall: start.elapsed(),
            rounds: outcome.rounds,
            estimates: outcome.estimates,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{gaussian, rnaseq, SynthConfig};
    use crate::data::{Data, DenseData};
    use crate::distance::Metric;
    use crate::engine::{CountingEngine, NativeEngine};
    use crate::util::testing;

    fn planted_engine(n: usize, seed: u64) -> CountingEngine<NativeEngine> {
        let data = gaussian::generate(&SynthConfig {
            n,
            dim: 16,
            seed,
            outlier_frac: 0.05,
            ..Default::default()
        });
        CountingEngine::new(NativeEngine::new(data, Metric::L2))
    }

    #[test]
    fn finds_planted_medoid_with_modest_budget() {
        let engine = planted_engine(512, 3);
        let mut hits = 0;
        for trial in 0..20 {
            let mut rng = Rng::seeded(trial);
            let res = CorrSh::with_pulls_per_arm(32.0).run(&engine, &mut rng);
            hits += (res.best == 0) as usize;
        }
        assert!(hits >= 19, "corrSH hit rate {hits}/20 too low");
    }

    #[test]
    fn respects_budget_property() {
        testing::check(
            "corrsh-budget",
            16, // engine construction is expensive; fewer cases
            |rng| {
                let n = rng.range(8, 400);
                let per_arm = rng.range(1, 50) as f64;
                (n, per_arm, rng.next_u64())
            },
            |&(n, per_arm, seed), prng| {
                let engine = planted_engine(n, seed);
                let res = CorrSh::with_pulls_per_arm(per_arm).run(&engine, prng);
                // budget + the t_r>=1 clamp slack (see BudgetLedger::new)
                let cap = (per_arm * n as f64).ceil() as u64 + 2 * n as u64 + 64;
                if res.pulls > cap {
                    return Err(format!("pulls {} > cap {cap}", res.pulls));
                }
                if res.pulls != engine.pulls() {
                    return Err("ledger vs engine counter mismatch".into());
                }
                engine.reset();
                Ok(())
            },
        );
    }

    #[test]
    fn round_structure_halves() {
        let engine = planted_engine(300, 4);
        let mut rng = Rng::seeded(9);
        let res = CorrSh::with_pulls_per_arm(8.0).run(&engine, &mut rng);
        for w in res.rounds.windows(2) {
            assert_eq!(w[1].survivors, w[0].survivors.div_ceil(2));
        }
        assert_eq!(res.rounds[0].survivors, 300);
    }

    #[test]
    fn huge_budget_exact_exit_is_perfect() {
        // t_0 = n ⇒ the answer equals the exact medoid every time
        let engine = planted_engine(128, 5);
        let mut rng = Rng::seeded(0);
        let res = CorrSh::with_pulls_per_arm(10_000.0).run(&engine, &mut rng);
        assert_eq!(res.rounds.len(), 1);
        assert_eq!(res.rounds[0].t, 128);
        assert_eq!(res.best, 0);
    }

    #[test]
    fn works_on_sparse_l1() {
        let data =
            rnaseq::generate(&SynthConfig { n: 300, dim: 256, seed: 6, ..Default::default() });
        let engine = CountingEngine::new(NativeEngine::new(data, Metric::L1));
        // ground truth by exact sweep
        let truth = crate::bandits::Exact::new().run(&engine, &mut Rng::seeded(0)).best;
        let mut hits = 0;
        for trial in 0..10 {
            let mut rng = Rng::seeded(100 + trial);
            if CorrSh::with_pulls_per_arm(64.0).run(&engine, &mut rng).best == truth {
                hits += 1;
            }
        }
        assert!(hits >= 9, "sparse l1 hit rate {hits}/10");
    }

    #[test]
    fn n_leq_1_trivial() {
        let engine = planted_engine(1, 7);
        let res = CorrSh::with_pulls_per_arm(5.0).run(&engine, &mut Rng::seeded(0));
        assert_eq!(res.best, 0);
        assert_eq!(res.pulls, 0);
    }

    #[test]
    fn budget_per_arm_edge_cases_clamp() {
        // x <= 0 and NaN clamp to one pull per arm.
        assert_eq!(Budget::PerArm(0.0).total(100), 100);
        assert_eq!(Budget::PerArm(-3.5).total(100), 100);
        assert_eq!(Budget::PerArm(f64::NAN).total(100), 100);
        // Non-finite / overflowing x·n saturates instead of wrapping to 0.
        assert_eq!(Budget::PerArm(f64::INFINITY).total(100), u64::MAX);
        assert_eq!(Budget::PerArm(1e18).total(1_000), u64::MAX);
        // Sane values are unchanged (and never below the floor).
        assert_eq!(Budget::PerArm(2.5).total(10), 25);
        assert_eq!(Budget::PerArm(1e-9).total(10), 10);
        // Total is clamped into [n, u64::MAX] exactly like PerArm: a
        // sub-n request (e.g. a server `{"total": 0}`) floors at one pull
        // per arm instead of surviving on ledger slack alone.
        assert_eq!(Budget::Total(7).total(100), 100);
        assert_eq!(Budget::Total(0).total(100), 100);
        assert_eq!(Budget::Total(100).total(100), 100);
        assert_eq!(Budget::Total(101).total(100), 101);
        assert_eq!(Budget::Total(u64::MAX).total(100), u64::MAX);
        // n = 0/1 degenerate instances keep a nonzero floor.
        assert_eq!(Budget::PerArm(f64::NAN).total(0), 1);
        assert_eq!(Budget::Total(0).total(0), 1);
        assert_eq!(Budget::Total(0).total(1), 1);
    }

    #[test]
    fn nan_poisoned_arm_sorts_last_and_is_never_selected() {
        // A NaN distance (e.g. cosine on a zero-norm row) used to hit a
        // NaN-unsafe unwrap_or(Equal) comparator and silently corrupt the
        // halving order. With total_cmp the poisoned arm sorts last, is
        // dropped in round 0, and the run stays deterministic.
        let n = 64;
        let dim = 4;
        let mut rng = Rng::seeded(11);
        let mut raw = vec![0f32; n * dim];
        for v in raw.iter_mut().skip(dim) {
            *v = rng.gaussian() as f32;
        }
        raw[7 * dim..8 * dim].fill(f32::NAN); // poison arm 7
        let data = Data::Dense(DenseData::new(n, dim, raw));
        let engine = NativeEngine::new(data, Metric::L2);

        let a = CorrSh::with_pulls_per_arm(16.0).run(&engine, &mut Rng::seeded(3));
        let b = CorrSh::with_pulls_per_arm(16.0).run(&engine, &mut Rng::seeded(3));
        assert_ne!(a.best, 7, "NaN-poisoned arm won the halving");
        assert_eq!(a.best, b.best, "NaN ordering made the run non-deterministic");
        assert_eq!(a.pulls, b.pulls);
        assert!(engine.nan_pulls() > 0, "NaN pulls were not counted");
        // Exact exit also never reports the poisoned arm (argmin skips NaN).
        let c = CorrSh::with_pulls_per_arm(1e6).run(&engine, &mut Rng::seeded(0));
        assert_ne!(c.best, 7);
    }

    #[test]
    fn large_magnitude_estimates_match_exact_sweep() {
        // Precision regression: with t = n and distances ~1e7, the old f32
        // round sums lost ~2^-24-relative precision per add (≫1e-6 after
        // hundreds of refs). The f64 path must match a scalar f64 sweep to
        // 1e-6 relative.
        let n = 512;
        let dim = 8;
        let mut rng = Rng::seeded(21);
        let raw: Vec<f32> = (0..n * dim).map(|_| (rng.gaussian() * 1e7) as f32).collect();
        let data = Data::Dense(DenseData::new(n, dim, raw));
        let engine = NativeEngine::new(data, Metric::L2);

        // Huge budget forces the exact exit: estimates are full-sweep means.
        let res = CorrSh::with_pulls_per_arm(1e9).run(&engine, &mut Rng::seeded(0));
        assert_eq!(res.rounds[0].t, n);
        assert_eq!(res.estimates.len(), n);
        for &(i, est) in &res.estimates {
            let mut acc = 0f64;
            for j in 0..n {
                acc += engine.pull(i, j) as f64;
            }
            let want = acc / n as f64;
            let rel = (est - want).abs() / want.abs().max(1.0);
            assert!(rel < 1e-6, "arm {i}: estimate {est} vs exact {want} (rel {rel:.3e})");
        }
    }

    #[test]
    fn generalized_oracle_handles_split_universes() {
        // 10 arms scored against 40 refs: arm i's score of ref j is
        // |i·4 − j|, so arm 5 (closest to the middle of the universe) wins.
        let outcome = correlated_halving_argmin(
            10,
            40,
            10 * 40 * 4,
            &mut Rng::seeded(1),
            &mut |arms, refs, out| {
                for (k, &a) in arms.iter().enumerate() {
                    out[k] = refs.iter().map(|&r| ((a * 4) as f64 - r as f64).abs()).sum();
                }
            },
        );
        assert!(outcome.exact_exit, "budget covers t = n_refs");
        assert_eq!(outcome.best, 5);
        assert!(outcome.rounds.iter().all(|r| r.t <= 40));
    }

    #[test]
    fn reported_pulls_aggregate_from_score_blocks() {
        // The reported total is the ledger's saturating aggregate of what
        // each block said it executed — here each block over-reports by one
        // pull (as a re-dispatching engine legitimately can), so the
        // reported total is scheduled + rounds while `pulls` stays exactly
        // the schedule.
        let outcome = correlated_halving_argmin_reported(
            32,
            32,
            32 * 8,
            &mut Rng::seeded(2),
            &mut |arms, refs, out| {
                for (k, &a) in arms.iter().enumerate() {
                    out[k] = (a as f64 + 1.0) * refs.len() as f64;
                }
                (arms.len() * refs.len()) as u64 + 1
            },
        );
        assert_eq!(outcome.best, 0);
        assert_eq!(
            outcome.reported_pulls,
            outcome.pulls + outcome.rounds.len() as u64,
            "each round over-reported exactly one pull"
        );
        // The plain wrapper reports the schedule: the two totals agree.
        let mut score = |arms: &[usize], refs: &[usize], out: &mut [f64]| {
            for (k, &a) in arms.iter().enumerate() {
                out[k] = (a as f64 + 1.0) * refs.len() as f64;
            }
        };
        let local = correlated_halving_argmin(32, 32, 32 * 8, &mut Rng::seeded(2), &mut score);
        assert_eq!(local.reported_pulls, local.pulls);
    }

    #[test]
    fn negative_nan_scores_also_sort_last() {
        // total_cmp alone orders -NaN *first*; the nan_last key must keep a
        // sign-flipped poisoned arm from surviving the halving.
        for budget in [32u64, 100_000] {
            let outcome = correlated_halving_argmin(
                8,
                8,
                budget,
                &mut Rng::seeded(4),
                &mut |arms, refs, out| {
                    for (k, &a) in arms.iter().enumerate() {
                        out[k] = if a == 2 {
                            -f64::NAN
                        } else {
                            (a as f64 + 1.0) * refs.len() as f64
                        };
                    }
                },
            );
            assert_ne!(outcome.best, 2, "-NaN arm won (budget {budget})");
            assert_eq!(outcome.best, 0, "smallest finite score must win");
        }
    }
}
