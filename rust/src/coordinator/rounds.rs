//! The halving schedule of Algorithm 1.
//!
//! Round r keeps `⌈|S_r|/2⌉` arms and draws `t_r` shared references:
//!
//! ```text
//! t_r = clamp(⌊ T / (|S_r| · ⌈log₂ n⌉) ⌋, 1, n)
//! ```
//!
//! If `t_r = n` the round's estimates are *exact* centralities, so the
//! algorithm outputs the argmin immediately (paper line 5-6). These
//! functions are pure so the schedule is testable and the experiment
//! harness can predict pull counts without running anything.

/// `⌈log₂ n⌉` as used by Algorithm 1 (n = 1 ⇒ 0 rounds).
pub fn ceil_log2(n: usize) -> usize {
    if n <= 1 {
        0
    } else {
        usize::BITS as usize - (n - 1).leading_zeros() as usize
    }
}

/// One planned round.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct RoundPlan {
    pub r: usize,
    /// |S_r| — surviving arms entering the round.
    pub survivors: usize,
    /// t_r — shared references drawn this round.
    pub t: usize,
    /// survivors × t pulls charged this round.
    pub pulls: u64,
    /// true ⇒ estimates are exact and the algorithm stops here.
    pub exact_exit: bool,
}

/// t_r for a given budget/survivor count (Algorithm 1 line 3).
pub fn t_r(total_budget: u64, survivors: usize, n: usize) -> usize {
    t_r_capped(total_budget, survivors, ceil_log2(n), n)
}

/// Generalized t_r for a split arm/reference universe: `log_rounds` halving
/// rounds over the arm space (`⌈log₂ n_arms⌉`), with the shared reference
/// draw clamped to the reference-universe size `max_t`. The medoid problem
/// is the special case `log_rounds = ⌈log₂ n⌉, max_t = n`; the k-medoids
/// BUILD/SWAP oracles halve over candidate/swap arms while still drawing
/// references from the `n` data points.
pub fn t_r_capped(total_budget: u64, survivors: usize, log_rounds: usize, max_t: usize) -> usize {
    let log = log_rounds.max(1) as u64;
    // Clamp in the u64 domain *before* narrowing to usize: on a 32-bit
    // target `quotient as usize` truncates high bits, and a huge budget
    // could wrap to a tiny t instead of capping at max_t.
    let cap = max_t.max(1) as u64;
    let t = (total_budget / (survivors.max(1) as u64 * log)).min(cap) as usize;
    t.max(1)
}

/// The complete (deterministic) halving schedule for (n, T).
pub fn halving_rounds(n: usize, total_budget: u64) -> Vec<RoundPlan> {
    let mut out = Vec::new();
    if n <= 1 {
        return out;
    }
    let mut survivors = n;
    for r in 0..ceil_log2(n) {
        let t = t_r(total_budget, survivors, n);
        let exact_exit = t == n;
        out.push(RoundPlan {
            r,
            survivors,
            t,
            pulls: survivors as u64 * t as u64,
            exact_exit,
        });
        if exact_exit || survivors <= 1 {
            break;
        }
        survivors = survivors.div_ceil(2);
    }
    out
}

/// Total pulls the schedule will consume.
pub fn planned_pulls(n: usize, total_budget: u64) -> u64 {
    halving_rounds(n, total_budget).iter().map(|r| r.pulls).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::testing;

    #[test]
    fn ceil_log2_exact() {
        assert_eq!(ceil_log2(1), 0);
        assert_eq!(ceil_log2(2), 1);
        assert_eq!(ceil_log2(3), 2);
        assert_eq!(ceil_log2(4), 2);
        assert_eq!(ceil_log2(5), 3);
        assert_eq!(ceil_log2(1024), 10);
        assert_eq!(ceil_log2(1025), 11);
    }

    #[test]
    fn halving_survivor_counts() {
        // n = 10: 10 -> 5 -> 3 -> 2 (ceil halving), ceil(log2 10) = 4 rounds
        let rounds = halving_rounds(10, 10_000_000); // huge budget -> t=n, exits round 0
        assert!(rounds[0].exact_exit);

        let rounds = halving_rounds(10, 40); // t_0 = 40/(10*4) = 1
        let sizes: Vec<usize> = rounds.iter().map(|r| r.survivors).collect();
        assert_eq!(sizes, vec![10, 5, 3, 2]);
    }

    #[test]
    fn exact_exit_when_t_reaches_n() {
        // big budget relative to survivors: t_r caps at n and exits
        let rounds = halving_rounds(16, 16 * 4 * 16); // t_0 = 16 = n
        assert_eq!(rounds.len(), 1);
        assert!(rounds[0].exact_exit);
    }

    #[test]
    fn budget_respected_property() {
        // Theorem accounting: sum of round pulls <= T + n (init slack of
        // 1 pull/arm when floor() hits 0 and we clamp to t=1).
        testing::check(
            "halving-budget",
            testing::default_cases(),
            |rng| {
                let n = rng.range(2, 5_000);
                let per_arm = rng.range(1, 64) as u64;
                (n, per_arm * n as u64)
            },
            |&(n, budget), _| {
                let total = planned_pulls(n, budget);
                // t_r >= 1 clamp: a starved round still pays |S_r| pulls,
                // so the overshoot is bounded by sum of the halving sizes.
                let slack = 2 * n as u64 + ceil_log2(n) as u64 + 1;
                if total <= budget + slack {
                    Ok(())
                } else {
                    Err(format!("pulls {total} > budget {budget} + slack {slack}"))
                }
            },
        );
    }

    #[test]
    fn rounds_monotone_and_terminating() {
        testing::check(
            "halving-shape",
            testing::default_cases(),
            |rng| {
                let n = rng.range(2, 100_000);
                let budget = rng.range(1, 100) as u64 * n as u64;
                (n, budget)
            },
            |&(n, budget), _| {
                let rounds = halving_rounds(n, budget);
                if rounds.is_empty() {
                    return Err("no rounds for n >= 2".into());
                }
                for w in rounds.windows(2) {
                    if w[1].survivors != w[0].survivors.div_ceil(2) {
                        return Err(format!(
                            "survivors {} -> {} is not ceil-halving",
                            w[0].survivors, w[1].survivors
                        ));
                    }
                    if w[0].exact_exit {
                        return Err("rounds continued past exact exit".into());
                    }
                }
                let last = rounds.last().unwrap();
                if !(last.exact_exit
                    || rounds.len() == ceil_log2(n)
                    || last.survivors <= 1)
                {
                    return Err("schedule ended early without exit condition".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn t_r_clamps() {
        assert_eq!(t_r(0, 10, 100), 1); // floor 0 -> clamp 1
        assert_eq!(t_r(u64::MAX / 2, 2, 100), 100); // huge -> clamp n
    }

    #[test]
    fn t_r_capped_generalizes_t_r() {
        // arms == refs == n reproduces the paper schedule exactly
        for (budget, survivors, n) in [(4_000u64, 100usize, 100usize), (64, 10, 10)] {
            assert_eq!(t_r(budget, survivors, n), t_r_capped(budget, survivors, ceil_log2(n), n));
        }
        // split universes: refs clamp to the data size, not the arm count
        assert_eq!(t_r_capped(u64::MAX / 2, 2, ceil_log2(10_000), 500), 500);
        assert_eq!(t_r_capped(0, 10_000, ceil_log2(10_000), 500), 1);
        // degenerate inputs never divide by zero
        assert_eq!(t_r_capped(100, 0, 0, 0), 1);
    }

    #[test]
    fn t_r_capped_clamps_in_u64_domain_before_cast() {
        // Regression: quotients beyond usize::MAX must hit the max_t cap,
        // not be narrowed first (on 32-bit, `as usize` truncation could
        // wrap a huge quotient to a small t — e.g. 2^32 -> 0).
        assert_eq!(t_r_capped(u64::MAX, 1, 1, 7), 7);
        assert_eq!(t_r_capped(u64::MAX, 1, 1, 1), 1);
        let huge = (1u64 << 32) * 3; // truncates to 0 on a 32-bit usize
        assert_eq!(t_r_capped(huge, 1, 1, 500), 500);
        // At the boundary itself the cap is inclusive.
        assert_eq!(t_r_capped(500, 1, 1, 500), 500);
        assert_eq!(t_r_capped(499, 1, 1, 500), 499);
    }
}
