//! Placement and outstanding-request tracking for the distributed engine.
//!
//! The coordinator splits the reference axis into a **canonical segment
//! grid** that depends only on the dataset shape and the configured segment
//! count — never on how many workers are currently alive. Worker ownership
//! is a second, mutable layer on top: each alive worker owns a contiguous
//! run of segments (assigned in ascending worker-index order), and when a
//! worker dies or rejoins only the ownership layer moves; the grid itself is
//! frozen at registration.
//!
//! That split is what makes the distributed reduction bitwise-deterministic
//! (DESIGN.md §15): workers return one f64 partial sum per (arm, segment),
//! and the coordinator folds segments in ascending canonical order. Since
//! segment boundaries and the fold order are worker-count-independent, the
//! reduced sums are bit-identical across 1, 2, or N workers, and across any
//! sequence of failures and re-dispatches.
//!
//! Segment widths come from [`planner::shard_aligned_chunk`] so that, when
//! the dataset is served from a shard manifest, segment boundaries land on
//! shard boundaries and a worker sweeping its range touches whole shards.
//!
//! Everything here is pure bookkeeping — no sockets, no I/O — so the
//! invariants are unit-testable without spinning up processes. The wire
//! layer lives in `engine::distributed`.

use crate::coordinator::planner;

/// Canonical segment grid plus the current segment → worker assignment.
#[derive(Clone, Debug)]
pub struct Placement {
    n: usize,
    width: usize,
    /// Per-segment owning worker slot.
    owner: Vec<usize>,
}

impl Placement {
    /// Freeze the canonical grid for `n` reference rows cut into about
    /// `segments` runs, shard-aligned when `shard_rows > 0` (0 = resident
    /// data, plain split). All segments start owned by worker 0; call
    /// [`Placement::assign`] to spread them over the alive set.
    pub fn new(n: usize, segments: usize, shard_rows: usize) -> crate::Result<Self> {
        crate::ensure!(n >= 1, "placement over an empty dataset");
        crate::ensure!(segments >= 1, "placement needs at least one segment");
        let width = planner::shard_aligned_chunk(n, segments, 1, shard_rows);
        let count = n.div_ceil(width);
        Ok(Placement { n, width, owner: vec![0; count] })
    }

    /// Number of canonical segments (fixed for the lifetime of the grid).
    pub fn segments(&self) -> usize {
        self.owner.len()
    }

    /// Rows per segment (the tail segment may be shorter).
    pub fn width(&self) -> usize {
        self.width
    }

    pub fn n(&self) -> usize {
        self.n
    }

    /// Half-open row range `[lo, hi)` of segment `s`.
    pub fn bounds(&self, s: usize) -> (usize, usize) {
        (s * self.width, ((s + 1) * self.width).min(self.n))
    }

    /// Canonical segment owning row `row`.
    pub fn seg_of(&self, row: usize) -> usize {
        row / self.width
    }

    /// Worker slot currently owning segment `s`.
    pub fn owner_of(&self, s: usize) -> usize {
        self.owner[s]
    }

    /// Re-spread segment ownership over the alive workers: contiguous runs
    /// of segments, assigned in ascending worker-index order. The canonical
    /// grid is untouched — only ownership moves, so a rebalance (worker
    /// death or rejoin) never perturbs reduction results.
    pub fn assign(&mut self, alive: &[bool]) -> crate::Result<()> {
        let alive_idx: Vec<usize> =
            alive.iter().enumerate().filter(|(_, &a)| a).map(|(i, _)| i).collect();
        crate::ensure!(!alive_idx.is_empty(), "no alive workers to assign segments to");
        let per = self.owner.len().div_ceil(alive_idx.len());
        for (s, o) in self.owner.iter_mut().enumerate() {
            *o = alive_idx[s / per];
        }
        Ok(())
    }

    /// Partition reference *positions* by owning segment, preserving the
    /// caller's order inside each segment. `idx[s]` holds indices into
    /// `refs` whose row falls in segment `s` — order preservation is what
    /// keeps each worker-side partial sum bitwise-stable, and positions
    /// (rather than row values) are what the matrix path scatters by.
    pub fn split_idx(&self, refs: &[usize]) -> Vec<Vec<usize>> {
        let mut idx: Vec<Vec<usize>> = vec![Vec::new(); self.owner.len()];
        for (j, &r) in refs.iter().enumerate() {
            idx[self.seg_of(r)].push(j);
        }
        idx
    }
}

/// One in-flight request on a worker channel.
#[derive(Clone, Debug)]
pub struct Pending {
    /// Protocol v2 request id.
    pub id: u64,
    /// Canonical segments the request covers (for re-dispatch on failure).
    pub segs: Vec<usize>,
}

/// Outstanding-request tracker: at most one in-flight request per worker
/// channel (the engine writes one `worker.pull` per worker per block, then
/// reads responses in worker-index order). On failure the tracker hands the
/// dead worker's segment list back for re-dispatch to a survivor.
#[derive(Clone, Debug, Default)]
pub struct Outstanding {
    pending: Vec<Option<Pending>>,
}

impl Outstanding {
    pub fn new(workers: usize) -> Self {
        Outstanding { pending: vec![None; workers] }
    }

    /// Record a request issued to `worker`. Errors if one is already
    /// outstanding there — the engine protocol is strictly one-at-a-time
    /// per channel, so a double-issue is a coordinator bug.
    pub fn issue(&mut self, worker: usize, id: u64, segs: Vec<usize>) -> crate::Result<()> {
        crate::ensure!(
            self.pending[worker].is_none(),
            "worker {worker} already has an outstanding request"
        );
        self.pending[worker] = Some(Pending { id, segs });
        Ok(())
    }

    /// Settle the outstanding request on `worker` (response arrived or the
    /// channel died); returns it for result-filling or re-dispatch.
    pub fn take(&mut self, worker: usize) -> Option<Pending> {
        self.pending[worker].take()
    }

    pub fn is_pending(&self, worker: usize) -> bool {
        self.pending[worker].is_some()
    }

    pub fn in_flight(&self) -> usize {
        self.pending.iter().filter(|p| p.is_some()).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::testing;

    #[test]
    fn bounds_partition_all_rows() {
        testing::check(
            "placement-bounds",
            testing::default_cases(),
            |rng| {
                let n = 1 + rng.below(5000);
                let segments = 1 + rng.below(16);
                let shard_rows = [0, 100, 77, 61][rng.below(4)];
                (n, segments, shard_rows)
            },
            |&(n, segments, shard_rows), _| {
                let p = Placement::new(n, segments, shard_rows).unwrap();
                let mut pos = 0;
                for s in 0..p.segments() {
                    let (lo, hi) = p.bounds(s);
                    if lo != pos || hi <= lo {
                        return Err(format!("segment {s} = [{lo},{hi}) breaks cover at {pos}"));
                    }
                    pos = hi;
                }
                if pos != n {
                    return Err(format!("segments end at {pos} != n = {n}"));
                }
                for row in [0, n / 2, n - 1] {
                    let s = p.seg_of(row);
                    let (lo, hi) = p.bounds(s);
                    if row < lo || row >= hi {
                        return Err(format!("row {row} mapped to segment {s} = [{lo},{hi})"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn grid_is_independent_of_worker_count() {
        // The canonical grid is a function of (n, segments, shard_rows)
        // only; assigning to different alive sets must never move bounds.
        let mut a = Placement::new(1000, 8, 100).unwrap();
        let mut b = a.clone();
        a.assign(&[true]).unwrap();
        b.assign(&[true, true, true, true]).unwrap();
        assert_eq!(a.segments(), b.segments());
        for s in 0..a.segments() {
            assert_eq!(a.bounds(s), b.bounds(s));
        }
    }

    #[test]
    fn assign_is_contiguous_and_alive_only() {
        testing::check(
            "placement-assign",
            testing::default_cases(),
            |rng| {
                let n = 1 + rng.below(3000);
                let workers = 1 + rng.below(6);
                let mut alive: Vec<bool> = (0..workers).map(|_| rng.chance(0.7)).collect();
                if !alive.iter().any(|&a| a) {
                    alive[rng.below(workers)] = true;
                }
                let segments = workers + rng.below(16);
                (n, segments, alive)
            },
            |(n, segments, alive), _| {
                let mut p = Placement::new(*n, *segments, 0).unwrap();
                p.assign(alive).unwrap();
                let owners: Vec<usize> = (0..p.segments()).map(|s| p.owner_of(s)).collect();
                for &o in &owners {
                    if !alive[o] {
                        return Err(format!("segment assigned to dead worker {o}"));
                    }
                }
                // Contiguity: ascending worker order along the segment axis.
                for w in owners.windows(2) {
                    if w[1] < w[0] {
                        return Err(format!("ownership not ascending: {owners:?}"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn rebalance_moves_ownership_not_bounds() {
        let mut p = Placement::new(800, 8, 100).unwrap();
        p.assign(&[true, true, true, true]).unwrap();
        let before: Vec<(usize, usize)> = (0..p.segments()).map(|s| p.bounds(s)).collect();
        let owned_by_1: Vec<usize> =
            (0..p.segments()).filter(|&s| p.owner_of(s) == 1).collect();
        assert!(!owned_by_1.is_empty());
        // worker 1 dies: its segments must land on survivors, bounds frozen.
        p.assign(&[true, false, true, true]).unwrap();
        for s in 0..p.segments() {
            assert_ne!(p.owner_of(s), 1, "segment {s} still on the dead worker");
            assert_eq!(p.bounds(s), before[s], "rebalance moved segment {s}");
        }
        // rejoin: worker 1 is assignable again.
        p.assign(&[true, true, true, true]).unwrap();
        assert!((0..p.segments()).any(|s| p.owner_of(s) == 1));
    }

    #[test]
    fn split_idx_partitions_and_preserves_order() {
        testing::check(
            "placement-split",
            testing::default_cases(),
            |rng| {
                let n = 10 + rng.below(2000);
                let k = 1 + rng.below(200.min(n));
                let refs = rng.sample_without_replacement(n, k);
                (n, refs)
            },
            |(n, refs), _| {
                let p = Placement::new(*n, 8, 77).unwrap();
                let idx = p.split_idx(refs);
                let mut seen = vec![false; refs.len()];
                for (s, group) in idx.iter().enumerate() {
                    let (lo, hi) = p.bounds(s);
                    for w in group.windows(2) {
                        if w[1] <= w[0] {
                            return Err("order not preserved inside a segment".into());
                        }
                    }
                    for &j in group {
                        if refs[j] < lo || refs[j] >= hi {
                            return Err(format!("ref {} outside its segment {s}", refs[j]));
                        }
                        if seen[j] {
                            return Err(format!("ref position {j} in two segments"));
                        }
                        seen[j] = true;
                    }
                }
                if !seen.iter().all(|&s| s) {
                    return Err("a ref position was dropped".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn outstanding_lifecycle() {
        let mut o = Outstanding::new(3);
        assert_eq!(o.in_flight(), 0);
        o.issue(1, 7, vec![0, 1]).unwrap();
        assert!(o.is_pending(1) && !o.is_pending(0));
        assert_eq!(o.in_flight(), 1);
        // double-issue on a busy channel is a coordinator bug
        assert!(o.issue(1, 8, vec![2]).is_err());
        let p = o.take(1).unwrap();
        assert_eq!((p.id, p.segs.as_slice()), (7, &[0usize, 1][..]));
        assert_eq!(o.in_flight(), 0);
        assert!(o.take(1).is_none());
        // after settling, the channel is reusable (re-dispatch path)
        o.issue(1, 9, vec![2]).unwrap();
        assert!(o.is_pending(1));
    }
}
