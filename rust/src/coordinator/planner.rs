//! Batch planner: tile a round's `arms × refs` pull workload into jobs
//! shaped like the available AOT buckets.
//!
//! The PJRT artifacts have *static* shapes (A, R). A round with `|S_r|`
//! surviving arms and `t_r` references becomes a grid of jobs: arms are cut
//! into runs of ≤A, refs into runs of ≤R, and short tails are zero-padded
//! (padded refs are masked out inside the HLO; padded arm outputs are
//! discarded on readback — semantics pinned by `python/tests/test_model.py`
//! and re-verified end-to-end in `rust/tests/pjrt_parity.rs`).
//!
//! Bucket choice: for each axis pick the smallest bucket ≥ the remaining
//! run, else the largest bucket (repeating). That minimizes padded waste on
//! tails while using the big MXU-shaped tiles for the bulk.
//!
//! Invariant (property-tested): every (arm, ref) pair is covered by exactly
//! one job, and every job's shape is an available bucket.

/// Tile-aligned work split: the chunk size that divides `len` into about
/// `parts` runs while keeping every run (except possibly the tail) a
/// multiple of `tile`.
///
/// The native dense tile layer (`engine::kernel`) parallelizes over arm
/// chunks with this: chunk boundaries landing on tile boundaries mean an
/// arm's micro-tile membership — and therefore its bitwise result — is
/// independent of the worker count, the same exact-coverage discipline the
/// PJRT job grid below gets from bucket shapes.
pub fn aligned_chunk(len: usize, parts: usize, tile: usize) -> usize {
    let tile = tile.max(1);
    let per = len.div_ceil(parts.max(1)).max(1);
    per.div_ceil(tile) * tile
}

/// One PJRT job: `arm_span` and `ref_span` index into the round's arm/ref
/// lists; the job runs on bucket `(bucket_arms, bucket_refs)` with padding.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Job {
    pub arm_start: usize,
    pub arm_len: usize,
    pub ref_start: usize,
    pub ref_len: usize,
    pub bucket_arms: usize,
    pub bucket_refs: usize,
}

impl Job {
    /// Padded-waste ratio of this job (0 = perfectly full).
    pub fn waste(&self) -> f64 {
        1.0 - (self.arm_len * self.ref_len) as f64
            / (self.bucket_arms * self.bucket_refs) as f64
    }
}

/// Plans jobs against a fixed bucket ladder.
#[derive(Clone, Debug)]
pub struct BatchPlanner {
    /// Available (arms, refs) bucket shapes, sorted ascending.
    buckets: Vec<(usize, usize)>,
    arm_sizes: Vec<usize>,
    ref_sizes: Vec<usize>,
}

impl BatchPlanner {
    /// `buckets`: the (A, R) shapes present in the artifact manifest for the
    /// relevant (metric, dim).
    pub fn new(mut buckets: Vec<(usize, usize)>) -> crate::Result<Self> {
        crate::ensure!(!buckets.is_empty(), "no buckets available");
        buckets.sort_unstable();
        buckets.dedup();
        let mut arm_sizes: Vec<usize> = buckets.iter().map(|b| b.0).collect();
        arm_sizes.sort_unstable();
        arm_sizes.dedup();
        let mut ref_sizes: Vec<usize> = buckets.iter().map(|b| b.1).collect();
        ref_sizes.sort_unstable();
        ref_sizes.dedup();
        Ok(BatchPlanner { buckets, arm_sizes, ref_sizes })
    }

    /// Split `len` into runs using `sizes` (ascending): largest size for the
    /// bulk, smallest size ≥ tail for the tail.
    fn cut(sizes: &[usize], len: usize) -> Vec<(usize, usize, usize)> {
        // (start, len, chosen_size)
        let mut out = Vec::new();
        let largest = *sizes.last().unwrap();
        let mut pos = 0;
        while pos < len {
            let rest = len - pos;
            let size = if rest >= largest {
                largest
            } else {
                *sizes.iter().find(|&&s| s >= rest).unwrap_or(&largest)
            };
            let take = size.min(rest);
            out.push((pos, take, size));
            pos += take;
        }
        out
    }

    /// Check a (bucket_arm, bucket_ref) combination exists; if not, fall
    /// back to the smallest bucket whose arm size matches and refs fit, else
    /// the largest overall.
    fn resolve(&self, a: usize, r: usize) -> (usize, usize) {
        if self.buckets.binary_search(&(a, r)).is_ok() {
            return (a, r);
        }
        // prefer same arm bucket with the smallest refs >= r
        if let Some(&(ba, br)) = self
            .buckets
            .iter()
            .filter(|&&(ba, br)| ba == a && br >= r)
            .min_by_key(|&&(_, br)| br)
        {
            return (ba, br);
        }
        // any bucket that fits both
        if let Some(&b) = self
            .buckets
            .iter()
            .filter(|&&(ba, br)| ba >= a && br >= r)
            .min_by_key(|&&(ba, br)| ba * br)
        {
            return b;
        }
        *self.buckets.last().unwrap()
    }

    /// Plan the full job grid for `n_arms × n_refs`.
    pub fn plan(&self, n_arms: usize, n_refs: usize) -> Vec<Job> {
        if n_arms == 0 || n_refs == 0 {
            return Vec::new();
        }
        let arm_runs = Self::cut(&self.arm_sizes, n_arms);
        let ref_runs = Self::cut(&self.ref_sizes, n_refs);
        let mut jobs = Vec::with_capacity(arm_runs.len() * ref_runs.len());
        for &(astart, alen, asize) in &arm_runs {
            for &(rstart, rlen, rsize) in &ref_runs {
                let (ba, br) = self.resolve(asize, rsize);
                debug_assert!(ba >= alen && br >= rlen);
                jobs.push(Job {
                    arm_start: astart,
                    arm_len: alen,
                    ref_start: rstart,
                    ref_len: rlen,
                    bucket_arms: ba,
                    bucket_refs: br,
                });
            }
        }
        jobs
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::testing;

    fn ladder() -> Vec<(usize, usize)> {
        vec![(64, 16), (64, 64), (256, 64), (256, 256), (1024, 256)]
    }

    #[test]
    fn small_round_single_job() {
        let p = BatchPlanner::new(ladder()).unwrap();
        let jobs = p.plan(10, 5);
        assert_eq!(jobs.len(), 1);
        let j = &jobs[0];
        assert_eq!((j.arm_len, j.ref_len), (10, 5));
        assert_eq!((j.bucket_arms, j.bucket_refs), (64, 16));
    }

    #[test]
    fn bulk_uses_biggest_bucket() {
        let p = BatchPlanner::new(ladder()).unwrap();
        let jobs = p.plan(4096, 512);
        // bulk jobs should be 1024x256
        let bulk = jobs.iter().filter(|j| j.bucket_arms == 1024 && j.bucket_refs == 256).count();
        assert_eq!(bulk, 8, "{jobs:?}");
    }

    #[test]
    fn coverage_exact_property() {
        testing::check(
            "planner-coverage",
            testing::default_cases(),
            |rng| {
                let n_arms = rng.range(1, 3000);
                let n_refs = rng.range(1, 700);
                (n_arms, n_refs)
            },
            |&(n_arms, n_refs), _| {
                let p = BatchPlanner::new(ladder()).unwrap();
                let jobs = p.plan(n_arms, n_refs);
                // exact cover: counts per (arm, ref) cell must all be 1.
                // use a coarse check (interval partition per axis) to stay O(n)
                let mut arm_cov = vec![0u32; n_arms];
                let mut ref_marks: Vec<(usize, usize)> = jobs
                    .iter()
                    .map(|j| (j.ref_start, j.ref_len))
                    .collect::<std::collections::BTreeSet<_>>()
                    .into_iter()
                    .collect();
                ref_marks.sort_unstable();
                // ref runs must partition [0, n_refs)
                let mut pos = 0;
                for (s, l) in &ref_marks {
                    if *s != pos {
                        return Err(format!("ref gap/overlap at {pos} (next run {s})"));
                    }
                    pos = s + l;
                }
                if pos != n_refs {
                    return Err(format!("ref cover ends at {pos} != {n_refs}"));
                }
                // each arm must be covered once per ref-run
                let ref_runs = ref_marks.len();
                for j in &jobs {
                    for a in j.arm_start..j.arm_start + j.arm_len {
                        arm_cov[a] += 1;
                    }
                    if j.arm_len > j.bucket_arms || j.ref_len > j.bucket_refs {
                        return Err(format!("job exceeds bucket: {j:?}"));
                    }
                    if !ladder().contains(&(j.bucket_arms, j.bucket_refs)) {
                        return Err(format!("job uses unknown bucket: {j:?}"));
                    }
                }
                if arm_cov.iter().any(|&c| c as usize != ref_runs) {
                    return Err("arm not covered exactly once per ref-run".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn waste_bounded_on_bulk() {
        let p = BatchPlanner::new(ladder()).unwrap();
        // a full-size round: waste only on the tail jobs
        let jobs = p.plan(2048, 256);
        let total_cells: usize = jobs.iter().map(|j| j.bucket_arms * j.bucket_refs).sum();
        let useful = 2048 * 256;
        assert!(
            (total_cells as f64) < useful as f64 * 1.05,
            "padding waste too high: {total_cells} vs {useful}"
        );
    }

    #[test]
    fn aligned_chunk_is_tile_multiple_and_covers() {
        testing::check(
            "aligned-chunk",
            testing::default_cases(),
            |rng| (1 + rng.below(5000), 1 + rng.below(64), 1 + rng.below(16)),
            |&(len, parts, tile), _| {
                let chunk = aligned_chunk(len, parts, tile);
                if chunk == 0 || chunk % tile != 0 {
                    return Err(format!("chunk {chunk} not a positive multiple of {tile}"));
                }
                // About `parts` runs: never more than the unaligned split.
                let runs = len.div_ceil(chunk);
                if runs > parts {
                    return Err(format!("{runs} runs > {parts} parts (chunk {chunk})"));
                }
                Ok(())
            },
        );
        // degenerate inputs clamp instead of panicking
        assert_eq!(aligned_chunk(10, 0, 4), 12);
        assert_eq!(aligned_chunk(0, 8, 4), 4);
        assert_eq!(aligned_chunk(100, 3, 0), 34);
    }

    #[test]
    fn empty_plan() {
        let p = BatchPlanner::new(ladder()).unwrap();
        assert!(p.plan(0, 10).is_empty());
        assert!(p.plan(10, 0).is_empty());
    }

    #[test]
    fn no_buckets_is_error() {
        assert!(BatchPlanner::new(vec![]).is_err());
    }
}
