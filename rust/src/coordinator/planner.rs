//! Batch planner: tile a round's `arms × refs` pull workload into jobs
//! shaped like the available AOT buckets.
//!
//! The PJRT artifacts have *static* shapes (A, R). A round with `|S_r|`
//! surviving arms and `t_r` references becomes a grid of jobs: arms are cut
//! into runs of ≤A, refs into runs of ≤R, and short tails are zero-padded
//! (padded refs are masked out inside the HLO; padded arm outputs are
//! discarded on readback — semantics pinned by `python/tests/test_model.py`
//! and re-verified end-to-end in `rust/tests/pjrt_parity.rs`).
//!
//! Bucket choice: for each axis pick the smallest bucket ≥ the remaining
//! run, else the largest bucket (repeating). That minimizes padded waste on
//! tails while using the big MXU-shaped tiles for the bulk.
//!
//! Invariant (property-tested): every (arm, ref) pair is covered by exactly
//! one job, and every job's shape is an available bucket.

/// Tile-aligned work split: the chunk size that divides `len` into about
/// `parts` runs while keeping every run (except possibly the tail) a
/// multiple of `tile`.
///
/// The native dense tile layer (`engine::kernel`) parallelizes over arm
/// chunks with this: chunk boundaries landing on tile boundaries mean an
/// arm's micro-tile membership — and therefore its bitwise result — is
/// independent of the worker count, the same exact-coverage discipline the
/// PJRT job grid below gets from bucket shapes.
pub fn aligned_chunk(len: usize, parts: usize, tile: usize) -> usize {
    let tile = tile.max(1);
    let per = len.div_ceil(parts.max(1)).max(1);
    per.div_ceil(tile) * tile
}

/// [`aligned_chunk`] with shard awareness: when the data lives in a shard
/// store of `shard_rows` rows per shard (0 = resident, no shards), the
/// chunk additionally lands on shard boundaries — big chunks round up to
/// whole shards, small chunks to a tile-multiple divisor of the shard — so
/// a worker sweeping a sorted row range touches at most one shard per job
/// instead of paying cold shard fetches on both ends.
///
/// Only applies when `shard_rows` is itself tile-aligned (otherwise shard
/// alignment would break the tile alignment that bitwise determinism
/// rides on — tile alignment always wins).
pub fn shard_aligned_chunk(len: usize, parts: usize, tile: usize, shard_rows: usize) -> usize {
    let tile = tile.max(1);
    let base = aligned_chunk(len, parts, tile);
    if shard_rows < 2 || shard_rows % tile != 0 {
        return base;
    }
    if base >= shard_rows {
        return base.div_ceil(shard_rows) * shard_rows;
    }
    // Largest tile-multiple divisor of the shard that fits the base chunk:
    // chunks tile the shard exactly, so no chunk straddles a boundary.
    let mut best = tile;
    let mut c = tile;
    while c <= base {
        if shard_rows % c == 0 {
            best = c;
        }
        c += tile;
    }
    // Alignment is a perf heuristic only — if the shard size is divisor-poor
    // (e.g. a prime row count) the best divisor can collapse toward `tile`,
    // which would shatter the split into per-tile jobs. Accepting an
    // occasional straddled shard strictly dominates that, so keep the plain
    // split unless the divisor stays within 2x of the requested granularity.
    if best * 2 >= base {
        best
    } else {
        base
    }
}

/// One PJRT job: `arm_span` and `ref_span` index into the round's arm/ref
/// lists; the job runs on bucket `(bucket_arms, bucket_refs)` with padding.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Job {
    pub arm_start: usize,
    pub arm_len: usize,
    pub ref_start: usize,
    pub ref_len: usize,
    pub bucket_arms: usize,
    pub bucket_refs: usize,
}

impl Job {
    /// Padded-waste ratio of this job (0 = perfectly full).
    pub fn waste(&self) -> f64 {
        1.0 - (self.arm_len * self.ref_len) as f64
            / (self.bucket_arms * self.bucket_refs) as f64
    }
}

/// Plans jobs against a fixed bucket ladder.
#[derive(Clone, Debug)]
pub struct BatchPlanner {
    /// Available (arms, refs) bucket shapes, sorted ascending.
    buckets: Vec<(usize, usize)>,
    arm_sizes: Vec<usize>,
    ref_sizes: Vec<usize>,
}

impl BatchPlanner {
    /// `buckets`: the (A, R) shapes present in the artifact manifest for the
    /// relevant (metric, dim).
    pub fn new(mut buckets: Vec<(usize, usize)>) -> crate::Result<Self> {
        crate::ensure!(!buckets.is_empty(), "no buckets available");
        buckets.sort_unstable();
        buckets.dedup();
        let mut arm_sizes: Vec<usize> = buckets.iter().map(|b| b.0).collect();
        arm_sizes.sort_unstable();
        arm_sizes.dedup();
        let mut ref_sizes: Vec<usize> = buckets.iter().map(|b| b.1).collect();
        ref_sizes.sort_unstable();
        ref_sizes.dedup();
        Ok(BatchPlanner { buckets, arm_sizes, ref_sizes })
    }

    /// Split `len` into runs using `sizes` (ascending): largest size for the
    /// bulk, smallest size ≥ tail for the tail.
    fn cut(sizes: &[usize], len: usize) -> Vec<(usize, usize, usize)> {
        // (start, len, chosen_size)
        let mut out = Vec::new();
        let largest = *sizes.last().unwrap();
        let mut pos = 0;
        while pos < len {
            let rest = len - pos;
            let size = if rest >= largest {
                largest
            } else {
                *sizes.iter().find(|&&s| s >= rest).unwrap_or(&largest)
            };
            let take = size.min(rest);
            out.push((pos, take, size));
            pos += take;
        }
        out
    }

    /// Check a (bucket_arm, bucket_ref) combination exists; if not, fall
    /// back to the smallest bucket whose arm size matches and refs fit, else
    /// the largest overall.
    fn resolve(&self, a: usize, r: usize) -> (usize, usize) {
        if self.buckets.binary_search(&(a, r)).is_ok() {
            return (a, r);
        }
        // prefer same arm bucket with the smallest refs >= r
        if let Some(&(ba, br)) = self
            .buckets
            .iter()
            .filter(|&&(ba, br)| ba == a && br >= r)
            .min_by_key(|&&(_, br)| br)
        {
            return (ba, br);
        }
        // any bucket that fits both
        if let Some(&b) = self
            .buckets
            .iter()
            .filter(|&&(ba, br)| ba >= a && br >= r)
            .min_by_key(|&&(ba, br)| ba * br)
        {
            return b;
        }
        *self.buckets.last().unwrap()
    }

    /// Plan the full job grid for `n_arms × n_refs`.
    pub fn plan(&self, n_arms: usize, n_refs: usize) -> Vec<Job> {
        if n_arms == 0 || n_refs == 0 {
            return Vec::new();
        }
        let arm_runs = Self::cut(&self.arm_sizes, n_arms);
        let ref_runs = Self::cut(&self.ref_sizes, n_refs);
        let mut jobs = Vec::with_capacity(arm_runs.len() * ref_runs.len());
        for &(astart, alen, asize) in &arm_runs {
            for &(rstart, rlen, rsize) in &ref_runs {
                let (ba, br) = self.resolve(asize, rsize);
                debug_assert!(ba >= alen && br >= rlen);
                jobs.push(Job {
                    arm_start: astart,
                    arm_len: alen,
                    ref_start: rstart,
                    ref_len: rlen,
                    bucket_arms: ba,
                    bucket_refs: br,
                });
            }
        }
        jobs
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::testing;

    fn ladder() -> Vec<(usize, usize)> {
        vec![(64, 16), (64, 64), (256, 64), (256, 256), (1024, 256)]
    }

    #[test]
    fn small_round_single_job() {
        let p = BatchPlanner::new(ladder()).unwrap();
        let jobs = p.plan(10, 5);
        assert_eq!(jobs.len(), 1);
        let j = &jobs[0];
        assert_eq!((j.arm_len, j.ref_len), (10, 5));
        assert_eq!((j.bucket_arms, j.bucket_refs), (64, 16));
    }

    #[test]
    fn bulk_uses_biggest_bucket() {
        let p = BatchPlanner::new(ladder()).unwrap();
        let jobs = p.plan(4096, 512);
        // bulk jobs should be 1024x256
        let bulk = jobs.iter().filter(|j| j.bucket_arms == 1024 && j.bucket_refs == 256).count();
        assert_eq!(bulk, 8, "{jobs:?}");
    }

    #[test]
    fn coverage_exact_property() {
        testing::check(
            "planner-coverage",
            testing::default_cases(),
            |rng| {
                let n_arms = rng.range(1, 3000);
                let n_refs = rng.range(1, 700);
                (n_arms, n_refs)
            },
            |&(n_arms, n_refs), _| {
                let p = BatchPlanner::new(ladder()).unwrap();
                let jobs = p.plan(n_arms, n_refs);
                // exact cover: counts per (arm, ref) cell must all be 1.
                // use a coarse check (interval partition per axis) to stay O(n)
                let mut arm_cov = vec![0u32; n_arms];
                let mut ref_marks: Vec<(usize, usize)> = jobs
                    .iter()
                    .map(|j| (j.ref_start, j.ref_len))
                    .collect::<std::collections::BTreeSet<_>>()
                    .into_iter()
                    .collect();
                ref_marks.sort_unstable();
                // ref runs must partition [0, n_refs)
                let mut pos = 0;
                for (s, l) in &ref_marks {
                    if *s != pos {
                        return Err(format!("ref gap/overlap at {pos} (next run {s})"));
                    }
                    pos = s + l;
                }
                if pos != n_refs {
                    return Err(format!("ref cover ends at {pos} != {n_refs}"));
                }
                // each arm must be covered once per ref-run
                let ref_runs = ref_marks.len();
                for j in &jobs {
                    for a in j.arm_start..j.arm_start + j.arm_len {
                        arm_cov[a] += 1;
                    }
                    if j.arm_len > j.bucket_arms || j.ref_len > j.bucket_refs {
                        return Err(format!("job exceeds bucket: {j:?}"));
                    }
                    if !ladder().contains(&(j.bucket_arms, j.bucket_refs)) {
                        return Err(format!("job uses unknown bucket: {j:?}"));
                    }
                }
                if arm_cov.iter().any(|&c| c as usize != ref_runs) {
                    return Err("arm not covered exactly once per ref-run".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn waste_bounded_on_bulk() {
        let p = BatchPlanner::new(ladder()).unwrap();
        // a full-size round: waste only on the tail jobs
        let jobs = p.plan(2048, 256);
        let total_cells: usize = jobs.iter().map(|j| j.bucket_arms * j.bucket_refs).sum();
        let useful = 2048 * 256;
        assert!(
            (total_cells as f64) < useful as f64 * 1.05,
            "padding waste too high: {total_cells} vs {useful}"
        );
    }

    #[test]
    fn aligned_chunk_is_tile_multiple_and_covers() {
        testing::check(
            "aligned-chunk",
            testing::default_cases(),
            |rng| (1 + rng.below(5000), 1 + rng.below(64), 1 + rng.below(16)),
            |&(len, parts, tile), _| {
                let chunk = aligned_chunk(len, parts, tile);
                if chunk == 0 || chunk % tile != 0 {
                    return Err(format!("chunk {chunk} not a positive multiple of {tile}"));
                }
                // About `parts` runs: never more than the unaligned split.
                let runs = len.div_ceil(chunk);
                if runs > parts {
                    return Err(format!("{runs} runs > {parts} parts (chunk {chunk})"));
                }
                Ok(())
            },
        );
        // degenerate inputs clamp instead of panicking
        assert_eq!(aligned_chunk(10, 0, 4), 12);
        assert_eq!(aligned_chunk(0, 8, 4), 4);
        assert_eq!(aligned_chunk(100, 3, 0), 34);
    }

    #[test]
    fn shard_aligned_chunk_respects_tiles_and_boundaries() {
        testing::check(
            "shard-aligned-chunk",
            testing::default_cases(),
            |rng| {
                let len = 1 + rng.below(100_000);
                let parts = 1 + rng.below(64);
                let tile = 1 + rng.below(16);
                // tile-aligned and unaligned shard sizes, plus 0 = resident
                let shard_rows = [0, tile * (1 + rng.below(64)), 1 + rng.below(1000)]
                    [rng.below(3)];
                (len, parts, tile, shard_rows)
            },
            |&(len, parts, tile, shard_rows), _| {
                let chunk = shard_aligned_chunk(len, parts, tile, shard_rows);
                if chunk == 0 || chunk % tile != 0 {
                    return Err(format!("chunk {chunk} not a positive multiple of {tile}"));
                }
                let plain = aligned_chunk(len, parts, tile);
                if shard_rows >= 2 && shard_rows % tile == 0 {
                    // shard discipline: whole shards, an exact divisor, or —
                    // when the shard is divisor-poor — the plain split
                    // (granularity must never collapse below half of it)
                    let aligned_to_shard =
                        chunk % shard_rows == 0 || shard_rows % chunk == 0;
                    if !aligned_to_shard && chunk != plain {
                        return Err(format!(
                            "chunk {chunk} neither shard-aligned ({shard_rows} rows/shard) \
                             nor the plain split {plain}"
                        ));
                    }
                    if 2 * chunk < plain {
                        return Err(format!(
                            "chunk {chunk} shattered the split (plain {plain})"
                        ));
                    }
                } else if chunk != plain {
                    return Err("unaligned shards must not change the plain split".into());
                }
                Ok(())
            },
        );
        // spot values: big chunks round up to whole shards…
        assert_eq!(shard_aligned_chunk(1000, 2, 4, 128), 512);
        // …small chunks divide one shard exactly…
        assert_eq!(shard_aligned_chunk(128, 8, 4, 128), 16);
        // …and a shard size that defeats both keeps plain tile alignment.
        assert_eq!(shard_aligned_chunk(100, 3, 4, 7), aligned_chunk(100, 3, 4));
        // Divisor-poor shard sizes (e.g. a prime row count) fall back to
        // the plain split instead of shattering the workload into
        // chunk=tile jobs (the prepare pass calls this with tile=1, where
        // that degeneration meant one pool job per row).
        assert_eq!(
            shard_aligned_chunk(1_000_000, 16, 1, 65_537),
            aligned_chunk(1_000_000, 16, 1)
        );
        assert_eq!(shard_aligned_chunk(10_000, 8, 4, 4 * 9973), aligned_chunk(10_000, 8, 4));
    }

    #[test]
    fn empty_plan() {
        let p = BatchPlanner::new(ladder()).unwrap();
        assert!(p.plan(0, 10).is_empty());
        assert!(p.plan(10, 0).is_empty());
    }

    #[test]
    fn no_buckets_is_error() {
        assert!(BatchPlanner::new(vec![]).is_err());
    }
}
