//! L3 coordination: the paper's contribution lives here.
//!
//! * [`planner`] — the batch planner that tiles a round's `|S_r| × t_r`
//!   pull workload into bucket-shaped jobs matching the AOT artifacts
//!   (pad + mask semantics, exact-coverage invariant).
//! * [`ledger`] — fixed-budget accounting: Algorithm 1's per-round
//!   `t_r = clamp(⌊T / (|S_r|⌈log₂n⌉)⌋, 1, n)` and the guarantee that the
//!   total never exceeds `T` plus the ≤1-pull-per-arm initialization slack.
//! * [`rounds`] — the halving schedule `|S_{r+1}| = ⌈|S_r|/2⌉` with the
//!   early-exit rule when `t_r = n` (exact centrality ⇒ zero uncertainty).
//! * [`dispatch`] — the distributed layer's bookkeeping: the canonical
//!   segment grid (worker-count-independent, shard-aligned) plus the
//!   outstanding-request tracker the coordinator re-dispatches from when a
//!   worker dies (DESIGN.md §15). Pure logic; the sockets live in
//!   `engine::distributed`.
//!
//! The Correlated Sequential Halving *algorithm* (`bandits::corr_sh`) is a
//! thin loop over these pieces plus an engine; the correlation itself is the
//! planner guaranteeing every arm in a round is scored against the **same**
//! reference set `J_r`.

pub mod dispatch;
pub mod ledger;
pub mod planner;
pub mod rounds;

pub use dispatch::{Outstanding, Placement};
pub use ledger::BudgetLedger;
pub use planner::{BatchPlanner, Job};
pub use rounds::{halving_rounds, RoundPlan};
