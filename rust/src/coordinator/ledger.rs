//! Fixed-budget pull accounting.
//!
//! Algorithm 1 is *fixed budget*: given T total distance computations it
//! never exceeds T (plus the ≤1-pull-per-arm clamp slack). The ledger is the
//! single authority on what has been spent; the experiment harness asserts
//! its invariants after every trial.

/// Tracks pulls against a fixed budget.
#[derive(Clone, Debug)]
pub struct BudgetLedger {
    budget: u64,
    /// Extra allowance from the `t_r ≥ 1` clamp: a starved round still pays
    /// |S_r| pulls, so across all rounds the overshoot is bounded by
    /// Σ_r ⌈|S_r|⌉ ≤ 2n + ⌈log₂ n⌉ (ceil-halving).
    slack: u64,
    spent: u64,
    /// Pulls *reported* by the engine that executed each round — equal to
    /// the scheduled charge for local engines, but sourced from worker
    /// report frames in the distributed path, where the coordinator must
    /// account what remote processes actually computed (including pulls
    /// repeated on re-dispatch after a worker death).
    remote_reported: u64,
    rounds: Vec<(usize, u64)>,
}

impl BudgetLedger {
    pub fn new(budget: u64, n: usize) -> Self {
        let slack = 2 * n as u64 + crate::coordinator::rounds::ceil_log2(n) as u64 + 1;
        BudgetLedger { budget, slack, spent: 0, remote_reported: 0, rounds: Vec::new() }
    }

    pub fn budget(&self) -> u64 {
        self.budget
    }

    pub fn spent(&self) -> u64 {
        self.spent
    }

    pub fn remaining(&self) -> u64 {
        self.budget.saturating_add(self.slack).saturating_sub(self.spent)
    }

    /// Charge a round's pulls. Panics (debug) / errors if the hard cap
    /// (budget + slack) would be breached — a scheduling bug, not a runtime
    /// condition.
    pub fn charge_round(&mut self, round: usize, pulls: u64) -> crate::Result<()> {
        crate::ensure!(
            self.spent.saturating_add(pulls) <= self.budget.saturating_add(self.slack),
            "round {round} would overspend: spent {} + {pulls} > budget {} + slack {}",
            self.spent,
            self.budget,
            self.slack
        );
        self.spent = self.spent.saturating_add(pulls);
        self.rounds.push((round, pulls));
        Ok(())
    }

    /// Aggregate pulls charged by the executing engine's report frames.
    /// Saturating: a misbehaving remote cannot wrap the counter and forge a
    /// tiny total. Call once per scored block with that block's reported
    /// count (for local engines, the scheduled `|S_r| · t_r`).
    pub fn report_remote(&mut self, pulls: u64) {
        self.remote_reported = self.remote_reported.saturating_add(pulls);
    }

    /// Total pulls aggregated from report frames (see [`Self::report_remote`]).
    pub fn remote_reported(&self) -> u64 {
        self.remote_reported
    }

    /// Per-round history (round index, pulls).
    pub fn history(&self) -> &[(usize, u64)] {
        &self.rounds
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::rounds::halving_rounds;
    use crate::util::testing;

    #[test]
    fn charges_accumulate() {
        let mut l = BudgetLedger::new(100, 10);
        l.charge_round(0, 40).unwrap();
        l.charge_round(1, 30).unwrap();
        assert_eq!(l.spent(), 70);
        // slack(n=10) = 2*10 + ceil_log2(10) + 1 = 25
        assert_eq!(l.remaining(), 100 + 25 - 70);
        assert_eq!(l.history(), &[(0, 40), (1, 30)]);
    }

    #[test]
    fn overspend_rejected() {
        // slack(n=5) = 10 + 3 + 1 = 14 -> hard cap 114
        let mut l = BudgetLedger::new(100, 5);
        assert!(l.charge_round(0, 115).is_err());
        assert!(l.charge_round(0, 114).is_ok());
        assert!(l.charge_round(1, 1).is_err());
    }

    #[test]
    fn remote_reports_aggregate_and_saturate() {
        let mut l = BudgetLedger::new(100, 10);
        assert_eq!(l.remote_reported(), 0);
        l.report_remote(40);
        l.report_remote(30);
        // reports mirror local charges when the engine computes locally
        l.charge_round(0, 70).unwrap();
        assert_eq!(l.remote_reported(), l.spent());
        // a worker re-dispatch can legitimately report more than scheduled…
        l.report_remote(5);
        assert_eq!(l.remote_reported(), 75);
        // …and a hostile/buggy report can never wrap the accumulator
        l.report_remote(u64::MAX);
        assert_eq!(l.remote_reported(), u64::MAX);
        l.report_remote(1);
        assert_eq!(l.remote_reported(), u64::MAX);
    }

    #[test]
    fn halving_schedule_always_fits_ledger() {
        // The schedule and the ledger must agree for every (n, T): this is
        // the paper's "at most T distance computations" claim.
        testing::check(
            "ledger-fits-schedule",
            testing::default_cases(),
            |rng| {
                let n = rng.range(2, 20_000);
                let budget = rng.range(1, 200) as u64 * n as u64;
                (n, budget)
            },
            |&(n, budget), _| {
                let mut ledger = BudgetLedger::new(budget, n);
                for round in halving_rounds(n, budget) {
                    ledger
                        .charge_round(round.r, round.pulls)
                        .map_err(|e| e.to_string())?;
                }
                Ok(())
            },
        );
    }
}
