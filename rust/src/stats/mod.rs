//! Statistics engine behind the paper's analysis figures and §1.3 numbers:
//! exact centralities θ_i, gaps Δ_i, correlation factors ρ_i, the data
//! constant σ, and the hardness measures H₂ (independent sampling) and
//! H̃₂ (correlated sampling) whose ratio quantifies the theoretical gain.
//!
//! Definitions (paper §1.3, §2, Fig 3/4):
//!
//! * θ_i = (1/n) Σ_j d(x_i, x_j); Δ_i = θ_i − θ_1 (arm 1 = the medoid).
//! * σ: sub-Gaussian scale of single-distance sampling — estimated as the
//!   std of d(x_i, x_J) averaged over arms (Fig 3 caption normalization).
//! * ρ_i: relative concentration of the *correlated* difference —
//!   std(d(x_1, x_J) − d(x_i, x_J)) / σ.
//! * H₂  = max_{i≥2} i / Δ_(i)²            (sorted by Δ)
//! * H̃₂ = max_{i≥2} i ρ_(i)² / Δ_(i)²      (sorted by Δ_i/ρ_i — Thm 2.1)

pub mod histogram;

pub use histogram::Histogram;

use crate::bandits::exact::exact_thetas;
use crate::engine::PullEngine;
use crate::metrics::Welford;
use crate::util::rng::Rng;

/// Full instance statistics for one (dataset, metric).
#[derive(Clone, Debug)]
pub struct InstanceStats {
    /// Exact centralities, index-aligned with the dataset.
    pub thetas: Vec<f64>,
    /// Medoid index (argmin θ).
    pub medoid: usize,
    /// Δ_i = θ_i − θ_medoid (Δ_medoid = 0).
    pub deltas: Vec<f64>,
    /// ρ_i (ρ_medoid = 0 by convention).
    pub rhos: Vec<f64>,
    /// σ: mean per-arm std of single-distance samples.
    pub sigma: f64,
    /// H₂ = max_{i≥2} i/Δ_(i)² over arms sorted by Δ.
    pub h2: f64,
    /// H̃₂ = max_{i≥2} i·ρ_(i)²/Δ_(i)² over arms sorted by Δ/ρ.
    pub h2_tilde: f64,
}

impl InstanceStats {
    /// The paper's theoretical-gain ratio (> 1 when correlation helps;
    /// 6.6 on RNA-Seq 20k, 4.8 on MNIST in the paper).
    pub fn gain_ratio(&self) -> f64 {
        if self.h2_tilde > 0.0 {
            self.h2 / self.h2_tilde
        } else {
            f64::INFINITY
        }
    }
}

/// Compute exact per-arm statistics.
///
/// Cost: one exact O(n²) sweep for θ plus `sample_refs` full distance
/// columns for σ/ρ estimation (the paper does the same on its ≤20k
/// datasets and reports the 100k ones as infeasible — same here).
pub fn instance_stats(engine: &dyn PullEngine, sample_refs: usize, rng: &mut Rng) -> InstanceStats {
    let n = engine.n();
    assert!(n >= 2, "need at least two points");
    let thetas = exact_thetas(engine);
    let medoid = crate::bandits::argmin(thetas.iter().cloned());
    let deltas: Vec<f64> = thetas.iter().map(|&t| t - thetas[medoid]).collect();

    // Shared reference sample J for σ and ρ estimation (the correlated draw
    // of Fig 3a).
    let m = sample_refs.clamp(2, n);
    let refs = rng.sample_without_replacement(n, m);

    // distance columns: d(i, J) for all i — m pulls per arm
    let arms: Vec<usize> = (0..n).collect();
    let mut dmat = vec![0f32; n * m];
    engine.pull_matrix(&arms, &refs, &mut dmat);

    // σ: mean over arms of std(d(x_i, x_J))
    let mut sigma_acc = Welford::default();
    for i in 0..n {
        let mut w = Welford::default();
        for j in 0..m {
            w.push(dmat[i * m + j] as f64);
        }
        sigma_acc.push(w.std());
    }
    let sigma = sigma_acc.mean().max(1e-12);

    // ρ_i: std of the correlated difference, normalized by σ
    let mut rhos = vec![0f64; n];
    for i in 0..n {
        if i == medoid {
            continue;
        }
        let mut w = Welford::default();
        for j in 0..m {
            w.push((dmat[medoid * m + j] - dmat[i * m + j]) as f64);
        }
        rhos[i] = w.std() / sigma;
    }

    let (h2, h2_tilde) = hardness(&deltas, &rhos, medoid);
    InstanceStats { thetas, medoid, deltas, rhos, sigma, h2, h2_tilde }
}

/// H₂ and H̃₂ from per-arm gaps and correlation factors.
pub fn hardness(deltas: &[f64], rhos: &[f64], medoid: usize) -> (f64, f64) {
    let n = deltas.len();
    // H2: sort by Δ ascending, skip the medoid (Δ=0)
    let mut by_delta: Vec<usize> = (0..n).filter(|&i| i != medoid).collect();
    by_delta.sort_unstable_by(|&a, &b| deltas[a].total_cmp(&deltas[b]).then_with(|| a.cmp(&b)));
    let mut h2 = 0f64;
    for (rank0, &i) in by_delta.iter().enumerate() {
        let rank = rank0 + 2; // the paper's index starts at i=2 for the first non-medoid
        let d = deltas[i].max(1e-12);
        h2 = h2.max(rank as f64 / (d * d));
    }
    // H̃2: sort by Δ/ρ ascending
    let mut by_ratio: Vec<usize> = (0..n).filter(|&i| i != medoid).collect();
    by_ratio.sort_unstable_by(|&a, &b| {
        let ra = deltas[a] / rhos[a].max(1e-12);
        let rb = deltas[b] / rhos[b].max(1e-12);
        ra.total_cmp(&rb).then_with(|| a.cmp(&b))
    });
    let mut h2t = 0f64;
    for (rank0, &i) in by_ratio.iter().enumerate() {
        let rank = rank0 + 2;
        let d = deltas[i].max(1e-12);
        let r = rhos[i];
        h2t = h2t.max(rank as f64 * r * r / (d * d));
    }
    (h2, h2t)
}

/// Fig 3 data: sampled differences `d(arm, J) − d(medoid, J)` under
/// correlated (same J) vs independent (J₁, J₂) reference draws.
pub struct DifferenceSamples {
    pub correlated: Vec<f64>,
    pub independent: Vec<f64>,
    pub mean: f64,
    pub std_correlated: f64,
    pub std_independent: f64,
}

impl DifferenceSamples {
    /// Probability that the arm looks better than the medoid after a single
    /// measurement (the paper's .19 → .0011 observation).
    pub fn p_negative(xs: &[f64]) -> f64 {
        xs.iter().filter(|&&x| x < 0.0).count() as f64 / xs.len().max(1) as f64
    }
}

pub fn difference_samples(
    engine: &dyn PullEngine,
    medoid: usize,
    arm: usize,
    samples: usize,
    rng: &mut Rng,
) -> DifferenceSamples {
    let n = engine.n();
    let mut correlated = Vec::with_capacity(samples);
    let mut independent = Vec::with_capacity(samples);
    let (mut wc, mut wi) = (Welford::default(), Welford::default());
    for _ in 0..samples {
        let j = rng.below(n);
        let c = (engine.pull(arm, j) - engine.pull(medoid, j)) as f64;
        correlated.push(c);
        wc.push(c);
        let (j1, j2) = (rng.below(n), rng.below(n));
        let ind = (engine.pull(arm, j1) - engine.pull(medoid, j2)) as f64;
        independent.push(ind);
        wi.push(ind);
    }
    DifferenceSamples {
        correlated,
        independent,
        mean: wc.mean(),
        std_correlated: wc.std(),
        std_independent: wi.std(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{gaussian, rnaseq, SynthConfig};
    use crate::distance::Metric;
    use crate::engine::{CountingEngine, NativeEngine};

    fn engine(n: usize, seed: u64) -> CountingEngine<NativeEngine> {
        let data = gaussian::generate(&SynthConfig {
            n,
            dim: 12,
            seed,
            outlier_frac: 0.08,
            ..Default::default()
        });
        CountingEngine::new(NativeEngine::new(data, Metric::L2))
    }

    #[test]
    fn stats_identify_planted_medoid() {
        let e = engine(150, 61);
        let s = instance_stats(&e, 100, &mut Rng::seeded(0));
        assert_eq!(s.medoid, 0);
        assert!(s.deltas[0].abs() < 1e-12);
        assert!(s.deltas.iter().all(|&d| d >= -1e-9));
        assert!(s.sigma > 0.0);
    }

    #[test]
    fn rho_bounded_orlicz() {
        // Orlicz bound (paper §3.2): ρ ≲ 2 when both arms are σ-sub-Gaussian
        let e = engine(200, 62);
        let s = instance_stats(&e, 150, &mut Rng::seeded(1));
        let violators = s.rhos.iter().filter(|&&r| r > 3.0).count();
        assert!(violators <= 2, "{violators} arms with wild ρ");
    }

    #[test]
    fn correlation_gain_on_clustered_data() {
        // On structured data correlated differences concentrate faster:
        // gain ratio H2/H̃2 should exceed 1 (paper: 6.6 on RNA-Seq 20k).
        let data = rnaseq::generate(&SynthConfig {
            n: 250,
            dim: 256,
            seed: 63,
            ..Default::default()
        });
        let e = CountingEngine::new(NativeEngine::new(data, Metric::L1));
        let s = instance_stats(&e, 200, &mut Rng::seeded(2));
        assert!(
            s.gain_ratio() > 1.0,
            "expected correlation gain, H2={:.3e} H̃2={:.3e}",
            s.h2,
            s.h2_tilde
        );
    }

    #[test]
    fn difference_samples_stds_ordered() {
        let data = rnaseq::generate(&SynthConfig {
            n: 200,
            dim: 256,
            seed: 64,
            ..Default::default()
        });
        let e = CountingEngine::new(NativeEngine::new(data, Metric::L1));
        let thetas = exact_thetas(&e);
        let medoid = crate::bandits::argmin(thetas.iter().cloned());
        let arm = (medoid + 1) % 200;
        let ds = difference_samples(&e, medoid, arm, 3000, &mut Rng::seeded(3));
        assert!(
            ds.std_correlated <= ds.std_independent * 1.05,
            "correlated std {} > independent {}",
            ds.std_correlated,
            ds.std_independent
        );
        // both estimators are unbiased for Δ_i: means must agree loosely
        let ind_mean = ds.independent.iter().sum::<f64>() / ds.independent.len() as f64;
        assert!((ds.mean - ind_mean).abs() < 5.0 * ds.std_independent / (3000f64).sqrt() + 0.05);
    }

    #[test]
    fn hardness_hand_example() {
        // 3 arms: medoid=0, Δ = [0, 0.5, 1.0], ρ = [0, 0.5, 1.0]
        let deltas = vec![0.0, 0.5, 1.0];
        let rhos = vec![0.0, 0.5, 1.0];
        let (h2, h2t) = hardness(&deltas, &rhos, 0);
        // H2 = max(2/0.25, 3/1.0) = 8
        // H̃2: Δ/ρ = [1, 1] (stable order): max(2·0.25/0.25, 3·1/1) = 3
        assert!((h2 - 8.0).abs() < 1e-9);
        assert!((h2t - 3.0).abs() < 1e-9);
    }

    #[test]
    fn p_negative_counts() {
        assert_eq!(DifferenceSamples::p_negative(&[-1.0, 1.0, 2.0, -3.0]), 0.5);
        assert_eq!(DifferenceSamples::p_negative(&[]), 0.0);
    }
}
