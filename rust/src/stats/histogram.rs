//! Fixed-bin histogram for the figure emitters (Figs 3 and 6 are
//! histograms; the harness prints them as CSV rows + ASCII sparklines).

#[derive(Clone, Debug)]
pub struct Histogram {
    pub lo: f64,
    pub hi: f64,
    pub bins: Vec<u64>,
    pub underflow: u64,
    pub overflow: u64,
    pub count: u64,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, nbins: usize) -> Self {
        assert!(hi > lo && nbins > 0);
        Histogram { lo, hi, bins: vec![0; nbins], underflow: 0, overflow: 0, count: 0 }
    }

    /// Build from data with automatic range (±0.5% margin).
    pub fn auto(data: &[f64], nbins: usize) -> Self {
        let mut lo = f64::INFINITY;
        let mut hi = f64::NEG_INFINITY;
        for &x in data {
            lo = lo.min(x);
            hi = hi.max(x);
        }
        if !lo.is_finite() || hi <= lo {
            lo = 0.0;
            hi = 1.0;
        }
        let margin = (hi - lo) * 0.005 + 1e-12;
        let mut h = Histogram::new(lo - margin, hi + margin, nbins);
        for &x in data {
            h.push(x);
        }
        h
    }

    pub fn push(&mut self, x: f64) {
        self.count += 1;
        if x < self.lo {
            self.underflow += 1;
        } else if x >= self.hi {
            self.overflow += 1;
        } else {
            let k = ((x - self.lo) / (self.hi - self.lo) * self.bins.len() as f64) as usize;
            let last = self.bins.len() - 1;
            self.bins[k.min(last)] += 1;
        }
    }

    pub fn bin_center(&self, k: usize) -> f64 {
        let w = (self.hi - self.lo) / self.bins.len() as f64;
        self.lo + (k as f64 + 0.5) * w
    }

    /// CSV rows: `bin_center,count,frequency`.
    pub fn to_csv(&self) -> String {
        let mut s = String::from("bin_center,count,frequency\n");
        for (k, &c) in self.bins.iter().enumerate() {
            s.push_str(&format!(
                "{:.6},{},{:.6}\n",
                self.bin_center(k),
                c,
                c as f64 / self.count.max(1) as f64
            ));
        }
        s
    }

    /// Compact ASCII rendering for terminal reports.
    pub fn sparkline(&self) -> String {
        const GLYPHS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
        let max = self.bins.iter().copied().max().unwrap_or(0).max(1);
        self.bins
            .iter()
            .map(|&c| GLYPHS[(c * 7 / max) as usize])
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bins_and_edges() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        for x in [0.0, 0.5, 9.99, 5.0, -1.0, 10.0, 100.0] {
            h.push(x);
        }
        assert_eq!(h.bins[0], 2); // 0.0, 0.5
        assert_eq!(h.bins[9], 1); // 9.99
        assert_eq!(h.bins[5], 1); // 5.0
        assert_eq!(h.underflow, 1);
        assert_eq!(h.overflow, 2);
        assert_eq!(h.count, 7);
    }

    #[test]
    fn auto_covers_all_points() {
        let data: Vec<f64> = (0..1000).map(|i| (i as f64).sin() * 3.0).collect();
        let h = Histogram::auto(&data, 32);
        assert_eq!(h.underflow + h.overflow, 0);
        assert_eq!(h.bins.iter().sum::<u64>(), 1000);
    }

    #[test]
    fn csv_has_all_bins() {
        let h = Histogram::auto(&[1.0, 2.0, 3.0], 4);
        let csv = h.to_csv();
        assert_eq!(csv.lines().count(), 5); // header + 4 bins
    }

    #[test]
    fn degenerate_data_ok() {
        let h = Histogram::auto(&[], 4);
        assert_eq!(h.count, 0);
        let h2 = Histogram::auto(&[5.0, 5.0], 4);
        assert_eq!(h2.count, 2);
        assert!(!h2.sparkline().is_empty());
    }
}
