//! Synthetic Netflix-prize-like ratings: very sparse CSR under cosine.
//!
//! The real dataset: ~480k users × ~17.8k movies, 0.21% dense, ratings 1–5.
//! The paper subsamples users (20k / 100k) and clusters them with cosine
//! distance. The geometry corrSH sees: distances driven by *support overlap*
//! (popularity power-law means most co-ratings happen on blockbusters) plus
//! a latent taste alignment — so ρ_i decays slower than on RNA-Seq and
//! corrSH needs ~15–19 pulls/arm instead of ~2 (Table 1 rows 3–4).
//!
//! Construction: movie popularity ~ Zipf(α); user activity ~ power law
//! around `density · dim`; user/movie latent factors in R^f; rating =
//! clamp(round(3 + u·v + noise), 1, 5); support drawn popularity-weighted
//! without replacement.

use crate::data::{Data, SparseData};
use crate::util::rng::Rng;

use super::SynthConfig;

pub fn generate(cfg: &SynthConfig) -> Data {
    let mut rng = Rng::seeded(cfg.seed ^ 0x0E7F_11F5);
    let n = cfg.n;
    let dim = cfg.dim;
    let f = 8usize; // latent factor dimension

    // movie popularity weights: Zipf-ish over a shuffled order
    let mut pop: Vec<f64> = (1..=dim).map(|r| 1.0 / (r as f64).powf(0.9)).collect();
    rng.shuffle(&mut pop);
    // cumulative table for weighted sampling
    let mut cum: Vec<f64> = Vec::with_capacity(dim);
    let mut acc = 0.0;
    for &w in &pop {
        acc += w;
        cum.push(acc);
    }
    let total_w = acc;

    // latent factors; a handful of taste archetypes + user jitter keeps a
    // dense core of "mainstream" users (unique medoid)
    let k = cfg.clusters.max(1);
    let archetypes: Vec<Vec<f64>> =
        (0..k).map(|_| (0..f).map(|_| rng.gaussian() * 0.5).collect()).collect();
    let movie_f: Vec<Vec<f64>> =
        (0..dim).map(|_| (0..f).map(|_| rng.gaussian() * 0.5).collect()).collect();

    let target_nnz = (cfg.density.max(1e-5) * dim as f64).max(2.0);

    let mut rows: Vec<Vec<(u32, f32)>> = Vec::with_capacity(n);
    for _ in 0..n {
        // mainstream cluster is big (core), others smaller
        let a = if rng.chance(0.5) { 0 } else { rng.below(k) };
        let u: Vec<f64> = archetypes[a]
            .iter()
            .map(|&x| x + rng.gaussian() * 0.3)
            .collect();

        // activity: power-law multiple of the target
        let mult = rng.power_law(1.8).min(20.0);
        let nnz = ((target_nnz * mult) as usize).clamp(1, dim);

        // popularity-weighted support without replacement (rejection)
        let mut seen = std::collections::HashSet::with_capacity(nnz * 2);
        let mut support = Vec::with_capacity(nnz);
        let mut guard = 0;
        while support.len() < nnz && guard < nnz * 50 {
            guard += 1;
            let x = rng.f64() * total_w;
            let m = cum.partition_point(|&c| c < x).min(dim - 1);
            if seen.insert(m) {
                support.push(m);
            }
        }
        // fill any shortfall uniformly
        while support.len() < nnz {
            let m = rng.below(dim);
            if seen.insert(m) {
                support.push(m);
            }
        }
        support.sort_unstable();

        let row: Vec<(u32, f32)> = support
            .into_iter()
            .map(|m| {
                let affinity: f64 =
                    u.iter().zip(&movie_f[m]).map(|(a, b)| a * b).sum::<f64>();
                let r = (3.0 + affinity * 2.0 + rng.gaussian() * 0.7).round();
                (m as u32, r.clamp(1.0, 5.0) as f32)
            })
            .collect();
        rows.push(row);
    }

    Data::Sparse(SparseData::from_rows(n, dim, rows))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::distance::Metric;

    fn gen(n: usize, dim: usize) -> Data {
        generate(&SynthConfig { n, dim, seed: 4, density: 0.01, ..Default::default() })
    }

    #[test]
    fn ratings_in_1_to_5() {
        let d = gen(100, 500);
        if let Data::Sparse(s) = &d {
            assert!(s.values.iter().all(|&v| (1.0..=5.0).contains(&v)));
        } else {
            panic!("netflix must be sparse");
        }
    }

    #[test]
    fn density_near_target() {
        let d = gen(400, 1000);
        if let Data::Sparse(s) = &d {
            // power-law activity inflates the mean; just require the right
            // order of magnitude and actual sparsity
            assert!(s.density() > 0.003 && s.density() < 0.1, "density {}", s.density());
        }
    }

    #[test]
    fn popularity_skew_exists() {
        let d = gen(300, 400);
        if let Data::Sparse(s) = &d {
            let mut col_counts = vec![0usize; 400];
            for &c in &s.indices {
                col_counts[c as usize] += 1;
            }
            col_counts.sort_unstable_by(|a, b| b.cmp(a));
            let top10: usize = col_counts[..40].iter().sum();
            let total: usize = col_counts.iter().sum();
            // top 10% of movies should take a disproportionate share (>25%)
            assert!(
                top10 as f64 > total as f64 * 0.25,
                "no popularity skew: top10% = {top10}/{total}"
            );
        }
    }

    #[test]
    fn cosine_distances_nontrivial() {
        let d = gen(100, 500);
        let norms = d.norms();
        let mut rng = crate::util::rng::Rng::seeded(2);
        let mut vals = Vec::new();
        for _ in 0..200 {
            let (i, j) = (rng.below(100), rng.below(100));
            if i != j {
                vals.push(d.distance(Metric::Cosine, i, j, Some(&norms)));
            }
        }
        let mean = vals.iter().sum::<f32>() / vals.len() as f32;
        assert!((0.05..1.6).contains(&mean), "degenerate cosine geometry {mean}");
        let spread = vals.iter().cloned().fold(f32::MIN, f32::max)
            - vals.iter().cloned().fold(f32::MAX, f32::min);
        assert!(spread > 0.05, "no spread {spread}");
    }
}
