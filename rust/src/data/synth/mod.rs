//! Synthetic dataset generators standing in for the paper's evaluation data
//! (10x RNA-Seq, Netflix-prize, MNIST zeros — see DESIGN.md §7 for the
//! substitution rationale). Each generator is deterministic in
//! `SynthConfig::seed` and matched to the *statistical geometry* that drives
//! Correlated Sequential Halving: a dense core with a unique medoid, a
//! heavy-tailed periphery, and difference-variances (ρ_i) that shrink with
//! Δ_i.

pub mod gaussian;
pub mod mnist;
pub mod netflix;
pub mod rnaseq;

use crate::data::Data;

/// Common generator knobs. Defaults give quick-test sizes; the experiment
/// configs scale `n`/`dim` up to the paper's shapes.
#[derive(Clone, Debug)]
pub struct SynthConfig {
    /// Number of points (cells / users / images).
    pub n: usize,
    /// Ambient dimension (genes / movies / pixels).
    pub dim: usize,
    /// RNG seed; trials vary this 0..999 as in the paper §3.1.
    pub seed: u64,
    /// Number of latent clusters (where applicable).
    pub clusters: usize,
    /// Target density for sparse generators.
    pub density: f64,
    /// Fraction of periphery/outlier points.
    pub outlier_frac: f64,
}

impl Default for SynthConfig {
    fn default() -> Self {
        SynthConfig {
            n: 1_000,
            dim: 256,
            seed: 0,
            clusters: 8,
            density: 0.002,
            outlier_frac: 0.05,
        }
    }
}

/// Named dataset kinds the launcher/config system exposes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kind {
    RnaSeq,
    Netflix,
    Mnist,
    Gaussian,
    /// Gaussian mixture with planted per-cluster medoids (`clusters` knob)
    /// — the k-medoids ground-truth workload.
    Mixture,
}

impl Kind {
    pub fn name(&self) -> &'static str {
        match self {
            Kind::RnaSeq => "rnaseq",
            Kind::Netflix => "netflix",
            Kind::Mnist => "mnist",
            Kind::Gaussian => "gaussian",
            Kind::Mixture => "mixture",
        }
    }

    /// The metric the paper pairs with this dataset.
    pub fn default_metric(&self) -> crate::distance::Metric {
        use crate::distance::Metric;
        match self {
            Kind::RnaSeq => Metric::L1,
            Kind::Netflix => Metric::Cosine,
            Kind::Mnist => Metric::L2,
            Kind::Gaussian => Metric::L2,
            Kind::Mixture => Metric::L2,
        }
    }

    pub fn generate(&self, cfg: &SynthConfig) -> Data {
        match self {
            Kind::RnaSeq => rnaseq::generate(cfg),
            Kind::Netflix => netflix::generate(cfg),
            Kind::Mnist => mnist::generate(cfg),
            Kind::Gaussian => gaussian::generate(cfg),
            Kind::Mixture => gaussian::generate_mixture(cfg),
        }
    }

    /// Generate straight into a shard set under `dir` (returns the
    /// manifest path). Below [`STREAM_THRESHOLD_FLOATS`] the resident
    /// generator runs and is converted — bitwise identical to
    /// [`Kind::generate`]. Above it, the gaussian-family kinds stream
    /// shard-by-shard through their per-row generators
    /// ([`gaussian::fill_rows_streamed`]) so n = 10⁶ never materializes —
    /// a distinct deterministic family (draw order differs from the
    /// resident generator). Kinds without a streaming writer refuse
    /// oversize requests instead of silently exhausting memory.
    pub fn write_sharded(
        &self,
        cfg: &SynthConfig,
        dir: impl AsRef<std::path::Path>,
        rows_per_shard: usize,
    ) -> crate::Result<std::path::PathBuf> {
        use crate::data::store;
        if cfg.n.saturating_mul(cfg.dim) <= STREAM_THRESHOLD_FLOATS {
            let data = self.generate(cfg);
            return store::write_sharded(&data, dir, rows_per_shard);
        }
        let fill: fn(&SynthConfig, usize, &mut [f32]) = match self {
            Kind::Gaussian => gaussian::fill_rows_streamed,
            Kind::Mixture => gaussian::fill_mixture_rows_streamed,
            other => crate::bail!(
                "{}: no streaming shard writer — {}x{} exceeds the resident limit",
                other.name(),
                cfg.n,
                cfg.dim
            ),
        };
        let mut w = store::DenseShardWriter::create(dir, cfg.dim, rows_per_shard)?;
        let mut buf = vec![0f32; rows_per_shard.min(cfg.n) * cfg.dim];
        let mut row = 0usize;
        while row < cfg.n {
            let take = rows_per_shard.min(cfg.n - row);
            let slab = &mut buf[..take * cfg.dim];
            fill(cfg, row, slab);
            w.push_rows(slab)?;
            row += take;
        }
        w.finish()
    }
}

/// Largest `n·dim` the resident-then-convert path of
/// [`Kind::write_sharded`] will materialize (2²⁶ floats = 256 MiB).
pub const STREAM_THRESHOLD_FLOATS: usize = 1 << 26;

impl std::str::FromStr for Kind {
    type Err = crate::util::error::Error;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "rnaseq" | "rna" | "rna-seq" => Ok(Kind::RnaSeq),
            "netflix" => Ok(Kind::Netflix),
            "mnist" | "mnist-zeros" => Ok(Kind::Mnist),
            "gaussian" | "toy" => Ok(Kind::Gaussian),
            "mixture" | "gmm" => Ok(Kind::Mixture),
            other => crate::bail!("unknown dataset kind {other:?}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kinds_parse() {
        for k in [Kind::RnaSeq, Kind::Netflix, Kind::Mnist, Kind::Gaussian, Kind::Mixture] {
            assert_eq!(k.name().parse::<Kind>().unwrap(), k);
        }
    }

    #[test]
    fn write_sharded_small_matches_generate_bitwise() {
        // Below the streaming threshold the shard set is a conversion of
        // the resident generator — every row bitwise equal.
        let dir = std::env::temp_dir().join("corrsh-synth-tests");
        let cfg = SynthConfig { n: 60, dim: 24, seed: 4, density: 0.1, ..Default::default() };
        for k in [Kind::Gaussian, Kind::RnaSeq] {
            let sub = dir.join(k.name());
            let _ = std::fs::remove_dir_all(&sub);
            let manifest = k.write_sharded(&cfg, &sub, 16).unwrap();
            let sharded = crate::data::loader::load(&manifest).unwrap();
            let resident = k.generate(&cfg);
            assert_eq!(sharded.is_sparse(), resident.is_sparse(), "{}", k.name());
            let mut a = vec![0f32; cfg.dim];
            let mut b = vec![0f32; cfg.dim];
            for i in 0..cfg.n {
                sharded.densify_row_into(i, &mut a);
                resident.densify_row_into(i, &mut b);
                assert_eq!(a, b, "{} row {i}", k.name());
            }
        }
    }

    #[test]
    fn generators_deterministic_by_seed() {
        let cfg = SynthConfig { n: 50, dim: 64, seed: 9, ..Default::default() };
        for k in [Kind::RnaSeq, Kind::Netflix, Kind::Mnist, Kind::Gaussian, Kind::Mixture] {
            let a = k.generate(&cfg);
            let b = k.generate(&cfg);
            assert_eq!(a.n(), b.n());
            // deep determinism: distances agree
            for (i, j) in [(0, 1), (3, 40), (20, 7)] {
                let m = k.default_metric();
                assert_eq!(
                    a.distance(m, i, j, None),
                    b.distance(m, i, j, None),
                    "{} not deterministic",
                    k.name()
                );
            }
            let c = k.generate(&SynthConfig { seed: 10, ..cfg.clone() });
            let diff = a.distance(k.default_metric(), 0, 1, None)
                - c.distance(k.default_metric(), 0, 1, None);
            assert!(diff.abs() > 0.0 || a.n() < 2, "{} ignores seed", k.name());
        }
    }
}
