//! Gaussian mixture toy data — the Fig. 2 intuition dataset and the
//! planted-medoid workload the integration tests use.
//!
//! A dominant isotropic cluster at the origin (its center-most point is the
//! medoid with overwhelming probability) plus `outlier_frac` periphery
//! points at large radius: exactly the "reference point on the periphery"
//! situation the paper's Fig. 2a draws.

use crate::data::{Data, DenseData};
use crate::util::rng::Rng;

use super::SynthConfig;

pub fn generate(cfg: &SynthConfig) -> Data {
    let mut rng = Rng::seeded(cfg.seed ^ 0x6A05_51AA);
    let n = cfg.n;
    let dim = cfg.dim;
    let mut data = vec![0f32; n * dim];

    // point 0 is planted exactly at the origin -> it is the medoid of the
    // core cluster (and of the dataset, for small outlier_frac)
    for i in 1..n {
        let row = &mut data[i * dim..(i + 1) * dim];
        if rng.chance(cfg.outlier_frac) {
            // periphery: radius ~ 8x core scale in a random direction
            let scale = 6.0 + rng.power_law(2.0).min(10.0);
            for v in row.iter_mut() {
                *v = (rng.gaussian() * scale) as f32;
            }
        } else {
            for v in row.iter_mut() {
                *v = rng.gaussian() as f32;
            }
        }
    }
    Data::Dense(DenseData::new(n, dim, data))
}

/// Gaussian mixture with **planted per-cluster medoids** — the k-medoids
/// workload's ground-truth dataset ([`crate::kmedoids`]).
///
/// `cfg.clusters` well-separated unit-variance clusters (centers drawn at
/// 10× scale, so inter-center distances dwarf the within-cluster spread).
/// Point `j` belongs to cluster `j % clusters`, and points `0..clusters`
/// sit *exactly* on their cluster's center — each is its cluster's medoid
/// with overwhelming probability (same argument as [`generate`]'s planted
/// point 0), so the optimal medoid set is `{0, .., clusters-1}`.
pub fn generate_mixture(cfg: &SynthConfig) -> Data {
    let mut rng = Rng::seeded(cfg.seed ^ 0x13C7_55EE);
    let n = cfg.n;
    let dim = cfg.dim;
    let k = cfg.clusters.clamp(1, n.max(1));
    let mut centers = vec![0f32; k * dim];
    for v in centers.iter_mut() {
        *v = (rng.gaussian() * 10.0) as f32;
    }
    let mut data = vec![0f32; n * dim];
    for i in 0..n {
        let c = i % k;
        let row = &mut data[i * dim..(i + 1) * dim];
        row.copy_from_slice(&centers[c * dim..(c + 1) * dim]);
        if i >= k {
            for v in row.iter_mut() {
                *v += rng.gaussian() as f32;
            }
        }
    }
    Data::Dense(DenseData::new(n, dim, data))
}

/// Fill `out` (row-major, `out.len() / dim` rows) with rows
/// `row0..row0+rows` of the *streamed* gaussian family: every row is
/// generated from its own `(seed, index)`-derived RNG, so any shard of the
/// dataset can be produced independently — the shape the shard writers
/// need at n = 10⁶ where materializing the matrix is exactly what we're
/// avoiding. Same structure as [`generate`] (planted row 0 at the origin,
/// `outlier_frac` periphery), but a distinct deterministic family: the
/// draw order differs, so streamed bytes ≠ [`generate`] bytes.
pub fn fill_rows_streamed(cfg: &SynthConfig, row0: usize, out: &mut [f32]) {
    let dim = cfg.dim;
    debug_assert_eq!(out.len() % dim, 0);
    for (k, row) in out.chunks_exact_mut(dim).enumerate() {
        let i = row0 + k;
        let mut rng = Rng::seeded(
            (cfg.seed ^ 0x5EED_57AE).wrapping_add((i as u64).wrapping_mul(0x9E3779B97F4A7C15)),
        );
        if i == 0 {
            row.fill(0.0);
        } else if rng.chance(cfg.outlier_frac) {
            let scale = 6.0 + rng.power_law(2.0).min(10.0);
            for v in row.iter_mut() {
                *v = (rng.gaussian() * scale) as f32;
            }
        } else {
            for v in row.iter_mut() {
                *v = rng.gaussian() as f32;
            }
        }
    }
}

/// Streamed mixture rows (see [`fill_rows_streamed`]): same planted
/// structure as [`generate_mixture`] — point `i` in cluster `i % k`,
/// points `0..k` exactly on their centers — with per-row RNGs so shards
/// generate independently.
pub fn fill_mixture_rows_streamed(cfg: &SynthConfig, row0: usize, out: &mut [f32]) {
    let dim = cfg.dim;
    debug_assert_eq!(out.len() % dim, 0);
    let k = cfg.clusters.clamp(1, cfg.n.max(1));
    // centers are tiny (k·dim): regenerate per call from the center RNG
    let mut crng = Rng::seeded(cfg.seed ^ 0x13C7_55EE);
    let mut centers = vec![0f32; k * dim];
    for v in centers.iter_mut() {
        *v = (crng.gaussian() * 10.0) as f32;
    }
    for (j, row) in out.chunks_exact_mut(dim).enumerate() {
        let i = row0 + j;
        let c = i % k;
        row.copy_from_slice(&centers[c * dim..(c + 1) * dim]);
        if i >= k {
            let mut rng = Rng::seeded(
                (cfg.seed ^ 0x717E_D0CC)
                    .wrapping_add((i as u64).wrapping_mul(0x9E3779B97F4A7C15)),
            );
            for v in row.iter_mut() {
                *v += rng.gaussian() as f32;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::distance::Metric;

    #[test]
    fn origin_point_is_central() {
        let cfg = SynthConfig {
            n: 300,
            dim: 16,
            seed: 8,
            outlier_frac: 0.05,
            ..Default::default()
        };
        let d = generate(&cfg);
        // exact θ_i sweep; arm 0 must be the argmin (planted medoid)
        let n = d.n();
        let theta = |i: usize| -> f64 {
            (0..n).map(|j| d.distance(Metric::L2, i, j, None) as f64).sum::<f64>() / n as f64
        };
        let t0 = theta(0);
        let mut best = (0, t0);
        for i in 1..n {
            let t = theta(i);
            if t < best.1 {
                best = (i, t);
            }
        }
        assert_eq!(best.0, 0, "planted medoid lost: θ_0={t0:.4}, θ_{}={:.4}", best.0, best.1);
    }

    #[test]
    fn mixture_plants_per_cluster_medoids() {
        let k = 4;
        let cfg = SynthConfig { n: 400, dim: 8, seed: 3, clusters: k, ..Default::default() };
        let d = generate_mixture(&cfg);
        // Within each cluster (members j ≡ c mod k), the planted center c
        // must be the exact within-cluster medoid.
        for c in 0..k {
            let members: Vec<usize> = (0..d.n()).filter(|j| j % k == c).collect();
            let theta = |i: usize| -> f64 {
                members.iter().map(|&j| d.distance(Metric::L2, i, j, None) as f64).sum()
            };
            let t_center = theta(c);
            for &m in &members {
                assert!(
                    t_center <= theta(m) + 1e-9,
                    "cluster {c}: planted center beaten by member {m}"
                );
            }
        }
        // Clusters are well separated: cross-cluster distances dwarf
        // within-cluster ones.
        let within = d.distance(Metric::L2, 0, k, None);
        let across = d.distance(Metric::L2, 0, 1, None);
        assert!(across > 3.0 * within, "clusters not separated: {across} vs {within}");
    }

    #[test]
    fn streamed_rows_are_shard_independent() {
        // Generating [0, 40) in one call must equal generating any window
        // split — the property that lets shards stream independently.
        let cfg = SynthConfig { n: 40, dim: 6, seed: 11, ..Default::default() };
        let mut whole = vec![0f32; 40 * 6];
        fill_rows_streamed(&cfg, 0, &mut whole);
        #[allow(clippy::float_cmp)]
        // lint: float-eq-ok(row 0 is written as literal zeros, not computed)
        let origin = whole[..6].iter().all(|&v| v == 0.0);
        assert!(origin, "row 0 planted at origin");
        for (start, rows) in [(0usize, 7usize), (7, 13), (20, 20)] {
            let mut window = vec![0f32; rows * 6];
            fill_rows_streamed(&cfg, start, &mut window);
            assert_eq!(window, whole[start * 6..(start + rows) * 6], "window {start}+{rows}");
        }
        // mixture: same independence plus planted centers
        let mcfg = SynthConfig { n: 40, dim: 6, clusters: 4, seed: 2, ..Default::default() };
        let mut mw = vec![0f32; 40 * 6];
        fill_mixture_rows_streamed(&mcfg, 0, &mut mw);
        let mut window = vec![0f32; 10 * 6];
        fill_mixture_rows_streamed(&mcfg, 17, &mut window);
        assert_eq!(window, mw[17 * 6..27 * 6]);
        // points 0..k sit exactly on their centers; members of the same
        // cluster are near them
        for i in 0..4 {
            let center = &mw[i * 6..(i + 1) * 6];
            let member = &mw[(i + 4) * 6..(i + 5) * 6];
            let d2: f32 = center.iter().zip(member).map(|(a, b)| (a - b) * (a - b)).sum();
            assert!(d2 < 100.0, "cluster {i} member strayed: {d2}");
        }
    }

    #[test]
    fn has_periphery() {
        let cfg = SynthConfig { n: 500, dim: 8, seed: 9, outlier_frac: 0.1, ..Default::default() };
        let d = generate(&cfg);
        let norms: Vec<f32> = (0..d.n())
            .map(|i| d.distance(Metric::L2, 0, i, None))
            .collect();
        let far = norms.iter().filter(|&&r| r > 10.0).count();
        assert!(far > 10, "expected periphery points, got {far}");
    }
}
