//! Synthetic single-cell RNA-Seq: sparse probability vectors under ℓ₁.
//!
//! Real scRNA-Seq expression profiles (the 10x mouse-brain dataset used in
//! the paper) are normalized per cell to a probability distribution over
//! ~28k genes, are ~90% zero, and cluster by cell type with a heavy-tailed
//! periphery of stressed/doublet cells. What corrSH cares about is the
//! resulting geometry of θ_i (a dense core → unique medoid, small Δ for many
//! arms) and of ρ_i (differences concentrate because reference-point
//! "remoteness" is shared across arms — the β_j confounder of Appendix B).
//!
//! Construction: K cluster centers, each a sparse log-normal expression
//! profile over a cluster-specific subset of "expressed" genes (plus a
//! shared housekeeping block so distances are not trivially bimodal); a cell
//! = multiplicative log-normal jitter of its center, re-normalized to sum 1;
//! `outlier_frac` of cells mix two random centers (doublets) or get heavy
//! extra jitter (stress), forming the periphery Fig. 2 depicts.

use crate::data::{Data, SparseData};
use crate::util::rng::Rng;

use super::SynthConfig;

pub fn generate(cfg: &SynthConfig) -> Data {
    let mut rng = Rng::seeded(cfg.seed ^ 0x5EED_51CE);
    let n = cfg.n;
    let dim = cfg.dim;
    let k = cfg.clusters.max(1);

    // per-cluster expressed-gene support: housekeeping block (first 10%)
    // + cluster-specific block (~20% of the remainder)
    let housekeeping = (dim / 10).max(1);
    let specific = ((dim - housekeeping) / 5).max(1);

    let mut center_support: Vec<Vec<u32>> = Vec::with_capacity(k);
    let mut center_logexpr: Vec<Vec<f32>> = Vec::with_capacity(k);
    for _ in 0..k {
        let mut support: Vec<u32> = (0..housekeeping as u32).collect();
        let extra = rng.sample_without_replacement(dim - housekeeping, specific);
        support.extend(extra.into_iter().map(|g| (g + housekeeping) as u32));
        support.sort_unstable();
        // log-normal expression level per expressed gene
        let logexpr: Vec<f32> =
            (0..support.len()).map(|_| (rng.gaussian() * 1.2) as f32).collect();
        center_support.push(support);
        center_logexpr.push(logexpr);
    }

    // cluster sizes: one dominant cluster (the medoid's neighbourhood) so the
    // dataset has a dense core, rest geometric-ish
    let mut rows: Vec<Vec<(u32, f32)>> = Vec::with_capacity(n);
    for _ in 0..n {
        let c = if rng.chance(0.45) { 0 } else { rng.below(k) };
        let outlier = rng.chance(cfg.outlier_frac);

        let (support, logexpr): (Vec<u32>, Vec<f32>) = if outlier && rng.chance(0.5) && k > 1 {
            // doublet: union of two cluster profiles at half weight
            let c2 = (c + 1 + rng.below(k - 1)) % k;
            let mut merged: Vec<(u32, f32)> = Vec::new();
            for (s, l) in [(c, 0.0f32), (c2, 0.0f32)] {
                let _ = l;
                for (&g, &e) in center_support[s].iter().zip(&center_logexpr[s]) {
                    merged.push((g, e - 0.7));
                }
            }
            merged.sort_unstable_by_key(|&(g, _)| g);
            merged.dedup_by(|a, b| {
                if a.0 == b.0 {
                    b.1 = (a.1.exp() + b.1.exp()).ln();
                    true
                } else {
                    false
                }
            });
            merged.into_iter().unzip()
        } else {
            (center_support[c].clone(), center_logexpr[c].clone())
        };

        // per-cell multiplicative jitter; outliers get 3x the noise
        let noise = if outlier { 1.8 } else { 0.6 };
        let mut vals: Vec<f32> = logexpr
            .iter()
            .map(|&le| (le as f64 + rng.gaussian() * noise).exp() as f32)
            .collect();
        // drop-outs: zero a random ~30% of expressed genes (scRNA capture)
        for v in vals.iter_mut() {
            if rng.chance(0.3) {
                *v = 0.0;
            }
        }
        // normalize to a probability vector (paper: ℓ₁ on normalized counts)
        let total: f32 = vals.iter().sum();
        let row: Vec<(u32, f32)> = if total > 0.0 {
            support
                .iter()
                .zip(&vals)
                .filter(|(_, &v)| v > 0.0)
                .map(|(&g, &v)| (g, v / total))
                .collect()
        } else {
            vec![(support[0], 1.0)]
        };
        rows.push(row);
    }

    Data::Sparse(SparseData::from_rows(n, dim, rows))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::distance::Metric;

    fn small() -> Data {
        generate(&SynthConfig { n: 200, dim: 256, seed: 3, ..Default::default() })
    }

    #[test]
    fn rows_are_probability_vectors() {
        let d = small();
        let s = match &d {
            Data::Sparse(s) => s,
            _ => panic!("rnaseq must be sparse"),
        };
        for i in 0..s.n {
            let sum: f32 = s.row(i).values.iter().sum();
            assert!((sum - 1.0).abs() < 1e-4, "row {i} sums to {sum}");
            assert!(s.row(i).values.iter().all(|&v| v >= 0.0));
        }
    }

    #[test]
    fn l1_distances_in_range() {
        // ℓ₁ between probability vectors is in [0, 2]
        let d = small();
        let mut rng = crate::util::rng::Rng::seeded(0);
        for _ in 0..100 {
            let (i, j) = (rng.below(200), rng.below(200));
            let dist = d.distance(Metric::L1, i, j, None);
            assert!((0.0..=2.0 + 1e-5).contains(&dist), "d({i},{j}) = {dist}");
        }
    }

    #[test]
    fn has_cluster_structure() {
        // within-core distances must be smaller than cross-cluster on average
        let d = small();
        let mut rng = crate::util::rng::Rng::seeded(1);
        let mut all = Vec::new();
        for _ in 0..500 {
            let (i, j) = (rng.below(200), rng.below(200));
            if i != j {
                all.push(d.distance(Metric::L1, i, j, None));
            }
        }
        let mean = all.iter().sum::<f32>() / all.len() as f32;
        let min = all.iter().cloned().fold(f32::MAX, f32::min);
        // structure: some pairs much closer than the average pair
        assert!(min < 0.5 * mean, "no cluster structure: min {min}, mean {mean}");
    }

    #[test]
    fn is_actually_sparse() {
        let d = small();
        if let Data::Sparse(s) = &d {
            assert!(s.density() < 0.35, "density {}", s.density());
        }
    }
}
