//! Synthetic MNIST-zeros: dense 28×28-style ring images under ℓ₂.
//!
//! The paper's smallest dataset (6,424 images of handwritten '0', d = 784)
//! is the regime where exact computation is cheap and corrSH's advantage
//! narrows (Table 1 row 5: 47.9 pulls/arm). The relevant geometry: one
//! visual cluster (all zeros), smooth variation (stroke width, ellipse
//! shape, translation), dense vectors in [0,1].
//!
//! Construction: each image is an elliptical annulus with per-image center
//! jitter, radii, rotation, stroke width and intensity, rendered with a
//! soft (gaussian-profile) edge + pixel noise. `dim` must be a perfect
//! square (784 = 28² by default) — other values render on the nearest
//! square grid and pad/truncate.

use crate::data::{Data, DenseData};
use crate::util::rng::Rng;

use super::SynthConfig;

pub fn generate(cfg: &SynthConfig) -> Data {
    let mut rng = Rng::seeded(cfg.seed ^ 0x3141_5926);
    let n = cfg.n;
    let dim = cfg.dim;
    let side = (dim as f64).sqrt().round() as usize;
    let side = side.max(4);

    let mut data = vec![0f32; n * dim];
    for img in 0..n {
        // per-image shape parameters
        let cx = side as f64 / 2.0 + rng.gaussian() * side as f64 * 0.04;
        let cy = side as f64 / 2.0 + rng.gaussian() * side as f64 * 0.04;
        let r0 = side as f64 * (0.28 + rng.f64() * 0.08); // mean radius
        let ecc = 0.75 + rng.f64() * 0.5; // x/y radius ratio
        let theta = rng.gaussian() * 0.3; // rotation
        let stroke = side as f64 * (0.06 + rng.f64() * 0.05);
        let intensity = 0.75 + rng.f64() * 0.25;
        let outlier = rng.chance(cfg.outlier_frac);
        let noise = if outlier { 0.18 } else { 0.05 };

        let (sin_t, cos_t) = theta.sin_cos();
        let row = &mut data[img * dim..(img + 1) * dim];
        for py in 0..side {
            for px in 0..side {
                let idx = py * side + px;
                if idx >= dim {
                    continue;
                }
                // rotate into the ellipse frame
                let dx = px as f64 + 0.5 - cx;
                let dy = py as f64 + 0.5 - cy;
                let ex = (dx * cos_t + dy * sin_t) / ecc;
                let ey = -dx * sin_t + dy * cos_t;
                let r = (ex * ex + ey * ey).sqrt();
                // soft annulus: gaussian profile around radius r0
                let z = (r - r0) / stroke;
                let v = intensity * (-0.5 * z * z).exp();
                let v = v + rng.gaussian() * noise;
                row[idx] = v.clamp(0.0, 1.0) as f32;
            }
        }
    }
    Data::Dense(DenseData::new(n, dim, data))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::distance::Metric;

    fn gen(n: usize) -> Data {
        generate(&SynthConfig { n, dim: 784, seed: 6, ..Default::default() })
    }

    #[test]
    fn pixels_in_unit_interval() {
        let d = gen(50);
        if let Data::Dense(dd) = &d {
            assert!(dd.data.iter().all(|&v| (0.0..=1.0).contains(&v)));
        } else {
            panic!("mnist must be dense");
        }
    }

    #[test]
    fn images_have_ring_mass() {
        // ring images: substantial nonzero mass, but far from full
        let d = gen(20);
        if let Data::Dense(dd) = &d {
            for i in 0..dd.n {
                let mass: f32 = dd.row(i).iter().sum();
                let lit = dd.row(i).iter().filter(|&&v| v > 0.3).count();
                assert!(mass > 10.0, "image {i} empty (mass {mass})");
                assert!(
                    lit > 30 && lit < 784 * 3 / 4,
                    "image {i} not ring-like ({lit} bright pixels)"
                );
            }
        }
    }

    #[test]
    fn single_cluster_geometry() {
        // all zeros look alike: max pairwise l2 well below the d=784 diameter
        let d = gen(60);
        let mut rng = crate::util::rng::Rng::seeded(3);
        let mut vals = Vec::new();
        for _ in 0..300 {
            let (i, j) = (rng.below(60), rng.below(60));
            if i != j {
                vals.push(d.distance(Metric::L2, i, j, None));
            }
        }
        let max = vals.iter().cloned().fold(f32::MIN, f32::max);
        let min = vals.iter().cloned().fold(f32::MAX, f32::min);
        assert!(max < 28.0, "zeros too spread: {max}"); // sqrt(784)=28 is all-on vs all-off
        assert!(min > 0.0, "duplicate images");
    }

    #[test]
    fn nonsquare_dim_still_works() {
        let d = generate(&SynthConfig { n: 5, dim: 100, seed: 1, ..Default::default() });
        assert_eq!(d.dim(), 100);
        assert_eq!(d.n(), 5);
    }
}
