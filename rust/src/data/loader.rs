//! Dataset loaders: `.npy` dense matrices, a simple CSR triplet format,
//! and shard manifests (`manifest.json` / shard directories — see
//! [`crate::data::store`]), so real datasets drop in for the synthetic
//! generators at any scale.
//!
//! CSR text format (one header line, then one line per nonzero):
//! ```text
//! csr <n> <dim>
//! <row> <col> <value>
//! ...
//! ```

use std::io::{BufRead, BufReader};
use std::path::Path;

use crate::bail;
use crate::util::error::{Context, Result};

use crate::data::store::{Manifest, ShardedData};
use crate::data::{Data, DenseData, SparseData};
use crate::util::npy;

/// Load a dataset, auto-detecting the format: a shard directory or
/// `manifest.json` opens as [`ShardedData`] *without loading payloads*;
/// `.npy` (dense) and `.csr` (sparse triplets) load resident.
pub fn load(path: impl AsRef<Path>) -> Result<Data> {
    let p = path.as_ref();
    if Manifest::detect(p) {
        return Ok(Data::Sharded(ShardedData::open(p)?));
    }
    match p.extension().and_then(|e| e.to_str()) {
        Some("npy") => {
            let m = npy::read(p)?;
            Ok(Data::Dense(DenseData::new(m.rows, m.cols, m.data)))
        }
        Some("csr") => load_csr(p),
        other => bail!(
            "unsupported dataset path {p:?} (want .npy, .csr, or a shard manifest); \
             extension {other:?}"
        ),
    }
}

fn load_csr(path: &Path) -> Result<Data> {
    let f = std::fs::File::open(path).with_context(|| format!("open {path:?}"))?;
    let mut lines = BufReader::new(f).lines();
    let header = lines.next().context("empty csr file")??;
    let mut it = header.split_whitespace();
    if it.next() != Some("csr") {
        bail!("bad csr header (want `csr <n> <dim>`)");
    }
    let n: usize = it.next().context("missing n")?.parse()?;
    let dim: usize = it.next().context("missing dim")?.parse()?;

    let mut rows: Vec<Vec<(u32, f32)>> = vec![Vec::new(); n];
    for (lineno, line) in lines.enumerate() {
        let line = line?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('#') {
            continue;
        }
        let mut it = t.split_whitespace();
        let r: usize = it.next().context("missing row")?.parse()
            .with_context(|| format!("line {}", lineno + 2))?;
        let c: u32 = it.next().context("missing col")?.parse()?;
        let v: f32 = it.next().context("missing value")?.parse()?;
        if r >= n || c as usize >= dim {
            bail!("entry ({r},{c}) out of bounds for {n}x{dim} at line {}", lineno + 2);
        }
        rows[r].push((c, v));
    }
    Ok(Data::Sparse(SparseData::from_rows(n, dim, rows)))
}

/// Save a dense dataset as `.npy` (interchange with the python layer).
pub fn save_dense_npy(path: impl AsRef<Path>, d: &DenseData) -> Result<()> {
    npy::write(path, &npy::Matrix::new(d.n, d.dim, d.data.clone()))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("corrsh-loader-tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn npy_roundtrip_through_loader() {
        let d = DenseData::new(2, 3, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let p = tmp("x.npy");
        save_dense_npy(&p, &d).unwrap();
        match load(&p).unwrap() {
            Data::Dense(back) => {
                assert_eq!(back.data, d.data);
                assert_eq!((back.n, back.dim), (2, 3));
            }
            _ => panic!("expected dense"),
        }
    }

    #[test]
    fn csr_text_roundtrip() {
        let p = tmp("x.csr");
        std::fs::write(&p, "csr 3 5\n0 1 2.5\n0 4 -1\n2 0 7\n# comment\n\n").unwrap();
        match load(&p).unwrap() {
            Data::Sparse(s) => {
                assert_eq!((s.n, s.dim), (3, 5));
                assert_eq!(s.row(0).indices, &[1, 4]);
                assert_eq!(s.row(1).nnz(), 0);
                assert_eq!(s.row(2).values, &[7.0]);
            }
            _ => panic!("expected sparse"),
        }
    }

    #[test]
    fn csr_bounds_checked() {
        let p = tmp("bad.csr");
        std::fs::write(&p, "csr 2 2\n5 0 1.0\n").unwrap();
        assert!(load(&p).is_err());
    }

    #[test]
    fn unknown_extension_rejected() {
        assert!(load("data.parquet").is_err());
    }

    #[test]
    fn manifest_roundtrip_through_loader() {
        use crate::data::store;
        let d = DenseData::new(9, 4, (0..36).map(|i| i as f32 * 0.25).collect());
        let dir = std::env::temp_dir().join("corrsh-loader-tests").join("shards");
        let _ = std::fs::remove_dir_all(&dir);
        let manifest = store::write_sharded(&Data::Dense(d.clone()), &dir, 4).unwrap();
        // both the manifest path and its directory auto-detect
        for p in [manifest.as_path(), dir.as_path()] {
            match load(p).unwrap() {
                Data::Sharded(sd) => {
                    assert_eq!((sd.n(), sd.dim()), (9, 4));
                    let mut buf = vec![0f32; 4];
                    sd.densify_row_into(7, &mut buf);
                    assert_eq!(buf, d.row(7));
                }
                other => panic!("expected sharded, got {other:?}"),
            }
        }
    }
}
