//! Shard readers: zero-copy mmap (feature `mmap`, linux x86_64/aarch64)
//! with a pure-`std` fallback that `pread()`s shard windows into a small
//! LRU of pinned blocks, so the default no-unsafe/offline build serves the
//! same manifests with bounded resident memory (DESIGN.md §12).
//!
//! Cache traffic is observable through the process-global
//! [`cache_stats`] (hit/miss counters + pinned-bytes gauge), exported by
//! the server's `metrics` op as `shard_cache`.

use std::collections::HashMap;
use std::fs::File;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicI64, Ordering};
use std::sync::{Arc, Mutex};

use crate::data::store::manifest::{Manifest, ShardFiles};
use crate::distance::SparseRow;
use crate::metrics::Counter;
use crate::util::error::{Context, Result};
use crate::util::npy;

/// Reader knobs. Defaults serve million-point shard sets inside a small,
/// fixed resident budget; tests shrink the cache to force evictions.
#[derive(Clone, Debug)]
pub struct StoreOptions {
    /// Total bytes the pinned-block caches may hold per dataset
    /// (default 128 MiB, env `CORRSH_SHARD_CACHE_MB` overrides).
    pub cache_bytes: usize,
    /// Bytes per cached dense block (rounded to whole rows; default 256 KiB).
    pub block_bytes: usize,
    /// Skip the mmap reader even when compiled in (tests compare readers).
    pub force_pinned: bool,
}

impl Default for StoreOptions {
    fn default() -> Self {
        let mb = std::env::var("CORRSH_SHARD_CACHE_MB")
            .ok()
            .and_then(|s| s.parse::<usize>().ok())
            .unwrap_or(128)
            .max(1);
        StoreOptions { cache_bytes: mb << 20, block_bytes: 1 << 18, force_pinned: false }
    }
}

/// Process-global shard-cache telemetry: hits/misses are monotone
/// counters, `pinned_bytes` tracks bytes currently held by pinned-block
/// caches across every open [`crate::data::store::ShardedData`].
#[derive(Debug)]
pub struct ShardCacheStats {
    hits: Counter,
    misses: Counter,
    pinned: AtomicI64,
}

impl ShardCacheStats {
    const fn new() -> Self {
        ShardCacheStats { hits: Counter::new(), misses: Counter::new(), pinned: AtomicI64::new(0) }
    }

    pub fn hits(&self) -> u64 {
        self.hits.get()
    }

    pub fn misses(&self) -> u64 {
        self.misses.get()
    }

    pub fn pinned_bytes(&self) -> u64 {
        self.pinned.load(Ordering::Relaxed).max(0) as u64
    }

    fn add_pinned(&self, delta: i64) {
        self.pinned.fetch_add(delta, Ordering::Relaxed);
    }
}

/// The global shard-cache stats sink (see [`ShardCacheStats`]).
pub fn cache_stats() -> &'static ShardCacheStats {
    static STATS: ShardCacheStats = ShardCacheStats::new();
    &STATS
}

/// Positioned read that never moves a shared cursor (concurrent workers
/// read the same shard files).
#[cfg(unix)]
fn read_exact_at(f: &File, buf: &mut [u8], off: u64) -> std::io::Result<()> {
    std::os::unix::fs::FileExt::read_exact_at(f, buf, off)
}

#[cfg(windows)]
fn read_exact_at(f: &File, buf: &mut [u8], off: u64) -> std::io::Result<()> {
    use std::os::windows::fs::FileExt;
    let mut pos = 0usize;
    while pos < buf.len() {
        let n = f.seek_read(&mut buf[pos..], off + pos as u64)?;
        if n == 0 {
            return Err(std::io::Error::new(std::io::ErrorKind::UnexpectedEof, "short read"));
        }
        pos += n;
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// mmap (feature `mmap`): raw-syscall read-only mapping, so the offline
// dependency closure stays empty (no libc crate). Unsupported targets and
// the default build fall back to the pinned reader transparently.
// ---------------------------------------------------------------------------

#[cfg(all(
    feature = "mmap",
    target_os = "linux",
    any(target_arch = "x86_64", target_arch = "aarch64")
))]
mod mapping {
    use std::fs::File;
    use std::os::fd::AsRawFd;

    const PROT_READ: usize = 1;
    const MAP_PRIVATE: usize = 2;

    /// Read-only private mapping of a whole shard file.
    pub struct Mmap {
        ptr: *const u8,
        len: usize,
    }

    // SAFETY: the mapping is PROT_READ/MAP_PRIVATE over an immutable shard
    // file — shared references to its bytes never alias a write.
    unsafe impl Send for Mmap {}
    unsafe impl Sync for Mmap {}

    impl Mmap {
        pub fn map_readonly(f: &File) -> std::io::Result<Mmap> {
            let len = f.metadata()?.len() as usize;
            if len == 0 {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::InvalidInput,
                    "cannot map an empty shard",
                ));
            }
            // SAFETY: valid fd, length > 0; the kernel picks the address.
            let ret = unsafe { sys_mmap(len, f.as_raw_fd()) };
            if (-4095..0).contains(&ret) {
                return Err(std::io::Error::from_raw_os_error(-ret as i32));
            }
            Ok(Mmap { ptr: ret as *const u8, len })
        }

        pub fn bytes(&self) -> &[u8] {
            // SAFETY: ptr/len describe the live mapping owned by self.
            unsafe { std::slice::from_raw_parts(self.ptr, self.len) }
        }
    }

    impl Drop for Mmap {
        fn drop(&mut self) {
            // SAFETY: unmapping exactly the range mmap returned.
            unsafe { sys_munmap(self.ptr, self.len) };
        }
    }

    // SAFETY: caller must pass a readable fd and a non-zero length no larger
    // than the file; the raw syscall clobbers only the registers listed.
    #[cfg(target_arch = "x86_64")]
    unsafe fn sys_mmap(len: usize, fd: i32) -> isize {
        let mut ret: isize = 9; // __NR_mmap
        std::arch::asm!(
            "syscall",
            inlateout("rax") ret,
            in("rdi") 0usize,
            in("rsi") len,
            in("rdx") PROT_READ,
            in("r10") MAP_PRIVATE,
            in("r8") fd as isize,
            in("r9") 0usize,
            lateout("rcx") _,
            lateout("r11") _,
            options(nostack)
        );
        ret
    }

    // SAFETY: caller must pass the exact (addr, len) a successful sys_mmap
    // returned, and no reference into the mapping may outlive the call.
    #[cfg(target_arch = "x86_64")]
    unsafe fn sys_munmap(addr: *const u8, len: usize) {
        let mut _ret: isize = 11; // __NR_munmap
        std::arch::asm!(
            "syscall",
            inlateout("rax") _ret,
            in("rdi") addr,
            in("rsi") len,
            lateout("rcx") _,
            lateout("r11") _,
            options(nostack)
        );
    }

    // SAFETY: caller must pass a readable fd and a non-zero length no larger
    // than the file; svc 0 clobbers only the registers listed.
    #[cfg(target_arch = "aarch64")]
    unsafe fn sys_mmap(len: usize, fd: i32) -> isize {
        let mut ret: isize = 0;
        std::arch::asm!(
            "svc 0",
            in("x8") 222usize, // __NR_mmap
            inlateout("x0") ret,
            in("x1") len,
            in("x2") PROT_READ,
            in("x3") MAP_PRIVATE,
            in("x4") fd as isize,
            in("x5") 0usize,
            options(nostack)
        );
        ret
    }

    // SAFETY: caller must pass the exact (addr, len) a successful sys_mmap
    // returned, and no reference into the mapping may outlive the call.
    #[cfg(target_arch = "aarch64")]
    unsafe fn sys_munmap(addr: *const u8, len: usize) {
        let mut _ret: isize = addr as isize;
        std::arch::asm!(
            "svc 0",
            in("x8") 215usize, // __NR_munmap
            inlateout("x0") _ret,
            in("x1") len,
            options(nostack)
        );
    }
}

/// True when this build can serve dense shards zero-copy via mmap.
pub fn mmap_compiled() -> bool {
    cfg!(all(
        feature = "mmap",
        target_os = "linux",
        any(target_arch = "x86_64", target_arch = "aarch64")
    ))
}

// ---------------------------------------------------------------------------
// Dense backend
// ---------------------------------------------------------------------------

struct DenseShard {
    file: File,
    data_off: u64,
    rows: usize,
    #[cfg(all(
        feature = "mmap",
        target_os = "linux",
        any(target_arch = "x86_64", target_arch = "aarch64")
    ))]
    map: Option<mapping::Mmap>,
}

impl DenseShard {
    /// Zero-copy f32 view of the whole shard payload (mmap builds only).
    #[cfg(all(
        feature = "mmap",
        target_os = "linux",
        any(target_arch = "x86_64", target_arch = "aarch64")
    ))]
    fn floats(&self, dim: usize) -> Option<&[f32]> {
        let m = self.map.as_ref()?;
        let off = self.data_off as usize;
        let count = self.rows * dim;
        let bytes = m.bytes();
        debug_assert!(off % 4 == 0 && off + count * 4 <= bytes.len());
        // SAFETY: 4-alignment of `off` and payload bounds were validated at
        // open (unaligned/short shards are never mapped); the mapping is
        // read-only and outlives the returned borrow.
        Some(unsafe { std::slice::from_raw_parts(bytes.as_ptr().add(off) as *const f32, count) })
    }

    #[cfg(not(all(
        feature = "mmap",
        target_os = "linux",
        any(target_arch = "x86_64", target_arch = "aarch64")
    )))]
    fn floats(&self, _dim: usize) -> Option<&[f32]> {
        None
    }
}

struct CachedBlock {
    data: Arc<Vec<f32>>,
    stamp: u64,
}

struct BlockCache {
    map: HashMap<(u32, u32), CachedBlock>,
    clock: u64,
    bytes: usize,
    budget: usize,
}

pub(crate) struct DenseBackend {
    dim: usize,
    rows_per_shard: usize,
    /// Rows per pinned block (blocks never straddle a shard).
    block_rows: usize,
    shards: Vec<DenseShard>,
    cache: Mutex<BlockCache>,
}

impl DenseBackend {
    pub fn open(manifest: &Manifest, dir: &Path, opts: &StoreOptions) -> Result<DenseBackend> {
        let dim = manifest.dim;
        let mut shards = Vec::with_capacity(manifest.shards.len());
        for (s, e) in manifest.shards.iter().enumerate() {
            let ShardFiles::Dense { data } = &e.files else {
                crate::bail!("shard {s}: dense backend over sparse manifest entry");
            };
            let path = dir.join(data);
            let mut file = File::open(&path).with_context(|| format!("open shard {path:?}"))?;
            let h = npy::read_header_from(&mut file)
                .with_context(|| format!("shard header {path:?}"))?;
            crate::ensure!(
                h.dtype == npy::Dtype::F4,
                "shard {s}: dtype {:?} (shards must be <f4)",
                h.dtype
            );
            crate::ensure!(
                h.rows == e.rows && h.cols == dim,
                "shard {s}: {}x{} on disk vs {}x{dim} in manifest",
                h.rows,
                h.cols,
                e.rows
            );
            let need = h.data_offset + (e.rows * dim * 4) as u64;
            let len = file.metadata().with_context(|| format!("stat {path:?}"))?.len();
            crate::ensure!(len >= need, "shard {s}: file {len}B short of payload {need}B");
            shards.push(Self::new_shard(file, &h, e.rows, opts));
        }
        let block_rows =
            (opts.block_bytes / (dim * 4).max(1)).clamp(1, manifest.rows_per_shard.max(1));
        Ok(DenseBackend {
            dim,
            rows_per_shard: manifest.rows_per_shard,
            block_rows,
            shards,
            cache: Mutex::new(BlockCache {
                map: HashMap::new(),
                clock: 0,
                bytes: 0,
                budget: opts.cache_bytes.max(1),
            }),
        })
    }

    #[cfg(all(
        feature = "mmap",
        target_os = "linux",
        any(target_arch = "x86_64", target_arch = "aarch64")
    ))]
    fn new_shard(file: File, h: &npy::Header, rows: usize, opts: &StoreOptions) -> DenseShard {
        // The zero-copy view needs 4-aligned payloads and a little-endian
        // host; anything else quietly serves through the pinned reader.
        let map = if opts.force_pinned
            || h.data_offset % 4 != 0
            || !cfg!(target_endian = "little")
        {
            None
        } else {
            mapping::Mmap::map_readonly(&file).ok()
        };
        DenseShard { file, data_off: h.data_offset, rows, map }
    }

    #[cfg(not(all(
        feature = "mmap",
        target_os = "linux",
        any(target_arch = "x86_64", target_arch = "aarch64")
    )))]
    fn new_shard(file: File, h: &npy::Header, rows: usize, _opts: &StoreOptions) -> DenseShard {
        DenseShard { file, data_off: h.data_offset, rows }
    }

    /// True when every shard is served zero-copy.
    pub fn fully_mapped(&self) -> bool {
        self.shards.iter().all(|s| s.floats(self.dim).is_some())
    }

    /// Bytes currently pinned by this dataset's block cache.
    pub fn pinned_bytes(&self) -> usize {
        self.cache.lock().unwrap().bytes
    }

    #[inline]
    fn locate(&self, i: usize) -> (usize, usize) {
        (i / self.rows_per_shard, i % self.rows_per_shard)
    }

    /// Zero-copy row borrow — `Some` only on fully-mapped shards.
    #[inline]
    pub fn try_row(&self, i: usize) -> Option<&[f32]> {
        let (s, l) = self.locate(i);
        let fl = self.shards[s].floats(self.dim)?;
        Some(&fl[l * self.dim..(l + 1) * self.dim])
    }

    /// Serve row `i` to `f`, through the map or a pinned block.
    #[inline]
    pub fn with_row<R>(&self, i: usize, f: impl FnOnce(&[f32]) -> R) -> R {
        let (s, l) = self.locate(i);
        if let Some(fl) = self.shards[s].floats(self.dim) {
            return f(&fl[l * self.dim..(l + 1) * self.dim]);
        }
        let b = l / self.block_rows;
        let block = self.fetch_block(s, b);
        let base = (l - b * self.block_rows) * self.dim;
        f(&block[base..base + self.dim])
    }

    /// Visit rows `start..start+count` in order, fetching each shard window
    /// exactly once — the streaming shape `PreparedEngine` reduces over.
    pub fn for_rows(&self, start: usize, count: usize, mut f: impl FnMut(usize, &[f32])) {
        let end = start + count;
        let mut i = start;
        while i < end {
            let (s, l) = self.locate(i);
            let shard = &self.shards[s];
            if let Some(fl) = shard.floats(self.dim) {
                let take = (end - i).min(shard.rows - l);
                for k in 0..take {
                    f(i + k, &fl[(l + k) * self.dim..(l + k + 1) * self.dim]);
                }
                i += take;
            } else {
                let b = l / self.block_rows;
                let b0 = b * self.block_rows;
                let block_len = (shard.rows - b0).min(self.block_rows);
                let take = (end - i).min(block_len - (l - b0));
                let block = self.fetch_block(s, b);
                for k in 0..take {
                    let base = (l - b0 + k) * self.dim;
                    f(i + k, &block[base..base + self.dim]);
                }
                i += take;
            }
        }
    }

    fn fetch_block(&self, s: usize, b: usize) -> Arc<Vec<f32>> {
        let key = (s as u32, b as u32);
        {
            let mut c = self.cache.lock().unwrap();
            c.clock += 1;
            let stamp = c.clock;
            if let Some(e) = c.map.get_mut(&key) {
                e.stamp = stamp;
                let out = e.data.clone();
                drop(c);
                cache_stats().hits.add(1);
                return out;
            }
        }
        cache_stats().misses.add(1);
        // Shard I/O runs outside the cache lock so concurrent workers on
        // different blocks never serialize behind a pread; a racing pair on
        // the same cold block costs one redundant read at worst.
        let data = Arc::new(self.read_block(s, b));
        let bytes = data.len() * 4;
        let mut c = self.cache.lock().unwrap();
        c.clock += 1;
        let stamp = c.clock;
        let out = match c.map.get_mut(&key) {
            Some(e) => {
                e.stamp = stamp;
                e.data.clone()
            }
            None => {
                c.bytes += bytes;
                cache_stats().add_pinned(bytes as i64);
                c.map.insert(key, CachedBlock { data: data.clone(), stamp });
                data
            }
        };
        while c.bytes > c.budget && c.map.len() > 1 {
            let victim = c
                .map
                .iter()
                .filter(|(k, _)| **k != key)
                .min_by_key(|(_, e)| e.stamp)
                .map(|(k, _)| *k);
            match victim {
                Some(k) => {
                    let e = c.map.remove(&k).expect("victim present");
                    let freed = e.data.len() * 4;
                    c.bytes -= freed;
                    cache_stats().add_pinned(-(freed as i64));
                }
                None => break,
            }
        }
        out
    }

    fn read_block(&self, s: usize, b: usize) -> Vec<f32> {
        let shard = &self.shards[s];
        let r0 = b * self.block_rows;
        let rows = (shard.rows - r0).min(self.block_rows);
        let count = rows * self.dim;
        let mut raw = vec![0u8; count * 4];
        read_exact_at(&shard.file, &mut raw, shard.data_off + (r0 * self.dim * 4) as u64)
            .unwrap_or_else(|e| panic!("shard {s} block {b}: pread failed: {e}"));
        raw.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect()
    }
}

impl Drop for DenseBackend {
    fn drop(&mut self) {
        let c = self.cache.get_mut().unwrap();
        cache_stats().add_pinned(-(c.bytes as i64));
    }
}

// ---------------------------------------------------------------------------
// Sparse backend: whole decoded CSR shards in the LRU (a CSR row's three
// slices don't window cleanly into fixed-size blocks).
// ---------------------------------------------------------------------------

struct SparseShardFiles {
    indptr: PathBuf,
    indices: PathBuf,
    values: PathBuf,
    rows: usize,
    nnz: usize,
}

/// One decoded CSR shard (shard-local indptr).
struct SparseShardData {
    indptr: Vec<usize>,
    indices: Vec<u32>,
    values: Vec<f32>,
}

impl SparseShardData {
    fn bytes(&self) -> usize {
        self.indptr.len() * 8 + self.indices.len() * 4 + self.values.len() * 4
    }
}

struct CachedShard {
    data: Arc<SparseShardData>,
    stamp: u64,
}

/// Per-worker pin on the last-touched sparse shard (see
/// [`SparseBackend::with_row_cached`]). Holding the `Arc` keeps the shard
/// alive even if the LRU evicts it, so a cursor never serves stale rows.
pub struct SparseCursor {
    shard: Option<(u32, Arc<SparseShardData>)>,
}

struct SparseCache {
    map: HashMap<u32, CachedShard>,
    clock: u64,
    bytes: usize,
    budget: usize,
}

pub(crate) struct SparseBackend {
    rows_per_shard: usize,
    dim: usize,
    avg_nnz: usize,
    shards: Vec<SparseShardFiles>,
    cache: Mutex<SparseCache>,
}

impl SparseBackend {
    pub fn open(manifest: &Manifest, dir: &Path, opts: &StoreOptions) -> Result<SparseBackend> {
        let mut shards = Vec::with_capacity(manifest.shards.len());
        for (s, e) in manifest.shards.iter().enumerate() {
            let ShardFiles::Sparse { indptr, indices, values } = &e.files else {
                crate::bail!("shard {s}: sparse backend over dense manifest entry");
            };
            let f = SparseShardFiles {
                indptr: dir.join(indptr),
                indices: dir.join(indices),
                values: dir.join(values),
                rows: e.rows,
                nnz: e.nnz,
            };
            for (path, want) in [
                (&f.indptr, ((e.rows + 1) * 8) as u64),
                (&f.indices, (e.nnz * 4) as u64),
                (&f.values, (e.nnz * 4) as u64),
            ] {
                let len = std::fs::metadata(path)
                    .with_context(|| format!("stat {path:?}"))?
                    .len();
                crate::ensure!(len == want, "shard {s}: {path:?} is {len}B (want {want}B)");
            }
            shards.push(f);
        }
        // Same formula as `SparseData::avg_nnz` so the FLOP-based thread
        // cutoff plans identically for resident and sharded backends.
        let avg_nnz = manifest.nnz.div_ceil(manifest.n.max(1)).max(1);
        Ok(SparseBackend {
            rows_per_shard: manifest.rows_per_shard,
            dim: manifest.dim,
            avg_nnz,
            shards,
            cache: Mutex::new(SparseCache {
                map: HashMap::new(),
                clock: 0,
                bytes: 0,
                budget: opts.cache_bytes.max(1),
            }),
        })
    }

    pub fn avg_nnz(&self) -> usize {
        self.avg_nnz
    }

    /// Bytes currently pinned by this dataset's shard cache.
    pub fn pinned_bytes(&self) -> usize {
        self.cache.lock().unwrap().bytes
    }

    #[inline]
    pub fn with_row<R>(&self, i: usize, f: impl FnOnce(SparseRow<'_>) -> R) -> R {
        let (s, l) = (i / self.rows_per_shard, i % self.rows_per_shard);
        let shard = self.fetch_shard(s);
        let (lo, hi) = (shard.indptr[l], shard.indptr[l + 1]);
        f(SparseRow { indices: &shard.indices[lo..hi], values: &shard.values[lo..hi] })
    }

    pub fn cursor(&self) -> SparseCursor {
        SparseCursor { shard: None }
    }

    /// [`SparseBackend::with_row`] through a per-worker cursor that pins
    /// the last-touched shard: consecutive row accesses within one shard
    /// skip the dataset-wide cache lock entirely — without this, the
    /// sparse engine hot loops would take the Mutex once per (arm, ref)
    /// pair and serialize every worker on it.
    #[inline]
    pub fn with_row_cached<R>(
        &self,
        cur: &mut SparseCursor,
        i: usize,
        f: impl FnOnce(SparseRow<'_>) -> R,
    ) -> R {
        let (s, l) = (i / self.rows_per_shard, i % self.rows_per_shard);
        let hit = matches!(&cur.shard, Some((cs, _)) if *cs == s as u32);
        if !hit {
            cur.shard = Some((s as u32, self.fetch_shard(s)));
        }
        let shard = &cur.shard.as_ref().expect("just pinned").1;
        let (lo, hi) = (shard.indptr[l], shard.indptr[l + 1]);
        f(SparseRow { indices: &shard.indices[lo..hi], values: &shard.values[lo..hi] })
    }

    /// Visit rows `start..start+count` in order, decoding each shard once.
    pub fn for_rows(&self, start: usize, count: usize, mut f: impl FnMut(usize, SparseRow<'_>)) {
        let end = start + count;
        let mut i = start;
        while i < end {
            let (s, l) = (i / self.rows_per_shard, i % self.rows_per_shard);
            let shard = self.fetch_shard(s);
            let take = (end - i).min(self.shards[s].rows - l);
            for k in 0..take {
                let (lo, hi) = (shard.indptr[l + k], shard.indptr[l + k + 1]);
                f(
                    i + k,
                    SparseRow { indices: &shard.indices[lo..hi], values: &shard.values[lo..hi] },
                );
            }
            i += take;
        }
    }

    fn fetch_shard(&self, s: usize) -> Arc<SparseShardData> {
        let key = s as u32;
        {
            let mut c = self.cache.lock().unwrap();
            c.clock += 1;
            let stamp = c.clock;
            if let Some(e) = c.map.get_mut(&key) {
                e.stamp = stamp;
                let out = e.data.clone();
                drop(c);
                cache_stats().hits.add(1);
                return out;
            }
        }
        cache_stats().misses.add(1);
        let data = Arc::new(self.read_shard(s));
        let bytes = data.bytes();
        let mut c = self.cache.lock().unwrap();
        c.clock += 1;
        let stamp = c.clock;
        let out = match c.map.get_mut(&key) {
            Some(e) => {
                e.stamp = stamp;
                e.data.clone()
            }
            None => {
                c.bytes += bytes;
                cache_stats().add_pinned(bytes as i64);
                c.map.insert(key, CachedShard { data: data.clone(), stamp });
                data
            }
        };
        while c.bytes > c.budget && c.map.len() > 1 {
            let victim = c
                .map
                .iter()
                .filter(|(k, _)| **k != key)
                .min_by_key(|(_, e)| e.stamp)
                .map(|(k, _)| *k);
            match victim {
                Some(k) => {
                    let e = c.map.remove(&k).expect("victim present");
                    let freed = e.data.bytes();
                    c.bytes -= freed;
                    cache_stats().add_pinned(-(freed as i64));
                }
                None => break,
            }
        }
        out
    }

    fn read_shard(&self, s: usize) -> SparseShardData {
        let files = &self.shards[s];
        let indptr_raw = std::fs::read(&files.indptr)
            .unwrap_or_else(|e| panic!("sparse shard {s}: read indptr failed: {e}"));
        let indices_raw = std::fs::read(&files.indices)
            .unwrap_or_else(|e| panic!("sparse shard {s}: read indices failed: {e}"));
        let values_raw = std::fs::read(&files.values)
            .unwrap_or_else(|e| panic!("sparse shard {s}: read values failed: {e}"));
        let indptr: Vec<usize> = indptr_raw
            .chunks_exact(8)
            .map(|c| u64::from_le_bytes(c.try_into().unwrap()) as usize)
            .collect();
        let indices: Vec<u32> = indices_raw
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        let values: Vec<f32> =
            values_raw.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect();
        assert_eq!(indptr.len(), files.rows + 1, "sparse shard {s}: indptr len");
        assert_eq!(indices.len(), files.nnz, "sparse shard {s}: indices len");
        assert_eq!(values.len(), files.nnz, "sparse shard {s}: values len");
        assert_eq!(*indptr.last().unwrap(), files.nnz, "sparse shard {s}: indptr tail");
        // Structural validation at decode time (open stays payload-free):
        // a corrupt or hand-built shard must fail here with a clear message
        // — which the server's executor catches into an error response —
        // not out-of-bounds-panic deep inside an engine hot loop.
        let mut prev = 0usize;
        for (r, &p) in indptr.iter().enumerate() {
            assert!(
                p >= prev && p <= files.nnz,
                "sparse shard {s}: indptr not monotone/bounded at local row {r}"
            );
            prev = p;
        }
        if let Some(&bad) = indices.iter().find(|&&c| c as usize >= self.dim) {
            panic!("sparse shard {s}: column index {bad} >= dim {}", self.dim);
        }
        SparseShardData { indptr, indices, values }
    }
}

impl Drop for SparseBackend {
    fn drop(&mut self) {
        let c = self.cache.get_mut().unwrap();
        cache_stats().add_pinned(-(c.bytes as i64));
    }
}
