//! Sharded, memory-mapped dataset backend (DESIGN.md §12): an on-disk
//! manifest pointing at fixed-row-count `.npy` shards (dense) or CSR shard
//! triples (sparse), served through a zero-copy mmap reader (feature
//! `mmap`) or a pure-`std` pinned-block LRU fallback.
//!
//! This is what lets the bandit layer host the paper's n ≈ 10⁵–10⁶
//! workloads: corrSH touches only ~n log n of the n² distances, so the
//! binding constraint is *holding* the points — [`ShardedData`] keeps
//! resident memory at the cache budget instead of the dataset size, and
//! the engines pull rows through the shard map instead of a contiguous
//! matrix.

pub mod manifest;
pub mod reader;
pub mod writer;

pub use manifest::{Manifest, ShardKind, MANIFEST_FILE};
pub use reader::{cache_stats, mmap_compiled, ShardCacheStats, SparseCursor, StoreOptions};
pub use writer::{shard_file, write_sharded, DenseShardWriter, SparseShardWriter};

use std::path::{Path, PathBuf};
use std::sync::Arc;

use crate::data::{Data, DenseData, SparseData};
use crate::distance::{Metric, SparseRow};
use crate::util::error::Result;

use reader::{DenseBackend, SparseBackend};

enum Backend {
    Dense(DenseBackend),
    Sparse(SparseBackend),
}

struct Inner {
    manifest: Manifest,
    dir: PathBuf,
    backend: Backend,
}

/// A dataset served from an on-disk shard set. Opening reads only the
/// manifest and shard headers — payload bytes are pulled on demand, so
/// registering a million-point dataset is O(#shards), not O(n·d).
///
/// Cloning shares the underlying readers and caches (`Arc`).
#[derive(Clone)]
pub struct ShardedData {
    inner: Arc<Inner>,
}

impl std::fmt::Debug for ShardedData {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ShardedData")
            .field("kind", &self.inner.manifest.kind)
            .field("n", &self.inner.manifest.n)
            .field("dim", &self.inner.manifest.dim)
            .field("rows_per_shard", &self.inner.manifest.rows_per_shard)
            .field("shards", &self.inner.manifest.shards.len())
            .field("mmapped", &self.mmapped())
            .finish()
    }
}

impl ShardedData {
    /// Open a shard set from a manifest path or its directory.
    pub fn open(path: impl AsRef<Path>) -> Result<Self> {
        Self::open_with(path, &StoreOptions::default())
    }

    pub fn open_with(path: impl AsRef<Path>, opts: &StoreOptions) -> Result<Self> {
        let (manifest, dir) = Manifest::load(path.as_ref())?;
        let backend = match manifest.kind {
            ShardKind::Dense => Backend::Dense(DenseBackend::open(&manifest, &dir, opts)?),
            ShardKind::Sparse => Backend::Sparse(SparseBackend::open(&manifest, &dir, opts)?),
        };
        Ok(ShardedData { inner: Arc::new(Inner { manifest, dir, backend }) })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.inner.manifest
    }

    /// Directory the shard files live in (the manifest's directory).
    pub fn dir(&self) -> &Path {
        &self.inner.dir
    }

    pub fn n(&self) -> usize {
        self.inner.manifest.n
    }

    pub fn dim(&self) -> usize {
        self.inner.manifest.dim
    }

    pub fn rows_per_shard(&self) -> usize {
        self.inner.manifest.rows_per_shard
    }

    pub fn is_sparse(&self) -> bool {
        self.inner.manifest.is_sparse()
    }

    /// Effective per-pair dim of the sparse support walks (same formula as
    /// [`SparseData::avg_nnz`]); `dim` for dense.
    pub fn avg_nnz(&self) -> usize {
        match &self.inner.backend {
            Backend::Dense(_) => self.dim(),
            Backend::Sparse(s) => s.avg_nnz(),
        }
    }

    /// True when every dense shard is served zero-copy via mmap.
    pub fn mmapped(&self) -> bool {
        match &self.inner.backend {
            Backend::Dense(d) => d.fully_mapped(),
            Backend::Sparse(_) => false,
        }
    }

    /// Bytes currently pinned by this dataset's block/shard cache (mapped
    /// shards pin nothing — the OS owns those pages).
    pub fn pinned_bytes(&self) -> usize {
        match &self.inner.backend {
            Backend::Dense(d) => d.pinned_bytes(),
            Backend::Sparse(s) => s.pinned_bytes(),
        }
    }

    fn dense(&self) -> &DenseBackend {
        match &self.inner.backend {
            Backend::Dense(d) => d,
            Backend::Sparse(_) => panic!("dense row access on a sparse shard set"),
        }
    }

    fn sparse(&self) -> &SparseBackend {
        match &self.inner.backend {
            Backend::Sparse(s) => s,
            Backend::Dense(_) => panic!("sparse row access on a dense shard set"),
        }
    }

    /// Serve dense row `i` to `f` (zero-copy when mapped, pinned otherwise).
    #[inline]
    pub fn with_dense_row<R>(&self, i: usize, f: impl FnOnce(&[f32]) -> R) -> R {
        self.dense().with_row(i, f)
    }

    /// Zero-copy dense row borrow — `Some` only on fully-mapped shard sets.
    #[inline]
    pub fn try_dense_row(&self, i: usize) -> Option<&[f32]> {
        match &self.inner.backend {
            Backend::Dense(d) => d.try_row(i),
            Backend::Sparse(_) => None,
        }
    }

    /// Serve sparse row `i` to `f`.
    #[inline]
    pub fn with_sparse_row<R>(&self, i: usize, f: impl FnOnce(SparseRow<'_>) -> R) -> R {
        self.sparse().with_row(i, f)
    }

    /// A per-worker cursor for [`ShardedData::with_sparse_row_cached`].
    pub fn sparse_cursor(&self) -> SparseCursor {
        self.sparse().cursor()
    }

    /// [`ShardedData::with_sparse_row`] through a cursor pinning the
    /// last-touched shard — the engine hot loops use this so consecutive
    /// row accesses don't take the dataset-wide cache lock per pair.
    #[inline]
    pub fn with_sparse_row_cached<R>(
        &self,
        cur: &mut SparseCursor,
        i: usize,
        f: impl FnOnce(SparseRow<'_>) -> R,
    ) -> R {
        self.sparse().with_row_cached(cur, i, f)
    }

    /// Stream dense rows `start..start+count` in order (each shard window
    /// fetched once) — the shape the `PreparedEngine` reductions sweep.
    pub fn for_dense_rows(&self, start: usize, count: usize, f: impl FnMut(usize, &[f32])) {
        self.dense().for_rows(start, count, f);
    }

    pub fn for_sparse_rows(&self, start: usize, count: usize, f: impl FnMut(usize, SparseRow<'_>)) {
        self.sparse().for_rows(start, count, f);
    }

    /// Copy row `i` into `out` as a dense vector (the PJRT gather path).
    pub fn densify_row_into(&self, i: usize, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.dim());
        match &self.inner.backend {
            Backend::Dense(d) => d.with_row(i, |row| out.copy_from_slice(row)),
            Backend::Sparse(s) => s.with_row(i, |r| {
                out.fill(0.0);
                for (&c, &v) in r.indices.iter().zip(r.values) {
                    out[c as usize] = v;
                }
            }),
        }
    }

    /// Distance between rows `i` and `j` — the same scalar kernels as the
    /// resident backends, on bitwise-identical row bytes.
    #[inline]
    pub fn distance(&self, metric: Metric, i: usize, j: usize, ni: f32, nj: f32) -> f32 {
        match &self.inner.backend {
            Backend::Dense(d) => {
                d.with_row(i, |a| d.with_row(j, |b| metric.dense(a, b, ni, nj)))
            }
            Backend::Sparse(s) => {
                s.with_row(i, |a| s.with_row(j, |b| metric.sparse(a, b, ni, nj)))
            }
        }
    }

    /// Materialize the shard set as a resident [`Data`] (tests / small
    /// datasets only — this is exactly the allocation sharding avoids).
    pub fn to_resident(&self) -> Data {
        match &self.inner.backend {
            Backend::Dense(d) => {
                let (n, dim) = (self.n(), self.dim());
                let mut out = vec![0f32; n * dim];
                d.for_rows(0, n, |i, row| out[i * dim..(i + 1) * dim].copy_from_slice(row));
                Data::Dense(DenseData::new(n, dim, out))
            }
            Backend::Sparse(s) => {
                let mut rows: Vec<Vec<(u32, f32)>> = Vec::with_capacity(self.n());
                s.for_rows(0, self.n(), |_, r| {
                    rows.push(r.indices.iter().copied().zip(r.values.iter().copied()).collect());
                });
                Data::Sparse(SparseData::from_rows(self.n(), self.dim(), rows))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{netflix, rnaseq, SynthConfig};
    use crate::data::DenseData;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("corrsh-store-tests").join(name);
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn dense_roundtrip_bitwise() {
        let n = 37;
        let dim = 9;
        let data: Vec<f32> = (0..n * dim).map(|i| (i as f32).sin()).collect();
        let d = DenseData::new(n, dim, data);
        let dir = tmp("dense-rt");
        let manifest = write_sharded(&Data::Dense(d.clone()), &dir, 8).unwrap();
        let sd = ShardedData::open(&manifest).unwrap();
        assert_eq!((sd.n(), sd.dim(), sd.rows_per_shard()), (n, dim, 8));
        assert!(!sd.is_sparse());
        let mut buf = vec![0f32; dim];
        for i in 0..n {
            sd.densify_row_into(i, &mut buf);
            assert_eq!(buf, d.row(i), "row {i}");
            sd.with_dense_row(i, |row| assert_eq!(row, d.row(i)));
        }
        // streaming visit covers every row once, in order
        let mut seen = 0;
        sd.for_dense_rows(0, n, |i, row| {
            assert_eq!(i, seen);
            assert_eq!(row, d.row(i));
            seen += 1;
        });
        assert_eq!(seen, n);
        match sd.to_resident() {
            Data::Dense(back) => assert_eq!(back.data, d.data),
            _ => panic!("dense expected"),
        }
    }

    #[test]
    fn sparse_roundtrip_bitwise() {
        let cfg = SynthConfig { n: 41, dim: 60, seed: 3, density: 0.1, ..Default::default() };
        let data = rnaseq::generate(&cfg);
        let Data::Sparse(sp) = &data else { panic!("rnaseq is sparse") };
        let dir = tmp("sparse-rt");
        let manifest = write_sharded(&data, &dir, 7).unwrap();
        let sd = ShardedData::open(&manifest).unwrap();
        assert!(sd.is_sparse());
        assert_eq!(sd.avg_nnz(), sp.avg_nnz());
        for i in 0..sp.n {
            let want = sp.row(i);
            sd.with_sparse_row(i, |r| {
                assert_eq!(r.indices, want.indices, "row {i}");
                assert_eq!(r.values, want.values, "row {i}");
            });
        }
        match sd.to_resident() {
            Data::Sparse(back) => {
                assert_eq!(back.indptr, sp.indptr);
                assert_eq!(back.indices, sp.indices);
                assert_eq!(back.values, sp.values);
            }
            _ => panic!("sparse expected"),
        }
    }

    #[test]
    fn tiny_cache_still_serves_every_row() {
        // Force evictions on every other access: a 1-block cache must stay
        // correct (the LRU is a performance layer, never a semantic one).
        let n = 50;
        let dim = 16;
        let d = DenseData::new(n, dim, (0..n * dim).map(|i| i as f32).collect());
        let dir = tmp("tiny-cache");
        let manifest = write_sharded(&Data::Dense(d.clone()), &dir, 6).unwrap();
        let opts = StoreOptions {
            cache_bytes: dim * 4, // one row's bytes -> at most one block
            block_bytes: dim * 4,
            force_pinned: true,
        };
        let sd = ShardedData::open_with(&manifest, &opts).unwrap();
        assert!(!sd.mmapped());
        // strided access defeats the cache on purpose
        for pass in 0..3 {
            for i in (0..n).step_by(7 + pass) {
                sd.with_dense_row(i, |row| assert_eq!(row, d.row(i), "row {i}"));
            }
        }
        // the pinned budget holds even under pathological access: at most
        // one resident block beyond the (one-block) budget floor
        assert!(
            sd.pinned_bytes() <= opts.cache_bytes + opts.block_bytes,
            "cache exceeded budget: {} > {}",
            sd.pinned_bytes(),
            opts.cache_bytes + opts.block_bytes
        );
        assert!(sd.pinned_bytes() > 0, "pinned reader holds at least the hot block");
    }

    #[test]
    fn cache_stats_move_and_stay_monotone() {
        let cfg = SynthConfig { n: 30, dim: 40, seed: 5, density: 0.2, ..Default::default() };
        let data = netflix::generate(&cfg);
        let dir = tmp("stats");
        let manifest = write_sharded(&data, &dir, 8).unwrap();
        let opts = StoreOptions { force_pinned: true, ..Default::default() };
        let sd = ShardedData::open_with(&manifest, &opts).unwrap();
        let (h0, m0) = (cache_stats().hits(), cache_stats().misses());
        sd.with_sparse_row(0, |_| ());
        sd.with_sparse_row(1, |_| ());
        let (h1, m1) = (cache_stats().hits(), cache_stats().misses());
        assert!(m1 > m0, "first touch is a miss");
        assert!(h1 >= h0 && h1 + m1 > h0 + m0);
        sd.with_sparse_row(0, |_| ());
        assert!(cache_stats().hits() > h1, "re-touch within budget is a hit");
    }

    #[test]
    fn sparse_cursor_matches_uncached_access() {
        // The cursor is a lock-elision layer, never a semantic one: even
        // with a cache evicting on every fetch, cursor reads must be
        // identical to plain reads (the pinned Arc keeps evicted shards
        // alive for the cursor's holder).
        let cfg = SynthConfig { n: 50, dim: 40, seed: 7, density: 0.2, ..Default::default() };
        let data = rnaseq::generate(&cfg);
        let dir = tmp("cursor");
        let manifest = write_sharded(&data, &dir, 7).unwrap();
        let opts = StoreOptions { cache_bytes: 1, block_bytes: 64, force_pinned: true };
        let sd = ShardedData::open_with(&manifest, &opts).unwrap();
        let mut cur = sd.sparse_cursor();
        // strided orders force shard switches mid-stream
        for step in [1usize, 3, 11] {
            for i in (0..50).step_by(step) {
                sd.with_sparse_row(i, |want| {
                    sd.with_sparse_row_cached(&mut cur, i, |got| {
                        assert_eq!(got.indices, want.indices, "row {i} (step {step})");
                        assert_eq!(got.values, want.values, "row {i} (step {step})");
                    });
                });
            }
        }
    }

    #[test]
    fn reshard_into_source_dir_is_rejected() {
        // Re-sharding a manifest into its own directory would truncate the
        // shard files the reader still streams from — must refuse.
        let d = DenseData::new(8, 3, (0..24).map(|i| i as f32).collect());
        let dir = tmp("reshard-guard");
        let manifest = write_sharded(&Data::Dense(d.clone()), &dir, 4).unwrap();
        let sd = Data::Sharded(ShardedData::open(&manifest).unwrap());
        assert!(write_sharded(&sd, &dir, 2).is_err(), "clobbering the source must fail");
        // source is intact and a distinct target works
        let manifest2 = write_sharded(&sd, dir.join("copy"), 2).unwrap();
        let back = ShardedData::open_with(
            &manifest2,
            &StoreOptions { force_pinned: true, ..Default::default() },
        )
        .unwrap();
        assert_eq!((back.n(), back.dim(), back.rows_per_shard()), (8, 3, 2));
        let mut buf = vec![0f32; 3];
        back.densify_row_into(5, &mut buf);
        assert_eq!(buf, d.row(5));
    }

    #[test]
    fn open_rejects_corrupt_shard_sets() {
        let d = DenseData::new(10, 4, (0..40).map(|i| i as f32).collect());
        let dir = tmp("corrupt");
        let manifest = write_sharded(&Data::Dense(d), &dir, 4).unwrap();
        // truncate a shard payload
        let shard0 = dir.join("shard-00000.npy");
        let bytes = std::fs::read(&shard0).unwrap();
        std::fs::write(&shard0, &bytes[..bytes.len() - 8]).unwrap();
        assert!(ShardedData::open(&manifest).is_err(), "short shard must fail at open");
    }

    #[test]
    fn corrupt_sparse_shard_fails_with_clear_message() {
        // A crafted shard with out-of-range column indices must fail at
        // decode with a descriptive panic (the server executor catches
        // panics into error responses) — never an OOB index deep in an
        // engine hot loop.
        let rows: Vec<Vec<(u32, f32)>> =
            (0..20).map(|i| vec![(0u32, i as f32), (5, 1.0)]).collect();
        let data = Data::Sparse(crate::data::SparseData::from_rows(20, 16, rows));
        let dir = tmp("corrupt-sparse");
        let manifest = write_sharded(&data, &dir, 8).unwrap();
        // poison shard 1's indices with a column >= dim (every shard has
        // exactly 2 nonzeros per row by construction)
        let idx_path = dir.join("shard-00001.indices.bin");
        let mut bytes = std::fs::read(&idx_path).unwrap();
        bytes[..4].copy_from_slice(&999u32.to_le_bytes());
        std::fs::write(&idx_path, bytes).unwrap();
        let sd = ShardedData::open(&manifest).unwrap(); // open stays lazy
        let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            sd.with_sparse_row(9, |r| r.nnz()) // row 9 lives in shard 1
        }))
        .expect_err("decoding the poisoned shard must fail");
        let msg = err
            .downcast_ref::<String>()
            .cloned()
            .or_else(|| err.downcast_ref::<&str>().map(|s| s.to_string()))
            .unwrap_or_default();
        assert!(msg.contains("column index"), "unhelpful panic message: {msg:?}");
        // untouched shards still serve
        sd.with_sparse_row(0, |r| assert!(r.nnz() < 17));
    }

    #[test]
    fn writer_rejects_degenerate_input() {
        let dir = tmp("degenerate");
        assert!(DenseShardWriter::create(&dir, 0, 4).is_err());
        assert!(DenseShardWriter::create(&dir, 4, 0).is_err());
        let mut w = DenseShardWriter::create(&dir, 4, 2).unwrap();
        assert!(w.push_row(&[1.0, 2.0]).is_err(), "wrong row length");
        let w = DenseShardWriter::create(&dir, 4, 2).unwrap();
        assert!(w.finish().is_err(), "empty shard set");
        let mut w = SparseShardWriter::create(&dir, 4, 2).unwrap();
        assert!(w.push_row(&[2, 1], &[1.0, 1.0]).is_err(), "unsorted indices");
        assert!(w.push_row(&[9], &[1.0]).is_err(), "index out of range");
    }
}
