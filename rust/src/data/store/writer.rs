//! Shard writers: stream rows into fixed-row-count shard files plus a
//! manifest, without ever holding more than one shard in memory — the
//! converse of the readers in [`crate::data::store::reader`].

use std::io::Write;
use std::path::{Path, PathBuf};

use crate::data::store::manifest::{Manifest, ShardEntry, ShardFiles, ShardKind};
use crate::data::Data;
use crate::util::error::{Context, Result};
use crate::util::npy;

fn shard_stem(idx: usize) -> String {
    format!("shard-{idx:05}")
}

/// Streaming dense shard writer: buffer up to `rows_per_shard` rows, flush
/// each full shard as a `<f4` `.npy`, then write `manifest.json`.
pub struct DenseShardWriter {
    dir: PathBuf,
    dim: usize,
    rows_per_shard: usize,
    buf: Vec<f32>,
    entries: Vec<ShardEntry>,
    rows_total: usize,
}

impl DenseShardWriter {
    pub fn create(dir: impl AsRef<Path>, dim: usize, rows_per_shard: usize) -> Result<Self> {
        crate::ensure!(dim >= 1, "shard writer: dim must be >= 1");
        crate::ensure!(rows_per_shard >= 1, "shard writer: rows_per_shard must be >= 1");
        let dir = dir.as_ref().to_path_buf();
        std::fs::create_dir_all(&dir).with_context(|| format!("create {dir:?}"))?;
        Ok(DenseShardWriter {
            dir,
            dim,
            rows_per_shard,
            buf: Vec::new(),
            entries: Vec::new(),
            rows_total: 0,
        })
    }

    pub fn push_row(&mut self, row: &[f32]) -> Result<()> {
        crate::ensure!(row.len() == self.dim, "push_row: {} values, dim {}", row.len(), self.dim);
        self.buf.extend_from_slice(row);
        self.rows_total += 1;
        if self.buf.len() == self.rows_per_shard * self.dim {
            self.flush_shard()?;
        }
        Ok(())
    }

    /// Push `rows.len() / dim` row-major rows at once.
    pub fn push_rows(&mut self, rows: &[f32]) -> Result<()> {
        crate::ensure!(rows.len() % self.dim == 0, "push_rows: length not a multiple of dim");
        for row in rows.chunks_exact(self.dim) {
            self.push_row(row)?;
        }
        Ok(())
    }

    fn flush_shard(&mut self) -> Result<()> {
        let rows = self.buf.len() / self.dim;
        let name = format!("{}.npy", shard_stem(self.entries.len()));
        let m = npy::Matrix::new(rows, self.dim, std::mem::take(&mut self.buf));
        npy::write(self.dir.join(&name), &m)?;
        self.entries.push(ShardEntry { rows, nnz: 0, files: ShardFiles::Dense { data: name } });
        Ok(())
    }

    /// Flush the tail shard and write `manifest.json`; returns its path.
    pub fn finish(mut self) -> Result<PathBuf> {
        if !self.buf.is_empty() {
            self.flush_shard()?;
        }
        crate::ensure!(self.rows_total >= 1, "shard writer: no rows written");
        let manifest = Manifest {
            kind: ShardKind::Dense,
            n: self.rows_total,
            dim: self.dim,
            rows_per_shard: self.rows_per_shard,
            nnz: 0,
            shards: self.entries,
        };
        manifest.save(&self.dir)
    }
}

/// Streaming sparse (CSR) shard writer: per shard, three raw little-endian
/// files — `*.indptr.bin` (u64, shard-local), `*.indices.bin` (u32),
/// `*.values.bin` (f32).
pub struct SparseShardWriter {
    dir: PathBuf,
    dim: usize,
    rows_per_shard: usize,
    indptr: Vec<u64>,
    indices: Vec<u32>,
    values: Vec<f32>,
    entries: Vec<ShardEntry>,
    rows_total: usize,
    nnz_total: usize,
}

impl SparseShardWriter {
    pub fn create(dir: impl AsRef<Path>, dim: usize, rows_per_shard: usize) -> Result<Self> {
        crate::ensure!(dim >= 1, "shard writer: dim must be >= 1");
        crate::ensure!(rows_per_shard >= 1, "shard writer: rows_per_shard must be >= 1");
        let dir = dir.as_ref().to_path_buf();
        std::fs::create_dir_all(&dir).with_context(|| format!("create {dir:?}"))?;
        Ok(SparseShardWriter {
            dir,
            dim,
            rows_per_shard,
            indptr: vec![0],
            indices: Vec::new(),
            values: Vec::new(),
            entries: Vec::new(),
            rows_total: 0,
            nnz_total: 0,
        })
    }

    /// Push one row's (sorted, in-range) CSR slices.
    pub fn push_row(&mut self, indices: &[u32], values: &[f32]) -> Result<()> {
        crate::ensure!(indices.len() == values.len(), "push_row: indices/values mismatch");
        for w in indices.windows(2) {
            crate::ensure!(w[0] < w[1], "push_row: indices not strictly sorted");
        }
        if let Some(&last) = indices.last() {
            crate::ensure!((last as usize) < self.dim, "push_row: index {last} >= dim");
        }
        self.indices.extend_from_slice(indices);
        self.values.extend_from_slice(values);
        self.indptr.push(self.indices.len() as u64);
        self.rows_total += 1;
        self.nnz_total += indices.len();
        if self.indptr.len() - 1 == self.rows_per_shard {
            self.flush_shard()?;
        }
        Ok(())
    }

    fn flush_shard(&mut self) -> Result<()> {
        let rows = self.indptr.len() - 1;
        let nnz = self.indices.len();
        let stem = shard_stem(self.entries.len());
        let files = ShardFiles::Sparse {
            indptr: format!("{stem}.indptr.bin"),
            indices: format!("{stem}.indices.bin"),
            values: format!("{stem}.values.bin"),
        };
        let ShardFiles::Sparse { indptr, indices, values } = &files else { unreachable!() };
        write_le(&self.dir.join(indptr), self.indptr.iter().map(|v| v.to_le_bytes()))?;
        write_le(&self.dir.join(indices), self.indices.iter().map(|v| v.to_le_bytes()))?;
        write_le(&self.dir.join(values), self.values.iter().map(|v| v.to_le_bytes()))?;
        self.entries.push(ShardEntry { rows, nnz, files });
        self.indptr.clear();
        self.indptr.push(0);
        self.indices.clear();
        self.values.clear();
        Ok(())
    }

    pub fn finish(mut self) -> Result<PathBuf> {
        if self.indptr.len() > 1 {
            self.flush_shard()?;
        }
        crate::ensure!(self.rows_total >= 1, "shard writer: no rows written");
        let manifest = Manifest {
            kind: ShardKind::Sparse,
            n: self.rows_total,
            dim: self.dim,
            rows_per_shard: self.rows_per_shard,
            nnz: self.nnz_total,
            shards: self.entries,
        };
        manifest.save(&self.dir)
    }
}

fn write_le<const N: usize>(
    path: &Path,
    items: impl Iterator<Item = [u8; N]>,
) -> Result<()> {
    let mut buf = Vec::new();
    for b in items {
        buf.extend_from_slice(&b);
    }
    let mut f =
        std::fs::File::create(path).with_context(|| format!("create {path:?}"))?;
    f.write_all(&buf).with_context(|| format!("write {path:?}"))?;
    Ok(())
}

/// Convert any in-memory (or already-sharded) dataset into a shard set
/// under `dir`; returns the manifest path. Row payloads are copied
/// bitwise, so the sharded dataset is bit-identical to its source.
pub fn write_sharded(data: &Data, dir: impl AsRef<Path>, rows_per_shard: usize) -> Result<PathBuf> {
    let dir = dir.as_ref();
    match data {
        Data::Dense(d) => {
            let mut w = DenseShardWriter::create(dir, d.dim, rows_per_shard)?;
            for i in 0..d.n {
                w.push_row(d.row(i))?;
            }
            w.finish()
        }
        Data::Sparse(s) => {
            let mut w = SparseShardWriter::create(dir, s.dim, rows_per_shard)?;
            for i in 0..s.n {
                let r = s.row(i);
                w.push_row(r.indices, r.values)?;
            }
            w.finish()
        }
        Data::Sharded(sd) => {
            // Re-sharding into the source directory would truncate shard
            // files the reader is still streaming from — refuse instead of
            // destroying the dataset mid-copy.
            std::fs::create_dir_all(dir).with_context(|| format!("create {dir:?}"))?;
            let src = sd.dir().canonicalize().ok();
            let dst = dir.canonicalize().ok();
            crate::ensure!(
                src.is_none() || dst.is_none() || src != dst,
                "re-shard target {dir:?} is the source shard directory"
            );
            if sd.is_sparse() {
                let mut w = SparseShardWriter::create(dir, sd.dim(), rows_per_shard)?;
                let mut err = Ok(());
                sd.for_sparse_rows(0, sd.n(), |_, r| {
                    if err.is_ok() {
                        err = w.push_row(r.indices, r.values);
                    }
                });
                err?;
                w.finish()
            } else {
                let mut w = DenseShardWriter::create(dir, sd.dim(), rows_per_shard)?;
                let mut err = Ok(());
                sd.for_dense_rows(0, sd.n(), |_, row| {
                    if err.is_ok() {
                        err = w.push_row(row);
                    }
                });
                err?;
                w.finish()
            }
        }
    }
}

/// The `corrsh shard` conversion: load a resident dataset file (`.npy` or
/// `.csr`) — or re-shard an existing manifest — and write a shard set into
/// `out_dir`. Returns the manifest path.
pub fn shard_file(
    input: impl AsRef<Path>,
    out_dir: impl AsRef<Path>,
    rows_per_shard: usize,
) -> Result<PathBuf> {
    let data = crate::data::loader::load(input)?;
    write_sharded(&data, out_dir, rows_per_shard)
}
