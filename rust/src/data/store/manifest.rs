//! On-disk shard manifest: one `manifest.json` per shard directory
//! describing fixed-row-count shard files (DESIGN.md §12).
//!
//! ```json
//! {"format":"corrsh-shards","version":1,"kind":"dense","n":1000000,
//!  "dim":128,"rows_per_shard":16384,
//!  "shards":[{"rows":16384,"data":"shard-00000.npy"}, ...]}
//! ```
//!
//! Sparse manifests replace `data` with a CSR triple per shard
//! (`indptr`/`indices`/`values`, raw little-endian u64/u32/f32) plus the
//! shard's `nnz`. Shard file names are stored relative to the manifest's
//! directory so a shard set can be moved or mounted read-only as a unit.

use std::path::{Path, PathBuf};

use crate::bail;
use crate::util::error::{Context, Result};
use crate::util::json::{self, Value};

pub const MANIFEST_FORMAT: &str = "corrsh-shards";
pub const MANIFEST_VERSION: u64 = 1;
/// Default manifest file name inside a shard directory.
pub const MANIFEST_FILE: &str = "manifest.json";

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ShardKind {
    Dense,
    Sparse,
}

impl ShardKind {
    pub fn name(&self) -> &'static str {
        match self {
            ShardKind::Dense => "dense",
            ShardKind::Sparse => "sparse",
        }
    }
}

/// Per-shard file set (file names relative to the manifest directory).
#[derive(Clone, Debug)]
pub enum ShardFiles {
    Dense { data: String },
    Sparse { indptr: String, indices: String, values: String },
}

#[derive(Clone, Debug)]
pub struct ShardEntry {
    /// Rows stored in this shard (== `rows_per_shard` except the tail).
    pub rows: usize,
    /// Nonzeros in this shard (sparse only; 0 for dense).
    pub nnz: usize,
    pub files: ShardFiles,
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub kind: ShardKind,
    pub n: usize,
    pub dim: usize,
    pub rows_per_shard: usize,
    /// Total nonzeros (sparse only; 0 for dense).
    pub nnz: usize,
    pub shards: Vec<ShardEntry>,
}

impl Manifest {
    /// `(shard index, row index within the shard)` of global row `i`.
    #[inline]
    pub fn locate(&self, i: usize) -> (usize, usize) {
        debug_assert!(i < self.n);
        (i / self.rows_per_shard, i % self.rows_per_shard)
    }

    pub fn is_sparse(&self) -> bool {
        self.kind == ShardKind::Sparse
    }

    /// Structural invariants: every shard holds exactly `rows_per_shard`
    /// rows except a shorter tail, and the rows sum to `n`.
    pub fn validate(&self) -> Result<()> {
        crate::ensure!(self.n >= 1, "manifest: n must be >= 1");
        crate::ensure!(self.dim >= 1, "manifest: dim must be >= 1");
        crate::ensure!(self.rows_per_shard >= 1, "manifest: rows_per_shard must be >= 1");
        let want = self.n.div_ceil(self.rows_per_shard);
        crate::ensure!(
            self.shards.len() == want,
            "manifest: {} shards for n={} rows_per_shard={} (want {want})",
            self.shards.len(),
            self.n,
            self.rows_per_shard
        );
        let mut total = 0usize;
        let mut nnz = 0usize;
        for (s, e) in self.shards.iter().enumerate() {
            let full = s + 1 < self.shards.len();
            let want_rows = if full {
                self.rows_per_shard
            } else {
                self.n - s * self.rows_per_shard
            };
            crate::ensure!(
                e.rows == want_rows,
                "manifest: shard {s} has {} rows (want {want_rows})",
                e.rows
            );
            match (&e.files, self.kind) {
                (ShardFiles::Dense { .. }, ShardKind::Dense) => {}
                (ShardFiles::Sparse { .. }, ShardKind::Sparse) => {}
                _ => bail!("manifest: shard {s} file set does not match kind"),
            }
            total += e.rows;
            nnz += e.nnz;
        }
        crate::ensure!(total == self.n, "manifest: shard rows sum {total} != n {}", self.n);
        if self.kind == ShardKind::Sparse {
            crate::ensure!(nnz == self.nnz, "manifest: shard nnz sum {nnz} != nnz {}", self.nnz);
        }
        Ok(())
    }

    pub fn to_value(&self) -> Value {
        let shards: Vec<Value> = self
            .shards
            .iter()
            .map(|e| {
                let mut pairs = vec![("rows", e.rows.into())];
                match &e.files {
                    ShardFiles::Dense { data } => pairs.push(("data", data.as_str().into())),
                    ShardFiles::Sparse { indptr, indices, values } => {
                        pairs.push(("nnz", e.nnz.into()));
                        pairs.push(("indptr", indptr.as_str().into()));
                        pairs.push(("indices", indices.as_str().into()));
                        pairs.push(("values", values.as_str().into()));
                    }
                }
                Value::from_pairs(pairs)
            })
            .collect();
        let mut pairs = vec![
            ("format", MANIFEST_FORMAT.into()),
            ("version", MANIFEST_VERSION.into()),
            ("kind", self.kind.name().into()),
            ("n", self.n.into()),
            ("dim", self.dim.into()),
            ("rows_per_shard", self.rows_per_shard.into()),
        ];
        if self.kind == ShardKind::Sparse {
            pairs.push(("nnz", self.nnz.into()));
        }
        pairs.push(("shards", Value::Array(shards)));
        Value::from_pairs(pairs)
    }

    pub fn from_value(v: &Value) -> Result<Manifest> {
        crate::ensure!(
            v.get("format").as_str() == Some(MANIFEST_FORMAT),
            "not a {MANIFEST_FORMAT} manifest"
        );
        let version = v.get("version").as_u64().context("manifest: missing version")?;
        crate::ensure!(
            version == MANIFEST_VERSION,
            "manifest version {version} unsupported (want {MANIFEST_VERSION})"
        );
        let kind = match v.get("kind").as_str().context("manifest: missing kind")? {
            "dense" => ShardKind::Dense,
            "sparse" => ShardKind::Sparse,
            other => bail!("manifest: unknown kind {other:?}"),
        };
        let n = v.get("n").as_usize().context("manifest: missing n")?;
        let dim = v.get("dim").as_usize().context("manifest: missing dim")?;
        let rows_per_shard =
            v.get("rows_per_shard").as_usize().context("manifest: missing rows_per_shard")?;
        let mut shards = Vec::new();
        for (s, e) in v.get("shards").as_array().context("manifest: missing shards")?.iter()
            .enumerate()
        {
            let rows = e.get("rows").as_usize().with_context(|| format!("shard {s}: rows"))?;
            let nnz = e.get("nnz").as_usize().unwrap_or(0);
            let files = match kind {
                ShardKind::Dense => ShardFiles::Dense {
                    data: e
                        .get("data")
                        .as_str()
                        .with_context(|| format!("shard {s}: data"))?
                        .to_string(),
                },
                ShardKind::Sparse => ShardFiles::Sparse {
                    indptr: e
                        .get("indptr")
                        .as_str()
                        .with_context(|| format!("shard {s}: indptr"))?
                        .to_string(),
                    indices: e
                        .get("indices")
                        .as_str()
                        .with_context(|| format!("shard {s}: indices"))?
                        .to_string(),
                    values: e
                        .get("values")
                        .as_str()
                        .with_context(|| format!("shard {s}: values"))?
                        .to_string(),
                },
            };
            shards.push(ShardEntry { rows, nnz, files });
        }
        let nnz = v.get("nnz").as_usize().unwrap_or(0);
        let m = Manifest { kind, n, dim, rows_per_shard, nnz, shards };
        m.validate()?;
        Ok(m)
    }

    /// Write `manifest.json` into `dir`; returns the manifest path.
    pub fn save(&self, dir: &Path) -> Result<PathBuf> {
        self.validate()?;
        let path = dir.join(MANIFEST_FILE);
        std::fs::write(&path, json::to_string(&self.to_value()) + "\n")
            .with_context(|| format!("write {path:?}"))?;
        Ok(path)
    }

    /// Load a manifest from a `manifest.json` path *or* a directory that
    /// contains one; returns the manifest plus its directory (shard file
    /// names resolve relative to it).
    pub fn load(path: &Path) -> Result<(Manifest, PathBuf)> {
        let file = if path.is_dir() { path.join(MANIFEST_FILE) } else { path.to_path_buf() };
        let dir = file.parent().context("manifest has no parent directory")?.to_path_buf();
        let text =
            std::fs::read_to_string(&file).with_context(|| format!("read {file:?}"))?;
        let v = json::parse(&text).with_context(|| format!("parse {file:?}"))?;
        let m = Self::from_value(&v).with_context(|| format!("manifest {file:?}"))?;
        Ok((m, dir))
    }

    /// True if `path` plausibly names a shard manifest (used by the loader's
    /// auto-detection; cheap — does not read shard files).
    pub fn detect(path: &Path) -> bool {
        let file = if path.is_dir() { path.join(MANIFEST_FILE) } else { path.to_path_buf() };
        if file.file_name().and_then(|f| f.to_str()) != Some(MANIFEST_FILE)
            && file.extension().and_then(|e| e.to_str()) != Some("json")
        {
            return false;
        }
        match std::fs::read_to_string(&file) {
            Ok(text) => json::parse(&text)
                .map(|v| v.get("format").as_str() == Some(MANIFEST_FORMAT))
                .unwrap_or(false),
            Err(_) => false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy(kind: ShardKind) -> Manifest {
        let shards = match kind {
            ShardKind::Dense => vec![
                ShardEntry {
                    rows: 4,
                    nnz: 0,
                    files: ShardFiles::Dense { data: "shard-00000.npy".into() },
                },
                ShardEntry {
                    rows: 2,
                    nnz: 0,
                    files: ShardFiles::Dense { data: "shard-00001.npy".into() },
                },
            ],
            ShardKind::Sparse => vec![
                ShardEntry {
                    rows: 4,
                    nnz: 7,
                    files: ShardFiles::Sparse {
                        indptr: "s0.indptr.bin".into(),
                        indices: "s0.indices.bin".into(),
                        values: "s0.values.bin".into(),
                    },
                },
                ShardEntry {
                    rows: 2,
                    nnz: 3,
                    files: ShardFiles::Sparse {
                        indptr: "s1.indptr.bin".into(),
                        indices: "s1.indices.bin".into(),
                        values: "s1.values.bin".into(),
                    },
                },
            ],
        };
        Manifest {
            kind,
            n: 6,
            dim: 5,
            rows_per_shard: 4,
            nnz: if kind == ShardKind::Sparse { 10 } else { 0 },
            shards,
        }
    }

    #[test]
    fn roundtrip_both_kinds() {
        for kind in [ShardKind::Dense, ShardKind::Sparse] {
            let m = toy(kind);
            m.validate().unwrap();
            let back = Manifest::from_value(&m.to_value()).unwrap();
            assert_eq!(back.n, 6);
            assert_eq!(back.rows_per_shard, 4);
            assert_eq!(back.kind, kind);
            assert_eq!(back.shards.len(), 2);
        }
    }

    #[test]
    fn validation_catches_bad_shapes() {
        let mut m = toy(ShardKind::Dense);
        m.shards[0].rows = 3; // not a full shard
        assert!(m.validate().is_err());
        let mut m = toy(ShardKind::Dense);
        m.n = 7; // rows don't sum
        assert!(m.validate().is_err());
        let mut m = toy(ShardKind::Sparse);
        m.nnz = 99;
        assert!(m.validate().is_err());
    }

    #[test]
    fn locate_maps_rows_to_shards() {
        let m = toy(ShardKind::Dense);
        assert_eq!(m.locate(0), (0, 0));
        assert_eq!(m.locate(3), (0, 3));
        assert_eq!(m.locate(4), (1, 0));
        assert_eq!(m.locate(5), (1, 1));
    }

    #[test]
    fn save_load_detect() {
        let dir = std::env::temp_dir().join("corrsh-manifest-tests");
        std::fs::create_dir_all(&dir).unwrap();
        let m = toy(ShardKind::Dense);
        let path = m.save(&dir).unwrap();
        assert!(Manifest::detect(&path));
        assert!(Manifest::detect(&dir));
        let (back, back_dir) = Manifest::load(&dir).unwrap();
        assert_eq!(back.n, m.n);
        assert_eq!(back_dir, dir);
        // a random json is not a manifest
        let other = dir.join("not-manifest.json");
        std::fs::write(&other, "{\"x\":1}").unwrap();
        assert!(!Manifest::detect(&other));
        assert!(!Manifest::detect(std::path::Path::new("/nonexistent/manifest.json")));
    }
}
