//! Dataset substrate: dense matrices, CSR sparse matrices, the sharded
//! on-disk store, loaders and the synthetic generators standing in for the
//! paper's datasets (DESIGN.md §7, §12).

pub mod loader;
pub mod sparse;
pub mod store;
pub mod synth;

pub use sparse::SparseData;
pub use store::ShardedData;

use crate::distance::{Metric, SparseRow};

/// Dense row-major f32 dataset.
#[derive(Clone, Debug)]
pub struct DenseData {
    pub n: usize,
    pub dim: usize,
    pub data: Vec<f32>,
}

impl DenseData {
    pub fn new(n: usize, dim: usize, data: Vec<f32>) -> Self {
        assert_eq!(n * dim, data.len(), "dense data length mismatch");
        DenseData { n, dim, data }
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.dim..(i + 1) * self.dim]
    }
}

/// A dataset: points living in a common space with per-row access.
///
/// All storage layouts serve every metric; the engines pick the fastest
/// path (sparse merge-walks vs dense vectorized sweeps vs shard-aware
/// gathers) per representation. [`Data::Sharded`] serves rows from an
/// on-disk shard set within a fixed resident budget — the backend that
/// hosts the paper's 10⁵–10⁶-point workloads (DESIGN.md §12).
#[derive(Clone, Debug)]
pub enum Data {
    Dense(DenseData),
    Sparse(SparseData),
    Sharded(ShardedData),
}

impl Data {
    pub fn n(&self) -> usize {
        match self {
            Data::Dense(d) => d.n,
            Data::Sparse(s) => s.n,
            Data::Sharded(sd) => sd.n(),
        }
    }

    pub fn dim(&self) -> usize {
        match self {
            Data::Dense(d) => d.dim,
            Data::Sparse(s) => s.dim,
            Data::Sharded(sd) => sd.dim(),
        }
    }

    pub fn is_sparse(&self) -> bool {
        match self {
            Data::Sparse(_) => true,
            Data::Sharded(sd) => sd.is_sparse(),
            Data::Dense(_) => false,
        }
    }

    /// Euclidean norms of every row (precomputed once for cosine). The
    /// sharded backend streams shard-by-shard — same per-row kernels, so
    /// the result is bitwise identical to the resident path.
    pub fn norms(&self) -> Vec<f32> {
        match self {
            Data::Dense(d) => (0..d.n).map(|i| crate::distance::dense::norm(d.row(i))).collect(),
            Data::Sparse(s) => (0..s.n).map(|i| s.row(i).norm()).collect(),
            Data::Sharded(sd) => {
                let mut out = vec![0f32; sd.n()];
                if sd.is_sparse() {
                    sd.for_sparse_rows(0, sd.n(), |i, r| out[i] = r.norm());
                } else {
                    sd.for_dense_rows(0, sd.n(), |i, row| {
                        out[i] = crate::distance::dense::norm(row)
                    });
                }
                out
            }
        }
    }

    /// Distance between rows `i` and `j` (cosine uses `norms` if given).
    #[inline]
    pub fn distance(&self, metric: Metric, i: usize, j: usize, norms: Option<&[f32]>) -> f32 {
        let (ni, nj) = match norms {
            Some(ns) => (ns[i], ns[j]),
            None => (f32::NAN, f32::NAN),
        };
        match self {
            Data::Dense(d) => metric.dense(d.row(i), d.row(j), ni, nj),
            Data::Sparse(s) => metric.sparse(s.row(i), s.row(j), ni, nj),
            Data::Sharded(sd) => sd.distance(metric, i, j, ni, nj),
        }
    }

    /// Copy row `i` into `out` as a dense vector (gather for the PJRT path).
    pub fn densify_row_into(&self, i: usize, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.dim());
        match self {
            Data::Dense(d) => out.copy_from_slice(d.row(i)),
            Data::Sparse(s) => {
                out.fill(0.0);
                let r: SparseRow<'_> = s.row(i);
                for (&c, &v) in r.indices.iter().zip(r.values) {
                    out[c as usize] = v;
                }
            }
            Data::Sharded(sd) => sd.densify_row_into(i, out),
        }
    }

    /// Materialize the whole dataset densely (small datasets / tests only).
    pub fn to_dense(&self) -> DenseData {
        match self {
            Data::Dense(d) => d.clone(),
            Data::Sparse(s) => {
                let mut data = vec![0f32; s.n * s.dim];
                for i in 0..s.n {
                    let r = s.row(i);
                    let row = &mut data[i * s.dim..(i + 1) * s.dim];
                    for (&c, &v) in r.indices.iter().zip(r.values) {
                        row[c as usize] = v;
                    }
                }
                DenseData::new(s.n, s.dim, data)
            }
            Data::Sharded(sd) => sd.to_resident().to_dense(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_dense() -> Data {
        Data::Dense(DenseData::new(3, 2, vec![0.0, 0.0, 3.0, 4.0, 1.0, 1.0]))
    }

    #[test]
    fn dense_rows_and_distance() {
        let d = toy_dense();
        assert_eq!(d.n(), 3);
        assert_eq!(d.dim(), 2);
        assert_eq!(d.distance(Metric::L2, 0, 1, None), 5.0);
        assert_eq!(d.distance(Metric::L1, 0, 2, None), 2.0);
    }

    #[test]
    fn norms_match_rows() {
        let d = toy_dense();
        let ns = d.norms();
        assert_eq!(ns[1], 5.0);
        // cosine with precomputed norms == on-the-fly
        let with = d.distance(Metric::Cosine, 1, 2, Some(&ns));
        let without = d.distance(Metric::Cosine, 1, 2, None);
        assert!((with - without).abs() < 1e-7);
    }

    #[test]
    fn sparse_dense_distance_agree() {
        use crate::util::rng::Rng;
        let mut rng = Rng::seeded(30);
        let s = synth::netflix::generate(&synth::SynthConfig {
            n: 40,
            dim: 100,
            seed: 5,
            ..Default::default()
        });
        let sp = match &s {
            Data::Sparse(sp) => sp.clone(),
            _ => panic!("netflix generator must be sparse"),
        };
        let dense = Data::Dense(s.to_dense());
        let norms_s = s.norms();
        let norms_d = dense.norms();
        for _ in 0..50 {
            let i = rng.below(40);
            let j = rng.below(40);
            for m in Metric::ALL {
                let a = s.distance(m, i, j, Some(&norms_s));
                let b = dense.distance(m, i, j, Some(&norms_d));
                assert!((a - b).abs() < 1e-4, "{m} mismatch at ({i},{j}): {a} vs {b}");
            }
        }
        assert_eq!(sp.n, 40);
    }

    #[test]
    fn densify_row_roundtrip() {
        let s = synth::rnaseq::generate(&synth::SynthConfig {
            n: 10,
            dim: 50,
            seed: 1,
            ..Default::default()
        });
        let dense = s.to_dense();
        let mut buf = vec![0f32; 50];
        for i in 0..10 {
            s.densify_row_into(i, &mut buf);
            assert_eq!(buf, dense.row(i), "row {i}");
        }
    }
}
