//! CSR sparse matrix: the storage for the Netflix-like (0.2% dense) and
//! large RNA-Seq-like workloads.

use crate::distance::SparseRow;

#[derive(Clone, Debug)]
pub struct SparseData {
    pub n: usize,
    pub dim: usize,
    /// `indptr[i]..indptr[i+1]` delimits row i; len n+1.
    pub indptr: Vec<usize>,
    /// Column indices, sorted within each row.
    pub indices: Vec<u32>,
    pub values: Vec<f32>,
}

impl SparseData {
    /// Validating constructor: indptr monotone, indices in range + sorted.
    pub fn new(
        n: usize,
        dim: usize,
        indptr: Vec<usize>,
        indices: Vec<u32>,
        values: Vec<f32>,
    ) -> crate::Result<Self> {
        crate::ensure!(indptr.len() == n + 1, "indptr len {} != n+1", indptr.len());
        crate::ensure!(indptr[0] == 0, "indptr[0] != 0");
        crate::ensure!(*indptr.last().unwrap() == indices.len(), "indptr tail mismatch");
        crate::ensure!(indices.len() == values.len(), "indices/values mismatch");
        for i in 0..n {
            crate::ensure!(indptr[i] <= indptr[i + 1], "indptr not monotone at {i}");
            let row = &indices[indptr[i]..indptr[i + 1]];
            for w in row.windows(2) {
                crate::ensure!(w[0] < w[1], "row {i} indices not strictly sorted");
            }
            if let Some(&last) = row.last() {
                crate::ensure!((last as usize) < dim, "row {i} index {last} >= dim {dim}");
            }
        }
        Ok(SparseData { n, dim, indptr, indices, values })
    }

    /// Average nnz per row, never 0 — the *effective* per-pair dim of the
    /// engine's sparse support walks, which the FLOP-based serial-vs-
    /// parallel cutoff scales by (the nominal `dim` would overcount the
    /// work by ~1/density).
    pub fn avg_nnz(&self) -> usize {
        self.indices.len().div_ceil(self.n.max(1)).max(1)
    }

    /// Build from per-row (index, value) lists (sorts each row).
    pub fn from_rows(n: usize, dim: usize, rows: Vec<Vec<(u32, f32)>>) -> Self {
        assert_eq!(rows.len(), n);
        let mut indptr = Vec::with_capacity(n + 1);
        let mut indices = Vec::new();
        let mut values = Vec::new();
        indptr.push(0);
        for mut row in rows {
            row.sort_unstable_by_key(|&(i, _)| i);
            row.dedup_by_key(|&mut (i, _)| i);
            for (i, v) in row {
                debug_assert!((i as usize) < dim);
                indices.push(i);
                values.push(v);
            }
            indptr.push(indices.len());
        }
        SparseData { n, dim, indptr, indices, values }
    }

    #[inline]
    pub fn row(&self, i: usize) -> SparseRow<'_> {
        let (lo, hi) = (self.indptr[i], self.indptr[i + 1]);
        SparseRow { indices: &self.indices[lo..hi], values: &self.values[lo..hi] }
    }

    pub fn nnz(&self) -> usize {
        self.indices.len()
    }

    /// Fraction of nonzero entries.
    pub fn density(&self) -> f64 {
        self.nnz() as f64 / (self.n as f64 * self.dim as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_rows_sorts_and_indexes() {
        let s = SparseData::from_rows(
            2,
            10,
            vec![vec![(5, 1.0), (2, 2.0)], vec![]],
        );
        assert_eq!(s.row(0).indices, &[2, 5]);
        assert_eq!(s.row(0).values, &[2.0, 1.0]);
        assert_eq!(s.row(1).nnz(), 0);
        assert_eq!(s.nnz(), 2);
    }

    #[test]
    fn validation_catches_corruption() {
        // bad indptr tail
        assert!(SparseData::new(1, 4, vec![0, 2], vec![1], vec![1.0]).is_err());
        // unsorted row
        assert!(SparseData::new(1, 4, vec![0, 2], vec![3, 1], vec![1.0, 1.0]).is_err());
        // index out of range
        assert!(SparseData::new(1, 4, vec![0, 1], vec![9], vec![1.0]).is_err());
        // good
        assert!(SparseData::new(1, 4, vec![0, 2], vec![1, 3], vec![1.0, 1.0]).is_ok());
    }

    #[test]
    fn density() {
        let s = SparseData::from_rows(2, 10, vec![vec![(0, 1.0)], vec![(1, 1.0)]]);
        assert!((s.density() - 0.1).abs() < 1e-12);
    }
}
