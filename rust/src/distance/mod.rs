//! Distance metrics over dense rows and sparse (CSR) rows.
//!
//! These are the scalar building blocks; the batched hot paths live in
//! [`crate::engine`] (native SIMD-friendly sweeps) and in the L1 Pallas
//! kernels (PJRT path). The paper's three evaluation metrics are implemented
//! exactly: ℓ₁ (RNA-Seq), cosine (Netflix), ℓ₂ (MNIST).

use std::fmt;
use std::str::FromStr;

pub mod dense;
pub mod sparse;

pub use dense::{cosine_dense, l1_dense, l2_dense};
pub use sparse::{cosine_sparse, l1_sparse, l2_sparse, SparseRow};

/// Distance metric. `Display`/`FromStr` use the python-layer names
/// (`l1`, `l2`, `cosine`) so config files, artifact names and CLI agree.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Metric {
    L1,
    L2,
    Cosine,
}

impl Metric {
    pub const ALL: [Metric; 3] = [Metric::L1, Metric::L2, Metric::Cosine];

    pub fn name(&self) -> &'static str {
        match self {
            Metric::L1 => "l1",
            Metric::L2 => "l2",
            Metric::Cosine => "cosine",
        }
    }

    /// Dense distance between two equal-length rows.
    ///
    /// For cosine, `ni`/`nj` are the precomputed euclidean norms of the rows
    /// (pass [`f32::NAN`] to compute on the fly).
    #[inline]
    pub fn dense(&self, a: &[f32], b: &[f32], ni: f32, nj: f32) -> f32 {
        match self {
            Metric::L1 => l1_dense(a, b),
            Metric::L2 => l2_dense(a, b),
            Metric::Cosine => {
                let ni = if ni.is_nan() { dense::norm(a) } else { ni };
                let nj = if nj.is_nan() { dense::norm(b) } else { nj };
                cosine_dense(a, b, ni, nj)
            }
        }
    }

    /// Sparse distance between two CSR rows (see [`SparseRow`]).
    /// As with [`Metric::dense`], pass [`f32::NAN`] norms to compute on the fly.
    #[inline]
    pub fn sparse(&self, a: SparseRow<'_>, b: SparseRow<'_>, ni: f32, nj: f32) -> f32 {
        match self {
            Metric::L1 => l1_sparse(a, b),
            Metric::L2 => l2_sparse(a, b),
            Metric::Cosine => {
                let ni = if ni.is_nan() { a.norm() } else { ni };
                let nj = if nj.is_nan() { b.norm() } else { nj };
                cosine_sparse(a, b, ni, nj)
            }
        }
    }
}

impl fmt::Display for Metric {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

impl FromStr for Metric {
    type Err = crate::util::error::Error;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "l1" | "manhattan" => Ok(Metric::L1),
            "l2" | "euclidean" => Ok(Metric::L2),
            "cosine" | "cos" => Ok(Metric::Cosine),
            other => crate::bail!("unknown metric {other:?} (want l1|l2|cosine)"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_and_display_roundtrip() {
        for m in Metric::ALL {
            assert_eq!(m.name().parse::<Metric>().unwrap(), m);
        }
        assert_eq!("euclidean".parse::<Metric>().unwrap(), Metric::L2);
        assert!("chebyshev".parse::<Metric>().is_err());
    }

    #[test]
    fn dense_dispatch_matches_direct() {
        let a = [1.0f32, -2.0, 3.0];
        let b = [0.5f32, 1.0, -1.0];
        assert_eq!(Metric::L1.dense(&a, &b, f32::NAN, f32::NAN), l1_dense(&a, &b));
        assert_eq!(Metric::L2.dense(&a, &b, f32::NAN, f32::NAN), l2_dense(&a, &b));
        let c_direct = cosine_dense(&a, &b, dense::norm(&a), dense::norm(&b));
        assert_eq!(Metric::Cosine.dense(&a, &b, f32::NAN, f32::NAN), c_direct);
    }
}
