//! Sparse-row (CSR) distance kernels — merge-walks over sorted index lists.
//!
//! The Netflix workload (0.2% density cosine) and the large RNA-Seq configs
//! run on these: O(nnz_a + nnz_b) per pull instead of O(d).

/// Borrowed view of one CSR row: parallel sorted `indices` + `values`.
#[derive(Clone, Copy, Debug)]
pub struct SparseRow<'a> {
    pub indices: &'a [u32],
    pub values: &'a [f32],
}

impl<'a> SparseRow<'a> {
    pub fn nnz(&self) -> usize {
        self.indices.len()
    }

    pub fn norm(&self) -> f32 {
        self.values.iter().map(|v| v * v).sum::<f32>().sqrt()
    }

    /// Σ|v| over the support, accumulated in f64 — feeds the engine's L1
    /// row-reduction, whose correction terms cancel at large magnitudes
    /// (DESIGN.md §9: the f32 chain error here is what the f64 round-sum
    /// policy exists to exclude).
    pub fn abs_sum_f64(&self) -> f64 {
        self.values.iter().map(|&v| (v as f64).abs()).sum()
    }
}

/// Iterator over maximal runs of *consecutive* column indices in a sorted
/// CSR index list, yielded as `(start_position, run_length)` pairs. Within
/// a run, the values and any densified reference row are both contiguous,
/// which is what lets the engine's correction walks (`engine::simd`) go
/// vector-wide without gathers. Segmentation depends only on the indices,
/// so every kernel variant sees identical run boundaries.
pub fn index_runs(indices: &[u32]) -> IndexRuns<'_> {
    IndexRuns { indices, pos: 0 }
}

/// See [`index_runs`].
pub struct IndexRuns<'a> {
    indices: &'a [u32],
    pos: usize,
}

impl Iterator for IndexRuns<'_> {
    type Item = (usize, usize);

    fn next(&mut self) -> Option<(usize, usize)> {
        let start = self.pos;
        if start >= self.indices.len() {
            return None;
        }
        let mut len = 1usize;
        while start + len < self.indices.len()
            && self.indices[start + len] as u64 == self.indices[start] as u64 + len as u64
        {
            len += 1;
        }
        self.pos = start + len;
        Some((start, len))
    }
}

/// Σ |a_k − b_k| via merge-walk; indices absent from both contribute 0.
pub fn l1_sparse(a: SparseRow<'_>, b: SparseRow<'_>) -> f32 {
    let (mut i, mut j) = (0usize, 0usize);
    let mut s = 0f32;
    while i < a.indices.len() && j < b.indices.len() {
        match a.indices[i].cmp(&b.indices[j]) {
            std::cmp::Ordering::Less => {
                s += a.values[i].abs();
                i += 1;
            }
            std::cmp::Ordering::Greater => {
                s += b.values[j].abs();
                j += 1;
            }
            std::cmp::Ordering::Equal => {
                s += (a.values[i] - b.values[j]).abs();
                i += 1;
                j += 1;
            }
        }
    }
    s += a.values[i..].iter().map(|v| v.abs()).sum::<f32>();
    s += b.values[j..].iter().map(|v| v.abs()).sum::<f32>();
    s
}

/// Σ (a_k − b_k)²
pub fn l2sq_sparse(a: SparseRow<'_>, b: SparseRow<'_>) -> f32 {
    let (mut i, mut j) = (0usize, 0usize);
    let mut s = 0f32;
    while i < a.indices.len() && j < b.indices.len() {
        match a.indices[i].cmp(&b.indices[j]) {
            std::cmp::Ordering::Less => {
                s += a.values[i] * a.values[i];
                i += 1;
            }
            std::cmp::Ordering::Greater => {
                s += b.values[j] * b.values[j];
                j += 1;
            }
            std::cmp::Ordering::Equal => {
                let d = a.values[i] - b.values[j];
                s += d * d;
                i += 1;
                j += 1;
            }
        }
    }
    s += a.values[i..].iter().map(|v| v * v).sum::<f32>();
    s += b.values[j..].iter().map(|v| v * v).sum::<f32>();
    s
}

pub fn l2_sparse(a: SparseRow<'_>, b: SparseRow<'_>) -> f32 {
    l2sq_sparse(a, b).sqrt()
}

/// Σ a_k b_k — only co-occurring indices contribute.
pub fn dot_sparse(a: SparseRow<'_>, b: SparseRow<'_>) -> f32 {
    let (mut i, mut j) = (0usize, 0usize);
    let mut s = 0f32;
    while i < a.indices.len() && j < b.indices.len() {
        match a.indices[i].cmp(&b.indices[j]) {
            std::cmp::Ordering::Less => i += 1,
            std::cmp::Ordering::Greater => j += 1,
            std::cmp::Ordering::Equal => {
                s += a.values[i] * b.values[j];
                i += 1;
                j += 1;
            }
        }
    }
    s
}

/// Cosine distance with precomputed norms (zero rows → distance 1).
pub fn cosine_sparse(a: SparseRow<'_>, b: SparseRow<'_>, na: f32, nb: f32) -> f32 {
    let denom = na * nb;
    if denom <= 1e-24 {
        return 1.0;
    }
    1.0 - dot_sparse(a, b) / denom
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::distance::dense;
    use crate::util::rng::Rng;

    /// densify a sparse row for oracle comparison
    fn densify(r: SparseRow<'_>, d: usize) -> Vec<f32> {
        let mut out = vec![0f32; d];
        for (&i, &v) in r.indices.iter().zip(r.values) {
            out[i as usize] = v;
        }
        out
    }

    fn random_sparse(rng: &mut Rng, d: usize, density: f64) -> (Vec<u32>, Vec<f32>) {
        let nnz = ((d as f64 * density) as usize).min(d);
        let mut idx = rng.sample_without_replacement(d, nnz);
        idx.sort_unstable();
        let vals: Vec<f32> = (0..nnz).map(|_| rng.gaussian() as f32).collect();
        (idx.into_iter().map(|i| i as u32).collect(), vals)
    }

    #[test]
    fn sparse_matches_dense_oracle() {
        let mut rng = Rng::seeded(20);
        for _ in 0..100 {
            let d = 200;
            let (ia, va) = random_sparse(&mut rng, d, 0.1);
            let (ib, vb) = random_sparse(&mut rng, d, 0.3);
            let a = SparseRow { indices: &ia, values: &va };
            let b = SparseRow { indices: &ib, values: &vb };
            let da = densify(a, d);
            let db = densify(b, d);
            assert!((l1_sparse(a, b) - dense::l1_dense(&da, &db)).abs() < 1e-4);
            assert!((l2_sparse(a, b) - dense::l2_dense(&da, &db)).abs() < 1e-4);
            let cs = cosine_sparse(a, b, a.norm(), b.norm());
            let cd = dense::cosine_dense(&da, &db, dense::norm(&da), dense::norm(&db));
            assert!((cs - cd).abs() < 1e-5, "{cs} vs {cd}");
        }
    }

    #[test]
    fn empty_rows() {
        let e = SparseRow { indices: &[], values: &[] };
        let (i, v) = (vec![1u32, 5], vec![2.0f32, -3.0]);
        let a = SparseRow { indices: &i, values: &v };
        assert_eq!(l1_sparse(e, e), 0.0);
        assert_eq!(l1_sparse(a, e), 5.0);
        assert_eq!(l2_sparse(a, e), (4.0f32 + 9.0).sqrt());
        assert_eq!(cosine_sparse(a, e, a.norm(), 0.0), 1.0);
        assert_eq!(e.abs_sum_f64(), 0.0);
        assert_eq!(a.abs_sum_f64(), 5.0);
    }

    #[test]
    fn disjoint_supports() {
        let (ia, va) = (vec![0u32, 2], vec![1.0f32, 1.0]);
        let (ib, vb) = (vec![1u32, 3], vec![1.0f32, 1.0]);
        let a = SparseRow { indices: &ia, values: &va };
        let b = SparseRow { indices: &ib, values: &vb };
        assert_eq!(dot_sparse(a, b), 0.0);
        assert_eq!(l1_sparse(a, b), 4.0);
        assert_eq!(cosine_sparse(a, b, a.norm(), b.norm()), 1.0);
    }

    #[test]
    fn index_runs_segments_consecutive_spans() {
        let runs = |idx: &[u32]| index_runs(idx).collect::<Vec<_>>();
        assert_eq!(runs(&[]), vec![]);
        assert_eq!(runs(&[7]), vec![(0, 1)]);
        assert_eq!(runs(&[0, 1, 2, 3]), vec![(0, 4)]);
        assert_eq!(runs(&[0, 2, 4]), vec![(0, 1), (1, 1), (2, 1)]);
        assert_eq!(runs(&[3, 4, 5, 9, 10, 20]), vec![(0, 3), (3, 2), (5, 1)]);
        // positions cover the whole support exactly once, in order
        let mut rng = Rng::seeded(8);
        for _ in 0..50 {
            let (idx, _) = random_sparse(&mut rng, 300, 0.2);
            let mut covered = 0usize;
            for (start, len) in index_runs(&idx) {
                assert_eq!(start, covered, "runs must tile the support");
                assert!(len >= 1);
                for t in 1..len {
                    assert_eq!(idx[start + t], idx[start] + t as u32);
                }
                covered += len;
            }
            assert_eq!(covered, idx.len());
        }
    }

    #[test]
    fn identical_rows_zero_distance() {
        let (i, v) = (vec![3u32, 7, 9], vec![1.5f32, -2.0, 0.5]);
        let a = SparseRow { indices: &i, values: &v };
        assert_eq!(l1_sparse(a, a), 0.0);
        assert_eq!(l2_sparse(a, a), 0.0);
        assert!(cosine_sparse(a, a, a.norm(), a.norm()).abs() < 1e-6);
    }
}
