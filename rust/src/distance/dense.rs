//! Dense-row distance kernels.
//!
//! Written as 4-lane unrolled loops over `f32` slices: LLVM auto-vectorizes
//! these to AVX2 (verified in the §Perf pass via `perf annotate` — see
//! EXPERIMENTS.md). Keeping four independent accumulators breaks the
//! loop-carried dependence so the FMA ports stay busy.

/// Σ |a_k − b_k|
#[inline]
pub fn l1_dense(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0f32; 4];
    let chunks = a.len() / 4;
    for c in 0..chunks {
        let i = c * 4;
        acc[0] += (a[i] - b[i]).abs();
        acc[1] += (a[i + 1] - b[i + 1]).abs();
        acc[2] += (a[i + 2] - b[i + 2]).abs();
        acc[3] += (a[i + 3] - b[i + 3]).abs();
    }
    let mut s = (acc[0] + acc[1]) + (acc[2] + acc[3]);
    for i in chunks * 4..a.len() {
        s += (a[i] - b[i]).abs();
    }
    s
}

/// Σ (a_k − b_k)²  (no sqrt — callers that need the metric take sqrt once)
#[inline]
pub fn l2sq_dense(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0f32; 4];
    let chunks = a.len() / 4;
    for c in 0..chunks {
        let i = c * 4;
        let d0 = a[i] - b[i];
        let d1 = a[i + 1] - b[i + 1];
        let d2 = a[i + 2] - b[i + 2];
        let d3 = a[i + 3] - b[i + 3];
        acc[0] += d0 * d0;
        acc[1] += d1 * d1;
        acc[2] += d2 * d2;
        acc[3] += d3 * d3;
    }
    let mut s = (acc[0] + acc[1]) + (acc[2] + acc[3]);
    for i in chunks * 4..a.len() {
        let d = a[i] - b[i];
        s += d * d;
    }
    s
}

/// √Σ (a_k − b_k)²
#[inline]
pub fn l2_dense(a: &[f32], b: &[f32]) -> f32 {
    l2sq_dense(a, b).sqrt()
}

/// Σ a_k b_k
#[inline]
pub fn dot_dense(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0f32; 4];
    let chunks = a.len() / 4;
    for c in 0..chunks {
        let i = c * 4;
        acc[0] += a[i] * b[i];
        acc[1] += a[i + 1] * b[i + 1];
        acc[2] += a[i + 2] * b[i + 2];
        acc[3] += a[i + 3] * b[i + 3];
    }
    let mut s = (acc[0] + acc[1]) + (acc[2] + acc[3]);
    for i in chunks * 4..a.len() {
        s += a[i] * b[i];
    }
    s
}

/// Euclidean norm of a row.
#[inline]
pub fn norm(a: &[f32]) -> f32 {
    dot_dense(a, a).sqrt()
}

/// Squared euclidean norm accumulated in f64 — exact for f32 inputs up to
/// f64 rounding. The tiled L2 norm expansion (`engine::kernel`) subtracts
/// `2⟨a,b⟩` from `‖a‖² + ‖b‖²`, so the norms must not carry f32 chain
/// error of their own into the cancellation.
#[inline]
pub fn sqnorm_f64(a: &[f32]) -> f64 {
    a.iter().map(|&v| v as f64 * v as f64).sum()
}

/// Cosine distance `1 − <a,b>/(‖a‖‖b‖)` with precomputed norms.
/// Zero rows (norm 0) get distance 1 to everything — same convention as the
/// L1 Pallas kernel and python oracle.
#[inline]
pub fn cosine_dense(a: &[f32], b: &[f32], na: f32, nb: f32) -> f32 {
    let denom = na * nb;
    if denom <= 1e-24 {
        return 1.0;
    }
    1.0 - dot_dense(a, b) / denom
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn naive_l1(a: &[f32], b: &[f32]) -> f32 {
        a.iter().zip(b).map(|(x, y)| (x - y).abs()).sum()
    }
    fn naive_l2(a: &[f32], b: &[f32]) -> f32 {
        a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum::<f32>().sqrt()
    }
    fn naive_dot(a: &[f32], b: &[f32]) -> f32 {
        a.iter().zip(b).map(|(x, y)| x * y).sum()
    }

    #[test]
    fn hand_values() {
        assert_eq!(l1_dense(&[0.0, 0.0], &[1.0, 1.0]), 2.0);
        assert_eq!(l2_dense(&[0.0, 0.0], &[3.0, 4.0]), 5.0);
        assert!((cosine_dense(&[1.0, 0.0], &[0.0, 1.0], 1.0, 1.0) - 1.0).abs() < 1e-7);
        assert!(cosine_dense(&[1.0, 0.0], &[2.0, 0.0], 1.0, 2.0).abs() < 1e-7);
    }

    #[test]
    fn matches_naive_over_random_lengths() {
        let mut rng = Rng::seeded(10);
        for _ in 0..200 {
            let len = rng.below(130); // covers remainder-loop paths incl. 0
            let a: Vec<f32> = (0..len).map(|_| rng.gaussian() as f32).collect();
            let b: Vec<f32> = (0..len).map(|_| rng.gaussian() as f32).collect();
            let scale = naive_l1(&a, &b).max(1.0);
            assert!((l1_dense(&a, &b) - naive_l1(&a, &b)).abs() / scale < 1e-5);
            assert!((l2_dense(&a, &b) - naive_l2(&a, &b)).abs() < 1e-4);
            assert!((dot_dense(&a, &b) - naive_dot(&a, &b)).abs() < 1e-3);
        }
    }

    /// The 4-lane unrolled kernels have a scalar remainder loop; pin the
    /// `len % 4 != 0` tail path explicitly for every metric against a naive
    /// scalar reference (the random-length test above covers it
    /// statistically, this covers it deterministically).
    #[test]
    fn tail_lengths_match_naive_all_metrics() {
        let mut rng = Rng::seeded(12);
        // 0..=9 hits every remainder class twice; 127/129 exercise a long
        // body plus a 3-lane / 1-lane tail.
        let lens: Vec<usize> = (0..=9).chain([127, 129]).collect();
        for len in lens {
            let a: Vec<f32> = (0..len).map(|_| rng.gaussian() as f32).collect();
            let b: Vec<f32> = (0..len).map(|_| rng.gaussian() as f32).collect();

            let l1 = naive_l1(&a, &b);
            assert!(
                (l1_dense(&a, &b) - l1).abs() <= l1.abs().max(1.0) * 1e-5,
                "l1 len {len}: {} vs {l1}",
                l1_dense(&a, &b)
            );

            let l2 = naive_l2(&a, &b);
            assert!(
                (l2_dense(&a, &b) - l2).abs() <= l2.abs().max(1.0) * 1e-5,
                "l2 len {len}: {} vs {l2}",
                l2_dense(&a, &b)
            );

            let dot = naive_dot(&a, &b);
            assert!(
                (dot_dense(&a, &b) - dot).abs() <= dot.abs().max(1.0) * 1e-4,
                "dot len {len}: {} vs {dot}",
                dot_dense(&a, &b)
            );

            // cosine via the kernel norms must match a fully naive version
            let (na, nb) = (norm(&a), norm(&b));
            let cos = cosine_dense(&a, &b, na, nb);
            let denom = naive_dot(&a, &a).sqrt() * naive_dot(&b, &b).sqrt();
            let want = if denom <= 1e-24 { 1.0 } else { 1.0 - dot / denom };
            assert!((cos - want).abs() < 1e-4, "cosine len {len}: {cos} vs {want}");
        }
    }

    #[test]
    fn sqnorm_f64_matches_f64_oracle() {
        let mut rng = Rng::seeded(13);
        for len in [0usize, 1, 3, 4, 7, 129] {
            let a: Vec<f32> = (0..len).map(|_| (rng.gaussian() * 1e6) as f32).collect();
            let want: f64 = a.iter().map(|&v| (v as f64).powi(2)).sum();
            let got = sqnorm_f64(&a);
            assert!((got - want).abs() <= want.abs() * 1e-14, "len {len}: {got} vs {want}");
            // and it agrees with the f32 norm at f32 precision
            let n32 = norm(&a) as f64;
            assert!((got.sqrt() - n32).abs() <= n32.max(1.0) * 1e-5);
        }
    }

    #[test]
    fn zero_row_cosine_is_one() {
        let z = [0.0f32; 8];
        let a = [1.0f32; 8];
        assert_eq!(cosine_dense(&z, &a, 0.0, norm(&a)), 1.0);
    }

    #[test]
    fn metric_axioms_dense() {
        let mut rng = Rng::seeded(11);
        for _ in 0..50 {
            let d = 32;
            let a: Vec<f32> = (0..d).map(|_| rng.gaussian() as f32).collect();
            let b: Vec<f32> = (0..d).map(|_| rng.gaussian() as f32).collect();
            let c: Vec<f32> = (0..d).map(|_| rng.gaussian() as f32).collect();
            // identity + symmetry
            assert!(l1_dense(&a, &a) < 1e-6);
            assert!((l1_dense(&a, &b) - l1_dense(&b, &a)).abs() < 1e-5);
            // triangle inequality (l1, l2)
            assert!(l1_dense(&a, &c) <= l1_dense(&a, &b) + l1_dense(&b, &c) + 1e-4);
            assert!(l2_dense(&a, &c) <= l2_dense(&a, &b) + l2_dense(&b, &c) + 1e-4);
        }
    }
}
