//! `artifacts/manifest.json` — the build-time contract between
//! `python/compile/aot.py` and the rust runtime.

use std::path::{Path, PathBuf};

use crate::util::error::{Context, Result};

use crate::distance::Metric;
use crate::util::json;

/// One AOT-compiled bucket: `chunk_sums_<metric>_a<A>_r<R>_d<d>.hlo.txt`.
#[derive(Clone, Debug, PartialEq)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: String,
    pub metric: Metric,
    pub arms: usize,
    pub refs: usize,
    pub dim: usize,
}

#[derive(Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: Vec<ArtifactSpec>,
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("read {path:?} (run `make artifacts`)"))?;
        let v = json::parse(&text).context("parse manifest.json")?;
        crate::ensure!(
            v.get("version").as_usize() == Some(1),
            "unsupported manifest version {:?}",
            v.get("version")
        );
        crate::ensure!(
            v.get("entry").as_str() == Some("chunk_sums"),
            "unexpected entry point {:?}",
            v.get("entry")
        );
        let arts = v
            .get("artifacts")
            .as_array()
            .context("manifest missing artifacts[]")?;
        let mut artifacts = Vec::with_capacity(arts.len());
        for (i, a) in arts.iter().enumerate() {
            let get_n = |k: &str| {
                a.get(k).as_usize().with_context(|| format!("artifact[{i}].{k} missing"))
            };
            let spec = ArtifactSpec {
                name: a.get("name").as_str().context("artifact name")?.to_string(),
                file: a.get("file").as_str().context("artifact file")?.to_string(),
                metric: a.get("metric").as_str().context("artifact metric")?.parse()?,
                arms: get_n("arms")?,
                refs: get_n("refs")?,
                dim: get_n("dim")?,
            };
            crate::ensure!(
                dir.join(&spec.file).exists(),
                "artifact file {:?} listed in manifest but missing on disk",
                spec.file
            );
            artifacts.push(spec);
        }
        Ok(Manifest { dir, artifacts })
    }

    /// Exact bucket lookup.
    pub fn find(
        &self,
        metric: Metric,
        arms: usize,
        refs: usize,
        dim: usize,
    ) -> Option<&ArtifactSpec> {
        self.artifacts
            .iter()
            .find(|a| a.metric == metric && a.arms == arms && a.refs == refs && a.dim == dim)
    }

    /// All buckets available for (metric, dim), sorted by (arms, refs)
    /// ascending — the planner's ladder.
    pub fn buckets(&self, metric: Metric, dim: usize) -> Vec<(usize, usize)> {
        let mut v: Vec<(usize, usize)> = self
            .artifacts
            .iter()
            .filter(|a| a.metric == metric && a.dim == dim)
            .map(|a| (a.arms, a.refs))
            .collect();
        v.sort_unstable();
        v.dedup();
        v
    }

    /// Dims with at least one bucket for `metric`.
    pub fn dims(&self, metric: Metric) -> Vec<usize> {
        let mut v: Vec<usize> = self
            .artifacts
            .iter()
            .filter(|a| a.metric == metric)
            .map(|a| a.dim)
            .collect();
        v.sort_unstable();
        v.dedup();
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(dir: &Path, body: &str, files: &[&str]) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(dir.join("manifest.json"), body).unwrap();
        for f in files {
            std::fs::write(dir.join(f), "HloModule fake").unwrap();
        }
    }

    fn tmpdir(name: &str) -> PathBuf {
        let d = std::env::temp_dir().join("corrsh-manifest-tests").join(name);
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    const GOOD: &str = r#"{"version":1,"entry":"chunk_sums","inputs":[],
        "output":{"tuple":true},
        "artifacts":[
         {"name":"chunk_sums_l1_a64_r16_d256","file":"a.hlo.txt","metric":"l1","arms":64,"refs":16,"dim":256},
         {"name":"chunk_sums_l1_a256_r64_d256","file":"b.hlo.txt","metric":"l1","arms":256,"refs":64,"dim":256},
         {"name":"chunk_sums_l2_a64_r16_d784","file":"c.hlo.txt","metric":"l2","arms":64,"refs":16,"dim":784}
        ]}"#;

    #[test]
    fn loads_and_indexes() {
        let d = tmpdir("good");
        write_manifest(&d, GOOD, &["a.hlo.txt", "b.hlo.txt", "c.hlo.txt"]);
        let m = Manifest::load(&d).unwrap();
        assert_eq!(m.artifacts.len(), 3);
        assert!(m.find(Metric::L1, 64, 16, 256).is_some());
        assert!(m.find(Metric::L1, 64, 16, 784).is_none());
        assert_eq!(m.buckets(Metric::L1, 256), vec![(64, 16), (256, 64)]);
        assert_eq!(m.dims(Metric::L2), vec![784]);
    }

    #[test]
    fn missing_file_on_disk_rejected() {
        let d = tmpdir("missing");
        write_manifest(&d, GOOD, &["a.hlo.txt", "b.hlo.txt"]); // c missing
        assert!(Manifest::load(&d).is_err());
    }

    #[test]
    fn wrong_version_rejected() {
        let d = tmpdir("ver");
        write_manifest(&d, r#"{"version":2,"entry":"chunk_sums","artifacts":[]}"#, &[]);
        assert!(Manifest::load(&d).is_err());
    }

    #[test]
    fn absent_dir_errors_helpfully() {
        let err = Manifest::load("/definitely/not/a/dir").unwrap_err();
        assert!(format!("{err:#}").contains("make artifacts"));
    }
}
