//! PJRT runtime: load the AOT HLO-text artifacts emitted by
//! `python/compile/aot.py`, compile them on the CPU PJRT client, cache the
//! executables, and run bucket-shaped chunk-sum jobs from the rust hot path.
//!
//! Contract with the python layer (see `artifacts/manifest.json`):
//! one artifact per (metric, arm-bucket A, ref-bucket R, dim d), entry point
//! `chunk_sums(x_arms f32[A,d], y_refs f32[R,d], mask f32[R]) -> (f32[A],)`
//! lowered with `return_tuple=True` (unwrapped here with `to_tuple1`).
//!
//! HLO *text* is the interchange format: jax ≥ 0.5 emits protos with 64-bit
//! instruction ids that xla_extension 0.5.1 rejects; `HloModuleProto::
//! from_text_file` reassigns ids and round-trips cleanly.
//!
//! This module is compiled only with the `pjrt` cargo feature. The offline
//! build links the in-tree [`xla`] stub backend; every layer above the raw
//! client (manifest, planner, engine wiring) is real and tested.

pub mod manifest;
pub mod xla;

pub use manifest::{ArtifactSpec, Manifest};

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::util::error::{Context, Result};

use crate::distance::Metric;
use crate::metrics::Counter;

/// A compiled chunk-sums executable for one bucket shape.
pub struct Executable {
    pub spec: ArtifactSpec,
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    /// Execute one job. Inputs must already be padded to the bucket shape:
    /// `x_arms` is `A*d` floats, `y_refs` is `R*d`, `mask` is `R` (1.0 for
    /// real reference rows, 0.0 for padding). Returns the `A` per-arm sums
    /// (padded arm rows produce garbage sums the caller discards).
    pub fn run(&self, x_arms: &[f32], y_refs: &[f32], mask: &[f32]) -> Result<Vec<f32>> {
        let (a, r, d) = (self.spec.arms, self.spec.refs, self.spec.dim);
        crate::ensure!(x_arms.len() == a * d, "x_arms len {} != {}", x_arms.len(), a * d);
        crate::ensure!(y_refs.len() == r * d, "y_refs len {} != {}", y_refs.len(), r * d);
        crate::ensure!(mask.len() == r, "mask len {} != {}", mask.len(), r);

        let lx = lit_f32(x_arms, &[a, d])?;
        let ly = lit_f32(y_refs, &[r, d])?;
        let lm = lit_f32(mask, &[r])?;
        let result = self
            .exe
            .execute::<xla::Literal>(&[lx, ly, lm])
            .context("pjrt execute")?[0][0]
            .to_literal_sync()?;
        let out = result.to_tuple1().context("unwrap 1-tuple output")?;
        Ok(out.to_vec::<f32>()?)
    }
}

fn lit_f32(data: &[f32], dims: &[usize]) -> Result<xla::Literal> {
    // SAFETY: reinterpreting an f32 slice as its underlying bytes — same
    // allocation, same length in bytes, and u8 has no alignment requirement.
    let bytes: &[u8] = unsafe {
        std::slice::from_raw_parts(data.as_ptr() as *const u8, std::mem::size_of_val(data))
    };
    Ok(xla::Literal::create_from_shape_and_untyped_data(
        xla::ElementType::F32,
        dims,
        bytes,
    )?)
}

/// The artifact registry: PJRT client + lazily compiled executable cache.
pub struct Runtime {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: Mutex<HashMap<String, Arc<Executable>>>,
    /// Cumulative compile time (ns) — surfaced in metrics/EXPERIMENTS.
    pub compile_ns: Counter,
}

impl Runtime {
    /// Open the artifact directory (must contain `manifest.json`).
    pub fn open(dir: impl AsRef<std::path::Path>) -> Result<Self> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        Ok(Runtime {
            client,
            manifest,
            cache: Mutex::new(HashMap::new()),
            compile_ns: Counter::new(),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Get (compiling + caching on first use) the executable for an exact
    /// bucket shape.
    pub fn executable(
        &self,
        metric: Metric,
        arms: usize,
        refs: usize,
        dim: usize,
    ) -> Result<Arc<Executable>> {
        let spec = self
            .manifest
            .find(metric, arms, refs, dim)
            .with_context(|| {
                format!("no artifact for {metric} a{arms} r{refs} d{dim} (run `make artifacts`)")
            })?
            .clone();
        let mut cache = self.cache.lock().unwrap();
        if let Some(exe) = cache.get(&spec.name) {
            return Ok(exe.clone());
        }
        let t = crate::metrics::Timer::start(&self.compile_ns);
        let path = self.manifest.dir.join(&spec.file);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("parse HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).with_context(|| format!("compile {}", spec.name))?;
        drop(t);
        let arc = Arc::new(Executable { spec: spec.clone(), exe });
        cache.insert(spec.name.clone(), arc.clone());
        Ok(arc)
    }

    /// Number of compiled executables currently cached.
    pub fn cached_count(&self) -> usize {
        self.cache.lock().unwrap().len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> Option<std::path::PathBuf> {
        // tests run from the crate root; skip silently if artifacts absent
        let p = std::path::Path::new("artifacts");
        p.join("manifest.json").exists().then(|| p.to_path_buf())
    }

    #[test]
    fn compile_and_run_smallest_bucket() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: no artifacts (run `make artifacts`)");
            return;
        };
        let rt = Runtime::open(dir).unwrap();
        let exe = rt.executable(Metric::L1, 64, 16, 256).unwrap();
        // x rows: constant rows i -> distance |i - j| * d
        let d = 256;
        let mut x = vec![0f32; 64 * d];
        for i in 0..64 {
            x[i * d..(i + 1) * d].fill(i as f32);
        }
        let mut y = vec![0f32; 16 * d];
        for j in 0..16 {
            y[j * d..(j + 1) * d].fill(j as f32);
        }
        let mask = vec![1f32; 16];
        let sums = exe.run(&x, &y, &mask).unwrap();
        // l1(x_i, y_j) = |i-j| * 256; sum over j=0..15
        for i in 0..64usize {
            let want: f32 = (0..16).map(|j| (i as f32 - j as f32).abs() * 256.0).sum();
            assert!(
                (sums[i] - want).abs() < want.max(1.0) * 1e-5,
                "arm {i}: {} vs {want}",
                sums[i]
            );
        }
        // cache hit
        let again = rt.executable(Metric::L1, 64, 16, 256).unwrap();
        assert!(Arc::ptr_eq(&exe, &again));
        assert_eq!(rt.cached_count(), 1);
    }

    #[test]
    fn mask_zeroes_padding() {
        let Some(dir) = artifacts_dir() else {
            return;
        };
        let rt = Runtime::open(dir).unwrap();
        let exe = rt.executable(Metric::L2, 64, 16, 256).unwrap();
        let d = 256;
        let x = vec![1f32; 64 * d];
        let mut y = vec![0f32; 16 * d];
        // only first 3 refs real: each at per-coord diff 1 -> distance sqrt(d)
        for j in 3..16 {
            y[j * d..(j + 1) * d].fill(123.0); // junk that the mask must hide
        }
        let mut mask = vec![0f32; 16];
        mask[..3].fill(1.0);
        let sums = exe.run(&x, &y, &mask).unwrap();
        let want = 3.0 * (d as f32).sqrt();
        for i in 0..64 {
            assert!((sums[i] - want).abs() < 1e-2, "arm {i}: {} vs {want}", sums[i]);
        }
    }

    #[test]
    fn missing_bucket_is_error() {
        let Some(dir) = artifacts_dir() else {
            return;
        };
        let rt = Runtime::open(dir).unwrap();
        assert!(rt.executable(Metric::L1, 3, 3, 3).is_err());
    }
}
