//! In-tree stand-in for the `xla` PJRT bindings.
//!
//! The real bindings (xla_extension) are not part of the offline dependency
//! closure, so the `pjrt` feature compiles against this API-compatible stub:
//! every constructor returns a descriptive error, and instance methods are
//! statically unreachable (the types embed an uninhabited `Never`), so the
//! whole PJRT path type-checks and the coordinator/planner/manifest layers
//! stay fully tested without linking XLA. Swapping in the real backend is a
//! one-line change in `runtime/mod.rs` (`use xla;` instead of this module).

use std::path::Path;

use crate::bail;
use crate::util::error::Result;

/// Uninhabited: values of the stub handle types cannot be constructed.
#[derive(Debug, Clone, Copy)]
enum Never {}

const UNAVAILABLE: &str = "PJRT backend unavailable: this build's `pjrt` feature links the \
                           in-tree stub (rust/src/runtime/xla.rs); wire the real `xla` \
                           bindings to execute AOT artifacts";

/// Stub of `xla::PjRtClient`.
#[derive(Debug)]
pub struct PjRtClient {
    never: Never,
}

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        bail!(UNAVAILABLE);
    }

    pub fn platform_name(&self) -> String {
        match self.never {}
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        match self.never {}
    }
}

/// Stub of `xla::PjRtLoadedExecutable`.
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    never: Never,
}

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        match self.never {}
    }
}

/// Stub of `xla::PjRtBuffer`.
#[derive(Debug)]
pub struct PjRtBuffer {
    never: Never,
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        match self.never {}
    }
}

/// Stub of `xla::Literal`.
#[derive(Debug)]
pub struct Literal {
    never: Never,
}

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        _ty: ElementType,
        _dims: &[usize],
        _data: &[u8],
    ) -> Result<Self> {
        bail!(UNAVAILABLE);
    }

    pub fn to_tuple1(self) -> Result<Literal> {
        match self.never {}
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        match self.never {}
    }
}

/// Stub of `xla::ElementType` (only the variant the runtime uses).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    F32,
}

/// Stub of `xla::HloModuleProto`.
#[derive(Debug)]
pub struct HloModuleProto {
    never: Never,
}

impl HloModuleProto {
    pub fn from_text_file(_path: impl AsRef<Path>) -> Result<Self> {
        bail!(UNAVAILABLE);
    }
}

/// Stub of `xla::XlaComputation`.
#[derive(Debug)]
pub struct XlaComputation {
    never: Never,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> Self {
        match proto.never {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constructors_error_with_guidance() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(format!("{err:#}").contains("PJRT backend unavailable"));
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
        assert!(Literal::create_from_shape_and_untyped_data(ElementType::F32, &[1], &[0; 4])
            .is_err());
    }
}
