//! Config system: dataset / engine / algorithm / experiment settings,
//! loadable from JSON files with CLI overrides, plus the named presets that
//! mirror the paper's five Table-1 rows.
//!
//! JSON (not TOML) because the build is offline and the in-tree parser
//! (`util::json`) already exists for the AOT manifest. Example:
//!
//! ```json
//! {
//!   "dataset": {"kind": "rnaseq", "n": 20000, "dim": 2048, "seed": 0},
//!   "metric": "l1",
//!   "engine": "native",
//!   "algo": {"name": "corrsh", "pulls_per_arm": 24.0}
//! }
//! ```

use std::path::Path;

use crate::util::error::{Context, Result};

use crate::data::synth::{Kind, SynthConfig};
use crate::distance::Metric;
use crate::util::json::{self, Value};

/// Which engine executes pulls.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineKind {
    /// Vectorized rust sweeps (dense + CSR).
    Native,
    /// AOT Pallas/JAX artifacts through PJRT (dense dims in the manifest).
    Pjrt,
}

impl std::str::FromStr for EngineKind {
    type Err = crate::util::error::Error;

    fn from_str(s: &str) -> Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "native" => Ok(EngineKind::Native),
            "pjrt" | "xla" => Ok(EngineKind::Pjrt),
            other => crate::bail!("unknown engine {other:?} (want native|pjrt)"),
        }
    }
}

/// Algorithm selection + parameters.
#[derive(Clone, Debug, PartialEq)]
pub enum AlgoConfig {
    CorrSh { pulls_per_arm: f64 },
    SeqHalving { pulls_per_arm: f64 },
    Meddit { delta: f64, cap: u64 },
    Rand { refs_per_arm: usize },
    TopRank { phase1_refs: usize },
    Exact,
    /// trimed (arXiv 1605.06950): triangle-inequality elimination — exact
    /// answer, usually sub-n² pulls; the corrSH verification tier.
    Trimed { anchors: usize },
}

impl AlgoConfig {
    pub fn name(&self) -> &'static str {
        match self {
            AlgoConfig::CorrSh { .. } => "corrsh",
            AlgoConfig::SeqHalving { .. } => "seq-halving",
            AlgoConfig::Meddit { .. } => "meddit",
            AlgoConfig::Rand { .. } => "rand",
            AlgoConfig::TopRank { .. } => "toprank",
            AlgoConfig::Exact => "exact",
            AlgoConfig::Trimed { .. } => "trimed",
        }
    }

    /// Instantiate the algorithm object.
    pub fn build(&self, n: usize) -> Box<dyn crate::bandits::MedoidAlgorithm> {
        use crate::bandits::*;
        match *self {
            AlgoConfig::CorrSh { pulls_per_arm } => {
                Box::new(CorrSh::with_pulls_per_arm(pulls_per_arm))
            }
            AlgoConfig::SeqHalving { pulls_per_arm } => {
                Box::new(SeqHalving::with_pulls_per_arm(pulls_per_arm))
            }
            AlgoConfig::Meddit { delta, cap } => {
                let d = if delta > 0.0 { delta } else { 1.0 / n as f64 };
                Box::new(Meddit::new(d).with_budget_cap(cap))
            }
            AlgoConfig::Rand { refs_per_arm } => Box::new(RandBaseline::new(refs_per_arm)),
            AlgoConfig::TopRank { phase1_refs } => Box::new(TopRank::new(phase1_refs)),
            AlgoConfig::Exact => Box::new(Exact::new()),
            AlgoConfig::Trimed { anchors } => Box::new(Trimed::new(anchors)),
        }
    }

    fn from_json(v: &Value) -> Result<Self> {
        let name = v.get("name").as_str().context("algo.name missing")?;
        let f = |k: &str, d: f64| v.get(k).as_f64().unwrap_or(d);
        Ok(match name {
            "corrsh" => AlgoConfig::CorrSh { pulls_per_arm: f("pulls_per_arm", 24.0) },
            "seq-halving" | "sh" => {
                AlgoConfig::SeqHalving { pulls_per_arm: f("pulls_per_arm", 24.0) }
            }
            "meddit" => AlgoConfig::Meddit {
                delta: f("delta", 0.0),
                cap: f("cap", 0.0) as u64,
            },
            "rand" => AlgoConfig::Rand { refs_per_arm: f("refs_per_arm", 1000.0) as usize },
            "toprank" => AlgoConfig::TopRank { phase1_refs: f("phase1_refs", 1000.0) as usize },
            "exact" => AlgoConfig::Exact,
            "trimed" => AlgoConfig::Trimed { anchors: f("anchors", 4.0) as usize },
            other => crate::bail!("unknown algorithm {other:?}"),
        })
    }
}

/// k-medoids clustering knobs (the `kmedoids` CLI subcommand, server op and
/// [`crate::kmedoids::BanditKMedoids`]). Budgets are pulls-per-arm over the
/// respective arm space: BUILD arms are candidate points, SWAP arms are
/// (medoid, non-medoid) pairs, polish arms are cluster members.
#[derive(Clone, Debug, PartialEq)]
pub struct KMedoidsConfig {
    /// Number of medoids.
    pub k: usize,
    /// Halving budget per BUILD step (pulls per candidate arm).
    pub build_pulls_per_arm: f64,
    /// Halving budget per SWAP round (pulls per swap-pair arm).
    pub swap_pulls_per_arm: f64,
    /// SWAP rounds before giving up (each round stops early once the best
    /// verified swap no longer improves the exact loss). 0 disables SWAP.
    pub max_swap_rounds: usize,
    /// Per-cluster corrSH polish budget (pulls per member arm); 0 disables
    /// the polish pass.
    pub polish_pulls_per_arm: f64,
    /// Cross-round pull-reuse cache (BanditPAM++-style): retain candidate
    /// rows and winner verification rows across BUILD steps and SWAP
    /// rounds so repeat pairs never reach the engine. Winner/loss-neutral
    /// by the bitwise-determinism invariant; off reproduces the uncached
    /// pull pattern exactly.
    pub reuse_cache: bool,
}

impl Default for KMedoidsConfig {
    fn default() -> Self {
        KMedoidsConfig {
            k: 5,
            build_pulls_per_arm: 12.0,
            swap_pulls_per_arm: 3.0,
            max_swap_rounds: 3,
            polish_pulls_per_arm: 32.0,
            reuse_cache: true,
        }
    }
}

impl KMedoidsConfig {
    /// Parse from a JSON object (`{"k": 5, "build_pulls_per_arm": 12, ...}`;
    /// unknown fields are ignored, `Null` yields the defaults).
    pub fn from_json_value(v: &Value) -> Result<Self> {
        let mut cfg = KMedoidsConfig::default();
        if matches!(v, Value::Null) {
            return Ok(cfg);
        }
        if let Some(k) = v.get("k").as_usize() {
            cfg.k = k;
        }
        if let Some(x) = v.get("build_pulls_per_arm").as_f64() {
            cfg.build_pulls_per_arm = x;
        }
        if let Some(x) = v.get("swap_pulls_per_arm").as_f64() {
            cfg.swap_pulls_per_arm = x;
        }
        if let Some(r) = v.get("max_swap_rounds").as_usize() {
            cfg.max_swap_rounds = r;
        }
        if let Some(x) = v.get("polish_pulls_per_arm").as_f64() {
            cfg.polish_pulls_per_arm = x;
        }
        if let Some(b) = v.get("reuse_cache").as_bool() {
            cfg.reuse_cache = b;
        }
        cfg.validate()?;
        Ok(cfg)
    }

    /// Reject degenerate knobs up front (the Budget layer would clamp them,
    /// but a config typo should fail loudly, not silently under-sample).
    pub fn validate(&self) -> Result<()> {
        crate::ensure!(self.k >= 1, "kmedoids.k must be >= 1");
        crate::ensure!(
            self.build_pulls_per_arm.is_finite() && self.build_pulls_per_arm > 0.0,
            "kmedoids.build_pulls_per_arm must be finite and > 0"
        );
        crate::ensure!(
            self.swap_pulls_per_arm.is_finite() && self.swap_pulls_per_arm >= 0.0,
            "kmedoids.swap_pulls_per_arm must be finite and >= 0"
        );
        crate::ensure!(
            self.polish_pulls_per_arm.is_finite() && self.polish_pulls_per_arm >= 0.0,
            "kmedoids.polish_pulls_per_arm must be finite and >= 0"
        );
        Ok(())
    }

    /// Instantiate the clustering algorithm.
    pub fn build(&self) -> crate::kmedoids::BanditKMedoids {
        crate::kmedoids::BanditKMedoids::new(self.clone())
    }
}

/// Server runtime shape: the `serve` command, `server::Executor`, and the
/// event loop's admission-control knobs.
#[derive(Clone, Debug, PartialEq)]
pub struct ServerConfig {
    pub addr: String,
    /// Executor worker threads (0 → `threads::default_threads()`).
    pub workers: usize,
    /// Bounded request-queue capacity; submitters block (backpressure)
    /// once it is full.
    pub queue_cap: usize,
    /// Maximum bytes in a single request line; oversized frames are
    /// answered with `error.code:"bad_request"` instead of buffering.
    pub max_request_bytes: usize,
    /// Open-connection cap; connections beyond it are refused with an
    /// `overloaded` line at accept time.
    pub max_connections: usize,
    /// v2 in-flight quota per connection; excess requests are shed.
    pub max_inflight_per_conn: usize,
    /// In-flight quota per dataset across all connections (multi-tenant
    /// fairness); excess v2 requests are shed, v1 requests are deferred.
    pub max_inflight_per_dataset: usize,
    /// Executor queue depth at which new v2 requests are shed with
    /// `overloaded` (0 → use `queue_cap`).
    pub shed_watermark: usize,
    /// Close connections idle (no traffic, nothing in flight) longer than
    /// this; 0 disables the idle sweep.
    pub idle_timeout_ms: u64,
    /// Per-connection buffered-output threshold above which the event loop
    /// stops reading that socket (write backpressure).
    pub write_buf_bytes: usize,
    /// Run as a coordinator: registrations fan out to `worker_endpoints`
    /// and medoid queries execute on the distributed engine (DESIGN.md §15).
    pub coordinator: bool,
    /// Worker endpoints (`host:port`) the coordinator fans pulls out to.
    pub worker_endpoints: Vec<String>,
    /// Minimum segment count of the coordinator's canonical reduction grid
    /// (0 → the distributed engine's default).
    pub dist_segments: usize,
    /// Deadline for `worker.health` probes and connection establishment.
    pub health_timeout_ms: u64,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            addr: "127.0.0.1:7878".to_string(),
            workers: 0,
            queue_cap: 256,
            max_request_bytes: 1 << 20,
            max_connections: 4096,
            max_inflight_per_conn: 64,
            max_inflight_per_dataset: 256,
            shed_watermark: 0,
            idle_timeout_ms: 30_000,
            write_buf_bytes: 1 << 20,
            coordinator: false,
            worker_endpoints: Vec::new(),
            dist_segments: 0,
            health_timeout_ms: 2_000,
        }
    }
}

impl ServerConfig {
    /// Parse from the optional `"server"` object of a config file:
    /// `{"server": {"addr": "0.0.0.0:7878", "workers": 8, "queue_cap": 512,
    /// "max_request_bytes": 1048576, "max_connections": 4096,
    /// "max_inflight_per_conn": 64, "max_inflight_per_dataset": 256,
    /// "shed_watermark": 0, "idle_timeout_ms": 30000}}`.
    pub fn from_json_value(v: &Value) -> Result<Self> {
        let mut cfg = ServerConfig::default();
        let s = v.get("server");
        if matches!(s, Value::Null) {
            return Ok(cfg);
        }
        if let Some(addr) = s.get("addr").as_str() {
            cfg.addr = addr.to_string();
        }
        if let Some(w) = s.get("workers").as_usize() {
            cfg.workers = w;
        }
        if let Some(c) = s.get("queue_cap").as_usize() {
            crate::ensure!(c >= 1, "server.queue_cap must be >= 1");
            cfg.queue_cap = c;
        }
        if let Some(b) = s.get("max_request_bytes").as_usize() {
            crate::ensure!(b >= 1, "server.max_request_bytes must be >= 1");
            cfg.max_request_bytes = b;
        }
        if let Some(c) = s.get("max_connections").as_usize() {
            crate::ensure!(c >= 1, "server.max_connections must be >= 1");
            cfg.max_connections = c;
        }
        if let Some(q) = s.get("max_inflight_per_conn").as_usize() {
            crate::ensure!(q >= 1, "server.max_inflight_per_conn must be >= 1");
            cfg.max_inflight_per_conn = q;
        }
        if let Some(q) = s.get("max_inflight_per_dataset").as_usize() {
            crate::ensure!(q >= 1, "server.max_inflight_per_dataset must be >= 1");
            cfg.max_inflight_per_dataset = q;
        }
        if let Some(w) = s.get("shed_watermark").as_usize() {
            cfg.shed_watermark = w;
        }
        if let Some(t) = s.get("idle_timeout_ms").as_u64() {
            cfg.idle_timeout_ms = t;
        }
        if let Some(b) = s.get("write_buf_bytes").as_usize() {
            crate::ensure!(b >= 1, "server.write_buf_bytes must be >= 1");
            cfg.write_buf_bytes = b;
        }
        if let Some(c) = s.get("coordinator").as_bool() {
            cfg.coordinator = c;
        }
        if let Some(eps) = s.get("worker_endpoints").as_array() {
            cfg.worker_endpoints = eps
                .iter()
                .map(|e| {
                    e.as_str()
                        .map(str::to_string)
                        .context("server.worker_endpoints entries must be strings")
                })
                .collect::<Result<_>>()?;
        }
        if let Some(n) = s.get("dist_segments").as_usize() {
            cfg.dist_segments = n;
        }
        if let Some(t) = s.get("health_timeout_ms").as_u64() {
            crate::ensure!(t >= 1, "server.health_timeout_ms must be >= 1");
            cfg.health_timeout_ms = t;
        }
        crate::ensure!(
            !cfg.coordinator || !cfg.worker_endpoints.is_empty(),
            "server.coordinator requires a non-empty server.worker_endpoints"
        );
        Ok(cfg)
    }
}

/// A full run configuration.
#[derive(Clone, Debug)]
pub struct RunConfig {
    pub dataset_kind: Kind,
    pub synth: SynthConfig,
    pub metric: Metric,
    pub engine: EngineKind,
    pub algo: AlgoConfig,
    /// k-medoids knobs (the `kmedoids` subcommand; ignored by `medoid`).
    pub kmedoids: KMedoidsConfig,
    /// Artifact directory for the PJRT engine.
    pub artifacts_dir: String,
    pub trials: usize,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            dataset_kind: Kind::Gaussian,
            synth: SynthConfig::default(),
            metric: Metric::L2,
            engine: EngineKind::Native,
            algo: AlgoConfig::CorrSh { pulls_per_arm: 24.0 },
            kmedoids: KMedoidsConfig::default(),
            artifacts_dir: "artifacts".to_string(),
            trials: 1,
        }
    }
}

impl RunConfig {
    pub fn from_json_value(v: &Value) -> Result<Self> {
        let mut cfg = RunConfig::default();
        let ds = v.get("dataset");
        if !matches!(ds, Value::Null) {
            if let Some(kind) = ds.get("kind").as_str() {
                cfg.dataset_kind = kind.parse()?;
                cfg.metric = cfg.dataset_kind.default_metric();
            }
            if let Some(n) = ds.get("n").as_usize() {
                cfg.synth.n = n;
            }
            if let Some(d) = ds.get("dim").as_usize() {
                cfg.synth.dim = d;
            }
            if let Some(s) = ds.get("seed").as_f64() {
                cfg.synth.seed = s as u64;
            }
            if let Some(c) = ds.get("clusters").as_usize() {
                cfg.synth.clusters = c;
            }
            if let Some(x) = ds.get("density").as_f64() {
                cfg.synth.density = x;
            }
            if let Some(x) = ds.get("outlier_frac").as_f64() {
                cfg.synth.outlier_frac = x;
            }
        }
        if let Some(m) = v.get("metric").as_str() {
            cfg.metric = m.parse()?;
        }
        if let Some(e) = v.get("engine").as_str() {
            cfg.engine = e.parse()?;
        }
        if let Some(dir) = v.get("artifacts_dir").as_str() {
            cfg.artifacts_dir = dir.to_string();
        }
        if let Some(t) = v.get("trials").as_usize() {
            cfg.trials = t;
        }
        let algo = v.get("algo");
        if !matches!(algo, Value::Null) {
            cfg.algo = AlgoConfig::from_json(algo)?;
        }
        cfg.kmedoids = KMedoidsConfig::from_json_value(v.get("kmedoids"))?;
        Ok(cfg)
    }

    pub fn from_json_file(path: impl AsRef<Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("read config {:?}", path.as_ref()))?;
        let v = json::parse(&text).context("parse config json")?;
        Self::from_json_value(&v)
    }

    /// Named presets mirroring the paper's Table-1 rows (scaled dims — see
    /// DESIGN.md §7; pass `--paper-scale` to the CLI for the full dims).
    pub fn preset(name: &str) -> Result<Self> {
        let mut cfg = RunConfig::default();
        match name {
            "rnaseq20k" => {
                cfg.dataset_kind = Kind::RnaSeq;
                cfg.synth = SynthConfig { n: 20_000, dim: 2_048, ..Default::default() };
                cfg.metric = Metric::L1;
            }
            "rnaseq100k" => {
                cfg.dataset_kind = Kind::RnaSeq;
                cfg.synth = SynthConfig { n: 109_140, dim: 2_048, ..Default::default() };
                cfg.metric = Metric::L1;
            }
            "netflix20k" => {
                cfg.dataset_kind = Kind::Netflix;
                cfg.synth = SynthConfig {
                    n: 20_000,
                    dim: 17_769,
                    density: 0.0021,
                    ..Default::default()
                };
                cfg.metric = Metric::Cosine;
            }
            "netflix100k" => {
                cfg.dataset_kind = Kind::Netflix;
                cfg.synth = SynthConfig {
                    n: 100_000,
                    dim: 17_769,
                    density: 0.0021,
                    ..Default::default()
                };
                cfg.metric = Metric::Cosine;
            }
            "mnist" => {
                cfg.dataset_kind = Kind::Mnist;
                cfg.synth = SynthConfig { n: 6_424, dim: 784, ..Default::default() };
                cfg.metric = Metric::L2;
            }
            "toy" => {
                cfg.dataset_kind = Kind::Gaussian;
                cfg.synth = SynthConfig { n: 1_000, dim: 16, ..Default::default() };
                cfg.metric = Metric::L2;
            }
            other => crate::bail!(
                "unknown preset {other:?} (want rnaseq20k|rnaseq100k|netflix20k|netflix100k|mnist|toy)"
            ),
        }
        Ok(cfg)
    }

    /// Shrink a preset to a quick-run size (for tests and smoke runs):
    /// divides n by `factor`, keeping geometry knobs.
    pub fn scaled_down(mut self, factor: usize) -> Self {
        self.synth.n = (self.synth.n / factor).max(64);
        self.synth.dim = self.synth.dim.min(2_048);
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_full_config() {
        let v = json::parse(
            r#"{"dataset": {"kind": "rnaseq", "n": 500, "dim": 128, "seed": 7},
                "engine": "native", "trials": 3,
                "algo": {"name": "corrsh", "pulls_per_arm": 12.5}}"#,
        )
        .unwrap();
        let cfg = RunConfig::from_json_value(&v).unwrap();
        assert_eq!(cfg.dataset_kind, Kind::RnaSeq);
        assert_eq!(cfg.synth.n, 500);
        assert_eq!(cfg.metric, Metric::L1); // dataset default
        assert_eq!(cfg.trials, 3);
        assert_eq!(cfg.algo, AlgoConfig::CorrSh { pulls_per_arm: 12.5 });
    }

    #[test]
    fn metric_override_wins() {
        let v = json::parse(r#"{"dataset": {"kind": "rnaseq"}, "metric": "l2"}"#).unwrap();
        let cfg = RunConfig::from_json_value(&v).unwrap();
        assert_eq!(cfg.metric, Metric::L2);
    }

    #[test]
    fn presets_match_paper_shapes() {
        let t1 = RunConfig::preset("rnaseq20k").unwrap();
        assert_eq!(t1.synth.n, 20_000);
        assert_eq!(t1.metric, Metric::L1);
        let t3 = RunConfig::preset("netflix20k").unwrap();
        assert_eq!(t3.synth.dim, 17_769);
        assert_eq!(t3.metric, Metric::Cosine);
        let t5 = RunConfig::preset("mnist").unwrap();
        assert_eq!((t5.synth.n, t5.synth.dim), (6_424, 784));
        assert!(RunConfig::preset("nope").is_err());
    }

    #[test]
    fn all_algos_parse_and_build() {
        for (spec, name) in [
            (r#"{"name": "corrsh"}"#, "corrsh"),
            (r#"{"name": "sh"}"#, "seq-halving"),
            (r#"{"name": "meddit", "delta": 0.01}"#, "meddit"),
            (r#"{"name": "rand", "refs_per_arm": 10}"#, "rand"),
            (r#"{"name": "toprank"}"#, "toprank"),
            (r#"{"name": "exact"}"#, "exact"),
            (r#"{"name": "trimed"}"#, "trimed"),
            (r#"{"name": "trimed", "anchors": 8}"#, "trimed"),
        ] {
            let v = json::parse(spec).unwrap();
            let algo = AlgoConfig::from_json(&v).unwrap();
            assert_eq!(algo.name(), name);
            let _ = algo.build(100);
        }
    }

    #[test]
    fn kmedoids_config_parses_and_validates() {
        // absent block -> defaults
        let cfg = RunConfig::from_json_value(&json::parse("{}").unwrap()).unwrap();
        assert_eq!(cfg.kmedoids, KMedoidsConfig::default());
        // overrides ride along a full run config
        let v = json::parse(
            r#"{"dataset": {"kind": "mixture", "n": 2000, "clusters": 5},
                "kmedoids": {"k": 5, "build_pulls_per_arm": 16,
                             "swap_pulls_per_arm": 2, "max_swap_rounds": 2,
                             "polish_pulls_per_arm": 24, "reuse_cache": false}}"#,
        )
        .unwrap();
        let cfg = RunConfig::from_json_value(&v).unwrap();
        assert_eq!(cfg.kmedoids.k, 5);
        assert_eq!(cfg.kmedoids.build_pulls_per_arm, 16.0);
        assert_eq!(cfg.kmedoids.swap_pulls_per_arm, 2.0);
        assert_eq!(cfg.kmedoids.max_swap_rounds, 2);
        assert_eq!(cfg.kmedoids.polish_pulls_per_arm, 24.0);
        assert!(!cfg.kmedoids.reuse_cache, "reuse_cache:false must parse");
        assert!(KMedoidsConfig::default().reuse_cache, "reuse defaults on");
        // degenerate knobs fail loudly
        for bad in [
            r#"{"k": 0}"#,
            r#"{"build_pulls_per_arm": 0}"#,
            r#"{"build_pulls_per_arm": -2}"#,
            r#"{"swap_pulls_per_arm": -1}"#,
        ] {
            let v = json::parse(bad).unwrap();
            assert!(KMedoidsConfig::from_json_value(&v).is_err(), "accepted {bad}");
        }
        // the config builds a runnable algorithm
        assert_eq!(KMedoidsConfig::default().build().cfg.k, 5);
    }

    #[test]
    fn server_config_parses_and_defaults() {
        let cfg = ServerConfig::from_json_value(&json::parse("{}").unwrap()).unwrap();
        assert_eq!(cfg, ServerConfig::default());
        assert_eq!(cfg.max_request_bytes, 1 << 20);
        let v = json::parse(
            r#"{"server": {"addr": "0.0.0.0:9000", "workers": 8, "queue_cap": 512,
                "max_request_bytes": 4096, "max_connections": 100,
                "max_inflight_per_conn": 2, "max_inflight_per_dataset": 5,
                "shed_watermark": 7, "idle_timeout_ms": 0, "write_buf_bytes": 65536}}"#,
        )
        .unwrap();
        let cfg = ServerConfig::from_json_value(&v).unwrap();
        assert_eq!(cfg.addr, "0.0.0.0:9000");
        assert_eq!(cfg.workers, 8);
        assert_eq!(cfg.queue_cap, 512);
        assert_eq!(cfg.max_request_bytes, 4096);
        assert_eq!(cfg.max_connections, 100);
        assert_eq!(cfg.max_inflight_per_conn, 2);
        assert_eq!(cfg.max_inflight_per_dataset, 5);
        assert_eq!(cfg.shed_watermark, 7);
        assert_eq!(cfg.idle_timeout_ms, 0);
        assert_eq!(cfg.write_buf_bytes, 65536);
        assert!(!cfg.coordinator, "coordinator defaults off");
        assert!(cfg.worker_endpoints.is_empty());
        // coordinator mode parses with its fleet knobs
        let v = json::parse(
            r#"{"server": {"coordinator": true, "dist_segments": 16,
                "health_timeout_ms": 500,
                "worker_endpoints": ["127.0.0.1:7801", "127.0.0.1:7802"]}}"#,
        )
        .unwrap();
        let cfg = ServerConfig::from_json_value(&v).unwrap();
        assert!(cfg.coordinator);
        assert_eq!(cfg.worker_endpoints, vec!["127.0.0.1:7801", "127.0.0.1:7802"]);
        assert_eq!(cfg.dist_segments, 16);
        assert_eq!(cfg.health_timeout_ms, 500);
        for bad in [
            r#"{"server": {"queue_cap": 0}}"#,
            r#"{"server": {"max_request_bytes": 0}}"#,
            r#"{"server": {"max_connections": 0}}"#,
            r#"{"server": {"max_inflight_per_conn": 0}}"#,
            r#"{"server": {"max_inflight_per_dataset": 0}}"#,
            r#"{"server": {"health_timeout_ms": 0}}"#,
            r#"{"server": {"coordinator": true}}"#,
            r#"{"server": {"worker_endpoints": [7801]}}"#,
        ] {
            let v = json::parse(bad).unwrap();
            assert!(ServerConfig::from_json_value(&v).is_err(), "accepted {bad}");
        }
    }

    #[test]
    fn scaled_down_keeps_floor() {
        let cfg = RunConfig::preset("rnaseq20k").unwrap().scaled_down(1000);
        assert_eq!(cfg.synth.n, 64);
    }
}
