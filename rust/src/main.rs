//! `corrsh` — launcher for the Correlated Sequential Halving framework.
//!
//! ```text
//! corrsh medoid   --preset rnaseq20k --scale 20 --algo corrsh --budget 24 [--engine pjrt]
//! corrsh kmedoids --kind mixture --n 2000 --clusters 5 --k 5 [--seed S --workers W]
//! corrsh repro    --exp table1|fig1|fig2|fig3|fig4|fig5|fig6|ablation [--scale N --trials T]
//! corrsh stats    --preset mnist --scale 8
//! corrsh serve    --addr 127.0.0.1:7878
//! corrsh serve    --coordinator --workers-endpoints 127.0.0.1:7801,127.0.0.1:7802
//! corrsh worker   --addr 127.0.0.1:7801 [--shards 0..500000]
//! corrsh gen      --kind rnaseq --n 2000 --dim 256 --out data.npy
//! corrsh shard    data.npy shards/ --rows-per-shard 65536
//! corrsh shard    --kind gaussian --n 1000000 --dim 128 --out shards/
//! corrsh kernelinfo
//! corrsh lint     [--ci] [--root DIR] [--out report.json]
//! ```

use corrsh::util::error::{Context, Result};

use corrsh::config::{AlgoConfig, RunConfig};
use corrsh::data::synth::Kind;
use corrsh::experiments::{figures, runner, table1};
use corrsh::server;
use corrsh::util::cli::Args;
use corrsh::util::rng::Rng;

const USAGE: &str = "corrsh <medoid|kmedoids|repro|stats|serve|worker|gen|shard|kernelinfo|lint> [flags]
  medoid:   --preset P | --config file.json [--scale N] [--algo A] [--budget X]
            [--anchors A (trimed)] [--engine native|pjrt] [--seed S] [--trials T]
  kmedoids: --preset P | --config file.json | --kind K [--n N --dim D --clusters C]
            [--k K] [--build-budget X] [--swap-budget X] [--swap-rounds R]
            [--polish-budget X] [--no-reuse] [--seed S] [--workers W]
            (native engine only)
  repro:    --exp table1|fig1|fig2|fig3|fig4|fig5|fig6|ablation|all
            [--scale N] [--trials T] [--seed S]
  stats:    --preset P [--scale N] [--seed S]
  serve:    [--addr HOST:PORT] [--preload P] [--workers N] [--queue-cap N]
            [--max-request-bytes N] [--max-connections N] [--max-inflight-per-conn N]
            [--max-inflight-per-dataset N] [--shed-watermark N] [--idle-timeout-ms MS]
            [--coordinator --workers-endpoints H:P,H:P,... [--dist-segments N]
             [--health-timeout-ms MS]]
  worker:   [--addr HOST:PORT] [--shards A..B] [--workers N] [--max-request-bytes N]
  gen:      --kind K --n N --dim D [--seed S] --out FILE.npy
  shard:    <in.npy|in.csr|manifest.json> <out-dir> [--rows-per-shard N]
            | --kind K --n N --dim D [--seed S] --out DIR (streams at scale)
  kernelinfo: print the dispatched distance micro-kernel (CORRSH_KERNEL)
  lint:     [--ci] [--root DIR] [--out report.json]
            token-level invariant analyzer (rules R1-R8, DESIGN.md §16);
            exits 1 when any rule fires, --ci prints the JSON report";

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n{USAGE}");
            std::process::exit(2);
        }
    };
    // Validate CORRSH_KERNEL before any command runs: an invalid override
    // must be a hard startup error, not a panic deep inside the first pull.
    if let Err(e) = corrsh::engine::simd::startup_check() {
        eprintln!("error: {e:#}");
        std::process::exit(2);
    }
    let cmd = args.command.clone().unwrap_or_default();
    let result = match cmd.as_str() {
        "medoid" => cmd_medoid(&args),
        "kmedoids" => cmd_kmedoids(&args),
        "repro" => cmd_repro(&args),
        "stats" => cmd_stats(&args),
        "serve" => cmd_serve(&args),
        "worker" => cmd_worker(&args),
        "gen" => cmd_gen(&args),
        "shard" => cmd_shard(&args),
        "kernelinfo" => cmd_kernelinfo(&args),
        "lint" => cmd_lint(&args),
        "" | "help" | "--help" => {
            println!("{USAGE}");
            Ok(())
        }
        other => {
            eprintln!("unknown command {other:?}\n{USAGE}");
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// Shared flags → RunConfig.
fn load_config(args: &Args) -> Result<RunConfig> {
    let mut cfg = if let Some(path) = args.str_opt("config") {
        RunConfig::from_json_file(path)?
    } else {
        let preset = args.str_or("preset", "toy");
        RunConfig::preset(&preset)?
    };
    let scale: usize = args.parse_or("scale", 1)?;
    if scale > 1 {
        cfg = cfg.scaled_down(scale);
    }
    if let Some(kind) = args.str_opt("kind") {
        let new_kind: Kind = kind.parse()?;
        // Refresh the metric only when it was derived from the old kind —
        // an explicitly-configured metric (config file "metric" key)
        // survives a --kind override; --metric below still wins over both.
        if cfg.metric == cfg.dataset_kind.default_metric() {
            cfg.metric = new_kind.default_metric();
        }
        cfg.dataset_kind = new_kind;
    }
    if let Some(c) = args.parse_opt::<usize>("clusters")? {
        cfg.synth.clusters = c;
    }
    if let Some(n) = args.parse_opt::<usize>("n")? {
        cfg.synth.n = n;
    }
    if let Some(d) = args.parse_opt::<usize>("dim")? {
        cfg.synth.dim = d;
    }
    if let Some(s) = args.parse_opt::<u64>("data-seed")? {
        cfg.synth.seed = s;
    }
    if let Some(m) = args.str_opt("metric") {
        cfg.metric = m.parse()?;
    }
    if let Some(e) = args.str_opt("engine") {
        cfg.engine = e.parse()?;
    }
    if let Some(dir) = args.str_opt("artifacts") {
        cfg.artifacts_dir = dir.to_string();
    }
    if let Some(algo) = args.str_opt("algo") {
        let budget: f64 = args.parse_or("budget", 24.0)?;
        let anchors: usize = args.parse_or("anchors", 4)?;
        cfg.algo = match algo {
            "corrsh" => AlgoConfig::CorrSh { pulls_per_arm: budget },
            "sh" | "seq-halving" => AlgoConfig::SeqHalving { pulls_per_arm: budget },
            "meddit" => AlgoConfig::Meddit { delta: 0.0, cap: 0 },
            "rand" => AlgoConfig::Rand { refs_per_arm: budget as usize },
            "toprank" => AlgoConfig::TopRank { phase1_refs: budget as usize },
            "exact" => AlgoConfig::Exact,
            // Budget does not apply to trimed: it pulls until the triangle
            // bound proves the rest eliminated, like "exact" ignores it too.
            "trimed" => AlgoConfig::Trimed { anchors: anchors.max(1) },
            other => corrsh::bail!("unknown algo {other:?}"),
        };
    } else {
        let _ = args.parse_or("budget", 24.0)?; // consume if present
        let _ = args.parse_or("anchors", 4usize)?; // consume if present
    }
    cfg.trials = args.parse_or("trials", cfg.trials)?;
    Ok(cfg)
}

fn cmd_medoid(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let seed: u64 = args.parse_or("seed", 0)?;
    args.finish()?;

    eprintln!(
        "dataset={} n={} dim={} metric={} engine={:?} algo={}",
        cfg.dataset_kind.name(),
        cfg.synth.n,
        cfg.synth.dim,
        cfg.metric,
        cfg.engine,
        cfg.algo.name()
    );
    let t0 = std::time::Instant::now();
    let data = runner::build_data(&cfg);
    eprintln!("generated dataset in {:.2}s", t0.elapsed().as_secs_f64());

    let engine = runner::build_engine(&cfg, &data)?;
    for t in 0..cfg.trials.max(1) {
        let mut rng = Rng::seeded(seed + t as u64);
        let algo = cfg.algo.build(data.n());
        let res = algo.run(engine.as_ref(), &mut rng);
        println!(
            "trial {t}: medoid={} pulls={} ({:.2}/arm) wall={:.3}s rounds={}",
            res.best,
            res.pulls,
            res.pulls as f64 / data.n() as f64,
            res.wall.as_secs_f64(),
            res.rounds.len()
        );
    }
    Ok(())
}

fn cmd_kmedoids(args: &Args) -> Result<()> {
    use corrsh::kmedoids::ClusteringAlgorithm;

    let cfg = load_config(args)?;
    let mut kcfg = cfg.kmedoids.clone();
    if let Some(k) = args.parse_opt::<usize>("k")? {
        kcfg.k = k;
    }
    if let Some(x) = args.parse_opt::<f64>("build-budget")? {
        kcfg.build_pulls_per_arm = x;
    }
    if let Some(x) = args.parse_opt::<f64>("swap-budget")? {
        kcfg.swap_pulls_per_arm = x;
    }
    if let Some(r) = args.parse_opt::<usize>("swap-rounds")? {
        kcfg.max_swap_rounds = r;
    }
    if let Some(x) = args.parse_opt::<f64>("polish-budget")? {
        kcfg.polish_pulls_per_arm = x;
    }
    if args.switch("no-reuse") {
        kcfg.reuse_cache = false;
    }
    kcfg.validate()?;
    let seed: u64 = args.parse_or("seed", 0)?;
    let workers: usize = args.parse_or("workers", corrsh::util::threads::default_threads())?;
    args.finish()?;
    if cfg.engine == corrsh::config::EngineKind::Pjrt {
        corrsh::bail!("kmedoids: native engine only (drop --engine pjrt)");
    }

    eprintln!(
        "dataset={} n={} dim={} metric={} k={} workers={workers}",
        cfg.dataset_kind.name(),
        cfg.synth.n,
        cfg.synth.dim,
        cfg.metric,
        kcfg.k
    );
    let data = runner::build_data(&cfg);
    corrsh::ensure!(
        kcfg.k <= data.n(),
        "kmedoids: k = {} exceeds dataset size n = {}",
        kcfg.k,
        data.n()
    );
    let engine = corrsh::engine::NativeEngine::with_threads(
        data.clone(),
        cfg.metric,
        workers.max(1),
    );
    let mut rng = Rng::seeded(seed);
    let res = corrsh::kmedoids::BanditKMedoids::new(kcfg).run(&engine, &mut rng);
    let mut medoids = res.medoids.clone();
    medoids.sort_unstable();
    println!(
        "medoids={medoids:?} loss={:.4} pulls={} (build={} swap={} polish={}, \
         {:.2}/point) swaps={}/{} wall={:.3}s",
        res.loss,
        res.pulls(),
        res.build_pulls,
        res.swap_pulls,
        res.polish_pulls,
        res.pulls() as f64 / data.n() as f64,
        res.swaps_accepted,
        res.swap_rounds,
        res.wall.as_secs_f64()
    );
    println!("cluster_sizes={:?}", res.cluster_sizes());
    Ok(())
}

fn cmd_repro(args: &Args) -> Result<()> {
    let exp = args.str_or("exp", "all");
    let scale: usize = args.parse_or("scale", 20)?;
    let trials: usize = args.parse_or("trials", 20)?;
    let seed: u64 = args.parse_or("seed", 0)?;
    args.finish()?;

    let budgets_small: Vec<f64> = vec![1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0];
    let run_sweep = |name: &str, preset: &str| -> Result<()> {
        let cfg = RunConfig::preset(preset)?.scaled_down(scale);
        let pts = figures::error_vs_budget(&cfg, &budgets_small, trials, seed)?;
        figures::emit_sweep(name, &pts);
        Ok(())
    };

    match exp.as_str() {
        "table1" => {
            table1::run(scale, trials, seed)?;
        }
        "fig1" => {
            run_sweep("fig1_rnaseq20k", "rnaseq20k")?;
            run_sweep("fig1_netflix100k", "netflix100k")?;
        }
        "fig2" => {
            let d = figures::fig2_toy_demo(20_000, seed);
            println!(
                "fig2 (toy): P[mid point beats medoid after 1 sample] independent={:.4} correlated={:.4}",
                d.p_flip_independent, d.p_flip_correlated
            );
        }
        "fig3" => {
            let cfg = RunConfig::preset("rnaseq20k")?.scaled_down(scale);
            for row in figures::fig3_difference_histograms(&cfg, 20_000, seed)? {
                println!(
                    "fig3 {:<14} σ={:.4} ρ={:.3} std_ind={:.4} P(neg): ind={:.4} corr={:.4}",
                    row.arm_kind,
                    row.sigma,
                    row.rho,
                    row.std_independent,
                    row.p_neg_independent,
                    row.p_neg_correlated
                );
            }
        }
        "fig4" => {
            for preset in ["rnaseq20k", "mnist"] {
                let cfg = RunConfig::preset(preset)?.scaled_down(scale);
                let out = figures::fig4_delta_vs_rho(&cfg, seed)?;
                println!(
                    "fig4 {preset}: H2={:.4e} H̃2={:.4e} gain H2/H̃2={:.2} ({} arms)",
                    out.h2, out.h2_tilde, out.gain_ratio, out.rows
                );
            }
        }
        "fig5" => {
            run_sweep("fig5_netflix20k", "netflix20k")?;
            run_sweep("fig5_rnaseq100k", "rnaseq100k")?;
            run_sweep("fig5_mnist", "mnist")?;
        }
        "fig6" => {
            for preset in ["rnaseq20k", "mnist"] {
                let cfg = RunConfig::preset(preset)?.scaled_down(scale);
                figures::fig6_distance_to_medoid(&cfg, seed)?;
            }
        }
        "ablation" => {
            let cfg = RunConfig::preset("rnaseq20k")?.scaled_down(scale);
            let pts = figures::ablation_corr_vs_uncorr(&cfg, &budgets_small, trials, seed)?;
            figures::emit_sweep("ablation_corr_vs_uncorr", &pts);
        }
        "all" => {
            for e in ["table1", "fig1", "fig2", "fig3", "fig4", "fig5", "fig6", "ablation"] {
                println!("\n=== repro {e} ===");
                let sub = Args::parse(
                    [
                        "repro".to_string(),
                        format!("--exp={e}"),
                        format!("--scale={scale}"),
                        format!("--trials={trials}"),
                        format!("--seed={seed}"),
                    ]
                    .into_iter(),
                )?;
                cmd_repro(&sub)?;
            }
        }
        other => corrsh::bail!("unknown experiment {other:?}"),
    }
    Ok(())
}

fn cmd_stats(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let seed: u64 = args.parse_or("seed", 0)?;
    args.finish()?;
    let data = runner::build_data(&cfg);
    let engine = corrsh::engine::NativeEngine::with_threads(
        data.clone(),
        cfg.metric,
        corrsh::util::threads::default_threads(),
    );
    let mut rng = Rng::seeded(seed);
    let st = corrsh::stats::instance_stats(&engine, 512.min(data.n()), &mut rng);
    println!(
        "n={} medoid={} σ={:.5} H2={:.4e} H̃2={:.4e} gain={:.2}",
        data.n(),
        st.medoid,
        st.sigma,
        st.h2,
        st.h2_tilde,
        st.gain_ratio()
    );
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let defaults = corrsh::config::ServerConfig::default();
    // Passing worker endpoints implies coordinator mode; the bare
    // --coordinator switch still demands them so a fleet is never empty.
    let worker_endpoints: Vec<String> = match args.str_opt("workers-endpoints") {
        Some(s) => {
            s.split(',').map(|e| e.trim().to_string()).filter(|e| !e.is_empty()).collect()
        }
        None => Vec::new(),
    };
    let server_cfg = corrsh::config::ServerConfig {
        addr: args.str_or("addr", &defaults.addr),
        workers: args.parse_or("workers", defaults.workers)?,
        queue_cap: args.parse_or("queue-cap", defaults.queue_cap)?,
        max_request_bytes: args.parse_or("max-request-bytes", defaults.max_request_bytes)?,
        max_connections: args.parse_or("max-connections", defaults.max_connections)?,
        max_inflight_per_conn: args
            .parse_or("max-inflight-per-conn", defaults.max_inflight_per_conn)?,
        max_inflight_per_dataset: args
            .parse_or("max-inflight-per-dataset", defaults.max_inflight_per_dataset)?,
        shed_watermark: args.parse_or("shed-watermark", defaults.shed_watermark)?,
        idle_timeout_ms: args.parse_or("idle-timeout-ms", defaults.idle_timeout_ms)?,
        write_buf_bytes: defaults.write_buf_bytes,
        coordinator: args.switch("coordinator") || !worker_endpoints.is_empty(),
        worker_endpoints,
        dist_segments: args.parse_or("dist-segments", defaults.dist_segments)?,
        health_timeout_ms: args.parse_or("health-timeout-ms", defaults.health_timeout_ms)?,
    };
    let preload = args.str_opt("preload").map(str::to_string);
    args.finish()?;
    corrsh::ensure!(
        !server_cfg.coordinator || !server_cfg.worker_endpoints.is_empty(),
        "serve --coordinator requires --workers-endpoints HOST:PORT[,HOST:PORT...]"
    );
    let state = server::State::new();
    if server_cfg.coordinator {
        let mut dist_cfg = corrsh::engine::DistConfig::default();
        if server_cfg.dist_segments > 0 {
            dist_cfg.segments = server_cfg.dist_segments;
        }
        dist_cfg.health_timeout_ms = server_cfg.health_timeout_ms;
        state.set_distributed(std::sync::Arc::new(corrsh::engine::DistRuntime::new(
            server_cfg.worker_endpoints.clone(),
            dist_cfg,
        )));
        eprintln!(
            "coordinator: fanning registrations out to {} worker(s)",
            server_cfg.worker_endpoints.len()
        );
    }
    if let Some(preset) = preload {
        let cfg = RunConfig::preset(&preset)?.scaled_down(20);
        // prepare:true warms the engine-session cache before the first
        // client query arrives.
        let req = corrsh::util::json::parse(&format!(
            r#"{{"op":"register","name":"{preset}","kind":"{}","n":{},"dim":{},"seed":{},"prepare":true}}"#,
            cfg.dataset_kind.name(),
            cfg.synth.n,
            cfg.synth.dim,
            cfg.synth.seed
        ))?;
        let resp = state.handle(&req);
        eprintln!("preloaded: {resp}");
    }
    server::serve_with(state, &server_cfg)
}

/// `corrsh worker` — a shard-scoring worker process: an ordinary server
/// whose request cap defaults high enough for coordinator fan-in (round-0
/// requests carry whole reference-segment id lists) and which advertises
/// its launch-time shard range through `worker.health` and `metrics`.
/// Workers bind loopback-ephemeral by default; pass `--addr` to place one.
fn cmd_worker(args: &Args) -> Result<()> {
    let defaults = corrsh::config::ServerConfig::default();
    let server_cfg = corrsh::config::ServerConfig {
        addr: args.str_or("addr", "127.0.0.1:0"),
        workers: args.parse_or("workers", defaults.workers)?,
        max_request_bytes: args.parse_or("max-request-bytes", 1 << 28)?,
        ..defaults
    };
    let shards = args.str_opt("shards").map(parse_shards).transpose()?;
    args.finish()?;
    let state = server::State::new();
    state.set_worker_shards(shards);
    if let Some((a, b)) = shards {
        eprintln!("worker: serving shard rows {a}..{b}");
    }
    server::serve_with(state, &server_cfg)
}

/// Parse a `--shards A..B` row range (end-exclusive).
fn parse_shards(s: &str) -> Result<(usize, usize)> {
    let (a, b) = s.split_once("..").context("--shards expects A..B (end-exclusive rows)")?;
    let a: usize = a.trim().parse().with_context(|| format!("--shards start {a:?}"))?;
    let b: usize = b.trim().parse().with_context(|| format!("--shards end {b:?}"))?;
    corrsh::ensure!(a < b, "--shards range {a}..{b} is empty");
    Ok((a, b))
}

/// `corrsh shard <in> <out-dir> [--rows-per-shard N]` — convert an
/// existing dataset file (or re-shard a manifest) into a shard set; or
/// `corrsh shard --kind K --n N --dim D --out DIR` to generate one
/// directly (streaming shard-by-shard past the resident limit, which is
/// how the n = 10⁶ bench datasets are produced).
fn cmd_shard(args: &Args) -> Result<()> {
    let rows_per_shard: usize = args.parse_or("rows-per-shard", 65_536)?;
    corrsh::ensure!(rows_per_shard >= 1, "--rows-per-shard must be >= 1");
    let manifest = if let Some(kind) = args.str_opt("kind") {
        let kind: Kind = kind.parse()?;
        let out = args.str_required("out")?;
        let cfg = corrsh::data::synth::SynthConfig {
            n: args.parse_or("n", 1000)?,
            dim: args.parse_or("dim", 256)?,
            seed: args.parse_or("seed", 0)?,
            ..Default::default()
        };
        args.finish()?;
        kind.write_sharded(&cfg, &out, rows_per_shard)?
    } else {
        let input = args
            .positional
            .first()
            .context("shard: missing input path (corrsh shard <in> <out-dir>)")?;
        let out = args.positional.get(1).context("shard: missing output directory")?;
        args.finish()?;
        corrsh::data::store::shard_file(input, out, rows_per_shard)
            .with_context(|| format!("shard {input}"))?
    };
    let data = corrsh::data::loader::load(&manifest)?;
    eprintln!(
        "wrote {} ({} x {}, {} rows/shard, {})",
        manifest.display(),
        data.n(),
        data.dim(),
        rows_per_shard,
        if data.is_sparse() { "sparse" } else { "dense" }
    );
    Ok(())
}

/// `corrsh kernelinfo` — report which distance micro-kernel the process
/// dispatched (scalar reference vs AVX2/NEON), where the decision came
/// from (auto-detect vs `CORRSH_KERNEL`), and the layout constants the
/// bitwise contract pins (DESIGN.md §14).
fn cmd_kernelinfo(args: &Args) -> Result<()> {
    args.finish()?;
    println!("{}", corrsh::engine::simd::kernel_info());
    Ok(())
}

/// `corrsh lint` — run the token-level invariant analyzer (rules R1–R8,
/// DESIGN.md §16) over the repo tree and exit non-zero on any finding.
/// `--ci` prints the machine-readable JSON report to stdout (CI uploads it
/// as an artifact); `--out FILE` writes the same JSON regardless of mode;
/// the default mode prints human-readable `file:line: [Rn] message` rows.
fn cmd_lint(args: &Args) -> Result<()> {
    let root = args.str_or("root", ".");
    let ci = args.switch("ci");
    let out_path = args.str_opt("out").map(str::to_string);
    args.finish()?;

    let report = corrsh::analysis::lint_root(std::path::Path::new(&root))
        .with_context(|| format!("lint --root {root}"))?;
    corrsh::ensure!(
        report.files_scanned > 0,
        "lint: no .rs files under {root} (expected the corrsh repo root; pass --root)"
    );
    let json = corrsh::util::json::to_string(&report.to_json());
    if let Some(path) = &out_path {
        std::fs::write(path, &json).with_context(|| format!("lint: write {path}"))?;
    }
    if ci {
        println!("{json}");
    } else {
        print!("{}", report.render_text());
    }
    if !report.ok() {
        // Structured output above carries the detail; the error exit is the
        // CI gate (main maps Err to exit code 1).
        corrsh::bail!(
            "lint: {} finding(s) across {} file(s)",
            report.findings.len(),
            report.files_scanned
        );
    }
    Ok(())
}

fn cmd_gen(args: &Args) -> Result<()> {
    let kind: Kind = args.str_required("kind")?.parse()?;
    let n: usize = args.parse_or("n", 1000)?;
    let dim: usize = args.parse_or("dim", 256)?;
    let seed: u64 = args.parse_or("seed", 0)?;
    let out = args.str_required("out")?;
    args.finish()?;
    let cfg = corrsh::data::synth::SynthConfig { n, dim, seed, ..Default::default() };
    let data = kind.generate(&cfg);
    let dense = data.to_dense();
    corrsh::data::loader::save_dense_npy(&out, &dense)
        .with_context(|| format!("write {out}"))?;
    eprintln!("wrote {} ({}x{})", out, dense.n, dense.dim);
    Ok(())
}
