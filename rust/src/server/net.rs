//! Transport: a raw epoll event loop (zero-dep syscalls, like
//! `data/store/reader.rs`) serving the line-delimited protocol.
//!
//! One thread owns every socket: nonblocking accept, per-connection
//! read/write buffers with incremental newline framing ([`proto::Framer`]),
//! request pipelining (many in-flight per connection; v2 responses are
//! id-matched and may return out of order), and backpressure that drops
//! `EPOLLIN` interest on a socket whose write buffer or v1 queue is full.
//! Compute runs on the bounded [`Executor`]; workers serialize wire frames
//! off-loop and hand them back through a completion queue + wake pipe.
//!
//! Admission control (v2 requests): per-connection and per-dataset
//! in-flight quotas, a queue-depth watermark, and the executor's own
//! bounded queue all shed with a structured `overloaded` error instead of
//! stalling the loop. v1 requests are never shed — the legacy contract is
//! serial, in-order responses, so v1 frames queue per connection, execute
//! one at a time, and defer (pause) rather than fail when quotas are hot.
//!
//! On non-Linux (or non-x86_64/aarch64) hosts the same protocol is served
//! by a thread-per-connection blocking fallback; `event_loop_supported()`
//! tells tests and benches which engine is underneath.

use std::net::TcpListener;
use std::sync::Arc;

use crate::config::ServerConfig;
use crate::server::exec::Executor;
use crate::server::ops::State;
use crate::util::error::{Context, Result};
use crate::util::threads;

/// True when this build serves connections from the epoll event loop
/// (Linux on x86_64/aarch64); false means the blocking fallback.
pub fn event_loop_supported() -> bool {
    cfg!(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64")))
}

/// Best-effort: raise the process soft fd limit to the hard cap and return
/// the resulting soft limit. The soak bench opens thousands of sockets in
/// one process; everything else ignores this.
pub fn raise_nofile_limit() -> u64 {
    #[cfg(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64")))]
    {
        sys::raise_nofile_limit()
    }
    #[cfg(not(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64"))))]
    {
        1024
    }
}

/// Serve until a `shutdown` request arrives (e.g. on "127.0.0.1:7878"),
/// with the default server shape.
pub fn serve(state: Arc<State>, addr: &str) -> Result<()> {
    let cfg = ServerConfig { addr: addr.to_string(), ..Default::default() };
    serve_with(state, &cfg)
}

/// Serve with an explicit [`ServerConfig`]. Returns cleanly after a
/// `shutdown` request: in-flight work drains, write buffers flush, and the
/// executor joins.
pub fn serve_with(state: Arc<State>, cfg: &ServerConfig) -> Result<()> {
    let listener =
        TcpListener::bind(&cfg.addr).with_context(|| format!("bind {}", cfg.addr))?;
    eprintln!("corrsh-serve listening on {}", listener.local_addr()?);
    serve_on(state, cfg, listener)
}

/// Bind to an ephemeral port and serve in a background thread (tests/demo).
pub fn serve_background(state: Arc<State>) -> Result<std::net::SocketAddr> {
    serve_background_with(state, &ServerConfig::default())
}

/// `serve_background` with an explicit server shape (the configured
/// `addr` is ignored — the port is always ephemeral).
pub fn serve_background_with(
    state: Arc<State>,
    cfg: &ServerConfig,
) -> Result<std::net::SocketAddr> {
    let listener = TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?;
    let cfg = cfg.clone();
    threads::spawn("corrsh-serve", move || {
        if let Err(e) = serve_on(state, &cfg, listener) {
            eprintln!("server error: {e:#}");
        }
    });
    Ok(addr)
}

fn serve_on(state: Arc<State>, cfg: &ServerConfig, listener: TcpListener) -> Result<()> {
    let exec = Executor::new(state, cfg.workers, cfg.queue_cap);
    #[cfg(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64")))]
    epoll::EventLoop::new(exec.clone(), cfg, listener)?.run()?;
    #[cfg(not(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64"))))]
    blocking::accept_loop(&exec, listener, cfg);
    exec.shutdown();
    Ok(())
}

/// Raw epoll bindings (Linux x86_64/aarch64), following the syscall idiom
/// of `data/store/reader.rs`. `epoll_pwait` is used on both arches because
/// aarch64 Linux has no plain `epoll_wait` syscall.
#[cfg(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64")))]
mod sys {
    use std::io;
    use std::os::fd::RawFd;

    /// Kernel `struct epoll_event`: packed on x86_64 only. Fields must be
    /// read by value — references into a packed struct are ill-formed.
    #[repr(C)]
    #[cfg_attr(target_arch = "x86_64", repr(packed))]
    #[derive(Clone, Copy)]
    pub struct EpollEvent {
        pub events: u32,
        pub data: u64,
    }

    pub const EPOLLIN: u32 = 0x1;
    pub const EPOLLOUT: u32 = 0x4;
    pub const EPOLLERR: u32 = 0x8;
    pub const EPOLLHUP: u32 = 0x10;
    pub const EPOLLET: u32 = 1 << 31;
    pub const EPOLL_CTL_ADD: i32 = 1;
    pub const EPOLL_CTL_MOD: i32 = 3;

    #[cfg(target_arch = "x86_64")]
    mod nr {
        pub const EPOLL_CREATE1: usize = 291;
        pub const EPOLL_CTL: usize = 233;
        pub const EPOLL_PWAIT: usize = 281;
        pub const PRLIMIT64: usize = 302;
    }
    #[cfg(target_arch = "aarch64")]
    mod nr {
        pub const EPOLL_CREATE1: usize = 20;
        pub const EPOLL_CTL: usize = 21;
        pub const EPOLL_PWAIT: usize = 22;
        pub const PRLIMIT64: usize = 261;
    }

    // SAFETY: caller must pass a valid syscall number with argument types
    // and pointer lifetimes matching that syscall's kernel ABI; the asm
    // clobbers only rax/rcx/r11 per the x86_64 Linux convention.
    #[cfg(target_arch = "x86_64")]
    unsafe fn syscall6(
        nr: usize,
        a: usize,
        b: usize,
        c: usize,
        d: usize,
        e: usize,
        f: usize,
    ) -> isize {
        let mut ret: isize = nr as isize;
        std::arch::asm!(
            "syscall",
            inlateout("rax") ret,
            in("rdi") a,
            in("rsi") b,
            in("rdx") c,
            in("r10") d,
            in("r8") e,
            in("r9") f,
            lateout("rcx") _,
            lateout("r11") _,
            options(nostack),
        );
        ret
    }

    // SAFETY: caller must pass a valid syscall number with argument types
    // and pointer lifetimes matching that syscall's kernel ABI; `svc 0`
    // returns in x0 and preserves everything else per the aarch64
    // convention.
    #[cfg(target_arch = "aarch64")]
    unsafe fn syscall6(
        nr: usize,
        a: usize,
        b: usize,
        c: usize,
        d: usize,
        e: usize,
        f: usize,
    ) -> isize {
        let mut ret: isize = a as isize;
        std::arch::asm!(
            "svc 0",
            in("x8") nr,
            inlateout("x0") ret,
            in("x1") b,
            in("x2") c,
            in("x3") d,
            in("x4") e,
            in("x5") f,
            options(nostack),
        );
        ret
    }

    fn check(ret: isize) -> io::Result<isize> {
        if (-4095..0).contains(&ret) {
            Err(io::Error::from_raw_os_error(-ret as i32))
        } else {
            Ok(ret)
        }
    }

    pub fn epoll_create1() -> io::Result<RawFd> {
        // SAFETY: epoll_create1 takes one integer flag and touches no
        // memory; flag = EPOLL_CLOEXEC (== O_CLOEXEC).
        let ret = unsafe { syscall6(nr::EPOLL_CREATE1, 0o2000000, 0, 0, 0, 0, 0) };
        check(ret).map(|fd| fd as RawFd)
    }

    pub fn epoll_ctl(
        epfd: RawFd,
        op: i32,
        fd: RawFd,
        event: Option<&mut EpollEvent>,
    ) -> io::Result<()> {
        let ptr = event.map_or(0, |e| e as *mut EpollEvent as usize);
        // SAFETY: `ptr` is NULL or a live &mut EpollEvent (repr(C), matching
        // the kernel struct); the kernel only reads/writes that one event.
        let ret =
            unsafe { syscall6(nr::EPOLL_CTL, epfd as usize, op as usize, fd as usize, ptr, 0, 0) };
        check(ret).map(|_| ())
    }

    /// `epoll_pwait` with a NULL sigmask; retries on EINTR.
    pub fn epoll_wait(
        epfd: RawFd,
        events: &mut [EpollEvent],
        timeout_ms: i32,
    ) -> io::Result<usize> {
        loop {
            // SAFETY: the events pointer/len name a live &mut [EpollEvent]
            // the kernel fills up to `len` entries of; sigmask is NULL.
            let ret = unsafe {
                syscall6(
                    nr::EPOLL_PWAIT,
                    epfd as usize,
                    events.as_mut_ptr() as usize,
                    events.len(),
                    timeout_ms as usize,
                    0, // sigmask = NULL
                    8, // sigsetsize
                )
            };
            match check(ret) {
                Ok(n) => return Ok(n as usize),
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(e) => return Err(e),
            }
        }
    }

    #[repr(C)]
    struct RLimit64 {
        cur: u64,
        max: u64,
    }

    pub fn raise_nofile_limit() -> u64 {
        const RLIMIT_NOFILE: usize = 7;
        let mut lim = RLimit64 { cur: 0, max: 0 };
        // SAFETY: old_limit points at a live repr(C) RLimit64 the kernel
        // writes; new_limit is NULL (read-only query), pid 0 = self.
        let ret = unsafe {
            syscall6(nr::PRLIMIT64, 0, RLIMIT_NOFILE, 0, &mut lim as *mut RLimit64 as usize, 0, 0)
        };
        if check(ret).is_err() {
            return 1024;
        }
        let want = RLimit64 { cur: lim.max, max: lim.max };
        // SAFETY: new_limit points at a live repr(C) RLimit64 the kernel
        // reads; old_limit is NULL, pid 0 = self.
        let ret = unsafe {
            syscall6(nr::PRLIMIT64, 0, RLIMIT_NOFILE, &want as *const RLimit64 as usize, 0, 0, 0)
        };
        if check(ret).is_ok() {
            lim.max
        } else {
            lim.cur
        }
    }
}

#[cfg(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64")))]
mod epoll {
    use std::collections::{HashMap, VecDeque};
    use std::io::{self, Read, Write};
    use std::net::{TcpListener, TcpStream};
    use std::os::fd::{AsRawFd, FromRawFd, OwnedFd};
    use std::os::unix::net::UnixStream;
    use std::sync::{Arc, Mutex};
    use std::time::{Duration, Instant};

    use super::sys::{self, EpollEvent};
    use crate::config::ServerConfig;
    use crate::server::exec::{Executor, SubmitError};
    use crate::server::proto::{self, Envelope, Frame, Framer, OpError};
    use crate::util::json::{self, Value};

    const TOKEN_LISTENER: u64 = u64::MAX;
    const TOKEN_WAKE: u64 = u64::MAX - 1;
    /// v1 requests queued (not yet submitted) per connection before the
    /// loop stops reading that socket.
    const V1_PENDING_MAX: usize = 32;
    /// Loop tick: bounds idle-sweep latency and shutdown polling.
    const TICK_MS: i32 = 250;
    const IDLE_SWEEP_EVERY: Duration = Duration::from_millis(500);
    const DRAIN_GRACE: Duration = Duration::from_secs(5);

    /// One finished (or partial) wire frame, serialized by an executor
    /// worker, heading back to the loop thread.
    struct Completion {
        token: u64,
        line: String,
        fin: bool,
        /// Dataset quota key to release on `fin` — carried here so the
        /// count is released even if the connection died mid-request.
        dataset: Option<String>,
        v1: bool,
    }

    /// Worker→loop channel: a mutex'd vec plus a wake pipe. The byte is
    /// written only on empty→non-empty so the pipe can't fill up.
    struct Shared {
        completions: Mutex<Vec<Completion>>,
        wake_tx: UnixStream,
    }

    impl Shared {
        fn push(&self, c: Completion) {
            let was_empty = {
                let mut q = crate::util::threads::lock(&self.completions);
                let was = q.is_empty();
                q.push(c);
                was
            };
            if was_empty {
                let _ = (&self.wake_tx).write(&[1u8]);
            }
        }
    }

    /// A v1 queue item: either a request awaiting serial execution, or a
    /// response already shaped on the loop thread (parse errors), held in
    /// line so v1 responses keep arriving in request order.
    enum V1Item {
        Req(Envelope),
        Resolved(Value),
    }

    struct Conn {
        stream: TcpStream,
        token: u64,
        framer: Framer,
        wbuf: Vec<u8>,
        wpos: usize,
        /// Epoll interest mask currently installed for this fd.
        interest: u32,
        /// Requests submitted to the executor, not yet finished.
        in_flight: usize,
        v1_pending: VecDeque<V1Item>,
        /// A v1 request is executing; the next one waits (serial order).
        v1_busy: bool,
        /// Once a connection speaks v2, un-attributable errors (bad JSON,
        /// oversized frames) are shaped as v2 envelopes with `id:null`.
        saw_v2: bool,
        peer_closed: bool,
        last_activity: Instant,
    }

    impl Conn {
        fn new(stream: TcpStream, token: u64, max_request_bytes: usize) -> Self {
            Conn {
                stream,
                token,
                framer: Framer::new(max_request_bytes),
                wbuf: Vec::new(),
                wpos: 0,
                interest: sys::EPOLLIN,
                in_flight: 0,
                v1_pending: VecDeque::new(),
                v1_busy: false,
                saw_v2: false,
                peer_closed: false,
                last_activity: Instant::now(),
            }
        }

        fn queue(&mut self, resp: &Value) {
            let mut line = json::to_string(resp);
            line.push('\n');
            self.wbuf.extend_from_slice(line.as_bytes());
        }

        fn write_pending(&self) -> usize {
            self.wbuf.len() - self.wpos
        }
    }

    /// Write as much of the buffered output as the socket accepts.
    /// Returns false when the connection is dead.
    fn flush_conn(conn: &mut Conn) -> bool {
        while conn.wpos < conn.wbuf.len() {
            match conn.stream.write(&conn.wbuf[conn.wpos..]) {
                Ok(0) => return false,
                Ok(n) => conn.wpos += n,
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(_) => return false,
            }
        }
        if conn.wpos == conn.wbuf.len() {
            conn.wbuf.clear();
            conn.wpos = 0;
        } else if conn.wpos > 64 * 1024 {
            conn.wbuf.drain(..conn.wpos);
            conn.wpos = 0;
        }
        true
    }

    /// Resolved admission-control limits (config defaults applied).
    struct Limits {
        max_request_bytes: usize,
        max_connections: usize,
        max_inflight_per_conn: usize,
        max_inflight_per_dataset: usize,
        shed_watermark: usize,
        idle_timeout: Option<Duration>,
        write_buf_bytes: usize,
    }

    pub(super) struct EventLoop {
        exec: Arc<Executor>,
        limits: Limits,
        epfd: OwnedFd,
        listener: Option<TcpListener>,
        wake_rx: UnixStream,
        shared: Arc<Shared>,
        conns: Vec<Option<Conn>>,
        epochs: Vec<u32>,
        free: Vec<usize>,
        open: usize,
        /// Live per-dataset in-flight counts (admission quota).
        dataset_inflight: HashMap<String, usize>,
        /// All submitted-but-unfinished requests, across live and dead
        /// connections (the drain barrier).
        unfinished: usize,
        /// Connections whose v1 queue should be pumped this iteration.
        v1_retry: Vec<u64>,
        draining: bool,
        drain_deadline: Option<Instant>,
        last_sweep: Instant,
    }

    impl EventLoop {
        pub(super) fn new(
            exec: Arc<Executor>,
            cfg: &ServerConfig,
            listener: TcpListener,
        ) -> io::Result<Self> {
            listener.set_nonblocking(true)?;
            let raw = sys::epoll_create1()?;
            // SAFETY: `raw` is a freshly created epoll fd we exclusively
            // own; OwnedFd takes over closing it exactly once.
            let epfd = unsafe { OwnedFd::from_raw_fd(raw) };
            // Edge-triggered listener: accept drains to WouldBlock, so a
            // full backlog under EMFILE can't busy-spin the loop.
            let mut ev =
                EpollEvent { events: sys::EPOLLIN | sys::EPOLLET, data: TOKEN_LISTENER };
            sys::epoll_ctl(
                epfd.as_raw_fd(),
                sys::EPOLL_CTL_ADD,
                listener.as_raw_fd(),
                Some(&mut ev),
            )?;
            let (wake_tx, wake_rx) = UnixStream::pair()?;
            wake_tx.set_nonblocking(true)?;
            wake_rx.set_nonblocking(true)?;
            let mut ev = EpollEvent { events: sys::EPOLLIN, data: TOKEN_WAKE };
            sys::epoll_ctl(
                epfd.as_raw_fd(),
                sys::EPOLL_CTL_ADD,
                wake_rx.as_raw_fd(),
                Some(&mut ev),
            )?;
            let limits = Limits {
                max_request_bytes: cfg.max_request_bytes.max(1),
                max_connections: cfg.max_connections.max(1),
                max_inflight_per_conn: cfg.max_inflight_per_conn.max(1),
                max_inflight_per_dataset: cfg.max_inflight_per_dataset.max(1),
                shed_watermark: if cfg.shed_watermark == 0 {
                    exec.queue_cap()
                } else {
                    cfg.shed_watermark
                },
                idle_timeout: (cfg.idle_timeout_ms > 0)
                    .then(|| Duration::from_millis(cfg.idle_timeout_ms)),
                write_buf_bytes: cfg.write_buf_bytes.max(1),
            };
            Ok(EventLoop {
                exec,
                limits,
                epfd,
                listener: Some(listener),
                wake_rx,
                shared: Arc::new(Shared { completions: Mutex::new(Vec::new()), wake_tx }),
                conns: Vec::new(),
                epochs: Vec::new(),
                free: Vec::new(),
                open: 0,
                dataset_inflight: HashMap::new(),
                unfinished: 0,
                v1_retry: Vec::new(),
                draining: false,
                drain_deadline: None,
                last_sweep: Instant::now(),
            })
        }

        pub(super) fn run(&mut self) -> io::Result<()> {
            let mut events = vec![EpollEvent { events: 0, data: 0 }; 1024];
            loop {
                let n = sys::epoll_wait(self.epfd.as_raw_fd(), &mut events, TICK_MS)?;
                for ev in &events[..n] {
                    // copy fields out of the (possibly packed) struct
                    let (bits, data) = { (ev.events, ev.data) };
                    match data {
                        TOKEN_LISTENER => self.accept_ready(),
                        TOKEN_WAKE => self.drain_wake(),
                        token => self.conn_ready(token, bits),
                    }
                }
                self.drain_completions();
                self.pump_v1_retries();
                self.sweep_idle();
                if !self.draining && self.exec.state().shutting_down() {
                    self.begin_drain();
                }
                if self.draining && self.drain_complete() {
                    return Ok(());
                }
            }
        }

        fn token_for(&self, slot: usize) -> u64 {
            (slot as u64) | ((self.epochs[slot] as u64) << 32)
        }

        fn take_conn(&mut self, token: u64) -> Option<Conn> {
            let slot = (token & 0xFFFF_FFFF) as usize;
            let conn = self.conns.get_mut(slot)?.take()?;
            if conn.token != token {
                self.conns[slot] = Some(conn);
                return None;
            }
            Some(conn)
        }

        fn retire(&mut self, conn: Conn) {
            let slot = (conn.token & 0xFFFF_FFFF) as usize;
            self.open -= 1;
            self.exec.state().net.connections.dec();
            self.epochs[slot] = self.epochs[slot].wrapping_add(1);
            self.free.push(slot);
            drop(conn);
        }

        /// Flush, maybe close, refresh epoll interest, and return the
        /// connection to its slot.
        fn finish_io(&mut self, mut conn: Conn) {
            if !flush_conn(&mut conn) {
                self.retire(conn);
                return;
            }
            let drained = conn.write_pending() == 0;
            if conn.peer_closed
                && drained
                && conn.in_flight == 0
                && conn.v1_pending.is_empty()
            {
                self.retire(conn);
                return;
            }
            if self.update_interest(&mut conn).is_err() {
                self.retire(conn);
                return;
            }
            let slot = (conn.token & 0xFFFF_FFFF) as usize;
            self.conns[slot] = Some(conn);
        }

        fn update_interest(&self, conn: &mut Conn) -> io::Result<()> {
            let mut desired = 0u32;
            if conn.write_pending() > 0 {
                desired |= sys::EPOLLOUT;
            }
            // Backpressure: stop reading when this connection's output or
            // v1 queue is saturated (or the server is draining).
            let paused = self.draining
                || conn.peer_closed
                || conn.write_pending() > self.limits.write_buf_bytes
                || conn.v1_pending.len() >= V1_PENDING_MAX;
            if !paused {
                desired |= sys::EPOLLIN;
            }
            if desired != conn.interest {
                let mut ev = EpollEvent { events: desired, data: conn.token };
                sys::epoll_ctl(
                    self.epfd.as_raw_fd(),
                    sys::EPOLL_CTL_MOD,
                    conn.stream.as_raw_fd(),
                    Some(&mut ev),
                )?;
                conn.interest = desired;
            }
            Ok(())
        }

        fn accept_ready(&mut self) {
            loop {
                let Some(listener) = &self.listener else { return };
                match listener.accept() {
                    Ok((stream, _)) => {
                        if self.draining {
                            continue; // dropped: we are going away
                        }
                        if self.open >= self.limits.max_connections {
                            // Best-effort structured refusal, then drop.
                            let e = OpError::overloaded(format!(
                                "max_connections ({}) reached",
                                self.limits.max_connections
                            ));
                            let mut line =
                                json::to_string(&proto::wire_error(1, &Value::Null, &e));
                            line.push('\n');
                            let _ = (&stream).write(line.as_bytes());
                            self.exec.state().net.shed.add(1);
                            continue;
                        }
                        if stream.set_nonblocking(true).is_err() {
                            continue;
                        }
                        let _ = stream.set_nodelay(true);
                        let slot = self.free.pop().unwrap_or_else(|| {
                            self.conns.push(None);
                            self.epochs.push(0);
                            self.conns.len() - 1
                        });
                        let token = self.token_for(slot);
                        let mut ev = EpollEvent { events: sys::EPOLLIN, data: token };
                        if sys::epoll_ctl(
                            self.epfd.as_raw_fd(),
                            sys::EPOLL_CTL_ADD,
                            stream.as_raw_fd(),
                            Some(&mut ev),
                        )
                        .is_err()
                        {
                            self.free.push(slot);
                            continue;
                        }
                        self.conns[slot] =
                            Some(Conn::new(stream, token, self.limits.max_request_bytes));
                        self.open += 1;
                        let net = &self.exec.state().net;
                        net.accepted.add(1);
                        net.connections.inc();
                    }
                    Err(e) if e.kind() == io::ErrorKind::WouldBlock => return,
                    Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                    Err(e) => {
                        eprintln!("accept error: {e}");
                        return;
                    }
                }
            }
        }

        fn drain_wake(&mut self) {
            let mut buf = [0u8; 256];
            loop {
                match (&self.wake_rx).read(&mut buf) {
                    Ok(0) => return,
                    Ok(_) => continue,
                    Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                    Err(_) => return,
                }
            }
        }

        fn conn_ready(&mut self, token: u64, bits: u32) {
            let Some(mut conn) = self.take_conn(token) else { return };
            if bits & (sys::EPOLLERR | sys::EPOLLHUP) != 0 {
                self.retire(conn);
                return;
            }
            if bits & sys::EPOLLIN != 0 && !self.read_ready(&mut conn) {
                self.retire(conn);
                return;
            }
            self.finish_io(conn);
        }

        /// One read per readiness event (level-triggered re-arms for the
        /// rest), then frame/parse/dispatch everything it completed.
        fn read_ready(&mut self, conn: &mut Conn) -> bool {
            let mut buf = [0u8; 16 * 1024];
            loop {
                match conn.stream.read(&mut buf) {
                    Ok(0) => {
                        conn.peer_closed = true;
                        break;
                    }
                    Ok(n) => {
                        conn.last_activity = Instant::now();
                        conn.framer.push(&buf[..n]);
                        break;
                    }
                    Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
                    Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                    Err(_) => return false,
                }
            }
            self.process_frames(conn);
            true
        }

        fn process_frames(&mut self, conn: &mut Conn) {
            while let Some(frame) = conn.framer.next_frame() {
                match frame {
                    Frame::Line(line) => match proto::parse_request(&line) {
                        Ok(env) if env.v >= 2 => {
                            conn.saw_v2 = true;
                            self.admit_v2(conn, env);
                        }
                        Ok(env) => {
                            conn.v1_pending.push_back(V1Item::Req(env));
                        }
                        Err(pe) => {
                            // Parse failures are answered on the loop
                            // thread and (for v1) held in queue order; the
                            // State request/error counters are untouched,
                            // matching the old blocking server.
                            let v = if pe.v >= 2 || conn.saw_v2 { 2 } else { 1 };
                            let resp = proto::wire_error(v, &pe.id, &pe.err);
                            if v >= 2 {
                                conn.queue(&resp);
                            } else {
                                conn.v1_pending.push_back(V1Item::Resolved(resp));
                            }
                        }
                    },
                    Frame::Oversized { len } => {
                        self.exec.state().net.oversized.add(1);
                        let e = OpError::bad_request(format!(
                            "request of {len} bytes exceeds max_request_bytes ({})",
                            self.limits.max_request_bytes
                        ));
                        let v = if conn.saw_v2 { 2 } else { 1 };
                        let resp = proto::wire_error(v, &Value::Null, &e);
                        if v >= 2 {
                            conn.queue(&resp);
                        } else {
                            conn.v1_pending.push_back(V1Item::Resolved(resp));
                        }
                    }
                    Frame::Invalid => {
                        let e = OpError::bad_request("request is not valid UTF-8");
                        let v = if conn.saw_v2 { 2 } else { 1 };
                        let resp = proto::wire_error(v, &Value::Null, &e);
                        if v >= 2 {
                            conn.queue(&resp);
                        } else {
                            conn.v1_pending.push_back(V1Item::Resolved(resp));
                        }
                    }
                }
            }
            self.pump_v1(conn);
        }

        fn completion_cb(
            &self,
            token: u64,
            dataset: Option<String>,
            v1: bool,
        ) -> Box<dyn FnMut(Value, bool) + Send> {
            let shared = self.shared.clone();
            Box::new(move |frame, fin| {
                let mut line = json::to_string(&frame);
                line.push('\n');
                shared.push(Completion { token, line, fin, dataset: dataset.clone(), v1 });
            })
        }

        fn book_submit(&mut self, conn: &mut Conn, dataset: Option<String>) {
            conn.in_flight += 1;
            self.unfinished += 1;
            self.exec.state().net.in_flight.inc();
            if let Some(ds) = dataset {
                *self.dataset_inflight.entry(ds).or_insert(0) += 1;
            }
        }

        fn dataset_saturated(&self, dataset: Option<&String>) -> bool {
            dataset.is_some_and(|ds| {
                self.dataset_inflight.get(ds).copied().unwrap_or(0)
                    >= self.limits.max_inflight_per_dataset
            })
        }

        /// v2 admission: quotas and watermarks shed with structured
        /// `overloaded` errors; accepted requests pipeline freely.
        fn admit_v2(&mut self, conn: &mut Conn, env: Envelope) {
            let state = self.exec.state().clone();
            if self.draining || state.shutting_down() {
                conn.queue(&proto::wire_error(2, &env.id, &OpError::shutting_down()));
                return;
            }
            if conn.in_flight >= self.limits.max_inflight_per_conn {
                state.net.shed.add(1);
                let e = OpError::overloaded(format!(
                    "per-connection in-flight quota ({}) exceeded",
                    self.limits.max_inflight_per_conn
                ));
                conn.queue(&proto::wire_error(2, &env.id, &e));
                return;
            }
            let dataset = proto::dataset_of(&env).map(str::to_string);
            if self.dataset_saturated(dataset.as_ref()) {
                state.net.shed.add(1);
                let e = OpError::overloaded(format!(
                    "dataset {:?} in-flight quota ({}) exceeded",
                    dataset.as_deref().unwrap_or(""),
                    self.limits.max_inflight_per_dataset
                ));
                conn.queue(&proto::wire_error(2, &env.id, &e));
                return;
            }
            if self.exec.queue_depth() as usize >= self.limits.shed_watermark {
                state.net.shed.add(1);
                let e = OpError::overloaded(format!(
                    "queue depth watermark ({}) reached",
                    self.limits.shed_watermark
                ));
                conn.queue(&proto::wire_error(2, &env.id, &e));
                return;
            }
            let cb = self.completion_cb(conn.token, dataset.clone(), false);
            match self.exec.try_submit(env, cb) {
                Ok(()) => self.book_submit(conn, dataset),
                Err((env, SubmitError::Overloaded)) => {
                    state.net.shed.add(1);
                    let e = OpError::overloaded("executor queue full");
                    conn.queue(&proto::wire_error(2, &env.id, &e));
                }
                Err((env, SubmitError::ShuttingDown)) => {
                    conn.queue(&proto::wire_error(2, &env.id, &OpError::shutting_down()));
                }
            }
        }

        /// v1 pump: submit the queue head when idle. v1 requests are never
        /// shed — on quota or queue pressure the head is deferred and the
        /// connection's reads pause instead.
        fn pump_v1(&mut self, conn: &mut Conn) {
            while !conn.v1_busy {
                let Some(item) = conn.v1_pending.pop_front() else { break };
                let env = match item {
                    V1Item::Resolved(resp) => {
                        conn.queue(&resp);
                        continue;
                    }
                    V1Item::Req(env) => env,
                };
                if self.draining || self.exec.state().shutting_down() {
                    conn.queue(&proto::wire_error(1, &Value::Null, &OpError::shutting_down()));
                    continue;
                }
                let dataset = proto::dataset_of(&env).map(str::to_string);
                if self.dataset_saturated(dataset.as_ref()) {
                    conn.v1_pending.push_front(V1Item::Req(env));
                    self.v1_retry.push(conn.token);
                    break;
                }
                let cb = self.completion_cb(conn.token, dataset.clone(), true);
                match self.exec.try_submit(env, cb) {
                    Ok(()) => {
                        conn.v1_busy = true;
                        self.book_submit(conn, dataset);
                    }
                    Err((env, SubmitError::Overloaded)) => {
                        conn.v1_pending.push_front(V1Item::Req(env));
                        self.v1_retry.push(conn.token);
                        break;
                    }
                    Err((_, SubmitError::ShuttingDown)) => {
                        conn.queue(&proto::wire_error(
                            1,
                            &Value::Null,
                            &OpError::shutting_down(),
                        ));
                    }
                }
            }
        }

        fn pump_v1_retries(&mut self) {
            if self.v1_retry.is_empty() {
                return;
            }
            let tokens = std::mem::take(&mut self.v1_retry);
            for token in tokens {
                let Some(mut conn) = self.take_conn(token) else { continue };
                self.pump_v1(&mut conn);
                self.finish_io(conn);
            }
        }

        fn drain_completions(&mut self) {
            let items =
                std::mem::take(&mut *crate::util::threads::lock(&self.shared.completions));
            for c in items {
                if c.fin {
                    self.unfinished = self.unfinished.saturating_sub(1);
                    self.exec.state().net.in_flight.dec();
                    if let Some(ds) = &c.dataset {
                        if let Some(count) = self.dataset_inflight.get_mut(ds) {
                            *count = count.saturating_sub(1);
                            if *count == 0 {
                                self.dataset_inflight.remove(ds);
                            }
                        }
                    }
                }
                let Some(mut conn) = self.take_conn(c.token) else { continue };
                conn.wbuf.extend_from_slice(c.line.as_bytes());
                if c.fin {
                    conn.in_flight = conn.in_flight.saturating_sub(1);
                    conn.last_activity = Instant::now();
                    if c.v1 {
                        conn.v1_busy = false;
                        self.v1_retry.push(c.token);
                    }
                }
                self.finish_io(conn);
            }
        }

        fn sweep_idle(&mut self) {
            let Some(timeout) = self.limits.idle_timeout else { return };
            if self.last_sweep.elapsed() < IDLE_SWEEP_EVERY {
                return;
            }
            self.last_sweep = Instant::now();
            let mut stale = Vec::new();
            for conn in self.conns.iter().flatten() {
                if conn.in_flight == 0
                    && conn.v1_pending.is_empty()
                    && conn.write_pending() == 0
                    && conn.last_activity.elapsed() >= timeout
                {
                    stale.push(conn.token);
                }
            }
            for token in stale {
                if let Some(conn) = self.take_conn(token) {
                    self.exec.state().net.idle_closed.add(1);
                    self.retire(conn);
                }
            }
        }

        /// First tick after a `shutdown` request: stop accepting (dropping
        /// the listener refuses new connects and resets the backlog), then
        /// answer queued v1 requests with `shutting_down`.
        fn begin_drain(&mut self) {
            self.draining = true;
            self.drain_deadline = Some(Instant::now() + DRAIN_GRACE);
            self.listener = None;
            for conn in self.conns.iter().flatten() {
                self.v1_retry.push(conn.token);
            }
        }

        /// Done when every submitted request finished and every response
        /// byte was flushed — or the grace period expired.
        fn drain_complete(&mut self) -> bool {
            if self.drain_deadline.is_some_and(|d| Instant::now() >= d) {
                return true;
            }
            self.unfinished == 0
                && self
                    .conns
                    .iter()
                    .flatten()
                    .all(|c| c.write_pending() == 0 && c.v1_pending.is_empty())
        }
    }
}

/// Thread-per-connection fallback for hosts without the raw epoll
/// bindings: same framing, size cap, and v1/v2 envelopes; no pipelining
/// (requests on one socket execute serially) and no partial frames.
#[cfg(not(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64"))))]
mod blocking {
    use std::io::{Read, Write};
    use std::net::{TcpListener, TcpStream};
    use std::sync::Arc;

    use crate::config::ServerConfig;
    use crate::server::exec::Executor;
    use crate::server::proto::{self, Frame, Framer, OpError};
    use crate::util::json::{self, Value};

    pub(super) fn accept_loop(exec: &Arc<Executor>, listener: TcpListener, cfg: &ServerConfig) {
        let max_request_bytes = cfg.max_request_bytes.max(1);
        for stream in listener.incoming() {
            if exec.state().shutting_down() {
                break;
            }
            match stream {
                Ok(s) => {
                    let e = exec.clone();
                    crate::util::threads::spawn("corrsh-conn", move || {
                        client_loop(e, s, max_request_bytes)
                    });
                }
                Err(e) => eprintln!("accept error: {e}"),
            }
        }
    }

    fn client_loop(exec: Arc<Executor>, mut stream: TcpStream, max_request_bytes: usize) {
        let state = exec.state().clone();
        state.net.accepted.add(1);
        state.net.connections.inc();
        // Our side of the connection = the listener's address; used to
        // wake the accept loop after a shutdown request.
        let local = stream.local_addr().ok();
        let mut framer = Framer::new(max_request_bytes);
        let mut buf = [0u8; 16 * 1024];
        let mut saw_v2 = false;
        'outer: loop {
            let n = match stream.read(&mut buf) {
                Ok(0) | Err(_) => break,
                Ok(n) => n,
            };
            framer.push(&buf[..n]);
            while let Some(frame) = framer.next_frame() {
                let resp = match frame {
                    Frame::Line(line) => match proto::parse_request(&line) {
                        Ok(env) => {
                            saw_v2 |= env.v >= 2;
                            exec.submit_env(env)
                        }
                        Err(pe) => {
                            let v = if pe.v >= 2 || saw_v2 { 2 } else { 1 };
                            proto::wire_error(v, &pe.id, &pe.err)
                        }
                    },
                    Frame::Oversized { len } => {
                        state.net.oversized.add(1);
                        let e = OpError::bad_request(format!(
                            "request of {len} bytes exceeds max_request_bytes ({max_request_bytes})"
                        ));
                        proto::wire_error(if saw_v2 { 2 } else { 1 }, &Value::Null, &e)
                    }
                    Frame::Invalid => {
                        let e = OpError::bad_request("request is not valid UTF-8");
                        proto::wire_error(if saw_v2 { 2 } else { 1 }, &Value::Null, &e)
                    }
                };
                let mut out = json::to_string(&resp);
                out.push('\n');
                if stream.write_all(out.as_bytes()).is_err() {
                    break 'outer;
                }
                if state.shutting_down() {
                    if let Some(addr) = local {
                        let _ = TcpStream::connect(addr);
                    }
                    break 'outer;
                }
            }
        }
        state.net.connections.dec();
    }
}

#[cfg(test)]
mod tests {
    use std::io::{BufRead, BufReader, Write};
    use std::net::TcpStream;

    use super::*;
    use crate::util::json::{self, Value};

    fn req(s: &str) -> Value {
        json::parse(s).unwrap()
    }

    fn register_toy(state: &State, name: &str) {
        let r = state.handle(&req(&format!(
            r#"{{"op":"register","name":"{name}","kind":"gaussian","n":200,"dim":8,"seed":4}}"#
        )));
        assert_eq!(r.get("ok").as_bool(), Some(true), "register failed: {r}");
    }

    #[test]
    #[cfg_attr(miri, ignore)] // binds a real TCP socket + raw epoll syscalls
    fn tcp_roundtrip() {
        let state = State::new();
        state.handle(&req(
            r#"{"op":"register","name":"t","kind":"gaussian","n":100,"dim":4,"seed":0}"#,
        ));
        let addr = serve_background(state).unwrap();
        let mut sock = TcpStream::connect(addr).unwrap();
        sock.write_all(
            b"{\"op\":\"ping\"}\nnot json\n{\"op\":\"medoid\",\"dataset\":\"t\",\"seed\":3}\n",
        )
        .unwrap();
        let mut reader = BufReader::new(sock.try_clone().unwrap());
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("pong"));
        line.clear();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("bad json"));
        line.clear();
        reader.read_line(&mut line).unwrap();
        let resp = json::parse(line.trim()).unwrap();
        assert_eq!(resp.get("ok").as_bool(), Some(true));
        assert_eq!(resp.get("medoid").as_usize(), Some(0));
    }

    #[test]
    #[cfg_attr(miri, ignore)] // binds a real TCP socket + raw epoll syscalls
    fn tcp_concurrent_clients_are_deterministic_per_seed() {
        // ≥4 concurrent clients, each with its own seed; every response
        // must equal the single-threaded reference answer for that seed.
        let reference = State::new();
        register_toy(&reference, "toy");
        let mut expect = Vec::new();
        for seed in 0u64..4 {
            let r = reference.handle(&req(&format!(
                r#"{{"op":"medoid","dataset":"toy","pulls_per_arm":48,"seed":{seed}}}"#
            )));
            expect.push((r.get("medoid").as_usize().unwrap(), r.get("pulls").as_u64().unwrap()));
        }

        let state = State::new();
        register_toy(&state, "toy");
        let cfg = crate::config::ServerConfig { workers: 4, queue_cap: 8, ..Default::default() };
        let addr = serve_background_with(state, &cfg).unwrap();
        std::thread::scope(|s| {
            for (seed, (medoid, pulls)) in expect.iter().enumerate() {
                s.spawn(move || {
                    let mut sock = TcpStream::connect(addr).unwrap();
                    let mut reader = BufReader::new(sock.try_clone().unwrap());
                    let mut line = String::new();
                    for _ in 0..3 {
                        sock.write_all(
                            format!(
                                "{{\"op\":\"medoid\",\"dataset\":\"toy\",\
                                 \"pulls_per_arm\":48,\"seed\":{seed}}}\n"
                            )
                            .as_bytes(),
                        )
                        .unwrap();
                        line.clear();
                        reader.read_line(&mut line).unwrap();
                        let resp = json::parse(line.trim()).unwrap();
                        assert_eq!(resp.get("ok").as_bool(), Some(true), "{resp}");
                        assert_eq!(resp.get("medoid").as_usize(), Some(*medoid), "seed {seed}");
                        assert_eq!(resp.get("pulls").as_u64(), Some(*pulls), "seed {seed}");
                    }
                });
            }
        });
    }

    #[test]
    #[cfg_attr(miri, ignore)] // binds a real TCP socket + raw epoll syscalls
    fn tcp_shutdown_op_stops_the_server() {
        let state = State::new();
        let addr = serve_background(state.clone()).unwrap();
        let mut sock = TcpStream::connect(addr).unwrap();
        let mut reader = BufReader::new(sock.try_clone().unwrap());
        sock.write_all(b"{\"op\":\"shutdown\"}\n").unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("shutting_down"));
        assert!(state.shutting_down());
        // The event loop drains and the listener is dropped: within a
        // bounded window new connections must stop being served.
        let mut stopped = false;
        for _ in 0..100 {
            match TcpStream::connect(addr) {
                Err(_) => {
                    stopped = true;
                    break;
                }
                Ok(mut probe) => {
                    // Connection may still land in the accept backlog; a
                    // served probe would get a response, an unserved one
                    // gets EOF.
                    let _ = probe.write_all(b"{\"op\":\"ping\"}\n");
                    let mut r = BufReader::new(probe);
                    let mut l = String::new();
                    if matches!(r.read_line(&mut l), Ok(0)) {
                        stopped = true;
                        break;
                    }
                }
            }
            std::thread::sleep(std::time::Duration::from_millis(20));
        }
        assert!(stopped, "server kept serving after shutdown op");
    }
}
