//! Bounded-queue request executor: a fixed set of workers drains a
//! capacity-capped queue of parsed request envelopes. Transport code only
//! frames bytes and enqueues — heavy work (engine queries, which
//! themselves fan out on the worker pool) happens on executor workers, so
//! a burst of clients applies backpressure instead of spawning a compute
//! avalanche.
//!
//! Two submission paths:
//! - [`Executor::submit`] / [`Executor::submit_env`] block the calling
//!   thread until the response is ready (the blocking fallback server, the
//!   CLI preload, benches).
//! - [`Executor::try_submit`] never blocks: it enqueues with a completion
//!   callback, or returns the envelope with a [`SubmitError`] so the event
//!   loop can shape a structured `overloaded` / `shutting_down` response.
//!
//! Workers serialize responses to wire shape themselves (envelope +
//! streaming partial frames), keeping JSON work off the event-loop thread.

use std::collections::VecDeque;
use std::sync::atomic::Ordering;
use std::sync::{Arc, Condvar, Mutex};

use crate::metrics::Gauge;
use crate::server::ops::State;
use crate::server::proto::{self, Envelope, OpError};
use crate::util::json::Value;
use crate::util::threads;

/// Why a [`Executor::try_submit`] was refused.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SubmitError {
    /// The bounded queue is full.
    Overloaded,
    /// A `shutdown` request has been accepted.
    ShuttingDown,
}

/// Where a finished (or streaming) wire frame goes. `fin` marks the final
/// frame for the request.
pub(crate) enum Responder {
    /// Blocking caller: parked on the slot. Partial frames are dropped —
    /// a blocking call site has nowhere to deliver them early.
    Slot(Arc<ResponseSlot>),
    /// Event-loop caller: frames are handed to the callback as they are
    /// produced (off-loop serialization happens before the call).
    Callback(Box<dyn FnMut(Value, bool) + Send>),
}

impl Responder {
    fn send(&mut self, frame: Value, fin: bool) {
        match self {
            Responder::Slot(slot) => {
                if fin {
                    slot.fill(frame);
                }
            }
            Responder::Callback(cb) => cb(frame, fin),
        }
    }
}

/// One queued envelope plus where its frames go.
struct ExecJob {
    env: Envelope,
    responder: Responder,
}

#[derive(Default)]
pub(crate) struct ResponseSlot {
    value: Mutex<Option<Value>>,
    ready: Condvar,
}

impl ResponseSlot {
    fn fill(&self, v: Value) {
        *threads::lock(&self.value) = Some(v);
        self.ready.notify_all();
    }

    fn wait(&self) -> Value {
        let mut v = threads::lock(&self.value);
        loop {
            if let Some(val) = v.take() {
                return val;
            }
            v = threads::wait(&self.ready, v);
        }
    }
}

struct ExecQueue {
    jobs: VecDeque<ExecJob>,
    shutdown: bool,
}

struct ExecShared {
    queue: Mutex<ExecQueue>,
    /// Workers wait here for jobs.
    ready: Condvar,
    /// Blocking submitters wait here while the bounded queue is full.
    space: Condvar,
    cap: usize,
    depth: Gauge,
}

/// The bounded request executor (see module docs).
pub struct Executor {
    state: Arc<State>,
    shared: Arc<ExecShared>,
    workers: Mutex<Vec<std::thread::JoinHandle<()>>>,
}

impl Executor {
    /// `workers == 0` means `threads::default_threads()`.
    pub fn new(state: Arc<State>, workers: usize, queue_cap: usize) -> Arc<Self> {
        let workers = if workers == 0 { threads::default_threads() } else { workers };
        let shared = Arc::new(ExecShared {
            queue: Mutex::new(ExecQueue { jobs: VecDeque::new(), shutdown: false }),
            ready: Condvar::new(),
            space: Condvar::new(),
            cap: queue_cap.max(1),
            depth: Gauge::new(),
        });
        let handles = (0..workers)
            .map(|i| {
                let state = state.clone();
                let shared = shared.clone();
                threads::spawn(&format!("corrsh-exec-{i}"), move || {
                    exec_worker(state, shared, workers)
                })
            })
            .collect();
        Arc::new(Executor { state, shared, workers: Mutex::new(handles) })
    }

    pub fn state(&self) -> &Arc<State> {
        &self.state
    }

    pub fn queue_depth(&self) -> u64 {
        self.shared.depth.get()
    }

    pub fn queue_cap(&self) -> usize {
        self.shared.cap
    }

    pub fn workers(&self) -> usize {
        threads::lock(&self.workers).len()
    }

    /// Submit one bare v1 request object and block for its flattened
    /// response (the legacy call surface; benches and tests use it).
    pub fn submit(&self, req: Value) -> Value {
        self.submit_env(proto::v1_envelope(&req))
    }

    /// Submit one envelope and block for its final wire frame. Applies
    /// backpressure (blocks) while the bounded queue is full; after
    /// shutdown, returns the shaped error immediately.
    pub fn submit_env(&self, env: Envelope) -> Value {
        let slot = Arc::new(ResponseSlot::default());
        {
            let mut q = threads::lock(&self.shared.queue);
            loop {
                if q.shutdown {
                    return proto::wire_final(&env, Err(OpError::shutting_down()));
                }
                if q.jobs.len() < self.shared.cap {
                    break;
                }
                q = threads::wait(&self.shared.space, q);
            }
            q.jobs.push_back(ExecJob { env, responder: Responder::Slot(slot.clone()) });
            self.shared.depth.inc();
        }
        self.shared.ready.notify_one();
        slot.wait()
    }

    /// Non-blocking submission for the event loop: enqueue with a frame
    /// callback, or hand the envelope back with the refusal reason so the
    /// caller can shape the load-shed response itself.
    pub(crate) fn try_submit(
        &self,
        env: Envelope,
        cb: Box<dyn FnMut(Value, bool) + Send>,
    ) -> Result<(), (Envelope, SubmitError)> {
        {
            let mut q = threads::lock(&self.shared.queue);
            if q.shutdown {
                return Err((env, SubmitError::ShuttingDown));
            }
            if q.jobs.len() >= self.shared.cap {
                return Err((env, SubmitError::Overloaded));
            }
            q.jobs.push_back(ExecJob { env, responder: Responder::Callback(cb) });
            self.shared.depth.inc();
        }
        self.shared.ready.notify_one();
        Ok(())
    }

    /// Stop accepting new work, drain already-queued requests, join the
    /// workers. Idempotent.
    pub fn shutdown(&self) {
        threads::lock(&self.shared.queue).shutdown = true;
        self.shared.ready.notify_all();
        self.shared.space.notify_all();
        let handles: Vec<_> = threads::lock(&self.workers).drain(..).collect();
        for h in handles {
            let _ = h.join();
        }
    }
}

fn exec_worker(state: Arc<State>, shared: Arc<ExecShared>, workers: usize) {
    let mut q = threads::lock(&shared.queue);
    loop {
        match q.jobs.pop_front() {
            Some(mut job) => {
                shared.depth.dec();
                drop(q);
                shared.space.notify_one();
                run_job(&state, &shared, workers, &mut job);
                q = threads::lock(&shared.queue);
            }
            None if q.shutdown => return,
            None => q = threads::wait(&shared.ready, q),
        }
    }
}

fn run_job(state: &State, shared: &ExecShared, workers: usize, job: &mut ExecJob) {
    let env: &Envelope = &job.env;
    let responder = &mut job.responder;
    let mut seq = 0u64;
    // A panicking handler must neither kill this worker nor leave the
    // caller without a final frame.
    let outcome = {
        let streaming = env.v >= 2;
        let mut sink = |payload: Value| {
            // Partial frames are v2-only: v1 clients read responses in
            // order and would misparse interleaved frames.
            if streaming {
                responder.send(proto::wire_partial(env, seq, payload), false);
                seq += 1;
            }
        };
        std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            state.execute(env, &mut sink)
        }))
    };
    let mut result = outcome.unwrap_or_else(|_| {
        state.errors.fetch_add(1, Ordering::Relaxed);
        Err(OpError::internal("internal error: request handler panicked"))
    });
    // Executor-level numbers are merged here (the pure State doesn't know
    // about queues).
    if env.op == "metrics" {
        if let Ok(Value::Object(obj)) = &mut result {
            obj.insert(
                "executor".to_string(),
                Value::from_pairs(vec![
                    ("queue_depth", shared.depth.get().into()),
                    ("queue_cap", shared.cap.into()),
                    ("workers", workers.into()),
                ]),
            );
        }
    }
    responder.send(proto::wire_final(env, result), true);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json;

    fn req(s: &str) -> Value {
        json::parse(s).unwrap()
    }

    fn register_toy(state: &State, name: &str) {
        let r = state.handle(&req(&format!(
            r#"{{"op":"register","name":"{name}","kind":"gaussian","n":200,"dim":8,"seed":4}}"#
        )));
        assert_eq!(r.get("ok").as_bool(), Some(true), "register failed: {r}");
    }

    #[test]
    fn executor_roundtrip_and_shutdown() {
        let state = State::new();
        register_toy(&state, "toy");
        let exec = Executor::new(state, 2, 4);
        assert_eq!(exec.workers(), 2);
        let r = exec.submit(req(r#"{"op":"ping"}"#));
        assert_eq!(r.get("pong").as_bool(), Some(true));
        let r = exec.submit(req(r#"{"op":"medoid","dataset":"toy","seed":1}"#));
        assert_eq!(r.get("ok").as_bool(), Some(true));
        // metrics through the executor gains the executor sub-object
        let m = exec.submit(req(r#"{"op":"metrics"}"#));
        assert_eq!(m.get("executor").get("queue_cap").as_usize(), Some(4));
        assert_eq!(m.get("executor").get("workers").as_usize(), Some(2));
        assert_eq!(m.get("executor").get("queue_depth").as_u64(), Some(0));
        exec.shutdown();
        let r = exec.submit(req(r#"{"op":"ping"}"#));
        assert_eq!(r.get("ok").as_bool(), Some(false));
        assert!(r.get("error").as_str().unwrap().contains("shutting down"));
        exec.shutdown(); // idempotent
    }

    #[test]
    fn executor_handles_concurrent_submitters_with_tiny_queue() {
        let state = State::new();
        let exec = Executor::new(state, 1, 1);
        std::thread::scope(|s| {
            for _ in 0..6 {
                let exec = &exec;
                s.spawn(move || {
                    for _ in 0..10 {
                        let r = exec.submit(json::parse(r#"{"op":"ping"}"#).unwrap());
                        assert_eq!(r.get("pong").as_bool(), Some(true));
                    }
                });
            }
        });
        assert_eq!(exec.queue_depth(), 0);
        assert_eq!(exec.state().requests.load(Ordering::Relaxed), 60);
        exec.shutdown();
    }

    #[test]
    fn v2_envelopes_round_trip_and_stream_partials() {
        let state = State::new();
        register_toy(&state, "toy");
        let exec = Executor::new(state, 1, 8);

        // blocking v2 submission: enveloped final, partials dropped
        let env = proto::parse_request(
            r#"{"v":2,"id":42,"op":"medoid","params":{"dataset":"toy","seed":1,"stream":true}}"#,
        )
        .unwrap();
        let r = exec.submit_env(env);
        assert_eq!(r.get("id").as_u64(), Some(42));
        assert_eq!(r.get("ok").as_bool(), Some(true));
        assert_eq!(r.get("result").get("medoid").as_usize(), Some(0));

        // callback v2 submission: partial frames precede the final one
        let (tx, rx) = std::sync::mpsc::channel::<(Value, bool)>();
        let env = proto::parse_request(
            r#"{"v":2,"id":7,"op":"medoid","params":{"dataset":"toy","seed":1,"stream":true}}"#,
        )
        .unwrap();
        exec.try_submit(env, Box::new(move |frame, fin| tx.send((frame, fin)).unwrap()))
            .expect("queue has room");
        let mut frames = Vec::new();
        loop {
            let (frame, fin) = rx.recv().unwrap();
            frames.push(frame);
            if fin {
                break;
            }
        }
        assert!(frames.len() >= 2, "expected partial frames, got {}", frames.len());
        for (i, f) in frames[..frames.len() - 1].iter().enumerate() {
            assert_eq!(f.get("partial").as_bool(), Some(true));
            assert_eq!(f.get("seq").as_u64(), Some(i as u64));
            assert_eq!(f.get("id").as_u64(), Some(7));
            assert!(f.get("result").get("survivors").as_u64().is_some());
        }
        let last = frames.last().unwrap();
        assert!(matches!(last.get("partial"), Value::Null));
        assert_eq!(last.get("result").get("medoid").as_usize(), Some(0));

        // after shutdown, try_submit refuses with the reason
        exec.shutdown();
        let env = proto::parse_request(r#"{"v":2,"id":1,"op":"ping"}"#).unwrap();
        let err = exec.try_submit(env, Box::new(|_, _| {})).unwrap_err();
        assert_eq!(err.1, SubmitError::ShuttingDown);
        assert_eq!(err.0.op, "ping");
    }
}
