//! Request handlers: the dataset registry, the prepared-engine session
//! cache, and the pure envelope→result dispatch. No sockets, no queues —
//! [`State::execute`] is request→response (plus an optional stream of
//! partial-result payloads), so the whole op surface is unit-testable
//! without I/O.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::bandits::MedoidAlgorithm;
use crate::config::{AlgoConfig, KMedoidsConfig};
use crate::data::synth::{Kind, SynthConfig};
use crate::data::Data;
use crate::distance::Metric;
use crate::engine::distributed::bits_value;
use crate::engine::{DistRuntime, EngineCache, NativeEngine};
use crate::kmedoids::ClusteringAlgorithm;
use crate::metrics::{Counter, Gauge};
use crate::server::proto::{self, Envelope, OpError};
use crate::util::error::{Context, Result};
use crate::util::json::Value;
use crate::util::rng::Rng;
use crate::util::threads;

struct Entry {
    data: Arc<Data>,
    metric: Metric,
    /// Monotone registry counter for this binding of the name to data —
    /// part of the engine-cache key, so a re-register racing an in-flight
    /// query can never leave a stale session serving the new name.
    generation: u64,
}

/// Transport-layer counters, owned by [`State`] so the `metrics` op can
/// export them without the pure op layer knowing about sockets.
#[derive(Default)]
pub struct NetStats {
    /// Connections accepted over the lifetime of the process.
    pub accepted: Counter,
    /// Currently open connections.
    pub connections: Gauge,
    /// Requests admitted but not yet answered (event-loop servers only).
    pub in_flight: Gauge,
    /// Requests answered `overloaded` by admission control.
    pub shed: Counter,
    /// Connections closed by the idle timeout.
    pub idle_closed: Counter,
    /// Frames rejected by the request size cap.
    pub oversized: Counter,
    /// Requests that arrived in the legacy v1 shape.
    pub v1_requests: Counter,
}

impl NetStats {
    fn to_value(&self) -> Value {
        Value::from_pairs(vec![
            ("accepted", self.accepted.get().into()),
            ("connections", self.connections.get().into()),
            ("in_flight", self.in_flight.get().into()),
            ("shed", self.shed.get().into()),
            ("idle_closed", self.idle_closed.get().into()),
            ("oversized", self.oversized.get().into()),
            ("v1_requests", self.v1_requests.get().into()),
        ])
    }
}

/// Shared server state: the dataset registry, the prepared-engine session
/// cache, and request counters.
#[derive(Default)]
pub struct State {
    datasets: Mutex<HashMap<String, Arc<Entry>>>,
    cache: EngineCache,
    generation: AtomicU64,
    pub requests: AtomicU64,
    pub errors: AtomicU64,
    pulls: Counter,
    /// Completed `kmedoids` runs (the clustering workload's op counter).
    kmedoids_runs: Counter,
    /// Completed `worker.pull` ops (the distributed data-plane counter).
    worker_pull_ops: Counter,
    /// Shard row range `[a, b)` this process was launched to serve when run
    /// as `corrsh worker --shards a..b`. Informational: workers register the
    /// full dataset (re-dispatch needs any survivor to be able to score any
    /// segment); the coordinator's placement decides what each worker is
    /// actually asked to compute.
    worker_shards: Mutex<Option<(usize, usize)>>,
    /// Present on coordinators: the runtime fanning registrations out to
    /// worker processes and owning per-dataset distributed engines.
    dist: Mutex<Option<Arc<DistRuntime>>>,
    /// Transport counters (filled in by whichever server fronts this state).
    pub net: NetStats,
    shutdown: AtomicBool,
}

impl State {
    pub fn new() -> Arc<Self> {
        Arc::new(State::default())
    }

    /// True once a `shutdown` request has been accepted.
    pub fn shutting_down(&self) -> bool {
        self.shutdown.load(Ordering::Acquire)
    }

    /// The prepared-engine session cache (hit/miss counters feed the
    /// `metrics` op).
    pub fn engine_cache(&self) -> &EngineCache {
        &self.cache
    }

    /// Record the shard range this process serves (`corrsh worker` mode);
    /// surfaced through `worker.health` and the `metrics` op.
    pub fn set_worker_shards(&self, range: Option<(usize, usize)>) {
        *threads::lock(&self.worker_shards) = range;
    }

    /// Attach a coordinator's distributed runtime: from here on,
    /// registrations fan out to its workers and `medoid` queries run on the
    /// distributed engine instead of the local one.
    pub fn set_distributed(&self, rt: Arc<DistRuntime>) {
        *threads::lock(&self.dist) = Some(rt);
    }

    fn dist(&self) -> Option<Arc<DistRuntime>> {
        threads::lock(&self.dist).clone()
    }

    fn get(&self, name: &str) -> Result<Arc<Entry>> {
        threads::lock(&self.datasets)
            .get(name)
            .cloned()
            .with_context(|| format!("dataset {name:?} not registered"))
    }

    /// Cached-session engine: O(n·d) preparation only on the first call
    /// per `(dataset, generation, metric)`.
    fn engine(&self, name: &str, entry: &Entry) -> NativeEngine {
        let prepared =
            self.cache.get_or_prepare(name, entry.generation, entry.metric, &entry.data);
        NativeEngine::from_prepared(prepared, threads::default_threads())
    }

    /// Handle one bare v1 request object → flattened v1 response object
    /// (the legacy entry point; CLI preload and tests use it directly).
    pub fn handle(&self, req: &Value) -> Value {
        let env = proto::v1_envelope(req);
        let result = self.execute(&env, &mut |_| {});
        proto::wire_final(&env, result)
    }

    /// Handle one parsed envelope. Streaming ops (`"stream":true` params)
    /// feed per-round payloads to `sink`; the final result is the return
    /// value. Counts one request, and one error on failure.
    pub fn execute(
        &self,
        env: &Envelope,
        sink: &mut dyn FnMut(Value),
    ) -> Result<Value, OpError> {
        self.requests.fetch_add(1, Ordering::Relaxed);
        if env.v < 2 {
            self.net.v1_requests.add(1);
        }
        match self.dispatch(env, sink).map_err(OpError::classify) {
            Ok(v) => Ok(v),
            Err(e) => {
                self.errors.fetch_add(1, Ordering::Relaxed);
                Err(e)
            }
        }
    }

    fn dispatch(&self, env: &Envelope, sink: &mut dyn FnMut(Value)) -> Result<Value> {
        let req = &env.params;
        // v1 requests with no "op" key surface the legacy error string.
        let op: &str = if env.op.is_empty() {
            req.get("op").as_str().context("missing op")?
        } else {
            &env.op
        };
        let stream = req.get("stream").as_bool() == Some(true);
        match op {
            "ping" => Ok(Value::from_pairs(vec![("ok", true.into()), ("pong", true.into())])),
            "list" => {
                let names: Vec<Value> = threads::lock(&self.datasets)
                    .keys()
                    .map(|k| Value::Str(k.clone()))
                    .collect();
                Ok(Value::from_pairs(vec![
                    ("ok", true.into()),
                    ("datasets", Value::Array(names)),
                ]))
            }
            "register" => {
                let name = req.get("name").as_str().context("missing name")?.to_string();
                // Two sources: `path` (a .npy/.csr file, or a shard
                // manifest — the latter registers *without loading*, rows
                // stream from disk on demand) or `kind` (a generator).
                let (data, metric) = if let Some(path) = req.get("path").as_str() {
                    let data = crate::data::loader::load(path)?;
                    let metric: Metric = match req.get("metric").as_str() {
                        Some(m) => m.parse()?,
                        None if data.is_sparse() => Metric::L1,
                        None => Metric::L2,
                    };
                    crate::ensure!(data.n() >= 2, "register: dataset has n = {}", data.n());
                    (Arc::new(data), metric)
                } else {
                    let kind: Kind =
                        req.get("kind").as_str().context("missing kind (or path)")?.parse()?;
                    let mut cfg = SynthConfig {
                        n: req.get("n").as_usize().unwrap_or(1000),
                        dim: req.get("dim").as_usize().unwrap_or(256),
                        seed: req.get("seed").as_u64().unwrap_or(0),
                        ..Default::default()
                    };
                    if let Some(c) = req.get("clusters").as_usize() {
                        crate::ensure!(c >= 1, "register: clusters must be >= 1");
                        cfg.clusters = c;
                    }
                    crate::ensure!(cfg.n >= 2, "register: n must be >= 2 (got {})", cfg.n);
                    crate::ensure!(cfg.dim >= 1, "register: dim must be >= 1");
                    let metric = match req.get("metric").as_str() {
                        Some(m) => m.parse()?,
                        None => kind.default_metric(),
                    };
                    (Arc::new(kind.generate(&cfg)), metric)
                };
                let n = data.n();
                let sharded = matches!(&*data, Data::Sharded(_));
                // Stale sessions for the old binding of this name are
                // swept here (memory hygiene); correctness against the
                // re-register race comes from the generation cache key.
                self.cache.invalidate(&name);
                let generation = self.generation.fetch_add(1, Ordering::Relaxed);
                let entry = Arc::new(Entry { data, metric, generation });
                threads::lock(&self.datasets).insert(name.clone(), entry.clone());
                // Optional eager warmup so the first query is already hot.
                if req.get("prepare").as_bool() == Some(true) {
                    let _ = self.engine(&name, &entry);
                }
                let mut pairs = vec![
                    ("ok", true.into()),
                    ("name", name.as_str().into()),
                    ("n", n.into()),
                    ("metric", metric.name().into()),
                    ("sharded", sharded.into()),
                ];
                // Coordinator mode: fan the registration out to every worker
                // (they re-run it from the same params) and open the
                // distributed session. A failed fan-out rolls the local
                // registration back — a half-registered coordinator would
                // silently answer locally for a dataset the workers never
                // admitted.
                if let Some(rt) = self.dist() {
                    let shard_rows = match &*entry.data {
                        Data::Sharded(sd) => sd.rows_per_shard(),
                        _ => 0,
                    };
                    match rt.register(&name, req, shard_rows) {
                        Ok(dist) => {
                            pairs.push(("distributed", true.into()));
                            pairs.push(("workers", dist.alive_workers().into()));
                        }
                        Err(e) => {
                            threads::lock(&self.datasets).remove(&name);
                            self.cache.invalidate(&name);
                            return Err(e).with_context(|| {
                                format!("register: fan-out to workers failed for {name:?}")
                            });
                        }
                    }
                }
                Ok(Value::from_pairs(pairs))
            }
            "unregister" => {
                let name = req
                    .get("name")
                    .as_str()
                    .or(req.get("dataset").as_str())
                    .context("missing name")?;
                let removed = threads::lock(&self.datasets).remove(name);
                self.cache.invalidate(name);
                if let Some(rt) = self.dist() {
                    rt.unregister(name);
                }
                crate::ensure!(removed.is_some(), "dataset {name:?} not registered");
                Ok(Value::from_pairs(vec![
                    ("ok", true.into()),
                    ("name", name.into()),
                    ("removed", true.into()),
                ]))
            }
            "medoid" => {
                let name = req.get("dataset").as_str().context("missing dataset")?;
                let entry = self.get(name)?;
                let algo = build_algo(req, entry.data.n())?;
                let seed = req.get("seed").as_u64().unwrap_or(0);
                let mut rng = Rng::seeded(seed);
                // Coordinator mode: the same algorithm runs against the
                // distributed engine — pulls execute on the workers, and
                // the canonical fold keeps the sums bitwise-identical at
                // any worker count (DESIGN.md §15).
                let dist = self.dist().and_then(|rt| rt.engine(name));
                let res = match &dist {
                    Some(eng) => algo.run(&**eng, &mut rng),
                    None => algo.run(&self.engine(name, &entry), &mut rng),
                };
                // The distributed engine has no error channel inside the
                // bandit loop: a total fleet loss zero-fills pulls and
                // poisons the engine. Discard such an answer here — a
                // medoid computed over zeroed segments is silently wrong.
                if let Some(eng) = &dist {
                    if let Some(why) = eng.take_failure() {
                        crate::bail!("distributed medoid on {name:?} failed: {why}");
                    }
                }
                self.pulls.add(res.pulls);
                if stream {
                    // Replay the halving trace as partial frames: one per
                    // round, carrying the surviving-arm count and budget.
                    for r in &res.rounds {
                        sink(Value::from_pairs(vec![
                            ("round", r.r.into()),
                            ("survivors", r.survivors.into()),
                            ("t", r.t.into()),
                            ("pulls", r.pulls.into()),
                        ]));
                    }
                }
                let mut pairs = vec![
                    ("ok", true.into()),
                    ("medoid", res.best.into()),
                    ("pulls", res.pulls.into()),
                    ("wall_ms", (res.wall.as_secs_f64() * 1e3).into()),
                    ("algo", algo.name().into()),
                    ("seed", seed_value(seed)),
                ];
                if let Some(eng) = &dist {
                    pairs.push(("distributed", true.into()));
                    pairs.push(("workers", eng.alive_workers().into()));
                    pairs.push(("redispatches", eng.redispatches().into()));
                }
                Ok(Value::from_pairs(pairs))
            }
            "medoid_batch" => self.medoid_batch(req),
            "kmedoids" => {
                let name = req.get("dataset").as_str().context("missing dataset")?;
                let entry = self.get(name)?;
                let n = entry.data.n();
                let cfg = KMedoidsConfig::from_json_value(req)?;
                crate::ensure!(cfg.k <= n, "kmedoids: k = {} exceeds dataset size n = {n}", cfg.k);
                let seed = req.get("seed").as_u64().unwrap_or(0);
                let engine = self.engine(name, &entry);
                let mut rng = Rng::seeded(seed);
                let algo = cfg.build();
                let res = if stream {
                    // Live loss trajectory: one partial frame per accepted
                    // step of BUILD/SWAP/polish.
                    let mut observer = |phase: &'static str, step: usize, loss: f64| {
                        sink(Value::from_pairs(vec![
                            ("phase", phase.into()),
                            ("step", step.into()),
                            ("loss", loss.into()),
                        ]));
                    };
                    algo.run_with_observer(&engine, &mut rng, &mut observer)
                } else {
                    algo.run(&engine, &mut rng)
                };
                self.pulls.add(res.pulls());
                self.kmedoids_runs.add(1);
                let medoids: Vec<Value> = res.medoids.iter().map(|&m| Value::from(m)).collect();
                let sizes: Vec<Value> =
                    res.cluster_sizes().iter().map(|&s| Value::from(s)).collect();
                let mut pairs = vec![
                    ("ok", true.into()),
                    ("algo", "bandit-kmedoids".into()),
                    ("k", res.medoids.len().into()),
                    ("medoids", Value::Array(medoids)),
                    ("cluster_sizes", Value::Array(sizes)),
                    ("loss", res.loss.into()),
                    ("pulls", res.pulls().into()),
                    ("build_pulls", res.build_pulls.into()),
                    ("swap_pulls", res.swap_pulls.into()),
                    ("polish_pulls", res.polish_pulls.into()),
                    ("swap_rounds", res.swap_rounds.into()),
                    ("swaps_accepted", res.swaps_accepted.into()),
                    ("wall_ms", (res.wall.as_secs_f64() * 1e3).into()),
                    ("seed", seed_value(seed)),
                ];
                // Full per-point assignments are O(n) on the wire — opt-in.
                if req.get("assignments").as_bool() == Some(true) {
                    let a: Vec<Value> = res.assignments.iter().map(|&x| Value::from(x)).collect();
                    pairs.push(("assignments", Value::Array(a)));
                }
                Ok(Value::from_pairs(pairs))
            }
            "stats" => {
                let name = req.get("dataset").as_str().context("missing dataset")?;
                let entry = self.get(name)?;
                let engine = self.engine(name, &entry);
                let mut rng = Rng::seeded(0);
                let st = crate::stats::instance_stats(
                    &engine,
                    256.min(entry.data.n()),
                    &mut rng,
                );
                Ok(Value::from_pairs(vec![
                    ("ok", true.into()),
                    ("medoid", st.medoid.into()),
                    ("sigma", st.sigma.into()),
                    ("h2", st.h2.into()),
                    ("h2_tilde", st.h2_tilde.into()),
                    ("gain_ratio", st.gain_ratio().into()),
                ]))
            }
            // Coordinator→worker data plane. Same envelope framing as every
            // other op; a worker is just a `State` that happens to answer
            // these three ops fast (DESIGN.md §15).
            "worker.prepare" => {
                let name = req.get("dataset").as_str().context("missing dataset")?;
                let entry = self.get(name)?;
                let prepared =
                    self.cache.get_or_prepare(name, entry.generation, entry.metric, &entry.data);
                Ok(Value::from_pairs(vec![
                    ("ok", true.into()),
                    ("n", entry.data.n().into()),
                    ("dim", entry.data.dim().into()),
                    ("metric", entry.metric.name().into()),
                    // Bit pattern, not a float: digests above 2⁵³ must not
                    // round on the wire (bits_value).
                    ("digest", bits_value(prepared.digest())),
                ]))
            }
            "worker.pull" => {
                let name = req.get("dataset").as_str().context("missing dataset")?;
                let entry = self.get(name)?;
                let n = entry.data.n();
                let arms: Vec<usize> = if let Some(r) = req.get("arms_range").as_array() {
                    crate::ensure!(r.len() == 2, "worker.pull: arms_range must be [lo, hi)");
                    let lo = r[0].as_usize().context("worker.pull: bad arms_range")?;
                    let hi = r[1].as_usize().context("worker.pull: bad arms_range")?;
                    crate::ensure!(
                        lo < hi && hi <= n,
                        "worker.pull: arms_range [{lo}, {hi}) out of bounds for n = {n}"
                    );
                    (lo..hi).collect()
                } else {
                    req.get("arms")
                        .as_array()
                        .context("worker.pull: missing arms (or arms_range)")?
                        .iter()
                        .map(|v| v.as_usize().context("worker.pull: bad arm index"))
                        .collect::<Result<_>>()?
                };
                crate::ensure!(!arms.is_empty(), "worker.pull: empty arms");
                crate::ensure!(
                    arms.iter().all(|&a| a < n),
                    "worker.pull: arm index out of bounds for n = {n}"
                );
                let raw = req.get("ref_groups").as_array().context("missing ref_groups")?;
                crate::ensure!(!raw.is_empty(), "worker.pull: empty ref_groups");
                let mut groups: Vec<Vec<usize>> = Vec::with_capacity(raw.len());
                for g in raw {
                    let refs: Vec<usize> = g
                        .as_array()
                        .context("worker.pull: ref group is not an array")?
                        .iter()
                        .map(|v| v.as_usize().context("worker.pull: bad ref index"))
                        .collect::<Result<_>>()?;
                    crate::ensure!(!refs.is_empty(), "worker.pull: empty ref group");
                    crate::ensure!(
                        refs.iter().all(|&r| r < n),
                        "worker.pull: ref index out of bounds for n = {n}"
                    );
                    groups.push(refs);
                }
                let matrix = req.get("matrix").as_bool() == Some(true);
                let engine = self.engine(name, &entry);
                let mut pulls = 0u64;
                // One answer row per request group, in request order — the
                // coordinator maps rows back to segments positionally. All
                // payloads are bit patterns (lossless, NaN-safe).
                let rows: Vec<Value> = groups
                    .iter()
                    .map(|refs| {
                        pulls = pulls.saturating_add((arms.len() * refs.len()) as u64);
                        if matrix {
                            let mut buf = vec![0f32; arms.len() * refs.len()];
                            engine.pull_matrix(&arms, refs, &mut buf);
                            Value::Array(
                                buf.iter().map(|d| bits_value(d.to_bits() as u64)).collect(),
                            )
                        } else {
                            let mut out = vec![0f64; arms.len()];
                            engine.pull_block(&arms, refs, &mut out);
                            Value::Array(out.iter().map(|s| bits_value(s.to_bits())).collect())
                        }
                    })
                    .collect();
                self.pulls.add(pulls);
                self.worker_pull_ops.add(1);
                Ok(Value::from_pairs(vec![
                    ("ok", true.into()),
                    (if matrix { "dists" } else { "sums" }, Value::Array(rows)),
                    ("pulls", pulls.into()),
                ]))
            }
            "worker.health" => {
                let mut pairs = vec![
                    ("ok", true.into()),
                    ("datasets", threads::lock(&self.datasets).len().into()),
                    ("pulls", self.pulls.get().into()),
                    ("worker_pull_ops", self.worker_pull_ops.get().into()),
                ];
                if let Some((a, b)) = *threads::lock(&self.worker_shards) {
                    pairs.push(("shards", Value::Array(vec![a.into(), b.into()])));
                }
                Ok(Value::from_pairs(pairs))
            }
            "metrics" => {
                let mut pairs = vec![
                    ("ok", true.into()),
                    ("requests", self.requests.load(Ordering::Relaxed).into()),
                    ("errors", self.errors.load(Ordering::Relaxed).into()),
                    ("pulls", self.pulls.get().into()),
                    ("kmedoids_runs", self.kmedoids_runs.get().into()),
                    ("datasets", threads::lock(&self.datasets).len().into()),
                    (
                        "engine_cache",
                        Value::from_pairs(vec![
                            ("entries", self.cache.len().into()),
                            ("hits", self.cache.hits().into()),
                            ("misses", self.cache.misses().into()),
                            ("nan_pulls", self.cache.nan_pulls().into()),
                            // Dispatched micro-kernel variant every cached
                            // session's hot paths run on (engine::simd).
                            ("kernel_variant", crate::engine::simd::active().name().into()),
                        ]),
                    ),
                    (
                        // Shard-store traffic (process-global): monotone
                        // hit/miss counters plus the pinned-bytes gauge, so
                        // "the million-point dataset stayed inside its cache
                        // budget" is observable, not assumed (DESIGN.md §12).
                        "shard_cache",
                        {
                            let s = crate::data::store::cache_stats();
                            Value::from_pairs(vec![
                                ("hits", s.hits().into()),
                                ("misses", s.misses().into()),
                                ("pinned_bytes", s.pinned_bytes().into()),
                            ])
                        },
                    ),
                    // Transport counters (zeros under the blocking fallback
                    // or when querying a bare State).
                    ("net", self.net.to_value()),
                    // Invariant analyzer identity: which lint semantics and
                    // how many rules this binary enforces (`corrsh lint`,
                    // DESIGN.md §16) — lets CI cross-check that the gate and
                    // the serving binary agree on the rule set.
                    (
                        "lint",
                        Value::from_pairs(vec![
                            ("version", crate::analysis::LINT_VERSION.into()),
                            ("rules", crate::analysis::RULES.len().into()),
                        ]),
                    ),
                ];
                // Distributed roles: workers export their data-plane
                // traffic and shard range; coordinators export per-worker
                // rows (pulls, in_flight, restarts, p99) and the re-dispatch
                // total, so "the fleet is healthy" is observable.
                pairs.push(("worker_pull_ops", self.worker_pull_ops.get().into()));
                if let Some((a, b)) = *threads::lock(&self.worker_shards) {
                    pairs.push(("worker_shards", Value::Array(vec![a.into(), b.into()])));
                }
                if let Some(rt) = self.dist() {
                    pairs.push(("coordinator", true.into()));
                    pairs.push(("workers", rt.worker_rows_value()));
                    pairs.push(("redispatches", rt.redispatches().into()));
                }
                Ok(Value::from_pairs(pairs))
            }
            "shutdown" => {
                self.shutdown.store(true, Ordering::Release);
                Ok(Value::from_pairs(vec![
                    ("ok", true.into()),
                    ("shutting_down", true.into()),
                ]))
            }
            other => crate::bail!("unknown op {other:?}"),
        }
    }

    /// Many seeds (and optionally per-seed budgets) against one dataset,
    /// answered in a single sweep over one cached session: the engine is
    /// fetched once and the jobs fan out over the worker pool.
    fn medoid_batch(&self, req: &Value) -> Result<Value> {
        let name = req.get("dataset").as_str().context("missing dataset")?;
        let entry = self.get(name)?;
        let n = entry.data.n();
        const MAX_JOBS: usize = 4096;
        let seeds: Vec<u64> = match req.get("seeds").as_array() {
            Some(arr) => {
                crate::ensure!(
                    arr.len() <= MAX_JOBS,
                    "medoid_batch: at most {MAX_JOBS} jobs per request (got {})",
                    arr.len()
                );
                arr.iter()
                    .map(|v| v.as_u64().context("seeds entries must be non-negative integers"))
                    .collect::<Result<_>>()?
            }
            None => {
                let s0 = req.get("seed").as_u64().unwrap_or(0);
                let count = req.get("count").as_usize().unwrap_or(1);
                // Cap BEFORE materializing: `count` is client-controlled
                // and would otherwise size an allocation directly.
                crate::ensure!(
                    count <= MAX_JOBS,
                    "medoid_batch: at most {MAX_JOBS} jobs per request (got count {count})"
                );
                (0..count as u64).map(|i| s0.wrapping_add(i)).collect()
            }
        };
        crate::ensure!(!seeds.is_empty(), "medoid_batch: empty seed list");
        let mut budgets: Vec<Option<f64>> = vec![None; seeds.len()];
        if let Some(arr) = req.get("budgets").as_array() {
            crate::ensure!(
                arr.len() == seeds.len(),
                "medoid_batch: budgets len {} != seeds len {}",
                arr.len(),
                seeds.len()
            );
            for (slot, v) in budgets.iter_mut().zip(arr) {
                *slot = Some(v.as_f64().context("budgets entries must be numbers")?);
            }
        }
        // Validate every job's algorithm config up front so a bad job fails
        // the whole request instead of surfacing mid-sweep.
        let jobs: Vec<(u64, AlgoConfig)> = seeds
            .iter()
            .zip(&budgets)
            .map(|(&seed, &budget)| Ok((seed, algo_config(req, n, budget)?)))
            .collect::<Result<_>>()?;
        let engine = self.engine(name, &entry);
        let t0 = Instant::now();
        let workers = threads::default_threads().min(jobs.len()).max(1);
        let outcomes: Vec<(Value, u64)> = threads::parallel_map(jobs.len(), workers, |i| {
            let (seed, cfg) = &jobs[i];
            let mut rng = Rng::seeded(*seed);
            let res = cfg.build(n).run(&engine, &mut rng);
            let v = Value::from_pairs(vec![
                ("seed", seed_value(*seed)),
                ("algo", cfg.name().into()),
                ("medoid", res.best.into()),
                ("pulls", res.pulls.into()),
                ("wall_ms", (res.wall.as_secs_f64() * 1e3).into()),
            ]);
            (v, res.pulls)
        });
        let total_pulls: u64 = outcomes.iter().map(|(_, p)| p).sum();
        self.pulls.add(total_pulls);
        let results: Vec<Value> = outcomes.into_iter().map(|(v, _)| v).collect();
        Ok(Value::from_pairs(vec![
            ("ok", true.into()),
            ("dataset", name.into()),
            ("jobs", results.len().into()),
            ("pulls", total_pulls.into()),
            ("wall_ms", (t0.elapsed().as_secs_f64() * 1e3).into()),
            ("results", Value::Array(results)),
        ]))
    }
}

/// Algorithm selection from a request, with PR-2 fixes: `refs_per_arm`
/// clamps to n (the old default of 1000 asked RAND for more distinct
/// references than small datasets have) and seeds/caps read through the
/// lossless [`Value::as_u64`]. `budget` overrides the algorithm's primary
/// knob (per-job budgets in `medoid_batch`).
fn algo_config(req: &Value, n: usize, budget: Option<f64>) -> Result<AlgoConfig> {
    let name = req.get("algo").as_str().unwrap_or("corrsh");
    let ppa = |d: f64| budget.or(req.get("pulls_per_arm").as_f64()).unwrap_or(d);
    let cfg = match name {
        "corrsh" => AlgoConfig::CorrSh { pulls_per_arm: ppa(24.0) },
        "sh" | "seq-halving" => AlgoConfig::SeqHalving { pulls_per_arm: ppa(24.0) },
        "meddit" => AlgoConfig::Meddit {
            delta: req.get("delta").as_f64().unwrap_or(0.0),
            cap: budget.map(|b| b.max(0.0) as u64).or(req.get("cap").as_u64()).unwrap_or(0),
        },
        "rand" => AlgoConfig::Rand {
            refs_per_arm: budget
                .map(|b| b.max(0.0) as usize)
                .or(req.get("refs_per_arm").as_usize())
                .unwrap_or(1000)
                .min(n),
        },
        "toprank" => AlgoConfig::TopRank {
            phase1_refs: budget
                .map(|b| b.max(0.0) as usize)
                .or(req.get("phase1_refs").as_usize())
                .unwrap_or(1000)
                .min(n),
        },
        "exact" => AlgoConfig::Exact,
        // trimed is exact: `budget` does not apply (like "exact"), but the
        // anchor count is tunable per request.
        "trimed" => AlgoConfig::Trimed {
            anchors: req.get("anchors").as_usize().unwrap_or(4).max(1),
        },
        other => crate::bail!("unknown algo {other:?}"),
    };
    Ok(cfg)
}

fn build_algo(req: &Value, n: usize) -> Result<Box<dyn MedoidAlgorithm>> {
    Ok(algo_config(req, n, None)?.build(n))
}

/// Echo a seed losslessly: numbers up to 2⁵³ stay JSON numbers; larger
/// values go back out as the decimal-string form the request path accepts
/// (`Value::as_u64`), so an echoed seed always reproduces the same run.
pub(super) fn seed_value(seed: u64) -> Value {
    if seed <= (1u64 << 53) {
        seed.into()
    } else {
        Value::Str(seed.to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json;

    fn req(s: &str) -> Value {
        json::parse(s).unwrap()
    }

    fn register_toy(state: &State, name: &str) {
        let r = state.handle(&req(&format!(
            r#"{{"op":"register","name":"{name}","kind":"gaussian","n":200,"dim":8,"seed":4}}"#
        )));
        assert_eq!(r.get("ok").as_bool(), Some(true), "register failed: {r}");
    }

    #[test]
    fn protocol_register_and_query() {
        let state = State::new();
        let r = state.handle(&req(
            r#"{"op":"register","name":"toy","kind":"gaussian","n":200,"dim":8,"seed":4}"#,
        ));
        assert_eq!(r.get("ok").as_bool(), Some(true));
        assert_eq!(r.get("n").as_usize(), Some(200));
        assert_eq!(r.get("metric").as_str(), Some("l2"));

        let r = state.handle(&req(
            r#"{"op":"medoid","dataset":"toy","algo":"corrsh","pulls_per_arm":48,"seed":1}"#,
        ));
        assert_eq!(r.get("ok").as_bool(), Some(true));
        assert_eq!(r.get("medoid").as_usize(), Some(0), "planted medoid");
        assert!(r.get("pulls").as_f64().unwrap() > 0.0);
        assert_eq!(r.get("seed").as_u64(), Some(1));

        let r = state.handle(&req(r#"{"op":"list"}"#));
        assert_eq!(r.get("datasets").idx(0).as_str(), Some("toy"));
    }

    #[test]
    fn protocol_errors_are_reported() {
        let state = State::new();
        let r = state.handle(&req(r#"{"op":"medoid","dataset":"nope"}"#));
        assert_eq!(r.get("ok").as_bool(), Some(false));
        assert!(r.get("error").as_str().unwrap().contains("not registered"));
        let r = state.handle(&req(r#"{"op":"frobnicate"}"#));
        assert_eq!(r.get("ok").as_bool(), Some(false));
        assert_eq!(state.errors.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn rand_defaults_clamp_to_n() {
        let state = State::new();
        register_toy(&state, "toy");
        // Old default asked RAND for 1000 distinct references on n=200;
        // the honest default is m = n → an exact sweep of n*m pulls.
        let r = state.handle(&req(r#"{"op":"medoid","dataset":"toy","algo":"rand","seed":2}"#));
        assert_eq!(r.get("ok").as_bool(), Some(true), "{r}");
        assert_eq!(r.get("pulls").as_u64(), Some(200 * 200));
        // Explicit oversized values clamp too.
        let r = state.handle(&req(
            r#"{"op":"medoid","dataset":"toy","algo":"rand","refs_per_arm":5000,"seed":2}"#,
        ));
        assert_eq!(r.get("pulls").as_u64(), Some(200 * 200));
    }

    #[test]
    fn trimed_op_is_exact_and_reports_its_pulls() {
        let state = State::new();
        register_toy(&state, "toy");
        let exact =
            state.handle(&req(r#"{"op":"medoid","dataset":"toy","algo":"exact","seed":0}"#));
        let r = state.handle(&req(
            r#"{"op":"medoid","dataset":"toy","algo":"trimed","anchors":4,"seed":0}"#,
        ));
        assert_eq!(r.get("ok").as_bool(), Some(true), "{r}");
        assert_eq!(r.get("algo").as_str(), Some("trimed"));
        assert_eq!(r.get("medoid").as_usize(), exact.get("medoid").as_usize());
        let pulls = r.get("pulls").as_u64().unwrap();
        assert!(pulls > 0, "trimed reported zero pulls");
    }

    #[test]
    fn register_accepts_string_seed_beyond_f64() {
        let state = State::new();
        let r = state.handle(&req(
            r#"{"op":"register","name":"big","kind":"gaussian","n":64,"dim":4,
                "seed":"18446744073709551615"}"#,
        ));
        assert_eq!(r.get("ok").as_bool(), Some(true), "{r}");
        assert_eq!(r.get("n").as_usize(), Some(64));
        // A big query seed is echoed losslessly (string form), so feeding
        // the echo back reproduces the same run.
        let r = state.handle(&req(
            r#"{"op":"medoid","dataset":"big","pulls_per_arm":8,"seed":"18446744073709551615"}"#,
        ));
        assert_eq!(r.get("ok").as_bool(), Some(true), "{r}");
        assert_eq!(r.get("seed").as_u64(), Some(u64::MAX));
        assert_eq!(r.get("seed").as_str(), Some("18446744073709551615"));
    }

    #[test]
    fn register_by_path_matches_generator_registration() {
        // The same bytes registered three ways — generator, resident .npy,
        // shard manifest — must give identical medoid answers, and the
        // manifest registration must report sharded:true.
        let dir = std::env::temp_dir().join("corrsh-server-tests").join("register-path");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let cfg = crate::data::synth::SynthConfig { n: 150, dim: 8, seed: 4, ..Default::default() };
        let data = Kind::Gaussian.generate(&cfg);
        let npy = dir.join("toy.npy");
        crate::data::loader::save_dense_npy(&npy, &data.to_dense()).unwrap();
        let manifest = crate::data::store::write_sharded(&data, dir.join("shards"), 32).unwrap();

        let state = State::new();
        let r = state.handle(&req(
            r#"{"op":"register","name":"gen","kind":"gaussian","n":150,"dim":8,"seed":4}"#,
        ));
        assert_eq!(r.get("sharded").as_bool(), Some(false));
        let r = state.handle(&req(&format!(
            r#"{{"op":"register","name":"npy","path":{:?},"metric":"l2"}}"#,
            npy.to_str().unwrap()
        )));
        assert_eq!(r.get("ok").as_bool(), Some(true), "{r}");
        assert_eq!(r.get("sharded").as_bool(), Some(false));
        let r = state.handle(&req(&format!(
            r#"{{"op":"register","name":"shards","path":{:?},"metric":"l2"}}"#,
            manifest.to_str().unwrap()
        )));
        assert_eq!(r.get("ok").as_bool(), Some(true), "{r}");
        assert_eq!(r.get("sharded").as_bool(), Some(true));
        assert_eq!(r.get("n").as_usize(), Some(150));

        let answers: Vec<(Option<usize>, Option<u64>)> = ["gen", "npy", "shards"]
            .iter()
            .map(|name| {
                let r = state.handle(&req(&format!(
                    r#"{{"op":"medoid","dataset":"{name}","pulls_per_arm":32,"seed":7}}"#
                )));
                assert_eq!(r.get("ok").as_bool(), Some(true), "{name}: {r}");
                (r.get("medoid").as_usize(), r.get("pulls").as_u64())
            })
            .collect();
        assert_eq!(answers[0], answers[1], "generator vs npy");
        assert_eq!(answers[1], answers[2], "npy vs shard manifest");

        // shard_cache gauges are exported and the manifest dataset moved them
        let m = state.handle(&req(r#"{"op":"metrics"}"#));
        let sc = m.get("shard_cache");
        assert!(sc.get("hits").as_u64().is_some() && sc.get("misses").as_u64().is_some());
        // registering a bogus path fails cleanly
        let r = state.handle(&req(r#"{"op":"register","name":"x","path":"/no/such.npy"}"#));
        assert_eq!(r.get("ok").as_bool(), Some(false));
    }

    #[test]
    fn register_rejects_degenerate_shapes() {
        let state = State::new();
        for bad in [
            r#"{"op":"register","name":"z","kind":"gaussian","n":0,"dim":4}"#,
            r#"{"op":"register","name":"z","kind":"gaussian","n":1,"dim":4}"#,
            r#"{"op":"register","name":"z","kind":"gaussian","n":10,"dim":0}"#,
        ] {
            let r = state.handle(&req(bad));
            assert_eq!(r.get("ok").as_bool(), Some(false), "should fail: {bad}");
        }
        let l = state.handle(&req(r#"{"op":"list"}"#));
        assert_eq!(l.get("datasets").as_array().unwrap().len(), 0);
    }

    #[test]
    fn second_query_hits_the_session_cache() {
        // The PR's acceptance check: the second medoid request on a
        // registered dataset performs zero engine preparation, observable
        // through the metrics op.
        let state = State::new();
        register_toy(&state, "toy");
        let m = state.handle(&req(r#"{"op":"metrics"}"#));
        assert_eq!(m.get("engine_cache").get("misses").as_u64(), Some(0));
        assert_eq!(m.get("engine_cache").get("entries").as_u64(), Some(0));

        let r = state.handle(&req(r#"{"op":"medoid","dataset":"toy","seed":1}"#));
        assert_eq!(r.get("ok").as_bool(), Some(true));
        let m = state.handle(&req(r#"{"op":"metrics"}"#));
        assert_eq!(m.get("engine_cache").get("misses").as_u64(), Some(1));
        assert_eq!(m.get("engine_cache").get("hits").as_u64(), Some(0));

        let r2 = state.handle(&req(r#"{"op":"medoid","dataset":"toy","seed":1}"#));
        assert_eq!(r2.get("medoid").as_usize(), r.get("medoid").as_usize());
        let m = state.handle(&req(r#"{"op":"metrics"}"#));
        assert_eq!(m.get("engine_cache").get("misses").as_u64(), Some(1), "no re-preparation");
        assert_eq!(m.get("engine_cache").get("hits").as_u64(), Some(1));
        assert_eq!(m.get("engine_cache").get("entries").as_u64(), Some(1));
        assert!(m.get("pulls").as_u64().unwrap() > 0);
        assert!(m.get("requests").as_u64().unwrap() >= 5);
        assert_eq!(m.get("datasets").as_u64(), Some(1));
    }

    #[test]
    fn reregister_invalidates_stale_sessions() {
        let state = State::new();
        register_toy(&state, "x");
        state.handle(&req(r#"{"op":"medoid","dataset":"x","seed":0}"#));
        // Same name, different data: the cached session must not survive.
        let r = state.handle(&req(
            r#"{"op":"register","name":"x","kind":"gaussian","n":150,"dim":8,"seed":99}"#,
        ));
        assert_eq!(r.get("ok").as_bool(), Some(true));
        let m = state.handle(&req(r#"{"op":"metrics"}"#));
        assert_eq!(m.get("engine_cache").get("entries").as_u64(), Some(0));
        state.handle(&req(r#"{"op":"medoid","dataset":"x","seed":0}"#));
        let m = state.handle(&req(r#"{"op":"metrics"}"#));
        assert_eq!(m.get("engine_cache").get("misses").as_u64(), Some(2));
    }

    #[test]
    fn register_prepare_flag_warms_cache() {
        let state = State::new();
        let r = state.handle(&req(
            r#"{"op":"register","name":"warm","kind":"gaussian","n":100,"dim":8,
                "seed":1,"prepare":true}"#,
        ));
        assert_eq!(r.get("ok").as_bool(), Some(true));
        // The first query is already a cache hit.
        state.handle(&req(r#"{"op":"medoid","dataset":"warm","seed":0}"#));
        let m = state.handle(&req(r#"{"op":"metrics"}"#));
        assert_eq!(m.get("engine_cache").get("hits").as_u64(), Some(1));
        assert_eq!(m.get("engine_cache").get("misses").as_u64(), Some(1));
    }

    #[test]
    fn medoid_batch_matches_individual_queries() {
        let state = State::new();
        register_toy(&state, "toy");
        let mut expect = Vec::new();
        for seed in [3u64, 7, 11, 42] {
            let r = state.handle(&req(&format!(
                r#"{{"op":"medoid","dataset":"toy","pulls_per_arm":48,"seed":{seed}}}"#
            )));
            expect.push((r.get("medoid").as_usize().unwrap(), r.get("pulls").as_u64().unwrap()));
        }
        let b = state.handle(&req(
            r#"{"op":"medoid_batch","dataset":"toy","pulls_per_arm":48,"seeds":[3,7,11,42]}"#,
        ));
        assert_eq!(b.get("ok").as_bool(), Some(true), "{b}");
        assert_eq!(b.get("jobs").as_usize(), Some(4));
        let results = b.get("results").as_array().unwrap();
        assert_eq!(results.len(), 4);
        for (i, (medoid, pulls)) in expect.iter().enumerate() {
            assert_eq!(results[i].get("medoid").as_usize(), Some(*medoid), "seed #{i}");
            assert_eq!(results[i].get("pulls").as_u64(), Some(*pulls), "seed #{i}");
        }
        let total: u64 = expect.iter().map(|&(_, p)| p).sum();
        assert_eq!(b.get("pulls").as_u64(), Some(total));
    }

    #[test]
    fn medoid_batch_seed_count_and_budgets() {
        let state = State::new();
        register_toy(&state, "toy");
        // seed+count shorthand
        let b = state.handle(&req(
            r#"{"op":"medoid_batch","dataset":"toy","seed":5,"count":3,"pulls_per_arm":16}"#,
        ));
        assert_eq!(b.get("jobs").as_usize(), Some(3));
        assert_eq!(b.get("results").idx(1).get("seed").as_u64(), Some(6));
        // per-job budgets change per-job pull counts
        let b = state.handle(&req(
            r#"{"op":"medoid_batch","dataset":"toy","seeds":[1,1],"budgets":[8,64]}"#,
        ));
        assert_eq!(b.get("ok").as_bool(), Some(true), "{b}");
        let lo = b.get("results").idx(0).get("pulls").as_u64().unwrap();
        let hi = b.get("results").idx(1).get("pulls").as_u64().unwrap();
        assert!(lo < hi, "budget 8 ({lo} pulls) must cost less than 64 ({hi})");
    }

    #[test]
    fn medoid_batch_error_paths() {
        let state = State::new();
        register_toy(&state, "toy");
        for bad in [
            r#"{"op":"medoid_batch","dataset":"toy","seeds":[]}"#,
            r#"{"op":"medoid_batch","dataset":"toy","seeds":[1,2],"budgets":[8]}"#,
            r#"{"op":"medoid_batch","dataset":"toy","seeds":[1],"algo":"nope"}"#,
            r#"{"op":"medoid_batch","dataset":"missing","seeds":[1]}"#,
            r#"{"op":"medoid_batch","dataset":"toy","seeds":[-1]}"#,
            // count is capped BEFORE the seed vector is materialized
            r#"{"op":"medoid_batch","dataset":"toy","seed":0,"count":200000000000}"#,
        ] {
            let r = state.handle(&req(bad));
            assert_eq!(r.get("ok").as_bool(), Some(false), "should fail: {bad}");
        }
    }

    #[test]
    fn kmedoids_op_recovers_planted_cluster_medoids() {
        // The PR's server-side acceptance check: k = 5 planted clusters on
        // n = 2000, ≥ 4/5 exact-medoid agreement at ≤ 5% of the exact
        // BUILD sweep (k·n² pulls), over a cached engine session.
        let state = State::new();
        let r = state.handle(&req(
            r#"{"op":"register","name":"mix","kind":"mixture","n":2000,"dim":16,
                "seed":42,"clusters":5}"#,
        ));
        assert_eq!(r.get("ok").as_bool(), Some(true), "{r}");
        let r = state.handle(&req(r#"{"op":"kmedoids","dataset":"mix","k":5,"seed":1}"#));
        assert_eq!(r.get("ok").as_bool(), Some(true), "{r}");
        let medoids = r.get("medoids").as_array().unwrap();
        assert_eq!(medoids.len(), 5);
        let hits = medoids.iter().filter(|m| m.as_usize().unwrap() < 5).count();
        assert!(hits >= 4, "planted-center agreement {hits}/5: {r}");
        let pulls = r.get("pulls").as_u64().unwrap();
        let exact = 5 * 2000u64 * 2000;
        assert!(pulls * 20 <= exact, "{pulls} pulls > 5% of exact {exact}");
        assert_eq!(
            pulls,
            r.get("build_pulls").as_u64().unwrap()
                + r.get("swap_pulls").as_u64().unwrap()
                + r.get("polish_pulls").as_u64().unwrap()
        );
        let sizes = r.get("cluster_sizes").as_array().unwrap();
        let total: usize = sizes.iter().map(|s| s.as_usize().unwrap()).sum();
        assert_eq!(total, 2000);
        assert!(matches!(r.get("assignments"), Value::Null), "assignments are opt-in");

        // Determinism through the cached session: same seed, same answer.
        let r2 = state.handle(&req(r#"{"op":"kmedoids","dataset":"mix","k":5,"seed":1}"#));
        assert_eq!(
            r2.get("medoids").as_array().unwrap(),
            medoids,
            "cached-session rerun diverged"
        );

        // Opt-in assignments round-trip, and the run counter advances.
        let r3 = state.handle(&req(
            r#"{"op":"kmedoids","dataset":"mix","k":3,"seed":0,"assignments":true}"#,
        ));
        assert_eq!(r3.get("assignments").as_array().unwrap().len(), 2000);
        let m = state.handle(&req(r#"{"op":"metrics"}"#));
        assert_eq!(m.get("kmedoids_runs").as_u64(), Some(3));
        assert_eq!(m.get("engine_cache").get("nan_pulls").as_u64(), Some(0));
        assert_eq!(
            m.get("engine_cache").get("kernel_variant").as_str(),
            Some(crate::engine::simd::active().name()),
            "metrics must export the dispatched kernel variant"
        );
        assert_eq!(m.get("engine_cache").get("misses").as_u64(), Some(1), "one preparation");
    }

    #[test]
    fn kmedoids_op_error_paths() {
        let state = State::new();
        register_toy(&state, "toy");
        for bad in [
            r#"{"op":"kmedoids","dataset":"missing","k":3}"#,
            r#"{"op":"kmedoids","dataset":"toy","k":0}"#,
            r#"{"op":"kmedoids","dataset":"toy","k":5000}"#,
            r#"{"op":"kmedoids","dataset":"toy","k":3,"build_pulls_per_arm":-1}"#,
        ] {
            let r = state.handle(&req(bad));
            assert_eq!(r.get("ok").as_bool(), Some(false), "should fail: {bad}");
        }
    }

    #[test]
    fn stats_and_unregister_flow() {
        let state = State::new();
        register_toy(&state, "toy");
        let s = state.handle(&req(r#"{"op":"stats","dataset":"toy"}"#));
        assert_eq!(s.get("ok").as_bool(), Some(true));
        assert_eq!(s.get("medoid").as_usize(), Some(0));
        assert!(s.get("gain_ratio").as_f64().unwrap() > 0.0);

        let u = state.handle(&req(r#"{"op":"unregister","name":"toy"}"#));
        assert_eq!(u.get("ok").as_bool(), Some(true));
        assert_eq!(u.get("removed").as_bool(), Some(true));
        let r = state.handle(&req(r#"{"op":"medoid","dataset":"toy","seed":0}"#));
        assert!(r.get("error").as_str().unwrap().contains("not registered"));
        let l = state.handle(&req(r#"{"op":"list"}"#));
        assert_eq!(l.get("datasets").as_array().unwrap().len(), 0);
        // double-unregister is an error
        let u2 = state.handle(&req(r#"{"op":"unregister","name":"toy"}"#));
        assert_eq!(u2.get("ok").as_bool(), Some(false));
        // cache entries for the name are gone
        let m = state.handle(&req(r#"{"op":"metrics"}"#));
        assert_eq!(m.get("engine_cache").as_u64(), None); // object, not a number
        assert_eq!(m.get("engine_cache").get("entries").as_u64(), Some(0));
    }

    #[test]
    fn v1_error_shape_is_flat() {
        // The compat shim flattens errors to the legacy {"ok":false,
        // "error":"..."} shape — no structured error object on v1.
        let state = State::new();
        let r = state.handle(&req(r#"{"op":"medoid","dataset":"nope"}"#));
        assert_eq!(r.get("ok").as_bool(), Some(false));
        assert!(r.get("error").as_str().is_some(), "v1 error must be a string: {r}");
        // and the ping reply carries the deprecation note
        let p = state.handle(&req(r#"{"op":"ping"}"#));
        assert_eq!(p.get("pong").as_bool(), Some(true));
        assert!(p.get("note").as_str().unwrap().contains("deprecated"), "{p}");
    }

    #[test]
    fn worker_ops_answer_the_coordinator_contract() {
        use crate::engine::PullEngine;
        let state = State::new();
        register_toy(&state, "toy");

        // worker.prepare: shape plus a digest that is stable across calls.
        let p = state.handle(&req(r#"{"op":"worker.prepare","dataset":"toy"}"#));
        assert_eq!(p.get("ok").as_bool(), Some(true), "{p}");
        assert_eq!(p.get("n").as_usize(), Some(200));
        assert_eq!(p.get("dim").as_usize(), Some(8));
        assert_eq!(p.get("metric").as_str(), Some("l2"));
        let digest = p.get("digest").as_u64().unwrap();
        let p2 = state.handle(&req(r#"{"op":"worker.prepare","dataset":"toy"}"#));
        assert_eq!(p2.get("digest").as_u64(), Some(digest), "digest must be stable");

        // worker.pull sums: bit-for-bit what a local engine computes per
        // group, in request order, with the exact pull count.
        let cfg = crate::data::synth::SynthConfig { n: 200, dim: 8, seed: 4, ..Default::default() };
        let engine = NativeEngine::new(Kind::Gaussian.generate(&cfg), Metric::L2);
        let r = state.handle(&req(
            r#"{"op":"worker.pull","dataset":"toy","arms_range":[0,4],
                "ref_groups":[[0,1,2],[7,5]]}"#,
        ));
        assert_eq!(r.get("ok").as_bool(), Some(true), "{r}");
        assert_eq!(r.get("pulls").as_u64(), Some(4 * 5));
        let sums = r.get("sums").as_array().unwrap();
        assert_eq!(sums.len(), 2);
        for (g, refs) in [vec![0usize, 1, 2], vec![7, 5]].iter().enumerate() {
            let mut want = vec![0f64; 4];
            engine.pull_block(&[0, 1, 2, 3], refs, &mut want);
            for (k, w) in want.iter().enumerate() {
                assert_eq!(sums[g].idx(k).as_u64(), Some(w.to_bits()), "group {g} arm {k}");
            }
        }

        // worker.pull matrix: arm-major f32 bit patterns.
        let r = state.handle(&req(
            r#"{"op":"worker.pull","dataset":"toy","arms":[3,1],
                "ref_groups":[[2,9]],"matrix":true}"#,
        ));
        assert_eq!(r.get("ok").as_bool(), Some(true), "{r}");
        assert_eq!(r.get("pulls").as_u64(), Some(4));
        let mut want = vec![0f32; 4];
        engine.pull_matrix(&[3, 1], &[2, 9], &mut want);
        let dists = r.get("dists").idx(0);
        for (k, w) in want.iter().enumerate() {
            assert_eq!(dists.idx(k).as_u64(), Some(w.to_bits() as u64), "cell {k}");
        }

        // worker.health reports the configured shard range.
        state.set_worker_shards(Some((0, 100)));
        let h = state.handle(&req(r#"{"op":"worker.health"}"#));
        assert_eq!(h.get("ok").as_bool(), Some(true), "{h}");
        assert_eq!(h.get("shards").idx(0).as_usize(), Some(0));
        assert_eq!(h.get("shards").idx(1).as_usize(), Some(100));
        assert_eq!(h.get("worker_pull_ops").as_u64(), Some(2));
        let m = state.handle(&req(r#"{"op":"metrics"}"#));
        assert_eq!(m.get("worker_pull_ops").as_u64(), Some(2));
        assert_eq!(m.get("worker_shards").idx(1).as_usize(), Some(100));
        assert!(matches!(m.get("coordinator"), Value::Null), "not a coordinator");

        // malformed pulls fail cleanly
        for bad in [
            r#"{"op":"worker.pull","dataset":"nope","arms":[0],"ref_groups":[[0]]}"#,
            r#"{"op":"worker.pull","dataset":"toy","arms":[],"ref_groups":[[0]]}"#,
            r#"{"op":"worker.pull","dataset":"toy","arms":[200],"ref_groups":[[0]]}"#,
            r#"{"op":"worker.pull","dataset":"toy","arms":[0],"ref_groups":[[]]}"#,
            r#"{"op":"worker.pull","dataset":"toy","arms":[0],"ref_groups":[[999]]}"#,
            r#"{"op":"worker.pull","dataset":"toy","arms_range":[4,2],"ref_groups":[[0]]}"#,
            r#"{"op":"worker.pull","dataset":"toy","arms":[0]}"#,
        ] {
            let r = state.handle(&req(bad));
            assert_eq!(r.get("ok").as_bool(), Some(false), "should fail: {bad}");
        }
    }

    #[test]
    fn metrics_export_net_counters() {
        let state = State::new();
        state.net.accepted.add(2);
        state.net.shed.add(1);
        state.net.v1_requests.add(3);
        let m = state.handle(&req(r#"{"op":"metrics"}"#));
        let net = m.get("net");
        assert_eq!(net.get("accepted").as_u64(), Some(2));
        assert_eq!(net.get("shed").as_u64(), Some(1));
        assert_eq!(net.get("connections").as_u64(), Some(0));
        // handle() itself goes through the v1 shim, so the metrics request
        // and the counter priming above are all v1 traffic.
        assert!(net.get("v1_requests").as_u64().unwrap() >= 3);
    }
}
