//! Medoid service: a small deployable front-end for the library.
//!
//! Line-delimited JSON over TCP (std::net threads; tokio is outside the
//! offline dependency closure). Datasets are registered once (generated or
//! loaded); prepared engine sessions are cached per `(dataset, metric)` in
//! an [`EngineCache`], so only the *first* query on a dataset pays the
//! O(n·d) preparation pass; and all request execution funnels through a
//! bounded-queue [`Executor`] whose workers run on top of the persistent
//! worker pool — connection threads only parse and write lines.
//!
//! ```text
//! → {"op":"register","name":"cells","kind":"rnaseq","n":2000,"dim":256,"seed":1}
//! ← {"ok":true,"name":"cells","n":2000,"metric":"l1","sharded":false}
//! → {"op":"register","name":"big","path":"/data/shards/manifest.json"}
//!                                            # shard manifest: no loading —
//! ← {"ok":true,"name":"big","n":1000000,...} # rows stream from disk on demand
//! → {"op":"medoid","dataset":"cells","algo":"corrsh","pulls_per_arm":24,"seed":7}
//! ← {"ok":true,"medoid":412,"pulls":52000,"wall_ms":8.3,"seed":7,"algo":"corrsh"}
//! → {"op":"medoid_batch","dataset":"cells","seeds":[1,2,3],"pulls_per_arm":24}
//! ← {"ok":true,"jobs":3,"pulls":156000,"results":[{"seed":1,...},...]}
//! → {"op":"kmedoids","dataset":"cells","k":5,"seed":7}   # BUILD/SWAP clustering
//! ← {"ok":true,"medoids":[0,412,...],"cluster_sizes":[...],"loss":1.93,
//!    "pulls":184000,"build_pulls":...,"swap_pulls":...,"polish_pulls":...}
//! → {"op":"stats","dataset":"cells"}         # Δ/ρ/H₂ summary
//! → {"op":"metrics"}                         # counters, cache, queue depth
//! → {"op":"list"}                            # registered datasets
//! → {"op":"unregister","name":"cells"}
//! → {"op":"ping"}
//! → {"op":"shutdown"}                        # drain + clean exit
//! ```
//!
//! Big seeds: JSON numbers are f64, exact only to 2⁵³ — send full-width
//! seeds as strings (`"seed":"18446744073709551615"`); see
//! [`Value::as_u64`].

use std::collections::{HashMap, VecDeque};
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

use crate::bandits::MedoidAlgorithm;
use crate::config::{AlgoConfig, KMedoidsConfig, ServerConfig};
use crate::kmedoids::ClusteringAlgorithm;
use crate::data::synth::{Kind, SynthConfig};
use crate::data::Data;
use crate::distance::Metric;
use crate::engine::{EngineCache, NativeEngine};
use crate::metrics::{Counter, Gauge};
use crate::util::error::{Context, Result};
use crate::util::json::{self, Value};
use crate::util::rng::Rng;
use crate::util::threads;

struct Entry {
    data: Arc<Data>,
    metric: Metric,
    /// Monotone registry counter for this binding of the name to data —
    /// part of the engine-cache key, so a re-register racing an in-flight
    /// query can never leave a stale session serving the new name.
    generation: u64,
}

/// Shared server state: the dataset registry, the prepared-engine session
/// cache, and request counters. `handle` is pure request→response (no
/// I/O), so the whole protocol is unit-testable without sockets.
#[derive(Default)]
pub struct State {
    datasets: Mutex<HashMap<String, Arc<Entry>>>,
    cache: EngineCache,
    generation: AtomicU64,
    pub requests: AtomicU64,
    pub errors: AtomicU64,
    pulls: Counter,
    /// Completed `kmedoids` runs (the clustering workload's op counter).
    kmedoids_runs: Counter,
    shutdown: AtomicBool,
}

impl State {
    pub fn new() -> Arc<Self> {
        Arc::new(State::default())
    }

    /// True once a `shutdown` request has been accepted.
    pub fn shutting_down(&self) -> bool {
        self.shutdown.load(Ordering::Acquire)
    }

    /// The prepared-engine session cache (hit/miss counters feed the
    /// `metrics` op).
    pub fn engine_cache(&self) -> &EngineCache {
        &self.cache
    }

    fn get(&self, name: &str) -> Result<Arc<Entry>> {
        self.datasets
            .lock()
            .unwrap()
            .get(name)
            .cloned()
            .with_context(|| format!("dataset {name:?} not registered"))
    }

    /// Cached-session engine: O(n·d) preparation only on the first call
    /// per `(dataset, generation, metric)`.
    fn engine(&self, name: &str, entry: &Entry) -> NativeEngine {
        let prepared =
            self.cache.get_or_prepare(name, entry.generation, entry.metric, &entry.data);
        NativeEngine::from_prepared(prepared, threads::default_threads())
    }

    /// Handle one request object → response object.
    pub fn handle(&self, req: &Value) -> Value {
        self.requests.fetch_add(1, Ordering::Relaxed);
        match self.dispatch(req) {
            Ok(v) => v,
            Err(e) => {
                self.errors.fetch_add(1, Ordering::Relaxed);
                Value::from_pairs(vec![
                    ("ok", false.into()),
                    ("error", format!("{e:#}").into()),
                ])
            }
        }
    }

    fn dispatch(&self, req: &Value) -> Result<Value> {
        match req.get("op").as_str().context("missing op")? {
            "ping" => Ok(Value::from_pairs(vec![("ok", true.into()), ("pong", true.into())])),
            "list" => {
                let names: Vec<Value> = self
                    .datasets
                    .lock()
                    .unwrap()
                    .keys()
                    .map(|k| Value::Str(k.clone()))
                    .collect();
                Ok(Value::from_pairs(vec![
                    ("ok", true.into()),
                    ("datasets", Value::Array(names)),
                ]))
            }
            "register" => {
                let name = req.get("name").as_str().context("missing name")?.to_string();
                // Two sources: `path` (a .npy/.csr file, or a shard
                // manifest — the latter registers *without loading*, rows
                // stream from disk on demand) or `kind` (a generator).
                let (data, metric) = if let Some(path) = req.get("path").as_str() {
                    let data = crate::data::loader::load(path)?;
                    let metric: Metric = match req.get("metric").as_str() {
                        Some(m) => m.parse()?,
                        None if data.is_sparse() => Metric::L1,
                        None => Metric::L2,
                    };
                    crate::ensure!(data.n() >= 2, "register: dataset has n = {}", data.n());
                    (Arc::new(data), metric)
                } else {
                    let kind: Kind =
                        req.get("kind").as_str().context("missing kind (or path)")?.parse()?;
                    let mut cfg = SynthConfig {
                        n: req.get("n").as_usize().unwrap_or(1000),
                        dim: req.get("dim").as_usize().unwrap_or(256),
                        seed: req.get("seed").as_u64().unwrap_or(0),
                        ..Default::default()
                    };
                    if let Some(c) = req.get("clusters").as_usize() {
                        crate::ensure!(c >= 1, "register: clusters must be >= 1");
                        cfg.clusters = c;
                    }
                    crate::ensure!(cfg.n >= 2, "register: n must be >= 2 (got {})", cfg.n);
                    crate::ensure!(cfg.dim >= 1, "register: dim must be >= 1");
                    let metric = match req.get("metric").as_str() {
                        Some(m) => m.parse()?,
                        None => kind.default_metric(),
                    };
                    (Arc::new(kind.generate(&cfg)), metric)
                };
                let n = data.n();
                let sharded = matches!(&*data, Data::Sharded(_));
                // Stale sessions for the old binding of this name are
                // swept here (memory hygiene); correctness against the
                // re-register race comes from the generation cache key.
                self.cache.invalidate(&name);
                let generation = self.generation.fetch_add(1, Ordering::Relaxed);
                let entry = Arc::new(Entry { data, metric, generation });
                self.datasets.lock().unwrap().insert(name.clone(), entry.clone());
                // Optional eager warmup so the first query is already hot.
                if req.get("prepare").as_bool() == Some(true) {
                    let _ = self.engine(&name, &entry);
                }
                Ok(Value::from_pairs(vec![
                    ("ok", true.into()),
                    ("name", name.into()),
                    ("n", n.into()),
                    ("metric", metric.name().into()),
                    ("sharded", sharded.into()),
                ]))
            }
            "unregister" => {
                let name = req
                    .get("name")
                    .as_str()
                    .or(req.get("dataset").as_str())
                    .context("missing name")?;
                let removed = self.datasets.lock().unwrap().remove(name);
                self.cache.invalidate(name);
                crate::ensure!(removed.is_some(), "dataset {name:?} not registered");
                Ok(Value::from_pairs(vec![
                    ("ok", true.into()),
                    ("name", name.into()),
                    ("removed", true.into()),
                ]))
            }
            "medoid" => {
                let name = req.get("dataset").as_str().context("missing dataset")?;
                let entry = self.get(name)?;
                let algo = build_algo(req, entry.data.n())?;
                let seed = req.get("seed").as_u64().unwrap_or(0);
                let engine = self.engine(name, &entry);
                let mut rng = Rng::seeded(seed);
                let res = algo.run(&engine, &mut rng);
                self.pulls.add(res.pulls);
                Ok(Value::from_pairs(vec![
                    ("ok", true.into()),
                    ("medoid", res.best.into()),
                    ("pulls", res.pulls.into()),
                    ("wall_ms", (res.wall.as_secs_f64() * 1e3).into()),
                    ("algo", algo.name().into()),
                    ("seed", seed_value(seed)),
                ]))
            }
            "medoid_batch" => self.medoid_batch(req),
            "kmedoids" => {
                let name = req.get("dataset").as_str().context("missing dataset")?;
                let entry = self.get(name)?;
                let n = entry.data.n();
                let cfg = KMedoidsConfig::from_json_value(req)?;
                crate::ensure!(cfg.k <= n, "kmedoids: k = {} exceeds dataset size n = {n}", cfg.k);
                let seed = req.get("seed").as_u64().unwrap_or(0);
                let engine = self.engine(name, &entry);
                let mut rng = Rng::seeded(seed);
                let res = cfg.build().run(&engine, &mut rng);
                self.pulls.add(res.pulls());
                self.kmedoids_runs.add(1);
                let medoids: Vec<Value> = res.medoids.iter().map(|&m| Value::from(m)).collect();
                let sizes: Vec<Value> =
                    res.cluster_sizes().iter().map(|&s| Value::from(s)).collect();
                let mut pairs = vec![
                    ("ok", true.into()),
                    ("algo", "bandit-kmedoids".into()),
                    ("k", res.medoids.len().into()),
                    ("medoids", Value::Array(medoids)),
                    ("cluster_sizes", Value::Array(sizes)),
                    ("loss", res.loss.into()),
                    ("pulls", res.pulls().into()),
                    ("build_pulls", res.build_pulls.into()),
                    ("swap_pulls", res.swap_pulls.into()),
                    ("polish_pulls", res.polish_pulls.into()),
                    ("swap_rounds", res.swap_rounds.into()),
                    ("swaps_accepted", res.swaps_accepted.into()),
                    ("wall_ms", (res.wall.as_secs_f64() * 1e3).into()),
                    ("seed", seed_value(seed)),
                ];
                // Full per-point assignments are O(n) on the wire — opt-in.
                if req.get("assignments").as_bool() == Some(true) {
                    let a: Vec<Value> = res.assignments.iter().map(|&x| Value::from(x)).collect();
                    pairs.push(("assignments", Value::Array(a)));
                }
                Ok(Value::from_pairs(pairs))
            }
            "stats" => {
                let name = req.get("dataset").as_str().context("missing dataset")?;
                let entry = self.get(name)?;
                let engine = self.engine(name, &entry);
                let mut rng = Rng::seeded(0);
                let st = crate::stats::instance_stats(
                    &engine,
                    256.min(entry.data.n()),
                    &mut rng,
                );
                Ok(Value::from_pairs(vec![
                    ("ok", true.into()),
                    ("medoid", st.medoid.into()),
                    ("sigma", st.sigma.into()),
                    ("h2", st.h2.into()),
                    ("h2_tilde", st.h2_tilde.into()),
                    ("gain_ratio", st.gain_ratio().into()),
                ]))
            }
            "metrics" => Ok(Value::from_pairs(vec![
                ("ok", true.into()),
                ("requests", self.requests.load(Ordering::Relaxed).into()),
                ("errors", self.errors.load(Ordering::Relaxed).into()),
                ("pulls", self.pulls.get().into()),
                ("kmedoids_runs", self.kmedoids_runs.get().into()),
                ("datasets", self.datasets.lock().unwrap().len().into()),
                (
                    "engine_cache",
                    Value::from_pairs(vec![
                        ("entries", self.cache.len().into()),
                        ("hits", self.cache.hits().into()),
                        ("misses", self.cache.misses().into()),
                        ("nan_pulls", self.cache.nan_pulls().into()),
                    ]),
                ),
                (
                    // Shard-store traffic (process-global): monotone
                    // hit/miss counters plus the pinned-bytes gauge, so
                    // "the million-point dataset stayed inside its cache
                    // budget" is observable, not assumed (DESIGN.md §12).
                    "shard_cache",
                    {
                        let s = crate::data::store::cache_stats();
                        Value::from_pairs(vec![
                            ("hits", s.hits().into()),
                            ("misses", s.misses().into()),
                            ("pinned_bytes", s.pinned_bytes().into()),
                        ])
                    },
                ),
            ])),
            "shutdown" => {
                self.shutdown.store(true, Ordering::Release);
                Ok(Value::from_pairs(vec![
                    ("ok", true.into()),
                    ("shutting_down", true.into()),
                ]))
            }
            other => crate::bail!("unknown op {other:?}"),
        }
    }

    /// Many seeds (and optionally per-seed budgets) against one dataset,
    /// answered in a single sweep over one cached session: the engine is
    /// fetched once and the jobs fan out over the worker pool.
    fn medoid_batch(&self, req: &Value) -> Result<Value> {
        let name = req.get("dataset").as_str().context("missing dataset")?;
        let entry = self.get(name)?;
        let n = entry.data.n();
        const MAX_JOBS: usize = 4096;
        let seeds: Vec<u64> = match req.get("seeds").as_array() {
            Some(arr) => {
                crate::ensure!(
                    arr.len() <= MAX_JOBS,
                    "medoid_batch: at most {MAX_JOBS} jobs per request (got {})",
                    arr.len()
                );
                arr.iter()
                    .map(|v| v.as_u64().context("seeds entries must be non-negative integers"))
                    .collect::<Result<_>>()?
            }
            None => {
                let s0 = req.get("seed").as_u64().unwrap_or(0);
                let count = req.get("count").as_usize().unwrap_or(1);
                // Cap BEFORE materializing: `count` is client-controlled
                // and would otherwise size an allocation directly.
                crate::ensure!(
                    count <= MAX_JOBS,
                    "medoid_batch: at most {MAX_JOBS} jobs per request (got count {count})"
                );
                (0..count as u64).map(|i| s0.wrapping_add(i)).collect()
            }
        };
        crate::ensure!(!seeds.is_empty(), "medoid_batch: empty seed list");
        let mut budgets: Vec<Option<f64>> = vec![None; seeds.len()];
        if let Some(arr) = req.get("budgets").as_array() {
            crate::ensure!(
                arr.len() == seeds.len(),
                "medoid_batch: budgets len {} != seeds len {}",
                arr.len(),
                seeds.len()
            );
            for (slot, v) in budgets.iter_mut().zip(arr) {
                *slot = Some(v.as_f64().context("budgets entries must be numbers")?);
            }
        }
        // Validate every job's algorithm config up front so a bad job fails
        // the whole request instead of surfacing mid-sweep.
        let jobs: Vec<(u64, AlgoConfig)> = seeds
            .iter()
            .zip(&budgets)
            .map(|(&seed, &budget)| Ok((seed, algo_config(req, n, budget)?)))
            .collect::<Result<_>>()?;
        let engine = self.engine(name, &entry);
        let t0 = Instant::now();
        let workers = threads::default_threads().min(jobs.len()).max(1);
        let outcomes: Vec<(Value, u64)> = threads::parallel_map(jobs.len(), workers, |i| {
            let (seed, cfg) = &jobs[i];
            let mut rng = Rng::seeded(*seed);
            let res = cfg.build(n).run(&engine, &mut rng);
            let v = Value::from_pairs(vec![
                ("seed", seed_value(*seed)),
                ("algo", cfg.name().into()),
                ("medoid", res.best.into()),
                ("pulls", res.pulls.into()),
                ("wall_ms", (res.wall.as_secs_f64() * 1e3).into()),
            ]);
            (v, res.pulls)
        });
        let total_pulls: u64 = outcomes.iter().map(|(_, p)| p).sum();
        self.pulls.add(total_pulls);
        let results: Vec<Value> = outcomes.into_iter().map(|(v, _)| v).collect();
        Ok(Value::from_pairs(vec![
            ("ok", true.into()),
            ("dataset", name.into()),
            ("jobs", results.len().into()),
            ("pulls", total_pulls.into()),
            ("wall_ms", (t0.elapsed().as_secs_f64() * 1e3).into()),
            ("results", Value::Array(results)),
        ]))
    }
}

/// Algorithm selection from a request, with PR-2 fixes: `refs_per_arm`
/// clamps to n (the old default of 1000 asked RAND for more distinct
/// references than small datasets have) and seeds/caps read through the
/// lossless [`Value::as_u64`]. `budget` overrides the algorithm's primary
/// knob (per-job budgets in `medoid_batch`).
fn algo_config(req: &Value, n: usize, budget: Option<f64>) -> Result<AlgoConfig> {
    let name = req.get("algo").as_str().unwrap_or("corrsh");
    let ppa = |d: f64| budget.or(req.get("pulls_per_arm").as_f64()).unwrap_or(d);
    let cfg = match name {
        "corrsh" => AlgoConfig::CorrSh { pulls_per_arm: ppa(24.0) },
        "sh" | "seq-halving" => AlgoConfig::SeqHalving { pulls_per_arm: ppa(24.0) },
        "meddit" => AlgoConfig::Meddit {
            delta: req.get("delta").as_f64().unwrap_or(0.0),
            cap: budget.map(|b| b.max(0.0) as u64).or(req.get("cap").as_u64()).unwrap_or(0),
        },
        "rand" => AlgoConfig::Rand {
            refs_per_arm: budget
                .map(|b| b.max(0.0) as usize)
                .or(req.get("refs_per_arm").as_usize())
                .unwrap_or(1000)
                .min(n),
        },
        "toprank" => AlgoConfig::TopRank {
            phase1_refs: budget
                .map(|b| b.max(0.0) as usize)
                .or(req.get("phase1_refs").as_usize())
                .unwrap_or(1000)
                .min(n),
        },
        "exact" => AlgoConfig::Exact,
        other => crate::bail!("unknown algo {other:?}"),
    };
    Ok(cfg)
}

fn build_algo(req: &Value, n: usize) -> Result<Box<dyn MedoidAlgorithm>> {
    Ok(algo_config(req, n, None)?.build(n))
}

fn error_response(msg: &str) -> Value {
    Value::from_pairs(vec![("ok", false.into()), ("error", msg.into())])
}

/// Echo a seed losslessly: numbers up to 2⁵³ stay JSON numbers; larger
/// values go back out as the decimal-string form the request path accepts
/// (`Value::as_u64`), so an echoed seed always reproduces the same run.
fn seed_value(seed: u64) -> Value {
    if seed <= (1u64 << 53) {
        seed.into()
    } else {
        Value::Str(seed.to_string())
    }
}

/// One queued request plus the slot its response lands in.
struct ExecJob {
    req: Value,
    slot: Arc<ResponseSlot>,
}

#[derive(Default)]
struct ResponseSlot {
    value: Mutex<Option<Value>>,
    ready: Condvar,
}

impl ResponseSlot {
    fn fill(&self, v: Value) {
        *self.value.lock().unwrap() = Some(v);
        self.ready.notify_all();
    }

    fn wait(&self) -> Value {
        let mut v = self.value.lock().unwrap();
        while v.is_none() {
            v = self.ready.wait(v).unwrap();
        }
        v.take().expect("slot filled")
    }
}

struct ExecQueue {
    jobs: VecDeque<ExecJob>,
    shutdown: bool,
}

struct ExecShared {
    queue: Mutex<ExecQueue>,
    /// Workers wait here for jobs.
    ready: Condvar,
    /// Submitters wait here while the bounded queue is full.
    space: Condvar,
    cap: usize,
    depth: Gauge,
}

/// Bounded-queue request executor: a fixed set of workers drains a
/// capacity-capped queue of protocol requests. Connection threads only
/// parse lines and block in [`Executor::submit`] — heavy work (engine
/// queries, which themselves fan out on the worker pool) happens on
/// executor workers, so a burst of clients applies backpressure instead of
/// spawning a compute avalanche.
pub struct Executor {
    state: Arc<State>,
    shared: Arc<ExecShared>,
    workers: Mutex<Vec<std::thread::JoinHandle<()>>>,
}

impl Executor {
    /// `workers == 0` means `threads::default_threads()`.
    pub fn new(state: Arc<State>, workers: usize, queue_cap: usize) -> Arc<Self> {
        let workers = if workers == 0 { threads::default_threads() } else { workers };
        let shared = Arc::new(ExecShared {
            queue: Mutex::new(ExecQueue { jobs: VecDeque::new(), shutdown: false }),
            ready: Condvar::new(),
            space: Condvar::new(),
            cap: queue_cap.max(1),
            depth: Gauge::new(),
        });
        let handles = (0..workers)
            .map(|i| {
                let state = state.clone();
                let shared = shared.clone();
                std::thread::Builder::new()
                    .name(format!("corrsh-exec-{i}"))
                    .spawn(move || exec_worker(state, shared))
                    .expect("spawn executor worker")
            })
            .collect();
        Arc::new(Executor { state, shared, workers: Mutex::new(handles) })
    }

    pub fn state(&self) -> &Arc<State> {
        &self.state
    }

    pub fn queue_depth(&self) -> u64 {
        self.shared.depth.get()
    }

    pub fn queue_cap(&self) -> usize {
        self.shared.cap
    }

    pub fn workers(&self) -> usize {
        self.workers.lock().unwrap().len()
    }

    /// Submit one request and block for its response. Applies backpressure
    /// (blocks) while the bounded queue is full; after shutdown, returns an
    /// error response immediately.
    pub fn submit(&self, req: Value) -> Value {
        let is_metrics = req.get("op").as_str() == Some("metrics");
        let slot = Arc::new(ResponseSlot::default());
        {
            let mut q = self.shared.queue.lock().unwrap();
            loop {
                if q.shutdown {
                    return error_response("server shutting down");
                }
                if q.jobs.len() < self.shared.cap {
                    break;
                }
                q = self.shared.space.wait(q).unwrap();
            }
            q.jobs.push_back(ExecJob { req, slot: slot.clone() });
            self.shared.depth.inc();
        }
        self.shared.ready.notify_one();
        let mut resp = slot.wait();
        if is_metrics {
            // Executor-level numbers are merged here (the pure State
            // doesn't know about queues).
            if let Value::Object(obj) = &mut resp {
                obj.insert(
                    "executor".to_string(),
                    Value::from_pairs(vec![
                        ("queue_depth", self.queue_depth().into()),
                        ("queue_cap", self.shared.cap.into()),
                        ("workers", self.workers().into()),
                    ]),
                );
            }
        }
        resp
    }

    /// Stop accepting new work, drain already-queued requests, join the
    /// workers. Idempotent.
    pub fn shutdown(&self) {
        self.shared.queue.lock().unwrap().shutdown = true;
        self.shared.ready.notify_all();
        self.shared.space.notify_all();
        let handles: Vec<_> = self.workers.lock().unwrap().drain(..).collect();
        for h in handles {
            let _ = h.join();
        }
    }
}

fn exec_worker(state: Arc<State>, shared: Arc<ExecShared>) {
    let mut q = shared.queue.lock().unwrap();
    loop {
        match q.jobs.pop_front() {
            Some(job) => {
                shared.depth.dec();
                drop(q);
                shared.space.notify_one();
                // A panicking handler must neither kill this worker nor
                // leave the submitter blocked on an unfilled slot forever.
                let resp = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    state.handle(&job.req)
                }))
                .unwrap_or_else(|_| {
                    state.errors.fetch_add(1, Ordering::Relaxed);
                    error_response("internal error: request handler panicked")
                });
                job.slot.fill(resp);
                q = shared.queue.lock().unwrap();
            }
            None if q.shutdown => return,
            None => q = shared.ready.wait(q).unwrap(),
        }
    }
}

fn client_loop(exec: Arc<Executor>, stream: TcpStream) {
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    // Our side of the connection = the listener's address; used to wake the
    // accept loop after a shutdown request.
    let local = stream.local_addr().ok();
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let Ok(line) = line else { break };
        if line.trim().is_empty() {
            continue;
        }
        let resp = match json::parse(&line) {
            Ok(req) => exec.submit(req),
            Err(e) => error_response(&format!("bad json: {e}")),
        };
        let mut out = json::to_string(&resp);
        out.push('\n');
        if writer.write_all(out.as_bytes()).is_err() {
            break;
        }
        if exec.state().shutting_down() {
            if let Some(addr) = local {
                let _ = TcpStream::connect(addr);
            }
            break;
        }
    }
}

fn accept_loop(exec: &Arc<Executor>, listener: TcpListener) {
    for stream in listener.incoming() {
        if exec.state().shutting_down() {
            break;
        }
        match stream {
            Ok(s) => {
                let e = exec.clone();
                std::thread::spawn(move || client_loop(e, s));
            }
            Err(e) => eprintln!("accept error: {e}"),
        }
    }
}

/// Serve until a `shutdown` request arrives (e.g. on "127.0.0.1:7878"),
/// with the default executor shape. One thread per connection; execution
/// bounded by the executor.
pub fn serve(state: Arc<State>, addr: &str) -> Result<()> {
    let cfg = ServerConfig { addr: addr.to_string(), ..Default::default() };
    serve_with(state, &cfg)
}

/// Serve with an explicit [`ServerConfig`] (address, executor workers,
/// queue capacity). Returns cleanly after a `shutdown` request: the accept
/// loop stops and the executor drains and joins.
pub fn serve_with(state: Arc<State>, cfg: &ServerConfig) -> Result<()> {
    let listener =
        TcpListener::bind(&cfg.addr).with_context(|| format!("bind {}", cfg.addr))?;
    eprintln!("corrsh-serve listening on {}", listener.local_addr()?);
    let exec = Executor::new(state, cfg.workers, cfg.queue_cap);
    accept_loop(&exec, listener);
    exec.shutdown();
    Ok(())
}

/// Bind to an ephemeral port and serve in a background thread (tests/demo).
pub fn serve_background(state: Arc<State>) -> Result<std::net::SocketAddr> {
    serve_background_with(state, &ServerConfig::default())
}

/// `serve_background` with an explicit executor shape (the configured
/// `addr` is ignored — the port is always ephemeral).
pub fn serve_background_with(
    state: Arc<State>,
    cfg: &ServerConfig,
) -> Result<std::net::SocketAddr> {
    let listener = TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?;
    let exec = Executor::new(state, cfg.workers, cfg.queue_cap);
    std::thread::spawn(move || {
        accept_loop(&exec, listener);
        exec.shutdown();
    });
    Ok(addr)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(s: &str) -> Value {
        json::parse(s).unwrap()
    }

    fn register_toy(state: &State, name: &str) {
        let r = state.handle(&req(&format!(
            r#"{{"op":"register","name":"{name}","kind":"gaussian","n":200,"dim":8,"seed":4}}"#
        )));
        assert_eq!(r.get("ok").as_bool(), Some(true), "register failed: {r}");
    }

    #[test]
    fn protocol_register_and_query() {
        let state = State::new();
        let r = state.handle(&req(
            r#"{"op":"register","name":"toy","kind":"gaussian","n":200,"dim":8,"seed":4}"#,
        ));
        assert_eq!(r.get("ok").as_bool(), Some(true));
        assert_eq!(r.get("n").as_usize(), Some(200));
        assert_eq!(r.get("metric").as_str(), Some("l2"));

        let r = state.handle(&req(
            r#"{"op":"medoid","dataset":"toy","algo":"corrsh","pulls_per_arm":48,"seed":1}"#,
        ));
        assert_eq!(r.get("ok").as_bool(), Some(true));
        assert_eq!(r.get("medoid").as_usize(), Some(0), "planted medoid");
        assert!(r.get("pulls").as_f64().unwrap() > 0.0);
        assert_eq!(r.get("seed").as_u64(), Some(1));

        let r = state.handle(&req(r#"{"op":"list"}"#));
        assert_eq!(r.get("datasets").idx(0).as_str(), Some("toy"));
    }

    #[test]
    fn protocol_errors_are_reported() {
        let state = State::new();
        let r = state.handle(&req(r#"{"op":"medoid","dataset":"nope"}"#));
        assert_eq!(r.get("ok").as_bool(), Some(false));
        assert!(r.get("error").as_str().unwrap().contains("not registered"));
        let r = state.handle(&req(r#"{"op":"frobnicate"}"#));
        assert_eq!(r.get("ok").as_bool(), Some(false));
        assert_eq!(state.errors.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn rand_defaults_clamp_to_n() {
        let state = State::new();
        register_toy(&state, "toy");
        // Old default asked RAND for 1000 distinct references on n=200;
        // the honest default is m = n → an exact sweep of n*m pulls.
        let r = state.handle(&req(r#"{"op":"medoid","dataset":"toy","algo":"rand","seed":2}"#));
        assert_eq!(r.get("ok").as_bool(), Some(true), "{r}");
        assert_eq!(r.get("pulls").as_u64(), Some(200 * 200));
        // Explicit oversized values clamp too.
        let r = state.handle(&req(
            r#"{"op":"medoid","dataset":"toy","algo":"rand","refs_per_arm":5000,"seed":2}"#,
        ));
        assert_eq!(r.get("pulls").as_u64(), Some(200 * 200));
    }

    #[test]
    fn register_accepts_string_seed_beyond_f64() {
        let state = State::new();
        let r = state.handle(&req(
            r#"{"op":"register","name":"big","kind":"gaussian","n":64,"dim":4,
                "seed":"18446744073709551615"}"#,
        ));
        assert_eq!(r.get("ok").as_bool(), Some(true), "{r}");
        assert_eq!(r.get("n").as_usize(), Some(64));
        // A big query seed is echoed losslessly (string form), so feeding
        // the echo back reproduces the same run.
        let r = state.handle(&req(
            r#"{"op":"medoid","dataset":"big","pulls_per_arm":8,"seed":"18446744073709551615"}"#,
        ));
        assert_eq!(r.get("ok").as_bool(), Some(true), "{r}");
        assert_eq!(r.get("seed").as_u64(), Some(u64::MAX));
        assert_eq!(r.get("seed").as_str(), Some("18446744073709551615"));
    }

    #[test]
    fn register_by_path_matches_generator_registration() {
        // The same bytes registered three ways — generator, resident .npy,
        // shard manifest — must give identical medoid answers, and the
        // manifest registration must report sharded:true.
        let dir = std::env::temp_dir().join("corrsh-server-tests").join("register-path");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let cfg = crate::data::synth::SynthConfig { n: 150, dim: 8, seed: 4, ..Default::default() };
        let data = Kind::Gaussian.generate(&cfg);
        let npy = dir.join("toy.npy");
        crate::data::loader::save_dense_npy(&npy, &data.to_dense()).unwrap();
        let manifest = crate::data::store::write_sharded(&data, dir.join("shards"), 32).unwrap();

        let state = State::new();
        let r = state.handle(&req(
            r#"{"op":"register","name":"gen","kind":"gaussian","n":150,"dim":8,"seed":4}"#,
        ));
        assert_eq!(r.get("sharded").as_bool(), Some(false));
        let r = state.handle(&req(&format!(
            r#"{{"op":"register","name":"npy","path":{:?},"metric":"l2"}}"#,
            npy.to_str().unwrap()
        )));
        assert_eq!(r.get("ok").as_bool(), Some(true), "{r}");
        assert_eq!(r.get("sharded").as_bool(), Some(false));
        let r = state.handle(&req(&format!(
            r#"{{"op":"register","name":"shards","path":{:?},"metric":"l2"}}"#,
            manifest.to_str().unwrap()
        )));
        assert_eq!(r.get("ok").as_bool(), Some(true), "{r}");
        assert_eq!(r.get("sharded").as_bool(), Some(true));
        assert_eq!(r.get("n").as_usize(), Some(150));

        let answers: Vec<(Option<usize>, Option<u64>)> = ["gen", "npy", "shards"]
            .iter()
            .map(|name| {
                let r = state.handle(&req(&format!(
                    r#"{{"op":"medoid","dataset":"{name}","pulls_per_arm":32,"seed":7}}"#
                )));
                assert_eq!(r.get("ok").as_bool(), Some(true), "{name}: {r}");
                (r.get("medoid").as_usize(), r.get("pulls").as_u64())
            })
            .collect();
        assert_eq!(answers[0], answers[1], "generator vs npy");
        assert_eq!(answers[1], answers[2], "npy vs shard manifest");

        // shard_cache gauges are exported and the manifest dataset moved them
        let m = state.handle(&req(r#"{"op":"metrics"}"#));
        let sc = m.get("shard_cache");
        assert!(sc.get("hits").as_u64().is_some() && sc.get("misses").as_u64().is_some());
        // registering a bogus path fails cleanly
        let r = state.handle(&req(r#"{"op":"register","name":"x","path":"/no/such.npy"}"#));
        assert_eq!(r.get("ok").as_bool(), Some(false));
    }

    #[test]
    fn register_rejects_degenerate_shapes() {
        let state = State::new();
        for bad in [
            r#"{"op":"register","name":"z","kind":"gaussian","n":0,"dim":4}"#,
            r#"{"op":"register","name":"z","kind":"gaussian","n":1,"dim":4}"#,
            r#"{"op":"register","name":"z","kind":"gaussian","n":10,"dim":0}"#,
        ] {
            let r = state.handle(&req(bad));
            assert_eq!(r.get("ok").as_bool(), Some(false), "should fail: {bad}");
        }
        let l = state.handle(&req(r#"{"op":"list"}"#));
        assert_eq!(l.get("datasets").as_array().unwrap().len(), 0);
    }

    #[test]
    fn second_query_hits_the_session_cache() {
        // The PR's acceptance check: the second medoid request on a
        // registered dataset performs zero engine preparation, observable
        // through the metrics op.
        let state = State::new();
        register_toy(&state, "toy");
        let m = state.handle(&req(r#"{"op":"metrics"}"#));
        assert_eq!(m.get("engine_cache").get("misses").as_u64(), Some(0));
        assert_eq!(m.get("engine_cache").get("entries").as_u64(), Some(0));

        let r = state.handle(&req(r#"{"op":"medoid","dataset":"toy","seed":1}"#));
        assert_eq!(r.get("ok").as_bool(), Some(true));
        let m = state.handle(&req(r#"{"op":"metrics"}"#));
        assert_eq!(m.get("engine_cache").get("misses").as_u64(), Some(1));
        assert_eq!(m.get("engine_cache").get("hits").as_u64(), Some(0));

        let r2 = state.handle(&req(r#"{"op":"medoid","dataset":"toy","seed":1}"#));
        assert_eq!(r2.get("medoid").as_usize(), r.get("medoid").as_usize());
        let m = state.handle(&req(r#"{"op":"metrics"}"#));
        assert_eq!(m.get("engine_cache").get("misses").as_u64(), Some(1), "no re-preparation");
        assert_eq!(m.get("engine_cache").get("hits").as_u64(), Some(1));
        assert_eq!(m.get("engine_cache").get("entries").as_u64(), Some(1));
        assert!(m.get("pulls").as_u64().unwrap() > 0);
        assert!(m.get("requests").as_u64().unwrap() >= 5);
        assert_eq!(m.get("datasets").as_u64(), Some(1));
    }

    #[test]
    fn reregister_invalidates_stale_sessions() {
        let state = State::new();
        register_toy(&state, "x");
        state.handle(&req(r#"{"op":"medoid","dataset":"x","seed":0}"#));
        // Same name, different data: the cached session must not survive.
        let r = state.handle(&req(
            r#"{"op":"register","name":"x","kind":"gaussian","n":150,"dim":8,"seed":99}"#,
        ));
        assert_eq!(r.get("ok").as_bool(), Some(true));
        let m = state.handle(&req(r#"{"op":"metrics"}"#));
        assert_eq!(m.get("engine_cache").get("entries").as_u64(), Some(0));
        state.handle(&req(r#"{"op":"medoid","dataset":"x","seed":0}"#));
        let m = state.handle(&req(r#"{"op":"metrics"}"#));
        assert_eq!(m.get("engine_cache").get("misses").as_u64(), Some(2));
    }

    #[test]
    fn register_prepare_flag_warms_cache() {
        let state = State::new();
        let r = state.handle(&req(
            r#"{"op":"register","name":"warm","kind":"gaussian","n":100,"dim":8,
                "seed":1,"prepare":true}"#,
        ));
        assert_eq!(r.get("ok").as_bool(), Some(true));
        // The first query is already a cache hit.
        state.handle(&req(r#"{"op":"medoid","dataset":"warm","seed":0}"#));
        let m = state.handle(&req(r#"{"op":"metrics"}"#));
        assert_eq!(m.get("engine_cache").get("hits").as_u64(), Some(1));
        assert_eq!(m.get("engine_cache").get("misses").as_u64(), Some(1));
    }

    #[test]
    fn medoid_batch_matches_individual_queries() {
        let state = State::new();
        register_toy(&state, "toy");
        let mut expect = Vec::new();
        for seed in [3u64, 7, 11, 42] {
            let r = state.handle(&req(&format!(
                r#"{{"op":"medoid","dataset":"toy","pulls_per_arm":48,"seed":{seed}}}"#
            )));
            expect.push((r.get("medoid").as_usize().unwrap(), r.get("pulls").as_u64().unwrap()));
        }
        let b = state.handle(&req(
            r#"{"op":"medoid_batch","dataset":"toy","pulls_per_arm":48,"seeds":[3,7,11,42]}"#,
        ));
        assert_eq!(b.get("ok").as_bool(), Some(true), "{b}");
        assert_eq!(b.get("jobs").as_usize(), Some(4));
        let results = b.get("results").as_array().unwrap();
        assert_eq!(results.len(), 4);
        for (i, (medoid, pulls)) in expect.iter().enumerate() {
            assert_eq!(results[i].get("medoid").as_usize(), Some(*medoid), "seed #{i}");
            assert_eq!(results[i].get("pulls").as_u64(), Some(*pulls), "seed #{i}");
        }
        let total: u64 = expect.iter().map(|&(_, p)| p).sum();
        assert_eq!(b.get("pulls").as_u64(), Some(total));
    }

    #[test]
    fn medoid_batch_seed_count_and_budgets() {
        let state = State::new();
        register_toy(&state, "toy");
        // seed+count shorthand
        let b = state.handle(&req(
            r#"{"op":"medoid_batch","dataset":"toy","seed":5,"count":3,"pulls_per_arm":16}"#,
        ));
        assert_eq!(b.get("jobs").as_usize(), Some(3));
        assert_eq!(b.get("results").idx(1).get("seed").as_u64(), Some(6));
        // per-job budgets change per-job pull counts
        let b = state.handle(&req(
            r#"{"op":"medoid_batch","dataset":"toy","seeds":[1,1],"budgets":[8,64]}"#,
        ));
        assert_eq!(b.get("ok").as_bool(), Some(true), "{b}");
        let lo = b.get("results").idx(0).get("pulls").as_u64().unwrap();
        let hi = b.get("results").idx(1).get("pulls").as_u64().unwrap();
        assert!(lo < hi, "budget 8 ({lo} pulls) must cost less than 64 ({hi})");
    }

    #[test]
    fn medoid_batch_error_paths() {
        let state = State::new();
        register_toy(&state, "toy");
        for bad in [
            r#"{"op":"medoid_batch","dataset":"toy","seeds":[]}"#,
            r#"{"op":"medoid_batch","dataset":"toy","seeds":[1,2],"budgets":[8]}"#,
            r#"{"op":"medoid_batch","dataset":"toy","seeds":[1],"algo":"nope"}"#,
            r#"{"op":"medoid_batch","dataset":"missing","seeds":[1]}"#,
            r#"{"op":"medoid_batch","dataset":"toy","seeds":[-1]}"#,
            // count is capped BEFORE the seed vector is materialized
            r#"{"op":"medoid_batch","dataset":"toy","seed":0,"count":200000000000}"#,
        ] {
            let r = state.handle(&req(bad));
            assert_eq!(r.get("ok").as_bool(), Some(false), "should fail: {bad}");
        }
    }

    #[test]
    fn kmedoids_op_recovers_planted_cluster_medoids() {
        // The PR's server-side acceptance check: k = 5 planted clusters on
        // n = 2000, ≥ 4/5 exact-medoid agreement at ≤ 5% of the exact
        // BUILD sweep (k·n² pulls), over a cached engine session.
        let state = State::new();
        let r = state.handle(&req(
            r#"{"op":"register","name":"mix","kind":"mixture","n":2000,"dim":16,
                "seed":42,"clusters":5}"#,
        ));
        assert_eq!(r.get("ok").as_bool(), Some(true), "{r}");
        let r = state.handle(&req(r#"{"op":"kmedoids","dataset":"mix","k":5,"seed":1}"#));
        assert_eq!(r.get("ok").as_bool(), Some(true), "{r}");
        let medoids = r.get("medoids").as_array().unwrap();
        assert_eq!(medoids.len(), 5);
        let hits = medoids.iter().filter(|m| m.as_usize().unwrap() < 5).count();
        assert!(hits >= 4, "planted-center agreement {hits}/5: {r}");
        let pulls = r.get("pulls").as_u64().unwrap();
        let exact = 5 * 2000u64 * 2000;
        assert!(pulls * 20 <= exact, "{pulls} pulls > 5% of exact {exact}");
        assert_eq!(
            pulls,
            r.get("build_pulls").as_u64().unwrap()
                + r.get("swap_pulls").as_u64().unwrap()
                + r.get("polish_pulls").as_u64().unwrap()
        );
        let sizes = r.get("cluster_sizes").as_array().unwrap();
        let total: usize = sizes.iter().map(|s| s.as_usize().unwrap()).sum();
        assert_eq!(total, 2000);
        assert!(matches!(r.get("assignments"), Value::Null), "assignments are opt-in");

        // Determinism through the cached session: same seed, same answer.
        let r2 = state.handle(&req(r#"{"op":"kmedoids","dataset":"mix","k":5,"seed":1}"#));
        assert_eq!(
            r2.get("medoids").as_array().unwrap(),
            medoids,
            "cached-session rerun diverged"
        );

        // Opt-in assignments round-trip, and the run counter advances.
        let r3 = state.handle(&req(
            r#"{"op":"kmedoids","dataset":"mix","k":3,"seed":0,"assignments":true}"#,
        ));
        assert_eq!(r3.get("assignments").as_array().unwrap().len(), 2000);
        let m = state.handle(&req(r#"{"op":"metrics"}"#));
        assert_eq!(m.get("kmedoids_runs").as_u64(), Some(3));
        assert_eq!(m.get("engine_cache").get("nan_pulls").as_u64(), Some(0));
        assert_eq!(m.get("engine_cache").get("misses").as_u64(), Some(1), "one preparation");
    }

    #[test]
    fn kmedoids_op_error_paths() {
        let state = State::new();
        register_toy(&state, "toy");
        for bad in [
            r#"{"op":"kmedoids","dataset":"missing","k":3}"#,
            r#"{"op":"kmedoids","dataset":"toy","k":0}"#,
            r#"{"op":"kmedoids","dataset":"toy","k":5000}"#,
            r#"{"op":"kmedoids","dataset":"toy","k":3,"build_pulls_per_arm":-1}"#,
        ] {
            let r = state.handle(&req(bad));
            assert_eq!(r.get("ok").as_bool(), Some(false), "should fail: {bad}");
        }
    }

    #[test]
    fn stats_and_unregister_flow() {
        let state = State::new();
        register_toy(&state, "toy");
        let s = state.handle(&req(r#"{"op":"stats","dataset":"toy"}"#));
        assert_eq!(s.get("ok").as_bool(), Some(true));
        assert_eq!(s.get("medoid").as_usize(), Some(0));
        assert!(s.get("gain_ratio").as_f64().unwrap() > 0.0);

        let u = state.handle(&req(r#"{"op":"unregister","name":"toy"}"#));
        assert_eq!(u.get("ok").as_bool(), Some(true));
        assert_eq!(u.get("removed").as_bool(), Some(true));
        let r = state.handle(&req(r#"{"op":"medoid","dataset":"toy","seed":0}"#));
        assert!(r.get("error").as_str().unwrap().contains("not registered"));
        let l = state.handle(&req(r#"{"op":"list"}"#));
        assert_eq!(l.get("datasets").as_array().unwrap().len(), 0);
        // double-unregister is an error
        let u2 = state.handle(&req(r#"{"op":"unregister","name":"toy"}"#));
        assert_eq!(u2.get("ok").as_bool(), Some(false));
        // cache entries for the name are gone
        let m = state.handle(&req(r#"{"op":"metrics"}"#));
        assert_eq!(m.get("engine_cache").get("entries").as_u64(), Some(0));
    }

    #[test]
    fn executor_roundtrip_and_shutdown() {
        let state = State::new();
        register_toy(&state, "toy");
        let exec = Executor::new(state, 2, 4);
        assert_eq!(exec.workers(), 2);
        let r = exec.submit(req(r#"{"op":"ping"}"#));
        assert_eq!(r.get("pong").as_bool(), Some(true));
        let r = exec.submit(req(r#"{"op":"medoid","dataset":"toy","seed":1}"#));
        assert_eq!(r.get("ok").as_bool(), Some(true));
        // metrics through the executor gains the executor sub-object
        let m = exec.submit(req(r#"{"op":"metrics"}"#));
        assert_eq!(m.get("executor").get("queue_cap").as_usize(), Some(4));
        assert_eq!(m.get("executor").get("workers").as_usize(), Some(2));
        assert_eq!(m.get("executor").get("queue_depth").as_u64(), Some(0));
        exec.shutdown();
        let r = exec.submit(req(r#"{"op":"ping"}"#));
        assert_eq!(r.get("ok").as_bool(), Some(false));
        assert!(r.get("error").as_str().unwrap().contains("shutting down"));
        exec.shutdown(); // idempotent
    }

    #[test]
    fn executor_handles_concurrent_submitters_with_tiny_queue() {
        let state = State::new();
        let exec = Executor::new(state, 1, 1);
        std::thread::scope(|s| {
            for _ in 0..6 {
                let exec = &exec;
                s.spawn(move || {
                    for _ in 0..10 {
                        let r = exec.submit(json::parse(r#"{"op":"ping"}"#).unwrap());
                        assert_eq!(r.get("pong").as_bool(), Some(true));
                    }
                });
            }
        });
        assert_eq!(exec.queue_depth(), 0);
        assert_eq!(exec.state().requests.load(Ordering::Relaxed), 60);
        exec.shutdown();
    }

    #[test]
    fn tcp_roundtrip() {
        let state = State::new();
        state.handle(&req(
            r#"{"op":"register","name":"t","kind":"gaussian","n":100,"dim":4,"seed":0}"#,
        ));
        let addr = serve_background(state).unwrap();
        let mut sock = TcpStream::connect(addr).unwrap();
        sock.write_all(b"{\"op\":\"ping\"}\nnot json\n{\"op\":\"medoid\",\"dataset\":\"t\",\"seed\":3}\n")
            .unwrap();
        let mut reader = BufReader::new(sock.try_clone().unwrap());
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("pong"));
        line.clear();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("bad json"));
        line.clear();
        reader.read_line(&mut line).unwrap();
        let resp = json::parse(line.trim()).unwrap();
        assert_eq!(resp.get("ok").as_bool(), Some(true));
        assert_eq!(resp.get("medoid").as_usize(), Some(0));
    }

    #[test]
    fn tcp_concurrent_clients_are_deterministic_per_seed() {
        // ≥4 concurrent clients, each with its own seed; every response
        // must equal the single-threaded reference answer for that seed.
        let reference = State::new();
        register_toy(&reference, "toy");
        let mut expect = Vec::new();
        for seed in 0u64..4 {
            let r = reference.handle(&req(&format!(
                r#"{{"op":"medoid","dataset":"toy","pulls_per_arm":48,"seed":{seed}}}"#
            )));
            expect.push((r.get("medoid").as_usize().unwrap(), r.get("pulls").as_u64().unwrap()));
        }

        let state = State::new();
        register_toy(&state, "toy");
        let cfg = ServerConfig { workers: 4, queue_cap: 8, ..Default::default() };
        let addr = serve_background_with(state, &cfg).unwrap();
        std::thread::scope(|s| {
            for (seed, (medoid, pulls)) in expect.iter().enumerate() {
                s.spawn(move || {
                    let mut sock = TcpStream::connect(addr).unwrap();
                    let mut reader = BufReader::new(sock.try_clone().unwrap());
                    let mut line = String::new();
                    for _ in 0..3 {
                        sock.write_all(
                            format!(
                                "{{\"op\":\"medoid\",\"dataset\":\"toy\",\
                                 \"pulls_per_arm\":48,\"seed\":{seed}}}\n"
                            )
                            .as_bytes(),
                        )
                        .unwrap();
                        line.clear();
                        reader.read_line(&mut line).unwrap();
                        let resp = json::parse(line.trim()).unwrap();
                        assert_eq!(resp.get("ok").as_bool(), Some(true), "{resp}");
                        assert_eq!(resp.get("medoid").as_usize(), Some(*medoid), "seed {seed}");
                        assert_eq!(resp.get("pulls").as_u64(), Some(*pulls), "seed {seed}");
                    }
                });
            }
        });
    }

    #[test]
    fn tcp_shutdown_op_stops_the_server() {
        let state = State::new();
        let addr = serve_background(state.clone()).unwrap();
        let mut sock = TcpStream::connect(addr).unwrap();
        let mut reader = BufReader::new(sock.try_clone().unwrap());
        sock.write_all(b"{\"op\":\"shutdown\"}\n").unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("shutting_down"));
        assert!(state.shutting_down());
        // The accept loop exits and the listener is dropped: within a
        // bounded window new connections must stop being served.
        let mut stopped = false;
        for _ in 0..100 {
            match TcpStream::connect(addr) {
                Err(_) => {
                    stopped = true;
                    break;
                }
                Ok(mut probe) => {
                    // Connection may still land in the accept backlog; a
                    // served probe would get a response, an unserved one
                    // gets EOF.
                    let _ = probe.write_all(b"{\"op\":\"ping\"}\n");
                    let mut r = BufReader::new(probe);
                    let mut l = String::new();
                    if matches!(r.read_line(&mut l), Ok(0)) {
                        stopped = true;
                        break;
                    }
                }
            }
            std::thread::sleep(std::time::Duration::from_millis(20));
        }
        assert!(stopped, "server kept serving after shutdown op");
    }
}
