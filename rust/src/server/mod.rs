//! Medoid service: a small deployable front-end for the library.
//!
//! Line-delimited JSON over TCP (std::net threads; tokio is outside the
//! offline dependency closure). Datasets are registered once (generated or
//! loaded), engines + ground work are cached, and each request runs a
//! medoid query with its own seed/budget:
//!
//! ```text
//! → {"op":"register","name":"cells","kind":"rnaseq","n":2000,"dim":256,"seed":1}
//! ← {"ok":true,"name":"cells","n":2000}
//! → {"op":"medoid","dataset":"cells","algo":"corrsh","pulls_per_arm":24,"seed":7}
//! ← {"ok":true,"medoid":412,"pulls":52000,"wall_ms":8.3}
//! → {"op":"stats","dataset":"cells"}         # Δ/ρ/H₂ summary
//! → {"op":"list"}                            # registered datasets
//! → {"op":"ping"}
//! ```

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{Context, Result};

use crate::bandits::MedoidAlgorithm;
use crate::config::AlgoConfig;
use crate::data::synth::{Kind, SynthConfig};
use crate::data::Data;
use crate::distance::Metric;
use crate::engine::NativeEngine;
use crate::util::json::{self, Value};
use crate::util::rng::Rng;

struct Entry {
    data: Arc<Data>,
    metric: Metric,
}

/// Shared server state: the dataset registry + request counters.
#[derive(Default)]
pub struct State {
    datasets: Mutex<HashMap<String, Arc<Entry>>>,
    pub requests: AtomicU64,
    pub errors: AtomicU64,
}

impl State {
    pub fn new() -> Arc<Self> {
        Arc::new(State::default())
    }

    fn get(&self, name: &str) -> Result<Arc<Entry>> {
        self.datasets
            .lock()
            .unwrap()
            .get(name)
            .cloned()
            .with_context(|| format!("dataset {name:?} not registered"))
    }

    /// Handle one request object → response object. Pure (no I/O), so the
    /// protocol is unit-testable without sockets.
    pub fn handle(&self, req: &Value) -> Value {
        self.requests.fetch_add(1, Ordering::Relaxed);
        match self.dispatch(req) {
            Ok(v) => v,
            Err(e) => {
                self.errors.fetch_add(1, Ordering::Relaxed);
                Value::from_pairs(vec![
                    ("ok", false.into()),
                    ("error", format!("{e:#}").into()),
                ])
            }
        }
    }

    fn dispatch(&self, req: &Value) -> Result<Value> {
        match req.get("op").as_str().context("missing op")? {
            "ping" => Ok(Value::from_pairs(vec![("ok", true.into()), ("pong", true.into())])),
            "list" => {
                let names: Vec<Value> = self
                    .datasets
                    .lock()
                    .unwrap()
                    .keys()
                    .map(|k| Value::Str(k.clone()))
                    .collect();
                Ok(Value::from_pairs(vec![
                    ("ok", true.into()),
                    ("datasets", Value::Array(names)),
                ]))
            }
            "register" => {
                let name = req.get("name").as_str().context("missing name")?.to_string();
                let kind: Kind = req.get("kind").as_str().context("missing kind")?.parse()?;
                let cfg = SynthConfig {
                    n: req.get("n").as_usize().unwrap_or(1000),
                    dim: req.get("dim").as_usize().unwrap_or(256),
                    seed: req.get("seed").as_f64().unwrap_or(0.0) as u64,
                    ..Default::default()
                };
                let metric = match req.get("metric").as_str() {
                    Some(m) => m.parse()?,
                    None => kind.default_metric(),
                };
                let data = Arc::new(kind.generate(&cfg));
                let n = data.n();
                self.datasets
                    .lock()
                    .unwrap()
                    .insert(name.clone(), Arc::new(Entry { data, metric }));
                Ok(Value::from_pairs(vec![
                    ("ok", true.into()),
                    ("name", name.into()),
                    ("n", n.into()),
                ]))
            }
            "medoid" => {
                let entry = self.get(req.get("dataset").as_str().context("missing dataset")?)?;
                let algo = build_algo(req, entry.data.n())?;
                let seed = req.get("seed").as_f64().unwrap_or(0.0) as u64;
                let engine = NativeEngine::with_threads(
                    entry.data.clone(),
                    entry.metric,
                    crate::util::threads::default_threads(),
                );
                let mut rng = Rng::seeded(seed);
                let res = algo.run(&engine, &mut rng);
                Ok(Value::from_pairs(vec![
                    ("ok", true.into()),
                    ("medoid", res.best.into()),
                    ("pulls", res.pulls.into()),
                    ("wall_ms", (res.wall.as_secs_f64() * 1e3).into()),
                    ("algo", algo.name().into()),
                ]))
            }
            "stats" => {
                let entry = self.get(req.get("dataset").as_str().context("missing dataset")?)?;
                let engine = NativeEngine::with_threads(
                    entry.data.clone(),
                    entry.metric,
                    crate::util::threads::default_threads(),
                );
                let mut rng = Rng::seeded(0);
                let st = crate::stats::instance_stats(
                    &engine,
                    256.min(entry.data.n()),
                    &mut rng,
                );
                Ok(Value::from_pairs(vec![
                    ("ok", true.into()),
                    ("medoid", st.medoid.into()),
                    ("sigma", st.sigma.into()),
                    ("h2", st.h2.into()),
                    ("h2_tilde", st.h2_tilde.into()),
                    ("gain_ratio", st.gain_ratio().into()),
                ]))
            }
            other => anyhow::bail!("unknown op {other:?}"),
        }
    }
}

fn build_algo(req: &Value, n: usize) -> Result<Box<dyn MedoidAlgorithm>> {
    let name = req.get("algo").as_str().unwrap_or("corrsh");
    let cfg = match name {
        "corrsh" => AlgoConfig::CorrSh {
            pulls_per_arm: req.get("pulls_per_arm").as_f64().unwrap_or(24.0),
        },
        "meddit" => AlgoConfig::Meddit {
            delta: req.get("delta").as_f64().unwrap_or(0.0),
            cap: req.get("cap").as_f64().unwrap_or(0.0) as u64,
        },
        "rand" => AlgoConfig::Rand {
            refs_per_arm: req.get("refs_per_arm").as_usize().unwrap_or(1000),
        },
        "exact" => AlgoConfig::Exact,
        other => anyhow::bail!("unknown algo {other:?}"),
    };
    Ok(cfg.build(n))
}

fn client_loop(state: Arc<State>, stream: TcpStream) {
    let peer = stream.peer_addr().map(|a| a.to_string()).unwrap_or_default();
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let Ok(line) = line else { break };
        if line.trim().is_empty() {
            continue;
        }
        let resp = match json::parse(&line) {
            Ok(req) => state.handle(&req),
            Err(e) => Value::from_pairs(vec![
                ("ok", false.into()),
                ("error", format!("bad json: {e}").into()),
            ]),
        };
        let mut out = json::to_string(&resp);
        out.push('\n');
        if writer.write_all(out.as_bytes()).is_err() {
            break;
        }
    }
    let _ = peer;
}

/// Serve forever on `addr` (e.g. "127.0.0.1:7878"). One thread per client.
pub fn serve(state: Arc<State>, addr: &str) -> Result<()> {
    let listener = TcpListener::bind(addr).with_context(|| format!("bind {addr}"))?;
    eprintln!("corrsh-serve listening on {}", listener.local_addr()?);
    for stream in listener.incoming() {
        match stream {
            Ok(s) => {
                let st = state.clone();
                std::thread::spawn(move || client_loop(st, s));
            }
            Err(e) => eprintln!("accept error: {e}"),
        }
    }
    Ok(())
}

/// Bind to an ephemeral port and serve in a background thread (tests/demo).
pub fn serve_background(state: Arc<State>) -> Result<std::net::SocketAddr> {
    let listener = TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?;
    std::thread::spawn(move || {
        for stream in listener.incoming().flatten() {
            let st = state.clone();
            std::thread::spawn(move || client_loop(st, stream));
        }
    });
    Ok(addr)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(s: &str) -> Value {
        json::parse(s).unwrap()
    }

    #[test]
    fn protocol_register_and_query() {
        let state = State::new();
        let r = state.handle(&req(
            r#"{"op":"register","name":"toy","kind":"gaussian","n":200,"dim":8,"seed":4}"#,
        ));
        assert_eq!(r.get("ok").as_bool(), Some(true));
        assert_eq!(r.get("n").as_usize(), Some(200));

        let r = state.handle(&req(
            r#"{"op":"medoid","dataset":"toy","algo":"corrsh","pulls_per_arm":48,"seed":1}"#,
        ));
        assert_eq!(r.get("ok").as_bool(), Some(true));
        assert_eq!(r.get("medoid").as_usize(), Some(0), "planted medoid");
        assert!(r.get("pulls").as_f64().unwrap() > 0.0);

        let r = state.handle(&req(r#"{"op":"list"}"#));
        assert_eq!(r.get("datasets").idx(0).as_str(), Some("toy"));
    }

    #[test]
    fn protocol_errors_are_reported() {
        let state = State::new();
        let r = state.handle(&req(r#"{"op":"medoid","dataset":"nope"}"#));
        assert_eq!(r.get("ok").as_bool(), Some(false));
        assert!(r.get("error").as_str().unwrap().contains("not registered"));
        let r = state.handle(&req(r#"{"op":"frobnicate"}"#));
        assert_eq!(r.get("ok").as_bool(), Some(false));
        assert_eq!(state.errors.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn tcp_roundtrip() {
        let state = State::new();
        state.handle(&req(
            r#"{"op":"register","name":"t","kind":"gaussian","n":100,"dim":4,"seed":0}"#,
        ));
        let addr = serve_background(state).unwrap();
        let mut sock = TcpStream::connect(addr).unwrap();
        sock.write_all(b"{\"op\":\"ping\"}\n{\"op\":\"medoid\",\"dataset\":\"t\",\"seed\":3}\n")
            .unwrap();
        let mut reader = BufReader::new(sock.try_clone().unwrap());
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("pong"));
        line.clear();
        reader.read_line(&mut line).unwrap();
        let resp = json::parse(line.trim()).unwrap();
        assert_eq!(resp.get("ok").as_bool(), Some(true));
        assert_eq!(resp.get("medoid").as_usize(), Some(0));
    }
}
