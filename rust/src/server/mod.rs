//! A line-delimited JSON protocol server exposing medoid queries over TCP.
//!
//! Layering (one file per concern):
//!
//! - [`proto`] — the protocol surface: v2 envelopes, the v1 compat shim,
//!   error codes, and the incremental newline [`proto::Framer`] with its
//!   request-size cap. Pure functions over bytes and JSON values.
//! - [`ops`] — request handlers: the dataset registry, the prepared-engine
//!   session cache, and envelope→result dispatch ([`State`]).
//! - [`exec`] — the bounded worker pool that runs ops off the I/O path and
//!   serializes wire frames ([`Executor`]).
//! - [`net`] — the transport: a raw epoll event loop (Linux) with
//!   nonblocking accept, pipelining, backpressure, and multi-tenant
//!   admission control; a thread-per-connection fallback elsewhere.
//!
//! Protocol v2 (one JSON object per line; responses to pipelined requests
//! are id-matched and may arrive out of order):
//!
//! ```text
//! → {"v":2,"id":1,"op":"register","params":{"name":"toy","kind":"gaussian","n":10000,"dim":32}}
//! ← {"id":1,"ok":true,"result":{"registered":"toy","n":10000,...}}
//! → {"v":2,"id":2,"op":"kmedoids","params":{"dataset":"toy","k":8,"stream":true}}
//! ← {"id":2,"ok":true,"partial":true,"seq":0,"result":{"phase":"build","step":0,"loss":...}}
//! ← {"id":2,"ok":true,"result":{"medoids":[...],...}}
//! → {"v":2,"id":3,"op":"medoid","params":{"dataset":"nope"}}
//! ← {"id":3,"ok":false,"error":{"code":"unknown_dataset","message":"..."}}
//! ```
//!
//! Bare v1 requests (`{"op":"ping"}`) keep working through a compat shim
//! that infers the envelope and flattens responses to the legacy in-order
//! shape; the `ping` reply carries a deprecation note.
//!
//! The same server binary plays two more roles (DESIGN.md §15): `corrsh
//! worker` runs it as a fleet worker (serving the `worker.prepare` /
//! `worker.pull` / `worker.health` plane), and `corrsh serve
//! --coordinator --workers-endpoints …` attaches a
//! [`crate::engine::DistRuntime`] to [`State`] so `register` fans out to
//! the fleet and `medoid` runs through the distributed engine with exact
//! per-segment reduction.

pub mod exec;
pub mod net;
pub mod ops;
pub mod proto;

pub use exec::{Executor, SubmitError};
pub use net::{
    event_loop_supported, raise_nofile_limit, serve, serve_background, serve_background_with,
    serve_with,
};
pub use ops::State;
