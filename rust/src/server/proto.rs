//! Protocol envelope: parse/serialize for the versioned request/response
//! envelope, the v1 compat shim, and the incremental newline framer.
//!
//! v2 requests are `{"v":2,"id":<u64>,"op":"...","params":{...}}`; every v2
//! response carries the request id so pipelined responses may return out of
//! order: `{"id":...,"ok":true,"result":{...}}`,
//! `{"id":...,"ok":true,"partial":true,"seq":N,"result":{...}}` for
//! streaming frames, or `{"id":...,"ok":false,"error":{"code":...,
//! "message":...}}`. Bare v1 requests (no `"v"` key) keep working: the shim
//! infers `v:1`, treats the whole object as params, and flattens responses
//! to the legacy one-object shapes.
//!
//! Everything here is pure bytes/values — no sockets, no state — so the
//! corpus test below can hammer the parser in isolation.

use std::collections::VecDeque;

use crate::util::json::{self, Value};

/// Default cap on one framed request line (see `ServerConfig`).
pub const DEFAULT_MAX_REQUEST_BYTES: usize = 1 << 20;

/// Deprecation note attached to v1 `ping` replies.
pub const V1_DEPRECATION: &str =
    "v1 protocol is deprecated; send {\"v\":2,\"id\":N,\"op\":\"...\",\"params\":{...}}";

/// Structured error classification for the v2 envelope. v1 responses carry
/// only the message (stringly, as before).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ErrorCode {
    BadRequest,
    UnknownDataset,
    Overloaded,
    ShuttingDown,
    Internal,
}

impl ErrorCode {
    pub fn as_str(self) -> &'static str {
        match self {
            ErrorCode::BadRequest => "bad_request",
            ErrorCode::UnknownDataset => "unknown_dataset",
            ErrorCode::Overloaded => "overloaded",
            ErrorCode::ShuttingDown => "shutting_down",
            ErrorCode::Internal => "internal",
        }
    }
}

/// A failed operation: code for machines, message for humans. Messages use
/// the crate error's full context chain so v1 error strings are unchanged.
#[derive(Clone, Debug)]
pub struct OpError {
    pub code: ErrorCode,
    pub message: String,
}

impl OpError {
    pub fn new(code: ErrorCode, message: impl Into<String>) -> Self {
        OpError { code, message: message.into() }
    }

    pub fn bad_request(message: impl Into<String>) -> Self {
        Self::new(ErrorCode::BadRequest, message)
    }

    pub fn overloaded(message: impl Into<String>) -> Self {
        Self::new(ErrorCode::Overloaded, message)
    }

    pub fn shutting_down() -> Self {
        Self::new(ErrorCode::ShuttingDown, "server shutting down")
    }

    pub fn internal(message: impl Into<String>) -> Self {
        Self::new(ErrorCode::Internal, message)
    }

    /// Classify a crate error from an op body. Dataset-lookup failures are
    /// the one family with a dedicated code; everything else a handler
    /// reports is a caller mistake.
    pub fn classify(e: crate::util::error::Error) -> Self {
        let message = format!("{e:#}");
        let code = if message.contains("not registered") {
            ErrorCode::UnknownDataset
        } else {
            ErrorCode::BadRequest
        };
        OpError { code, message }
    }
}

/// A parsed request, normalized across protocol versions: v1 requests get
/// `v:1`, a `Null` id, and the whole request object as `params`.
#[derive(Clone, Debug)]
pub struct Envelope {
    pub v: u8,
    /// Raw id value, echoed verbatim in every response (`Null` for v1).
    pub id: Value,
    /// Op name; empty when a v1 request had no `"op"` key (dispatch then
    /// reports the legacy "missing op" error).
    pub op: String,
    pub params: Value,
}

/// Infallible v1 shim: any JSON object becomes an envelope; bad shapes
/// surface through dispatch so v1 error strings stay byte-identical.
pub fn v1_envelope(req: &Value) -> Envelope {
    Envelope {
        v: 1,
        id: Value::Null,
        op: req.get("op").as_str().unwrap_or("").to_string(),
        params: req.clone(),
    }
}

/// What to echo when a request can't even be parsed into an [`Envelope`]:
/// best-effort version and id (v2 only when a well-formed `"v":2` + id were
/// present) plus the error itself.
#[derive(Debug)]
pub struct ParseError {
    pub v: u8,
    pub id: Value,
    pub err: OpError,
}

impl ParseError {
    fn v1(err: OpError) -> Self {
        ParseError { v: 1, id: Value::Null, err }
    }
}

/// Parse one request line into an [`Envelope`].
pub fn parse_request(line: &str) -> Result<Envelope, ParseError> {
    let req = match json::parse(line) {
        Ok(v) => v,
        Err(e) => return Err(ParseError::v1(OpError::bad_request(format!("bad json: {e}")))),
    };
    match req.get("v") {
        Value::Null => Ok(v1_envelope(&req)),
        v if v.as_u64() == Some(1) => Ok(v1_envelope(&req)),
        v if v.as_u64() == Some(2) => {
            let id = req.get("id").clone();
            if id.as_u64().is_none() {
                return Err(ParseError::v1(OpError::bad_request(
                    "v2 request requires a non-negative integer id",
                )));
            }
            let op = match req.get("op").as_str() {
                Some(op) => op.to_string(),
                None => {
                    return Err(ParseError {
                        v: 2,
                        id,
                        err: OpError::bad_request("missing op"),
                    })
                }
            };
            let params = match req.get("params") {
                Value::Null => Value::from_pairs(vec![]),
                p => p.clone(),
            };
            Ok(Envelope { v: 2, id, op, params })
        }
        v => Err(ParseError::v1(OpError::bad_request(format!(
            "unsupported protocol version {v}"
        )))),
    }
}

/// The dataset a request touches, if any — the per-dataset admission quota
/// key (`dataset` for queries, `name` for registry ops).
pub fn dataset_of(env: &Envelope) -> Option<&str> {
    match env.params.get("dataset").as_str() {
        Some(d) => Some(d),
        None => env.params.get("name").as_str(),
    }
}

/// Serialize the final response for a request: v2 envelope, or the legacy
/// flattened v1 object (op results already carry `"ok":true`; v1 `ping`
/// replies gain the deprecation note).
pub fn wire_final(env: &Envelope, result: Result<Value, OpError>) -> Value {
    match result {
        Ok(mut r) => {
            if env.v >= 2 {
                Value::from_pairs(vec![
                    ("id", env.id.clone()),
                    ("ok", true.into()),
                    ("result", r),
                ])
            } else {
                if env.op == "ping" {
                    if let Value::Object(obj) = &mut r {
                        obj.insert("note".to_string(), V1_DEPRECATION.into());
                    }
                }
                r
            }
        }
        Err(e) => wire_error(env.v, &env.id, &e),
    }
}

/// One streaming frame (v2 only): same id, `partial:true`, a monotone `seq`.
pub fn wire_partial(env: &Envelope, seq: u64, result: Value) -> Value {
    Value::from_pairs(vec![
        ("id", env.id.clone()),
        ("ok", true.into()),
        ("partial", true.into()),
        ("seq", seq.into()),
        ("result", result),
    ])
}

/// An error response at either protocol version.
pub fn wire_error(v: u8, id: &Value, e: &OpError) -> Value {
    if v >= 2 {
        Value::from_pairs(vec![
            ("id", id.clone()),
            ("ok", false.into()),
            (
                "error",
                Value::from_pairs(vec![
                    ("code", e.code.as_str().into()),
                    ("message", e.message.as_str().into()),
                ]),
            ),
        ])
    } else {
        Value::from_pairs(vec![("ok", false.into()), ("error", e.message.as_str().into())])
    }
}

/// One framed unit off the wire.
#[derive(Debug, PartialEq, Eq)]
pub enum Frame {
    /// A complete request line (newline stripped, UTF-8, non-blank).
    Line(String),
    /// A line that exceeded the size cap; `len` is its full byte length.
    /// The framer resynchronizes at the next newline, so one oversized
    /// request costs one error response, not the connection.
    Oversized { len: usize },
    /// A complete line that was not valid UTF-8.
    Invalid,
}

/// Incremental newline framer with a hard per-line size cap: feed raw
/// socket chunks with [`Framer::push`], drain complete frames with
/// [`Framer::next_frame`]. Lines longer than the cap are discarded as they
/// stream in (bounded memory) and surface as one [`Frame::Oversized`].
pub struct Framer {
    buf: Vec<u8>,
    max: usize,
    /// Inside an over-cap line, counting bytes until the next newline.
    discarding: bool,
    discarded: usize,
    ready: VecDeque<Frame>,
}

impl Framer {
    pub fn new(max_request_bytes: usize) -> Self {
        Framer {
            buf: Vec::new(),
            max: max_request_bytes.max(1),
            discarding: false,
            discarded: 0,
            ready: VecDeque::new(),
        }
    }

    /// Bytes currently buffered for the incomplete tail line.
    pub fn pending_bytes(&self) -> usize {
        self.buf.len()
    }

    pub fn push(&mut self, chunk: &[u8]) {
        let mut rest = chunk;
        while !rest.is_empty() {
            match rest.iter().position(|&b| b == b'\n') {
                Some(pos) => {
                    let (head, tail) = rest.split_at(pos);
                    rest = &tail[1..];
                    if self.discarding {
                        self.ready
                            .push_back(Frame::Oversized { len: self.discarded + head.len() });
                        self.discarding = false;
                        self.discarded = 0;
                    } else if self.buf.len() + head.len() > self.max {
                        self.ready
                            .push_back(Frame::Oversized { len: self.buf.len() + head.len() });
                        self.buf.clear();
                    } else {
                        self.buf.extend_from_slice(head);
                        let complete = std::mem::take(&mut self.buf);
                        match String::from_utf8(complete) {
                            Ok(s) if s.trim().is_empty() => {}
                            Ok(s) => self.ready.push_back(Frame::Line(s)),
                            Err(_) => self.ready.push_back(Frame::Invalid),
                        }
                    }
                }
                None => {
                    if self.discarding {
                        self.discarded += rest.len();
                    } else if self.buf.len() + rest.len() > self.max {
                        self.discarding = true;
                        self.discarded = self.buf.len() + rest.len();
                        self.buf.clear();
                    } else {
                        self.buf.extend_from_slice(rest);
                    }
                    rest = &[];
                }
            }
        }
    }

    pub fn next_frame(&mut self) -> Option<Frame> {
        self.ready.pop_front()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn frames(framer: &mut Framer) -> Vec<Frame> {
        std::iter::from_fn(|| framer.next_frame()).collect()
    }

    #[test]
    fn v1_requests_infer_the_shim_envelope() {
        let env = parse_request(r#"{"op":"ping"}"#).unwrap();
        assert_eq!((env.v, env.op.as_str()), (1, "ping"));
        assert!(matches!(env.id, Value::Null));
        assert_eq!(env.params.get("op").as_str(), Some("ping"));
        // explicit v:1 behaves identically
        let env = parse_request(r#"{"v":1,"op":"list"}"#).unwrap();
        assert_eq!((env.v, env.op.as_str()), (1, "list"));
        // a v1 request without an op still parses; dispatch reports it
        let env = parse_request(r#"{"dataset":"x"}"#).unwrap();
        assert_eq!(env.op, "");
    }

    #[test]
    fn v2_requests_parse_and_validate() {
        let env =
            parse_request(r#"{"v":2,"id":7,"op":"medoid","params":{"dataset":"t"}}"#).unwrap();
        assert_eq!((env.v, env.op.as_str()), (2, "medoid"));
        assert_eq!(env.id.as_u64(), Some(7));
        assert_eq!(dataset_of(&env), Some("t"));
        // params are optional
        let env = parse_request(r#"{"v":2,"id":0,"op":"ping"}"#).unwrap();
        assert!(env.params.as_object().unwrap().is_empty());

        // id must be a non-negative integer
        let e = parse_request(r#"{"v":2,"op":"ping"}"#).unwrap_err();
        assert_eq!(e.err.code, ErrorCode::BadRequest);
        let e = parse_request(r#"{"v":2,"id":-1,"op":"ping"}"#).unwrap_err();
        assert!(e.err.message.contains("id"));
        // missing op echoes the id at v2
        let e = parse_request(r#"{"v":2,"id":9}"#).unwrap_err();
        assert_eq!((e.v, e.id.as_u64()), (2, Some(9)));
        assert_eq!(e.err.message, "missing op");
        // unknown versions are rejected
        let e = parse_request(r#"{"v":3,"id":1,"op":"ping"}"#).unwrap_err();
        assert!(e.err.message.contains("unsupported protocol version"));
        // garbage is a bad_request with the parser's message
        let e = parse_request("not json").unwrap_err();
        assert!(e.err.message.starts_with("bad json: "));
    }

    #[test]
    fn wire_shapes_round_trip() {
        let v2 = parse_request(r#"{"v":2,"id":3,"op":"ping"}"#).unwrap();
        let ok = wire_final(&v2, Ok(Value::from_pairs(vec![("pong", true.into())])));
        assert_eq!(ok.get("id").as_u64(), Some(3));
        assert_eq!(ok.get("ok").as_bool(), Some(true));
        assert_eq!(ok.get("result").get("pong").as_bool(), Some(true));
        assert!(matches!(ok.get("partial"), Value::Null));

        let part = wire_partial(&v2, 2, Value::from_pairs(vec![("loss", 1.5.into())]));
        assert_eq!(part.get("partial").as_bool(), Some(true));
        assert_eq!(part.get("seq").as_u64(), Some(2));
        assert_eq!(part.get("id").as_u64(), Some(3));

        let err = wire_final(&v2, Err(OpError::overloaded("queue full")));
        assert_eq!(err.get("ok").as_bool(), Some(false));
        assert_eq!(err.get("error").get("code").as_str(), Some("overloaded"));
        assert_eq!(err.get("error").get("message").as_str(), Some("queue full"));

        // v1 flattening: the result object passes through unchanged...
        let v1 = parse_request(r#"{"op":"list"}"#).unwrap();
        let flat = wire_final(
            &v1,
            Ok(Value::from_pairs(vec![("ok", true.into()), ("datasets", Value::Array(vec![]))])),
        );
        assert!(matches!(flat.get("id"), Value::Null));
        assert_eq!(flat.get("ok").as_bool(), Some(true));
        // ...errors flatten to the stringly legacy shape...
        let flat = wire_final(&v1, Err(OpError::bad_request("missing op")));
        assert_eq!(flat.get("error").as_str(), Some("missing op"));
        // ...and ping gains the deprecation note.
        let ping = parse_request(r#"{"op":"ping"}"#).unwrap();
        let flat = wire_final(
            &ping,
            Ok(Value::from_pairs(vec![("ok", true.into()), ("pong", true.into())])),
        );
        assert!(flat.get("note").as_str().unwrap().contains("deprecated"));
    }

    #[test]
    fn framer_splits_reassembles_and_caps() {
        let mut f = Framer::new(64);
        f.push(b"{\"op\":\"ping\"}\n");
        assert_eq!(frames(&mut f), vec![Frame::Line("{\"op\":\"ping\"}".into())]);

        // split across arbitrary read boundaries
        f.push(b"{\"op\":");
        assert!(f.next_frame().is_none());
        f.push(b"\"list\"}");
        f.push(b"\n{\"op\":\"x\"}\n\n  \n");
        assert_eq!(
            frames(&mut f),
            vec![Frame::Line("{\"op\":\"list\"}".into()), Frame::Line("{\"op\":\"x\"}".into())]
        );

        // an oversized line is dropped with bounded memory, and the framer
        // resynchronizes on the next newline
        let big = vec![b'x'; 200];
        f.push(&big);
        assert!(f.pending_bytes() == 0, "over-cap bytes must not be buffered");
        f.push(&big);
        f.push(b"\n{\"op\":\"after\"}\n");
        assert_eq!(
            frames(&mut f),
            vec![Frame::Oversized { len: 400 }, Frame::Line("{\"op\":\"after\"}".into())]
        );

        // a single push containing an over-cap line mid-chunk
        let mut f = Framer::new(8);
        f.push(b"0123456789ABCDEF\nok\n");
        assert_eq!(
            frames(&mut f),
            vec![Frame::Oversized { len: 16 }, Frame::Line("ok".into())]
        );

        // invalid UTF-8 surfaces as its own frame
        let mut f = Framer::new(64);
        f.push(&[0xff, 0xfe, b'\n']);
        assert_eq!(frames(&mut f), vec![Frame::Invalid]);
    }

    /// Deterministic fuzz-style corpus: random envelopes — valid v1/v2,
    /// worker-plane frames (bit-pattern partial sums, including >2⁵³
    /// string-encoded bits), truncated, garbage, oversized, split across
    /// arbitrary chunk boundaries — must never panic, and every complete
    /// valid line must parse to the same envelope it does unsplit. A final
    /// pass restarts the framer mid-corpus (a worker reconnect) and must
    /// classify identically.
    #[test]
    fn corpus_of_malformed_and_split_envelopes() {
        use crate::engine::distributed::bits_value;
        let mut rng = Rng::seeded(0xC0FFEE);
        let cap = 256;
        let mut corpus: Vec<Vec<u8>> = Vec::new();
        for i in 0..200u64 {
            let kind = rng.below(8);
            let line: Vec<u8> = match kind {
                0 => format!(r#"{{"op":"ping","tag":{i}}}"#).into_bytes(),
                1 => format!(r#"{{"v":2,"id":{i},"op":"medoid","params":{{"dataset":"d"}}}}"#)
                    .into_bytes(),
                2 => {
                    // truncated prefix of a valid request
                    let full = format!(r#"{{"v":2,"id":{i},"op":"list","params":{{}}}}"#);
                    let cut = 1 + rng.below(full.len() as u64 - 1) as usize;
                    full.into_bytes()[..cut].to_vec()
                }
                3 => (0..rng.below(40) + 1)
                    .map(|_| match rng.below(256) as u8 {
                        b'\n' => b'x', // newlines would change the framing
                        b => b,
                    })
                    .collect(),
                4 => vec![b'z'; cap + 1 + rng.below(200) as usize],
                5 => {
                    // a worker.pull partial-sum response as the worker
                    // writes it: f64 bit patterns above 2⁵³ ride as decimal
                    // strings (engine::distributed wire rule)
                    let sums = Value::Array(vec![Value::Array(vec![
                        bits_value(rng.next_u64() | (1 << 60)),
                        bits_value(rng.below(1000)),
                    ])]);
                    json::to_string(&Value::from_pairs(vec![
                        ("id", i.into()),
                        ("ok", true.into()),
                        ("result", Value::from_pairs(vec![("sums", sums), ("pulls", 8.into())])),
                    ]))
                    .into_bytes()
                }
                6 => format!(
                    r#"{{"v":2,"id":{i},"op":"worker.pull","params":{{"ref_groups":[[1,2]]}}}}"#
                )
                .into_bytes(),
                _ => format!(r#"{{"v":{},"id":1,"op":"ping"}}"#, rng.below(9)).into_bytes(),
            };
            corpus.push(line);
        }

        // Reference pass: whole lines, one frame each.
        let mut expect: Vec<Option<bool>> = Vec::new(); // Some(parsed ok) per surviving frame
        for line in &corpus {
            let mut f = Framer::new(cap);
            f.push(line);
            f.push(b"\n");
            match f.next_frame() {
                Some(Frame::Line(s)) => expect.push(Some(parse_request(&s).is_ok())),
                Some(Frame::Oversized { len }) => {
                    assert_eq!(len, line.len());
                    expect.push(None);
                }
                Some(Frame::Invalid) => expect.push(None),
                None => expect.push(None), // blank line
            }
            assert!(f.next_frame().is_none());
        }

        // Split pass: the same corpus as one byte stream, pushed in random
        // chunk sizes — classification must be identical.
        let mut stream: Vec<u8> = Vec::new();
        for line in &corpus {
            stream.extend_from_slice(line);
            stream.push(b'\n');
        }
        let mut f = Framer::new(cap);
        let mut off = 0;
        while off < stream.len() {
            let take = 1 + rng.below(17) as usize;
            let end = (off + take).min(stream.len());
            f.push(&stream[off..end]);
            off = end;
        }
        let mut got: Vec<Option<bool>> = Vec::new();
        while let Some(frame) = f.next_frame() {
            got.push(match frame {
                Frame::Line(s) => Some(parse_request(&s).is_ok()),
                _ => None,
            });
        }
        // Blank lines produce no frame in either pass; align by dropping
        // the reference's placeholder entries for blanks.
        let mut aligned = Vec::new();
        for (line, e) in corpus.iter().zip(&expect) {
            let blank = line.iter().all(|b| b.is_ascii_whitespace());
            if !blank {
                aligned.push(*e);
            }
        }
        assert_eq!(got, aligned, "split-across-read classification diverged");

        // Restart pass: a worker dies mid-corpus and its replacement opens
        // a fresh framer at a line boundary (the coordinator never splices
        // half-lines across reconnects — unread bytes die with the socket).
        // Classifications from the old and new channel concatenate to the
        // same reference sequence.
        let boundary: usize = corpus[..100].iter().map(|l| l.len() + 1).sum();
        let mut after_restart: Vec<Option<bool>> = Vec::new();
        for part in [&stream[..boundary], &stream[boundary..]] {
            let mut f = Framer::new(cap);
            let mut off = 0;
            while off < part.len() {
                let take = 1 + rng.below(17) as usize;
                let end = (off + take).min(part.len());
                f.push(&part[off..end]);
                off = end;
            }
            while let Some(frame) = f.next_frame() {
                after_restart.push(match frame {
                    Frame::Line(s) => Some(parse_request(&s).is_ok()),
                    _ => None,
                });
            }
        }
        assert_eq!(after_restart, aligned, "mid-stream framer restart diverged");
    }
}
